//! Quickstart: the end-to-end ECORE driver.
//!
//! Loads the AOT artifacts, profiles the device fleet on a small
//! synthetic set, selects the Table-1 testbed, deploys the node pool,
//! serves 100 COCO-like images through the Edge-Detection (ED) router,
//! and reports the paper's four metrics against the LE/HMG reference
//! points. Run with:
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;

use ecore::config::ExperimentConfig;
use ecore::dataset::coco;
use ecore::experiments::serve::{
    deployed_store, print_panel, run_router_on_dataset,
};
use ecore::experiments::Harness;
use ecore::gateway::router_by_name;

fn main() -> Result<()> {
    // 1) harness: PJRT engine + profiling cache under results/
    let cfg = ExperimentConfig {
        profile_per_group: 16, // small but enough for stable ordering
        coco_images: 100,
        ..Default::default()
    };
    let h = Harness::new(cfg)?;

    // 2) profile the 8x8 fleet and restrict to the Table-1 testbed
    let deployed = deployed_store(&h)?;
    println!("deployed testbed ({} pairs):", deployed.pairs().len());
    for p in deployed.pairs() {
        println!("  {p}");
    }

    // 3) serve 100 images through three routers and compare
    let ds = coco::build(h.cfg.coco_images, h.cfg.seed);
    let mut runs = Vec::new();
    for name in ["LE", "HMG", "ED"] {
        let spec = router_by_name(name).unwrap();
        let m = run_router_on_dataset(&h, spec, &deployed, &ds)?;
        runs.push(m);
    }
    print_panel("quickstart", &runs);

    let (secs, count) = h.engine.exec_stats();
    println!(
        "PJRT executed {count} inferences in {secs:.2}s wall ({:.1} ms each)",
        1000.0 * secs / count.max(1) as f64
    );
    Ok(())
}
