//! Surveillance scenario (the paper's motivating use-case, Fig. 1): a
//! pedestrian-crossing camera feed served through the Output-Based (OB)
//! router, which exploits temporal continuity to avoid per-frame
//! estimation. Reports per-window metrics so the adaptation to crowd
//! density is visible.
//!
//! ```sh
//! cargo run --release --example surveillance -- [--frames 240]
//! ```

use anyhow::Result;

use ecore::config::ExperimentConfig;
use ecore::dataset::video;
use ecore::experiments::serve::deployed_store;
use ecore::experiments::Harness;
use ecore::gateway::{router_by_name, Gateway};
use ecore::metrics::RunMetrics;
use ecore::nodes::NodePool;
use ecore::util::cli::Args;
use ecore::workload;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let frames_n = args.usize_or("frames", 240);
    let window = args.usize_or("window", 60);

    let cfg = ExperimentConfig {
        profile_per_group: 16,
        video_frames: frames_n,
        ..Default::default()
    };
    let h = Harness::new(cfg)?;
    let deployed = deployed_store(&h)?;

    println!("generating {frames_n}-frame pedestrian stream...");
    let frames = video::build_frames(frames_n, h.cfg.seed ^ 0x71DE);
    let pseudo = workload::pseudo_annotate(&h.engine, &frames)?;

    let pool = NodePool::deploy(
        &h.engine,
        &deployed.pairs(),
        &ecore::devices::fleet(),
        h.cfg.seed,
    )?;
    let mut gw = Gateway::new(
        &h.engine,
        router_by_name("OB").unwrap(),
        deployed,
        pool,
        h.cfg.delta_map,
        h.cfg.seed,
    );

    println!(
        "{:>10} {:>8} {:>10} {:>12} {:>14}",
        "window", "frames", "mean_objs", "energy_mWh", "latency_ms/frm"
    );
    let mut total = RunMetrics::new("OB");
    for (wi, chunk) in frames.chunks(window).enumerate() {
        let gts = &pseudo[wi * window..wi * window + chunk.len()];
        let mut m = RunMetrics::new("OB");
        for (scene, gt) in chunk.iter().zip(gts.iter()) {
            gw.handle(&scene.image, gt.len(), gt, &mut m)?;
        }
        let mean_objs = chunk
            .iter()
            .map(|f| f.gt.len() as f64)
            .sum::<f64>()
            / chunk.len() as f64;
        println!(
            "{:>10} {:>8} {:>10.2} {:>12.3} {:>14.2}",
            wi,
            chunk.len(),
            mean_objs,
            m.total_energy_mwh(),
            1000.0 * m.total_latency_s / chunk.len() as f64
        );
        // accumulate into the run total
        total.backend_energy_mwh += m.backend_energy_mwh;
        total.gateway_energy_mwh += m.gateway_energy_mwh;
        total.total_latency_s += m.total_latency_s;
        total.gateway_latency_s += m.gateway_latency_s;
        total.images.extend(m.images);
        total.requests += m.requests;
        total.est_abs_err_sum += m.est_abs_err_sum;
    }
    println!(
        "\ntotal: {} frames, mAP {:.2} (vs yolov8x pseudo-labels), \
         {:.2} mWh, {:.2} s, mean estimation error {:.2}",
        total.requests,
        total.map(),
        total.total_energy_mwh(),
        total.total_latency_s,
        total.mean_estimation_error()
    );
    Ok(())
}
