//! Fleet profiling: run the offline profiler over the full 8-device x
//! 8-model grid (or a custom fleet from a TOML config), print the Fig. 5
//! Pareto table and the Table-1 testbed selection.
//!
//! ```sh
//! cargo run --release --example fleet_profile -- [--profile-per-group 24]
//! ```

use anyhow::Result;

use ecore::config::ExperimentConfig;
use ecore::experiments::Harness;
use ecore::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let mut cfg = ExperimentConfig {
        profile_per_group: 24,
        ..Default::default()
    };
    cfg.override_with(&args);

    let h = Harness::new(cfg)?;
    h.run("fig5")?;
    h.run("table1")?;
    Ok(())
}
