//! Delta tuning: pick the largest delta_mAP that keeps measured accuracy
//! within a user-given budget of the strictest setting — the operational
//! decision Insight #4 of the paper supports ("delta = 5 costs ~2% mAP
//! for large energy savings").
//!
//! ```sh
//! cargo run --release --example delta_tuning -- --router ED --budget 3.0
//! ```

use anyhow::Result;

use ecore::config::ExperimentConfig;
use ecore::dataset::coco;
use ecore::experiments::serve::{deployed_store, run_router_with_delta};
use ecore::experiments::Harness;
use ecore::gateway::router_by_name;
use ecore::util::cli::Args;
use ecore::util::stats::pct_change;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let router = args.str_or("router", "ED");
    let budget = args.f64_or("budget", 3.0); // acceptable mAP drop, points
    let images = args.usize_or("images", 150);

    let cfg = ExperimentConfig {
        profile_per_group: 16,
        coco_images: images,
        ..Default::default()
    };
    let h = Harness::new(cfg)?;
    let deployed = deployed_store(&h)?;
    let spec = router_by_name(&router)
        .ok_or_else(|| anyhow::anyhow!("unknown router {router}"))?;
    let ds = coco::build(images, h.cfg.seed);

    println!("tuning delta for {router}: accuracy budget {budget} mAP pts");
    let strict = run_router_with_delta(&h, spec, &deployed, &ds, 0.0)?;
    println!(
        "delta=0 (strict): mAP {:.2}, energy {:.2} mWh",
        strict.map(),
        strict.total_energy_mwh()
    );

    let mut chosen = (0.0, strict.map(), strict.total_energy_mwh());
    for delta in [5.0, 10.0, 15.0, 20.0, 25.0] {
        let m = run_router_with_delta(&h, spec, &deployed, &ds, delta)?;
        let drop = strict.map() - m.map();
        let savings = pct_change(
            strict.total_energy_mwh(),
            m.total_energy_mwh(),
        );
        println!(
            "delta={delta:<4} mAP {:.2} (drop {drop:+.2}) energy {:.2} mWh ({savings:+.1}%)",
            m.map(),
            m.total_energy_mwh()
        );
        if drop <= budget {
            chosen = (delta, m.map(), m.total_energy_mwh());
        }
    }
    println!(
        "\nchosen delta = {} (mAP {:.2}, energy {:.2} mWh, {:+.1}% vs strict)",
        chosen.0,
        chosen.1,
        chosen.2,
        pct_change(strict.total_energy_mwh(), chosen.2)
    );
    Ok(())
}
