//! SF estimator helpers: the SSD-based front-end shares the backend
//! decode path (`detection::decode_heatmap` on the `ssd_front` artifact);
//! this module only adds the count extraction and a calibration hook.

use crate::detection::Detection;

/// Object count from front-end detections. Kept as its own function so
/// calibration (e.g. discounting low-score detections) has a seam.
pub fn count_from_detections(dets: &[Detection]) -> usize {
    dets.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::detection::BBox;

    #[test]
    fn counts_detections() {
        let d = |s: f32| Detection {
            bbox: BBox::new(0.0, 0.0, 10.0, 10.0),
            score: s,
            cls: 0,
        };
        assert_eq!(count_from_detections(&[]), 0);
        assert_eq!(count_from_detections(&[d(0.5), d(0.2)]), 2);
    }
}
