//! Object-count estimators (paper §3.3): the lightweight gateway-side
//! component that feeds Algorithm 1.
//!
//! * `Oracle` — ground-truth count (ideal benchmark).
//! * `EdgeDetection` (ED) — Canny edge map (AOT HLO artifact) +
//!   hysteresis linking + contour counting ([`ed`]).
//! * `SsdFront` (SF) — tiny detector at the gateway ([`sf`]).
//! * `OutputBased` (OB) — reuse the previous response's detection count.
//!
//! Every estimate carries a [`GatewayCost`] so experiments can isolate
//! router overhead exactly as the paper's §4.2 "Gateway Overhead" metric.

pub mod ed;
pub mod sf;

use crate::detection::decode_heatmap;
use crate::devices::DeviceSpec;
use crate::models;
use crate::runtime::Engine;
use anyhow::Result;

/// Gateway-side cost of producing one estimate.
#[derive(Clone, Copy, Debug, Default)]
pub struct GatewayCost {
    pub latency_s: f64,
    pub energy_mwh: f64,
}

/// Estimator kinds, including the paper's short labels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EstimatorKind {
    /// §4.2 "Orc": the idealized benchmark — the ground-truth object
    /// count arrives as request metadata, so estimation is free and
    /// exact. Upper-bounds what any count estimator can contribute.
    /// Also the stand-in estimator for the count-agnostic baselines
    /// (RR, Rnd, LE, LI, HM) and the group input of HMG.
    Oracle,
    /// §4.2 "ED" (paper §3.3.1): Canny edge map computed at the gateway
    /// (AOT HLO artifact) + hysteresis linking + contour counting. The
    /// cheapest *image-deriving* estimator — coarse counts, tiny cost.
    EdgeDetection,
    /// §4.2 "SF" (paper §3.3.2): a tiny SSD front-end detector run at
    /// the gateway; its detection count is the estimate. More accurate
    /// than ED and proportionally more expensive.
    SsdFront,
    /// §4.2 "OB" (paper §3.3.3): output-based feedback — reuse the
    /// detection count of the *previous* routed response as the next
    /// estimate. Zero gateway cost, one-request lag; starts at 0.
    OutputBased,
}

impl EstimatorKind {
    pub fn label(&self) -> &'static str {
        match self {
            EstimatorKind::Oracle => "Orc",
            EstimatorKind::EdgeDetection => "ED",
            EstimatorKind::SsdFront => "SF",
            EstimatorKind::OutputBased => "OB",
        }
    }
}

/// A stateful estimator instance.
pub struct Estimator {
    kind: EstimatorKind,
    /// OB state: the object count observed in the previous response.
    last_count: usize,
    ed: ed::EdConfig,
}

impl Estimator {
    pub fn new(kind: EstimatorKind) -> Self {
        Self {
            kind,
            last_count: 0, // paper: OB starts from a default estimate of 0
            ed: ed::EdConfig::default(),
        }
    }

    pub fn kind(&self) -> EstimatorKind {
        self.kind
    }

    /// Estimate the number of objects in `image`.
    ///
    /// `true_count` is consumed only by the Oracle (the paper passes the
    /// ground-truth count as request metadata for that benchmark).
    pub fn estimate(
        &mut self,
        engine: &Engine,
        gateway: &DeviceSpec,
        image: &[f32],
        true_count: usize,
    ) -> Result<(usize, GatewayCost)> {
        match self.kind {
            EstimatorKind::Oracle => Ok((true_count, GatewayCost::default())),
            EstimatorKind::OutputBased => {
                Ok((self.last_count, GatewayCost::default()))
            }
            EstimatorKind::EdgeDetection => {
                let meta = engine.meta(models::CANNY_MODEL)?;
                let edges = engine.infer(models::CANNY_MODEL, image)?;
                let count =
                    ed::count_contours(&edges, meta.res, &self.ed);
                let p = gateway.profile(&meta);
                Ok((
                    count,
                    GatewayCost {
                        latency_s: p.latency_s,
                        energy_mwh: p.energy_mwh,
                    },
                ))
            }
            EstimatorKind::SsdFront => {
                let meta = engine.meta(models::FRONTEND_MODEL)?;
                let heat = engine.infer(models::FRONTEND_MODEL, image)?;
                let dets = decode_heatmap(&heat, &meta, 1.0);
                let p = gateway.profile(&meta);
                Ok((
                    dets.len(),
                    GatewayCost {
                        latency_s: p.latency_s,
                        energy_mwh: p.energy_mwh,
                    },
                ))
            }
        }
    }

    /// Feed back the detection count returned by the routed backend
    /// (drives the OB estimator; a no-op for the others).
    pub fn observe_response(&mut self, detected_count: usize) {
        self.last_count = detected_count;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::scene;
    use crate::dataset::SceneSpec;
    use crate::devices::gateway_spec;

    fn engine() -> Engine {
        Engine::new(&crate::default_artifacts_dir()).unwrap()
    }

    #[test]
    fn oracle_returns_truth_at_zero_cost() {
        let e = engine();
        let g = gateway_spec();
        let mut est = Estimator::new(EstimatorKind::Oracle);
        let img = vec![0.5f32; 384 * 384];
        let (c, cost) = est.estimate(&e, &g, &img, 7).unwrap();
        assert_eq!(c, 7);
        assert_eq!(cost.latency_s, 0.0);
        assert_eq!(cost.energy_mwh, 0.0);
    }

    #[test]
    fn output_based_replays_observations() {
        let e = engine();
        let g = gateway_spec();
        let mut est = Estimator::new(EstimatorKind::OutputBased);
        let img = vec![0.5f32; 384 * 384];
        // default estimate is 0
        assert_eq!(est.estimate(&e, &g, &img, 9).unwrap().0, 0);
        est.observe_response(4);
        assert_eq!(est.estimate(&e, &g, &img, 9).unwrap().0, 4);
        est.observe_response(2);
        assert_eq!(est.estimate(&e, &g, &img, 9).unwrap().0, 2);
    }

    #[test]
    fn ed_and_sf_track_scene_density() {
        let e = engine();
        let g = gateway_spec();
        let sparse = scene::render_spec(&SceneSpec {
            id: 0,
            seed: 21,
            n_objects: 1,
        });
        let crowded = scene::render_spec(&SceneSpec {
            id: 1,
            seed: 22,
            n_objects: 8,
        });
        for kind in [EstimatorKind::EdgeDetection, EstimatorKind::SsdFront] {
            let mut est = Estimator::new(kind);
            let (c_sparse, cost) =
                est.estimate(&e, &g, &sparse.image, 1).unwrap();
            let (c_crowded, _) =
                est.estimate(&e, &g, &crowded.image, 8).unwrap();
            assert!(cost.latency_s > 0.0 && cost.energy_mwh > 0.0);
            assert!(
                c_crowded > c_sparse,
                "{kind:?}: sparse {c_sparse} vs crowded {c_crowded}"
            );
        }
    }

    #[test]
    fn ed_cheaper_than_sf() {
        let e = engine();
        let g = gateway_spec();
        let img = vec![0.5f32; 384 * 384];
        let mut ed = Estimator::new(EstimatorKind::EdgeDetection);
        let mut sf = Estimator::new(EstimatorKind::SsdFront);
        let (_, ce) = ed.estimate(&e, &g, &img, 0).unwrap();
        let (_, cs) = sf.estimate(&e, &g, &img, 0).unwrap();
        assert!(ce.energy_mwh < cs.energy_mwh);
        assert!(ce.latency_s < cs.latency_s);
    }
}
