//! ED estimator back half: hysteresis linking + contour counting over the
//! Canny edge-class map produced by the `canny` HLO artifact.
//!
//! The artifact emits per-pixel classes {0: none, 1: weak, 2: strong}.
//! This module (1) links weak pixels 8-connected to strong seeds
//! (classic Canny hysteresis — graph traversal, so it lives in Rust, not
//! in the data-parallel kernel), (2) groups surviving pixels into
//! connected components, (3) merges components whose bounding boxes
//! nearly touch (one object's ring can shatter into arcs after NMS
//! thinning), and (4) counts the merged contours with enough support.

/// Tunables for contour counting.
#[derive(Clone, Copy, Debug)]
pub struct EdConfig {
    /// Minimum pixels for a contour to count as an object boundary.
    pub min_contour_px: usize,
    /// Merge components whose bounding boxes come within this distance.
    pub merge_dist_px: f64,
}

impl Default for EdConfig {
    fn default() -> Self {
        Self {
            min_contour_px: 8,
            merge_dist_px: 4.0,
        }
    }
}

/// Count contours in an edge-class map of size `res` x `res`.
pub fn count_contours(edges: &[f32], res: usize, cfg: &EdConfig) -> usize {
    debug_assert_eq!(edges.len(), res * res);

    // 1) hysteresis: BFS from strong pixels through weak neighbours,
    //    labelling components as we go.
    let mut label = vec![0u32; res * res]; // 0 = unvisited/none
    let mut next_label = 0u32;
    let mut queue: Vec<usize> = Vec::new();
    let mut comp_pixels: Vec<usize> = Vec::new(); // per-label pixel count
    let mut comp_bbox: Vec<(usize, usize, usize, usize)> = Vec::new();

    for start in 0..res * res {
        if edges[start] != 2.0 || label[start] != 0 {
            continue;
        }
        next_label += 1;
        let l = next_label;
        queue.clear();
        queue.push(start);
        label[start] = l;
        let (mut n_px, mut bb) =
            (0usize, (usize::MAX, usize::MAX, 0usize, 0usize));
        while let Some(i) = queue.pop() {
            n_px += 1;
            let (y, x) = (i / res, i % res);
            bb = (bb.0.min(x), bb.1.min(y), bb.2.max(x), bb.3.max(y));
            for dy in -1i64..=1 {
                for dx in -1i64..=1 {
                    if dy == 0 && dx == 0 {
                        continue;
                    }
                    let (ny, nx) = (y as i64 + dy, x as i64 + dx);
                    if ny < 0 || nx < 0 || ny >= res as i64 || nx >= res as i64
                    {
                        continue;
                    }
                    let j = ny as usize * res + nx as usize;
                    // hysteresis: weak pixels join only via a linked chain
                    if label[j] == 0 && edges[j] >= 1.0 {
                        label[j] = l;
                        queue.push(j);
                    }
                }
            }
        }
        comp_pixels.push(n_px);
        comp_bbox.push(bb);
    }

    // 2) merge near-touching components (broken rings) via union-find on
    //    bbox proximity.
    let n = comp_pixels.len();
    let mut parent: Vec<usize> = (0..n).collect();
    fn find(parent: &mut Vec<usize>, i: usize) -> usize {
        if parent[i] != i {
            let r = find(parent, parent[i]);
            parent[i] = r;
        }
        parent[i]
    }
    for i in 0..n {
        for j in (i + 1)..n {
            let a = comp_bbox[i];
            let b = comp_bbox[j];
            let gap_x = if a.2 < b.0 {
                (b.0 - a.2) as f64
            } else if b.2 < a.0 {
                (a.0 - b.2) as f64
            } else {
                0.0
            };
            let gap_y = if a.3 < b.1 {
                (b.1 - a.3) as f64
            } else if b.3 < a.1 {
                (a.1 - b.3) as f64
            } else {
                0.0
            };
            if gap_x <= cfg.merge_dist_px && gap_y <= cfg.merge_dist_px {
                let (ri, rj) = (find(&mut parent, i), find(&mut parent, j));
                if ri != rj {
                    parent[ri] = rj;
                }
            }
        }
    }

    // 3) count merged contours with enough pixel support
    let mut merged_px: std::collections::BTreeMap<usize, usize> =
        std::collections::BTreeMap::new();
    for i in 0..n {
        let r = find(&mut parent, i);
        *merged_px.entry(r).or_default() += comp_pixels[i];
    }
    merged_px
        .values()
        .filter(|&&px| px >= cfg.min_contour_px)
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ring(edges: &mut [f32], res: usize, cx: f64, cy: f64, r: f64) {
        // rasterize a 1px circle of strong pixels
        let steps = (r * 12.0) as usize + 16;
        for s in 0..steps {
            let a = s as f64 / steps as f64 * std::f64::consts::TAU;
            let x = (cx + r * a.cos()).round() as i64;
            let y = (cy + r * a.sin()).round() as i64;
            if x >= 0 && y >= 0 && (x as usize) < res && (y as usize) < res {
                edges[y as usize * res + x as usize] = 2.0;
            }
        }
    }

    #[test]
    fn empty_map_counts_zero() {
        let edges = vec![0.0f32; 96 * 96];
        assert_eq!(count_contours(&edges, 96, &EdConfig::default()), 0);
    }

    #[test]
    fn single_ring_counts_one() {
        let mut edges = vec![0.0f32; 96 * 96];
        ring(&mut edges, 96, 48.0, 48.0, 10.0);
        assert_eq!(count_contours(&edges, 96, &EdConfig::default()), 1);
    }

    #[test]
    fn three_separated_rings_count_three() {
        let mut edges = vec![0.0f32; 96 * 96];
        ring(&mut edges, 96, 20.0, 20.0, 8.0);
        ring(&mut edges, 96, 70.0, 20.0, 8.0);
        ring(&mut edges, 96, 48.0, 70.0, 8.0);
        assert_eq!(count_contours(&edges, 96, &EdConfig::default()), 3);
    }

    #[test]
    fn broken_ring_merges_to_one() {
        let mut edges = vec![0.0f32; 96 * 96];
        ring(&mut edges, 96, 48.0, 48.0, 10.0);
        // punch two 2px gaps
        for dx in 0..2usize {
            edges[48 * 96 + (58 - dx)] = 0.0;
            edges[(48 + 10) * 96 + 48 + dx] = 0.0;
        }
        assert_eq!(count_contours(&edges, 96, &EdConfig::default()), 1);
    }

    #[test]
    fn weak_pixels_join_only_via_strong_seed() {
        let mut edges = vec![0.0f32; 96 * 96];
        // an isolated weak-only blob: never counted
        for y in 10..14 {
            for x in 10..14 {
                edges[y * 96 + x] = 1.0;
            }
        }
        assert_eq!(count_contours(&edges, 96, &EdConfig::default()), 0);
        // add one strong seed inside -> now linked and counted
        edges[12 * 96 + 12] = 2.0;
        assert_eq!(count_contours(&edges, 96, &EdConfig::default()), 1);
    }

    #[test]
    fn tiny_specks_filtered() {
        let mut edges = vec![0.0f32; 96 * 96];
        edges[5 * 96 + 5] = 2.0; // 1px noise speck
        edges[60 * 96 + 60] = 2.0;
        assert_eq!(count_contours(&edges, 96, &EdConfig::default()), 0);
    }
}
