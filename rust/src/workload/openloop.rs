//! Open-loop concurrent workload driver (DESIGN.md §6).
//!
//! Where the closed loop fires each request only after the previous
//! response arrives, the open loop models *offered* traffic: arrivals
//! fire at a configurable rate regardless of completions, many requests
//! are in flight at once, and each edge node serves a bounded FIFO
//! queue. Busy nodes accumulate queueing delay; a full queue triggers
//! the gateway's existing fallback re-route path, and a request finding
//! every feasible queue full is dropped (load shedding). This is the
//! regime where the paper's routing policies actually diverge under
//! load — a router that piles requests onto the single lowest-energy
//! node pays for it in tail latency once the arrival rate approaches
//! that node's service rate.
//!
//! The driver is a deterministic discrete-event simulator: a binary
//! min-heap of (virtual time, sequence) events over the same virtual
//! clock the rest of ECORE uses. Arrival times come from a seeded
//! [`ArrivalProcess`]; service times come from the node models (real
//! PJRT inference + simulated device cost), so a whole run replays
//! bit-identically from its seeds.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, VecDeque};

use anyhow::Result;

use crate::adapt::{AdaptConfig, AdaptReport};
use crate::dataset::{Dataset, GtBox, Scene};
use crate::devices;
use crate::estimators::GatewayCost;
use crate::gateway::{amortize, Gateway, RoutedRequest};
use crate::lifecycle::campaign::{
    CampaignConfig, CampaignPlan, CampaignReport, PlanEvent,
};
use crate::lifecycle::{
    self, ChurnConfig, ChurnReport, ChurnState, LossOutcome,
    ResiliencePolicy,
};
use crate::metrics::{RunMetrics, SloMetrics};
use crate::nodes::{NodeDown, NodeResponse};
use crate::obs::{ObsConfig, ObsShard};
use crate::router::PairId;
use crate::util::rng::Rng;
use crate::workload::slo::{SloConfig, SloTag};

/// How requests arrive at the gateway.
#[derive(Clone, Debug)]
pub enum ArrivalProcess {
    /// Poisson arrivals: exponential inter-arrival times at `rate_rps`.
    Poisson { rate_rps: f64 },
    /// Deterministic pacing: one arrival every `gap_s` seconds.
    Uniform { gap_s: f64 },
    /// Trace replay: explicit arrival timestamps (s). The trace is
    /// sorted into nondecreasing order before use ([`Self::times`]), so
    /// an out-of-order trace cannot smuggle a negative inter-arrival
    /// gap into the simulator. Extra requests beyond the trace extend
    /// it by its last (sorted) gap; a single-element trace `[t]`
    /// extends with gap `t` (the gap from the implicit origin), so
    /// `[t]` yields `t, 2t, 3t, …`.
    Trace(Vec<f64>),
    /// Markov-modulated Poisson process: a 2-state phase chain where
    /// state `i` emits Poisson arrivals at `rates[i]` and dwells for an
    /// exponential time of mean `dwell_s` before switching. The
    /// burstiness knob for the churn/campaign sweeps — same mean rate
    /// as a Poisson process at the dwell-weighted average, but arrivals
    /// clump while the hot state holds. State switches redraw the
    /// pending gap (exponentials are memoryless, so this is exact).
    Mmpp { rates: [f64; 2], dwell_s: f64 },
}

impl ArrivalProcess {
    /// Materialize `n` arrival timestamps, deterministic in `seed`.
    pub fn times(&self, n: usize, seed: u64) -> Vec<f64> {
        match self {
            ArrivalProcess::Poisson { rate_rps } => {
                let mut rng = Rng::new(seed ^ 0x09E2_7A11);
                let mut t = 0.0;
                (0..n)
                    .map(|_| {
                        // inverse-CDF exponential sample; 1 - u in (0, 1]
                        t += -(1.0 - rng.f64()).ln() / rate_rps.max(1e-9);
                        t
                    })
                    .collect()
            }
            ArrivalProcess::Uniform { gap_s } => {
                (0..n).map(|i| (i + 1) as f64 * gap_s).collect()
            }
            ArrivalProcess::Trace(ts) => {
                // The trace documents nondecreasing timestamps but
                // nothing enforces it at construction; a decreasing
                // trace used to yield a negative last gap (silently
                // clamped to 1e-9) AND out-of-order arrivals. Sort
                // first so both the replayed prefix and the extension
                // gap are well defined.
                let mut sorted = ts.clone();
                sorted.sort_by(|a, b| a.total_cmp(b));
                let mut out: Vec<f64> =
                    sorted.iter().copied().take(n).collect();
                let last_gap = match sorted.len() {
                    0 => 1.0,
                    1 => sorted[0],
                    k => sorted[k - 1] - sorted[k - 2],
                };
                while out.len() < n {
                    let last = out.last().copied().unwrap_or(0.0);
                    out.push(last + last_gap.max(1e-9));
                }
                out
            }
            ArrivalProcess::Mmpp { rates, dwell_s } => {
                let mut rng = Rng::new(seed ^ 0x0330_77A2);
                let mut t = 0.0;
                let mut state = 0usize;
                let mut switch =
                    -(1.0 - rng.f64()).ln() * dwell_s.max(1e-9);
                let mut out = Vec::with_capacity(n);
                while out.len() < n {
                    let gap =
                        -(1.0 - rng.f64()).ln() / rates[state].max(1e-9);
                    if t + gap >= switch {
                        // phase switch before the next arrival: jump to
                        // the switch instant and redraw (memoryless)
                        t = switch;
                        state ^= 1;
                        switch = t
                            + -(1.0 - rng.f64()).ln() * dwell_s.max(1e-9);
                    } else {
                        t += gap;
                        out.push(t);
                    }
                }
                out
            }
        }
    }
}

/// Configuration of one open-loop run.
#[derive(Clone, Debug)]
pub struct OpenLoopConfig {
    pub arrivals: ArrivalProcess,
    /// Bounded per-node FIFO capacity (the in-service slot included).
    pub queue_capacity: usize,
    /// Seed for the arrival process (independent of the gateway seed).
    pub seed: u64,
    /// Node churn (DESIGN.md §9): ground-truth crash/rejoin events on
    /// the shared heap, probe-driven membership at the gateway, and a
    /// resilience policy for requests lost to crashes. `None` keeps the
    /// pre-churn event stream bit for bit.
    pub churn: Option<ChurnConfig>,
    /// SLO + batching (DESIGN.md §11): deadline classes with admission
    /// control, EDF queue ordering, and per-pair batch formation.
    /// `None` keeps the event stream bit-identical to the pre-SLO
    /// driver.
    pub slo: Option<SloConfig>,
    /// Online adaptation (DESIGN.md §12): telemetry-driven profile
    /// corrections on every completion, plus (when `scale` is set)
    /// energy-proportional autoscaling on a periodic decision tick.
    /// `None` keeps the event stream bit-identical to the
    /// pre-adaptation driver.
    pub adapt: Option<AdaptConfig>,
    /// Correlated failure campaign (DESIGN.md §15): domain-wide
    /// outages folded with per-node churn into one effective
    /// ground-truth timeline. Requires `churn`; the open loop has a
    /// single gateway, so gateway kills must be disabled. `None`
    /// keeps the event stream bit-identical to the pre-campaign
    /// driver.
    pub campaign: Option<CampaignConfig>,
    /// Observability (DESIGN.md §14): a passive collector folds every
    /// stage transition into span records and virtual-time series,
    /// exported at end of run. Schedules zero events either way;
    /// `None` collects nothing and keeps reports/traces bit-identical.
    pub obs: Option<ObsConfig>,
}

impl Default for OpenLoopConfig {
    fn default() -> Self {
        Self {
            arrivals: ArrivalProcess::Poisson { rate_rps: 8.0 },
            queue_capacity: 8,
            seed: 7,
            churn: None,
            slo: None,
            adapt: None,
            campaign: None,
            obs: None,
        }
    }
}

/// Outcome of one open-loop run.
#[derive(Clone, Debug)]
pub struct OpenLoopReport {
    /// Per-request accounting (energy, accuracy, queue delay, latency
    /// percentiles) over the *served* requests.
    pub metrics: RunMetrics,
    /// Requests offered by the arrival process
    /// (served + dropped + lost).
    pub offered: usize,
    /// Requests shed because every feasible queue was full.
    pub dropped: usize,
    /// Virtual time at which the last response left the system (s).
    pub makespan_s: f64,
    /// Peak number of requests simultaneously in the system
    /// (hedged duplicates count individually).
    pub peak_in_flight: usize,
    /// Fallback re-routes during this run (down or queue-full nodes),
    /// snapshotted from the gateway's cumulative counter.
    pub fallbacks: usize,
    /// Churn accounting — present exactly when the run had a lifecycle
    /// config. `served + dropped + lost == offered` always holds.
    pub churn: Option<ChurnReport>,
    /// SLO accounting (attainment per class, sheds, batch-size
    /// histogram) — present exactly when the run had an SLO config.
    pub slo: Option<SloMetrics>,
    /// Adaptation accounting (telemetry corrections, power
    /// transitions, idle-energy comparison vs a static fleet) —
    /// present exactly when the run had an adapt config.
    pub adapt: Option<AdaptReport>,
    /// Campaign schedule summary (domains, outages, mean duration) —
    /// present exactly when the run had a campaign config.
    pub campaign: Option<CampaignReport>,
}

impl OpenLoopReport {
    /// Served throughput over the run's virtual wall-clock (req/s).
    pub fn goodput_rps(&self) -> f64 {
        if self.makespan_s > 0.0 {
            self.metrics.requests as f64 / self.makespan_s
        } else {
            0.0
        }
    }

    /// Requests permanently lost to node crashes (0 without churn).
    pub fn lost(&self) -> usize {
        self.churn.as_ref().map(|c| c.lost).unwrap_or(0)
    }

    /// Mean dynamic energy per served request (mWh), the churn sweep's
    /// headline efficiency column.
    pub fn energy_per_request_mwh(&self) -> f64 {
        if self.metrics.requests > 0 {
            self.metrics.total_energy_mwh() / self.metrics.requests as f64
        } else {
            0.0
        }
    }

    /// Stable JSON report (field order fixed by the Json substrate's
    /// BTreeMap) — the golden-trace determinism tests compare this dump
    /// byte for byte across runs.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut fields = vec![
            ("offered", Json::num(self.offered as f64)),
            ("dropped", Json::num(self.dropped as f64)),
            ("lost", Json::num(self.lost() as f64)),
            ("fallbacks", Json::num(self.fallbacks as f64)),
            ("makespan_s", Json::num(self.makespan_s)),
            ("peak_in_flight", Json::num(self.peak_in_flight as f64)),
            ("goodput_rps", Json::num(self.goodput_rps())),
            ("metrics", self.metrics.to_json()),
        ];
        if let Some(c) = &self.churn {
            fields.push(("churn", c.to_json()));
        }
        if let Some(s) = &self.slo {
            fields.push(("slo", s.to_json()));
        }
        if let Some(a) = &self.adapt {
            fields.push(("adapt", a.to_json()));
        }
        if let Some(c) = &self.campaign {
            fields.push(("campaign", c.to_json()));
        }
        Json::obj(fields)
    }
}

/// One event on the virtual clock. Ordered by (time, sequence) so ties
/// resolve in insertion order and the whole run is deterministic.
///
/// NOTE: `fleet::run_frames` carries a shard-aware copy of this event
/// machinery (ordering, queue-delay formula, completion scheduling).
/// A fix to either copy must land in both — the golden-trace tests pin
/// each side's behavior.
struct Event {
    t: f64,
    seq: u64,
    kind: EventKind,
}

enum EventKind {
    /// Request `idx` arrives at the gateway.
    Arrival(usize),
    /// The in-service request on this node's queue completes. `token`
    /// identifies the service instance: a completion whose token no
    /// longer matches the queue's in-service slot belongs to a request
    /// that was lost to a crash and is ignored.
    Completion { pair: PairId, token: u64 },
    /// Ground-truth crash of pool node `node` (churn runs only): the
    /// node rejects traffic and everything queued on it is lost.
    Crash(usize),
    /// Ground-truth rejoin of pool node `node` (reboots its drift
    /// state). The gateway only learns of it through probes.
    Rejoin(usize),
    /// The gateway's periodic health probe fires: ground truth is
    /// snapshotted now, results apply after the probe timeout.
    Probe,
    /// Probe responses (pool order) reach the membership view.
    ProbeResult(Vec<bool>),
    /// Re-dispatch of request `idx` lost to a crash (retry policy).
    Retry(usize),
    /// A batch formation window on `pair` closes (SLO runs only).
    /// `token` identifies the formation generation: a new member
    /// reschedules the close, leaving earlier events stale.
    BatchClose { pair: PairId, token: u64 },
    /// The autoscaler's periodic decision tick (adapt runs with
    /// `scale` only): close the arrival-rate window and perform at
    /// most one power transition.
    ScaleTick,
    /// A failure domain tripped (`down`) or restored (campaign runs
    /// only): a pure observability marker — the member crashes ride
    /// alongside as ordinary `Crash`/`Rejoin` events.
    DomainMark { domain: usize, down: bool },
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq && self.t.total_cmp(&other.t).is_eq()
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t.total_cmp(&other.t).then(self.seq.cmp(&other.seq))
    }
}

/// A request admitted to a node's FIFO, waiting for service.
struct Pending {
    routed: RoutedRequest,
    idx: usize,
    arrival_s: f64,
    /// This copy is a hedged duplicate (its completion may be waste).
    hedge: bool,
    /// Deadline/batching tag; [`SloTag::default`] (inert) without SLOs.
    slo: SloTag,
}

/// The request a node is currently serving; the inference already ran
/// (its result is part of the completion event's payload).
struct InService {
    routed: RoutedRequest,
    idx: usize,
    arrival_s: f64,
    start_s: f64,
    resp: NodeResponse,
    /// Matches the scheduled completion event; a crash that loses this
    /// request leaves that event stale (token mismatch).
    token: u64,
    hedge: bool,
    slo: SloTag,
}

/// A batch under formation on one pair (SLO runs): members hold their
/// queue slots from admission, accumulate until the window closes, the
/// batch fills, or deadline slack runs out, then flush into the FIFO as
/// one contiguous amortized train.
struct Forming {
    members: Vec<Pending>,
    close_s: f64,
    /// Matches the live scheduled [`EventKind::BatchClose`]; each new
    /// member reschedules with a fresh token, staling earlier closes.
    token: u64,
}

impl Default for Forming {
    fn default() -> Self {
        Self { members: Vec::new(), close_s: f64::INFINITY, token: 0 }
    }
}

/// Per-node serving state: one in-service slot + FIFO backlog.
#[derive(Default)]
struct NodeQueue {
    serving: Option<InService>,
    backlog: VecDeque<Pending>,
}

/// Mutable simulator state threaded through the event handlers.
struct SimState {
    queues: BTreeMap<PairId, NodeQueue>,
    heap: BinaryHeap<Reverse<Event>>,
    seq: u64,
    dropped: usize,
    in_flight: usize,
    peak_in_flight: usize,
    makespan_s: f64,
    /// Per-pair batches under formation (always empty without SLOs).
    forming: BTreeMap<PairId, Forming>,
    /// Passive observability collector (`None` = obs off; the open
    /// loop is unsharded, so one shard-0 collector takes everything,
    /// run-level retries/abandons included).
    obs: Option<ObsShard>,
}

impl SimState {
    fn new() -> Self {
        Self {
            queues: BTreeMap::new(),
            heap: BinaryHeap::new(),
            seq: 0,
            dropped: 0,
            in_flight: 0,
            peak_in_flight: 0,
            makespan_s: 0.0,
            forming: BTreeMap::new(),
            obs: None,
        }
    }

    fn push(&mut self, t: f64, kind: EventKind) {
        self.heap.push(Reverse(Event {
            t,
            seq: self.seq,
            kind,
        }));
        self.seq += 1;
    }
}

/// Driver-side churn context: pool-ordered node identities (indexing
/// the ground-truth failure timeline and probe snapshots), the shared
/// request-copy accounting, and the per-request estimate cache that
/// lets retries re-enter routing without paying the estimator again.
struct ChurnDriver {
    pairs: Vec<PairId>,
    probe_timeout_s: f64,
    state: ChurnState,
    /// `(estimate, gateway cost)` paid at each request's first
    /// admission; retries route with these instead of re-estimating,
    /// so a request pays GatewayCost exactly once.
    est: Vec<Option<(usize, GatewayCost)>>,
    /// `(primary, hedge)` pair ids recorded at hedge dispatch;
    /// consumed by cancellation-on-first-response.
    hedge_pairs: Vec<Option<(PairId, PairId)>>,
    /// Cancel the losing sibling the instant the winner completes.
    hedge_cancel: bool,
}

/// Driver-side SLO context: the config, each request's absolute
/// deadline (precomputed from the materialized arrival times), and the
/// attainment/batch accounting.
struct SloRt {
    cfg: SloConfig,
    deadlines: Vec<f64>,
    metrics: SloMetrics,
}

impl SloRt {
    /// Record a completion or a shed outcome for request `idx`.
    fn record_done(&mut self, idx: usize, class: usize, done_s: f64) {
        self.metrics.record_completion(class, done_s <= self.deadlines[idx]);
    }

    fn shed(&mut self, idx: usize) {
        self.metrics.record_shed(self.cfg.class_of(idx));
    }
}

/// Drive a gateway over pre-rendered frames under open-loop arrivals.
///
/// `pseudo_gt[i]` doubles as the evaluation ground truth and the Oracle
/// estimator's request metadata, exactly like the closed-loop driver.
pub fn run_frames(
    gw: &mut Gateway<'_>,
    frames: &[Scene],
    pseudo_gt: &[Vec<GtBox>],
    cfg: &OpenLoopConfig,
) -> Result<OpenLoopReport> {
    anyhow::ensure!(frames.len() == pseudo_gt.len());
    gw.pool_mut().set_queue_capacity(cfg.queue_capacity);
    let fallbacks_before = gw.fallbacks;

    let mut metrics = RunMetrics::new(gw.spec.name);
    let mut sim = SimState::new();
    sim.obs =
        cfg.obs.as_ref().map(|c| ObsShard::new(c, 0, frames.len()));
    let obs_t0 = cfg.obs.as_ref().map(|_| std::time::Instant::now());
    let arrival_times = cfg.arrivals.times(frames.len(), cfg.seed);
    let horizon_s = arrival_times.last().copied().unwrap_or(0.0)
        + cfg.churn.as_ref().map(|c| c.horizon_slack_s).unwrap_or(0.0);
    // SLO runs: absolute deadlines are a pure function of the arrival
    // process, so they're materialized up front alongside it.
    let mut slo = match &cfg.slo {
        Some(c) => {
            anyhow::ensure!(
                !c.classes.is_empty(),
                "slo config needs at least one deadline class"
            );
            Some(SloRt {
                deadlines: arrival_times
                    .iter()
                    .enumerate()
                    .map(|(i, &t)| c.deadline_for(i, t))
                    .collect(),
                metrics: SloMetrics::new(&c.class_names()),
                cfg: c.clone(),
            })
        }
        None => None,
    };
    for (idx, t) in arrival_times.into_iter().enumerate() {
        sim.push(t, EventKind::Arrival(idx));
    }

    // churn runs: ground-truth failure timeline + probe schedule are
    // materialized up front (deterministic), the gateway switches to
    // its probe-driven membership view, and per-request copy accounting
    // starts. Without churn nothing below adds a single event.
    let mut campaign_plan: Option<CampaignPlan> = None;
    let mut churn = match &cfg.churn {
        Some(c) => {
            gw.enable_churn(c);
            // pool-ordered node ids (the failure timeline and probe
            // snapshots address nodes by pool position)
            let pairs: Vec<PairId> = gw
                .pool()
                .nodes()
                .iter()
                .map(|n| {
                    gw.store().id_of(&n.pair).expect(
                        "deployed pair missing from the routing table",
                    )
                })
                .collect();
            match &cfg.campaign {
                // a campaign folds churn + domain outages into one
                // effective ground-truth timeline (DESIGN.md §15); the
                // open loop is single-gateway, so gateway kills are a
                // fleet-driver feature
                Some(cc) => {
                    anyhow::ensure!(
                        !cc.gateway_enabled(),
                        "gateway campaigns need the fleet driver \
                         (the open loop has no shard gateways)"
                    );
                    let plan = CampaignPlan::build(
                        pairs.len(),
                        1,
                        horizon_s,
                        c,
                        cc,
                    )?;
                    for pe in &plan.events {
                        match *pe {
                            PlanEvent::Truth { t, node, up } => {
                                let kind = if up {
                                    EventKind::Rejoin(node)
                                } else {
                                    EventKind::Crash(node)
                                };
                                sim.push(t, kind);
                            }
                            PlanEvent::DomainMark {
                                t, domain, down, ..
                            } => sim.push(
                                t,
                                EventKind::DomainMark { domain, down },
                            ),
                            _ => anyhow::bail!(
                                "unexpected gateway event in an \
                                 open-loop campaign plan"
                            ),
                        }
                    }
                    campaign_plan = Some(plan);
                }
                None => {
                    for ev in lifecycle::failure_schedule(
                        pairs.len(),
                        horizon_s,
                        c,
                    ) {
                        let kind = if ev.up {
                            EventKind::Rejoin(ev.node)
                        } else {
                            EventKind::Crash(ev.node)
                        };
                        sim.push(ev.t, kind);
                    }
                }
            }
            let gap = c.probe_interval_s.max(1e-6);
            let mut t = gap;
            while t < horizon_s {
                sim.push(t, EventKind::Probe);
                t += gap;
            }
            Some(ChurnDriver {
                pairs,
                probe_timeout_s: c.probe_timeout_s,
                state: ChurnState::new(
                    frames.len(),
                    c.policy,
                    c.retry_backoff_s,
                ),
                est: vec![None; frames.len()],
                hedge_pairs: vec![None; frames.len()],
                hedge_cancel: c.hedge_cancel,
            })
        }
        None => {
            anyhow::ensure!(
                cfg.campaign.is_none(),
                "campaign requires a churn config (use mtbf_s = inf \
                 for a pure-campaign run)"
            );
            None
        }
    };

    // Online adaptation (DESIGN.md §12): telemetry corrections feed
    // from every completion through the gateway; when scaling is on,
    // decision ticks are scheduled like probes. Without adapt nothing
    // below adds a single event.
    if let Some(a) = &cfg.adapt {
        gw.enable_adapt(a);
        if a.scale {
            let gap = a.scale_interval_s.max(1e-6);
            let mut t = gap;
            while t < horizon_s {
                sim.push(t, EventKind::ScaleTick);
                t += gap;
            }
        }
    }

    while let Some(Reverse(ev)) = sim.heap.pop() {
        match ev.kind {
            EventKind::Arrival(idx) => {
                gw.adapt_arrival();
                let scene = &frames[idx];
                let true_count = pseudo_gt[idx].len();
                // the estimator runs ONCE per request, here at first
                // arrival; under churn the result is cached so retries
                // re-enter routing without paying GatewayCost again.
                // Estimator errors (inference failure) abort the run.
                let (estimate, cost) =
                    gw.estimate_request(&scene.image, true_count)?;
                if let Some(ch) = churn.as_mut() {
                    ch.est[idx] = Some((estimate, cost));
                }
                if let Some(o) = sim.obs.as_mut() {
                    o.admit(idx, ev.t, estimate);
                }
                // routing observes per-node occupancy (and, under
                // churn, believed health): full or down nodes are
                // skipped via the fallback path; if no feasible
                // endpoint has a free slot, the request is shed — or,
                // under the retry policy, backed off like a retrying
                // client. Any other routing error (misconfigured
                // store) aborts the run.
                let routed = match gw
                    .route_with_estimate(estimate, true_count, cost, ev.t)
                {
                    Ok(r) => r,
                    Err(e) if e.is::<crate::gateway::NoEndpoint>() => {
                        match churn.as_mut() {
                            Some(ch)
                                if matches!(
                                    ch.state.policy(),
                                    ResiliencePolicy::Retry { .. }
                                ) =>
                            {
                                if let LossOutcome::RetryAt(t) = ch
                                    .state
                                    .placement_failed(idx, ev.t)
                                {
                                    retry_or_abandon(
                                        &mut sim,
                                        &mut ch.state,
                                        slo.as_mut(),
                                        idx,
                                        t,
                                    );
                                }
                            }
                            _ => {
                                sim.dropped += 1;
                                // an overflow drop misses its SLO too
                                if let Some(s) = slo.as_mut() {
                                    s.shed(idx);
                                }
                                if let Some(o) = sim.obs.as_mut() {
                                    o.shed(idx, ev.t);
                                }
                            }
                        }
                        continue;
                    }
                    Err(e) => return Err(e),
                };
                if let Some(o) = sim.obs.as_mut() {
                    o.route(
                        idx,
                        ev.t,
                        i64::from(routed.pair_id.0),
                        routed.cost.latency_s,
                        routed.cost.energy_mwh,
                    );
                }
                // SLO admission control: when the predicted completion
                // (queue ahead x per-pair mean service + estimator cost
                // + network hop) already blows the deadline, shed now
                // instead of queueing doomed work (DESIGN.md §11).
                let mut tag = SloTag::default();
                if let Some(s) = slo.as_mut() {
                    let deadline = s.deadlines[idx];
                    let pred = gw.predicted_completion_s(
                        routed.pair_id,
                        ev.t,
                        routed.cost.latency_s,
                    );
                    if ev.t + pred > deadline {
                        sim.dropped += 1;
                        s.shed(idx);
                        if let Some(o) = sim.obs.as_mut() {
                            o.shed(idx, ev.t);
                        }
                        continue;
                    }
                    tag = SloTag {
                        class: s.cfg.class_of(idx),
                        deadline_s: deadline,
                        edf_s: deadline,
                        ..tag
                    };
                }
                // proactive hedging: duplicate onto the second-best
                // admissible pair, reusing the primary's estimate
                let dup = match churn.as_ref() {
                    Some(ch)
                        if ch.state.policy()
                            == ResiliencePolicy::Hedge =>
                    {
                        gw.route_secondary(&routed, ev.t)
                            .filter(|&p| match slo.as_ref() {
                                // hedges respect the remaining budget:
                                // don't duplicate onto a secondary that
                                // can't make the deadline anyway
                                Some(s) => {
                                    ev.t + gw
                                        .predicted_completion_s(
                                            p, ev.t, 0.0,
                                        )
                                        <= s.deadlines[idx]
                                }
                                None => true,
                            })
                            .map(|p| RoutedRequest {
                                pair_id: p,
                                ..routed
                            })
                    }
                    _ => None,
                };
                // register BOTH copies before admitting either: the
                // primary can die synchronously at dispatch (stale
                // view), and its loss must see the hedge as a live
                // sibling, not declare the request lost.
                if let Some(ch) = churn.as_mut() {
                    ch.state.dispatched(idx);
                    if let Some(d) = &dup {
                        ch.state.hedge_dispatched(idx);
                        ch.hedge_pairs[idx] =
                            Some((routed.pair_id, d.pair_id));
                    }
                }
                // batch formation: primary copies without a hedge
                // sibling join their pair's forming batch instead of
                // entering the FIFO directly
                let forms = dup.is_none()
                    && slo.as_ref().is_some_and(|s| {
                        s.cfg.batch_window_s > 0.0 && s.cfg.max_batch > 1
                    });
                if forms {
                    join_forming(
                        gw, frames, &mut sim, &mut churn, &mut slo,
                        routed, tag, idx, ev.t,
                    )?;
                    continue;
                }
                if let Some(s) = slo.as_mut() {
                    // unbatched dispatch: a size-1 "batch"
                    s.metrics.record_batch(1);
                }
                admit_copy(
                    gw, frames, &mut sim, &mut churn, &mut slo, routed,
                    idx, ev.t, false, tag,
                )?;
                if let Some(d) = dup {
                    if let Some(o) = sim.obs.as_mut() {
                        o.hedge(idx, ev.t, i64::from(d.pair_id.0));
                    }
                    admit_copy(
                        gw, frames, &mut sim, &mut churn, &mut slo, d,
                        idx, ev.t, true, tag,
                    )?;
                }
            }
            EventKind::Retry(idx) => {
                // the retry carries the request's ORIGINAL estimate
                // and gateway cost (cached at first arrival): the
                // estimator is not consulted again, and the winning
                // copy records that one cost at completion.
                let (estimate, cost) = churn
                    .as_ref()
                    .expect("retry without churn")
                    .est[idx]
                    .expect("retried request was never estimated");
                let routed = match gw.route_with_estimate(
                    estimate,
                    pseudo_gt[idx].len(),
                    cost,
                    ev.t,
                ) {
                    Ok(r) => r,
                    Err(e) if e.is::<crate::gateway::NoEndpoint>() => {
                        let ch =
                            churn.as_mut().expect("retry without churn");
                        if let LossOutcome::RetryAt(t) =
                            ch.state.placement_failed(idx, ev.t)
                        {
                            retry_or_abandon(
                                &mut sim,
                                &mut ch.state,
                                slo.as_mut(),
                                idx,
                                t,
                            );
                        }
                        continue;
                    }
                    Err(e) => return Err(e),
                };
                churn
                    .as_mut()
                    .expect("retry without churn")
                    .state
                    .retry_dispatched(idx);
                if let Some(o) = sim.obs.as_mut() {
                    o.route(
                        idx,
                        ev.t,
                        i64::from(routed.pair_id.0),
                        routed.cost.latency_s,
                        routed.cost.energy_mwh,
                    );
                }
                // retries bypass batch formation (the backoff already
                // ate the slack) but keep their deadline for EDF and
                // attainment accounting
                let tag = match slo.as_ref() {
                    Some(s) => SloTag {
                        class: s.cfg.class_of(idx),
                        deadline_s: s.deadlines[idx],
                        edf_s: s.deadlines[idx],
                        ..SloTag::default()
                    },
                    None => SloTag::default(),
                };
                admit_copy(
                    gw, frames, &mut sim, &mut churn, &mut slo, routed,
                    idx, ev.t, false, tag,
                )?;
            }
            EventKind::Completion { pair, token } => {
                let q = sim
                    .queues
                    .get_mut(&pair)
                    .expect("completion for unknown queue");
                if q.serving.as_ref().map(|s| s.token) != Some(token) {
                    // the in-service request was lost to a crash after
                    // this completion was scheduled — stale event
                    debug_assert!(
                        churn.is_some(),
                        "stale completion without churn"
                    );
                    continue;
                }
                let done = q.serving.take().expect("token just matched");
                gw.pool_mut().release_id(pair);
                sim.in_flight -= 1;
                sim.makespan_s = sim.makespan_s.max(ev.t);
                if let Some(o) = sim.obs.as_mut() {
                    o.in_flight(ev.t, sim.in_flight);
                }
                let (r_idx, r_hedge) = (done.idx, done.hedge);
                let winner = match churn.as_mut() {
                    Some(ch) => ch.state.copy_completed(
                        done.idx,
                        done.resp.energy_mwh,
                        done.hedge,
                    ),
                    None => true,
                };
                if winner {
                    // FIFO wait: service start minus the moment the
                    // request cleared gateway-side estimation.
                    let queue_delay_s = (done.start_s
                        - (done.arrival_s + done.routed.cost.latency_s))
                        .max(0.0);
                    // batch followers rode the leader's transfer
                    let net_s = if done.slo.net {
                        devices::NETWORK_S
                    } else {
                        0.0
                    };
                    let (d_idx, d_class) = (done.idx, done.slo.class);
                    let (e2e_s, e_mwh) =
                        (ev.t - done.arrival_s, done.resp.energy_mwh);
                    gw.finish_with_network(
                        &done.routed,
                        done.resp,
                        &pseudo_gt[done.idx],
                        queue_delay_s,
                        net_s,
                        &mut metrics,
                    );
                    if let Some(s) = slo.as_mut() {
                        s.record_done(d_idx, d_class, ev.t);
                    }
                    if let Some(o) = sim.obs.as_mut() {
                        let on_time = match slo.as_ref() {
                            Some(s) => ev.t <= s.deadlines[d_idx],
                            None => true,
                        };
                        o.finish(
                            d_idx,
                            ev.t,
                            i64::from(pair.0),
                            e2e_s,
                            e_mwh,
                            on_time,
                        );
                    }
                } else if let Some(o) = sim.obs.as_mut() {
                    o.hedge_loss(
                        done.idx,
                        ev.t,
                        i64::from(pair.0),
                        done.resp.energy_mwh,
                    );
                }
                // cancellation-on-first-response: the winner's arrival
                // makes the sibling pure waste — cancel it now, charge
                // only the energy it accrued, and free its slot
                let sib = match churn.as_mut() {
                    Some(ch) if winner && ch.hedge_cancel => ch
                        .hedge_pairs[r_idx]
                        .take()
                        .map(|(p, h)| if r_hedge { p } else { h }),
                    _ => None,
                };
                if let Some(sib) = sib {
                    cancel_sibling(
                        gw, frames, &mut sim, &mut churn, &mut slo,
                        sib, r_idx, ev.t,
                    )?;
                }
                start_next(
                    gw, frames, &mut sim, &mut churn, &mut slo, pair,
                    ev.t,
                )?;
            }
            EventKind::Crash(node) => {
                let ch = churn.as_mut().expect("crash without churn");
                let pair = ch.pairs[node];
                ch.state.crashes += 1;
                if let Some(o) = sim.obs.as_mut() {
                    o.crash(ev.t);
                }
                gw.pool_mut().set_health_id(pair, false);
                if let Some(m) = gw.membership_mut() {
                    m.ground_truth_changed(pair, false, ev.t);
                }
                lose_queued(
                    gw, &mut sim, &mut ch.state, &mut slo, pair, None,
                    ev.t,
                );
            }
            EventKind::Rejoin(node) => {
                let ch = churn.as_ref().expect("rejoin without churn");
                let pair = ch.pairs[node];
                gw.pool_mut().set_health_id(pair, true);
                if let Some(n) = gw.pool_mut().get_id(pair) {
                    n.on_rejoin(ev.t);
                }
                if let Some(m) = gw.membership_mut() {
                    m.ground_truth_changed(pair, true, ev.t);
                }
                if let Some(o) = sim.obs.as_mut() {
                    o.rejoin(ev.t);
                }
            }
            EventKind::Probe => {
                let ch = churn.as_ref().expect("probe without churn");
                let responses: Vec<bool> = ch
                    .pairs
                    .iter()
                    .map(|&p| gw.pool().is_healthy_id(p))
                    .collect();
                let timeout = ch.probe_timeout_s;
                sim.push(ev.t + timeout, EventKind::ProbeResult(responses));
            }
            EventKind::ProbeResult(responses) => {
                let ch = churn.as_ref().expect("probe without churn");
                let m = gw
                    .membership_mut()
                    .expect("churn gateway lost its membership");
                for (&p, up) in ch.pairs.iter().zip(&responses) {
                    m.observe_probe(p, *up, ev.t);
                }
            }
            EventKind::BatchClose { pair, token } => {
                if sim.forming.get(&pair).map(|f| f.token) != Some(token)
                {
                    // superseded: a later member rescheduled the close,
                    // the batch already flushed full, or a crash
                    // drained the formation
                    continue;
                }
                flush_batch(
                    gw, frames, &mut sim, &mut churn, &mut slo, pair,
                    ev.t,
                )?;
            }
            EventKind::ScaleTick => {
                gw.adapt_scale_tick(ev.t);
                let powered = gw
                    .adapt()
                    .and_then(|a| a.scaler.as_ref())
                    .map(|sc| sc.n_powered());
                if let (Some(o), Some(n)) = (sim.obs.as_mut(), powered)
                {
                    o.powered(ev.t, n);
                }
            }
            EventKind::DomainMark { domain, down } => {
                if let Some(o) = sim.obs.as_mut() {
                    o.domain_mark(ev.t, domain, down);
                }
            }
        }
    }

    if let Some(oc) = &cfg.obs {
        let wall_s =
            obs_t0.map_or(0.0, |t0| t0.elapsed().as_secs_f64());
        let shards: Vec<ObsShard> =
            sim.obs.take().into_iter().collect();
        if let Err(e) =
            crate::obs::export_run(oc, "openloop", shards, wall_s)
        {
            eprintln!("[obs] export failed: {e}");
        }
    }
    let churn_report = churn.map(|c| {
        let m = gw
            .membership()
            .expect("churn gateway lost its membership");
        ChurnReport::collect(&c.state, [m])
    });
    let adapt_report = gw.adapt_report(sim.makespan_s);
    Ok(OpenLoopReport {
        metrics,
        offered: frames.len(),
        dropped: sim.dropped,
        makespan_s: sim.makespan_s,
        peak_in_flight: sim.peak_in_flight,
        fallbacks: gw.fallbacks - fallbacks_before,
        churn: churn_report,
        slo: slo.map(|s| s.metrics),
        adapt: adapt_report,
        campaign: campaign_plan.map(|p| p.report),
    })
}

/// Enqueue one pending copy. A finite EDF key inserts in deadline order
/// (stable: ties and infinite keys go after), which degenerates to the
/// exact pre-SLO FIFO when SLOs are off — every key is infinite then.
fn push_pending(q: &mut NodeQueue, p: Pending) {
    if p.slo.edf_s.is_finite() {
        if let Some(pos) =
            q.backlog.iter().position(|b| b.slo.edf_s > p.slo.edf_s)
        {
            q.backlog.insert(pos, p);
            return;
        }
    }
    q.backlog.push_back(p);
}

/// Under SLOs a retry scheduled past the request's deadline cannot
/// help: abandon the request (it counts as lost) and record the shed.
/// Otherwise schedule the re-dispatch normally.
fn retry_or_abandon(
    sim: &mut SimState,
    state: &mut ChurnState,
    slo: Option<&mut SloRt>,
    idx: usize,
    retry_t: f64,
) {
    match slo {
        Some(s) if retry_t > s.deadlines[idx] => {
            state.abandon(idx);
            s.shed(idx);
            if let Some(o) = sim.obs.as_mut() {
                o.abandon(idx, retry_t);
            }
        }
        _ => {
            if let Some(o) = sim.obs.as_mut() {
                o.retry(idx, retry_t);
            }
            sim.push(retry_t, EventKind::Retry(idx));
        }
    }
}

/// Admit one routed copy of request `idx` into its pair's FIFO at time
/// `t` and try to start service.
#[allow(clippy::too_many_arguments)]
fn admit_copy(
    gw: &mut Gateway<'_>,
    frames: &[Scene],
    sim: &mut SimState,
    churn: &mut Option<ChurnDriver>,
    slo: &mut Option<SloRt>,
    routed: RoutedRequest,
    idx: usize,
    t: f64,
    hedge: bool,
    tag: SloTag,
) -> Result<()> {
    let admitted = gw.pool_mut().acquire_id(routed.pair_id);
    debug_assert!(admitted, "route() returned a pair without a free slot");
    sim.in_flight += 1;
    sim.peak_in_flight = sim.peak_in_flight.max(sim.in_flight);
    let pair = routed.pair_id;
    let depth = {
        let q = sim.queues.entry(pair).or_default();
        push_pending(
            q,
            Pending { routed, idx, arrival_s: t, hedge, slo: tag },
        );
        q.backlog.len() + usize::from(q.serving.is_some())
    };
    if let Some(o) = sim.obs.as_mut() {
        o.queue(idx, t, i64::from(pair.0), depth);
        o.in_flight(t, sim.in_flight);
    }
    start_next(gw, frames, sim, churn, slo, pair, t)
}

/// Admit request `idx` into `pair`'s forming batch. The queue slot is
/// acquired NOW — routing, occupancy checks, and admission control all
/// see forming members — and the batch flushes when it fills, when the
/// window closes, or early enough that the tightest member can still
/// make its deadline.
#[allow(clippy::too_many_arguments)]
fn join_forming(
    gw: &mut Gateway<'_>,
    frames: &[Scene],
    sim: &mut SimState,
    churn: &mut Option<ChurnDriver>,
    slo: &mut Option<SloRt>,
    routed: RoutedRequest,
    tag: SloTag,
    idx: usize,
    t: f64,
) -> Result<()> {
    let admitted = gw.pool_mut().acquire_id(routed.pair_id);
    debug_assert!(admitted, "route() returned a pair without a free slot");
    sim.in_flight += 1;
    sim.peak_in_flight = sim.peak_in_flight.max(sim.in_flight);
    let pair = routed.pair_id;
    let (window_s, max_batch) = {
        let s = slo.as_ref().expect("forming without slo");
        (s.cfg.batch_window_s, s.cfg.max_batch)
    };
    // latest viable close for THIS member: its deadline minus the
    // predicted service span once dispatched
    let latest_s = (tag.deadline_s
        - gw.predicted_completion_s(pair, t, 0.0))
    .max(t);
    let member_close = (t + window_s).min(latest_s);
    let (flush_now, close_s, size) = {
        let f = sim.forming.entry(pair).or_default();
        f.members.push(Pending {
            routed,
            idx,
            arrival_s: t,
            hedge: false,
            slo: tag,
        });
        f.close_s = f.close_s.min(member_close);
        (
            f.members.len() >= max_batch || f.close_s <= t,
            f.close_s,
            f.members.len(),
        )
    };
    if let Some(o) = sim.obs.as_mut() {
        o.batch_form(idx, t, i64::from(pair.0), size);
        o.in_flight(t, sim.in_flight);
    }
    if flush_now {
        return flush_batch(gw, frames, sim, churn, slo, pair, t);
    }
    // (re)schedule the close; earlier BatchClose events go stale
    let token = sim.seq;
    sim.forming.get_mut(&pair).expect("just inserted").token = token;
    sim.push(close_s, EventKind::BatchClose { pair, token });
    Ok(())
}

/// Flush `pair`'s forming batch into its FIFO as one amortized service
/// train: the leader pays full preprocess and the network hop,
/// followers amortize both, and every member shares the batch's
/// tightest deadline as its EDF key so the train stays contiguous.
fn flush_batch(
    gw: &mut Gateway<'_>,
    frames: &[Scene],
    sim: &mut SimState,
    churn: &mut Option<ChurnDriver>,
    slo: &mut Option<SloRt>,
    pair: PairId,
    now_s: f64,
) -> Result<()> {
    let Some(f) = sim.forming.remove(&pair) else {
        return Ok(());
    };
    if f.members.is_empty() {
        return Ok(());
    }
    if let Some(s) = slo.as_mut() {
        s.metrics.record_batch(f.members.len());
    }
    let edf_s = f
        .members
        .iter()
        .map(|m| m.slo.deadline_s)
        .fold(f64::INFINITY, f64::min);
    for (i, mut m) in f.members.into_iter().enumerate() {
        m.slo.edf_s = edf_s;
        m.slo.amortized = i > 0;
        m.slo.net = i == 0;
        // slots were acquired at formation entry — enqueue directly
        push_pending(sim.queues.entry(pair).or_default(), m);
    }
    start_next(gw, frames, sim, churn, slo, pair, now_s)
}

/// If `pair` is idle and has backlog, begin serving the head request at
/// `now_s` and schedule its completion. Service cannot begin before the
/// request's gateway-side estimation has finished. Under churn, a
/// dispatch that discovers a dead node (the membership view is stale)
/// loses everything queued there through the resilience policy and
/// feeds the failure back to the membership as passive health evidence.
fn start_next(
    gw: &mut Gateway<'_>,
    frames: &[Scene],
    sim: &mut SimState,
    churn: &mut Option<ChurnDriver>,
    slo: &mut Option<SloRt>,
    pair: PairId,
    now_s: f64,
) -> Result<()> {
    let q =
        sim.queues.get_mut(&pair).expect("start_next on unknown queue");
    if q.serving.is_some() {
        return Ok(());
    }
    let Some(p) = q.backlog.pop_front() else {
        return Ok(());
    };
    let start_s = now_s.max(p.arrival_s + p.routed.cost.latency_s);
    let mut resp = match gw.serve(pair, &frames[p.idx].image, start_s) {
        Ok(r) => r,
        Err(e) if churn.is_some() && e.is::<NodeDown>() => {
            if let Some(m) = gw.membership_mut() {
                m.observe_dispatch_failure(pair, now_s);
            }
            let ch = churn.as_mut().expect("checked above");
            lose_queued(gw, sim, &mut ch.state, slo, pair, Some(p), now_s);
            return Ok(());
        }
        Err(e) => return Err(e),
    };
    if p.slo.amortized {
        // batch follower: the leader already warmed preprocess
        let (save_s, save_mwh) = gw.batch_savings(pair);
        resp.latency_s = amortize(resp.latency_s, save_s);
        resp.energy_mwh = amortize(resp.energy_mwh, save_mwh);
    }
    let net_s = if p.slo.net { devices::NETWORK_S } else { 0.0 };
    if let Some(o) = sim.obs.as_mut() {
        o.serve(
            p.idx,
            start_s,
            i64::from(pair.0),
            resp.latency_s,
            resp.energy_mwh,
        );
    }
    let token = sim.seq;
    sim.push(
        start_s + resp.latency_s + net_s,
        EventKind::Completion { pair, token },
    );
    // re-borrow: gw.serve() above needed &mut Gateway exclusively
    sim.queues.get_mut(&pair).expect("queue vanished").serving =
        Some(InService {
            routed: p.routed,
            idx: p.idx,
            arrival_s: p.arrival_s,
            start_s,
            resp,
            token,
            hedge: p.hedge,
            slo: p.slo,
        });
    Ok(())
}

/// Drain every copy on `pair`'s queue — the in-service request (crash
/// case), an optional already-popped head (failed-dispatch case), and
/// the backlog — releasing their slots and feeding each loss through
/// the resilience policy.
fn lose_queued(
    gw: &mut Gateway<'_>,
    sim: &mut SimState,
    state: &mut ChurnState,
    slo: &mut Option<SloRt>,
    pair: PairId,
    head: Option<Pending>,
    now_s: f64,
) {
    let mut idxs: Vec<usize> = Vec::new();
    if let Some(q) = sim.queues.get_mut(&pair) {
        if let Some(s) = q.serving.take() {
            idxs.push(s.idx);
        }
        if let Some(p) = &head {
            idxs.push(p.idx);
        }
        while let Some(p) = q.backlog.pop_front() {
            idxs.push(p.idx);
        }
    } else if let Some(p) = &head {
        idxs.push(p.idx);
    }
    // batch members still forming on the crashed pair hold slots too;
    // removing the entry stales any scheduled BatchClose for it
    if let Some(f) = sim.forming.remove(&pair) {
        for m in f.members {
            idxs.push(m.idx);
        }
    }
    let lost_any = !idxs.is_empty();
    for idx in idxs {
        gw.pool_mut().release_id(pair);
        sim.in_flight -= 1;
        if let Some(o) = sim.obs.as_mut() {
            o.loss(idx, now_s, i64::from(pair.0));
        }
        match state.copy_lost(idx, now_s) {
            LossOutcome::RetryAt(t) => {
                retry_or_abandon(sim, state, slo.as_mut(), idx, t)
            }
            LossOutcome::Absorbed | LossOutcome::Lost => {}
        }
    }
    if lost_any {
        if let Some(o) = sim.obs.as_mut() {
            o.in_flight(now_s, sim.in_flight);
        }
    }
}

/// Cancel the losing hedge sibling the instant the winner completes
/// (hedge_cancel runs only): release its slot NOW and charge only the
/// energy it accrued — pro-rated by service progress for an in-service
/// copy, zero for a queued one. The sibling may already be gone
/// (crash-lost before the winner returned); then `copy_lost` settled
/// the ledger and there is nothing to cancel. Taking the in-service
/// slot stales the sibling's scheduled Completion (token mismatch).
#[allow(clippy::too_many_arguments)]
fn cancel_sibling(
    gw: &mut Gateway<'_>,
    frames: &[Scene],
    sim: &mut SimState,
    churn: &mut Option<ChurnDriver>,
    slo: &mut Option<SloRt>,
    sib: PairId,
    idx: usize,
    now_s: f64,
) -> Result<()> {
    enum Hit {
        Serving(f64),
        Queued,
        Gone,
    }
    let hit = match sim.queues.get_mut(&sib) {
        Some(q) => {
            if q.serving.as_ref().is_some_and(|x| x.idx == idx) {
                let sv = q.serving.take().expect("just matched");
                let frac = ((now_s - sv.start_s)
                    / sv.resp.latency_s.max(1e-12))
                .clamp(0.0, 1.0);
                Hit::Serving(sv.resp.energy_mwh * frac)
            } else if let Some(pos) =
                q.backlog.iter().position(|b| b.idx == idx)
            {
                q.backlog.remove(pos);
                Hit::Queued
            } else {
                Hit::Gone
            }
        }
        None => Hit::Gone,
    };
    let (partial, was_serving) = match hit {
        Hit::Serving(e) => (e, true),
        Hit::Queued => (0.0, false),
        Hit::Gone => return Ok(()),
    };
    gw.pool_mut().release_id(sib);
    sim.in_flight -= 1;
    let ch = churn.as_mut().expect("hedge without churn");
    ch.state.copy_cancelled(idx, partial);
    let n_if = sim.in_flight;
    if let Some(o) = sim.obs.as_mut() {
        o.hedge_loss(idx, now_s, i64::from(sib.0), partial);
        o.in_flight(now_s, n_if);
    }
    if was_serving {
        start_next(gw, frames, sim, churn, slo, sib, now_s)?;
    }
    Ok(())
}

/// Render a dataset up front and drive it open loop (the per-scene
/// render cost must not sit on the event clock's critical path).
pub fn run_dataset(
    gw: &mut Gateway<'_>,
    dataset: &Dataset,
    cfg: &OpenLoopConfig,
) -> Result<OpenLoopReport> {
    let frames: Vec<Scene> = dataset.iter_scenes().collect();
    let gts: Vec<Vec<GtBox>> =
        frames.iter().map(|s| s.gt.clone()).collect();
    run_frames(gw, &frames, &gts, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::coco;
    use crate::devices::fleet;
    use crate::gateway::router_by_name;
    use crate::nodes::NodePool;
    use crate::router::{PairKey, PairProfile, ProfileStore};
    use crate::runtime::Engine;
    use crate::workload;

    fn engine() -> Engine {
        Engine::new(&crate::default_artifacts_dir()).unwrap()
    }

    fn store() -> ProfileStore {
        let mut rows = Vec::new();
        for g in 0..5 {
            rows.push(PairProfile {
                pair: PairKey::new("ssd_v1", "jetson_orin_nano"),
                group: g,
                map: 50.0,
                latency_s: 0.005,
                energy_mwh: 0.002,
            });
            rows.push(PairProfile {
                pair: PairKey::new("yolov8n", "pi5"),
                group: g,
                map: if g >= 2 { 75.0 } else { 51.0 },
                latency_s: 0.05,
                energy_mwh: 0.05,
            });
        }
        ProfileStore::new(rows)
    }

    fn gateway<'e>(e: &'e Engine, router: &str, seed: u64) -> Gateway<'e> {
        let s = store();
        let pool =
            NodePool::deploy(e, &s.pairs(), &fleet(), seed).unwrap();
        Gateway::new(e, router_by_name(router).unwrap(), s, pool, 5.0, seed)
    }

    #[test]
    fn arrival_processes_are_deterministic_and_ordered() {
        let p = ArrivalProcess::Poisson { rate_rps: 20.0 };
        let a = p.times(50, 9);
        let b = p.times(50, 9);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
        assert_ne!(a, p.times(50, 10));
        // mean inter-arrival ~ 1/rate
        let mean_gap = a.last().unwrap() / 50.0;
        assert!((mean_gap - 0.05).abs() < 0.03, "mean gap {mean_gap}");

        let u = ArrivalProcess::Uniform { gap_s: 0.5 }.times(3, 0);
        assert_eq!(u, vec![0.5, 1.0, 1.5]);

        let tr = ArrivalProcess::Trace(vec![0.1, 0.3]).times(4, 0);
        assert_eq!(tr, vec![0.1, 0.3, 0.5, 0.7]);
    }

    #[test]
    fn trace_single_element_extends_with_gap_ts0() {
        // pinned semantics: a one-point trace [t] treats t as the gap
        // from the origin, so the extension is t, 2t, 3t, …
        let tr = ArrivalProcess::Trace(vec![0.4]).times(3, 0);
        assert_eq!(tr, vec![0.4, 0.8, 1.2000000000000002]);
    }

    #[test]
    fn trace_out_of_order_is_sorted_before_use() {
        // a decreasing trace used to produce a negative last gap
        // (clamped to 1e-9) and out-of-order arrivals; now the trace
        // sorts first, so arrivals are nondecreasing and the extension
        // gap comes from the sorted tail.
        let tr = ArrivalProcess::Trace(vec![0.9, 0.1, 0.5]).times(5, 0);
        assert_eq!(tr, vec![0.1, 0.5, 0.9, 1.3, 1.7000000000000002]);
        assert!(tr.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn empty_trace_extends_with_unit_gap() {
        let tr = ArrivalProcess::Trace(vec![]).times(3, 0);
        assert_eq!(tr, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn low_rate_open_loop_converges_to_closed_loop() {
        // satellite test (a): with arrivals far slower than service,
        // at most one request is ever in flight, so the open loop must
        // reproduce the closed loop's metrics exactly (same estimator,
        // policy, and jitter RNG sequences).
        let e = engine();
        let ds = coco::build(12, 77);
        for router in ["LE", "RR", "OB"] {
            let mut closed = gateway(&e, router, 3);
            let m_closed =
                workload::run_dataset(&mut closed, &ds).unwrap();

            let mut open = gateway(&e, router, 3);
            let report = run_dataset(
                &mut open,
                &ds,
                &OpenLoopConfig {
                    // 5 s between arrivals vs ~tens of ms of service:
                    // deterministic pacing guarantees zero overlap
                    arrivals: ArrivalProcess::Uniform { gap_s: 5.0 },
                    queue_capacity: 8,
                    seed: 5,
                    churn: None,
                    slo: None,
                    adapt: None,
                    campaign: None,
                    obs: None,
                },
            )
            .unwrap();
            let m_open = &report.metrics;

            assert_eq!(report.dropped, 0, "{router}");
            assert_eq!(report.peak_in_flight, 1, "{router}");
            assert_eq!(m_open.requests, m_closed.requests, "{router}");
            assert_eq!(m_open.queue_delay_s, 0.0, "{router}");
            assert_eq!(m_open.per_pair, m_closed.per_pair, "{router}");
            assert!(
                (m_open.total_latency_s - m_closed.total_latency_s).abs()
                    < 1e-9,
                "{router}: open {} vs closed {}",
                m_open.total_latency_s,
                m_closed.total_latency_s
            );
            assert!(
                (m_open.total_energy_mwh() - m_closed.total_energy_mwh())
                    .abs()
                    < 1e-9,
                "{router}"
            );
        }
    }

    #[test]
    fn queueing_delay_is_monotone_in_arrival_rate() {
        // satellite test (b): same workload, rising offered load =>
        // nondecreasing mean queueing delay. Capacity is large enough
        // that nothing is shed, so every run serves the same requests.
        let e = engine();
        let ds = coco::build(30, 41);
        let mut delays = Vec::new();
        for rate in [1.0, 25.0, 400.0] {
            let mut gw = gateway(&e, "LE", 3);
            let report = run_dataset(
                &mut gw,
                &ds,
                &OpenLoopConfig {
                    arrivals: ArrivalProcess::Poisson { rate_rps: rate },
                    queue_capacity: 64,
                    seed: 11,
                    churn: None,
                    slo: None,
                    adapt: None,
                    campaign: None,
                    obs: None,
                },
            )
            .unwrap();
            assert_eq!(report.dropped, 0, "rate {rate}");
            delays.push(report.metrics.mean_queue_delay_s());
        }
        assert!(
            delays.windows(2).all(|w| w[0] <= w[1]),
            "queue delay not monotone: {delays:?}"
        );
        // and the saturated end genuinely queues
        assert!(delays[2] > 0.0, "{delays:?}");
    }

    #[test]
    fn bounded_queue_overflow_falls_back_then_sheds() {
        // satellite test (c): capacity 1 and near-simultaneous arrivals.
        // LE always prefers the jetson pair, so the second arrival finds
        // it full and must fall back to the other pair (fallbacks += 1);
        // once both single-slot queues are full, arrivals are dropped.
        let e = engine();
        let ds = coco::build(10, 13);
        let mut gw = gateway(&e, "LE", 3);
        let report = run_dataset(
            &mut gw,
            &ds,
            &OpenLoopConfig {
                arrivals: ArrivalProcess::Uniform { gap_s: 1e-6 },
                queue_capacity: 1,
                seed: 2,
                churn: None,
                slo: None,
                adapt: None,
                campaign: None,
                obs: None,
            },
        )
        .unwrap();
        assert!(gw.fallbacks > 0, "expected overflow fallbacks");
        assert!(report.dropped > 0, "expected load shedding");
        assert_eq!(
            report.metrics.requests + report.dropped,
            report.offered
        );
        // both pairs ended up serving traffic
        assert_eq!(report.metrics.per_pair.len(), 2);
    }

    #[test]
    fn churn_crash_loses_requests_under_drop_policy() {
        // mtbf far below the run length and mttr far above it: both
        // nodes die almost immediately and stay dead, so in-flight and
        // later-arriving requests are lost (drop policy) or shed once
        // the membership view catches up. Every request is accounted
        // exactly once.
        let e = engine();
        let ds = coco::build(40, 21);
        let mut gw = gateway(&e, "LE", 3);
        let report = run_dataset(
            &mut gw,
            &ds,
            &OpenLoopConfig {
                arrivals: ArrivalProcess::Poisson { rate_rps: 400.0 },
                queue_capacity: 8,
                seed: 9,
                churn: Some(ChurnConfig {
                    mtbf_s: 0.02,
                    mttr_s: 100.0,
                    probe_interval_s: 0.1,
                    probe_timeout_s: 0.05,
                    suspect_after: 1,
                    policy: ResiliencePolicy::Drop,
                    horizon_slack_s: 1.0,
                    ..Default::default()
                }),
                slo: None,
                adapt: None,
                campaign: None,
                obs: None,
            },
        )
        .unwrap();
        let churn = report.churn.as_ref().expect("churn report");
        assert!(churn.crashes > 0, "no crashes fired");
        assert!(churn.lost > 0, "drop policy must lose in-flight work");
        assert_eq!(churn.retried, 0);
        assert_eq!(churn.hedged, 0);
        assert_eq!(
            report.metrics.requests + report.dropped + churn.lost,
            report.offered,
            "every request must be served, shed, or lost"
        );
        // all slots were released despite the crashes
        assert_eq!(gw.pool().total_in_flight(), 0);
    }

    #[test]
    fn retry_recovers_goodput_under_churn() {
        // acceptance shape: 20% steady-state unavailability
        // (mtbf/mttr = 3.2/0.8), greedy router, retry policy — goodput
        // must stay within 90% of the no-churn run. Rate is far below
        // capacity so recovery is limited only by detection + backoff.
        let e = engine();
        let ds = coco::build(80, 31);
        let open_cfg = |churn| OpenLoopConfig {
            arrivals: ArrivalProcess::Uniform { gap_s: 0.125 },
            queue_capacity: 8,
            seed: 13,
            churn,
            slo: None,
            adapt: None,
            campaign: None,
            obs: None,
        };
        let mut base_gw = gateway(&e, "Orc", 3);
        let base = run_dataset(&mut base_gw, &ds, &open_cfg(None)).unwrap();

        let mut gw = gateway(&e, "Orc", 3);
        let report = run_dataset(
            &mut gw,
            &ds,
            &open_cfg(Some(ChurnConfig {
                mtbf_s: 3.2,
                mttr_s: 0.8,
                probe_interval_s: 0.1,
                probe_timeout_s: 0.05,
                suspect_after: 1,
                warmup_s: 0.3,
                warmup_penalty: 0.5,
                policy: ResiliencePolicy::Retry { budget: 8 },
                retry_backoff_s: 0.2,
                hedge_cancel: false,
                horizon_slack_s: 5.0,
                seed: 11,
            })),
        )
        .unwrap();
        let churn = report.churn.as_ref().expect("churn report");
        assert!(churn.crashes > 0, "churn never fired");
        assert_eq!(
            report.metrics.requests + report.dropped + churn.lost,
            report.offered
        );
        assert!(
            report.goodput_rps() >= 0.9 * base.goodput_rps(),
            "retry recovered only {:.2} of {:.2} req/s (lost {}, dropped {}, retried {})",
            report.goodput_rps(),
            base.goodput_rps(),
            churn.lost,
            report.dropped,
            churn.retried
        );
        // recovery latency is observable once a node came back
        assert!(churn.mean_time_to_recover_s >= 0.0);
        assert_eq!(gw.pool().total_in_flight(), 0);
    }

    #[test]
    fn hedge_duplicates_requests_and_accounts_waste() {
        // no crashes (infinite mtbf): hedging still duplicates every
        // request onto the second-best pair, so the losing copy's
        // service shows up as wasted energy, never as a served request.
        let e = engine();
        let ds = coco::build(20, 17);
        let mut gw = gateway(&e, "LE", 3);
        let report = run_dataset(
            &mut gw,
            &ds,
            &OpenLoopConfig {
                arrivals: ArrivalProcess::Poisson { rate_rps: 20.0 },
                queue_capacity: 8,
                seed: 7,
                churn: Some(ChurnConfig {
                    mtbf_s: f64::INFINITY,
                    policy: ResiliencePolicy::Hedge,
                    horizon_slack_s: 1.0,
                    ..Default::default()
                }),
                slo: None,
                adapt: None,
                campaign: None,
                obs: None,
            },
        )
        .unwrap();
        let churn = report.churn.as_ref().expect("churn report");
        assert_eq!(
            churn.hedged, report.offered,
            "with both pairs free every request should hedge"
        );
        assert!(churn.hedge_wins <= churn.hedged);
        assert!(report.peak_in_flight >= 2, "copies must overlap");
        assert!(
            churn.wasted_energy_mwh > 0.0,
            "losing copies must be accounted as waste"
        );
        assert_eq!(churn.crashes, 0);
        assert_eq!(churn.lost, 0);
        // each request served exactly once despite two copies
        assert_eq!(report.metrics.requests, report.offered);
        assert_eq!(gw.pool().total_in_flight(), 0);
    }

    #[test]
    fn hedge_under_crashes_accounts_each_request_once() {
        // regression: a primary lost synchronously at dispatch (stale
        // membership view) must see its hedge as a live sibling —
        // both copies register before either is admitted — not declare
        // the request lost while the duplicate goes on to serve it.
        let e = engine();
        let ds = coco::build(32, 63);
        let mut gw = gateway(&e, "LE", 3);
        let report = run_dataset(
            &mut gw,
            &ds,
            &OpenLoopConfig {
                arrivals: ArrivalProcess::Poisson { rate_rps: 200.0 },
                queue_capacity: 4,
                seed: 3,
                churn: Some(ChurnConfig {
                    mtbf_s: 0.1,
                    mttr_s: 0.15,
                    probe_interval_s: 0.04,
                    probe_timeout_s: 0.02,
                    suspect_after: 1,
                    policy: ResiliencePolicy::Hedge,
                    horizon_slack_s: 1.0,
                    ..Default::default()
                }),
                slo: None,
                adapt: None,
                campaign: None,
                obs: None,
            },
        )
        .unwrap();
        let churn = report.churn.as_ref().expect("churn report");
        assert!(churn.crashes > 0, "churn never fired");
        assert!(churn.hedged > 0, "no hedges dispatched");
        assert_eq!(
            report.metrics.requests + report.dropped + churn.lost,
            report.offered,
            "hedged requests must be counted exactly once \
             (served {} dropped {} lost {})",
            report.metrics.requests,
            report.dropped,
            churn.lost
        );
        assert_eq!(gw.pool().total_in_flight(), 0);
    }

    #[test]
    fn churn_runs_replay_bit_identically() {
        // seed sensitivity of the failure timeline itself is pinned in
        // lifecycle::tests; here the whole serialized run must replay
        // byte for byte (heap order, losses, retries, probe effects).
        let e = engine();
        let ds = coco::build(24, 51);
        let run = |churn_seed: u64| {
            let mut gw = gateway(&e, "ED", 3);
            run_dataset(
                &mut gw,
                &ds,
                &OpenLoopConfig {
                    arrivals: ArrivalProcess::Poisson { rate_rps: 120.0 },
                    queue_capacity: 4,
                    seed: 19,
                    churn: Some(ChurnConfig {
                        mtbf_s: 0.2,
                        mttr_s: 0.3,
                        probe_interval_s: 0.05,
                        probe_timeout_s: 0.02,
                        suspect_after: 1,
                        policy: ResiliencePolicy::Retry { budget: 3 },
                        retry_backoff_s: 0.05,
                        horizon_slack_s: 2.0,
                        seed: churn_seed,
                        ..Default::default()
                    }),
                    slo: None,
                    adapt: None,
                    campaign: None,
                    obs: None,
                },
            )
            .unwrap()
            .to_json()
            .dump()
        };
        assert_eq!(run(5), run(5));
    }

    #[test]
    fn open_loop_replays_bit_identically_from_seeds() {
        let e = engine();
        let ds = coco::build(15, 99);
        let run = |e: &Engine| {
            let mut gw = gateway(e, "ED", 3);
            run_dataset(
                &mut gw,
                &ds,
                &OpenLoopConfig {
                    arrivals: ArrivalProcess::Poisson { rate_rps: 40.0 },
                    queue_capacity: 4,
                    seed: 17,
                    churn: None,
                    slo: None,
                    adapt: None,
                    campaign: None,
                    obs: None,
                },
            )
            .unwrap()
        };
        let a = run(&e);
        let b = run(&e);
        assert_eq!(a.metrics.requests, b.metrics.requests);
        assert_eq!(a.dropped, b.dropped);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.metrics.total_latency_s, b.metrics.total_latency_s);
        assert_eq!(a.metrics.queue_delay_s, b.metrics.queue_delay_s);
        assert_eq!(
            a.metrics.latency_samples,
            b.metrics.latency_samples
        );
    }

    #[test]
    fn edf_orders_backlog_and_infinite_keys_stay_fifo() {
        let mk = |idx: usize, edf: f64| Pending {
            routed: RoutedRequest {
                pair_id: PairId(0),
                group: 0,
                estimate: 0,
                true_count: 0,
                cost: Default::default(),
            },
            idx,
            arrival_s: 0.0,
            hedge: false,
            slo: SloTag {
                class: 0,
                deadline_s: edf,
                edf_s: edf,
                amortized: false,
                net: true,
            },
        };
        let mut q = NodeQueue::default();
        push_pending(&mut q, mk(0, 0.5));
        push_pending(&mut q, mk(1, 0.2));
        push_pending(&mut q, mk(2, 0.9));
        push_pending(&mut q, mk(3, 0.2)); // tie stays behind its equal
        let order: Vec<usize> =
            q.backlog.iter().map(|p| p.idx).collect();
        assert_eq!(order, vec![1, 3, 0, 2]);

        // SLOs off: every key is infinite, so insertion order survives
        let mut q = NodeQueue::default();
        for i in 0..3 {
            push_pending(
                &mut q,
                Pending { slo: SloTag::default(), ..mk(i, 0.0) },
            );
        }
        let order: Vec<usize> =
            q.backlog.iter().map(|p| p.idx).collect();
        assert_eq!(order, vec![0, 1, 2]);
    }

    #[test]
    fn slo_admission_sheds_doomed_requests_up_front() {
        use crate::workload::slo::SloClass;
        // deadlines far below even one service time: the admission
        // predictor sees every completion past its budget and sheds at
        // the gateway instead of queueing doomed work. The ledger still
        // balances and the slo block shows up in the JSON report.
        let e = engine();
        let ds = coco::build(12, 23);
        let mut gw = gateway(&e, "LE", 3);
        let report = run_dataset(
            &mut gw,
            &ds,
            &OpenLoopConfig {
                arrivals: ArrivalProcess::Poisson { rate_rps: 50.0 },
                queue_capacity: 8,
                seed: 21,
                churn: None,
                slo: Some(SloConfig {
                    classes: vec![SloClass {
                        name: "impossible".to_string(),
                        deadline_s: 1e-4,
                    }],
                    batch_window_s: 0.0,
                    max_batch: 1,
                }),
                adapt: None,
                campaign: None,
                obs: None,
            },
        )
        .unwrap();
        let slo = report.slo.as_ref().expect("slo report");
        assert_eq!(report.metrics.requests, 0);
        assert_eq!(report.dropped, report.offered);
        assert_eq!(slo.shed.iter().sum::<usize>(), report.offered);
        assert_eq!(slo.overall_attainment_pct(), 0.0);
        assert_eq!(gw.pool().total_in_flight(), 0);
        assert!(report.to_json().dump().contains("slo"));
    }

    #[test]
    fn batching_at_saturation_raises_goodput_and_cuts_energy() {
        use crate::workload::slo::SloClass;
        // acceptance shape: saturating arrivals, generous deadlines, a
        // queue deep enough that nothing is shed — so both runs serve
        // identical requests on the same pair and differ only in batch
        // formation. Amortized followers (and their skipped network
        // hops) must show up as strictly higher goodput and strictly
        // lower energy per request than the unbatched run.
        let e = engine();
        let ds = coco::build(40, 33);
        let run = |window_s: f64| {
            let mut gw = gateway(&e, "LE", 3);
            run_dataset(
                &mut gw,
                &ds,
                &OpenLoopConfig {
                    arrivals: ArrivalProcess::Poisson {
                        rate_rps: 400.0,
                    },
                    queue_capacity: 64,
                    seed: 11,
                    churn: None,
                    slo: Some(SloConfig {
                        classes: vec![SloClass {
                            name: "relaxed".to_string(),
                            deadline_s: 1e9,
                        }],
                        batch_window_s: window_s,
                        max_batch: 4,
                    }),
                    adapt: None,
                    campaign: None,
                    obs: None,
                },
            )
            .unwrap()
        };
        let fifo = run(0.0);
        let batched = run(0.02);
        assert_eq!(fifo.dropped, 0);
        assert_eq!(batched.dropped, 0);
        assert_eq!(fifo.metrics.requests, batched.metrics.requests);
        let fs = fifo.slo.as_ref().expect("slo report");
        let bs = batched.slo.as_ref().expect("slo report");
        assert!((fs.mean_batch_size() - 1.0).abs() < 1e-12);
        assert!(
            bs.mean_batch_size() > 1.5,
            "batches never formed: {}",
            bs.mean_batch_size()
        );
        assert_eq!(fs.overall_attainment_pct(), 100.0);
        assert_eq!(bs.overall_attainment_pct(), 100.0);
        assert!(
            batched.goodput_rps() > fifo.goodput_rps(),
            "batched {:.2} vs fifo {:.2} req/s",
            batched.goodput_rps(),
            fifo.goodput_rps()
        );
        assert!(
            batched.energy_per_request_mwh()
                < fifo.energy_per_request_mwh(),
            "batched {:.6} vs fifo {:.6} mWh/req",
            batched.energy_per_request_mwh(),
            fifo.energy_per_request_mwh()
        );
    }

    #[test]
    fn slo_runs_replay_bit_identically() {
        // the full SLO path — admission, formation, EDF, attainment —
        // on the default three-class mix must replay byte for byte.
        let e = engine();
        let ds = coco::build(18, 47);
        let run = || {
            let mut gw = gateway(&e, "ED", 3);
            run_dataset(
                &mut gw,
                &ds,
                &OpenLoopConfig {
                    arrivals: ArrivalProcess::Poisson {
                        rate_rps: 150.0,
                    },
                    queue_capacity: 4,
                    seed: 29,
                    churn: None,
                    slo: Some(SloConfig::default()),
                    adapt: None,
                    campaign: None,
                    obs: None,
                },
            )
            .unwrap()
            .to_json()
            .dump()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn mmpp_arrivals_are_deterministic_bursty_and_ordered() {
        let p = ArrivalProcess::Mmpp {
            rates: [200.0, 5.0],
            dwell_s: 0.5,
        };
        let a = p.times(400, 9);
        assert_eq!(a, p.times(400, 9), "same seed must replay");
        assert_ne!(a, p.times(400, 10), "seed must matter");
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "nondecreasing");
        // burstiness: the squared coefficient of variation of the
        // inter-arrival gaps must exceed a Poisson process's 1.0 —
        // arrivals clump in the 200 rps phase and starve in the 5 rps
        // phase
        let gaps: Vec<f64> = std::iter::once(a[0])
            .chain(a.windows(2).map(|w| w[1] - w[0]))
            .collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>()
            / gaps.len() as f64;
        let cv2 = var / (mean * mean);
        assert!(cv2 > 1.5, "MMPP not bursty: cv^2 = {cv2}");
        // degenerate MMPP (equal rates) is just Poisson pacing: still
        // deterministic and ordered
        let q = ArrivalProcess::Mmpp {
            rates: [20.0, 20.0],
            dwell_s: 0.1,
        };
        let b = q.times(50, 3);
        assert_eq!(b, q.times(50, 3));
        assert!(b.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn campaign_domain_outage_blacks_out_the_fleet_and_recovers() {
        // pure-campaign run (infinite node mtbf): both pool nodes sit
        // in one failure domain, so every outage is a full blackout —
        // each outage crashes exactly both nodes, restores rejoin
        // them, and the retry policy claws back what it can. The
        // ledger must balance and the whole report must replay byte
        // for byte.
        let e = engine();
        let ds = coco::build(40, 27);
        let run = || {
            let mut gw = gateway(&e, "LE", 3);
            run_dataset(
                &mut gw,
                &ds,
                &OpenLoopConfig {
                    arrivals: ArrivalProcess::Poisson { rate_rps: 60.0 },
                    queue_capacity: 8,
                    seed: 15,
                    churn: Some(ChurnConfig {
                        mtbf_s: f64::INFINITY,
                        probe_interval_s: 0.05,
                        probe_timeout_s: 0.02,
                        suspect_after: 1,
                        policy: ResiliencePolicy::Retry { budget: 4 },
                        retry_backoff_s: 0.05,
                        horizon_slack_s: 2.0,
                        ..Default::default()
                    }),
                    slo: None,
                    adapt: None,
                    campaign: Some(CampaignConfig {
                        domain_size: 2,
                        domain_mtbf_s: 0.5,
                        domain_mttr_s: 0.3,
                        gateway_mtbf_s: f64::INFINITY,
                        gateway_mttr_s: 1.0,
                        seed: 23,
                    }),
                    obs: None,
                },
            )
            .unwrap()
        };
        let report = run();
        let camp = report.campaign.as_ref().expect("campaign report");
        let churn = report.churn.as_ref().expect("churn report");
        assert_eq!(camp.domains, 1);
        assert_eq!(camp.domain_size, 2);
        assert!(camp.domain_outages > 0, "no outages fired");
        assert_eq!(camp.gw_kills, 0);
        assert!(camp.mean_outage_s > 0.0);
        // every outage crashes the whole domain at one instant, and
        // with infinite node mtbf those are the ONLY crashes
        assert_eq!(churn.crashes, 2 * camp.domain_outages);
        assert_eq!(
            report.metrics.requests + report.dropped + churn.lost,
            report.offered,
            "served + dropped + lost must equal offered"
        );
        assert!(report.to_json().dump().contains("campaign"));
        let a = run().to_json().dump();
        let b = run().to_json().dump();
        assert_eq!(a, b, "campaign run must replay bit-identically");
    }

    #[test]
    fn campaign_validation_rejects_unsupported_combos() {
        let e = engine();
        let ds = coco::build(4, 3);
        // campaign without churn: the resilience machinery the
        // campaign feeds does not exist
        let mut gw = gateway(&e, "LE", 3);
        let err = run_dataset(
            &mut gw,
            &ds,
            &OpenLoopConfig {
                campaign: Some(CampaignConfig::default()),
                ..OpenLoopConfig::default()
            },
        )
        .unwrap_err();
        assert!(err.to_string().contains("churn"), "{err}");
        // gateway kills: the open loop has no shard gateways
        let mut gw = gateway(&e, "LE", 3);
        let err = run_dataset(
            &mut gw,
            &ds,
            &OpenLoopConfig {
                churn: Some(ChurnConfig {
                    mtbf_s: f64::INFINITY,
                    ..Default::default()
                }),
                campaign: Some(CampaignConfig {
                    gateway_mtbf_s: 5.0,
                    ..CampaignConfig::default()
                }),
                ..OpenLoopConfig::default()
            },
        )
        .unwrap_err();
        assert!(err.to_string().contains("fleet"), "{err}");
    }

    #[test]
    fn hedge_cancellation_cuts_waste_and_keeps_the_ledger_exact() {
        // gentle load (one request at a time): every request hedges
        // onto the second pair, the fast pair always wins, and with
        // cancellation ON the loser is killed mid-service — so its
        // waste is the pro-rated fraction of its energy, strictly less
        // than the run-to-completion waste, while served counts and
        // the ledger stay identical.
        let e = engine();
        let ds = coco::build(12, 19);
        let run = |cancel: bool| {
            let mut gw = gateway(&e, "LE", 3);
            run_dataset(
                &mut gw,
                &ds,
                &OpenLoopConfig {
                    arrivals: ArrivalProcess::Uniform { gap_s: 0.5 },
                    queue_capacity: 8,
                    seed: 7,
                    churn: Some(ChurnConfig {
                        mtbf_s: f64::INFINITY,
                        policy: ResiliencePolicy::Hedge,
                        hedge_cancel: cancel,
                        horizon_slack_s: 1.0,
                        ..Default::default()
                    }),
                    slo: None,
                    adapt: None,
                    campaign: None,
                    obs: None,
                },
            )
            .unwrap()
        };
        let off = run(false);
        let on = run(true);
        for (label, r) in [("off", &off), ("on", &on)] {
            let c = r.churn.as_ref().expect("churn report");
            assert_eq!(c.hedged, r.offered, "{label}: every req hedges");
            assert_eq!(c.crashes, 0, "{label}");
            assert_eq!(c.lost, 0, "{label}");
            assert_eq!(r.dropped, 0, "{label}");
            assert_eq!(
                r.metrics.requests, r.offered,
                "{label}: each request served exactly once"
            );
        }
        let w_off =
            off.churn.as_ref().unwrap().wasted_energy_mwh;
        let w_on = on.churn.as_ref().unwrap().wasted_energy_mwh;
        assert!(w_off > 0.0, "losing copies must cost something");
        assert!(
            w_on < w_off,
            "cancellation must cut waste: on {w_on} vs off {w_off}"
        );
        // cancelled-run replay stays bit-identical
        let again = run(true);
        assert_eq!(on.to_json().dump(), again.to_json().dump());
    }

    #[test]
    fn scaler_holds_steady_on_a_constant_rate_workload() {
        // Hysteresis: a constant-rate workload whose utilization sits
        // inside the (down_util, up_util) band must never flap power
        // state — no power-downs into troughs that don't exist, no
        // re-warms chasing noise.
        let e = engine();
        let ds = coco::build(160, 23);
        let mut gw = gateway(&e, "LE", 3);
        let report = run_dataset(
            &mut gw,
            &ds,
            &OpenLoopConfig {
                // 40 req/s x 27.5 ms mean service / 2 nodes = 0.55
                // utilization: between down_util 0.35 and up_util 0.75
                arrivals: ArrivalProcess::Uniform { gap_s: 0.025 },
                queue_capacity: 8,
                seed: 31,
                churn: None,
                slo: None,
                adapt: Some(AdaptConfig::default()),
                campaign: None,
                obs: None,
            },
        )
        .unwrap();
        let a = report.adapt.as_ref().expect("adapt report");
        assert_eq!(a.power_downs, 0, "scaler flapped down: {a:?}");
        assert_eq!(a.power_ups, 0, "scaler flapped up: {a:?}");
        assert!(a.telemetry_samples > 0, "completions fed no telemetry");
        // nobody powered off, so the adaptive fleet burned exactly the
        // static fleet's node-seconds
        assert_eq!(a.powered_node_s, a.static_node_s);
    }

    #[test]
    fn adapt_runs_with_drift_replay_bit_identically() {
        // The full adaptation path — telemetry EWMAs, publication,
        // correction overlays, scale ticks — on a drifting fleet must
        // replay byte for byte.
        use crate::devices::drift::DriftConfig;
        let e = engine();
        let ds = coco::build(24, 41);
        let run = || {
            let mut gw = gateway(&e, "ED", 3);
            gw.pool_mut().enable_drift(&DriftConfig::default(), 7);
            run_dataset(
                &mut gw,
                &ds,
                &OpenLoopConfig {
                    arrivals: ArrivalProcess::Poisson { rate_rps: 60.0 },
                    queue_capacity: 8,
                    seed: 37,
                    churn: None,
                    slo: None,
                    adapt: Some(AdaptConfig::default()),
                    campaign: None,
                    obs: None,
                },
            )
            .unwrap()
            .to_json()
            .dump()
        };
        assert_eq!(run(), run());
    }
}
