//! Open-loop concurrent workload driver (DESIGN.md §6).
//!
//! Where the closed loop fires each request only after the previous
//! response arrives, the open loop models *offered* traffic: arrivals
//! fire at a configurable rate regardless of completions, many requests
//! are in flight at once, and each edge node serves a bounded FIFO
//! queue. Busy nodes accumulate queueing delay; a full queue triggers
//! the gateway's existing fallback re-route path, and a request finding
//! every feasible queue full is dropped (load shedding). This is the
//! regime where the paper's routing policies actually diverge under
//! load — a router that piles requests onto the single lowest-energy
//! node pays for it in tail latency once the arrival rate approaches
//! that node's service rate.
//!
//! The driver is a deterministic discrete-event simulator: a binary
//! min-heap of (virtual time, sequence) events over the same virtual
//! clock the rest of ECORE uses. Arrival times come from a seeded
//! [`ArrivalProcess`]; service times come from the node models (real
//! PJRT inference + simulated device cost), so a whole run replays
//! bit-identically from its seeds.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, VecDeque};

use anyhow::Result;

use crate::dataset::{Dataset, GtBox, Scene};
use crate::devices;
use crate::gateway::{Gateway, RoutedRequest};
use crate::metrics::RunMetrics;
use crate::nodes::NodeResponse;
use crate::router::PairKey;
use crate::util::rng::Rng;

/// How requests arrive at the gateway.
#[derive(Clone, Debug)]
pub enum ArrivalProcess {
    /// Poisson arrivals: exponential inter-arrival times at `rate_rps`.
    Poisson { rate_rps: f64 },
    /// Deterministic pacing: one arrival every `gap_s` seconds.
    Uniform { gap_s: f64 },
    /// Trace replay: explicit arrival timestamps (s), nondecreasing.
    /// Extra requests beyond the trace reuse its last gap.
    Trace(Vec<f64>),
}

impl ArrivalProcess {
    /// Materialize `n` arrival timestamps, deterministic in `seed`.
    pub fn times(&self, n: usize, seed: u64) -> Vec<f64> {
        match self {
            ArrivalProcess::Poisson { rate_rps } => {
                let mut rng = Rng::new(seed ^ 0x09E2_7A11);
                let mut t = 0.0;
                (0..n)
                    .map(|_| {
                        // inverse-CDF exponential sample; 1 - u in (0, 1]
                        t += -(1.0 - rng.f64()).ln() / rate_rps.max(1e-9);
                        t
                    })
                    .collect()
            }
            ArrivalProcess::Uniform { gap_s } => {
                (0..n).map(|i| (i + 1) as f64 * gap_s).collect()
            }
            ArrivalProcess::Trace(ts) => {
                let mut out: Vec<f64> = ts.iter().copied().take(n).collect();
                let last_gap = match ts.len() {
                    0 => 1.0,
                    1 => ts[0],
                    k => ts[k - 1] - ts[k - 2],
                };
                while out.len() < n {
                    let last = out.last().copied().unwrap_or(0.0);
                    out.push(last + last_gap.max(1e-9));
                }
                out
            }
        }
    }
}

/// Configuration of one open-loop run.
#[derive(Clone, Debug)]
pub struct OpenLoopConfig {
    pub arrivals: ArrivalProcess,
    /// Bounded per-node FIFO capacity (the in-service slot included).
    pub queue_capacity: usize,
    /// Seed for the arrival process (independent of the gateway seed).
    pub seed: u64,
}

impl Default for OpenLoopConfig {
    fn default() -> Self {
        Self {
            arrivals: ArrivalProcess::Poisson { rate_rps: 8.0 },
            queue_capacity: 8,
            seed: 7,
        }
    }
}

/// Outcome of one open-loop run.
#[derive(Clone, Debug)]
pub struct OpenLoopReport {
    /// Per-request accounting (energy, accuracy, queue delay, latency
    /// percentiles) over the *served* requests.
    pub metrics: RunMetrics,
    /// Requests offered by the arrival process (served + dropped).
    pub offered: usize,
    /// Requests shed because every feasible queue was full.
    pub dropped: usize,
    /// Virtual time at which the last response left the system (s).
    pub makespan_s: f64,
    /// Peak number of requests simultaneously in the system.
    pub peak_in_flight: usize,
    /// Fallback re-routes during this run (down or queue-full nodes),
    /// snapshotted from the gateway's cumulative counter.
    pub fallbacks: usize,
}

impl OpenLoopReport {
    /// Served throughput over the run's virtual wall-clock (req/s).
    pub fn goodput_rps(&self) -> f64 {
        if self.makespan_s > 0.0 {
            self.metrics.requests as f64 / self.makespan_s
        } else {
            0.0
        }
    }

    /// Stable JSON report (field order fixed by the Json substrate's
    /// BTreeMap) — the golden-trace determinism tests compare this dump
    /// byte for byte across runs.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("offered", Json::num(self.offered as f64)),
            ("dropped", Json::num(self.dropped as f64)),
            ("fallbacks", Json::num(self.fallbacks as f64)),
            ("makespan_s", Json::num(self.makespan_s)),
            ("peak_in_flight", Json::num(self.peak_in_flight as f64)),
            ("goodput_rps", Json::num(self.goodput_rps())),
            ("metrics", self.metrics.to_json()),
        ])
    }
}

/// One event on the virtual clock. Ordered by (time, sequence) so ties
/// resolve in insertion order and the whole run is deterministic.
///
/// NOTE: `fleet::run_frames` carries a shard-aware copy of this event
/// machinery (ordering, queue-delay formula, completion scheduling).
/// A fix to either copy must land in both — the golden-trace tests pin
/// each side's behavior.
struct Event {
    t: f64,
    seq: u64,
    kind: EventKind,
}

enum EventKind {
    /// Request `idx` arrives at the gateway.
    Arrival(usize),
    /// The in-service request on this node's queue completes.
    Completion(PairKey),
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq && self.t.total_cmp(&other.t).is_eq()
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t.total_cmp(&other.t).then(self.seq.cmp(&other.seq))
    }
}

/// A request admitted to a node's FIFO, waiting for service.
struct Pending {
    routed: RoutedRequest,
    idx: usize,
    arrival_s: f64,
}

/// The request a node is currently serving; the inference already ran
/// (its result is part of the completion event's payload).
struct InService {
    routed: RoutedRequest,
    idx: usize,
    arrival_s: f64,
    start_s: f64,
    resp: NodeResponse,
}

/// Per-node serving state: one in-service slot + FIFO backlog.
#[derive(Default)]
struct NodeQueue {
    serving: Option<InService>,
    backlog: VecDeque<Pending>,
}

/// Drive a gateway over pre-rendered frames under open-loop arrivals.
///
/// `pseudo_gt[i]` doubles as the evaluation ground truth and the Oracle
/// estimator's request metadata, exactly like the closed-loop driver.
pub fn run_frames(
    gw: &mut Gateway<'_>,
    frames: &[Scene],
    pseudo_gt: &[Vec<GtBox>],
    cfg: &OpenLoopConfig,
) -> Result<OpenLoopReport> {
    anyhow::ensure!(frames.len() == pseudo_gt.len());
    gw.pool_mut().set_queue_capacity(cfg.queue_capacity);
    let fallbacks_before = gw.fallbacks;

    let mut metrics = RunMetrics::new(gw.spec.name);
    let mut heap: BinaryHeap<Reverse<Event>> = BinaryHeap::new();
    let mut queues: BTreeMap<PairKey, NodeQueue> = BTreeMap::new();
    let mut seq = 0u64;
    for (idx, t) in cfg
        .arrivals
        .times(frames.len(), cfg.seed)
        .into_iter()
        .enumerate()
    {
        heap.push(Reverse(Event {
            t,
            seq,
            kind: EventKind::Arrival(idx),
        }));
        seq += 1;
    }

    let mut dropped = 0usize;
    let mut in_flight = 0usize;
    let mut peak_in_flight = 0usize;
    let mut makespan_s = 0.0f64;

    while let Some(Reverse(ev)) = heap.pop() {
        match ev.kind {
            EventKind::Arrival(idx) => {
                let scene = &frames[idx];
                let true_count = pseudo_gt[idx].len();
                // route() observes per-node occupancy: full or unhealthy
                // nodes are skipped via the fallback path; if no feasible
                // endpoint has a free slot, the request is shed. Any
                // other routing error (estimator inference failure,
                // misconfigured store) is real and aborts the run.
                let routed = match gw.route(&scene.image, true_count) {
                    Ok(r) => r,
                    Err(e) if e.is::<crate::gateway::NoEndpoint>() => {
                        dropped += 1;
                        continue;
                    }
                    Err(e) => return Err(e),
                };
                let admitted = gw.pool_mut().acquire(&routed.pair);
                debug_assert!(
                    admitted,
                    "route() returned a pair without a free slot"
                );
                in_flight += 1;
                peak_in_flight = peak_in_flight.max(in_flight);
                let pair = routed.pair.clone();
                queues.entry(pair.clone()).or_default().backlog.push_back(
                    Pending {
                        routed,
                        idx,
                        arrival_s: ev.t,
                    },
                );
                start_next(gw, frames, &mut queues, &mut heap, &mut seq, &pair, ev.t)?;
            }
            EventKind::Completion(pair) => {
                let q = queues
                    .get_mut(&pair)
                    .expect("completion for unknown queue");
                let done = q
                    .serving
                    .take()
                    .expect("completion with no in-service request");
                gw.pool_mut().release(&pair);
                in_flight -= 1;
                makespan_s = makespan_s.max(ev.t);
                // FIFO wait: service start minus the moment the request
                // cleared gateway-side estimation.
                let queue_delay_s = (done.start_s
                    - (done.arrival_s + done.routed.cost.latency_s))
                    .max(0.0);
                gw.finish(
                    &done.routed,
                    done.resp,
                    &pseudo_gt[done.idx],
                    queue_delay_s,
                    &mut metrics,
                );
                start_next(gw, frames, &mut queues, &mut heap, &mut seq, &pair, ev.t)?;
            }
        }
    }

    Ok(OpenLoopReport {
        metrics,
        offered: frames.len(),
        dropped,
        makespan_s,
        peak_in_flight,
        fallbacks: gw.fallbacks - fallbacks_before,
    })
}

/// If `pair` is idle and has backlog, begin serving the head request at
/// `now_s` and schedule its completion. Service cannot begin before the
/// request's gateway-side estimation has finished.
#[allow(clippy::too_many_arguments)]
fn start_next(
    gw: &mut Gateway<'_>,
    frames: &[Scene],
    queues: &mut BTreeMap<PairKey, NodeQueue>,
    heap: &mut BinaryHeap<Reverse<Event>>,
    seq: &mut u64,
    pair: &PairKey,
    now_s: f64,
) -> Result<()> {
    let q = queues.get_mut(pair).expect("start_next on unknown queue");
    if q.serving.is_some() {
        return Ok(());
    }
    let Some(p) = q.backlog.pop_front() else {
        return Ok(());
    };
    let start_s = now_s.max(p.arrival_s + p.routed.cost.latency_s);
    let resp = gw.serve(pair, &frames[p.idx].image, start_s)?;
    let done_s = start_s + resp.latency_s + devices::NETWORK_S;
    heap.push(Reverse(Event {
        t: done_s,
        seq: *seq,
        kind: EventKind::Completion(pair.clone()),
    }));
    *seq += 1;
    // re-borrow: gw.serve() above needed &mut Gateway exclusively
    queues.get_mut(pair).expect("queue vanished").serving =
        Some(InService {
            routed: p.routed,
            idx: p.idx,
            arrival_s: p.arrival_s,
            start_s,
            resp,
        });
    Ok(())
}

/// Render a dataset up front and drive it open loop (the per-scene
/// render cost must not sit on the event clock's critical path).
pub fn run_dataset(
    gw: &mut Gateway<'_>,
    dataset: &Dataset,
    cfg: &OpenLoopConfig,
) -> Result<OpenLoopReport> {
    let frames: Vec<Scene> = dataset.iter_scenes().collect();
    let gts: Vec<Vec<GtBox>> =
        frames.iter().map(|s| s.gt.clone()).collect();
    run_frames(gw, &frames, &gts, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::coco;
    use crate::devices::fleet;
    use crate::gateway::router_by_name;
    use crate::nodes::NodePool;
    use crate::router::{PairProfile, ProfileStore};
    use crate::runtime::Engine;
    use crate::workload;

    fn engine() -> Engine {
        Engine::new(&crate::default_artifacts_dir()).unwrap()
    }

    fn store() -> ProfileStore {
        let mut rows = Vec::new();
        for g in 0..5 {
            rows.push(PairProfile {
                pair: PairKey::new("ssd_v1", "jetson_orin_nano"),
                group: g,
                map: 50.0,
                latency_s: 0.005,
                energy_mwh: 0.002,
            });
            rows.push(PairProfile {
                pair: PairKey::new("yolov8n", "pi5"),
                group: g,
                map: if g >= 2 { 75.0 } else { 51.0 },
                latency_s: 0.05,
                energy_mwh: 0.05,
            });
        }
        ProfileStore::new(rows)
    }

    fn gateway<'e>(e: &'e Engine, router: &str, seed: u64) -> Gateway<'e> {
        let s = store();
        let pool =
            NodePool::deploy(e, &s.pairs(), &fleet(), seed).unwrap();
        Gateway::new(e, router_by_name(router).unwrap(), s, pool, 5.0, seed)
    }

    #[test]
    fn arrival_processes_are_deterministic_and_ordered() {
        let p = ArrivalProcess::Poisson { rate_rps: 20.0 };
        let a = p.times(50, 9);
        let b = p.times(50, 9);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
        assert_ne!(a, p.times(50, 10));
        // mean inter-arrival ~ 1/rate
        let mean_gap = a.last().unwrap() / 50.0;
        assert!((mean_gap - 0.05).abs() < 0.03, "mean gap {mean_gap}");

        let u = ArrivalProcess::Uniform { gap_s: 0.5 }.times(3, 0);
        assert_eq!(u, vec![0.5, 1.0, 1.5]);

        let tr = ArrivalProcess::Trace(vec![0.1, 0.3]).times(4, 0);
        assert_eq!(tr, vec![0.1, 0.3, 0.5, 0.7]);
    }

    #[test]
    fn low_rate_open_loop_converges_to_closed_loop() {
        // satellite test (a): with arrivals far slower than service,
        // at most one request is ever in flight, so the open loop must
        // reproduce the closed loop's metrics exactly (same estimator,
        // policy, and jitter RNG sequences).
        let e = engine();
        let ds = coco::build(12, 77);
        for router in ["LE", "RR", "OB"] {
            let mut closed = gateway(&e, router, 3);
            let m_closed =
                workload::run_dataset(&mut closed, &ds).unwrap();

            let mut open = gateway(&e, router, 3);
            let report = run_dataset(
                &mut open,
                &ds,
                &OpenLoopConfig {
                    // 5 s between arrivals vs ~tens of ms of service:
                    // deterministic pacing guarantees zero overlap
                    arrivals: ArrivalProcess::Uniform { gap_s: 5.0 },
                    queue_capacity: 8,
                    seed: 5,
                },
            )
            .unwrap();
            let m_open = &report.metrics;

            assert_eq!(report.dropped, 0, "{router}");
            assert_eq!(report.peak_in_flight, 1, "{router}");
            assert_eq!(m_open.requests, m_closed.requests, "{router}");
            assert_eq!(m_open.queue_delay_s, 0.0, "{router}");
            assert_eq!(m_open.per_pair, m_closed.per_pair, "{router}");
            assert!(
                (m_open.total_latency_s - m_closed.total_latency_s).abs()
                    < 1e-9,
                "{router}: open {} vs closed {}",
                m_open.total_latency_s,
                m_closed.total_latency_s
            );
            assert!(
                (m_open.total_energy_mwh() - m_closed.total_energy_mwh())
                    .abs()
                    < 1e-9,
                "{router}"
            );
        }
    }

    #[test]
    fn queueing_delay_is_monotone_in_arrival_rate() {
        // satellite test (b): same workload, rising offered load =>
        // nondecreasing mean queueing delay. Capacity is large enough
        // that nothing is shed, so every run serves the same requests.
        let e = engine();
        let ds = coco::build(30, 41);
        let mut delays = Vec::new();
        for rate in [1.0, 25.0, 400.0] {
            let mut gw = gateway(&e, "LE", 3);
            let report = run_dataset(
                &mut gw,
                &ds,
                &OpenLoopConfig {
                    arrivals: ArrivalProcess::Poisson { rate_rps: rate },
                    queue_capacity: 64,
                    seed: 11,
                },
            )
            .unwrap();
            assert_eq!(report.dropped, 0, "rate {rate}");
            delays.push(report.metrics.mean_queue_delay_s());
        }
        assert!(
            delays.windows(2).all(|w| w[0] <= w[1]),
            "queue delay not monotone: {delays:?}"
        );
        // and the saturated end genuinely queues
        assert!(delays[2] > 0.0, "{delays:?}");
    }

    #[test]
    fn bounded_queue_overflow_falls_back_then_sheds() {
        // satellite test (c): capacity 1 and near-simultaneous arrivals.
        // LE always prefers the jetson pair, so the second arrival finds
        // it full and must fall back to the other pair (fallbacks += 1);
        // once both single-slot queues are full, arrivals are dropped.
        let e = engine();
        let ds = coco::build(10, 13);
        let mut gw = gateway(&e, "LE", 3);
        let report = run_dataset(
            &mut gw,
            &ds,
            &OpenLoopConfig {
                arrivals: ArrivalProcess::Uniform { gap_s: 1e-6 },
                queue_capacity: 1,
                seed: 2,
            },
        )
        .unwrap();
        assert!(gw.fallbacks > 0, "expected overflow fallbacks");
        assert!(report.dropped > 0, "expected load shedding");
        assert_eq!(
            report.metrics.requests + report.dropped,
            report.offered
        );
        // both pairs ended up serving traffic
        assert_eq!(report.metrics.per_pair.len(), 2);
    }

    #[test]
    fn open_loop_replays_bit_identically_from_seeds() {
        let e = engine();
        let ds = coco::build(15, 99);
        let run = |e: &Engine| {
            let mut gw = gateway(e, "ED", 3);
            run_dataset(
                &mut gw,
                &ds,
                &OpenLoopConfig {
                    arrivals: ArrivalProcess::Poisson { rate_rps: 40.0 },
                    queue_capacity: 4,
                    seed: 17,
                },
            )
            .unwrap()
        };
        let a = run(&e);
        let b = run(&e);
        assert_eq!(a.metrics.requests, b.metrics.requests);
        assert_eq!(a.dropped, b.dropped);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.metrics.total_latency_s, b.metrics.total_latency_s);
        assert_eq!(a.metrics.queue_delay_s, b.metrics.queue_delay_s);
        assert_eq!(
            a.metrics.latency_samples,
            b.metrics.latency_samples
        );
    }
}
