//! Open-loop concurrent workload driver (DESIGN.md §6).
//!
//! Where the closed loop fires each request only after the previous
//! response arrives, the open loop models *offered* traffic: arrivals
//! fire at a configurable rate regardless of completions, many requests
//! are in flight at once, and each edge node serves a bounded FIFO
//! queue. Busy nodes accumulate queueing delay; a full queue triggers
//! the gateway's existing fallback re-route path, and a request finding
//! every feasible queue full is dropped (load shedding). This is the
//! regime where the paper's routing policies actually diverge under
//! load — a router that piles requests onto the single lowest-energy
//! node pays for it in tail latency once the arrival rate approaches
//! that node's service rate.
//!
//! The driver is a deterministic discrete-event simulator: a binary
//! min-heap of (virtual time, sequence) events over the same virtual
//! clock the rest of ECORE uses. Arrival times come from a seeded
//! [`ArrivalProcess`]; service times come from the node models (real
//! PJRT inference + simulated device cost), so a whole run replays
//! bit-identically from its seeds.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, VecDeque};

use anyhow::Result;

use crate::dataset::{Dataset, GtBox, Scene};
use crate::devices;
use crate::estimators::GatewayCost;
use crate::gateway::{Gateway, RoutedRequest};
use crate::lifecycle::{
    self, ChurnConfig, ChurnReport, ChurnState, LossOutcome,
    ResiliencePolicy,
};
use crate::metrics::RunMetrics;
use crate::nodes::{NodeDown, NodeResponse};
use crate::router::PairId;
use crate::util::rng::Rng;

/// How requests arrive at the gateway.
#[derive(Clone, Debug)]
pub enum ArrivalProcess {
    /// Poisson arrivals: exponential inter-arrival times at `rate_rps`.
    Poisson { rate_rps: f64 },
    /// Deterministic pacing: one arrival every `gap_s` seconds.
    Uniform { gap_s: f64 },
    /// Trace replay: explicit arrival timestamps (s), nondecreasing.
    /// Extra requests beyond the trace reuse its last gap.
    Trace(Vec<f64>),
}

impl ArrivalProcess {
    /// Materialize `n` arrival timestamps, deterministic in `seed`.
    pub fn times(&self, n: usize, seed: u64) -> Vec<f64> {
        match self {
            ArrivalProcess::Poisson { rate_rps } => {
                let mut rng = Rng::new(seed ^ 0x09E2_7A11);
                let mut t = 0.0;
                (0..n)
                    .map(|_| {
                        // inverse-CDF exponential sample; 1 - u in (0, 1]
                        t += -(1.0 - rng.f64()).ln() / rate_rps.max(1e-9);
                        t
                    })
                    .collect()
            }
            ArrivalProcess::Uniform { gap_s } => {
                (0..n).map(|i| (i + 1) as f64 * gap_s).collect()
            }
            ArrivalProcess::Trace(ts) => {
                let mut out: Vec<f64> = ts.iter().copied().take(n).collect();
                let last_gap = match ts.len() {
                    0 => 1.0,
                    1 => ts[0],
                    k => ts[k - 1] - ts[k - 2],
                };
                while out.len() < n {
                    let last = out.last().copied().unwrap_or(0.0);
                    out.push(last + last_gap.max(1e-9));
                }
                out
            }
        }
    }
}

/// Configuration of one open-loop run.
#[derive(Clone, Debug)]
pub struct OpenLoopConfig {
    pub arrivals: ArrivalProcess,
    /// Bounded per-node FIFO capacity (the in-service slot included).
    pub queue_capacity: usize,
    /// Seed for the arrival process (independent of the gateway seed).
    pub seed: u64,
    /// Node churn (DESIGN.md §9): ground-truth crash/rejoin events on
    /// the shared heap, probe-driven membership at the gateway, and a
    /// resilience policy for requests lost to crashes. `None` keeps the
    /// pre-churn event stream bit for bit.
    pub churn: Option<ChurnConfig>,
}

impl Default for OpenLoopConfig {
    fn default() -> Self {
        Self {
            arrivals: ArrivalProcess::Poisson { rate_rps: 8.0 },
            queue_capacity: 8,
            seed: 7,
            churn: None,
        }
    }
}

/// Outcome of one open-loop run.
#[derive(Clone, Debug)]
pub struct OpenLoopReport {
    /// Per-request accounting (energy, accuracy, queue delay, latency
    /// percentiles) over the *served* requests.
    pub metrics: RunMetrics,
    /// Requests offered by the arrival process
    /// (served + dropped + lost).
    pub offered: usize,
    /// Requests shed because every feasible queue was full.
    pub dropped: usize,
    /// Virtual time at which the last response left the system (s).
    pub makespan_s: f64,
    /// Peak number of requests simultaneously in the system
    /// (hedged duplicates count individually).
    pub peak_in_flight: usize,
    /// Fallback re-routes during this run (down or queue-full nodes),
    /// snapshotted from the gateway's cumulative counter.
    pub fallbacks: usize,
    /// Churn accounting — present exactly when the run had a lifecycle
    /// config. `served + dropped + lost == offered` always holds.
    pub churn: Option<ChurnReport>,
}

impl OpenLoopReport {
    /// Served throughput over the run's virtual wall-clock (req/s).
    pub fn goodput_rps(&self) -> f64 {
        if self.makespan_s > 0.0 {
            self.metrics.requests as f64 / self.makespan_s
        } else {
            0.0
        }
    }

    /// Requests permanently lost to node crashes (0 without churn).
    pub fn lost(&self) -> usize {
        self.churn.as_ref().map(|c| c.lost).unwrap_or(0)
    }

    /// Mean dynamic energy per served request (mWh), the churn sweep's
    /// headline efficiency column.
    pub fn energy_per_request_mwh(&self) -> f64 {
        if self.metrics.requests > 0 {
            self.metrics.total_energy_mwh() / self.metrics.requests as f64
        } else {
            0.0
        }
    }

    /// Stable JSON report (field order fixed by the Json substrate's
    /// BTreeMap) — the golden-trace determinism tests compare this dump
    /// byte for byte across runs.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut fields = vec![
            ("offered", Json::num(self.offered as f64)),
            ("dropped", Json::num(self.dropped as f64)),
            ("lost", Json::num(self.lost() as f64)),
            ("fallbacks", Json::num(self.fallbacks as f64)),
            ("makespan_s", Json::num(self.makespan_s)),
            ("peak_in_flight", Json::num(self.peak_in_flight as f64)),
            ("goodput_rps", Json::num(self.goodput_rps())),
            ("metrics", self.metrics.to_json()),
        ];
        if let Some(c) = &self.churn {
            fields.push(("churn", c.to_json()));
        }
        Json::obj(fields)
    }
}

/// One event on the virtual clock. Ordered by (time, sequence) so ties
/// resolve in insertion order and the whole run is deterministic.
///
/// NOTE: `fleet::run_frames` carries a shard-aware copy of this event
/// machinery (ordering, queue-delay formula, completion scheduling).
/// A fix to either copy must land in both — the golden-trace tests pin
/// each side's behavior.
struct Event {
    t: f64,
    seq: u64,
    kind: EventKind,
}

enum EventKind {
    /// Request `idx` arrives at the gateway.
    Arrival(usize),
    /// The in-service request on this node's queue completes. `token`
    /// identifies the service instance: a completion whose token no
    /// longer matches the queue's in-service slot belongs to a request
    /// that was lost to a crash and is ignored.
    Completion { pair: PairId, token: u64 },
    /// Ground-truth crash of pool node `node` (churn runs only): the
    /// node rejects traffic and everything queued on it is lost.
    Crash(usize),
    /// Ground-truth rejoin of pool node `node` (reboots its drift
    /// state). The gateway only learns of it through probes.
    Rejoin(usize),
    /// The gateway's periodic health probe fires: ground truth is
    /// snapshotted now, results apply after the probe timeout.
    Probe,
    /// Probe responses (pool order) reach the membership view.
    ProbeResult(Vec<bool>),
    /// Re-dispatch of request `idx` lost to a crash (retry policy).
    Retry(usize),
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq && self.t.total_cmp(&other.t).is_eq()
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t.total_cmp(&other.t).then(self.seq.cmp(&other.seq))
    }
}

/// A request admitted to a node's FIFO, waiting for service.
struct Pending {
    routed: RoutedRequest,
    idx: usize,
    arrival_s: f64,
    /// This copy is a hedged duplicate (its completion may be waste).
    hedge: bool,
}

/// The request a node is currently serving; the inference already ran
/// (its result is part of the completion event's payload).
struct InService {
    routed: RoutedRequest,
    idx: usize,
    arrival_s: f64,
    start_s: f64,
    resp: NodeResponse,
    /// Matches the scheduled completion event; a crash that loses this
    /// request leaves that event stale (token mismatch).
    token: u64,
    hedge: bool,
}

/// Per-node serving state: one in-service slot + FIFO backlog.
#[derive(Default)]
struct NodeQueue {
    serving: Option<InService>,
    backlog: VecDeque<Pending>,
}

/// Mutable simulator state threaded through the event handlers.
struct SimState {
    queues: BTreeMap<PairId, NodeQueue>,
    heap: BinaryHeap<Reverse<Event>>,
    seq: u64,
    dropped: usize,
    in_flight: usize,
    peak_in_flight: usize,
    makespan_s: f64,
}

impl SimState {
    fn new() -> Self {
        Self {
            queues: BTreeMap::new(),
            heap: BinaryHeap::new(),
            seq: 0,
            dropped: 0,
            in_flight: 0,
            peak_in_flight: 0,
            makespan_s: 0.0,
        }
    }

    fn push(&mut self, t: f64, kind: EventKind) {
        self.heap.push(Reverse(Event {
            t,
            seq: self.seq,
            kind,
        }));
        self.seq += 1;
    }
}

/// Driver-side churn context: pool-ordered node identities (indexing
/// the ground-truth failure timeline and probe snapshots), the shared
/// request-copy accounting, and the per-request estimate cache that
/// lets retries re-enter routing without paying the estimator again.
struct ChurnDriver {
    pairs: Vec<PairId>,
    probe_timeout_s: f64,
    state: ChurnState,
    /// `(estimate, gateway cost)` paid at each request's first
    /// admission; retries route with these instead of re-estimating,
    /// so a request pays GatewayCost exactly once.
    est: Vec<Option<(usize, GatewayCost)>>,
}

/// Drive a gateway over pre-rendered frames under open-loop arrivals.
///
/// `pseudo_gt[i]` doubles as the evaluation ground truth and the Oracle
/// estimator's request metadata, exactly like the closed-loop driver.
pub fn run_frames(
    gw: &mut Gateway<'_>,
    frames: &[Scene],
    pseudo_gt: &[Vec<GtBox>],
    cfg: &OpenLoopConfig,
) -> Result<OpenLoopReport> {
    anyhow::ensure!(frames.len() == pseudo_gt.len());
    gw.pool_mut().set_queue_capacity(cfg.queue_capacity);
    let fallbacks_before = gw.fallbacks;

    let mut metrics = RunMetrics::new(gw.spec.name);
    let mut sim = SimState::new();
    let arrival_times = cfg.arrivals.times(frames.len(), cfg.seed);
    let horizon_s = arrival_times.last().copied().unwrap_or(0.0)
        + cfg.churn.as_ref().map(|c| c.horizon_slack_s).unwrap_or(0.0);
    for (idx, t) in arrival_times.into_iter().enumerate() {
        sim.push(t, EventKind::Arrival(idx));
    }

    // churn runs: ground-truth failure timeline + probe schedule are
    // materialized up front (deterministic), the gateway switches to
    // its probe-driven membership view, and per-request copy accounting
    // starts. Without churn nothing below adds a single event.
    let mut churn = match &cfg.churn {
        Some(c) => {
            gw.enable_churn(c);
            // pool-ordered node ids (the failure timeline and probe
            // snapshots address nodes by pool position)
            let pairs: Vec<PairId> = gw
                .pool()
                .nodes()
                .iter()
                .map(|n| {
                    gw.store().id_of(&n.pair).expect(
                        "deployed pair missing from the routing table",
                    )
                })
                .collect();
            for ev in
                lifecycle::failure_schedule(pairs.len(), horizon_s, c)
            {
                let kind = if ev.up {
                    EventKind::Rejoin(ev.node)
                } else {
                    EventKind::Crash(ev.node)
                };
                sim.push(ev.t, kind);
            }
            let gap = c.probe_interval_s.max(1e-6);
            let mut t = gap;
            while t < horizon_s {
                sim.push(t, EventKind::Probe);
                t += gap;
            }
            Some(ChurnDriver {
                pairs,
                probe_timeout_s: c.probe_timeout_s,
                state: ChurnState::new(
                    frames.len(),
                    c.policy,
                    c.retry_backoff_s,
                ),
                est: vec![None; frames.len()],
            })
        }
        None => None,
    };

    while let Some(Reverse(ev)) = sim.heap.pop() {
        match ev.kind {
            EventKind::Arrival(idx) => {
                let scene = &frames[idx];
                let true_count = pseudo_gt[idx].len();
                // the estimator runs ONCE per request, here at first
                // arrival; under churn the result is cached so retries
                // re-enter routing without paying GatewayCost again.
                // Estimator errors (inference failure) abort the run.
                let (estimate, cost) =
                    gw.estimate_request(&scene.image, true_count)?;
                if let Some(ch) = churn.as_mut() {
                    ch.est[idx] = Some((estimate, cost));
                }
                // routing observes per-node occupancy (and, under
                // churn, believed health): full or down nodes are
                // skipped via the fallback path; if no feasible
                // endpoint has a free slot, the request is shed — or,
                // under the retry policy, backed off like a retrying
                // client. Any other routing error (misconfigured
                // store) aborts the run.
                let routed = match gw
                    .route_with_estimate(estimate, true_count, cost, ev.t)
                {
                    Ok(r) => r,
                    Err(e) if e.is::<crate::gateway::NoEndpoint>() => {
                        match churn.as_mut() {
                            Some(ch)
                                if matches!(
                                    ch.state.policy(),
                                    ResiliencePolicy::Retry { .. }
                                ) =>
                            {
                                if let LossOutcome::RetryAt(t) = ch
                                    .state
                                    .placement_failed(idx, ev.t)
                                {
                                    sim.push(t, EventKind::Retry(idx));
                                }
                            }
                            _ => sim.dropped += 1,
                        }
                        continue;
                    }
                    Err(e) => return Err(e),
                };
                // proactive hedging: duplicate onto the second-best
                // admissible pair, reusing the primary's estimate
                let dup = match churn.as_ref() {
                    Some(ch)
                        if ch.state.policy()
                            == ResiliencePolicy::Hedge =>
                    {
                        gw.route_secondary(&routed, ev.t).map(|p| {
                            RoutedRequest { pair_id: p, ..routed }
                        })
                    }
                    _ => None,
                };
                // register BOTH copies before admitting either: the
                // primary can die synchronously at dispatch (stale
                // view), and its loss must see the hedge as a live
                // sibling, not declare the request lost.
                if let Some(ch) = churn.as_mut() {
                    ch.state.dispatched(idx);
                    if dup.is_some() {
                        ch.state.hedge_dispatched(idx);
                    }
                }
                admit_copy(
                    gw, frames, &mut sim, &mut churn, routed, idx, ev.t,
                    false,
                )?;
                if let Some(d) = dup {
                    admit_copy(
                        gw, frames, &mut sim, &mut churn, d, idx, ev.t,
                        true,
                    )?;
                }
            }
            EventKind::Retry(idx) => {
                // the retry carries the request's ORIGINAL estimate
                // and gateway cost (cached at first arrival): the
                // estimator is not consulted again, and the winning
                // copy records that one cost at completion.
                let (estimate, cost) = churn
                    .as_ref()
                    .expect("retry without churn")
                    .est[idx]
                    .expect("retried request was never estimated");
                let routed = match gw.route_with_estimate(
                    estimate,
                    pseudo_gt[idx].len(),
                    cost,
                    ev.t,
                ) {
                    Ok(r) => r,
                    Err(e) if e.is::<crate::gateway::NoEndpoint>() => {
                        let ch =
                            churn.as_mut().expect("retry without churn");
                        if let LossOutcome::RetryAt(t) =
                            ch.state.placement_failed(idx, ev.t)
                        {
                            sim.push(t, EventKind::Retry(idx));
                        }
                        continue;
                    }
                    Err(e) => return Err(e),
                };
                churn
                    .as_mut()
                    .expect("retry without churn")
                    .state
                    .retry_dispatched(idx);
                admit_copy(
                    gw, frames, &mut sim, &mut churn, routed, idx, ev.t,
                    false,
                )?;
            }
            EventKind::Completion { pair, token } => {
                let q = sim
                    .queues
                    .get_mut(&pair)
                    .expect("completion for unknown queue");
                if q.serving.as_ref().map(|s| s.token) != Some(token) {
                    // the in-service request was lost to a crash after
                    // this completion was scheduled — stale event
                    debug_assert!(
                        churn.is_some(),
                        "stale completion without churn"
                    );
                    continue;
                }
                let done = q.serving.take().expect("token just matched");
                gw.pool_mut().release_id(pair);
                sim.in_flight -= 1;
                sim.makespan_s = sim.makespan_s.max(ev.t);
                let winner = match churn.as_mut() {
                    Some(ch) => ch.state.copy_completed(
                        done.idx,
                        done.resp.energy_mwh,
                        done.hedge,
                    ),
                    None => true,
                };
                if winner {
                    // FIFO wait: service start minus the moment the
                    // request cleared gateway-side estimation.
                    let queue_delay_s = (done.start_s
                        - (done.arrival_s + done.routed.cost.latency_s))
                        .max(0.0);
                    gw.finish(
                        &done.routed,
                        done.resp,
                        &pseudo_gt[done.idx],
                        queue_delay_s,
                        &mut metrics,
                    );
                }
                start_next(gw, frames, &mut sim, &mut churn, pair, ev.t)?;
            }
            EventKind::Crash(node) => {
                let ch = churn.as_mut().expect("crash without churn");
                let pair = ch.pairs[node];
                ch.state.crashes += 1;
                gw.pool_mut().set_health_id(pair, false);
                if let Some(m) = gw.membership_mut() {
                    m.ground_truth_changed(pair, false, ev.t);
                }
                lose_queued(gw, &mut sim, &mut ch.state, pair, None, ev.t);
            }
            EventKind::Rejoin(node) => {
                let ch = churn.as_ref().expect("rejoin without churn");
                let pair = ch.pairs[node];
                gw.pool_mut().set_health_id(pair, true);
                if let Some(n) = gw.pool_mut().get_id(pair) {
                    n.on_rejoin(ev.t);
                }
                if let Some(m) = gw.membership_mut() {
                    m.ground_truth_changed(pair, true, ev.t);
                }
            }
            EventKind::Probe => {
                let ch = churn.as_ref().expect("probe without churn");
                let responses: Vec<bool> = ch
                    .pairs
                    .iter()
                    .map(|&p| gw.pool().is_healthy_id(p))
                    .collect();
                let timeout = ch.probe_timeout_s;
                sim.push(ev.t + timeout, EventKind::ProbeResult(responses));
            }
            EventKind::ProbeResult(responses) => {
                let ch = churn.as_ref().expect("probe without churn");
                let m = gw
                    .membership_mut()
                    .expect("churn gateway lost its membership");
                for (&p, up) in ch.pairs.iter().zip(&responses) {
                    m.observe_probe(p, *up, ev.t);
                }
            }
        }
    }

    let churn_report = churn.map(|c| {
        let m = gw
            .membership()
            .expect("churn gateway lost its membership");
        ChurnReport::collect(&c.state, [m])
    });
    Ok(OpenLoopReport {
        metrics,
        offered: frames.len(),
        dropped: sim.dropped,
        makespan_s: sim.makespan_s,
        peak_in_flight: sim.peak_in_flight,
        fallbacks: gw.fallbacks - fallbacks_before,
        churn: churn_report,
    })
}

/// Admit one routed copy of request `idx` into its pair's FIFO at time
/// `t` and try to start service.
#[allow(clippy::too_many_arguments)]
fn admit_copy(
    gw: &mut Gateway<'_>,
    frames: &[Scene],
    sim: &mut SimState,
    churn: &mut Option<ChurnDriver>,
    routed: RoutedRequest,
    idx: usize,
    t: f64,
    hedge: bool,
) -> Result<()> {
    let admitted = gw.pool_mut().acquire_id(routed.pair_id);
    debug_assert!(admitted, "route() returned a pair without a free slot");
    sim.in_flight += 1;
    sim.peak_in_flight = sim.peak_in_flight.max(sim.in_flight);
    let pair = routed.pair_id;
    sim.queues.entry(pair).or_default().backlog.push_back(Pending {
        routed,
        idx,
        arrival_s: t,
        hedge,
    });
    start_next(gw, frames, sim, churn, pair, t)
}

/// If `pair` is idle and has backlog, begin serving the head request at
/// `now_s` and schedule its completion. Service cannot begin before the
/// request's gateway-side estimation has finished. Under churn, a
/// dispatch that discovers a dead node (the membership view is stale)
/// loses everything queued there through the resilience policy and
/// feeds the failure back to the membership as passive health evidence.
fn start_next(
    gw: &mut Gateway<'_>,
    frames: &[Scene],
    sim: &mut SimState,
    churn: &mut Option<ChurnDriver>,
    pair: PairId,
    now_s: f64,
) -> Result<()> {
    let q =
        sim.queues.get_mut(&pair).expect("start_next on unknown queue");
    if q.serving.is_some() {
        return Ok(());
    }
    let Some(p) = q.backlog.pop_front() else {
        return Ok(());
    };
    let start_s = now_s.max(p.arrival_s + p.routed.cost.latency_s);
    let resp = match gw.serve(pair, &frames[p.idx].image, start_s) {
        Ok(r) => r,
        Err(e) if churn.is_some() && e.is::<NodeDown>() => {
            if let Some(m) = gw.membership_mut() {
                m.observe_dispatch_failure(pair, now_s);
            }
            let ch = churn.as_mut().expect("checked above");
            lose_queued(gw, sim, &mut ch.state, pair, Some(p), now_s);
            return Ok(());
        }
        Err(e) => return Err(e),
    };
    let token = sim.seq;
    sim.push(
        start_s + resp.latency_s + devices::NETWORK_S,
        EventKind::Completion { pair, token },
    );
    // re-borrow: gw.serve() above needed &mut Gateway exclusively
    sim.queues.get_mut(&pair).expect("queue vanished").serving =
        Some(InService {
            routed: p.routed,
            idx: p.idx,
            arrival_s: p.arrival_s,
            start_s,
            resp,
            token,
            hedge: p.hedge,
        });
    Ok(())
}

/// Drain every copy on `pair`'s queue — the in-service request (crash
/// case), an optional already-popped head (failed-dispatch case), and
/// the backlog — releasing their slots and feeding each loss through
/// the resilience policy.
fn lose_queued(
    gw: &mut Gateway<'_>,
    sim: &mut SimState,
    state: &mut ChurnState,
    pair: PairId,
    head: Option<Pending>,
    now_s: f64,
) {
    let mut idxs: Vec<usize> = Vec::new();
    if let Some(q) = sim.queues.get_mut(&pair) {
        if let Some(s) = q.serving.take() {
            idxs.push(s.idx);
        }
        if let Some(p) = &head {
            idxs.push(p.idx);
        }
        while let Some(p) = q.backlog.pop_front() {
            idxs.push(p.idx);
        }
    } else if let Some(p) = &head {
        idxs.push(p.idx);
    }
    for idx in idxs {
        gw.pool_mut().release_id(pair);
        sim.in_flight -= 1;
        match state.copy_lost(idx, now_s) {
            LossOutcome::RetryAt(t) => sim.push(t, EventKind::Retry(idx)),
            LossOutcome::Absorbed | LossOutcome::Lost => {}
        }
    }
}

/// Render a dataset up front and drive it open loop (the per-scene
/// render cost must not sit on the event clock's critical path).
pub fn run_dataset(
    gw: &mut Gateway<'_>,
    dataset: &Dataset,
    cfg: &OpenLoopConfig,
) -> Result<OpenLoopReport> {
    let frames: Vec<Scene> = dataset.iter_scenes().collect();
    let gts: Vec<Vec<GtBox>> =
        frames.iter().map(|s| s.gt.clone()).collect();
    run_frames(gw, &frames, &gts, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::coco;
    use crate::devices::fleet;
    use crate::gateway::router_by_name;
    use crate::nodes::NodePool;
    use crate::router::{PairKey, PairProfile, ProfileStore};
    use crate::runtime::Engine;
    use crate::workload;

    fn engine() -> Engine {
        Engine::new(&crate::default_artifacts_dir()).unwrap()
    }

    fn store() -> ProfileStore {
        let mut rows = Vec::new();
        for g in 0..5 {
            rows.push(PairProfile {
                pair: PairKey::new("ssd_v1", "jetson_orin_nano"),
                group: g,
                map: 50.0,
                latency_s: 0.005,
                energy_mwh: 0.002,
            });
            rows.push(PairProfile {
                pair: PairKey::new("yolov8n", "pi5"),
                group: g,
                map: if g >= 2 { 75.0 } else { 51.0 },
                latency_s: 0.05,
                energy_mwh: 0.05,
            });
        }
        ProfileStore::new(rows)
    }

    fn gateway<'e>(e: &'e Engine, router: &str, seed: u64) -> Gateway<'e> {
        let s = store();
        let pool =
            NodePool::deploy(e, &s.pairs(), &fleet(), seed).unwrap();
        Gateway::new(e, router_by_name(router).unwrap(), s, pool, 5.0, seed)
    }

    #[test]
    fn arrival_processes_are_deterministic_and_ordered() {
        let p = ArrivalProcess::Poisson { rate_rps: 20.0 };
        let a = p.times(50, 9);
        let b = p.times(50, 9);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
        assert_ne!(a, p.times(50, 10));
        // mean inter-arrival ~ 1/rate
        let mean_gap = a.last().unwrap() / 50.0;
        assert!((mean_gap - 0.05).abs() < 0.03, "mean gap {mean_gap}");

        let u = ArrivalProcess::Uniform { gap_s: 0.5 }.times(3, 0);
        assert_eq!(u, vec![0.5, 1.0, 1.5]);

        let tr = ArrivalProcess::Trace(vec![0.1, 0.3]).times(4, 0);
        assert_eq!(tr, vec![0.1, 0.3, 0.5, 0.7]);
    }

    #[test]
    fn low_rate_open_loop_converges_to_closed_loop() {
        // satellite test (a): with arrivals far slower than service,
        // at most one request is ever in flight, so the open loop must
        // reproduce the closed loop's metrics exactly (same estimator,
        // policy, and jitter RNG sequences).
        let e = engine();
        let ds = coco::build(12, 77);
        for router in ["LE", "RR", "OB"] {
            let mut closed = gateway(&e, router, 3);
            let m_closed =
                workload::run_dataset(&mut closed, &ds).unwrap();

            let mut open = gateway(&e, router, 3);
            let report = run_dataset(
                &mut open,
                &ds,
                &OpenLoopConfig {
                    // 5 s between arrivals vs ~tens of ms of service:
                    // deterministic pacing guarantees zero overlap
                    arrivals: ArrivalProcess::Uniform { gap_s: 5.0 },
                    queue_capacity: 8,
                    seed: 5,
                    churn: None,
                },
            )
            .unwrap();
            let m_open = &report.metrics;

            assert_eq!(report.dropped, 0, "{router}");
            assert_eq!(report.peak_in_flight, 1, "{router}");
            assert_eq!(m_open.requests, m_closed.requests, "{router}");
            assert_eq!(m_open.queue_delay_s, 0.0, "{router}");
            assert_eq!(m_open.per_pair, m_closed.per_pair, "{router}");
            assert!(
                (m_open.total_latency_s - m_closed.total_latency_s).abs()
                    < 1e-9,
                "{router}: open {} vs closed {}",
                m_open.total_latency_s,
                m_closed.total_latency_s
            );
            assert!(
                (m_open.total_energy_mwh() - m_closed.total_energy_mwh())
                    .abs()
                    < 1e-9,
                "{router}"
            );
        }
    }

    #[test]
    fn queueing_delay_is_monotone_in_arrival_rate() {
        // satellite test (b): same workload, rising offered load =>
        // nondecreasing mean queueing delay. Capacity is large enough
        // that nothing is shed, so every run serves the same requests.
        let e = engine();
        let ds = coco::build(30, 41);
        let mut delays = Vec::new();
        for rate in [1.0, 25.0, 400.0] {
            let mut gw = gateway(&e, "LE", 3);
            let report = run_dataset(
                &mut gw,
                &ds,
                &OpenLoopConfig {
                    arrivals: ArrivalProcess::Poisson { rate_rps: rate },
                    queue_capacity: 64,
                    seed: 11,
                    churn: None,
                },
            )
            .unwrap();
            assert_eq!(report.dropped, 0, "rate {rate}");
            delays.push(report.metrics.mean_queue_delay_s());
        }
        assert!(
            delays.windows(2).all(|w| w[0] <= w[1]),
            "queue delay not monotone: {delays:?}"
        );
        // and the saturated end genuinely queues
        assert!(delays[2] > 0.0, "{delays:?}");
    }

    #[test]
    fn bounded_queue_overflow_falls_back_then_sheds() {
        // satellite test (c): capacity 1 and near-simultaneous arrivals.
        // LE always prefers the jetson pair, so the second arrival finds
        // it full and must fall back to the other pair (fallbacks += 1);
        // once both single-slot queues are full, arrivals are dropped.
        let e = engine();
        let ds = coco::build(10, 13);
        let mut gw = gateway(&e, "LE", 3);
        let report = run_dataset(
            &mut gw,
            &ds,
            &OpenLoopConfig {
                arrivals: ArrivalProcess::Uniform { gap_s: 1e-6 },
                queue_capacity: 1,
                seed: 2,
                churn: None,
            },
        )
        .unwrap();
        assert!(gw.fallbacks > 0, "expected overflow fallbacks");
        assert!(report.dropped > 0, "expected load shedding");
        assert_eq!(
            report.metrics.requests + report.dropped,
            report.offered
        );
        // both pairs ended up serving traffic
        assert_eq!(report.metrics.per_pair.len(), 2);
    }

    #[test]
    fn churn_crash_loses_requests_under_drop_policy() {
        // mtbf far below the run length and mttr far above it: both
        // nodes die almost immediately and stay dead, so in-flight and
        // later-arriving requests are lost (drop policy) or shed once
        // the membership view catches up. Every request is accounted
        // exactly once.
        let e = engine();
        let ds = coco::build(40, 21);
        let mut gw = gateway(&e, "LE", 3);
        let report = run_dataset(
            &mut gw,
            &ds,
            &OpenLoopConfig {
                arrivals: ArrivalProcess::Poisson { rate_rps: 400.0 },
                queue_capacity: 8,
                seed: 9,
                churn: Some(ChurnConfig {
                    mtbf_s: 0.02,
                    mttr_s: 100.0,
                    probe_interval_s: 0.1,
                    probe_timeout_s: 0.05,
                    suspect_after: 1,
                    policy: ResiliencePolicy::Drop,
                    horizon_slack_s: 1.0,
                    ..Default::default()
                }),
            },
        )
        .unwrap();
        let churn = report.churn.as_ref().expect("churn report");
        assert!(churn.crashes > 0, "no crashes fired");
        assert!(churn.lost > 0, "drop policy must lose in-flight work");
        assert_eq!(churn.retried, 0);
        assert_eq!(churn.hedged, 0);
        assert_eq!(
            report.metrics.requests + report.dropped + churn.lost,
            report.offered,
            "every request must be served, shed, or lost"
        );
        // all slots were released despite the crashes
        assert_eq!(gw.pool().total_in_flight(), 0);
    }

    #[test]
    fn retry_recovers_goodput_under_churn() {
        // acceptance shape: 20% steady-state unavailability
        // (mtbf/mttr = 3.2/0.8), greedy router, retry policy — goodput
        // must stay within 90% of the no-churn run. Rate is far below
        // capacity so recovery is limited only by detection + backoff.
        let e = engine();
        let ds = coco::build(80, 31);
        let open_cfg = |churn| OpenLoopConfig {
            arrivals: ArrivalProcess::Uniform { gap_s: 0.125 },
            queue_capacity: 8,
            seed: 13,
            churn,
        };
        let mut base_gw = gateway(&e, "Orc", 3);
        let base = run_dataset(&mut base_gw, &ds, &open_cfg(None)).unwrap();

        let mut gw = gateway(&e, "Orc", 3);
        let report = run_dataset(
            &mut gw,
            &ds,
            &open_cfg(Some(ChurnConfig {
                mtbf_s: 3.2,
                mttr_s: 0.8,
                probe_interval_s: 0.1,
                probe_timeout_s: 0.05,
                suspect_after: 1,
                warmup_s: 0.3,
                warmup_penalty: 0.5,
                policy: ResiliencePolicy::Retry { budget: 8 },
                retry_backoff_s: 0.2,
                horizon_slack_s: 5.0,
                seed: 11,
            })),
        )
        .unwrap();
        let churn = report.churn.as_ref().expect("churn report");
        assert!(churn.crashes > 0, "churn never fired");
        assert_eq!(
            report.metrics.requests + report.dropped + churn.lost,
            report.offered
        );
        assert!(
            report.goodput_rps() >= 0.9 * base.goodput_rps(),
            "retry recovered only {:.2} of {:.2} req/s (lost {}, dropped {}, retried {})",
            report.goodput_rps(),
            base.goodput_rps(),
            churn.lost,
            report.dropped,
            churn.retried
        );
        // recovery latency is observable once a node came back
        assert!(churn.mean_time_to_recover_s >= 0.0);
        assert_eq!(gw.pool().total_in_flight(), 0);
    }

    #[test]
    fn hedge_duplicates_requests_and_accounts_waste() {
        // no crashes (infinite mtbf): hedging still duplicates every
        // request onto the second-best pair, so the losing copy's
        // service shows up as wasted energy, never as a served request.
        let e = engine();
        let ds = coco::build(20, 17);
        let mut gw = gateway(&e, "LE", 3);
        let report = run_dataset(
            &mut gw,
            &ds,
            &OpenLoopConfig {
                arrivals: ArrivalProcess::Poisson { rate_rps: 20.0 },
                queue_capacity: 8,
                seed: 7,
                churn: Some(ChurnConfig {
                    mtbf_s: f64::INFINITY,
                    policy: ResiliencePolicy::Hedge,
                    horizon_slack_s: 1.0,
                    ..Default::default()
                }),
            },
        )
        .unwrap();
        let churn = report.churn.as_ref().expect("churn report");
        assert_eq!(
            churn.hedged, report.offered,
            "with both pairs free every request should hedge"
        );
        assert!(churn.hedge_wins <= churn.hedged);
        assert!(report.peak_in_flight >= 2, "copies must overlap");
        assert!(
            churn.wasted_energy_mwh > 0.0,
            "losing copies must be accounted as waste"
        );
        assert_eq!(churn.crashes, 0);
        assert_eq!(churn.lost, 0);
        // each request served exactly once despite two copies
        assert_eq!(report.metrics.requests, report.offered);
        assert_eq!(gw.pool().total_in_flight(), 0);
    }

    #[test]
    fn hedge_under_crashes_accounts_each_request_once() {
        // regression: a primary lost synchronously at dispatch (stale
        // membership view) must see its hedge as a live sibling —
        // both copies register before either is admitted — not declare
        // the request lost while the duplicate goes on to serve it.
        let e = engine();
        let ds = coco::build(32, 63);
        let mut gw = gateway(&e, "LE", 3);
        let report = run_dataset(
            &mut gw,
            &ds,
            &OpenLoopConfig {
                arrivals: ArrivalProcess::Poisson { rate_rps: 200.0 },
                queue_capacity: 4,
                seed: 3,
                churn: Some(ChurnConfig {
                    mtbf_s: 0.1,
                    mttr_s: 0.15,
                    probe_interval_s: 0.04,
                    probe_timeout_s: 0.02,
                    suspect_after: 1,
                    policy: ResiliencePolicy::Hedge,
                    horizon_slack_s: 1.0,
                    ..Default::default()
                }),
            },
        )
        .unwrap();
        let churn = report.churn.as_ref().expect("churn report");
        assert!(churn.crashes > 0, "churn never fired");
        assert!(churn.hedged > 0, "no hedges dispatched");
        assert_eq!(
            report.metrics.requests + report.dropped + churn.lost,
            report.offered,
            "hedged requests must be counted exactly once \
             (served {} dropped {} lost {})",
            report.metrics.requests,
            report.dropped,
            churn.lost
        );
        assert_eq!(gw.pool().total_in_flight(), 0);
    }

    #[test]
    fn churn_runs_replay_bit_identically() {
        // seed sensitivity of the failure timeline itself is pinned in
        // lifecycle::tests; here the whole serialized run must replay
        // byte for byte (heap order, losses, retries, probe effects).
        let e = engine();
        let ds = coco::build(24, 51);
        let run = |churn_seed: u64| {
            let mut gw = gateway(&e, "ED", 3);
            run_dataset(
                &mut gw,
                &ds,
                &OpenLoopConfig {
                    arrivals: ArrivalProcess::Poisson { rate_rps: 120.0 },
                    queue_capacity: 4,
                    seed: 19,
                    churn: Some(ChurnConfig {
                        mtbf_s: 0.2,
                        mttr_s: 0.3,
                        probe_interval_s: 0.05,
                        probe_timeout_s: 0.02,
                        suspect_after: 1,
                        policy: ResiliencePolicy::Retry { budget: 3 },
                        retry_backoff_s: 0.05,
                        horizon_slack_s: 2.0,
                        seed: churn_seed,
                        ..Default::default()
                    }),
                },
            )
            .unwrap()
            .to_json()
            .dump()
        };
        assert_eq!(run(5), run(5));
    }

    #[test]
    fn open_loop_replays_bit_identically_from_seeds() {
        let e = engine();
        let ds = coco::build(15, 99);
        let run = |e: &Engine| {
            let mut gw = gateway(e, "ED", 3);
            run_dataset(
                &mut gw,
                &ds,
                &OpenLoopConfig {
                    arrivals: ArrivalProcess::Poisson { rate_rps: 40.0 },
                    queue_capacity: 4,
                    seed: 17,
                    churn: None,
                },
            )
            .unwrap()
        };
        let a = run(&e);
        let b = run(&e);
        assert_eq!(a.metrics.requests, b.metrics.requests);
        assert_eq!(a.dropped, b.dropped);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.metrics.total_latency_s, b.metrics.total_latency_s);
        assert_eq!(a.metrics.queue_delay_s, b.metrics.queue_delay_s);
        assert_eq!(
            a.metrics.latency_samples,
            b.metrics.latency_samples
        );
    }
}
