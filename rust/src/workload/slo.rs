//! SLO vocabulary for the open-loop and fleet drivers (DESIGN.md §11):
//! deadline classes, the batching/admission configuration, and the
//! per-copy tag the node FIFOs order by.
//!
//! Everything here is *configuration-shaped*: the actual admission
//! predicate, batch formation, and EDF ordering live in the drivers
//! (`workload::openloop`, `fleet`), and a `None` SLO config keeps both
//! drivers' event streams bit-identical to the pre-SLO behavior.

use anyhow::{Context, Result};

/// One deadline class: requests of this class must complete within
/// `deadline_s` of their arrival on the virtual clock.
#[derive(Clone, Debug)]
pub struct SloClass {
    pub name: String,
    /// Relative deadline (s); `arrival + deadline_s` is the absolute
    /// budget the attainment accounting compares completions against.
    pub deadline_s: f64,
}

/// Configuration of the SLO/batching subsystem.
#[derive(Clone, Debug)]
pub struct SloConfig {
    /// Deadline classes; requests are assigned round-robin by index
    /// ([`SloConfig::class_of`]), deterministically.
    pub classes: Vec<SloClass>,
    /// Batch formation window (s): arrivals routed to the same
    /// `(model, device)` pair within this window dispatch as one
    /// amortized service train. 0 disables batch formation — SLO
    /// admission control and EDF ordering still apply.
    pub batch_window_s: f64,
    /// Hard cap on members per batch (a full batch flushes early).
    pub max_batch: usize,
}

impl Default for SloConfig {
    fn default() -> Self {
        Self {
            classes: vec![
                SloClass {
                    name: "interactive".to_string(),
                    deadline_s: 0.05,
                },
                SloClass {
                    name: "standard".to_string(),
                    deadline_s: 0.25,
                },
                SloClass { name: "relaxed".to_string(), deadline_s: 1.0 },
            ],
            batch_window_s: 0.004,
            max_batch: 4,
        }
    }
}

impl SloConfig {
    /// Deterministic class assignment: request `idx` cycles through the
    /// configured classes (the same request index always lands in the
    /// same class, so runs replay bit-identically).
    pub fn class_of(&self, idx: usize) -> usize {
        idx % self.classes.len().max(1)
    }

    /// Absolute deadline for request `idx` arriving at `arrival_s`;
    /// infinite when no classes are configured.
    pub fn deadline_for(&self, idx: usize, arrival_s: f64) -> f64 {
        match self.classes.get(self.class_of(idx)) {
            Some(c) => arrival_s + c.deadline_s,
            None => f64::INFINITY,
        }
    }

    /// Class names in index order (the metrics layer's label vector).
    pub fn class_names(&self) -> Vec<String> {
        self.classes.iter().map(|c| c.name.clone()).collect()
    }

    /// Parse `name:deadline_s` class specs (config/CLI edge).
    pub fn parse_classes(specs: &[String]) -> Result<Vec<SloClass>> {
        specs
            .iter()
            .map(|s| {
                let (name, d) = s.split_once(':').with_context(|| {
                    format!(
                        "slo class '{s}' must be 'name:deadline_s'"
                    )
                })?;
                let deadline_s: f64 =
                    d.trim().parse().with_context(|| {
                        format!("slo class '{s}': bad deadline '{d}'")
                    })?;
                anyhow::ensure!(
                    deadline_s > 0.0,
                    "slo class '{s}': deadline must be positive"
                );
                Ok(SloClass {
                    name: name.trim().to_string(),
                    deadline_s,
                })
            })
            .collect()
    }
}

/// The SLO half of one queued request copy, carried through the node
/// FIFOs. The default tag is inert: an infinite deadline (never misses,
/// never reorders — EDF with all-infinite keys IS arrival-order FIFO),
/// no amortization, and the full network charge, so `None`-config runs
/// behave bit-identically to the pre-SLO driver.
#[derive(Clone, Copy, Debug)]
pub struct SloTag {
    /// Deadline class index (0 when SLOs are off).
    pub class: usize,
    /// Absolute deadline on the virtual clock (attainment accounting).
    pub deadline_s: f64,
    /// EDF ordering key: the copy's own deadline, or — for batch
    /// members — the batch's tightest deadline, so a flushed batch
    /// stays contiguous in the FIFO instead of interleaving.
    pub edf_s: f64,
    /// Batch follower: amortize the preprocess share of service.
    pub amortized: bool,
    /// This copy pays the network hop (batch leader or unbatched;
    /// followers ride the leader's transfer).
    pub net: bool,
}

impl Default for SloTag {
    fn default() -> Self {
        Self {
            class: 0,
            deadline_s: f64::INFINITY,
            edf_s: f64::INFINITY,
            amortized: false,
            net: true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_classes_and_round_robin() {
        let c = SloConfig::default();
        assert_eq!(c.classes.len(), 3);
        assert_eq!(c.class_of(0), 0);
        assert_eq!(c.class_of(4), 1);
        assert_eq!(c.class_of(5), 2);
        assert_eq!(
            c.class_names(),
            vec!["interactive", "standard", "relaxed"]
        );
        let d = c.deadline_for(1, 10.0);
        assert!((d - 10.25).abs() < 1e-12);
    }

    #[test]
    fn parse_classes_accepts_specs_and_rejects_garbage() {
        let good = SloConfig::parse_classes(&[
            "fast: 0.02".to_string(),
            "slow:1.5".to_string(),
        ])
        .unwrap();
        assert_eq!(good.len(), 2);
        assert_eq!(good[0].name, "fast");
        assert!((good[0].deadline_s - 0.02).abs() < 1e-12);
        assert!((good[1].deadline_s - 1.5).abs() < 1e-12);
        assert!(SloConfig::parse_classes(&["nocolon".into()]).is_err());
        assert!(SloConfig::parse_classes(&["x:abc".into()]).is_err());
        assert!(SloConfig::parse_classes(&["x:-1".into()]).is_err());
        assert!(SloConfig::parse_classes(&["x:0".into()]).is_err());
    }

    #[test]
    fn default_tag_is_inert() {
        let t = SloTag::default();
        assert!(t.deadline_s.is_infinite());
        assert!(t.edf_s.is_infinite());
        assert!(!t.amortized);
        assert!(t.net);
    }

    #[test]
    fn empty_class_list_never_panics() {
        let c = SloConfig {
            classes: Vec::new(),
            ..SloConfig::default()
        };
        assert_eq!(c.class_of(17), 17); // modulo max(1)
        assert!(c.deadline_for(17, 1.0).is_infinite());
        assert!(c.class_names().is_empty());
    }
}
