//! Workload drivers.
//!
//! The functions in this module implement the *closed-loop* protocol —
//! the paper's Locust substitute (§4.2): requests are sent
//! "back-to-back in a piggybacked fashion", each fired only after the
//! previous response arrives, so total latency is the sum of
//! per-request service times on a virtual clock.
//!
//! [`openloop`] is the concurrent-serving counterpart: a discrete-event
//! simulator firing Poisson/paced/trace arrivals at a configurable rate
//! with bounded per-node FIFO queues (DESIGN.md §6).

pub mod openloop;
pub mod slo;

use anyhow::Result;

use crate::dataset::{Dataset, Scene};
use crate::gateway::Gateway;
use crate::metrics::RunMetrics;

/// Drive a gateway over a (lazily rendered) dataset.
pub fn run_dataset(
    gw: &mut Gateway<'_>,
    dataset: &Dataset,
) -> Result<RunMetrics> {
    let mut m = RunMetrics::new(gw.spec.name);
    for scene in dataset.iter_scenes() {
        gw.handle(&scene.image, scene.gt.len(), &scene.gt, &mut m)?;
    }
    Ok(m)
}

/// Drive a gateway over pre-rendered frames with *pseudo* ground truth
/// (the video protocol: labels come from the biggest model, §4.1.1).
pub fn run_frames(
    gw: &mut Gateway<'_>,
    frames: &[Scene],
    pseudo_gt: &[Vec<crate::dataset::GtBox>],
) -> Result<RunMetrics> {
    anyhow::ensure!(frames.len() == pseudo_gt.len());
    let mut m = RunMetrics::new(gw.spec.name);
    for (scene, gt) in frames.iter().zip(pseudo_gt.iter()) {
        gw.handle(&scene.image, gt.len(), gt, &mut m)?;
    }
    Ok(m)
}

/// Generate pseudo ground truth for frames by running the reference
/// model (yolov8x) — mirrors the paper's annotation protocol.
pub fn pseudo_annotate(
    engine: &crate::runtime::Engine,
    frames: &[Scene],
) -> Result<Vec<Vec<crate::dataset::GtBox>>> {
    use crate::dataset::GtBox;
    let meta = engine.meta(crate::models::GT_MODEL)?;
    let mut out = Vec::with_capacity(frames.len());
    for f in frames {
        let heat = engine.infer(crate::models::GT_MODEL, &f.image)?;
        let dets = crate::detection::decode_heatmap(&heat, &meta, 1.0);
        out.push(
            dets.into_iter()
                .map(|d| GtBox {
                    x0: d.bbox.x0,
                    y0: d.bbox.y0,
                    x1: d.bbox.x1,
                    y1: d.bbox.y1,
                    cls: d.cls,
                })
                .collect(),
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{coco, video};
    use crate::devices::fleet;
    use crate::gateway::router_by_name;
    use crate::nodes::NodePool;
    use crate::router::{PairKey, PairProfile, ProfileStore};
    use crate::runtime::Engine;

    fn engine() -> Engine {
        Engine::new(&crate::default_artifacts_dir()).unwrap()
    }

    fn store() -> ProfileStore {
        let mut rows = Vec::new();
        for g in 0..5 {
            rows.push(PairProfile {
                pair: PairKey::new("ssd_v1", "jetson_orin_nano"),
                group: g,
                map: 50.0,
                latency_s: 0.005,
                energy_mwh: 0.002,
            });
            rows.push(PairProfile {
                pair: PairKey::new("yolov8n", "pi5"),
                group: g,
                map: if g >= 2 { 75.0 } else { 51.0 },
                latency_s: 0.05,
                energy_mwh: 0.05,
            });
        }
        ProfileStore::new(rows)
    }

    #[test]
    fn closed_loop_latency_is_sum_of_requests() {
        let e = engine();
        let s = store();
        let pool = NodePool::deploy(&e, &s.pairs(), &fleet(), 3).unwrap();
        let mut gw = Gateway::new(
            &e,
            router_by_name("LE").unwrap(),
            s,
            pool,
            5.0,
            3,
        );
        let ds = coco::build(5, 77);
        let m = run_dataset(&mut gw, &ds).unwrap();
        assert_eq!(m.requests, 5);
        // LE always routes to the jetson pair: closed-loop total latency
        // = 5 x (device service time +- 3% jitter + network)
        let jetson = crate::devices::find(&fleet(), "jetson_orin_nano")
            .unwrap();
        let meta = e.meta("ssd_v1").unwrap();
        let per_req = jetson.profile(&meta).latency_s;
        let expect = 5.0 * (per_req + crate::devices::NETWORK_S);
        assert!(
            (m.total_latency_s - expect).abs() < 5.0 * per_req * 0.04,
            "latency {} vs expect {expect}",
            m.total_latency_s
        );
    }

    #[test]
    fn video_pseudo_annotation_close_to_truth() {
        let e = engine();
        let frames = video::build_frames(6, 4);
        let gts = pseudo_annotate(&e, &frames).unwrap();
        assert_eq!(gts.len(), 6);
        // pseudo labels should track true counts closely on these
        // well-separated pedestrian scenes
        let mut total_err = 0usize;
        for (f, gt) in frames.iter().zip(gts.iter()) {
            total_err += f.gt.len().abs_diff(gt.len());
        }
        assert!(
            total_err <= frames.len(),
            "pseudo-GT count error too large: {total_err}"
        );
    }
}
