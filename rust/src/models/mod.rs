//! Model registry: typed view of the AOT artifact manifest.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.json` describing
//! every compiled HLO artifact (shapes, decode parameters, FLOP counts).
//! This module parses it into a `ModelRegistry`, the single source of
//! truth the runtime, profiler, router, and device simulator all share.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

/// The eight routable backend models, in capacity order. `yolov8x` exists
/// in the manifest as the video pseudo-ground-truth generator but is not a
/// routing target (paper §4.1.1).
pub const BACKEND_MODELS: [&str; 8] = [
    "ssd_v1",
    "ssd_lite",
    "effdet_lite0",
    "effdet_lite1",
    "effdet_lite2",
    "yolov8n",
    "yolov8s",
    "yolov8m",
];

/// Pseudo-ground-truth model for the video dataset.
pub const GT_MODEL: &str = "yolov8x";
/// The SSD-based front-end estimator model (runs on the gateway).
pub const FRONTEND_MODEL: &str = "ssd_front";
/// The Canny edge-map artifact (runs on the gateway).
pub const CANNY_MODEL: &str = "canny";

#[derive(Clone, Debug, PartialEq)]
pub enum ModelKind {
    Detector,
    GatewayDetector,
    Canny,
}

/// Metadata for one compiled artifact.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    pub kind: ModelKind,
    pub file: PathBuf,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    pub flops: f64,
    /// Detector decode parameters (empty for canny).
    pub res: usize,
    pub factor: usize,
    pub k: usize,
    pub sigmas: Vec<f64>,
    pub band_radii_native: Vec<f64>,
    pub threshold: f64,
    /// Canny-specific double thresholds.
    pub canny_lo: f64,
    pub canny_hi: f64,
}

impl ModelMeta {
    pub fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    pub fn output_len(&self) -> usize {
        self.output_shape.iter().product()
    }
}

/// Registry of every artifact in a manifest.
#[derive(Clone, Debug)]
pub struct ModelRegistry {
    pub native_res: usize,
    pub version: usize,
    models: BTreeMap<String, ModelMeta>,
    pub artifacts_dir: PathBuf,
}

impl ModelRegistry {
    /// Load `<artifacts_dir>/manifest.json`.
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_json(&text, artifacts_dir)
    }

    pub fn from_json(text: &str, artifacts_dir: &Path) -> Result<Self> {
        let root = json::parse(text).context("parsing manifest.json")?;
        let version = root.req("version")?.as_usize().context("version")?;
        let native_res =
            root.req("native_res")?.as_usize().context("native_res")?;
        let mut models = BTreeMap::new();
        let model_objs = root
            .req("models")?
            .as_obj()
            .context("models must be an object")?;
        for (name, entry) in model_objs {
            models.insert(
                name.clone(),
                parse_model(name, entry, artifacts_dir)?,
            );
        }
        let reg = Self {
            native_res,
            version,
            models,
            artifacts_dir: artifacts_dir.to_path_buf(),
        };
        reg.validate()?;
        Ok(reg)
    }

    fn validate(&self) -> Result<()> {
        for name in BACKEND_MODELS {
            if !self.models.contains_key(name) {
                bail!("manifest missing backend model '{name}'");
            }
        }
        for name in [GT_MODEL, FRONTEND_MODEL, CANNY_MODEL] {
            if !self.models.contains_key(name) {
                bail!("manifest missing model '{name}'");
            }
        }
        for m in self.models.values() {
            if m.kind != ModelKind::Canny {
                if m.band_radii_native.len() != m.k {
                    bail!("{}: band radii/k mismatch", m.name);
                }
                if m.sigmas.len() != m.k + 1 {
                    bail!("{}: sigma ladder length mismatch", m.name);
                }
                if m.output_shape != vec![2, m.k, m.res, m.res] {
                    bail!("{}: unexpected output shape", m.name);
                }
            }
            if m.input_shape != vec![self.native_res, self.native_res] {
                bail!("{}: unexpected input shape", m.name);
            }
            if m.flops <= 0.0 {
                bail!("{}: non-positive flops", m.name);
            }
        }
        Ok(())
    }

    pub fn get(&self, name: &str) -> Result<&ModelMeta> {
        self.models
            .get(name)
            .with_context(|| format!("unknown model '{name}'"))
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.models.keys().map(|s| s.as_str())
    }

    pub fn backend_models(&self) -> Vec<&ModelMeta> {
        BACKEND_MODELS
            .iter()
            .map(|n| self.models.get(*n).expect("validated"))
            .collect()
    }
}

fn parse_model(name: &str, entry: &Json, dir: &Path) -> Result<ModelMeta> {
    let kind = match entry.req("kind")?.as_str() {
        Some("detector") => ModelKind::Detector,
        Some("gateway_detector") => ModelKind::GatewayDetector,
        Some("canny") => ModelKind::Canny,
        other => bail!("{name}: unknown kind {other:?}"),
    };
    let file = dir.join(
        entry
            .req("file")?
            .as_str()
            .context("file must be a string")?,
    );
    let shape_of = |j: &Json, key: &str| -> Result<Vec<usize>> {
        Ok(j.req(key)?
            .req("shape")?
            .f64s()
            .context("shape")?
            .into_iter()
            .map(|x| x as usize)
            .collect())
    };
    let params = entry.req("params")?;
    let getf = |key: &str| -> f64 {
        params.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0)
    };
    Ok(ModelMeta {
        name: name.to_string(),
        kind,
        file,
        input_shape: shape_of(entry, "input")?,
        output_shape: shape_of(entry, "output")?,
        flops: entry.req("flops")?.as_f64().context("flops")?,
        res: getf("res") as usize,
        factor: getf("factor") as usize,
        k: getf("k") as usize,
        sigmas: params.get("sigmas").and_then(|v| v.f64s()).unwrap_or_default(),
        band_radii_native: params
            .get("band_radii_native")
            .and_then(|v| v.f64s())
            .unwrap_or_default(),
        threshold: getf("threshold"),
        canny_lo: getf("lo"),
        canny_hi: getf("hi"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest() {
        let reg = ModelRegistry::load(&artifacts_dir()).unwrap();
        assert_eq!(reg.native_res, 384);
        assert_eq!(reg.backend_models().len(), 8);
        let ssd = reg.get("ssd_v1").unwrap();
        assert_eq!(ssd.res, 96);
        assert_eq!(ssd.factor, 4);
        assert_eq!(ssd.k, 3);
        assert!(ssd.threshold > 0.0);
        let canny = reg.get(CANNY_MODEL).unwrap();
        assert_eq!(canny.kind, ModelKind::Canny);
        assert!(canny.canny_lo < canny.canny_hi);
    }

    #[test]
    fn backend_models_flops_monotone() {
        let reg = ModelRegistry::load(&artifacts_dir()).unwrap();
        let flops: Vec<f64> =
            reg.backend_models().iter().map(|m| m.flops).collect();
        for w in flops.windows(2) {
            assert!(w[1] > w[0], "flops not monotone: {flops:?}");
        }
    }

    #[test]
    fn unknown_model_is_error() {
        let reg = ModelRegistry::load(&artifacts_dir()).unwrap();
        assert!(reg.get("resnet50").is_err());
    }

    #[test]
    fn rejects_incomplete_manifest() {
        let r = ModelRegistry::from_json(
            r#"{"version": 2, "native_res": 384, "models": {}}"#,
            Path::new("/tmp"),
        );
        assert!(r.is_err());
    }
}
