//! Pedestrian-crossing video twin (paper §4.1.1): a frame sequence with
//! temporally persistent object tracks. Object count follows a bounded
//! birth/death process with high persistence, and objects drift with
//! near-constant velocity — the temporal-continuity structure the
//! output-based (OB) estimator exploits.
//!
//! As in the paper, serving experiments generate *pseudo* ground truth by
//! running the largest model (yolov8x) over each frame; the generator
//! also keeps exact ground truth for diagnostics.

use super::scene::{self, PlacedObject};
use super::{Dataset, Scene, SceneSpec, NATIVE_RES};
use crate::util::rng::Rng;

/// Per-frame probability that a new pedestrian enters the scene.
const BIRTH_PROB: f64 = 0.06;
/// Per-frame probability that an existing pedestrian leaves.
const DEATH_PROB: f64 = 0.03;
/// Maximum simultaneous objects.
const MAX_OBJECTS: usize = 8;
/// Pedestrian radius range (native px).
const RADIUS_RANGE: (f64, f64) = (9.0, 18.0);
/// Speed range (px/frame).
const SPEED_RANGE: (f64, f64) = (1.0, 3.5);

#[derive(Clone, Debug)]
struct Track {
    obj: PlacedObject,
    vx: f64,
    vy: f64,
}

/// Stateful video stream generator.
pub struct VideoStream {
    rng: Rng,
    tracks: Vec<Track>,
    frame_idx: usize,
    n_frames: usize,
}

impl VideoStream {
    pub fn new(n_frames: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        // start with a small crossing group
        let mut s = Self {
            tracks: Vec::new(),
            frame_idx: 0,
            n_frames,
            rng: rng.derive(1),
        };
        let initial = 1 + rng.below(3) as usize;
        for _ in 0..initial {
            s.spawn();
        }
        s
    }

    fn spawn(&mut self) {
        if self.tracks.len() >= MAX_OBJECTS {
            return;
        }
        let r = self.rng.range(RADIUS_RANGE.0, RADIUS_RANGE.1);
        // pedestrians lean taller-than-wide, but stay within the aspect
        // range the detectors are profiled on (square-box decode —
        // DESIGN.md §3): stronger elongation would put every video frame
        // out of distribution for ALL models equally.
        let aspect = self.rng.range(0.75, 0.95);
        let speed = self.rng.range(SPEED_RANGE.0, SPEED_RANGE.1);
        // enter from left or right edge, walk across
        let from_left = self.rng.below(2) == 0;
        let margin = r + 6.0;
        let cx = if from_left {
            margin
        } else {
            NATIVE_RES as f64 - margin
        };
        let cy = self
            .rng
            .range(margin + 40.0, NATIVE_RES as f64 - margin - 40.0);
        self.tracks.push(Track {
            obj: PlacedObject {
                cx,
                cy,
                rx: r * aspect,
                ry: r / aspect,
                cls: self.rng.below(2) as usize,
                contrast: self.rng.range(0.25, 0.6),
                theta: 0.0,
            },
            vx: if from_left { speed } else { -speed },
            vy: self.rng.range(-0.3, 0.3),
        });
    }

    fn step(&mut self) {
        // births/deaths
        if self.rng.f64() < BIRTH_PROB {
            self.spawn();
        }
        if !self.tracks.is_empty() && self.rng.f64() < DEATH_PROB {
            let i = self.rng.below(self.tracks.len() as u64) as usize;
            self.tracks.remove(i);
        }
        // motion + leave-frame cleanup
        let n = NATIVE_RES as f64;
        for t in self.tracks.iter_mut() {
            t.obj.cx += t.vx;
            t.obj.cy += t.vy;
        }
        self.tracks.retain(|t| {
            let m = t.obj.rx.max(t.obj.ry) + 2.0;
            t.obj.cx > m && t.obj.cx < n - m && t.obj.cy > m && t.obj.cy < n - m
        });
    }

    pub fn current_count(&self) -> usize {
        self.tracks.len()
    }
}

impl Iterator for VideoStream {
    type Item = Scene;

    fn next(&mut self) -> Option<Scene> {
        if self.frame_idx >= self.n_frames {
            return None;
        }
        let objs: Vec<PlacedObject> =
            self.tracks.iter().map(|t| t.obj).collect();
        let mut frame_rng = self.rng.derive(0xF00D + self.frame_idx as u64);
        let scene =
            scene::render_objects(self.frame_idx, &objs, &mut frame_rng);
        self.frame_idx += 1;
        self.step();
        Some(scene)
    }
}

/// Materialize a video as a [`Dataset`]-like list of frames.
///
/// Frames can't be re-rendered from compact specs (track state is
/// sequential), so the video path returns rendered scenes directly.
pub fn build_frames(n_frames: usize, seed: u64) -> Vec<Scene> {
    VideoStream::new(n_frames, seed).collect()
}

/// A dataset facade for experiments that only need (id, count) specs,
/// e.g. the Oracle estimator. Rendering is NOT supported through this.
pub fn spec_view(frames: &[Scene]) -> Dataset {
    Dataset {
        name: "video".into(),
        specs: frames
            .iter()
            .map(|f| SceneSpec {
                id: f.id,
                seed: 0,
                n_objects: f.gt.len(),
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn video_is_deterministic() {
        let a = build_frames(30, 5);
        let b = build_frames(30, 5);
        assert_eq!(a.len(), 30);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.image, y.image);
            assert_eq!(x.gt, y.gt);
        }
    }

    #[test]
    fn counts_change_gradually() {
        let frames = build_frames(200, 11);
        let counts: Vec<usize> =
            frames.iter().map(|f| f.gt.len()).collect();
        // temporal continuity: successive frame counts differ by <= 1
        for w in counts.windows(2) {
            assert!(
                w[0].abs_diff(w[1]) <= 1,
                "count jump {} -> {}",
                w[0],
                w[1]
            );
        }
        // and the stream is not static: some change happens
        assert!(counts.windows(2).any(|w| w[0] != w[1]));
    }

    #[test]
    fn objects_move_between_frames() {
        let frames = build_frames(10, 3);
        // find a frame pair with the same count and check centers moved
        let mut moved = false;
        for w in frames.windows(2) {
            if w[0].gt.len() == w[1].gt.len() && !w[0].gt.is_empty() {
                let a = &w[0].gt[0];
                let b = &w[1].gt[0];
                if (a.x0 - b.x0).abs() > 0.5 {
                    moved = true;
                }
            }
        }
        assert!(moved, "no track motion observed");
    }

    #[test]
    fn spec_view_matches_counts() {
        let frames = build_frames(20, 9);
        let d = spec_view(&frames);
        for (f, s) in frames.iter().zip(d.specs.iter()) {
            assert_eq!(f.gt.len(), s.n_objects);
        }
    }
}
