//! The balanced *sorted* dataset (paper §4.1.1): five object-count groups
//! ('0', '1', '2', '3', '4 or more'), 200 images each, sent to the
//! gateway **ordered by group** — the workload shape that favours the
//! output-based (OB) estimator.

use super::{Dataset, SceneSpec};
use crate::util::rng::Rng;

/// Representative object counts per group. Group 5 ("4 or more") draws
/// counts uniformly from 4..=9 like the paper's bucket.
pub const GROUP_COUNTS: [usize; 5] = [0, 1, 2, 3, 4];

/// Build the balanced sorted dataset: `per_group` images per group,
/// ordered group 0 first.
pub fn build(per_group: usize, seed: u64) -> Dataset {
    let base = Rng::new(seed);
    let mut specs = Vec::with_capacity(5 * per_group);
    let mut id = 0usize;
    for (gi, &count) in GROUP_COUNTS.iter().enumerate() {
        for j in 0..per_group {
            let mut r = base.derive((gi * 1_000_003 + j) as u64);
            let n_objects = if gi == 4 {
                4 + r.below(6) as usize // 4..=9
            } else {
                count
            };
            specs.push(SceneSpec {
                id,
                seed: r.next_u64(),
                n_objects,
            });
            id += 1;
        }
    }
    Dataset {
        name: format!("balanced_sorted_{}x{per_group}", GROUP_COUNTS.len()),
        specs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_groups_sorted_and_sized() {
        let d = build(200, 3);
        assert_eq!(d.len(), 1000);
        for (i, s) in d.specs.iter().enumerate() {
            let group = i / 200;
            if group < 4 {
                assert_eq!(s.n_objects, group, "index {i}");
            } else {
                assert!((4..=9).contains(&s.n_objects), "index {i}");
            }
        }
    }

    #[test]
    fn sorted_by_group_nondecreasing_bucket() {
        let d = build(50, 9);
        let bucket =
            |n: usize| -> usize { n.min(4) };
        let buckets: Vec<usize> =
            d.specs.iter().map(|s| bucket(s.n_objects)).collect();
        for w in buckets.windows(2) {
            assert!(w[1] >= w[0]);
        }
    }

    #[test]
    fn deterministic() {
        assert_eq!(build(10, 5).specs, build(10, 5).specs);
        assert_ne!(build(10, 5).specs, build(10, 6).specs);
    }
}
