//! Procedural scene generator — production twin of
//! `python/compile/scenegen.py` (statistically equivalent object model;
//! see DESIGN.md §3 for why this preserves the paper's phenomena).
//!
//! Scenes are grayscale [`NATIVE_RES`]² images: a smooth sinusoidal
//! background plus white noise, with N rotated anisotropic Gaussian bumps
//! (bright = class 0, dark = class 1). The crowding law shrinks object
//! radii as N grows, which is what makes low-capacity detectors lose
//! accuracy on crowded scenes (paper Fig. 2).

use super::{GtBox, Scene, SceneSpec, NATIVE_RES};
use crate::util::rng::Rng;

pub const NOISE_STD: f64 = 0.02;
pub const BG_WAVE_AMP: f64 = 0.02;
pub const CONTRAST_LO: f64 = 0.20;
pub const CONTRAST_HI: f64 = 0.60;
const MAX_PLACE_TRIES: usize = 40;
const PLACEMENT_SLACK: f64 = 4.0;

/// One placed (not yet rendered) object.
#[derive(Clone, Copy, Debug)]
pub struct PlacedObject {
    pub cx: f64,
    pub cy: f64,
    pub rx: f64,
    pub ry: f64,
    pub cls: usize,
    pub contrast: f64,
    pub theta: f64,
}

impl PlacedObject {
    pub fn gt(&self) -> GtBox {
        GtBox {
            x0: self.cx - self.rx,
            y0: self.cy - self.ry,
            x1: self.cx + self.rx,
            y1: self.cy + self.ry,
            cls: self.cls,
        }
    }
}

/// Radius law: more objects -> smaller objects (crowding). Mirrors
/// `scenegen.radius_range`.
pub fn radius_range(n: usize) -> (f64, f64) {
    if n <= 1 {
        return (16.0, 32.0);
    }
    let hi = (32.0 / (1.0 + 0.35 * (n as f64 - 1.0))).max(8.0);
    ((hi / 2.5).max(5.0), hi)
}

fn boxes_overlap(a: &GtBox, b: &GtBox, slack: f64) -> bool {
    !(a.x1 + slack < b.x0
        || b.x1 + slack < a.x0
        || a.y1 + slack < b.y0
        || b.y1 + slack < a.y0)
}

/// Rejection-sample non-overlapping object placements. Objects that fail
/// placement after `MAX_PLACE_TRIES` are dropped (ground truth reflects
/// what is actually rendered).
pub fn place_objects(n: usize, rng: &mut Rng) -> Vec<PlacedObject> {
    let (lo, hi) = radius_range(n);
    let mut objs: Vec<PlacedObject> = Vec::with_capacity(n);
    for _ in 0..n {
        for _try in 0..MAX_PLACE_TRIES {
            let r = rng.range(lo, hi);
            let aspect = rng.range(0.75, 1.33);
            let (rx, ry) = (r * aspect, r / aspect);
            let margin = rx.max(ry) + 4.0;
            let span = NATIVE_RES as f64 - 2.0 * margin;
            if span <= 0.0 {
                break;
            }
            let cx = margin + rng.f64() * span;
            let cy = margin + rng.f64() * span;
            let cand = PlacedObject {
                cx,
                cy,
                rx,
                ry,
                cls: rng.below(2) as usize,
                contrast: rng.range(CONTRAST_LO, CONTRAST_HI),
                theta: rng.range(0.0, std::f64::consts::PI),
            };
            let cand_gt = cand.gt();
            if objs
                .iter()
                .all(|o| !boxes_overlap(&o.gt(), &cand_gt, PLACEMENT_SLACK))
            {
                objs.push(cand);
                break;
            }
        }
    }
    objs
}

/// Render placed objects into an image (with background + noise).
pub fn render(objs: &[PlacedObject], rng: &mut Rng) -> Vec<f32> {
    let n = NATIVE_RES;
    let mut img = vec![0.0f32; n * n];

    // smooth sinusoidal background. The wave argument is linear in x, so
    // each row is generated with the angle-addition recurrence
    // sin(a+d) = sin a cos d + cos a sin d — one sin/cos pair per ROW
    // instead of one sin per PIXEL (EXPERIMENTS.md §Perf).
    let fx = rng.range(0.5, 2.0);
    let fy = rng.range(0.5, 2.0);
    let ph = rng.range(0.0, 2.0 * std::f64::consts::PI);
    let two_pi = 2.0 * std::f64::consts::PI;
    let dx = two_pi * fx / n as f64;
    let (sin_dx, cos_dx) = dx.sin_cos();
    for y in 0..n {
        let a0 = two_pi * fy * y as f64 / n as f64 + ph;
        let (mut s, mut c) = a0.sin_cos();
        let row = &mut img[y * n..(y + 1) * n];
        for v in row.iter_mut() {
            *v = (0.5 + BG_WAVE_AMP * s) as f32;
            let s2 = s * cos_dx + c * sin_dx;
            c = c * cos_dx - s * sin_dx;
            s = s2;
        }
    }

    // objects: evaluate each bump only inside its 4-sigma bounding window
    for o in objs {
        let (ct, st) = (o.theta.cos(), o.theta.sin());
        let (sx, sy) = (o.rx / 2.0, o.ry / 2.0);
        let ext = 4.0 * sx.max(sy);
        let x0 = ((o.cx - ext).floor().max(0.0)) as usize;
        let x1 = ((o.cx + ext).ceil().min(n as f64 - 1.0)) as usize;
        let y0 = ((o.cy - ext).floor().max(0.0)) as usize;
        let y1 = ((o.cy + ext).ceil().min(n as f64 - 1.0)) as usize;
        let sign = if o.cls == 0 { 1.0 } else { -1.0 };
        let amp = sign * o.contrast;
        for y in y0..=y1 {
            let dy = y as f64 - o.cy;
            for x in x0..=x1 {
                let dx = x as f64 - o.cx;
                let u = (ct * dx + st * dy) / sx;
                let v = (-st * dx + ct * dy) / sy;
                let e = (-0.5 * (u * u + v * v)).exp();
                img[y * n + x] += (amp * e) as f32;
            }
        }
    }

    // white noise + clamp (paired Box-Muller: half the ln/sqrt calls)
    let mut i = 0;
    while i + 1 < img.len() {
        let (n1, n2) = rng.normal_pair();
        img[i] = (img[i] + (NOISE_STD * n1) as f32).clamp(0.0, 1.0);
        img[i + 1] =
            (img[i + 1] + (NOISE_STD * n2) as f32).clamp(0.0, 1.0);
        i += 2;
    }
    if i < img.len() {
        img[i] =
            (img[i] + (NOISE_STD * rng.normal()) as f32).clamp(0.0, 1.0);
    }
    img
}

/// Render a full scene from its spec (deterministic).
pub fn render_spec(spec: &SceneSpec) -> Scene {
    let mut rng = Rng::new(spec.seed);
    let objs = place_objects(spec.n_objects, &mut rng);
    let image = render(&objs, &mut rng);
    Scene {
        id: spec.id,
        image,
        gt: objs.iter().map(|o| o.gt()).collect(),
    }
}

/// Render a scene from explicit objects (used by the video generator,
/// where object state evolves across frames).
pub fn render_objects(id: usize, objs: &[PlacedObject], rng: &mut Rng) -> Scene {
    Scene {
        id,
        image: render(objs, rng),
        gt: objs.iter().map(|o| o.gt()).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall_ok;

    #[test]
    fn radius_law_monotone_nonincreasing() {
        let mut prev = f64::INFINITY;
        for n in 1..20 {
            let (lo, hi) = radius_range(n);
            assert!(lo <= hi);
            assert!(hi <= prev);
            assert!(lo >= 5.0);
            prev = hi;
        }
        assert_eq!(radius_range(1), (16.0, 32.0));
    }

    #[test]
    fn prop_scenes_bounded_and_gt_in_frame() {
        forall_ok(
            11,
            25,
            |r| SceneSpec {
                id: 0,
                seed: r.next_u64(),
                n_objects: r.below(12) as usize,
            },
            |spec| {
                let s = render_spec(spec);
                if s.image.len() != NATIVE_RES * NATIVE_RES {
                    return Err("bad image size".into());
                }
                if !s.image.iter().all(|&v| (0.0..=1.0).contains(&v)) {
                    return Err("pixel out of [0,1]".into());
                }
                if s.gt.len() > spec.n_objects {
                    return Err("more GT than requested".into());
                }
                for g in &s.gt {
                    if g.x0 < 0.0
                        || g.y0 < 0.0
                        || g.x1 > NATIVE_RES as f64
                        || g.y1 > NATIVE_RES as f64
                        || g.x0 >= g.x1
                        || g.y0 >= g.y1
                    {
                        return Err(format!("bad gt {g:?}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_placed_objects_never_overlap() {
        forall_ok(
            13,
            30,
            |r| (r.next_u64(), 1 + r.below(10) as usize),
            |&(seed, n)| {
                let mut rng = Rng::new(seed);
                let objs = place_objects(n, &mut rng);
                for (i, a) in objs.iter().enumerate() {
                    for b in objs.iter().skip(i + 1) {
                        if boxes_overlap(&a.gt(), &b.gt(), 0.0) {
                            return Err(format!(
                                "overlap {:?} {:?}",
                                a.gt(),
                                b.gt()
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn bright_object_raises_mean_dark_lowers() {
        let base = SceneSpec {
            id: 0,
            seed: 5,
            n_objects: 0,
        };
        let empty = render_spec(&base);
        let mean = |img: &[f32]| {
            img.iter().map(|&v| v as f64).sum::<f64>() / img.len() as f64
        };
        let m0 = mean(&empty.image);
        assert!((m0 - 0.5).abs() < 0.01, "empty mean {m0}");

        let mut rng = Rng::new(1);
        let bright = PlacedObject {
            cx: 192.0,
            cy: 192.0,
            rx: 30.0,
            ry: 30.0,
            cls: 0,
            contrast: 0.6,
            theta: 0.0,
        };
        let s = render_objects(0, &[bright], &mut rng);
        assert!(mean(&s.image) > m0 + 0.001);
        let dark = PlacedObject {
            cls: 1,
            ..bright
        };
        let mut rng = Rng::new(1);
        let s = render_objects(0, &[dark], &mut rng);
        assert!(mean(&s.image) < m0 - 0.001);
    }

    #[test]
    fn crowded_scene_places_most_objects() {
        let mut rng = Rng::new(99);
        let objs = place_objects(16, &mut rng);
        assert!(objs.len() >= 12, "only placed {}", objs.len());
    }
}
