//! "COCO validation" twin: a 5 000-image dataset whose per-image object
//! counts follow the long-tailed distribution of the real COCO val set
//! (paper Fig. 4): a small zero-object mass, a mode at 1–2 objects, and a
//! long tail out past 15 objects.

use super::{Dataset, SceneSpec};
use crate::util::rng::Rng;

/// Unnormalized weights for object counts 0..=MAX_COUNT, shaped after the
/// paper's Fig. 4 histogram of COCO val 2017.
pub const COUNT_WEIGHTS: [f64; 21] = [
    2.0,  // 0 objects
    17.0, // 1
    14.5, // 2
    11.5, // 3
    9.5,  // 4
    7.5,  // 5
    6.0,  // 6
    5.0,  // 7
    4.0,  // 8
    3.3,  // 9
    2.8,  // 10
    2.3,  // 11
    1.9,  // 12
    1.6,  // 13
    1.3,  // 14
    1.1,  // 15
    0.9,  // 16
    0.8,  // 17
    0.7,  // 18
    0.6,  // 19
    2.7,  // 20 ("20+" bucket)
];

pub const MAX_COUNT: usize = COUNT_WEIGHTS.len() - 1;

/// Sample one object count from the Fig. 4 distribution.
pub fn sample_count(rng: &mut Rng) -> usize {
    rng.weighted(&COUNT_WEIGHTS)
}

/// Build the synthetic COCO validation dataset.
pub fn build(n_images: usize, seed: u64) -> Dataset {
    let base = Rng::new(seed);
    let mut specs = Vec::with_capacity(n_images);
    for id in 0..n_images {
        let mut r = base.derive(id as u64);
        let n_objects = sample_count(&mut r);
        specs.push(SceneSpec {
            id,
            seed: r.next_u64(),
            n_objects,
        });
    }
    Dataset {
        name: format!("coco_val_{n_images}"),
        specs,
    }
}

/// Histogram of requested object counts (for the Fig. 4 experiment).
pub fn count_histogram(d: &Dataset) -> Vec<usize> {
    let mut h = vec![0usize; MAX_COUNT + 1];
    for s in &d.specs {
        h[s.n_objects.min(MAX_COUNT)] += 1;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distribution_matches_weights() {
        let d = build(20_000, 42);
        let h = count_histogram(&d);
        let total: f64 = COUNT_WEIGHTS.iter().sum();
        for (count, (&got, &w)) in
            h.iter().zip(COUNT_WEIGHTS.iter()).enumerate()
        {
            let expect = 20_000.0 * w / total;
            // 5-sigma binomial tolerance
            let sigma = (expect * (1.0 - w / total)).sqrt();
            assert!(
                (got as f64 - expect).abs() < 5.0 * sigma + 5.0,
                "count {count}: got {got}, expected ~{expect:.0}"
            );
        }
    }

    #[test]
    fn deterministic_and_distinct_scenes() {
        let a = build(100, 7);
        let b = build(100, 7);
        assert_eq!(a.specs, b.specs);
        let c = build(100, 8);
        assert_ne!(a.specs, c.specs);
        // ids are sequential
        for (i, s) in a.specs.iter().enumerate() {
            assert_eq!(s.id, i);
        }
        // seeds differ per image
        let mut seeds: Vec<u64> = a.specs.iter().map(|s| s.seed).collect();
        seeds.sort();
        seeds.dedup();
        assert_eq!(seeds.len(), 100);
    }

    #[test]
    fn mode_is_one_object() {
        let d = build(10_000, 1);
        let h = count_histogram(&d);
        let mode = h
            .iter()
            .enumerate()
            .max_by_key(|(_, &c)| c)
            .map(|(i, _)| i)
            .unwrap();
        assert_eq!(mode, 1);
        // zero-object images are rare but present
        assert!(h[0] > 0 && h[0] < h[1]);
    }
}
