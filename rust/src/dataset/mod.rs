//! Dataset substrate: synthetic stand-ins for the paper's three datasets.
//!
//! * [`coco`] — "COCO validation" twin: 5 000 scene specs whose
//!   object-count distribution follows Fig. 4 of the paper.
//! * [`balanced`] — the balanced *sorted* dataset: 5 groups x 200 images,
//!   ordered by group (paper §4.1.1).
//! * [`video`] — pedestrian-crossing video twin: temporally persistent
//!   object tracks rendered frame by frame.
//! * [`scene`] — the procedural scene generator itself (statistical twin
//!   of `python/compile/scenegen.py`).
//!
//! Images are rendered lazily from compact [`SceneSpec`]s so a 5 000-image
//! dataset costs bytes, not gigabytes.

pub mod balanced;
pub mod coco;
pub mod scene;
pub mod video;

/// Native image resolution (must match the manifest's `native_res`).
pub const NATIVE_RES: usize = 384;

/// Number of object classes (bright blobs / dark blobs).
pub const NUM_CLASSES: usize = 2;

/// One ground-truth object.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GtBox {
    pub x0: f64,
    pub y0: f64,
    pub x1: f64,
    pub y1: f64,
    pub cls: usize,
}

impl GtBox {
    pub fn width(&self) -> f64 {
        self.x1 - self.x0
    }

    pub fn height(&self) -> f64 {
        self.y1 - self.y0
    }

    pub fn area(&self) -> f64 {
        self.width() * self.height()
    }
}

/// Compact description of one dataset image; rendering is deterministic
/// in (seed, n_objects).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SceneSpec {
    pub id: usize,
    pub seed: u64,
    pub n_objects: usize,
}

/// A rendered scene: image + exact ground truth.
#[derive(Clone, Debug)]
pub struct Scene {
    pub id: usize,
    pub image: Vec<f32>,
    pub gt: Vec<GtBox>,
}

impl Scene {
    /// True object count (objects actually rendered; crowded scenes may
    /// drop unplaceable objects, and ground truth reflects that).
    pub fn object_count(&self) -> usize {
        self.gt.len()
    }
}

/// A dataset = ordered scene specs (rendered on demand).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub specs: Vec<SceneSpec>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.specs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    pub fn render(&self, idx: usize) -> Scene {
        scene::render_spec(&self.specs[idx])
    }

    pub fn iter_scenes(&self) -> impl Iterator<Item = Scene> + '_ {
        self.specs.iter().map(|s| scene::render_spec(s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gt_box_geometry() {
        let b = GtBox {
            x0: 10.0,
            y0: 20.0,
            x1: 30.0,
            y1: 60.0,
            cls: 0,
        };
        assert_eq!(b.width(), 20.0);
        assert_eq!(b.height(), 40.0);
        assert_eq!(b.area(), 800.0);
    }

    #[test]
    fn dataset_render_is_deterministic() {
        let d = Dataset {
            name: "t".into(),
            specs: vec![SceneSpec {
                id: 0,
                seed: 7,
                n_objects: 3,
            }],
        };
        let a = d.render(0);
        let b = d.render(0);
        assert_eq!(a.image, b.image);
        assert_eq!(a.gt, b.gt);
    }
}
