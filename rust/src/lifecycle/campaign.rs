//! Correlated failure campaigns (DESIGN.md §15).
//!
//! Per-node churn (this module's parent) models *independent* MTBF/MTTR
//! renewal processes; real edge fleets also fail in *correlated* ways —
//! a rack PDU trips, a power domain browns out, a shard-gateway host
//! dies. A campaign composes three seeded processes on top of churn:
//!
//! * **Failure domains**: every node belongs to domain
//!   `node / domain_size` (consecutive synthesis indices — a "rack"
//!   that spans shards, because the fleet homes node `i` on shard
//!   `i % n_shards`). Each domain runs its own alternating
//!   outage/restore renewal process; a domain outage crashes every
//!   member at one instant.
//! * **Shard-gateway failure with re-sharding**: each shard gateway
//!   runs its own kill/recover renewal process. A kill drains the
//!   gateway's queued work through the resilience policy and re-homes
//!   its orphaned nodes onto surviving shards in stable hash order;
//!   recovery pulls the gateway's original nodes back the same way.
//! * **Ground-truth masking**: a node is down iff its churn process
//!   *or* its domain says so. The merged timeline emits only
//!   *effective* flips, so a node that is already independently down
//!   when its domain trips crashes exactly once, and a domain restore
//!   does not resurrect a node whose own repair is still pending.
//!
//! [`CampaignPlan::build`] folds all of it into one deterministic,
//! pre-sorted event list both fleet engines (sequential shared-heap and
//! parallel per-shard) replay identically: the plan is a pure function
//! of `(n_nodes, n_shards, horizon, churn config, campaign config)`,
//! which is what keeps campaign reports bit-identical at any
//! `--threads`.

use anyhow::Result;

use crate::util::json::Json;
use crate::util::rng::Rng;

use super::{exp_sample, failure_schedule, ChurnConfig};

/// Salt of the per-domain outage renewal streams.
const DOMAIN_SALT: u64 = 0x00CA_4411;
/// Salt of the per-shard gateway kill renewal streams.
const GATEWAY_SALT: u64 = 0x00CA_9A7E;
/// Salt of the orphan re-homing hash (stable across campaigns).
const RESHARD_SALT: u64 = 0x00CA_5EED;

/// SplitMix64 finalizer: the stable re-homing hash. Pure in its input,
/// so adoption targets are independent of processing order.
fn mix64(seed: u64) -> u64 {
    let mut z = seed.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Parameters of one failure campaign. Composes with (and requires) a
/// [`ChurnConfig`]: the campaign injects correlated ground-truth
/// events, while churn's probe/membership/resilience machinery decides
/// what the gateways believe and what happens to in-flight work.
#[derive(Clone, Debug, PartialEq)]
pub struct CampaignConfig {
    /// Nodes per failure domain (rack / power-domain fan-out); domain
    /// of node `i` is `i / domain_size`. Must be >= 1.
    pub domain_size: usize,
    /// Mean time between outages per domain (s); non-finite or <= 0
    /// disables domain outages.
    pub domain_mtbf_s: f64,
    /// Mean domain outage duration (s).
    pub domain_mttr_s: f64,
    /// Mean time between kills per shard gateway (s); non-finite or
    /// <= 0 disables gateway kills (the openloop driver only supports
    /// the disabled form — it has no shard gateways).
    pub gateway_mtbf_s: f64,
    /// Mean gateway outage duration (s).
    pub gateway_mttr_s: f64,
    /// Seed of the campaign processes (independent of churn/arrivals).
    pub seed: u64,
}

impl Default for CampaignConfig {
    fn default() -> Self {
        Self {
            domain_size: 4,
            domain_mtbf_s: 20.0,
            domain_mttr_s: 2.0,
            gateway_mtbf_s: f64::INFINITY,
            gateway_mttr_s: 1.0,
            seed: 23,
        }
    }
}

impl CampaignConfig {
    /// Does this campaign schedule domain-wide outages?
    pub fn domains_enabled(&self) -> bool {
        self.domain_mtbf_s.is_finite() && self.domain_mtbf_s > 0.0
    }

    /// Does this campaign kill shard gateways (fleet driver only)?
    pub fn gateway_enabled(&self) -> bool {
        self.gateway_mtbf_s.is_finite() && self.gateway_mtbf_s > 0.0
    }

    /// Shape validation shared by every driver.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.domain_size >= 1,
            "campaign domain_size must be >= 1"
        );
        anyhow::ensure!(
            self.domain_mttr_s > 0.0,
            "campaign domain_mttr_s must be > 0"
        );
        anyhow::ensure!(
            self.gateway_mttr_s > 0.0,
            "campaign gateway_mttr_s must be > 0"
        );
        Ok(())
    }
}

/// One pre-planned campaign event. The vector order of
/// [`CampaignPlan::events`] is the canonical injection order: both
/// fleet engines push these as setup events with consecutive sequence
/// numbers, so equal-time events process in exactly this order.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PlanEvent {
    /// A failure domain tripped (`down`) or restored — an
    /// observability marker anchored to the home shard of the domain's
    /// first member (the member crashes follow as `Truth` events).
    DomainMark { t: f64, shard: usize, domain: usize, down: bool },
    /// An *effective* ground-truth health flip of one node (churn and
    /// domain masks already folded).
    Truth { t: f64, node: usize, up: bool },
    /// Shard `shard`'s gateway dies. Queued work drains through the
    /// `Release` events that follow immediately.
    GwDown { t: f64, shard: usize },
    /// Shard `shard`'s gateway recovers; its original nodes return
    /// through the `Release`/`Adopt` pairs that follow.
    GwUp { t: f64, shard: usize },
    /// Node `node` leaves `shard`: drain its queue through the
    /// resilience policy and park it dormant (`PoweredDown`).
    Release { t: f64, shard: usize, node: usize },
    /// Node `node` is adopted by `shard`; `up` is its ground-truth
    /// health at adoption. The adopting gateway bootstraps membership
    /// from scratch (Warming + probes) — stale-view realism, never
    /// ground-truth teleportation.
    Adopt { t: f64, shard: usize, node: usize, up: bool },
}

impl PlanEvent {
    /// Virtual time of the event.
    pub fn t(&self) -> f64 {
        match *self {
            PlanEvent::DomainMark { t, .. }
            | PlanEvent::Truth { t, .. }
            | PlanEvent::GwDown { t, .. }
            | PlanEvent::GwUp { t, .. }
            | PlanEvent::Release { t, .. }
            | PlanEvent::Adopt { t, .. } => t,
        }
    }
}

/// Static campaign summary: a pure function of the plan (identical at
/// every thread count by construction), serialized into the report.
#[derive(Clone, Debug, PartialEq)]
pub struct CampaignReport {
    /// Number of failure domains.
    pub domains: usize,
    /// Configured domain fan-out.
    pub domain_size: usize,
    /// Domain-wide outages injected.
    pub domain_outages: usize,
    /// Shard-gateway kills injected.
    pub gw_kills: usize,
    /// Node adoptions performed by re-sharding (kills + recoveries).
    pub adoptions: usize,
    /// Mean domain outage duration (open outages run to the horizon).
    pub mean_outage_s: f64,
}

impl CampaignReport {
    /// Stable JSON block — joins the golden-traced report dumps.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("domains", Json::num(self.domains as f64)),
            ("domain_size", Json::num(self.domain_size as f64)),
            ("domain_outages", Json::num(self.domain_outages as f64)),
            ("gw_kills", Json::num(self.gw_kills as f64)),
            ("adoptions", Json::num(self.adoptions as f64)),
            ("mean_outage_s", Json::num(self.mean_outage_s)),
        ])
    }

    /// One-line human summary for the CLI paths.
    pub fn summary(&self) -> String {
        format!(
            "campaign: {} domains x {}, {} outages (mean {:.2} s), {} gw kills, {} adoptions",
            self.domains,
            self.domain_size,
            self.domain_outages,
            self.mean_outage_s,
            self.gw_kills,
            self.adoptions
        )
    }
}

/// One raw renewal-process moment, before mask folding.
#[derive(Clone, Copy, Debug)]
enum Moment {
    /// Per-node churn flip (rank 0).
    Node { node: usize, down: bool },
    /// Domain-wide flip (rank 1).
    Domain { domain: usize, down: bool },
    /// Gateway flip (rank 2).
    Gateway { shard: usize, down: bool },
}

impl Moment {
    fn rank(&self) -> (u8, usize) {
        match *self {
            Moment::Node { node, .. } => (0, node),
            Moment::Domain { domain, .. } => (1, domain),
            Moment::Gateway { shard, .. } => (2, shard),
        }
    }
}

/// Alternating down/up renewal stream for `n` entities: one seeded
/// exponential process each, sorted by `(t, id)`.
fn renewal_stream(
    n: usize,
    horizon_s: f64,
    mtbf_s: f64,
    mttr_s: f64,
    base: &Rng,
) -> Vec<(f64, usize, bool)> {
    let mut out = Vec::new();
    for id in 0..n {
        let mut rng = base.derive(id as u64);
        let mut t = 0.0;
        loop {
            t += exp_sample(&mut rng, mtbf_s);
            if t >= horizon_s {
                break;
            }
            out.push((t, id, true)); // down
            t += exp_sample(&mut rng, mttr_s.max(1e-6));
            if t >= horizon_s {
                break;
            }
            out.push((t, id, false)); // restore
        }
    }
    out
}

/// The fully folded, deterministic campaign timeline plus the node →
/// shard homing history the parallel engine needs to statically assign
/// ground-truth events to workers.
#[derive(Clone, Debug)]
pub struct CampaignPlan {
    /// Canonical injection order (see [`PlanEvent`]).
    pub events: Vec<PlanEvent>,
    /// Static summary of the schedule.
    pub report: CampaignReport,
    /// Per-node home transitions `(t, shard)`, starting at
    /// `(0, node % n_shards)`.
    homes_log: Vec<Vec<(f64, usize)>>,
}

impl CampaignPlan {
    /// Fold churn + domain + gateway processes into the canonical
    /// event list. Pure in its arguments; `n_shards = 1` is the
    /// openloop (single-gateway) shape, where gateway kills must be
    /// disabled by the caller.
    pub fn build(
        n_nodes: usize,
        n_shards: usize,
        horizon_s: f64,
        churn: &ChurnConfig,
        cfg: &CampaignConfig,
    ) -> Result<Self> {
        cfg.validate()?;
        anyhow::ensure!(n_shards >= 1, "campaign needs >= 1 shard");
        let ds = cfg.domain_size;
        let n_domains = if n_nodes == 0 { 0 } else { n_nodes.div_ceil(ds) };

        // raw moments: per-node churn flips, domain flips, gateway
        // flips — merged by (t, rank, id); cross-stream time ties are
        // measure-zero (independent RNG streams)
        let mut moments: Vec<(f64, Moment)> = Vec::new();
        for ev in failure_schedule(n_nodes, horizon_s, churn) {
            moments.push((
                ev.t,
                Moment::Node { node: ev.node, down: !ev.up },
            ));
        }
        if cfg.domains_enabled() {
            let base = Rng::new(cfg.seed ^ DOMAIN_SALT);
            for (t, d, down) in renewal_stream(
                n_domains,
                horizon_s,
                cfg.domain_mtbf_s,
                cfg.domain_mttr_s,
                &base,
            ) {
                moments.push((t, Moment::Domain { domain: d, down }));
            }
        }
        if cfg.gateway_enabled() {
            let base = Rng::new(cfg.seed ^ GATEWAY_SALT);
            for (t, s, down) in renewal_stream(
                n_shards,
                horizon_s,
                cfg.gateway_mtbf_s,
                cfg.gateway_mttr_s,
                &base,
            ) {
                moments.push((t, Moment::Gateway { shard: s, down }));
            }
        }
        moments.sort_by(|a, b| {
            a.0.total_cmp(&b.0).then(a.1.rank().cmp(&b.1.rank()))
        });

        // fold masks + homing
        let mut churn_down = vec![false; n_nodes];
        let mut domain_down = vec![false; n_domains];
        let mut eff_down = vec![false; n_nodes];
        let mut gw_up = vec![true; n_shards];
        let mut home: Vec<usize> =
            (0..n_nodes).map(|i| i % n_shards).collect();
        let mut parked = vec![false; n_nodes];
        let mut homes_log: Vec<Vec<(f64, usize)>> =
            home.iter().map(|&s| vec![(0.0, s)]).collect();
        let mut events: Vec<PlanEvent> = Vec::new();
        let mut domain_outages = 0usize;
        let mut gw_kills = 0usize;
        let mut adoptions = 0usize;
        let mut outage_sum_s = 0.0f64;
        let mut outage_started: Vec<Option<f64>> = vec![None; n_domains];

        let mut flip =
            |events: &mut Vec<PlanEvent>,
             eff_down: &mut Vec<bool>,
             churn_down: &[bool],
             domain_down: &[bool],
             t: f64,
             node: usize| {
                let dom = node / ds;
                let eff = churn_down[node] || domain_down[dom];
                if eff != eff_down[node] {
                    eff_down[node] = eff;
                    events.push(PlanEvent::Truth { t, node, up: !eff });
                }
            };

        for (t, m) in moments {
            match m {
                Moment::Node { node, down } => {
                    churn_down[node] = down;
                    flip(
                        &mut events,
                        &mut eff_down,
                        &churn_down,
                        &domain_down,
                        t,
                        node,
                    );
                }
                Moment::Domain { domain, down } => {
                    domain_down[domain] = down;
                    if down {
                        domain_outages += 1;
                        outage_started[domain] = Some(t);
                    } else if let Some(t0) = outage_started[domain].take()
                    {
                        outage_sum_s += t - t0;
                    }
                    let first = domain * ds;
                    let last = ((domain + 1) * ds).min(n_nodes);
                    events.push(PlanEvent::DomainMark {
                        t,
                        shard: home[first],
                        domain,
                        down,
                    });
                    for node in first..last {
                        flip(
                            &mut events,
                            &mut eff_down,
                            &churn_down,
                            &domain_down,
                            t,
                            node,
                        );
                    }
                }
                Moment::Gateway { shard, down } => {
                    if down {
                        gw_up[shard] = false;
                        gw_kills += 1;
                        events.push(PlanEvent::GwDown { t, shard });
                        let survivors: Vec<usize> = (0..n_shards)
                            .filter(|&s| gw_up[s])
                            .collect();
                        for node in 0..n_nodes {
                            if home[node] != shard || parked[node] {
                                continue;
                            }
                            events.push(PlanEvent::Release {
                                t,
                                shard,
                                node,
                            });
                            if survivors.is_empty() {
                                parked[node] = true;
                            } else {
                                let pick = mix64(
                                    node as u64 ^ RESHARD_SALT,
                                )
                                    as usize
                                    % survivors.len();
                                let s2 = survivors[pick];
                                events.push(PlanEvent::Adopt {
                                    t,
                                    shard: s2,
                                    node,
                                    up: !eff_down[node],
                                });
                                adoptions += 1;
                                home[node] = s2;
                                homes_log[node].push((t, s2));
                            }
                        }
                    } else {
                        gw_up[shard] = true;
                        events.push(PlanEvent::GwUp { t, shard });
                        // recovery re-adopts the gateway's ORIGINAL
                        // nodes from wherever they live now (parked
                        // nodes of other dead shards stay parked until
                        // their own gateway returns)
                        for node in 0..n_nodes {
                            if node % n_shards != shard {
                                continue;
                            }
                            let cur = home[node];
                            events.push(PlanEvent::Release {
                                t,
                                shard: cur,
                                node,
                            });
                            events.push(PlanEvent::Adopt {
                                t,
                                shard,
                                node,
                                up: !eff_down[node],
                            });
                            adoptions += 1;
                            parked[node] = false;
                            if cur != shard {
                                home[node] = shard;
                                homes_log[node].push((t, shard));
                            }
                        }
                    }
                }
            }
        }
        // open outages run to the horizon
        for started in outage_started.into_iter().flatten() {
            outage_sum_s += horizon_s - started;
        }
        let report = CampaignReport {
            domains: n_domains,
            domain_size: ds,
            domain_outages,
            gw_kills,
            adoptions,
            mean_outage_s: if domain_outages > 0 {
                outage_sum_s / domain_outages as f64
            } else {
                0.0
            },
        };
        Ok(Self { events, report, homes_log })
    }

    /// The shard node `node` is homed on when an event at time `t`
    /// processes: the last transition strictly before `t` (same-time
    /// moves sort after ground-truth flips in the canonical order).
    pub fn home_at(&self, node: usize, t: f64) -> usize {
        let log = &self.homes_log[node];
        let mut cur = log[0].1;
        for &(tt, s) in log.iter() {
            if tt < t {
                cur = s;
            } else {
                break;
            }
        }
        cur
    }

    /// Did any re-homing happen (i.e. does the fleet need the
    /// pre-provisioned all-nodes shard tables)?
    pub fn re_shards(&self) -> bool {
        self.homes_log.iter().any(|l| l.len() > 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lifecycle::ResiliencePolicy;

    fn churn() -> ChurnConfig {
        ChurnConfig {
            mtbf_s: 5.0,
            mttr_s: 1.0,
            policy: ResiliencePolicy::Retry { budget: 2 },
            seed: 3,
            ..Default::default()
        }
    }

    fn camp() -> CampaignConfig {
        CampaignConfig {
            domain_size: 2,
            domain_mtbf_s: 4.0,
            domain_mttr_s: 1.0,
            gateway_mtbf_s: 6.0,
            gateway_mttr_s: 2.0,
            seed: 23,
        }
    }

    #[test]
    fn config_gates_and_validation() {
        let c = CampaignConfig::default();
        assert!(c.domains_enabled());
        assert!(!c.gateway_enabled(), "gateway kills default off");
        assert!(c.validate().is_ok());
        assert!(
            CampaignConfig { domain_size: 0, ..camp() }
                .validate()
                .is_err()
        );
        assert!(
            CampaignConfig { domain_mttr_s: 0.0, ..camp() }
                .validate()
                .is_err()
        );
    }

    #[test]
    fn plan_is_deterministic_and_time_sorted() {
        let a = CampaignPlan::build(8, 2, 40.0, &churn(), &camp())
            .unwrap();
        let b = CampaignPlan::build(8, 2, 40.0, &churn(), &camp())
            .unwrap();
        assert_eq!(a.events, b.events);
        assert_eq!(a.report, b.report);
        assert!(!a.events.is_empty());
        assert!(a
            .events
            .windows(2)
            .all(|w| w[0].t() <= w[1].t()));
        // a different campaign seed moves the correlated events but
        // leaves the independent churn flips alone
        let c = CampaignPlan::build(
            8,
            2,
            40.0,
            &churn(),
            &CampaignConfig { seed: 99, ..camp() },
        )
        .unwrap();
        assert_ne!(a.events, c.events);
    }

    #[test]
    fn masking_emits_each_effective_flip_once() {
        // pure-campaign (no independent churn): every domain trip
        // crashes each member exactly once, restore rejoins them
        let quiet = ChurnConfig {
            mtbf_s: f64::INFINITY,
            ..churn()
        };
        let plan = CampaignPlan::build(
            6,
            1,
            60.0,
            &quiet,
            &CampaignConfig {
                gateway_mtbf_s: f64::INFINITY,
                ..camp()
            },
        )
        .unwrap();
        assert!(plan.report.domain_outages > 0);
        assert_eq!(plan.report.gw_kills, 0);
        assert!(plan.report.mean_outage_s > 0.0);
        // strict per-node alternation: a crash is never followed by
        // another crash (the whole point of the effective-flip fold)
        for node in 0..6 {
            let mut down = false;
            for ev in &plan.events {
                if let PlanEvent::Truth { node: n, up, .. } = *ev {
                    if n == node {
                        assert_eq!(up, down, "node {node} double flip");
                        down = !up;
                    }
                }
            }
        }
        // with churn composed in, alternation must still hold
        let plan2 =
            CampaignPlan::build(6, 1, 60.0, &churn(), &camp()).unwrap();
        for node in 0..6 {
            let mut down = false;
            for ev in &plan2.events {
                if let PlanEvent::Truth { node: n, up, .. } = *ev {
                    if n == node {
                        assert_eq!(up, down, "node {node} double flip");
                        down = !up;
                    }
                }
            }
        }
    }

    #[test]
    fn gateway_kill_releases_and_rehomes_deterministically() {
        let plan = CampaignPlan::build(8, 2, 60.0, &churn(), &camp())
            .unwrap();
        assert!(plan.report.gw_kills > 0);
        assert!(plan.report.adoptions > 0);
        assert!(plan.re_shards());
        // every Release pairs with an Adopt or a park; adopted shards
        // are live at adoption time (never the shard just killed)
        let mut dead: Vec<bool> = vec![false; 2];
        for ev in &plan.events {
            match *ev {
                PlanEvent::GwDown { shard, .. } => dead[shard] = true,
                PlanEvent::GwUp { shard, .. } => dead[shard] = false,
                PlanEvent::Adopt { shard, .. } => {
                    assert!(!dead[shard], "adopted by a dead gateway")
                }
                _ => {}
            }
        }
        // home_at follows the log: before any event it is node % 2
        for node in 0..8 {
            assert_eq!(plan.home_at(node, 0.0), node % 2);
        }
    }

    #[test]
    fn disabled_campaign_is_churn_plus_empty_extras() {
        let off = CampaignConfig {
            domain_mtbf_s: f64::INFINITY,
            gateway_mtbf_s: f64::INFINITY,
            ..CampaignConfig::default()
        };
        let plan =
            CampaignPlan::build(4, 2, 40.0, &churn(), &off).unwrap();
        assert_eq!(plan.report.domain_outages, 0);
        assert_eq!(plan.report.gw_kills, 0);
        assert!(!plan.re_shards());
        // the timeline degenerates to the plain churn schedule
        let sched = failure_schedule(4, 40.0, &churn());
        let truths: Vec<(f64, usize, bool)> = plan
            .events
            .iter()
            .filter_map(|e| match *e {
                PlanEvent::Truth { t, node, up } => Some((t, node, up)),
                _ => None,
            })
            .collect();
        assert_eq!(truths.len(), sched.len());
        for (got, want) in truths.iter().zip(&sched) {
            assert_eq!(*got, (want.t, want.node, want.up));
        }
        let j = plan.report.to_json();
        assert_eq!(j.req("gw_kills").unwrap().as_usize(), Some(0));
        assert!(plan.report.summary().contains("0 gw kills"));
    }
}
