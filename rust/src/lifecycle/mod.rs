//! Node lifecycle under churn (DESIGN.md §9).
//!
//! The paper evaluates routing on a fixed, always-healthy testbed; a
//! production edge fleet is the opposite — devices crash, overheat,
//! reboot, and rejoin constantly. This module makes that a first-class
//! scenario axis for every router:
//!
//! * [`failure_schedule`] samples each node's alternating up/down
//!   renewal process (exponential MTBF/MTTR from the seeded RNG) on the
//!   shared virtual clock, so the open-loop and fleet simulators can
//!   inject ground-truth crash/rejoin events into their event heaps.
//! * [`Membership`] is the gateway's *believed* view of node health,
//!   fed only by periodic probes (and data-path dispatch failures) —
//!   never by ground truth. Routing therefore operates on a stale view:
//!   between a crash and its detection the gateway keeps dispatching to
//!   a dead node and pays for it.
//! * [`ResiliencePolicy`] decides what happens to requests lost to a
//!   crash: drop them, retry with a bounded budget, or (proactively)
//!   hedge every request with a duplicate on the second-best pair.
//! * [`ChurnState`] tracks the copies of each request in flight so the
//!   drivers can account lost / retried / hedged outcomes exactly once
//!   per request; [`ChurnReport`] is the serialized summary.
//!
//! Everything here is deterministic in its seeds; golden-trace tests
//! pin whole churn runs byte for byte.

use crate::router::{PairId, PairTable};
use crate::util::json::Json;
use crate::util::rng::Rng;

pub mod campaign;

/// How the gateway handles a request whose in-flight copy is lost to a
/// node crash (or that cannot be placed at arrival under churn).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResiliencePolicy {
    /// Lost requests are gone; the cheapest policy and the baseline the
    /// others are measured against.
    Drop,
    /// Re-route a lost request after a backoff, at most `budget` times;
    /// exhausting the budget loses it.
    Retry { budget: usize },
    /// Dispatch a duplicate of every request to the second-best
    /// admissible pair. Either copy completing serves the request; a
    /// crash only loses it when *both* copies die. No retries.
    Hedge,
}

impl ResiliencePolicy {
    /// Parse a config/CLI name: `drop`, `retry`, or `hedge`.
    /// `retry_budget` parameterizes the retry variant.
    pub fn parse(s: &str, retry_budget: usize) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "drop" => Some(Self::Drop),
            "retry" => Some(Self::Retry { budget: retry_budget }),
            "hedge" => Some(Self::Hedge),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Self::Drop => "drop",
            Self::Retry { .. } => "retry",
            Self::Hedge => "hedge",
        }
    }
}

/// Parameters of one churn scenario: the ground-truth failure process,
/// the probe loop that (belatedly) observes it, the warm-up window for
/// rejoining nodes, and the resilience policy.
#[derive(Clone, Debug)]
pub struct ChurnConfig {
    /// Mean time between failures per node (s); `INFINITY` = no churn
    /// (membership and probes still run, nothing ever crashes).
    pub mtbf_s: f64,
    /// Mean time to repair per node (s).
    pub mttr_s: f64,
    /// Gateway health-probe period (s).
    pub probe_interval_s: f64,
    /// Probe timeout (s): probe results — responses and misses alike —
    /// reach the membership view this long after the probe fires.
    pub probe_timeout_s: f64,
    /// Consecutive missed probes before a Suspect node is marked Down
    /// (>= 1; 1 means the first miss is terminal).
    pub suspect_after: usize,
    /// Warm-up window after a recovery is observed (s): the node is
    /// routable again but its profile rows are aged (cost-inflated)
    /// until the window closes.
    pub warmup_s: f64,
    /// Cost inflation at the start of the warm-up window (0.5 = +50%
    /// believed latency/energy), decaying linearly to 0 over
    /// `warmup_s`.
    pub warmup_penalty: f64,
    pub policy: ResiliencePolicy,
    /// Delay before a retry re-enters routing (s).
    pub retry_backoff_s: f64,
    /// Hedge cancellation-on-first-response: when the winning copy of a
    /// hedged request completes, cancel the in-flight sibling — release
    /// its node slot immediately and charge only the energy accrued up
    /// to the cancellation. `false` keeps the run-to-completion
    /// behavior (the loser serves fully and its whole energy is waste).
    pub hedge_cancel: bool,
    /// How far past the last arrival the failure/probe timelines extend
    /// (s) — bounds the event heap; late completions past the horizon
    /// simply see a frozen membership view.
    pub horizon_slack_s: f64,
    /// Seed of the failure process (independent of arrivals/jitter).
    pub seed: u64,
}

impl Default for ChurnConfig {
    fn default() -> Self {
        Self {
            mtbf_s: 60.0,
            mttr_s: 4.0,
            probe_interval_s: 0.5,
            probe_timeout_s: 0.2,
            suspect_after: 2,
            warmup_s: 3.0,
            warmup_penalty: 0.5,
            policy: ResiliencePolicy::Retry { budget: 4 },
            retry_backoff_s: 0.25,
            hedge_cancel: false,
            horizon_slack_s: 30.0,
            seed: 11,
        }
    }
}

/// MTBF yielding a target steady-state availability for a given MTTR:
/// availability = MTBF / (MTBF + MTTR). `availability >= 1` maps to
/// `INFINITY` (the no-churn baseline).
pub fn mtbf_for_availability(availability: f64, mttr_s: f64) -> f64 {
    if availability >= 1.0 {
        f64::INFINITY
    } else {
        mttr_s * availability / (1.0 - availability).max(1e-9)
    }
}

/// One ground-truth health flip in the failure/recovery process.
#[derive(Clone, Debug, PartialEq)]
pub struct FailureEvent {
    pub t: f64,
    /// Node index in pool order (fleet: global synthesis index).
    pub node: usize,
    /// `true` = rejoin, `false` = crash.
    pub up: bool,
}

fn exp_sample(rng: &mut Rng, mean_s: f64) -> f64 {
    -(1.0 - rng.f64()).ln() * mean_s
}

/// Sample every node's alternating crash/rejoin timeline up to
/// `horizon_s`. Nodes start up; each draws an exponential time to
/// failure (mean `mtbf_s`) then an exponential repair (mean `mttr_s`),
/// repeating. Per-node streams are derived from the churn seed, so the
/// schedule is deterministic and independent of node count changes
/// elsewhere. Sorted by `(t, node)`.
pub fn failure_schedule(
    n_nodes: usize,
    horizon_s: f64,
    cfg: &ChurnConfig,
) -> Vec<FailureEvent> {
    let mut events = Vec::new();
    if !cfg.mtbf_s.is_finite() || cfg.mtbf_s <= 0.0 || n_nodes == 0 {
        return events;
    }
    let base = Rng::new(cfg.seed ^ 0x11FE_C7C1E);
    for node in 0..n_nodes {
        let mut rng = base.derive(node as u64);
        let mut t = 0.0;
        loop {
            t += exp_sample(&mut rng, cfg.mtbf_s);
            if t >= horizon_s {
                break;
            }
            events.push(FailureEvent { t, node, up: false });
            t += exp_sample(&mut rng, cfg.mttr_s.max(1e-6));
            if t >= horizon_s {
                break;
            }
            events.push(FailureEvent { t, node, up: true });
        }
    }
    events.sort_by(|a, b| a.t.total_cmp(&b.t).then(a.node.cmp(&b.node)));
    events
}

/// A gateway's belief about one node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemberState {
    /// Responding to probes; fully routable.
    Up,
    /// Missed at least one probe (or failed a dispatch) but not yet
    /// declared Down; still routable — the grey zone where stale views
    /// lose requests.
    Suspect,
    /// Declared dead after `suspect_after` consecutive misses; excluded
    /// from routing until a probe answers again.
    Down,
    /// Responding again after Down; routable, but profile rows are aged
    /// (cost-inflated) until the warm-up window closes.
    Warming,
    /// Deliberately powered off by the autoscaler (`adapt::Scaler`).
    /// Excluded from routing like Down, but *sticky*: probe traffic
    /// cannot resurrect it — only an explicit
    /// [`Membership::power_up`] does, which re-enters routing through
    /// the same Warming window churn recoveries use. Census-wise it
    /// counts in the down bucket (the believed-unroutable set), so
    /// churn reports keep their shape.
    PoweredDown,
}

#[derive(Clone, Debug)]
struct MemberEntry {
    state: MemberState,
    misses: usize,
    warmup_until: f64,
    /// Ground-truth crash/rejoin timestamps, recorded by the driver for
    /// detection/recovery latency accounting only — routing never reads
    /// them (that is the whole point of the probe layer).
    crashed_at: Option<f64>,
    rejoined_at: Option<f64>,
    /// Ground-truth down marker mirroring the driver's pool health.
    /// Never read by routing; the autoscaler consults it so powering a
    /// node back up cannot resurrect one that is *actually* crashed
    /// (its pending Rejoin event restores health when repair ends).
    truth_down: bool,
}

/// Probe-driven membership: the stale health view one gateway routes
/// on, keyed by interned [`PairId`] (a dense per-id table over the
/// gateway's routing table, so every hot-path health check is an O(1)
/// array hit with no string comparison). Updated only by
/// [`Membership::observe_probe`] (scheduled probe results) and
/// [`Membership::observe_dispatch_failure`] (data-path evidence);
/// ground truth reaches it exclusively as accounting metadata via
/// [`Membership::ground_truth_changed`].
#[derive(Clone, Debug)]
pub struct Membership {
    /// Dense per-id entries, aligned with the routing table.
    entries: Vec<MemberEntry>,
    suspect_after: usize,
    warmup_s: f64,
    warmup_penalty: f64,
    detect_sum_s: f64,
    detect_count: usize,
    recover_sum_s: f64,
    recover_count: usize,
}

impl Membership {
    /// Start a membership view over every pair of a routing table
    /// (all believed Up).
    pub fn new(table: &PairTable, cfg: &ChurnConfig) -> Self {
        Self {
            entries: vec![
                MemberEntry {
                    state: MemberState::Up,
                    misses: 0,
                    warmup_until: 0.0,
                    crashed_at: None,
                    rejoined_at: None,
                    truth_down: false,
                };
                table.len()
            ],
            suspect_after: cfg.suspect_after.max(1),
            warmup_s: cfg.warmup_s.max(1e-9),
            warmup_penalty: cfg.warmup_penalty.max(0.0),
            detect_sum_s: 0.0,
            detect_count: 0,
            recover_sum_s: 0.0,
            recover_count: 0,
        }
    }

    pub fn state(&self, id: PairId) -> Option<MemberState> {
        self.entries.get(id.index()).map(|e| e.state)
    }

    /// Routable under the believed view: everything but Down and
    /// PoweredDown. Suspect nodes still take traffic (hysteresis);
    /// unknown ids do not.
    pub fn believed_up(&self, id: PairId) -> bool {
        self.entries
            .get(id.index())
            .map(|e| {
                !matches!(
                    e.state,
                    MemberState::Down | MemberState::PoweredDown
                )
            })
            .unwrap_or(false)
    }

    /// Believed cost multiplier for routing: 1.0 normally; during a
    /// warm-up window, `1 + penalty * remaining/warmup_s` (the aged
    /// profile a rejoining node routes with).
    pub fn cost_multiplier(&self, id: PairId, now_s: f64) -> f64 {
        match self.entries.get(id.index()) {
            Some(e)
                if e.state == MemberState::Warming
                    && now_s < e.warmup_until =>
            {
                1.0 + self.warmup_penalty * (e.warmup_until - now_s)
                    / self.warmup_s
            }
            _ => 1.0,
        }
    }

    /// Apply one probe result (fires `probe_timeout_s` after the probe
    /// sampled ground truth — the caller schedules that delay).
    pub fn observe_probe(&mut self, id: PairId, responded: bool, now_s: f64) {
        let suspect_after = self.suspect_after;
        let warmup_s = self.warmup_s;
        let Some(e) = self.entries.get_mut(id.index()) else {
            return;
        };
        if e.state == MemberState::PoweredDown {
            // Deliberate power-off is sticky: the node is physically
            // unresponsive, so misses carry no information, and even a
            // response (a straggler probe raced the power-down) must
            // not resurrect it — only power_up() does.
            return;
        }
        if responded {
            e.misses = 0;
            match e.state {
                MemberState::Down => {
                    e.state = MemberState::Warming;
                    e.warmup_until = now_s + warmup_s;
                    e.crashed_at = None;
                    if let Some(rj) = e.rejoined_at.take() {
                        self.recover_sum_s += (now_s - rj).max(0.0);
                        self.recover_count += 1;
                    }
                }
                MemberState::Suspect => e.state = MemberState::Up,
                MemberState::Warming => {
                    if now_s >= e.warmup_until {
                        e.state = MemberState::Up;
                    }
                }
                MemberState::Up => {}
            }
        } else {
            e.misses += 1;
            if e.state != MemberState::Down {
                if e.misses >= suspect_after {
                    e.state = MemberState::Down;
                    if let Some(ca) = e.crashed_at.take() {
                        self.detect_sum_s += (now_s - ca).max(0.0);
                        self.detect_count += 1;
                    }
                } else {
                    e.state = MemberState::Suspect;
                }
            }
        }
    }

    /// A dispatch to `id` found it dead: data-path evidence counts
    /// like a missed probe (passive health checking), so the gateway
    /// stops feeding a crashed node before the next probe cycle.
    pub fn observe_dispatch_failure(&mut self, id: PairId, now_s: f64) {
        self.observe_probe(id, false, now_s);
    }

    /// Accounting-only hook: the driver records ground-truth flips so
    /// detection (crash → Down) and recovery (rejoin → routable) delays
    /// can be reported. Never read by routing.
    pub fn ground_truth_changed(&mut self, id: PairId, up: bool, now_s: f64) {
        if let Some(e) = self.entries.get_mut(id.index()) {
            e.truth_down = !up;
            if up {
                e.rejoined_at = Some(now_s);
            } else {
                e.crashed_at = Some(now_s);
                e.rejoined_at = None;
            }
        }
    }

    /// Is `id` crashed in ground truth (last recorded flip was a
    /// crash)? An accounting/driver hook like
    /// [`Membership::ground_truth_changed`] — routing never reads it.
    /// Unknown ids report `false`.
    pub fn truth_down(&self, id: PairId) -> bool {
        self.entries
            .get(id.index())
            .map(|e| e.truth_down)
            .unwrap_or(false)
    }

    /// Census of believed states: (up, suspect, down, warming).
    /// PoweredDown folds into the down bucket — both mean "believed
    /// unroutable" — so [`ChurnReport`]'s serialized shape (and every
    /// golden trace pinning it) is independent of whether a scaler ran.
    pub fn counts(&self) -> (usize, usize, usize, usize) {
        let mut c = (0, 0, 0, 0);
        for e in &self.entries {
            match e.state {
                MemberState::Up => c.0 += 1,
                MemberState::Suspect => c.1 += 1,
                MemberState::Down | MemberState::PoweredDown => c.2 += 1,
                MemberState::Warming => c.3 += 1,
            }
        }
        c
    }

    /// Autoscaler hook: deliberately power `id` down. Unlike a crash
    /// there is no detection latency — the scaler *is* the gateway, so
    /// the believed view flips immediately and stays PoweredDown until
    /// [`Membership::power_up`].
    pub fn power_down(&mut self, id: PairId) {
        if let Some(e) = self.entries.get_mut(id.index()) {
            e.state = MemberState::PoweredDown;
            e.misses = 0;
        }
    }

    /// Autoscaler hook: power `id` back up at `now_s`. The node
    /// re-enters routing through the same Warming window a churn
    /// recovery uses (aged costs decaying over `warmup_s`).
    pub fn power_up(&mut self, id: PairId, now_s: f64) {
        if let Some(e) = self.entries.get_mut(id.index()) {
            if e.state == MemberState::PoweredDown {
                e.state = MemberState::Warming;
                e.warmup_until = now_s + self.warmup_s;
                e.misses = 0;
            }
        }
    }

    /// (sum, count) of crash → Down detection delays.
    pub fn detect_stats(&self) -> (f64, usize) {
        (self.detect_sum_s, self.detect_count)
    }

    /// (sum, count) of rejoin → routable recovery delays.
    pub fn recover_stats(&self) -> (f64, usize) {
        (self.recover_sum_s, self.recover_count)
    }
}

/// Per-request copy accounting.
#[derive(Clone, Copy, Debug, Default)]
struct ReqCopies {
    /// Copies currently in the system (1 normally, 2 when hedged).
    outstanding: u8,
    /// A copy already completed and was recorded.
    done: bool,
    /// Retries consumed.
    attempts: usize,
}

/// What the driver must do after losing one in-flight copy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LossOutcome {
    /// Nothing: a sibling copy is still in flight, or the request was
    /// already served.
    Absorbed,
    /// Schedule a re-dispatch of the request at this virtual time.
    RetryAt(f64),
    /// The request is permanently lost (already counted).
    Lost,
}

/// Request-copy state machine shared by the open-loop and fleet
/// drivers: tracks how many copies of each request are in flight and
/// applies the resilience policy when copies are lost, guaranteeing
/// each request is counted exactly once (served, lost, or shed).
#[derive(Clone, Debug)]
pub struct ChurnState {
    policy: ResiliencePolicy,
    retry_backoff_s: f64,
    req: Vec<ReqCopies>,
    /// Ground-truth crash events that fired during the run.
    pub crashes: usize,
    /// Requests permanently lost (crash losses the policy could not or
    /// would not recover).
    pub lost: usize,
    /// Successful re-dispatches (retry policy).
    pub retried: usize,
    /// Hedge duplicates dispatched.
    pub hedged: usize,
    /// Requests whose *hedge* copy completed first.
    pub hedge_wins: usize,
    /// Backend energy burned by losing hedge copies (their service is
    /// real but their response is discarded).
    pub wasted_energy_mwh: f64,
}

impl ChurnState {
    pub fn new(n_requests: usize, policy: ResiliencePolicy, retry_backoff_s: f64) -> Self {
        Self {
            policy,
            retry_backoff_s,
            req: vec![ReqCopies::default(); n_requests],
            crashes: 0,
            lost: 0,
            retried: 0,
            hedged: 0,
            hedge_wins: 0,
            wasted_energy_mwh: 0.0,
        }
    }

    pub fn policy(&self) -> ResiliencePolicy {
        self.policy
    }

    /// A primary copy entered the system (arrival admitted).
    pub fn dispatched(&mut self, idx: usize) {
        self.req[idx].outstanding += 1;
    }

    /// A hedge duplicate entered the system.
    pub fn hedge_dispatched(&mut self, idx: usize) {
        self.req[idx].outstanding += 1;
        self.hedged += 1;
    }

    /// A retry re-dispatch entered the system.
    pub fn retry_dispatched(&mut self, idx: usize) {
        self.req[idx].outstanding += 1;
        self.retried += 1;
    }

    /// One in-flight copy of `idx` was lost to a crash (or a dispatch
    /// onto a dead node).
    pub fn copy_lost(&mut self, idx: usize, now_s: f64) -> LossOutcome {
        let r = &mut self.req[idx];
        r.outstanding = r.outstanding.saturating_sub(1);
        if r.done || r.outstanding > 0 {
            return LossOutcome::Absorbed;
        }
        match self.policy {
            ResiliencePolicy::Retry { budget } if r.attempts < budget => {
                r.attempts += 1;
                LossOutcome::RetryAt(now_s + self.retry_backoff_s)
            }
            _ => {
                self.lost += 1;
                LossOutcome::Lost
            }
        }
    }

    /// A scheduled retry (or an arrival, under the retry policy) found
    /// no admissible endpoint: back off again if budget remains.
    pub fn placement_failed(&mut self, idx: usize, now_s: f64) -> LossOutcome {
        let r = &mut self.req[idx];
        if r.done {
            return LossOutcome::Absorbed;
        }
        match self.policy {
            ResiliencePolicy::Retry { budget } if r.attempts < budget => {
                r.attempts += 1;
                LossOutcome::RetryAt(now_s + self.retry_backoff_s)
            }
            _ => {
                self.lost += 1;
                LossOutcome::Lost
            }
        }
    }

    /// Give up on `idx` entirely: its deadline has passed, so a retry
    /// or re-dispatch can no longer help. Counts the request as lost
    /// (exactly once — a no-op if a copy already completed or the
    /// request was already abandoned) and marks it done so straggler
    /// copies resolve as absorbed/wasted.
    pub fn abandon(&mut self, idx: usize) {
        let r = &mut self.req[idx];
        if !r.done {
            r.done = true;
            self.lost += 1;
        }
    }

    /// The losing sibling of a hedged request was cancelled on the
    /// winner's completion (`hedge_cancel`): one outstanding copy
    /// leaves the system and only its partially accrued energy counts
    /// as waste. The request stays done — the winner already recorded
    /// it — so the ledger is untouched.
    pub fn copy_cancelled(&mut self, idx: usize, energy_mwh: f64) {
        let r = &mut self.req[idx];
        r.outstanding = r.outstanding.saturating_sub(1);
        self.wasted_energy_mwh += energy_mwh;
    }

    /// One copy of `idx` completed service. Returns `true` when this
    /// copy wins (the request must be recorded); a losing hedge copy's
    /// energy is accounted as waste instead.
    pub fn copy_completed(&mut self, idx: usize, energy_mwh: f64, hedge: bool) -> bool {
        let r = &mut self.req[idx];
        r.outstanding = r.outstanding.saturating_sub(1);
        if r.done {
            self.wasted_energy_mwh += energy_mwh;
            false
        } else {
            r.done = true;
            if hedge {
                self.hedge_wins += 1;
            }
            true
        }
    }
}

/// Serialized churn summary attached to open-loop and fleet reports.
#[derive(Clone, Debug)]
pub struct ChurnReport {
    pub crashes: usize,
    pub lost: usize,
    pub retried: usize,
    pub hedged: usize,
    pub hedge_wins: usize,
    pub wasted_energy_mwh: f64,
    pub mean_time_to_detect_s: f64,
    pub mean_time_to_recover_s: f64,
    /// Final membership census across all gateways:
    /// (up, suspect, down, warming).
    pub members: (usize, usize, usize, usize),
}

impl ChurnReport {
    /// Aggregate the request-copy state with one membership view per
    /// gateway (the fleet passes one per shard).
    pub fn collect<'a>(
        state: &ChurnState,
        memberships: impl IntoIterator<Item = &'a Membership>,
    ) -> Self {
        let mut detect = (0.0, 0usize);
        let mut recover = (0.0, 0usize);
        let mut members = (0, 0, 0, 0);
        for m in memberships {
            let d = m.detect_stats();
            detect.0 += d.0;
            detect.1 += d.1;
            let r = m.recover_stats();
            recover.0 += r.0;
            recover.1 += r.1;
            let c = m.counts();
            members.0 += c.0;
            members.1 += c.1;
            members.2 += c.2;
            members.3 += c.3;
        }
        let mean = |(sum, n): (f64, usize)| {
            if n > 0 {
                sum / n as f64
            } else {
                0.0
            }
        };
        Self {
            crashes: state.crashes,
            lost: state.lost,
            retried: state.retried,
            hedged: state.hedged,
            hedge_wins: state.hedge_wins,
            wasted_energy_mwh: state.wasted_energy_mwh,
            mean_time_to_detect_s: mean(detect),
            mean_time_to_recover_s: mean(recover),
            members,
        }
    }

    /// One-line human summary shared by the `serve --churn` CLI paths.
    pub fn summary(&self) -> String {
        format!(
            "churn: {} crashes, lost {}, retried {}, hedged {} ({} wins, {:.3} mWh wasted), ttd {:.2} s, ttr {:.2} s",
            self.crashes,
            self.lost,
            self.retried,
            self.hedged,
            self.hedge_wins,
            self.wasted_energy_mwh,
            self.mean_time_to_detect_s,
            self.mean_time_to_recover_s
        )
    }

    /// Stable JSON block (field order fixed by the Json substrate's
    /// BTreeMap) — joins the golden-traced report dumps.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("crashes", Json::num(self.crashes as f64)),
            ("lost", Json::num(self.lost as f64)),
            ("retried", Json::num(self.retried as f64)),
            ("hedged", Json::num(self.hedged as f64)),
            ("hedge_wins", Json::num(self.hedge_wins as f64)),
            (
                "wasted_energy_mwh",
                Json::num(self.wasted_energy_mwh),
            ),
            (
                "mean_time_to_detect_s",
                Json::num(self.mean_time_to_detect_s),
            ),
            (
                "mean_time_to_recover_s",
                Json::num(self.mean_time_to_recover_s),
            ),
            ("members_up", Json::num(self.members.0 as f64)),
            ("members_suspect", Json::num(self.members.1 as f64)),
            ("members_down", Json::num(self.members.2 as f64)),
            ("members_warming", Json::num(self.members.3 as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::router::PairKey;

    fn pair(i: usize) -> PairKey {
        PairKey::new("m", &format!("d{i}"))
    }

    /// Table over pairs d0..dn (ids 0..n in that order).
    fn table(n: usize) -> Arc<PairTable> {
        PairTable::from_keys((0..n).map(pair).collect())
    }

    #[test]
    fn policy_parse_round_trips_labels() {
        for (s, p) in [
            ("drop", ResiliencePolicy::Drop),
            ("retry", ResiliencePolicy::Retry { budget: 3 }),
            ("hedge", ResiliencePolicy::Hedge),
        ] {
            assert_eq!(ResiliencePolicy::parse(s, 3), Some(p));
            assert_eq!(p.label(), s);
        }
        assert_eq!(ResiliencePolicy::parse("HEDGE", 0), Some(ResiliencePolicy::Hedge));
        assert_eq!(ResiliencePolicy::parse("wat", 3), None);
    }

    #[test]
    fn availability_maps_to_mtbf() {
        assert!(mtbf_for_availability(1.0, 4.0).is_infinite());
        // 80% availability with mttr 4 => mtbf 16 (16 / 20 = 0.8)
        assert!((mtbf_for_availability(0.8, 4.0) - 16.0).abs() < 1e-9);
        assert!((mtbf_for_availability(0.5, 2.0) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn failure_schedule_is_deterministic_sorted_and_alternating() {
        let cfg = ChurnConfig {
            mtbf_s: 2.0,
            mttr_s: 1.0,
            seed: 5,
            ..Default::default()
        };
        let a = failure_schedule(4, 50.0, &cfg);
        let b = failure_schedule(4, 50.0, &cfg);
        assert_eq!(a, b);
        assert!(!a.is_empty());
        assert!(a.windows(2).all(|w| w[0].t <= w[1].t));
        // per node: strictly alternating starting with a crash
        for node in 0..4 {
            let evs: Vec<&FailureEvent> =
                a.iter().filter(|e| e.node == node).collect();
            for (i, e) in evs.iter().enumerate() {
                assert_eq!(e.up, i % 2 == 1, "node {node} event {i}");
            }
        }
        // different seed, different timeline
        let c = failure_schedule(
            4,
            50.0,
            &ChurnConfig { seed: 6, ..cfg.clone() },
        );
        assert_ne!(a, c);
        // no-churn baselines produce no events
        let inf = ChurnConfig { mtbf_s: f64::INFINITY, ..cfg };
        assert!(failure_schedule(4, 50.0, &inf).is_empty());
    }

    #[test]
    fn membership_detects_suspects_then_down_then_warms_back() {
        let cfg = ChurnConfig {
            suspect_after: 2,
            warmup_s: 2.0,
            warmup_penalty: 0.5,
            ..Default::default()
        };
        let t = table(1);
        let p = t.id_of(&pair(0)).unwrap();
        let mut m = Membership::new(&t, &cfg);
        assert_eq!(m.state(p), Some(MemberState::Up));
        assert!(m.believed_up(p));

        m.ground_truth_changed(p, false, 1.0); // crash (accounting only)
        assert!(m.believed_up(p), "probes have not noticed yet");

        m.observe_probe(p, false, 1.5);
        assert_eq!(m.state(p), Some(MemberState::Suspect));
        assert!(m.believed_up(p), "suspect still takes traffic");

        m.observe_probe(p, false, 2.0);
        assert_eq!(m.state(p), Some(MemberState::Down));
        assert!(!m.believed_up(p));
        assert_eq!(m.detect_stats(), (1.0, 1)); // 2.0 - 1.0

        m.ground_truth_changed(p, true, 2.5); // rejoin
        m.observe_probe(p, true, 3.0);
        assert_eq!(m.state(p), Some(MemberState::Warming));
        assert!(m.believed_up(p));
        assert_eq!(m.recover_stats(), (0.5, 1)); // 3.0 - 2.5

        // warm-up multiplier decays linearly to 1.0 at warmup_until=5.0
        assert!((m.cost_multiplier(p, 3.0) - 1.5).abs() < 1e-9);
        assert!((m.cost_multiplier(p, 4.0) - 1.25).abs() < 1e-9);
        assert!((m.cost_multiplier(p, 5.0) - 1.0).abs() < 1e-9);

        // still warming before the window closes, up after
        m.observe_probe(p, true, 4.0);
        assert_eq!(m.state(p), Some(MemberState::Warming));
        m.observe_probe(p, true, 5.5);
        assert_eq!(m.state(p), Some(MemberState::Up));
        assert_eq!(m.counts(), (1, 0, 0, 0));
    }

    #[test]
    fn membership_false_alarm_recovers_and_dispatch_failure_counts() {
        let cfg = ChurnConfig { suspect_after: 2, ..Default::default() };
        let t = table(1);
        let p = t.id_of(&pair(0)).unwrap();
        let mut m = Membership::new(&t, &cfg);
        // one miss then a response: back to Up, miss counter reset
        m.observe_probe(p, false, 1.0);
        assert_eq!(m.state(p), Some(MemberState::Suspect));
        m.observe_probe(p, true, 1.5);
        assert_eq!(m.state(p), Some(MemberState::Up));
        // dispatch failures count like missed probes
        m.observe_dispatch_failure(p, 2.0);
        m.observe_dispatch_failure(p, 2.1);
        assert_eq!(m.state(p), Some(MemberState::Down));
        // ids outside the table are never routable and never panic
        let ghost = PairId(9);
        assert!(!m.believed_up(ghost));
        m.observe_probe(ghost, false, 3.0);
        assert_eq!(m.cost_multiplier(ghost, 3.0), 1.0);
    }

    #[test]
    fn powered_down_is_sticky_and_exits_through_warming() {
        let cfg = ChurnConfig {
            suspect_after: 2,
            warmup_s: 2.0,
            warmup_penalty: 0.5,
            ..Default::default()
        };
        let t = table(2);
        let p = t.id_of(&pair(0)).unwrap();
        let mut m = Membership::new(&t, &cfg);

        m.power_down(p);
        assert_eq!(m.state(p), Some(MemberState::PoweredDown));
        assert!(!m.believed_up(p));
        // folded into the down bucket: report shape is scaler-agnostic
        assert_eq!(m.counts(), (1, 0, 1, 0));

        // probes cannot resurrect (or double-kill) a powered-down node
        m.observe_probe(p, true, 1.0);
        assert_eq!(m.state(p), Some(MemberState::PoweredDown));
        m.observe_probe(p, false, 1.5);
        m.observe_probe(p, false, 2.0);
        assert_eq!(m.state(p), Some(MemberState::PoweredDown));

        // power-up re-enters through Warming with aged costs
        m.power_up(p, 4.0);
        assert_eq!(m.state(p), Some(MemberState::Warming));
        assert!(m.believed_up(p));
        assert!((m.cost_multiplier(p, 4.0) - 1.5).abs() < 1e-9);
        assert!((m.cost_multiplier(p, 6.0) - 1.0).abs() < 1e-9);
        m.observe_probe(p, true, 6.5);
        assert_eq!(m.state(p), Some(MemberState::Up));

        // power_up on a node that was not powered down is a no-op
        let q = t.id_of(&pair(1)).unwrap();
        m.power_up(q, 1.0);
        assert_eq!(m.state(q), Some(MemberState::Up));
        // and out-of-table ids never panic
        m.power_down(PairId(9));
        m.power_up(PairId(9), 1.0);
    }

    #[test]
    fn truth_down_tracks_ground_truth_across_power_state() {
        let cfg = ChurnConfig::default();
        let t = table(1);
        let p = t.id_of(&pair(0)).unwrap();
        let mut m = Membership::new(&t, &cfg);
        assert!(!m.truth_down(p));
        // a crash landing on a powered-down node still marks ground
        // truth, so a later scaler power-up cannot resurrect it
        m.power_down(p);
        m.ground_truth_changed(p, false, 1.0);
        assert!(m.truth_down(p));
        assert_eq!(m.state(p), Some(MemberState::PoweredDown));
        m.power_up(p, 2.0);
        assert!(m.truth_down(p), "power_up must not clear ground truth");
        assert_eq!(m.state(p), Some(MemberState::Warming));
        // the pending repair clears it
        m.ground_truth_changed(p, true, 3.0);
        assert!(!m.truth_down(p));
        // unknown ids never panic
        assert!(!m.truth_down(PairId(9)));
    }

    #[test]
    fn churn_state_hedge_cancellation_charges_partial_waste() {
        let mut s = ChurnState::new(1, ResiliencePolicy::Hedge, 0.1);
        s.dispatched(0);
        s.hedge_dispatched(0);
        // primary wins; the sibling is cancelled mid-serve having
        // accrued 0.1 of its 0.4 mWh
        assert!(s.copy_completed(0, 0.3, false));
        s.copy_cancelled(0, 0.1);
        assert_eq!(s.lost, 0);
        assert_eq!(s.hedge_wins, 0);
        assert!((s.wasted_energy_mwh - 0.1).abs() < 1e-12);
        // the request resolved: a straggler loss event is absorbed
        assert_eq!(s.copy_lost(0, 2.0), LossOutcome::Absorbed);
    }

    #[test]
    fn churn_state_drop_retry_and_budget_exhaustion() {
        // drop: a lone lost copy is lost immediately
        let mut s = ChurnState::new(2, ResiliencePolicy::Drop, 0.1);
        s.dispatched(0);
        assert_eq!(s.copy_lost(0, 1.0), LossOutcome::Lost);
        assert_eq!(s.lost, 1);

        // retry: budget 2 => two RetryAt outcomes, then lost
        let mut s =
            ChurnState::new(1, ResiliencePolicy::Retry { budget: 2 }, 0.5);
        s.dispatched(0);
        assert_eq!(s.copy_lost(0, 1.0), LossOutcome::RetryAt(1.5));
        s.retry_dispatched(0);
        assert_eq!(s.copy_lost(0, 2.0), LossOutcome::RetryAt(2.5));
        s.retry_dispatched(0);
        assert_eq!(s.copy_lost(0, 3.0), LossOutcome::Lost);
        assert_eq!((s.retried, s.lost), (2, 1));

        // placement failure consumes the same budget
        let mut s =
            ChurnState::new(1, ResiliencePolicy::Retry { budget: 1 }, 0.5);
        assert_eq!(s.placement_failed(0, 1.0), LossOutcome::RetryAt(1.5));
        assert_eq!(s.placement_failed(0, 2.0), LossOutcome::Lost);
    }

    #[test]
    fn churn_state_hedge_sibling_and_waste_accounting() {
        let mut s = ChurnState::new(1, ResiliencePolicy::Hedge, 0.1);
        s.dispatched(0);
        s.hedge_dispatched(0);
        assert_eq!(s.hedged, 1);
        // losing one copy is absorbed by the sibling
        assert_eq!(s.copy_lost(0, 1.0), LossOutcome::Absorbed);
        // the surviving hedge copy wins
        assert!(s.copy_completed(0, 0.5, true));
        assert_eq!(s.hedge_wins, 1);
        assert_eq!(s.lost, 0);

        // both copies completing: second is waste
        let mut s = ChurnState::new(1, ResiliencePolicy::Hedge, 0.1);
        s.dispatched(0);
        s.hedge_dispatched(0);
        assert!(s.copy_completed(0, 0.3, false));
        assert!(!s.copy_completed(0, 0.4, true));
        assert_eq!(s.hedge_wins, 0);
        assert!((s.wasted_energy_mwh - 0.4).abs() < 1e-12);

        // both copies crashing loses the request (hedge never retries)
        let mut s = ChurnState::new(1, ResiliencePolicy::Hedge, 0.1);
        s.dispatched(0);
        s.hedge_dispatched(0);
        assert_eq!(s.copy_lost(0, 1.0), LossOutcome::Absorbed);
        assert_eq!(s.copy_lost(0, 1.1), LossOutcome::Lost);
        assert_eq!(s.lost, 1);
    }

    #[test]
    fn churn_report_aggregates_memberships() {
        let cfg = ChurnConfig::default();
        // two shard-local tables, as the fleet builds them
        let t1 = PairTable::from_keys(vec![pair(0), pair(1)]);
        let t2 = PairTable::from_keys(vec![pair(2)]);
        let p0 = t1.id_of(&pair(0)).unwrap();
        let mut m1 = Membership::new(&t1, &cfg);
        let m2 = Membership::new(&t2, &cfg);
        m1.ground_truth_changed(p0, false, 1.0);
        m1.observe_probe(p0, false, 2.0);
        m1.observe_probe(p0, false, 3.0);
        let state = ChurnState::new(4, ResiliencePolicy::Drop, 0.1);
        let r = ChurnReport::collect(&state, [&m1, &m2]);
        assert_eq!(r.members, (2, 0, 1, 0));
        assert!((r.mean_time_to_detect_s - 2.0).abs() < 1e-9);
        let j = r.to_json();
        assert_eq!(j.req("members_down").unwrap().as_usize(), Some(1));
        assert_eq!(j.req("crashes").unwrap().as_usize(), Some(0));
    }
}
