//! Axis-aligned bounding boxes and IoU.

/// Axis-aligned box in native-resolution pixel coordinates.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BBox {
    pub x0: f64,
    pub y0: f64,
    pub x1: f64,
    pub y1: f64,
}

impl BBox {
    pub fn new(x0: f64, y0: f64, x1: f64, y1: f64) -> Self {
        Self { x0, y0, x1, y1 }
    }

    pub fn from_center(cx: f64, cy: f64, rx: f64, ry: f64) -> Self {
        Self {
            x0: cx - rx,
            y0: cy - ry,
            x1: cx + rx,
            y1: cy + ry,
        }
    }

    pub fn area(&self) -> f64 {
        (self.x1 - self.x0).max(0.0) * (self.y1 - self.y0).max(0.0)
    }

    pub fn center(&self) -> (f64, f64) {
        ((self.x0 + self.x1) / 2.0, (self.y0 + self.y1) / 2.0)
    }

    pub fn intersection_area(&self, other: &BBox) -> f64 {
        let ix = (self.x1.min(other.x1) - self.x0.max(other.x0)).max(0.0);
        let iy = (self.y1.min(other.y1) - self.y0.max(other.y0)).max(0.0);
        ix * iy
    }
}

/// Intersection-over-union; 0.0 when the union is empty.
pub fn iou(a: &BBox, b: &BBox) -> f64 {
    let inter = a.intersection_area(b);
    let union = a.area() + b.area() - inter;
    if union > 0.0 {
        inter / union
    } else {
        0.0
    }
}

impl From<&crate::dataset::GtBox> for BBox {
    fn from(g: &crate::dataset::GtBox) -> Self {
        BBox::new(g.x0, g.y0, g.x1, g.y1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall_ok;
    use crate::util::rng::Rng;

    fn random_box(r: &mut Rng) -> BBox {
        let x0 = r.range(0.0, 300.0);
        let y0 = r.range(0.0, 300.0);
        BBox::new(x0, y0, x0 + r.range(1.0, 80.0), y0 + r.range(1.0, 80.0))
    }

    #[test]
    fn identical_boxes_iou_one() {
        let b = BBox::new(10.0, 10.0, 50.0, 40.0);
        assert!((iou(&b, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn disjoint_boxes_iou_zero() {
        let a = BBox::new(0.0, 0.0, 10.0, 10.0);
        let b = BBox::new(20.0, 20.0, 30.0, 30.0);
        assert_eq!(iou(&a, &b), 0.0);
    }

    #[test]
    fn half_overlap_known_value() {
        let a = BBox::new(0.0, 0.0, 10.0, 10.0);
        let b = BBox::new(5.0, 0.0, 15.0, 10.0);
        // inter 50, union 150
        assert!((iou(&a, &b) - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn prop_iou_symmetric_and_bounded() {
        forall_ok(
            21,
            200,
            |r| (random_box(r), random_box(r)),
            |(a, b)| {
                let ab = iou(a, b);
                let ba = iou(b, a);
                if (ab - ba).abs() > 1e-12 {
                    return Err(format!("asymmetric {ab} {ba}"));
                }
                if !(0.0..=1.0).contains(&ab) {
                    return Err(format!("out of bounds {ab}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_iou_one_iff_equal_for_nested() {
        forall_ok(
            22,
            100,
            |r| random_box(r),
            |b| {
                let shrunk = BBox::new(
                    b.x0 + 0.5,
                    b.y0 + 0.5,
                    b.x1,
                    b.y1,
                );
                if iou(b, &shrunk) >= 1.0 {
                    return Err("shrunk box iou must be < 1".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn from_center_roundtrip() {
        let b = BBox::from_center(100.0, 50.0, 20.0, 10.0);
        assert_eq!(b, BBox::new(80.0, 40.0, 120.0, 60.0));
        assert_eq!(b.center(), (100.0, 50.0));
    }
}
