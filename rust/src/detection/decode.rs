//! Heat-map decoding: model output [2, K, R, R] → detections.
//!
//! Mirrors `python/compile/calibrate.py::decode` (the build-time
//! calibration tool): threshold the sparse local-max heat map, turn each
//! peak (class, band, y, x) into a box using the manifest's per-band
//! radii, then greedy center-distance NMS across bands and classes — a
//! blob responds in 2–3 adjacent bands and casts an opposite-class ring;
//! both fall inside the winner's radius, while true neighbours are
//! separated by the scene placement law.

use super::bbox::BBox;
use crate::models::ModelMeta;

/// One decoded detection.
#[derive(Clone, Copy, Debug)]
pub struct Detection {
    pub bbox: BBox,
    pub score: f32,
    pub cls: usize,
}

/// Suppression factor: a candidate whose center lies within
/// `NMS_RADIUS_FACTOR * max(r_kept, r_cand)` of a kept center is dropped.
const NMS_RADIUS_FACTOR: f64 = 0.9;

/// Decode a detector heat map. `threshold_scale` models deployment
/// framework effects (e.g. int8 quantization on the Coral TPU raises the
/// effective decode threshold; see `devices`).
pub fn decode_heatmap(
    heat: &[f32],
    meta: &ModelMeta,
    threshold_scale: f64,
) -> Vec<Detection> {
    let (k, res, f) = (meta.k, meta.res, meta.factor as f64);
    debug_assert_eq!(heat.len(), 2 * k * res * res);
    let thr = (meta.threshold * threshold_scale) as f32;

    let mut cands: Vec<Detection> = Vec::new();
    let plane = res * res;
    for cls in 0..2 {
        for band in 0..k {
            let radius = meta.band_radii_native[band];
            let base = (cls * k + band) * plane;
            let slab = &heat[base..base + plane];
            for (i, &v) in slab.iter().enumerate() {
                if v > thr {
                    let y = (i / res) as f64;
                    let x = (i % res) as f64;
                    let cx = (x + 0.5) * f;
                    let cy = (y + 0.5) * f;
                    cands.push(Detection {
                        bbox: BBox::from_center(cx, cy, radius, radius),
                        score: v,
                        cls,
                    });
                }
            }
        }
    }
    nms_center_distance(cands)
}

/// Greedy center-distance NMS (score-descending).
pub fn nms_center_distance(mut cands: Vec<Detection>) -> Vec<Detection> {
    cands.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
    let mut kept: Vec<Detection> = Vec::new();
    'cand: for d in cands {
        let (cx, cy) = d.bbox.center();
        let r = (d.bbox.x1 - d.bbox.x0) / 2.0;
        for kpt in &kept {
            let (kx, ky) = kpt.bbox.center();
            let kr = (kpt.bbox.x1 - kpt.bbox.x0) / 2.0;
            let lim = NMS_RADIUS_FACTOR * r.max(kr);
            let (dx, dy) = (cx - kx, cy - ky);
            if dx * dx + dy * dy < lim * lim {
                continue 'cand;
            }
        }
        kept.push(d);
    }
    kept
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{ModelKind, ModelMeta};
    use std::path::PathBuf;

    fn test_meta(k: usize, res: usize, factor: usize) -> ModelMeta {
        ModelMeta {
            name: "test".into(),
            kind: ModelKind::Detector,
            file: PathBuf::new(),
            input_shape: vec![res * factor, res * factor],
            output_shape: vec![2, k, res, res],
            flops: 1.0,
            res,
            factor,
            k,
            sigmas: (0..=k).map(|i| 1.5 * 1.6f64.powi(i as i32)).collect(),
            band_radii_native: (0..k)
                .map(|i| 4.0 * 1.6f64.powi(i as i32))
                .collect(),
            threshold: 0.03,
            canny_lo: 0.0,
            canny_hi: 0.0,
        }
    }

    #[test]
    fn empty_heat_no_detections() {
        let meta = test_meta(3, 16, 4);
        let heat = vec![0.0f32; 2 * 3 * 16 * 16];
        assert!(decode_heatmap(&heat, &meta, 1.0).is_empty());
    }

    #[test]
    fn single_peak_decodes_to_expected_box() {
        let meta = test_meta(3, 16, 4);
        let mut heat = vec![0.0f32; 2 * 3 * 16 * 16];
        // class 1, band 2, y=8, x=4
        let idx = ((1 * 3 + 2) * 16 + 8) * 16 + 4;
        heat[idx] = 0.2;
        let dets = decode_heatmap(&heat, &meta, 1.0);
        assert_eq!(dets.len(), 1);
        let d = dets[0];
        assert_eq!(d.cls, 1);
        assert!((d.score - 0.2).abs() < 1e-6);
        let (cx, cy) = d.bbox.center();
        assert_eq!((cx, cy), (4.5 * 4.0, 8.5 * 4.0));
        let r = meta.band_radii_native[2];
        assert!(((d.bbox.x1 - d.bbox.x0) / 2.0 - r).abs() < 1e-9);
    }

    #[test]
    fn subthreshold_peak_ignored_and_scale_respected() {
        let meta = test_meta(2, 8, 4);
        let mut heat = vec![0.0f32; 2 * 2 * 8 * 8];
        heat[5] = 0.035;
        assert_eq!(decode_heatmap(&heat, &meta, 1.0).len(), 1);
        // a framework threshold scale of 1.3 pushes it below threshold
        assert_eq!(decode_heatmap(&heat, &meta, 1.3).len(), 0);
    }

    #[test]
    fn nms_suppresses_cross_band_duplicates() {
        let meta = test_meta(3, 16, 4);
        let mut heat = vec![0.0f32; 2 * 3 * 16 * 16];
        let plane = 16 * 16;
        // same spatial location in band 0 (weak) and band 1 (strong)
        heat[0 * plane + 8 * 16 + 8] = 0.1;
        heat[1 * plane + 8 * 16 + 8] = 0.3;
        let dets = decode_heatmap(&heat, &meta, 1.0);
        assert_eq!(dets.len(), 1);
        assert!((dets[0].score - 0.3).abs() < 1e-6);
    }

    #[test]
    fn nms_keeps_separated_objects() {
        let meta = test_meta(3, 32, 4);
        let mut heat = vec![0.0f32; 2 * 3 * 32 * 32];
        let plane = 32 * 32;
        heat[0 * plane + 4 * 32 + 4] = 0.2; // (18, 18) native
        heat[0 * plane + 28 * 32 + 28] = 0.25; // (114, 114) native
        let dets = decode_heatmap(&heat, &meta, 1.0);
        assert_eq!(dets.len(), 2);
    }

    #[test]
    fn nms_idempotent() {
        let meta = test_meta(3, 16, 4);
        let mut heat = vec![0.0f32; 2 * 3 * 16 * 16];
        for i in [5, 40, 300, 700, 1400] {
            heat[i] = 0.1 + i as f32 * 1e-4;
        }
        let once = decode_heatmap(&heat, &meta, 1.0);
        let twice = nms_center_distance(once.clone());
        assert_eq!(once.len(), twice.len());
    }
}
