//! Detection substrate: boxes + IoU, heat-map decoding (model output →
//! detections), and a COCO-style mAP evaluator (the paper's FiftyOne
//! substitute).

pub mod bbox;
pub mod decode;
pub mod map;

pub use bbox::{iou, BBox};
pub use decode::{decode_heatmap, Detection};
pub use map::{map_coco, MapResult};
