//! COCO-style mAP evaluator (the paper's FiftyOne substitute).
//!
//! AP per (class, IoU threshold) via greedy score-descending matching and
//! 101-point interpolated precision–recall integration; mAP averages over
//! IoU thresholds 0.50:0.05:0.95 and over classes that have ground truth.
//! Reported on the 0–100 scale like the paper.

use super::bbox::{iou, BBox};
use super::decode::Detection;
use crate::dataset::GtBox;

/// IoU thresholds 0.50:0.05:0.95 (COCO primary metric).
pub const IOU_THRESHOLDS: [f64; 10] =
    [0.50, 0.55, 0.60, 0.65, 0.70, 0.75, 0.80, 0.85, 0.90, 0.95];

/// Predictions and ground truth for one image.
#[derive(Clone, Debug, Default)]
pub struct ImageEval {
    pub dets: Vec<Detection>,
    pub gt: Vec<GtBox>,
}

#[derive(Clone, Debug)]
pub struct MapResult {
    /// mAP@[.50:.95] on the 0–100 scale.
    pub map: f64,
    /// mAP@0.50 only.
    pub map50: f64,
    /// per-class AP@[.50:.95] (classes without GT are None).
    pub per_class: Vec<Option<f64>>,
}

/// Evaluate mAP over a set of images.
///
/// Images with no ground truth contribute their false positives to the
/// precision denominator (standard COCO behaviour). If *no* image has
/// ground truth, returns the empty-set convention score: 100 if there are
/// no detections either, else 0 (used for the paper's group-'0' slice).
pub fn map_coco(images: &[ImageEval], num_classes: usize) -> MapResult {
    let any_gt = images.iter().any(|im| !im.gt.is_empty());
    if !any_gt {
        let any_det = images.iter().any(|im| !im.dets.is_empty());
        let score = if any_det { 0.0 } else { 100.0 };
        return MapResult {
            map: score,
            map50: score,
            per_class: vec![None; num_classes],
        };
    }

    let mut per_class: Vec<Option<f64>> = Vec::with_capacity(num_classes);
    let mut per_class50: Vec<Option<f64>> = Vec::with_capacity(num_classes);
    for cls in 0..num_classes {
        let has_gt = images
            .iter()
            .any(|im| im.gt.iter().any(|g| g.cls == cls));
        if !has_gt {
            per_class.push(None);
            per_class50.push(None);
            continue;
        }
        let mut aps = Vec::with_capacity(IOU_THRESHOLDS.len());
        for &thr in &IOU_THRESHOLDS {
            aps.push(ap_single(images, cls, thr));
        }
        per_class50.push(Some(aps[0]));
        per_class
            .push(Some(aps.iter().sum::<f64>() / aps.len() as f64));
    }

    let avg = |v: &[Option<f64>]| {
        let present: Vec<f64> = v.iter().filter_map(|x| *x).collect();
        if present.is_empty() {
            0.0
        } else {
            present.iter().sum::<f64>() / present.len() as f64
        }
    };
    MapResult {
        map: 100.0 * avg(&per_class),
        map50: 100.0 * avg(&per_class50),
        per_class: per_class
            .iter()
            .map(|x| x.map(|v| 100.0 * v))
            .collect(),
    }
}

/// AP for one class at one IoU threshold (0–1 scale).
fn ap_single(images: &[ImageEval], cls: usize, iou_thr: f64) -> f64 {
    // gather (score, image_idx, bbox) for this class
    let mut dets: Vec<(f32, usize, BBox)> = Vec::new();
    let mut total_gt = 0usize;
    for (i, im) in images.iter().enumerate() {
        total_gt += im.gt.iter().filter(|g| g.cls == cls).count();
        for d in im.dets.iter().filter(|d| d.cls == cls) {
            dets.push((d.score, i, d.bbox));
        }
    }
    if total_gt == 0 {
        return 0.0;
    }
    dets.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

    // greedy matching: each GT may be matched once per threshold pass
    let mut matched: Vec<Vec<bool>> = images
        .iter()
        .map(|im| vec![false; im.gt.len()])
        .collect();
    let mut tp = vec![false; dets.len()];
    for (di, &(_, img_idx, ref bb)) in dets.iter().enumerate() {
        let im = &images[img_idx];
        let mut best = 0.0;
        let mut best_gi = usize::MAX;
        for (gi, g) in im.gt.iter().enumerate() {
            if g.cls != cls || matched[img_idx][gi] {
                continue;
            }
            let v = iou(bb, &BBox::from(g));
            if v > best {
                best = v;
                best_gi = gi;
            }
        }
        if best >= iou_thr && best_gi != usize::MAX {
            matched[img_idx][best_gi] = true;
            tp[di] = true;
        }
    }

    // precision-recall curve + 101-point interpolation
    let mut cum_tp = 0usize;
    let mut precisions = Vec::with_capacity(dets.len());
    let mut recalls = Vec::with_capacity(dets.len());
    for (i, &is_tp) in tp.iter().enumerate() {
        if is_tp {
            cum_tp += 1;
        }
        precisions.push(cum_tp as f64 / (i + 1) as f64);
        recalls.push(cum_tp as f64 / total_gt as f64);
    }
    // make precision monotone non-increasing from the right
    for i in (0..precisions.len().saturating_sub(1)).rev() {
        if precisions[i] < precisions[i + 1] {
            precisions[i] = precisions[i + 1];
        }
    }
    let mut ap = 0.0;
    let mut det_i = 0usize;
    for r in 0..=100 {
        let r = r as f64 / 100.0;
        while det_i < recalls.len() && recalls[det_i] < r {
            det_i += 1;
        }
        if det_i < precisions.len() {
            ap += precisions[det_i];
        }
    }
    ap / 101.0
}

/// Paper group-'0' helper: share of images with zero detections, 0–100.
pub fn empty_image_score(images: &[ImageEval]) -> f64 {
    if images.is_empty() {
        return 100.0;
    }
    let clean = images.iter().filter(|im| im.dets.is_empty()).count();
    100.0 * clean as f64 / images.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall_ok;
    use crate::util::rng::Rng;

    fn det(x: f64, y: f64, r: f64, score: f32, cls: usize) -> Detection {
        Detection {
            bbox: BBox::from_center(x, y, r, r),
            score,
            cls,
        }
    }

    fn gt(x: f64, y: f64, r: f64, cls: usize) -> GtBox {
        GtBox {
            x0: x - r,
            y0: y - r,
            x1: x + r,
            y1: y + r,
            cls,
        }
    }

    #[test]
    fn perfect_predictions_score_100() {
        let images = vec![ImageEval {
            dets: vec![det(50.0, 50.0, 10.0, 0.9, 0), det(150.0, 150.0, 20.0, 0.8, 1)],
            gt: vec![gt(50.0, 50.0, 10.0, 0), gt(150.0, 150.0, 20.0, 1)],
        }];
        let r = map_coco(&images, 2);
        assert!((r.map - 100.0).abs() < 1e-9, "map={}", r.map);
        assert!((r.map50 - 100.0).abs() < 1e-9);
    }

    #[test]
    fn no_predictions_score_0_with_gt() {
        let images = vec![ImageEval {
            dets: vec![],
            gt: vec![gt(50.0, 50.0, 10.0, 0)],
        }];
        assert_eq!(map_coco(&images, 2).map, 0.0);
    }

    #[test]
    fn empty_everything_scores_100() {
        let images = vec![ImageEval::default()];
        assert_eq!(map_coco(&images, 2).map, 100.0);
        // false positives on empty images score 0
        let images = vec![ImageEval {
            dets: vec![det(10.0, 10.0, 5.0, 0.5, 0)],
            gt: vec![],
        }];
        assert_eq!(map_coco(&images, 2).map, 0.0);
    }

    #[test]
    fn localization_error_reduces_map_not_map50() {
        let exact = vec![ImageEval {
            dets: vec![det(50.0, 50.0, 10.0, 0.9, 0)],
            gt: vec![gt(50.0, 50.0, 10.0, 0)],
        }];
        // shifted by 4px: IoU ~0.67 -> passes 0.5/0.65, fails higher
        let shifted = vec![ImageEval {
            dets: vec![det(54.0, 50.0, 10.0, 0.9, 0)],
            gt: vec![gt(50.0, 50.0, 10.0, 0)],
        }];
        let re = map_coco(&exact, 2);
        let rs = map_coco(&shifted, 2);
        assert!((rs.map50 - 100.0).abs() < 1e-9);
        assert!(rs.map < re.map);
    }

    #[test]
    fn false_positive_lowers_precision() {
        let clean = vec![ImageEval {
            dets: vec![det(50.0, 50.0, 10.0, 0.9, 0)],
            gt: vec![gt(50.0, 50.0, 10.0, 0)],
        }];
        // extra high-scoring FP ranked first
        let noisy = vec![ImageEval {
            dets: vec![
                det(300.0, 300.0, 10.0, 0.95, 0),
                det(50.0, 50.0, 10.0, 0.9, 0),
            ],
            gt: vec![gt(50.0, 50.0, 10.0, 0)],
        }];
        assert!(map_coco(&noisy, 2).map < map_coco(&clean, 2).map);
    }

    #[test]
    fn low_scored_fp_hurts_less_than_high_scored_fp() {
        let gt_img = |fp_score: f32| {
            vec![ImageEval {
                dets: vec![
                    det(300.0, 300.0, 10.0, fp_score, 0),
                    det(50.0, 50.0, 10.0, 0.9, 0),
                ],
                gt: vec![gt(50.0, 50.0, 10.0, 0)],
            }]
        };
        let low = map_coco(&gt_img(0.1), 2).map;
        let high = map_coco(&gt_img(0.99), 2).map;
        assert!(low > high);
    }

    #[test]
    fn duplicate_detection_is_fp() {
        // a duplicate ranked between two true positives drags down the
        // precision reached at full recall (COCO semantics: a second
        // match to an already-matched GT is a false positive).
        let with_dup = vec![ImageEval {
            dets: vec![
                det(50.0, 50.0, 10.0, 0.9, 0),
                det(50.0, 50.0, 10.0, 0.8, 0), // duplicate -> FP
                det(150.0, 150.0, 10.0, 0.7, 0),
            ],
            gt: vec![gt(50.0, 50.0, 10.0, 0), gt(150.0, 150.0, 10.0, 0)],
        }];
        let without = vec![ImageEval {
            dets: vec![
                det(50.0, 50.0, 10.0, 0.9, 0),
                det(150.0, 150.0, 10.0, 0.7, 0),
            ],
            gt: vec![gt(50.0, 50.0, 10.0, 0), gt(150.0, 150.0, 10.0, 0)],
        }];
        let r_dup = map_coco(&with_dup, 2);
        let r_clean = map_coco(&without, 2);
        assert!((r_clean.map - 100.0).abs() < 1e-9);
        assert!(r_dup.map < r_clean.map);
    }

    #[test]
    fn class_confusion_scores_zero() {
        let images = vec![ImageEval {
            dets: vec![det(50.0, 50.0, 10.0, 0.9, 1)],
            gt: vec![gt(50.0, 50.0, 10.0, 0)],
        }];
        assert_eq!(map_coco(&images, 2).map, 0.0);
    }

    #[test]
    fn prop_map_bounded_and_permutation_invariant() {
        forall_ok(
            31,
            30,
            |r: &mut Rng| {
                let n_img = 1 + r.below(4) as usize;
                let mut images = Vec::new();
                for _ in 0..n_img {
                    let n_gt = r.below(4) as usize;
                    let n_det = r.below(6) as usize;
                    let gt_boxes: Vec<GtBox> = (0..n_gt)
                        .map(|_| {
                            gt(
                                r.range(30.0, 350.0),
                                r.range(30.0, 350.0),
                                r.range(5.0, 25.0),
                                r.below(2) as usize,
                            )
                        })
                        .collect();
                    let dets: Vec<Detection> = (0..n_det)
                        .map(|_| {
                            det(
                                r.range(30.0, 350.0),
                                r.range(30.0, 350.0),
                                r.range(5.0, 25.0),
                                r.f32(),
                                r.below(2) as usize,
                            )
                        })
                        .collect();
                    images.push(ImageEval {
                        dets,
                        gt: gt_boxes,
                    });
                }
                images
            },
            |images| {
                let r1 = map_coco(images, 2);
                if !(0.0..=100.0).contains(&r1.map) {
                    return Err(format!("map out of range: {}", r1.map));
                }
                if r1.map50 + 1e-9 < r1.map {
                    return Err(format!(
                        "map50 {} < map {}",
                        r1.map50, r1.map
                    ));
                }
                let mut rev: Vec<ImageEval> =
                    images.iter().rev().cloned().collect();
                // also shuffle detections within images
                for im in rev.iter_mut() {
                    im.dets.reverse();
                }
                let r2 = map_coco(&rev, 2);
                if (r1.map - r2.map).abs() > 1e-9 {
                    return Err(format!(
                        "not permutation invariant: {} vs {}",
                        r1.map, r2.map
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn empty_image_score_counts_clean_images() {
        let images = vec![
            ImageEval::default(),
            ImageEval {
                dets: vec![det(10.0, 10.0, 4.0, 0.4, 0)],
                gt: vec![],
            },
        ];
        assert_eq!(empty_image_score(&images), 50.0);
    }
}
