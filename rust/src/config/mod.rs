//! Config system: typed experiment/serving configuration with a minimal
//! TOML-subset parser (no external `toml` crate in this registry).
//!
//! Supported syntax: `[section]` headers, `key = value` with string,
//! integer/float, boolean, and flat-array values, `#` comments.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// A parsed value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str_list(&self) -> Option<Vec<String>> {
        match self {
            Value::Arr(v) => v
                .iter()
                .map(|x| x.as_str().map(|s| s.to_string()))
                .collect(),
            _ => None,
        }
    }

    pub fn as_f64_list(&self) -> Option<Vec<f64>> {
        match self {
            Value::Arr(v) => v.iter().map(|x| x.as_f64()).collect(),
            _ => None,
        }
    }
}

/// `section.key -> value` table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    entries: BTreeMap<String, Value>,
}

impl Table {
    pub fn parse(text: &str) -> Result<Table> {
        let mut t = Table::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) =
                line.strip_prefix('[').and_then(|s| s.strip_suffix(']'))
            {
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            t.entries.insert(key, parse_value(v.trim(), lineno + 1)?);
        }
        Ok(t)
    }

    pub fn load(path: &Path) -> Result<Table> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, lineno: usize) -> Result<Value> {
    if s.starts_with('"') {
        let inner = s
            .strip_prefix('"')
            .and_then(|x| x.strip_suffix('"'))
            .with_context(|| format!("line {lineno}: unterminated string"))?;
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body
            .strip_suffix(']')
            .with_context(|| format!("line {lineno}: unterminated array"))?;
        let mut items = Vec::new();
        let trimmed = body.trim();
        if !trimmed.is_empty() {
            for item in split_top_level(trimmed) {
                items.push(parse_value(item.trim(), lineno)?);
            }
        }
        return Ok(Value::Arr(items));
    }
    if let Ok(x) = s.parse::<f64>() {
        return Ok(Value::Num(x));
    }
    bail!("line {lineno}: cannot parse value '{s}'")
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

/// Typed experiment configuration with defaults matching the paper.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub artifacts_dir: String,
    /// delta_mAP tolerance (0–100 scale).
    pub delta_map: f64,
    /// Images for the full-COCO experiment.
    pub coco_images: usize,
    /// Images per group for the balanced sorted dataset.
    pub balanced_per_group: usize,
    /// Frames for the video experiment.
    pub video_frames: usize,
    /// Profiling images per group.
    pub profile_per_group: usize,
    pub seed: u64,
    pub routers: Vec<String>,
    /// Open-loop serving: Poisson arrival rate for `serve --open-loop`
    /// (req/s).
    pub rate_rps: f64,
    /// Open-loop serving: bounded per-node FIFO capacity.
    pub queue_capacity: usize,
    /// Arrival rates swept by the `openloop` saturation experiment.
    pub open_rates: Vec<f64>,
    /// Fleet sweep: synthesized fleet sizes (total nodes).
    pub fleet_sizes: Vec<usize>,
    /// Fleet sweep: gateway shard counts.
    pub fleet_shards: Vec<usize>,
    /// Fleet sweep: routers compared per cell.
    pub fleet_routers: Vec<String>,
    /// Fleet sweep: Poisson arrival rate (req/s).
    pub fleet_rate_rps: f64,
    /// Fleet sweep: offered requests per cell.
    pub fleet_requests: usize,
    /// Fleet synthesis: ± fractional perturbation of per-node
    /// throughput and power (silicon binning variation).
    pub fleet_perturb: f64,
    /// Shard dispatch policy: `hash` | `least` | `sticky`.
    pub fleet_dispatch: String,
    /// Distinct request sources (sticky-dispatch granularity).
    pub fleet_sources: usize,
    /// Worker threads for the fleet event engine (1 = the sequential
    /// shared-heap engine; >1 = per-shard heaps merged under the
    /// watermark protocol, DESIGN.md §13).
    pub fleet_threads: usize,
    /// Churn: mean time between failures per node (s) for `serve
    /// --churn`; the `churn` experiment derives MTBF from
    /// `churn_availability` instead.
    pub churn_mtbf_s: f64,
    /// Churn: mean time to repair per node (s).
    pub churn_mttr_s: f64,
    /// Churn: gateway health-probe period (s).
    pub churn_probe_interval_s: f64,
    /// Churn: probe timeout (s) before results reach the membership.
    pub churn_probe_timeout_s: f64,
    /// Churn: consecutive missed probes before Suspect becomes Down.
    pub churn_suspect_after: usize,
    /// Churn: warm-up window after an observed recovery (s).
    pub churn_warmup_s: f64,
    /// Churn: cost inflation at the start of the warm-up window.
    pub churn_warmup_penalty: f64,
    /// Churn: resilience policy: `drop` | `retry` | `hedge`.
    pub churn_policy: String,
    /// Churn: max re-dispatches per request under the retry policy.
    pub churn_retry_budget: usize,
    /// Churn: backoff before a retry re-enters routing (s).
    pub churn_retry_backoff_s: f64,
    /// Churn: cancellation-on-first-response for the hedge policy —
    /// kill the losing sibling the instant the winner completes,
    /// charging only the energy it accrued.
    pub churn_hedge_cancel: bool,
    /// Churn sweep: steady-state availability levels (1.0 = no churn).
    pub churn_availability: Vec<f64>,
    /// Churn sweep: resilience policies compared per cell.
    pub churn_policies: Vec<String>,
    /// Churn sweep: routers compared per cell (all ten by default).
    pub churn_routers: Vec<String>,
    /// Churn sweep: Poisson arrival rate (req/s).
    pub churn_rate_rps: f64,
    /// Churn sweep: offered requests per cell.
    pub churn_requests: usize,
    /// Campaign: nodes per failure domain (`serve --campaign`).
    pub campaign_domain_size: usize,
    /// Campaign: mean time between outages per domain (s); `inf`
    /// disables domain outages.
    pub campaign_domain_mtbf_s: f64,
    /// Campaign: mean domain outage duration (s).
    pub campaign_domain_mttr_s: f64,
    /// Campaign: mean time between shard-gateway kills (s); `inf`
    /// disables gateway kills (fleet mode only).
    pub campaign_gateway_mtbf_s: f64,
    /// Campaign: mean gateway outage duration (s).
    pub campaign_gateway_mttr_s: f64,
    /// Campaign sweep: synthesized fleet size (total nodes).
    pub campaign_nodes: usize,
    /// Campaign sweep: gateway shard count.
    pub campaign_shards: usize,
    /// Campaign sweep: domain fan-outs compared per cell.
    pub campaign_domain_sizes: Vec<usize>,
    /// Campaign sweep: per-domain outage rates (outages/s; the cell's
    /// `domain_mtbf_s` is the reciprocal).
    pub campaign_outage_rates: Vec<f64>,
    /// Campaign sweep: routers compared per cell.
    pub campaign_routers: Vec<String>,
    /// Campaign sweep: resilience policies compared per cell.
    pub campaign_policies: Vec<String>,
    /// Campaign sweep: Poisson arrival rate (req/s).
    pub campaign_rate_rps: f64,
    /// Campaign sweep: offered requests per cell.
    pub campaign_requests: usize,
    /// Campaign sweep: run the escalation phase (double the outage
    /// rate per step until each router's goodput collapses).
    pub campaign_escalate: bool,
    /// SLO: deadline classes as `name:deadline_s` specs, assigned
    /// round-robin by request index.
    pub slo_classes: Vec<String>,
    /// SLO: batch formation window (s); 0 disables batching while
    /// keeping admission control and EDF ordering.
    pub slo_batch_window_s: f64,
    /// SLO: hard cap on members per formed batch.
    pub slo_max_batch: usize,
    /// SLO sweep: Poisson arrival rates (req/s).
    pub slo_rate_rps: Vec<f64>,
    /// SLO sweep: batch windows compared per cell (s).
    pub slo_windows_s: Vec<f64>,
    /// SLO sweep: offered requests per cell.
    pub slo_requests: usize,
    /// SLO sweep: routers compared per cell.
    pub slo_routers: Vec<String>,
    /// Adapt: telemetry EWMA smoothing factor, in (0, 1].
    pub adapt_alpha: f64,
    /// Adapt: observations before a correction reaches full weight.
    pub adapt_confidence: usize,
    /// Adapt: correction clamp (factors stay within [1/x, x]).
    pub adapt_max_correction: f64,
    /// Adapt: 0 = continuous corrections; N > 0 = publish every N
    /// observations (periodic re-profiling mode).
    pub adapt_publish_every: usize,
    /// Adapt: enable the energy-proportional autoscaling half.
    pub adapt_scale: bool,
    /// Adapt: scaler decision period on the virtual clock (s).
    pub adapt_scale_interval_s: f64,
    /// Adapt: arrival-rate EWMA smoothing factor, in (0, 1].
    pub adapt_rate_alpha: f64,
    /// Adapt: utilization below which one node powers down per tick.
    pub adapt_down_util: f64,
    /// Adapt: utilization above which one node powers back up.
    pub adapt_up_util: f64,
    /// Adapt: floor on powered nodes.
    pub adapt_min_powered: usize,
    /// Adapt: idle draw charged per powered node (W).
    pub adapt_idle_power_w: f64,
    /// Adapt: warm-up window for powered-up nodes (s).
    pub adapt_warmup_s: f64,
    /// Adapt sweep: routers compared per cell.
    pub adapt_routers: Vec<String>,
    /// Adapt sweep: drift-intensity multipliers on the default drift
    /// model (heat rate and load-walk scale; 1.0 = default drift).
    pub adapt_drift: Vec<f64>,
    /// Adapt sweep: Poisson arrival rate (req/s).
    pub adapt_rate_rps: f64,
    /// Adapt sweep: offered requests per cell.
    pub adapt_requests: usize,
    /// Obs: virtual-time series bucket width (s).
    pub obs_tick_s: f64,
    /// Obs: spans of the first N requests are always retained.
    pub obs_span_head: usize,
    /// Obs: spans of the last N requests are always retained.
    pub obs_span_tail: usize,
    /// Obs: expected middle spans kept by the hash reservoir.
    pub obs_span_sample: usize,
    /// Obs: export directory ("" = collect without writing files).
    pub obs_out: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            artifacts_dir: String::new(),
            delta_map: 5.0,
            coco_images: 600,
            balanced_per_group: 60,
            video_frames: 300,
            profile_per_group: 40,
            seed: 7,
            routers: ["Orc", "RR", "Rnd", "LE", "LI", "HM", "HMG", "ED", "SF", "OB"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
            rate_rps: 8.0,
            queue_capacity: 8,
            open_rates: vec![2.0, 8.0, 32.0],
            fleet_sizes: vec![24, 200],
            fleet_shards: vec![2, 8],
            fleet_routers: ["LE", "HMG", "ED"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
            fleet_rate_rps: 60.0,
            fleet_requests: 120,
            fleet_perturb: 0.15,
            fleet_dispatch: "least".to_string(),
            fleet_sources: 32,
            fleet_threads: 1,
            churn_mtbf_s: 16.0,
            churn_mttr_s: 4.0,
            churn_probe_interval_s: 0.5,
            churn_probe_timeout_s: 0.2,
            churn_suspect_after: 2,
            churn_warmup_s: 3.0,
            churn_warmup_penalty: 0.5,
            churn_policy: "retry".to_string(),
            churn_retry_budget: 4,
            churn_retry_backoff_s: 0.25,
            churn_availability: vec![1.0, 0.9, 0.8],
            churn_policies: ["drop", "retry", "hedge"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
            churn_routers: ["Orc", "RR", "Rnd", "LE", "LI", "HM", "HMG", "ED", "SF", "OB"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
            churn_rate_rps: 8.0,
            churn_requests: 60,
            churn_hedge_cancel: false,
            campaign_domain_size: 4,
            campaign_domain_mtbf_s: 20.0,
            campaign_domain_mttr_s: 2.0,
            campaign_gateway_mtbf_s: f64::INFINITY,
            campaign_gateway_mttr_s: 1.0,
            campaign_nodes: 12,
            campaign_shards: 3,
            campaign_domain_sizes: vec![2, 4],
            campaign_outage_rates: vec![0.05, 0.2],
            campaign_routers: ["LE", "ED"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
            campaign_policies: ["drop", "retry", "hedge"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
            campaign_rate_rps: 60.0,
            campaign_requests: 96,
            campaign_escalate: true,
            slo_classes: [
                "interactive:0.05",
                "standard:0.25",
                "relaxed:1.0",
            ]
            .iter()
            .map(|s| s.to_string())
            .collect(),
            slo_batch_window_s: 0.004,
            slo_max_batch: 4,
            slo_rate_rps: vec![80.0, 160.0],
            slo_windows_s: vec![0.0, 0.004, 0.01],
            slo_requests: 200,
            slo_routers: ["ED", "LE"].iter().map(|s| s.to_string()).collect(),
            adapt_alpha: 0.3,
            adapt_confidence: 8,
            adapt_max_correction: 4.0,
            adapt_publish_every: 0,
            adapt_scale: true,
            adapt_scale_interval_s: 0.25,
            adapt_rate_alpha: 0.4,
            adapt_down_util: 0.35,
            adapt_up_util: 0.75,
            adapt_min_powered: 1,
            adapt_idle_power_w: 1.2,
            adapt_warmup_s: 1.0,
            adapt_routers: ["ED", "LE"].iter().map(|s| s.to_string()).collect(),
            adapt_drift: vec![1.0, 2.0],
            adapt_rate_rps: 40.0,
            adapt_requests: 160,
            obs_tick_s: 1.0,
            obs_span_head: 32,
            obs_span_tail: 32,
            obs_span_sample: 64,
            obs_out: "results/obs".to_string(),
        }
    }
}

impl ExperimentConfig {
    pub fn from_table(t: &Table) -> Self {
        let d = Self::default();
        Self {
            artifacts_dir: t.str_or("experiment.artifacts_dir", &d.artifacts_dir),
            delta_map: t.f64_or("experiment.delta_map", d.delta_map),
            coco_images: t.usize_or("experiment.coco_images", d.coco_images),
            balanced_per_group: t
                .usize_or("experiment.balanced_per_group", d.balanced_per_group),
            video_frames: t.usize_or("experiment.video_frames", d.video_frames),
            profile_per_group: t
                .usize_or("experiment.profile_per_group", d.profile_per_group),
            seed: t.f64_or("experiment.seed", d.seed as f64) as u64,
            routers: t
                .get("experiment.routers")
                .and_then(|v| v.as_str_list())
                .unwrap_or(d.routers),
            rate_rps: t.f64_or("experiment.rate_rps", d.rate_rps),
            queue_capacity: t
                .usize_or("experiment.queue_capacity", d.queue_capacity),
            open_rates: t
                .get("experiment.open_rates")
                .and_then(|v| v.as_f64_list())
                .unwrap_or(d.open_rates),
            fleet_sizes: t
                .get("experiment.fleet_sizes")
                .and_then(|v| v.as_f64_list())
                .map(|v| v.iter().map(|&x| x as usize).collect())
                .unwrap_or(d.fleet_sizes),
            fleet_shards: t
                .get("experiment.fleet_shards")
                .and_then(|v| v.as_f64_list())
                .map(|v| v.iter().map(|&x| x as usize).collect())
                .unwrap_or(d.fleet_shards),
            fleet_routers: t
                .get("experiment.fleet_routers")
                .and_then(|v| v.as_str_list())
                .unwrap_or(d.fleet_routers),
            fleet_rate_rps: t
                .f64_or("experiment.fleet_rate_rps", d.fleet_rate_rps),
            fleet_requests: t
                .usize_or("experiment.fleet_requests", d.fleet_requests),
            fleet_perturb: t
                .f64_or("experiment.fleet_perturb", d.fleet_perturb),
            fleet_dispatch: t
                .str_or("experiment.fleet_dispatch", &d.fleet_dispatch),
            fleet_sources: t
                .usize_or("experiment.fleet_sources", d.fleet_sources),
            fleet_threads: t
                .usize_or("experiment.fleet_threads", d.fleet_threads),
            churn_mtbf_s: t.f64_or("experiment.churn_mtbf_s", d.churn_mtbf_s),
            churn_mttr_s: t.f64_or("experiment.churn_mttr_s", d.churn_mttr_s),
            churn_probe_interval_s: t.f64_or(
                "experiment.churn_probe_interval_s",
                d.churn_probe_interval_s,
            ),
            churn_probe_timeout_s: t.f64_or(
                "experiment.churn_probe_timeout_s",
                d.churn_probe_timeout_s,
            ),
            churn_suspect_after: t.usize_or(
                "experiment.churn_suspect_after",
                d.churn_suspect_after,
            ),
            churn_warmup_s: t
                .f64_or("experiment.churn_warmup_s", d.churn_warmup_s),
            churn_warmup_penalty: t.f64_or(
                "experiment.churn_warmup_penalty",
                d.churn_warmup_penalty,
            ),
            churn_policy: t
                .str_or("experiment.churn_policy", &d.churn_policy),
            churn_retry_budget: t.usize_or(
                "experiment.churn_retry_budget",
                d.churn_retry_budget,
            ),
            churn_retry_backoff_s: t.f64_or(
                "experiment.churn_retry_backoff_s",
                d.churn_retry_backoff_s,
            ),
            churn_availability: t
                .get("experiment.churn_availability")
                .and_then(|v| v.as_f64_list())
                .unwrap_or(d.churn_availability),
            churn_policies: t
                .get("experiment.churn_policies")
                .and_then(|v| v.as_str_list())
                .unwrap_or(d.churn_policies),
            churn_routers: t
                .get("experiment.churn_routers")
                .and_then(|v| v.as_str_list())
                .unwrap_or(d.churn_routers),
            churn_rate_rps: t
                .f64_or("experiment.churn_rate_rps", d.churn_rate_rps),
            churn_requests: t
                .usize_or("experiment.churn_requests", d.churn_requests),
            churn_hedge_cancel: t.bool_or(
                "experiment.churn_hedge_cancel",
                d.churn_hedge_cancel,
            ),
            campaign_domain_size: t.usize_or(
                "experiment.campaign_domain_size",
                d.campaign_domain_size,
            ),
            campaign_domain_mtbf_s: t.f64_or(
                "experiment.campaign_domain_mtbf_s",
                d.campaign_domain_mtbf_s,
            ),
            campaign_domain_mttr_s: t.f64_or(
                "experiment.campaign_domain_mttr_s",
                d.campaign_domain_mttr_s,
            ),
            campaign_gateway_mtbf_s: t.f64_or(
                "experiment.campaign_gateway_mtbf_s",
                d.campaign_gateway_mtbf_s,
            ),
            campaign_gateway_mttr_s: t.f64_or(
                "experiment.campaign_gateway_mttr_s",
                d.campaign_gateway_mttr_s,
            ),
            campaign_nodes: t
                .usize_or("experiment.campaign_nodes", d.campaign_nodes),
            campaign_shards: t
                .usize_or("experiment.campaign_shards", d.campaign_shards),
            campaign_domain_sizes: t
                .get("experiment.campaign_domain_sizes")
                .and_then(|v| v.as_f64_list())
                .map(|v| v.iter().map(|&x| x as usize).collect())
                .unwrap_or(d.campaign_domain_sizes),
            campaign_outage_rates: t
                .get("experiment.campaign_outage_rates")
                .and_then(|v| v.as_f64_list())
                .unwrap_or(d.campaign_outage_rates),
            campaign_routers: t
                .get("experiment.campaign_routers")
                .and_then(|v| v.as_str_list())
                .unwrap_or(d.campaign_routers),
            campaign_policies: t
                .get("experiment.campaign_policies")
                .and_then(|v| v.as_str_list())
                .unwrap_or(d.campaign_policies),
            campaign_rate_rps: t
                .f64_or("experiment.campaign_rate_rps", d.campaign_rate_rps),
            campaign_requests: t.usize_or(
                "experiment.campaign_requests",
                d.campaign_requests,
            ),
            campaign_escalate: t.bool_or(
                "experiment.campaign_escalate",
                d.campaign_escalate,
            ),
            slo_classes: t
                .get("experiment.slo_classes")
                .and_then(|v| v.as_str_list())
                .unwrap_or(d.slo_classes),
            slo_batch_window_s: t.f64_or(
                "experiment.slo_batch_window_s",
                d.slo_batch_window_s,
            ),
            slo_max_batch: t
                .usize_or("experiment.slo_max_batch", d.slo_max_batch),
            slo_rate_rps: t
                .get("experiment.slo_rate_rps")
                .and_then(|v| v.as_f64_list())
                .unwrap_or(d.slo_rate_rps),
            slo_windows_s: t
                .get("experiment.slo_windows_s")
                .and_then(|v| v.as_f64_list())
                .unwrap_or(d.slo_windows_s),
            slo_requests: t
                .usize_or("experiment.slo_requests", d.slo_requests),
            slo_routers: t
                .get("experiment.slo_routers")
                .and_then(|v| v.as_str_list())
                .unwrap_or(d.slo_routers),
            adapt_alpha: t.f64_or("experiment.adapt_alpha", d.adapt_alpha),
            adapt_confidence: t
                .usize_or("experiment.adapt_confidence", d.adapt_confidence),
            adapt_max_correction: t.f64_or(
                "experiment.adapt_max_correction",
                d.adapt_max_correction,
            ),
            adapt_publish_every: t.usize_or(
                "experiment.adapt_publish_every",
                d.adapt_publish_every,
            ),
            adapt_scale: t.bool_or("experiment.adapt_scale", d.adapt_scale),
            adapt_scale_interval_s: t.f64_or(
                "experiment.adapt_scale_interval_s",
                d.adapt_scale_interval_s,
            ),
            adapt_rate_alpha: t
                .f64_or("experiment.adapt_rate_alpha", d.adapt_rate_alpha),
            adapt_down_util: t
                .f64_or("experiment.adapt_down_util", d.adapt_down_util),
            adapt_up_util: t
                .f64_or("experiment.adapt_up_util", d.adapt_up_util),
            adapt_min_powered: t
                .usize_or("experiment.adapt_min_powered", d.adapt_min_powered),
            adapt_idle_power_w: t
                .f64_or("experiment.adapt_idle_power_w", d.adapt_idle_power_w),
            adapt_warmup_s: t
                .f64_or("experiment.adapt_warmup_s", d.adapt_warmup_s),
            adapt_routers: t
                .get("experiment.adapt_routers")
                .and_then(|v| v.as_str_list())
                .unwrap_or(d.adapt_routers),
            adapt_drift: t
                .get("experiment.adapt_drift")
                .and_then(|v| v.as_f64_list())
                .unwrap_or(d.adapt_drift),
            adapt_rate_rps: t
                .f64_or("experiment.adapt_rate_rps", d.adapt_rate_rps),
            adapt_requests: t
                .usize_or("experiment.adapt_requests", d.adapt_requests),
            obs_tick_s: t.f64_or("experiment.obs_tick_s", d.obs_tick_s),
            obs_span_head: t
                .usize_or("experiment.obs_span_head", d.obs_span_head),
            obs_span_tail: t
                .usize_or("experiment.obs_span_tail", d.obs_span_tail),
            obs_span_sample: t
                .usize_or("experiment.obs_span_sample", d.obs_span_sample),
            obs_out: t.str_or("experiment.obs_out", &d.obs_out),
        }
    }

    /// Apply CLI overrides on top (CLI wins over file, file over default).
    pub fn override_with(&mut self, args: &crate::util::cli::Args) {
        self.delta_map = args.f64_or("delta", self.delta_map);
        self.coco_images = args.usize_or("images", self.coco_images);
        self.balanced_per_group =
            args.usize_or("per-group", self.balanced_per_group);
        self.video_frames = args.usize_or("frames", self.video_frames);
        self.profile_per_group =
            args.usize_or("profile-per-group", self.profile_per_group);
        self.seed = args.u64_or("seed", self.seed);
        if args.get("routers").is_some() {
            self.routers = args.list_or("routers", &[]);
        }
        self.rate_rps = args.f64_or("rate", self.rate_rps);
        self.queue_capacity =
            args.usize_or("queue-cap", self.queue_capacity);
        if args.get("rates").is_some() {
            self.open_rates = args.f64_list_or("rates", &[]);
        }
        if args.get("fleet-sizes").is_some() {
            self.fleet_sizes = args.usize_list_or("fleet-sizes", &[]);
        }
        if args.get("fleet-shards").is_some() {
            self.fleet_shards = args.usize_list_or("fleet-shards", &[]);
        }
        if args.get("fleet-routers").is_some() {
            self.fleet_routers = args.list_or("fleet-routers", &[]);
        }
        self.fleet_rate_rps = args.f64_or("fleet-rate", self.fleet_rate_rps);
        self.fleet_requests =
            args.usize_or("fleet-requests", self.fleet_requests);
        self.fleet_perturb =
            args.f64_or("fleet-perturb", self.fleet_perturb);
        if let Some(d) = args.get("dispatch") {
            self.fleet_dispatch = d.to_string();
        }
        self.fleet_sources =
            args.usize_or("fleet-sources", self.fleet_sources);
        self.fleet_threads =
            args.usize_or("threads", self.fleet_threads);
        self.churn_mtbf_s = args.f64_or("mtbf", self.churn_mtbf_s);
        self.churn_mttr_s = args.f64_or("mttr", self.churn_mttr_s);
        self.churn_probe_interval_s =
            args.f64_or("probe-interval", self.churn_probe_interval_s);
        self.churn_probe_timeout_s =
            args.f64_or("probe-timeout", self.churn_probe_timeout_s);
        self.churn_suspect_after =
            args.usize_or("suspect-after", self.churn_suspect_after);
        self.churn_warmup_s = args.f64_or("warmup", self.churn_warmup_s);
        if let Some(p) = args.get("resilience") {
            self.churn_policy = p.to_string();
        }
        self.churn_retry_budget =
            args.usize_or("retry-budget", self.churn_retry_budget);
        self.churn_retry_backoff_s =
            args.f64_or("retry-backoff", self.churn_retry_backoff_s);
        if args.get("churn-availability").is_some() {
            self.churn_availability =
                args.f64_list_or("churn-availability", &[]);
        }
        if args.get("churn-policies").is_some() {
            self.churn_policies = args.list_or("churn-policies", &[]);
        }
        if args.get("churn-routers").is_some() {
            self.churn_routers = args.list_or("churn-routers", &[]);
        }
        self.churn_rate_rps =
            args.f64_or("churn-rate", self.churn_rate_rps);
        self.churn_requests =
            args.usize_or("churn-requests", self.churn_requests);
        if args.flag("hedge-cancel") {
            self.churn_hedge_cancel = true;
        }
        self.campaign_domain_size =
            args.usize_or("domain-size", self.campaign_domain_size);
        self.campaign_domain_mtbf_s =
            args.f64_or("domain-mtbf", self.campaign_domain_mtbf_s);
        self.campaign_domain_mttr_s =
            args.f64_or("domain-mttr", self.campaign_domain_mttr_s);
        self.campaign_gateway_mtbf_s =
            args.f64_or("gateway-mtbf", self.campaign_gateway_mtbf_s);
        self.campaign_gateway_mttr_s =
            args.f64_or("gateway-mttr", self.campaign_gateway_mttr_s);
        self.campaign_nodes =
            args.usize_or("campaign-nodes", self.campaign_nodes);
        self.campaign_shards =
            args.usize_or("campaign-shards", self.campaign_shards);
        if args.get("campaign-domain-sizes").is_some() {
            self.campaign_domain_sizes =
                args.usize_list_or("campaign-domain-sizes", &[]);
        }
        if args.get("campaign-outage-rates").is_some() {
            self.campaign_outage_rates =
                args.f64_list_or("campaign-outage-rates", &[]);
        }
        if args.get("campaign-routers").is_some() {
            self.campaign_routers = args.list_or("campaign-routers", &[]);
        }
        if args.get("campaign-policies").is_some() {
            self.campaign_policies =
                args.list_or("campaign-policies", &[]);
        }
        self.campaign_rate_rps =
            args.f64_or("campaign-rate", self.campaign_rate_rps);
        self.campaign_requests =
            args.usize_or("campaign-requests", self.campaign_requests);
        if args.flag("no-escalate") {
            self.campaign_escalate = false;
        }
        if args.get("slo-classes").is_some() {
            self.slo_classes = args.list_or("slo-classes", &[]);
        }
        self.slo_batch_window_s =
            args.f64_or("batch-window", self.slo_batch_window_s);
        self.slo_max_batch =
            args.usize_or("max-batch", self.slo_max_batch);
        if args.get("slo-rates").is_some() {
            self.slo_rate_rps = args.f64_list_or("slo-rates", &[]);
        }
        if args.get("slo-windows").is_some() {
            self.slo_windows_s = args.f64_list_or("slo-windows", &[]);
        }
        self.slo_requests =
            args.usize_or("slo-requests", self.slo_requests);
        if args.get("slo-routers").is_some() {
            self.slo_routers = args.list_or("slo-routers", &[]);
        }
        self.adapt_alpha = args.f64_or("adapt-alpha", self.adapt_alpha);
        self.adapt_confidence =
            args.usize_or("adapt-confidence", self.adapt_confidence);
        self.adapt_max_correction = args
            .f64_or("adapt-max-correction", self.adapt_max_correction);
        self.adapt_publish_every =
            args.usize_or("adapt-publish-every", self.adapt_publish_every);
        if args.flag("adapt-no-scale") {
            self.adapt_scale = false;
        }
        self.adapt_scale_interval_s =
            args.f64_or("adapt-interval", self.adapt_scale_interval_s);
        self.adapt_rate_alpha =
            args.f64_or("adapt-rate-alpha", self.adapt_rate_alpha);
        self.adapt_down_util =
            args.f64_or("adapt-down-util", self.adapt_down_util);
        self.adapt_up_util =
            args.f64_or("adapt-up-util", self.adapt_up_util);
        self.adapt_min_powered =
            args.usize_or("adapt-min-powered", self.adapt_min_powered);
        self.adapt_idle_power_w =
            args.f64_or("adapt-idle-power", self.adapt_idle_power_w);
        self.adapt_warmup_s =
            args.f64_or("adapt-warmup", self.adapt_warmup_s);
        if args.get("adapt-routers").is_some() {
            self.adapt_routers = args.list_or("adapt-routers", &[]);
        }
        if args.get("adapt-drift").is_some() {
            self.adapt_drift = args.f64_list_or("adapt-drift", &[]);
        }
        self.adapt_rate_rps =
            args.f64_or("adapt-rate", self.adapt_rate_rps);
        self.adapt_requests =
            args.usize_or("adapt-requests", self.adapt_requests);
        self.obs_tick_s = args.f64_or("obs-tick", self.obs_tick_s);
        self.obs_span_head =
            args.usize_or("obs-span-head", self.obs_span_head);
        self.obs_span_tail =
            args.usize_or("obs-span-tail", self.obs_span_tail);
        self.obs_span_sample =
            args.usize_or("obs-span-sample", self.obs_span_sample);
        if let Some(o) = args.get("obs-out") {
            self.obs_out = o.to_string();
        }
    }

    /// Materialize the churn keys into a [`ChurnConfig`] (the `serve
    /// --churn` path; the `churn` experiment overrides `mtbf_s` per
    /// availability level via [`mtbf_for_availability`]).
    ///
    /// [`mtbf_for_availability`]: crate::lifecycle::mtbf_for_availability
    pub fn churn_config(&self) -> Result<crate::lifecycle::ChurnConfig> {
        let policy = crate::lifecycle::ResiliencePolicy::parse(
            &self.churn_policy,
            self.churn_retry_budget,
        )
        .with_context(|| {
            format!(
                "unknown resilience policy '{}' (drop|retry|hedge)",
                self.churn_policy
            )
        })?;
        Ok(crate::lifecycle::ChurnConfig {
            mtbf_s: self.churn_mtbf_s,
            mttr_s: self.churn_mttr_s,
            probe_interval_s: self.churn_probe_interval_s,
            probe_timeout_s: self.churn_probe_timeout_s,
            suspect_after: self.churn_suspect_after.max(1),
            warmup_s: self.churn_warmup_s,
            warmup_penalty: self.churn_warmup_penalty,
            policy,
            retry_backoff_s: self.churn_retry_backoff_s,
            hedge_cancel: self.churn_hedge_cancel,
            horizon_slack_s: crate::lifecycle::ChurnConfig::default()
                .horizon_slack_s,
            seed: self.seed ^ 0xC4A2,
        })
    }

    /// Materialize the campaign keys into a [`CampaignConfig`] (the
    /// `serve --campaign` path; the `campaign` sweep overrides
    /// `domain_size`/`domain_mtbf_s` per cell).
    ///
    /// [`CampaignConfig`]: crate::lifecycle::campaign::CampaignConfig
    pub fn campaign_config(
        &self,
    ) -> Result<crate::lifecycle::campaign::CampaignConfig> {
        let cfg = crate::lifecycle::campaign::CampaignConfig {
            domain_size: self.campaign_domain_size.max(1),
            domain_mtbf_s: self.campaign_domain_mtbf_s,
            domain_mttr_s: self.campaign_domain_mttr_s,
            gateway_mtbf_s: self.campaign_gateway_mtbf_s,
            gateway_mttr_s: self.campaign_gateway_mttr_s,
            seed: self.seed ^ 0x0CA4,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Materialize the SLO keys into an [`SloConfig`] (the `serve
    /// --slo` path and the `slo` sweep; windows are overridden per
    /// sweep cell).
    ///
    /// [`SloConfig`]: crate::workload::slo::SloConfig
    pub fn slo_config(&self) -> Result<crate::workload::slo::SloConfig> {
        let classes =
            crate::workload::slo::SloConfig::parse_classes(&self.slo_classes)?;
        anyhow::ensure!(
            !classes.is_empty(),
            "slo_classes must name at least one deadline class"
        );
        anyhow::ensure!(
            self.slo_batch_window_s >= 0.0,
            "slo_batch_window_s must be >= 0"
        );
        anyhow::ensure!(self.slo_max_batch >= 1, "slo_max_batch must be >= 1");
        Ok(crate::workload::slo::SloConfig {
            classes,
            batch_window_s: self.slo_batch_window_s,
            max_batch: self.slo_max_batch,
        })
    }

    /// Materialize the adapt keys into a validated [`AdaptConfig`]
    /// (the `serve --adapt` path and the `adapt` sweep; the sweep
    /// overrides `scale`/`publish_every` per arm).
    ///
    /// [`AdaptConfig`]: crate::adapt::AdaptConfig
    pub fn adapt_config(&self) -> Result<crate::adapt::AdaptConfig> {
        let cfg = crate::adapt::AdaptConfig {
            alpha: self.adapt_alpha,
            confidence: self.adapt_confidence,
            max_correction: self.adapt_max_correction,
            publish_every: self.adapt_publish_every,
            scale: self.adapt_scale,
            scale_interval_s: self.adapt_scale_interval_s,
            rate_alpha: self.adapt_rate_alpha,
            down_util: self.adapt_down_util,
            up_util: self.adapt_up_util,
            min_powered: self.adapt_min_powered,
            idle_power_w: self.adapt_idle_power_w,
            warmup_s: self.adapt_warmup_s,
            warmup_penalty: self.churn_warmup_penalty,
            seed: self.seed ^ 0xADA7,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Materialize the obs keys into an [`ObsConfig`] (the `serve
    /// --obs` path and the obs smoke/bench drivers). The retention
    /// reservoir seed derives from the run seed on its own stream, so
    /// span sampling never perturbs the simulation's RNG draws.
    ///
    /// [`ObsConfig`]: crate::obs::ObsConfig
    pub fn obs_config(&self) -> Result<crate::obs::ObsConfig> {
        anyhow::ensure!(
            self.obs_tick_s.is_finite() && self.obs_tick_s > 0.0,
            "obs_tick_s must be finite and > 0"
        );
        Ok(crate::obs::ObsConfig {
            tick_s: self.obs_tick_s,
            span_head: self.obs_span_head,
            span_tail: self.obs_span_tail,
            span_sample: self.obs_span_sample,
            seed: self.seed ^ 0x0B5,
            out_dir: self.obs_out.clone(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let t = Table::parse(
            r#"
# top comment
title = "ecore"
[experiment]
delta_map = 5.0          # margin
coco_images = 600
verbose = true
routers = ["ED", "OB"]
"#,
        )
        .unwrap();
        assert_eq!(t.str_or("title", ""), "ecore");
        assert_eq!(t.f64_or("experiment.delta_map", 0.0), 5.0);
        assert_eq!(t.usize_or("experiment.coco_images", 0), 600);
        assert!(t.bool_or("experiment.verbose", false));
        assert_eq!(
            t.get("experiment.routers").unwrap().as_str_list().unwrap(),
            vec!["ED", "OB"]
        );
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Table::parse("key without equals").is_err());
        assert!(Table::parse("x = [1, 2").is_err());
        assert!(Table::parse("x = @wat").is_err());
    }

    #[test]
    fn hash_inside_string_kept() {
        let t = Table::parse(r##"x = "a#b" # real comment"##).unwrap();
        assert_eq!(t.str_or("x", ""), "a#b");
    }

    #[test]
    fn experiment_config_defaults_and_table() {
        let t = Table::parse("[experiment]\ndelta_map = 10\n").unwrap();
        let c = ExperimentConfig::from_table(&t);
        assert_eq!(c.delta_map, 10.0);
        assert_eq!(c.coco_images, ExperimentConfig::default().coco_images);
        assert_eq!(c.routers.len(), 10);
    }

    #[test]
    fn fleet_keys_parse_and_override() {
        let t = Table::parse(
            "[experiment]\nfleet_sizes = [8, 16]\nfleet_dispatch = \"hash\"\nfleet_rate_rps = 25\n",
        )
        .unwrap();
        let mut c = ExperimentConfig::from_table(&t);
        assert_eq!(c.fleet_sizes, vec![8, 16]);
        assert_eq!(c.fleet_dispatch, "hash");
        assert_eq!(c.fleet_rate_rps, 25.0);
        // unset keys keep defaults
        let d = ExperimentConfig::default();
        assert_eq!(c.fleet_shards, d.fleet_shards);
        assert_eq!(c.fleet_requests, d.fleet_requests);
        // CLI wins over file
        let args = crate::util::cli::Args::parse(
            [
                "--fleet-shards",
                "2,4",
                "--dispatch",
                "sticky",
                "--fleet-requests",
                "9",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        c.override_with(&args);
        assert_eq!(c.fleet_shards, vec![2, 4]);
        assert_eq!(c.fleet_dispatch, "sticky");
        assert_eq!(c.fleet_requests, 9);
    }

    #[test]
    fn churn_keys_parse_override_and_materialize() {
        let t = Table::parse(
            "[experiment]\nchurn_mttr_s = 2\nchurn_policy = \"hedge\"\nchurn_availability = [1.0, 0.75]\n",
        )
        .unwrap();
        let mut c = ExperimentConfig::from_table(&t);
        assert_eq!(c.churn_mttr_s, 2.0);
        assert_eq!(c.churn_policy, "hedge");
        assert_eq!(c.churn_availability, vec![1.0, 0.75]);
        let d = ExperimentConfig::default();
        assert_eq!(c.churn_mtbf_s, d.churn_mtbf_s);
        assert_eq!(c.churn_routers.len(), 10);
        // CLI wins over file
        let args = crate::util::cli::Args::parse(
            [
                "--resilience",
                "retry",
                "--retry-budget",
                "7",
                "--mtbf",
                "9.5",
                "--churn-policies",
                "drop,retry",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        c.override_with(&args);
        assert_eq!(c.churn_policy, "retry");
        assert_eq!(c.churn_retry_budget, 7);
        assert_eq!(c.churn_mtbf_s, 9.5);
        assert_eq!(c.churn_policies, vec!["drop", "retry"]);
        // materializes into a typed ChurnConfig
        let cc = c.churn_config().unwrap();
        assert_eq!(
            cc.policy,
            crate::lifecycle::ResiliencePolicy::Retry { budget: 7 }
        );
        assert_eq!(cc.mtbf_s, 9.5);
        assert_eq!(cc.mttr_s, 2.0);
        // bad policy is a typed error
        c.churn_policy = "wat".into();
        assert!(c.churn_config().is_err());
    }

    #[test]
    fn campaign_keys_parse_override_and_materialize() {
        let t = Table::parse(
            "[experiment]\ncampaign_domain_size = 3\ncampaign_domain_mtbf_s = 8\ncampaign_outage_rates = [0.1, 0.4]\nchurn_hedge_cancel = true\n",
        )
        .unwrap();
        let mut c = ExperimentConfig::from_table(&t);
        assert_eq!(c.campaign_domain_size, 3);
        assert_eq!(c.campaign_domain_mtbf_s, 8.0);
        assert_eq!(c.campaign_outage_rates, vec![0.1, 0.4]);
        assert!(c.churn_hedge_cancel);
        let d = ExperimentConfig::default();
        assert_eq!(c.campaign_domain_mttr_s, d.campaign_domain_mttr_s);
        assert!(c.campaign_gateway_mtbf_s.is_infinite());
        assert_eq!(c.campaign_routers, d.campaign_routers);
        // CLI wins over file
        let args = crate::util::cli::Args::parse(
            [
                "--domain-size",
                "5",
                "--gateway-mtbf",
                "6.5",
                "--campaign-policies",
                "retry,hedge",
                "--no-escalate",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        c.override_with(&args);
        assert_eq!(c.campaign_domain_size, 5);
        assert_eq!(c.campaign_gateway_mtbf_s, 6.5);
        assert_eq!(c.campaign_policies, vec!["retry", "hedge"]);
        assert!(!c.campaign_escalate);
        // materializes into a typed CampaignConfig; the churn flag
        // flows into the churn materializer
        let cc = c.campaign_config().unwrap();
        assert_eq!(cc.domain_size, 5);
        assert_eq!(cc.domain_mtbf_s, 8.0);
        assert_eq!(cc.gateway_mtbf_s, 6.5);
        assert_eq!(cc.seed, c.seed ^ 0x0CA4);
        assert!(cc.domains_enabled() && cc.gateway_enabled());
        assert!(c.churn_config().unwrap().hedge_cancel);
        // a nonsensical schedule is a typed error
        c.campaign_domain_mttr_s = -1.0;
        assert!(c.campaign_config().is_err());
    }

    #[test]
    fn slo_keys_parse_override_and_materialize() {
        let t = Table::parse(
            "[experiment]\nslo_batch_window_s = 0.01\nslo_classes = [\"fast:0.02\", \"slow:2\"]\nslo_rate_rps = [40]\n",
        )
        .unwrap();
        let mut c = ExperimentConfig::from_table(&t);
        assert_eq!(c.slo_batch_window_s, 0.01);
        assert_eq!(c.slo_classes, vec!["fast:0.02", "slow:2"]);
        assert_eq!(c.slo_rate_rps, vec![40.0]);
        let d = ExperimentConfig::default();
        assert_eq!(c.slo_max_batch, d.slo_max_batch);
        assert_eq!(c.slo_windows_s, d.slo_windows_s);
        // CLI wins over file
        let args = crate::util::cli::Args::parse(
            [
                "--batch-window",
                "0.002",
                "--max-batch",
                "8",
                "--slo-routers",
                "LE",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        c.override_with(&args);
        assert_eq!(c.slo_batch_window_s, 0.002);
        assert_eq!(c.slo_max_batch, 8);
        assert_eq!(c.slo_routers, vec!["LE"]);
        // materializes into a typed SloConfig
        let sc = c.slo_config().unwrap();
        assert_eq!(sc.classes.len(), 2);
        assert_eq!(sc.classes[0].name, "fast");
        assert!((sc.classes[1].deadline_s - 2.0).abs() < 1e-12);
        assert_eq!(sc.max_batch, 8);
        // bad class spec is a typed error
        c.slo_classes = vec!["nope".into()];
        assert!(c.slo_config().is_err());
        c.slo_classes = Vec::new();
        assert!(c.slo_config().is_err());
    }

    #[test]
    fn adapt_keys_parse_override_and_materialize() {
        let t = Table::parse(
            "[experiment]\nadapt_alpha = 0.5\nadapt_scale = false\nadapt_drift = [1.5, 3.0]\n",
        )
        .unwrap();
        let mut c = ExperimentConfig::from_table(&t);
        assert_eq!(c.adapt_alpha, 0.5);
        assert!(!c.adapt_scale);
        assert_eq!(c.adapt_drift, vec![1.5, 3.0]);
        let d = ExperimentConfig::default();
        assert_eq!(c.adapt_confidence, d.adapt_confidence);
        assert_eq!(c.adapt_routers, d.adapt_routers);
        // CLI wins over file
        let args = crate::util::cli::Args::parse(
            [
                "--adapt-alpha",
                "0.25",
                "--adapt-routers",
                "ED",
                "--adapt-requests",
                "12",
                "--adapt-drift",
                "2.0",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        c.override_with(&args);
        assert_eq!(c.adapt_alpha, 0.25);
        assert_eq!(c.adapt_routers, vec!["ED"]);
        assert_eq!(c.adapt_requests, 12);
        assert_eq!(c.adapt_drift, vec![2.0]);
        // materializes into a validated AdaptConfig
        let ac = c.adapt_config().unwrap();
        assert_eq!(ac.alpha, 0.25);
        assert!(!ac.scale, "file turned scaling off");
        assert_eq!(ac.seed, c.seed ^ 0xADA7);
        // bad values surface as typed errors
        c.adapt_alpha = 0.0;
        assert!(c.adapt_config().is_err());
    }

    #[test]
    fn obs_keys_parse_override_and_materialize() {
        let t = Table::parse(
            "[experiment]\nobs_tick_s = 0.5\nobs_span_head = 8\nobs_out = \"out/obs\"\n",
        )
        .unwrap();
        let mut c = ExperimentConfig::from_table(&t);
        assert_eq!(c.obs_tick_s, 0.5);
        assert_eq!(c.obs_span_head, 8);
        assert_eq!(c.obs_out, "out/obs");
        let d = ExperimentConfig::default();
        assert_eq!(c.obs_span_tail, d.obs_span_tail);
        assert_eq!(c.obs_span_sample, d.obs_span_sample);
        // CLI wins over file
        let args = crate::util::cli::Args::parse(
            ["--obs-tick", "0.25", "--obs-out", "elsewhere"]
                .iter()
                .map(|s| s.to_string()),
        );
        c.override_with(&args);
        assert_eq!(c.obs_tick_s, 0.25);
        assert_eq!(c.obs_out, "elsewhere");
        // materializes into a validated ObsConfig
        let oc = c.obs_config().unwrap();
        assert_eq!(oc.tick_s, 0.25);
        assert_eq!(oc.span_head, 8);
        assert_eq!(oc.out_dir, "elsewhere");
        assert_eq!(oc.seed, c.seed ^ 0x0B5);
        // bad values surface as typed errors
        c.obs_tick_s = 0.0;
        assert!(c.obs_config().is_err());
        c.obs_tick_s = f64::NAN;
        assert!(c.obs_config().is_err());
    }

    #[test]
    fn cli_overrides_win() {
        let mut c = ExperimentConfig::default();
        let args = crate::util::cli::Args::parse(
            ["--delta", "15", "--routers", "ED,OB"]
                .iter()
                .map(|s| s.to_string()),
        );
        c.override_with(&args);
        assert_eq!(c.delta_map, 15.0);
        assert_eq!(c.routers, vec!["ED", "OB"]);
    }
}
