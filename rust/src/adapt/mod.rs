//! Online adaptation (DESIGN.md §12): the feedback loop the paper's
//! profiling-based design lacks.
//!
//! Two cooperating halves, both option-gated (`adapt: None` keeps
//! every pre-adaptation trace byte-identical):
//!
//! * [`Telemetry`] — every completion feeds its observed latency and
//!   energy back into a per-[`PairId`] EWMA of the observed/predicted
//!   cost ratio. The gateway turns that ratio into a confidence-
//!   weighted multiplicative *correction* and applies it on the
//!   [`RoutingView`](crate::router::RoutingView) cost overlay — the
//!   same `view.age()` path the lifecycle warm-up uses, composed by
//!   multiplication — so stale profiles converge toward drifted
//!   ground truth without re-running the profiler.
//! * [`Scaler`] — an arrival-rate EWMA drives energy-proportional
//!   autoscaling: in troughs surplus nodes are deliberately powered
//!   down (the lifecycle [`MemberState::PoweredDown`] path, sticky
//!   against probes), and re-warmed through the existing
//!   Warming/rejoin machinery when predicted utilization crosses the
//!   upper threshold. Idle power is accounted per powered-second so
//!   reports can compare fleet-wide energy/request against a static
//!   (always-on) fleet.
//!
//! Everything here is a deterministic function of the observations it
//! is fed (the seed exists for the synthesized membership config), so
//! golden traces pin whole adaptive runs byte for byte.
//!
//! [`MemberState::PoweredDown`]: crate::lifecycle::MemberState::PoweredDown

use anyhow::Result;

use crate::lifecycle::{ChurnConfig, ResiliencePolicy};
use crate::router::PairId;
use crate::util::json::Json;

/// Parameters of the adaptation subsystem (telemetry + scaler).
#[derive(Clone, Debug)]
pub struct AdaptConfig {
    /// EWMA smoothing factor for the per-pair observed/predicted cost
    /// ratio, in (0, 1]. Higher = faster convergence, noisier.
    pub alpha: f64,
    /// Observations before a pair's correction reaches full weight:
    /// the applied factor is `1 + min(1, n/confidence) * (ewma - 1)`,
    /// so a pair with few samples barely moves its profile.
    pub confidence: usize,
    /// Correction clamp: applied factors stay in
    /// `[1/max_correction, max_correction]`.
    pub max_correction: f64,
    /// `0` = continuous mode (each observation is immediately visible
    /// to routing); `N > 0` = periodic re-profiling mode (corrections
    /// are snapshot-published to routing every N observations).
    pub publish_every: usize,
    /// Enable the energy-proportional autoscaling half.
    pub scale: bool,
    /// Scaler decision period on the virtual clock (s).
    pub scale_interval_s: f64,
    /// EWMA smoothing factor for the arrival-rate estimate, in (0, 1].
    pub rate_alpha: f64,
    /// Predicted utilization below which one surplus node powers down
    /// per tick. Must sit strictly below `up_util` (hysteresis band).
    pub down_util: f64,
    /// Predicted utilization above which one node powers back up.
    pub up_util: f64,
    /// The scaler never powers the pool below this many nodes.
    pub min_powered: usize,
    /// Idle draw charged per powered node (W): the fleet-wide
    /// energy/request term that makes powering nodes down worthwhile.
    pub idle_power_w: f64,
    /// Warm-up window a powered-up node re-enters routing through (s),
    /// used when the gateway has no churn membership of its own.
    pub warmup_s: f64,
    /// Warm-up cost inflation at power-up (see
    /// [`ChurnConfig::warmup_penalty`]).
    pub warmup_penalty: f64,
    /// Seed for the synthesized membership config (and any future
    /// adaptation-local randomization; current decisions are all
    /// deterministic functions of the observations).
    pub seed: u64,
}

impl Default for AdaptConfig {
    fn default() -> Self {
        Self {
            alpha: 0.3,
            confidence: 8,
            max_correction: 4.0,
            publish_every: 0,
            scale: true,
            scale_interval_s: 0.25,
            rate_alpha: 0.4,
            down_util: 0.35,
            up_util: 0.75,
            min_powered: 1,
            idle_power_w: 1.2,
            warmup_s: 1.0,
            warmup_penalty: 0.5,
            seed: 17,
        }
    }
}

impl AdaptConfig {
    /// Validate the invariants the subsystem relies on.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.alpha > 0.0 && self.alpha <= 1.0,
            "adapt alpha must be in (0, 1], got {}",
            self.alpha
        );
        anyhow::ensure!(
            self.rate_alpha > 0.0 && self.rate_alpha <= 1.0,
            "adapt rate_alpha must be in (0, 1], got {}",
            self.rate_alpha
        );
        anyhow::ensure!(
            self.max_correction >= 1.0,
            "adapt max_correction must be >= 1, got {}",
            self.max_correction
        );
        anyhow::ensure!(
            self.down_util < self.up_util,
            "adapt hysteresis band inverted: down_util {} >= up_util {}",
            self.down_util,
            self.up_util
        );
        anyhow::ensure!(
            self.min_powered >= 1,
            "adapt min_powered must be >= 1"
        );
        anyhow::ensure!(
            self.scale_interval_s > 0.0,
            "adapt scale_interval_s must be > 0"
        );
        anyhow::ensure!(
            self.idle_power_w >= 0.0,
            "adapt idle_power_w must be >= 0"
        );
        Ok(())
    }

    /// The membership config a scaling gateway synthesizes when it has
    /// no churn membership of its own: nothing ever crashes
    /// (`mtbf_s = INFINITY`), but power-ups re-enter routing through
    /// the same Warming window churn recoveries use.
    pub fn membership_config(&self) -> ChurnConfig {
        ChurnConfig {
            mtbf_s: f64::INFINITY,
            warmup_s: self.warmup_s,
            warmup_penalty: self.warmup_penalty,
            policy: ResiliencePolicy::Drop,
            seed: self.seed,
            ..ChurnConfig::default()
        }
    }
}

/// Per-pair EWMA of the observed/predicted cost ratio.
#[derive(Clone, Copy, Debug)]
struct PairEwma {
    ratio: f64,
    n: usize,
}

impl Default for PairEwma {
    fn default() -> Self {
        Self { ratio: 1.0, n: 0 }
    }
}

/// Telemetry-driven profile correction: a dense per-[`PairId`] table
/// of EWMA cost ratios plus the published factors routing reads.
#[derive(Clone, Debug)]
pub struct Telemetry {
    alpha: f64,
    confidence: usize,
    max_correction: f64,
    publish_every: usize,
    live: Vec<PairEwma>,
    /// Factors visible to routing. Continuous mode keeps these in
    /// lock-step with `live`; periodic mode refreshes them every
    /// `publish_every` observations (the re-profiling cadence).
    published: Vec<f64>,
    observations: usize,
    /// Any published factor deviates from 1.0 — the hot-path gate
    /// that keeps the no-signal overlay loop free.
    active: bool,
}

impl Telemetry {
    pub fn new(cfg: &AdaptConfig, n_pairs: usize) -> Self {
        Self {
            alpha: cfg.alpha,
            confidence: cfg.confidence.max(1),
            max_correction: cfg.max_correction.max(1.0),
            publish_every: cfg.publish_every,
            live: vec![PairEwma::default(); n_pairs],
            published: vec![1.0; n_pairs],
            observations: 0,
            active: false,
        }
    }

    /// Feed one completed request's observed cost against the profiled
    /// baseline for its (pair, group) row. The per-sample ratio is the
    /// mean of the latency and energy component ratios (one scalar
    /// scales both on the routing view, mirroring the warm-up overlay),
    /// clamped to the correction range as an outlier guard.
    pub fn observe(
        &mut self,
        id: PairId,
        predicted_latency_s: f64,
        predicted_energy_mwh: f64,
        observed_latency_s: f64,
        observed_energy_mwh: f64,
    ) {
        let Some(e) = self.live.get_mut(id.index()) else {
            return;
        };
        let mut sum = 0.0;
        let mut k = 0;
        if predicted_latency_s > 0.0 {
            sum += observed_latency_s / predicted_latency_s;
            k += 1;
        }
        if predicted_energy_mwh > 0.0 {
            sum += observed_energy_mwh / predicted_energy_mwh;
            k += 1;
        }
        if k == 0 {
            return;
        }
        let r = (sum / k as f64)
            .clamp(1.0 / self.max_correction, self.max_correction);
        e.ratio = self.alpha * r + (1.0 - self.alpha) * e.ratio;
        e.n += 1;
        self.observations += 1;
        if self.publish_every == 0 {
            let f = Self::factor_of(
                self.live[id.index()],
                self.confidence,
                self.max_correction,
            );
            self.published[id.index()] = f;
            self.active = self.active || f != 1.0;
        } else if self.observations % self.publish_every == 0 {
            self.publish();
        }
    }

    /// Snapshot-publish every live correction to routing (the periodic
    /// re-profiling step; continuous mode publishes per observation).
    pub fn publish(&mut self) {
        for (i, &e) in self.live.iter().enumerate() {
            let f =
                Self::factor_of(e, self.confidence, self.max_correction);
            self.published[i] = f;
            self.active = self.active || f != 1.0;
        }
    }

    fn factor_of(e: PairEwma, confidence: usize, max: f64) -> f64 {
        if e.n == 0 {
            return 1.0;
        }
        let w = (e.n as f64 / confidence as f64).min(1.0);
        (1.0 + w * (e.ratio - 1.0)).clamp(1.0 / max, max)
    }

    /// The correction factor routing applies to `id`'s profiled costs
    /// (1.0 until published evidence says otherwise).
    pub fn correction(&self, id: PairId) -> f64 {
        self.published.get(id.index()).copied().unwrap_or(1.0)
    }

    /// Whether any published correction deviates from 1.0 (gates the
    /// per-request overlay loop).
    pub fn active(&self) -> bool {
        self.active
    }

    /// Total observations fed so far.
    pub fn samples(&self) -> usize {
        self.observations
    }

    /// Pairs with at least one observation.
    pub fn corrected_pairs(&self) -> usize {
        self.live.iter().filter(|e| e.n > 0).count()
    }

    /// Mean published correction over pairs with observations (1.0
    /// when nothing has been observed).
    pub fn mean_correction(&self) -> f64 {
        let mut sum = 0.0;
        let mut n = 0;
        for (i, e) in self.live.iter().enumerate() {
            if e.n > 0 {
                sum += self.published[i];
                n += 1;
            }
        }
        if n > 0 {
            sum / n as f64
        } else {
            1.0
        }
    }
}

/// Energy-proportional autoscaler state: the arrival-rate EWMA, the
/// powered set, and powered-seconds accounting for idle energy.
///
/// The scaler only *decides*; the gateway owns the actual transitions
/// (pool health, membership state, drift reboot) so every power event
/// flows through the same lifecycle machinery churn uses.
#[derive(Clone, Debug)]
pub struct Scaler {
    interval_s: f64,
    rate_alpha: f64,
    down_util: f64,
    up_util: f64,
    min_powered: usize,
    arrivals: usize,
    last_tick_s: f64,
    rate_rps: f64,
    ticked: bool,
    /// Powered flag per pair id (ids without a deployed node are
    /// permanently unpowered and never counted).
    powered: Vec<bool>,
    deployed: Vec<bool>,
    powered_since: Vec<f64>,
    /// Powered-seconds accumulated over completed power windows; open
    /// windows are finalized by [`Scaler::powered_node_s`].
    closed_powered_s: f64,
    initial_powered: usize,
    pub power_downs: usize,
    pub power_ups: usize,
}

impl Scaler {
    /// `deployed[i]` = pair id `i` has a node behind it; all deployed
    /// pairs start powered at t = 0.
    pub fn new(cfg: &AdaptConfig, deployed: Vec<bool>) -> Self {
        let initial = deployed.iter().filter(|&&d| d).count();
        Self {
            interval_s: cfg.scale_interval_s.max(1e-6),
            rate_alpha: cfg.rate_alpha,
            down_util: cfg.down_util,
            up_util: cfg.up_util,
            min_powered: cfg.min_powered.max(1),
            arrivals: 0,
            last_tick_s: 0.0,
            rate_rps: 0.0,
            ticked: false,
            powered: deployed.clone(),
            deployed,
            powered_since: vec![0.0; 0],
            closed_powered_s: 0.0,
            initial_powered: initial,
            power_downs: 0,
            power_ups: 0,
        }
        .with_since()
    }

    fn with_since(mut self) -> Self {
        self.powered_since = vec![0.0; self.powered.len()];
        self
    }

    /// Scaler decision period (the driver's tick schedule).
    pub fn interval_s(&self) -> f64 {
        self.interval_s
    }

    /// Count one offered arrival toward the rate estimate.
    pub fn note_arrival(&mut self) {
        self.arrivals += 1;
    }

    /// Close the current measurement window at `now_s` and return the
    /// predicted utilization `rate * mean_service / n_powered`, where
    /// `mean_service_of` maps a powered pair id to its profiled mean
    /// service time. Returns `None` when no time has passed or nothing
    /// is powered.
    pub fn tick(
        &mut self,
        now_s: f64,
        mean_service_of: impl Fn(PairId) -> f64,
    ) -> Option<f64> {
        let dt = now_s - self.last_tick_s;
        if dt <= 0.0 {
            return None;
        }
        let inst = self.arrivals as f64 / dt;
        self.rate_rps = if self.ticked {
            self.rate_alpha * inst + (1.0 - self.rate_alpha) * self.rate_rps
        } else {
            inst
        };
        self.ticked = true;
        self.arrivals = 0;
        self.last_tick_s = now_s;
        let mut svc_sum = 0.0;
        let mut n = 0usize;
        for (i, &p) in self.powered.iter().enumerate() {
            if p {
                svc_sum += mean_service_of(PairId(i as u32));
                n += 1;
            }
        }
        if n == 0 {
            return None;
        }
        Some(self.rate_rps * (svc_sum / n as f64) / n as f64)
    }

    pub fn down_util(&self) -> f64 {
        self.down_util
    }

    pub fn up_util(&self) -> f64 {
        self.up_util
    }

    pub fn min_powered(&self) -> usize {
        self.min_powered
    }

    pub fn is_powered(&self, id: PairId) -> bool {
        self.powered.get(id.index()).copied().unwrap_or(false)
    }

    pub fn n_powered(&self) -> usize {
        self.powered.iter().filter(|&&p| p).count()
    }

    /// Deployed pairs currently powered off.
    pub fn n_off(&self) -> usize {
        self.deployed
            .iter()
            .zip(&self.powered)
            .filter(|&(&d, &p)| d && !p)
            .count()
    }

    /// Record a power-down of `id` at `now_s` (the gateway performs
    /// the pool/membership side).
    pub fn power_down(&mut self, id: PairId, now_s: f64) {
        let i = id.index();
        if self.powered.get(i).copied() == Some(true) {
            self.powered[i] = false;
            self.closed_powered_s +=
                (now_s - self.powered_since[i]).max(0.0);
            self.power_downs += 1;
        }
    }

    /// Record a power-up of `id` at `now_s`.
    pub fn power_up(&mut self, id: PairId, now_s: f64) {
        let i = id.index();
        if self.deployed.get(i).copied() == Some(true) && !self.powered[i]
        {
            self.powered[i] = true;
            self.powered_since[i] = now_s;
            self.power_ups += 1;
        }
    }

    /// Fleet-wide powered node-seconds up to `makespan_s` (closed
    /// windows plus every still-open one).
    pub fn powered_node_s(&self, makespan_s: f64) -> f64 {
        let mut total = self.closed_powered_s;
        for (i, &p) in self.powered.iter().enumerate() {
            if p {
                total += (makespan_s - self.powered_since[i]).max(0.0);
            }
        }
        total
    }

    /// Node count of the equivalent static (always-on) fleet.
    pub fn initial_powered(&self) -> usize {
        self.initial_powered
    }
}

/// Per-gateway adaptation runtime: config + telemetry + optional
/// scaler. Lives on the gateway so corrections compose with routing
/// and power transitions flow through pool + membership.
#[derive(Clone, Debug)]
pub struct AdaptRuntime {
    pub cfg: AdaptConfig,
    pub telemetry: Telemetry,
    pub scaler: Option<Scaler>,
}

impl AdaptRuntime {
    /// `deployed[i]` = pair id `i` has a node (scaler candidates).
    pub fn new(cfg: &AdaptConfig, deployed: Vec<bool>) -> Self {
        let telemetry = Telemetry::new(cfg, deployed.len());
        let scaler = if cfg.scale {
            Some(Scaler::new(cfg, deployed))
        } else {
            None
        };
        Self { cfg: cfg.clone(), telemetry, scaler }
    }

    /// Summarize this runtime at end of run. `n_nodes` sizes the
    /// static-fleet comparison when the scaler is off (everything
    /// powered for the whole run).
    pub fn report(&self, n_nodes: usize, makespan_s: f64) -> AdaptReport {
        let (powered_s, static_nodes, downs, ups) = match &self.scaler {
            Some(sc) => (
                sc.powered_node_s(makespan_s),
                sc.initial_powered(),
                sc.power_downs,
                sc.power_ups,
            ),
            None => {
                (n_nodes as f64 * makespan_s.max(0.0), n_nodes, 0, 0)
            }
        };
        let static_s = static_nodes as f64 * makespan_s.max(0.0);
        // W * s = J; 1 mWh = 3.6 J
        let w = self.cfg.idle_power_w;
        AdaptReport {
            telemetry_samples: self.telemetry.samples(),
            corrected_pairs: self.telemetry.corrected_pairs(),
            mean_correction: self.telemetry.mean_correction(),
            power_downs: downs,
            power_ups: ups,
            powered_node_s: powered_s,
            static_node_s: static_s,
            idle_energy_mwh: w * powered_s / 3.6,
            static_idle_energy_mwh: w * static_s / 3.6,
        }
    }
}

/// Serialized adaptation summary attached to open-loop and fleet
/// reports (present exactly when the run had an adapt config).
#[derive(Clone, Debug)]
pub struct AdaptReport {
    pub telemetry_samples: usize,
    pub corrected_pairs: usize,
    /// Mean published correction over observed pairs (1.0 = profiles
    /// already matched reality).
    pub mean_correction: f64,
    pub power_downs: usize,
    pub power_ups: usize,
    /// Powered node-seconds actually accrued under the scaler.
    pub powered_node_s: f64,
    /// Node-seconds of the equivalent always-on fleet.
    pub static_node_s: f64,
    /// Idle energy charged to the (possibly scaled) fleet.
    pub idle_energy_mwh: f64,
    /// Idle energy the static fleet would have burned.
    pub static_idle_energy_mwh: f64,
}

impl AdaptReport {
    /// Fold another gateway's report into this one (fleet shards).
    pub fn merge(&mut self, other: &AdaptReport) {
        // weighted by observed pairs so the mean stays a mean
        let w_self = self.corrected_pairs as f64;
        let w_other = other.corrected_pairs as f64;
        if w_self + w_other > 0.0 {
            self.mean_correction = (self.mean_correction * w_self
                + other.mean_correction * w_other)
                / (w_self + w_other);
        }
        self.telemetry_samples += other.telemetry_samples;
        self.corrected_pairs += other.corrected_pairs;
        self.power_downs += other.power_downs;
        self.power_ups += other.power_ups;
        self.powered_node_s += other.powered_node_s;
        self.static_node_s += other.static_node_s;
        self.idle_energy_mwh += other.idle_energy_mwh;
        self.static_idle_energy_mwh += other.static_idle_energy_mwh;
    }

    /// Idle node-seconds saved vs the always-on fleet (>= 0).
    pub fn node_s_saved(&self) -> f64 {
        (self.static_node_s - self.powered_node_s).max(0.0)
    }

    /// One-line human summary shared by the `serve --adapt` CLI paths.
    pub fn summary(&self) -> String {
        format!(
            "adapt: {} samples over {} pairs (mean correction {:.3}), {} power-downs / {} power-ups, idle {:.3} mWh vs static {:.3} mWh",
            self.telemetry_samples,
            self.corrected_pairs,
            self.mean_correction,
            self.power_downs,
            self.power_ups,
            self.idle_energy_mwh,
            self.static_idle_energy_mwh
        )
    }

    /// Stable JSON block (field order fixed by the Json substrate's
    /// BTreeMap) — joins the golden-traced report dumps.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "telemetry_samples",
                Json::num(self.telemetry_samples as f64),
            ),
            (
                "corrected_pairs",
                Json::num(self.corrected_pairs as f64),
            ),
            ("mean_correction", Json::num(self.mean_correction)),
            ("power_downs", Json::num(self.power_downs as f64)),
            ("power_ups", Json::num(self.power_ups as f64)),
            ("powered_node_s", Json::num(self.powered_node_s)),
            ("static_node_s", Json::num(self.static_node_s)),
            ("idle_energy_mwh", Json::num(self.idle_energy_mwh)),
            (
                "static_idle_energy_mwh",
                Json::num(self.static_idle_energy_mwh),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AdaptConfig {
        AdaptConfig::default()
    }

    #[test]
    fn default_config_validates_and_bad_configs_do_not() {
        cfg().validate().unwrap();
        for bad in [
            AdaptConfig { alpha: 0.0, ..cfg() },
            AdaptConfig { alpha: 1.5, ..cfg() },
            AdaptConfig { rate_alpha: 0.0, ..cfg() },
            AdaptConfig { max_correction: 0.5, ..cfg() },
            AdaptConfig { down_util: 0.8, up_util: 0.4, ..cfg() },
            AdaptConfig { min_powered: 0, ..cfg() },
            AdaptConfig { scale_interval_s: 0.0, ..cfg() },
            AdaptConfig { idle_power_w: -1.0, ..cfg() },
        ] {
            assert!(bad.validate().is_err(), "{bad:?}");
        }
    }

    #[test]
    fn synthesized_membership_config_never_crashes() {
        let m = cfg().membership_config();
        assert!(m.mtbf_s.is_infinite());
        assert_eq!(m.warmup_s, cfg().warmup_s);
        assert_eq!(m.warmup_penalty, cfg().warmup_penalty);
    }

    #[test]
    fn telemetry_converges_toward_a_constant_drift_ratio() {
        // observed costs 2x the profile: the published factor must
        // climb from 1.0 toward 2.0 and stay clamped below max.
        let mut t = Telemetry::new(&cfg(), 2);
        let id = PairId(0);
        assert_eq!(t.correction(id), 1.0);
        assert!(!t.active());
        for _ in 0..100 {
            t.observe(id, 0.01, 0.005, 0.02, 0.01);
        }
        let f = t.correction(id);
        assert!(
            (f - 2.0).abs() < 0.05,
            "correction {f} did not converge to 2.0"
        );
        assert!(t.active());
        assert_eq!(t.corrected_pairs(), 1);
        assert_eq!(t.samples(), 100);
        // the unobserved pair is untouched
        assert_eq!(t.correction(PairId(1)), 1.0);
        // and recovery: ground truth back to the profile pulls the
        // correction back down
        for _ in 0..100 {
            t.observe(id, 0.01, 0.005, 0.01, 0.005);
        }
        assert!((t.correction(id) - 1.0).abs() < 0.05);
    }

    #[test]
    fn ewma_correction_converges_under_drift_model() {
        // the satellite property test: feed DriftModel ground truth
        // (stale profile vs heated/throttled reality) through the
        // telemetry path and require the published correction to land
        // within tolerance of the drifted observed/predicted ratio.
        use crate::devices::drift::{DriftConfig, DriftModel};
        let dev = crate::devices::fleet()[0].clone();
        let mut dm = DriftModel::new(dev, DriftConfig::default(), 42);
        let mut t = Telemetry::new(&cfg(), 1);
        let id = PairId(0);
        let (base_lat, base_en) = (0.05, 0.02);
        let mut tail_ratio = 0.0;
        let mut tail_n = 0.0;
        for i in 0..800 {
            // back-to-back busy requests: the device heats, throttles,
            // and droops — exactly the regime ablation_drift runs
            let (lat, en) = dm.step(base_lat, base_en, 0.0);
            t.observe(id, base_lat, base_en, lat, en);
            if i >= 600 {
                tail_ratio += 0.5 * (lat / base_lat + en / base_en);
                tail_n += 1.0;
            }
        }
        let truth = tail_ratio / tail_n;
        assert!(
            (truth - 1.0).abs() > 0.05,
            "drift must actually move ground truth, ratio {truth}"
        );
        let f = t.correction(id);
        assert!(
            (f - truth).abs() / truth < 0.15,
            "correction {f} did not converge to drifted ratio {truth}"
        );
    }

    #[test]
    fn confidence_weighting_damps_early_observations() {
        let c = AdaptConfig { confidence: 10, ..cfg() };
        let mut t = Telemetry::new(&c, 1);
        let id = PairId(0);
        t.observe(id, 0.01, 0.005, 0.03, 0.015);
        let first = t.correction(id);
        assert!(
            first > 1.0 && first < 1.2,
            "one sample must barely move the profile, got {first}"
        );
        for _ in 0..50 {
            t.observe(id, 0.01, 0.005, 0.03, 0.015);
        }
        assert!(t.correction(id) > 2.0, "full confidence converges");
    }

    #[test]
    fn corrections_are_clamped_to_the_configured_range() {
        let c = AdaptConfig { max_correction: 1.5, ..cfg() };
        let mut t = Telemetry::new(&c, 1);
        let id = PairId(0);
        for _ in 0..200 {
            t.observe(id, 0.01, 0.005, 1.0, 0.5); // 100x blowup
        }
        assert_eq!(t.correction(id), 1.5);
        let mut t = Telemetry::new(&c, 1);
        for _ in 0..200 {
            t.observe(id, 1.0, 0.5, 0.001, 0.0005); // 1000x faster
        }
        assert!((t.correction(id) - 1.0 / 1.5).abs() < 1e-12);
    }

    #[test]
    fn periodic_mode_publishes_in_batches() {
        let c = AdaptConfig { publish_every: 10, ..cfg() };
        let mut t = Telemetry::new(&c, 1);
        let id = PairId(0);
        for _ in 0..9 {
            t.observe(id, 0.01, 0.005, 0.02, 0.01);
        }
        // live EWMA has moved, but routing still sees 1.0
        assert_eq!(t.correction(id), 1.0, "unpublished until the batch");
        assert!(!t.active());
        t.observe(id, 0.01, 0.005, 0.02, 0.01);
        assert!(t.correction(id) > 1.0, "10th observation publishes");
        assert!(t.active());
    }

    #[test]
    fn zero_predictions_are_ignored_not_divided_by() {
        let mut t = Telemetry::new(&cfg(), 1);
        let id = PairId(0);
        t.observe(id, 0.0, 0.0, 0.5, 0.5);
        assert_eq!(t.samples(), 0);
        assert_eq!(t.correction(id), 1.0);
        // out-of-range ids are a no-op
        t.observe(PairId(9), 0.1, 0.1, 0.2, 0.2);
        assert_eq!(t.samples(), 0);
    }

    #[test]
    fn scaler_rate_ewma_tracks_arrivals_and_hysteresis_holds() {
        let c = AdaptConfig {
            scale_interval_s: 1.0,
            rate_alpha: 0.5,
            ..cfg()
        };
        let mut sc = Scaler::new(&c, vec![true, true, true]);
        assert_eq!(sc.n_powered(), 3);
        assert_eq!(sc.initial_powered(), 3);
        // 10 arrivals in the first 1 s window, service 0.05 s each:
        // util = 10 * 0.05 / 3
        for _ in 0..10 {
            sc.note_arrival();
        }
        let util = sc.tick(1.0, |_| 0.05).unwrap();
        assert!((util - 10.0 * 0.05 / 3.0).abs() < 1e-9, "util {util}");
        // constant rate: the EWMA stays put, so the utilization signal
        // cannot flap between ticks
        for _ in 0..10 {
            sc.note_arrival();
        }
        let util2 = sc.tick(2.0, |_| 0.05).unwrap();
        assert!((util2 - util).abs() < 1e-9);
        // zero-dt tick is refused
        assert!(sc.tick(2.0, |_| 0.05).is_none());
    }

    #[test]
    fn scaler_power_accounting_charges_only_powered_seconds() {
        let c = cfg();
        let mut sc = Scaler::new(&c, vec![true, true]);
        sc.power_down(PairId(1), 4.0);
        assert_eq!(sc.n_powered(), 1);
        assert_eq!(sc.n_off(), 1);
        assert_eq!(sc.power_downs, 1);
        // node 0: 10 s powered; node 1: 4 s before power-down
        assert!((sc.powered_node_s(10.0) - 14.0).abs() < 1e-9);
        sc.power_up(PairId(1), 6.0);
        assert_eq!(sc.power_ups, 1);
        // node 1 adds 10 - 6 = 4 more powered seconds
        assert!((sc.powered_node_s(10.0) - 18.0).abs() < 1e-9);
        // double transitions are idempotent
        sc.power_up(PairId(1), 7.0);
        assert_eq!(sc.power_ups, 1);
        sc.power_down(PairId(0), 8.0);
        sc.power_down(PairId(0), 9.0);
        assert_eq!(sc.power_downs, 2);
        // undeployed ids can never power up
        let mut sc = Scaler::new(&c, vec![true, false]);
        assert_eq!(sc.initial_powered(), 1);
        sc.power_up(PairId(1), 1.0);
        assert!(!sc.is_powered(PairId(1)));
    }

    #[test]
    fn runtime_report_compares_against_the_static_fleet() {
        let c = AdaptConfig { idle_power_w: 3.6, ..cfg() };
        let mut rt = AdaptRuntime::new(&c, vec![true, true]);
        rt.telemetry.observe(PairId(0), 0.01, 0.005, 0.02, 0.01);
        rt.scaler.as_mut().unwrap().power_down(PairId(1), 2.0);
        let r = rt.report(2, 10.0);
        assert_eq!(r.telemetry_samples, 1);
        assert_eq!(r.corrected_pairs, 1);
        assert_eq!(r.power_downs, 1);
        assert!((r.powered_node_s - 12.0).abs() < 1e-9);
        assert!((r.static_node_s - 20.0).abs() < 1e-9);
        // 3.6 W for 12 s = 43.2 J = 12 mWh
        assert!((r.idle_energy_mwh - 12.0).abs() < 1e-9);
        assert!((r.static_idle_energy_mwh - 20.0).abs() < 1e-9);
        assert!((r.node_s_saved() - 8.0).abs() < 1e-9);
        let j = r.to_json();
        assert_eq!(
            j.req("telemetry_samples").unwrap().as_usize(),
            Some(1)
        );
        assert_eq!(j.req("power_downs").unwrap().as_usize(), Some(1));
        assert!(r.summary().contains("1 power-downs"));

        // scaler off: the fleet is the static fleet
        let c = AdaptConfig { scale: false, idle_power_w: 3.6, ..cfg() };
        let rt = AdaptRuntime::new(&c, vec![true, true]);
        let r = rt.report(2, 10.0);
        assert_eq!(r.powered_node_s, r.static_node_s);
        assert_eq!(r.idle_energy_mwh, r.static_idle_energy_mwh);
    }

    #[test]
    fn report_merge_sums_and_weights_the_mean() {
        let c = cfg();
        let mut a = AdaptRuntime::new(&c, vec![true]);
        let mut b = AdaptRuntime::new(&c, vec![true]);
        for _ in 0..50 {
            a.telemetry.observe(PairId(0), 0.01, 0.005, 0.02, 0.01);
            b.telemetry.observe(PairId(0), 0.01, 0.005, 0.01, 0.005);
        }
        let mut ra = a.report(1, 5.0);
        let rb = b.report(1, 5.0);
        let (ma, mb) = (ra.mean_correction, rb.mean_correction);
        ra.merge(&rb);
        assert_eq!(ra.telemetry_samples, 100);
        assert_eq!(ra.corrected_pairs, 2);
        assert!((ra.mean_correction - (ma + mb) / 2.0).abs() < 1e-9);
        assert!((ra.static_node_s - 10.0).abs() < 1e-9);
    }
}
