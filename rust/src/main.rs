//! `ecore` — leader entrypoint.
//!
//! Subcommands:
//!   profile     build the 8x8x5 profiling grid and print Table-1 picks
//!   experiment  run a paper experiment: fig2|fig4|fig5|table1|fig6|fig7|
//!               fig8|fig9|overhead|openloop|all
//!   serve       route one dataset through a chosen router and report;
//!               `--open-loop` switches to concurrent Poisson arrivals,
//!               `--fleet` to sharded multi-gateway fleet serving,
//!               `--churn` adds node crashes/rejoins with probe-driven
//!               membership and a resilience policy (either mode),
//!               `--campaign` layers a correlated failure campaign on
//!               top — domain-wide outages (either mode) and
//!               shard-gateway kills with deterministic re-sharding
//!               (fleet mode),
//!               `--adapt` turns on telemetry-driven profile correction
//!               and energy-proportional autoscaling (either mode),
//!               `--obs` turns on span tracing + virtual-time metrics
//!               with streaming export (either mode)
//!   trace       pretty-print an exported span trace (spans.jsonl)
//!   list        list models, devices, routers
//!
//! Common options: --delta <mAP pts> --images <n> --per-group <n>
//! --frames <n> --profile-per-group <n> --seed <n> --routers a,b,c
//! --config <file.toml>; open-loop options: --rate <req/s>
//! --queue-cap <n> --rates r1,r2,r3; fleet options: --nodes <n>
//! --shards <k> --dispatch hash|least|sticky, and for the sweep
//! --fleet-sizes a,b --fleet-shards a,b --fleet-routers a,b
//! --fleet-rate <req/s> --fleet-requests <n> --fleet-perturb <f>;
//! churn options: --mtbf <s> --mttr <s> --resilience drop|retry|hedge
//! --retry-budget <n> --probe-interval <s> --warmup <s>
//! --hedge-cancel, and for the
//! sweep --churn-availability a,b --churn-policies a,b
//! --churn-routers a,b --churn-rate <req/s> --churn-requests <n>;
//! campaign options: --campaign --domain-size <n> --domain-mtbf <s>
//! --domain-mttr <s> --gateway-mtbf <s> --gateway-mttr <s>, and for
//! the sweep --campaign-domain-sizes a,b --campaign-outage-rates a,b
//! --campaign-routers a,b --campaign-policies a,b
//! --campaign-rate <req/s> --campaign-requests <n> --no-escalate;
//! slo options: --slo --slo-classes name:d,name:d --batch-window <s>
//! --max-batch <n>, and for the sweep --slo-rates a,b
//! --slo-windows a,b --slo-routers a,b --slo-requests <n>;
//! adapt options: --adapt --adapt-alpha <f> --adapt-no-scale
//! --adapt-interval <s> --adapt-publish-every <n>, and for the sweep
//! --adapt-routers a,b --adapt-drift a,b --adapt-rate <req/s>
//! --adapt-requests <n>; obs options: --obs --obs-tick <s>
//! --obs-out <dir> --obs-span-head <n> --obs-span-tail <n>
//! --obs-span-sample <n>

use anyhow::Result;

use ecore::config::{ExperimentConfig, Table};
use ecore::experiments::{Harness, ALL_EXPERIMENTS};
use ecore::gateway::{paper_routers, router_by_name};
use ecore::util::cli::Args;

const USAGE: &str = "\
ecore — energy-conscious optimized routing (paper reproduction)

USAGE:
  ecore profile    [--profile-per-group N] [--seed S]
  ecore experiment <id|all> [--images N] [--delta D] [--routers a,b,c]
                   [--rates r1,r2,r3] [--queue-cap N]
                   [--fleet-sizes a,b] [--fleet-shards a,b]
                   [--fleet-routers a,b] [--fleet-rate R]
                   [--fleet-requests N] [--dispatch hash|least|sticky]
                   [--threads N]
  ecore serve      [--router ED] [--dataset coco|balanced] [--images N]
                   [--open-loop] [--rate R] [--queue-cap N]
                   [--fleet] [--nodes N] [--shards K]
                   [--dispatch hash|least|sticky] [--threads N]
                   [--churn] [--mtbf S] [--mttr S]
                   [--resilience drop|retry|hedge] [--hedge-cancel]
                   [--campaign] [--domain-size N] [--domain-mtbf S]
                   [--domain-mttr S] [--gateway-mtbf S]
                   [--gateway-mttr S]
                   [--slo] [--slo-classes name:d,name:d]
                   [--batch-window S] [--max-batch N]
                   [--adapt] [--adapt-alpha F] [--adapt-no-scale]
                   [--adapt-interval S]
                   [--obs] [--obs-tick S] [--obs-out DIR]
  ecore trace      [--obs-out DIR] [--idx N] [--kind NAME] [--limit N]
  ecore list

experiments: fig2 fig4 fig5 table1 fig6 fig7 fig8 fig9 overhead openloop
             fleet churn slo adapt campaign
";

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "help" {
        print!("{USAGE}");
        return Ok(());
    }
    let cmd = argv[0].clone();
    let args = Args::parse(argv.into_iter().skip(1));
    if args.warn_swallowed() {
        anyhow::bail!(
            "option(s) missing a value (use --key=value if the value \
             starts with `--`)"
        );
    }

    let mut cfg = match args.get("config") {
        Some(path) => {
            ExperimentConfig::from_table(&Table::load(path.as_ref())?)
        }
        None => ExperimentConfig::default(),
    };
    cfg.override_with(&args);

    match cmd.as_str() {
        "profile" => {
            let h = Harness::new(cfg)?;
            let store = h.profiles()?;
            println!(
                "profiled {} rows over {} pairs",
                store.rows().len(),
                store.pairs().len()
            );
            h.run("table1")
        }
        "experiment" => {
            let id = args
                .positional
                .first()
                .map(|s| s.as_str())
                .unwrap_or("all");
            let h = Harness::new(cfg)?;
            h.run(id)
        }
        "serve" => {
            let router = args.str_or("router", "ED");
            let spec = router_by_name(&router).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown router '{router}' (known: {})",
                    paper_routers()
                        .iter()
                        .map(|r| r.name)
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })?;
            let h = Harness::new(cfg)?;
            let deployed = ecore::experiments::serve::deployed_store(&h)?;
            let dataset = match args.str_or("dataset", "coco").as_str() {
                "balanced" => ecore::dataset::balanced::build(
                    h.cfg.balanced_per_group,
                    h.cfg.seed,
                ),
                "coco" => ecore::dataset::coco::build(
                    h.cfg.coco_images,
                    h.cfg.seed,
                ),
                other => anyhow::bail!(
                    "unknown dataset '{other}' (coco|balanced; video is fig8)"
                ),
            };
            let campaign_cfg = if args.flag("campaign") {
                Some(h.cfg.campaign_config()?)
            } else {
                None
            };
            let churn_cfg = if args.flag("churn") {
                Some(h.cfg.churn_config()?)
            } else if campaign_cfg.is_some() {
                // --campaign implies probe-driven membership; without
                // an explicit --churn the per-node crash process is
                // silenced and only the campaign schedule injects
                // failures
                let mut c = h.cfg.churn_config()?;
                c.mtbf_s = f64::INFINITY;
                Some(c)
            } else {
                None
            };
            let slo_cfg = if args.flag("slo") {
                Some(h.cfg.slo_config()?)
            } else {
                None
            };
            let adapt_cfg = if args.flag("adapt") {
                Some(h.cfg.adapt_config()?)
            } else {
                None
            };
            let obs_cfg = if args.flag("obs") {
                Some(h.cfg.obs_config()?)
            } else {
                None
            };
            if args.flag("fleet") {
                let dispatch_s =
                    args.str_or("dispatch", &h.cfg.fleet_dispatch);
                let dispatch =
                    ecore::fleet::DispatchPolicy::parse(&dispatch_s)
                        .ok_or_else(|| {
                            anyhow::anyhow!(
                                "unknown dispatch '{dispatch_s}' (hash|least|sticky)"
                            )
                        })?;
                let fleet_cfg = ecore::fleet::FleetConfig {
                    n_nodes: args.usize_or("nodes", 24),
                    n_shards: args.usize_or("shards", 4),
                    perturb: h.cfg.fleet_perturb,
                    queue_capacity: h.cfg.queue_capacity,
                    dispatch,
                    n_sources: h.cfg.fleet_sources,
                    seed: h.cfg.seed,
                    drift: None,
                    churn: churn_cfg.clone(),
                    slo: slo_cfg.clone(),
                    adapt: adapt_cfg.clone(),
                    campaign: campaign_cfg.clone(),
                    obs: obs_cfg.clone(),
                    threads: h.cfg.fleet_threads,
                };
                let frames: Vec<ecore::dataset::Scene> =
                    dataset.iter_scenes().collect();
                let gts: Vec<Vec<ecore::dataset::GtBox>> =
                    frames.iter().map(|s| s.gt.clone()).collect();
                let report = ecore::fleet::parallel::run_frames_threads(
                    &ecore::fleet::parallel::ParallelFleetSpec {
                        artifacts_dir: h.artifacts_dir(),
                        base: &deployed,
                        spec,
                        delta_map: h.cfg.delta_map,
                    },
                    &fleet_cfg,
                    &frames,
                    &gts,
                    &ecore::workload::openloop::ArrivalProcess::Poisson {
                        rate_rps: h.cfg.rate_rps,
                    },
                    h.cfg.seed,
                )?;
                println!(
                    "--- serve --fleet ({} over {} nodes / {} shards, {} dispatch, {} req/s) ---",
                    spec.name,
                    fleet_cfg.n_nodes,
                    fleet_cfg.n_shards,
                    dispatch.label(),
                    h.cfg.rate_rps
                );
                println!(
                    "served {}/{} (dropped {}, node fallbacks {}, cross-shard {}), goodput {:.2} req/s over {:.2} s",
                    report.requests(),
                    report.offered,
                    report.dropped,
                    report.node_fallbacks,
                    report.cross_shard_fallbacks,
                    report.goodput_rps(),
                    report.makespan_s
                );
                println!(
                    "latency p50/p95/p99 = {:.1}/{:.1}/{:.1} ms, mean queue delay {:.1} ms, shard imbalance {:.2}, peak in-flight {}",
                    1000.0 * report.latency_percentile(50.0),
                    1000.0 * report.latency_percentile(95.0),
                    1000.0 * report.latency_percentile(99.0),
                    1000.0 * report.mean_queue_delay_s(),
                    report.shard_imbalance(),
                    report.peak_in_flight
                );
                println!(
                    "mAP {:.2}, energy {:.2} mWh ({:.4} mWh/request)",
                    report.map(),
                    report.total_energy_mwh(),
                    report.energy_per_request_mwh()
                );
                if let Some(c) = &report.churn {
                    println!("{}", c.summary());
                }
                if let Some(c) = &report.campaign {
                    println!("{}", c.summary());
                }
                if let Some(s) = &report.slo {
                    print_slo(s);
                }
                if let Some(a) = &report.adapt {
                    println!("{}", a.summary());
                }
                if let Some(o) = &obs_cfg {
                    if !o.out_dir.is_empty() {
                        println!("obs export: {}", o.out_dir);
                    }
                }
                return Ok(());
            }
            if args.flag("open-loop")
                || args.flag("churn")
                || args.flag("slo")
                || args.flag("adapt")
                || args.flag("obs")
                || args.flag("campaign")
            {
                let mut gw = ecore::experiments::serve::build_gateway(
                    &h,
                    spec,
                    &deployed,
                    h.cfg.delta_map,
                )?;
                let report = ecore::workload::openloop::run_dataset(
                    &mut gw,
                    &dataset,
                    &ecore::workload::openloop::OpenLoopConfig {
                        arrivals:
                            ecore::workload::openloop::ArrivalProcess::Poisson {
                                rate_rps: h.cfg.rate_rps,
                            },
                        queue_capacity: h.cfg.queue_capacity,
                        seed: h.cfg.seed,
                        churn: churn_cfg,
                        slo: slo_cfg,
                        adapt: adapt_cfg,
                        campaign: campaign_cfg,
                        obs: obs_cfg.clone(),
                    },
                )?;
                let m = &report.metrics;
                println!(
                    "--- serve --open-loop ({} @ {} req/s, queue cap {}) ---",
                    spec.name, h.cfg.rate_rps, h.cfg.queue_capacity
                );
                println!(
                    "served {}/{} (dropped {}, fallbacks {}), goodput {:.2} req/s over {:.2} s",
                    m.requests,
                    report.offered,
                    report.dropped,
                    report.fallbacks,
                    report.goodput_rps(),
                    report.makespan_s
                );
                println!(
                    "latency p50/p95/p99 = {:.1}/{:.1}/{:.1} ms, mean queue delay {:.1} ms, peak in-flight {}",
                    1000.0 * m.latency_percentile(50.0),
                    1000.0 * m.latency_percentile(95.0),
                    1000.0 * m.latency_percentile(99.0),
                    1000.0 * m.mean_queue_delay_s(),
                    report.peak_in_flight
                );
                println!(
                    "mAP {:.2}, energy {:.2} mWh (gateway {:.3} mWh)",
                    m.map(),
                    m.total_energy_mwh(),
                    m.gateway_energy_mwh
                );
                if let Some(c) = &report.churn {
                    println!("{}", c.summary());
                }
                if let Some(c) = &report.campaign {
                    println!("{}", c.summary());
                }
                if let Some(s) = &report.slo {
                    print_slo(s);
                }
                if let Some(a) = &report.adapt {
                    println!("{}", a.summary());
                }
                if let Some(o) = &obs_cfg {
                    if !o.out_dir.is_empty() {
                        println!("obs export: {}", o.out_dir);
                    }
                }
                return Ok(());
            }
            let m = ecore::experiments::serve::run_router_on_dataset(
                &h, spec, &deployed, &dataset,
            )?;
            ecore::experiments::serve::print_panel("serve", &[m]);
            Ok(())
        }
        "trace" => trace_cmd(&args, &cfg),
        "list" => {
            let h = Harness::new(cfg)?;
            println!("experiments: {}", ALL_EXPERIMENTS.join(" "));
            println!(
                "routers: {}",
                paper_routers()
                    .iter()
                    .map(|r| r.name)
                    .collect::<Vec<_>>()
                    .join(" ")
            );
            println!("devices:");
            for d in ecore::devices::fleet() {
                println!("  {:<18} accel={:?}", d.name, d.accel);
            }
            println!("models:");
            for m in h.engine.registry().backend_models() {
                println!(
                    "  {:<14} res={} k={} flops={:.1}M",
                    m.name,
                    m.res,
                    m.k,
                    m.flops / 1e6
                );
            }
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n{USAGE}");
            std::process::exit(2);
        }
    }
}

/// `ecore trace`: pretty-print an exported span trace. Reads
/// `<dir>/spans.jsonl` (dir from `--obs-out`, falling back to the
/// configured obs output directory) and prints one line per retained
/// event, optionally filtered by request (`--idx`) and event kind
/// (`--kind`). `--limit N` stops after N requests (0 = all).
fn trace_cmd(args: &Args, cfg: &ExperimentConfig) -> Result<()> {
    let dir = args.str_or("obs-out", &cfg.obs_out);
    let path = std::path::Path::new(&dir).join("spans.jsonl");
    let text = std::fs::read_to_string(&path).map_err(|e| {
        anyhow::anyhow!(
            "cannot read {}: {e} (run `ecore serve --obs` first)",
            path.display()
        )
    })?;
    let want_idx = args.get("idx").and_then(|v| v.parse::<f64>().ok());
    let want_kind = args.get("kind");
    let limit = args.usize_or("limit", 0);
    let mut shown = 0usize;
    for line in text.lines().filter(|l| !l.trim().is_empty()) {
        let v = ecore::util::json::parse(line)?;
        let idx = v.req("idx")?.as_f64().unwrap_or(-1.0);
        if want_idx.is_some_and(|w| w != idx) {
            continue;
        }
        let events = v.req("events")?.as_arr().unwrap_or(&[]);
        let mut rows: Vec<String> = Vec::new();
        for e in events {
            let kind = e.req("kind")?.as_str().unwrap_or("?");
            if want_kind.is_some_and(|w| w != kind) {
                continue;
            }
            let t = e.req("t")?.as_f64().unwrap_or(f64::NAN);
            let shard = e.req("shard")?.as_f64().unwrap_or(-1.0);
            let pair = e.req("pair")?.as_f64().unwrap_or(-1.0);
            let vv = e.req("v")?.as_f64().unwrap_or(0.0);
            let ee = e.req("e")?.as_f64().unwrap_or(0.0);
            // run-level events carry the spine sentinel shard id
            let shard_s = if shard == f64::from(u32::MAX) {
                "spine".to_string()
            } else {
                format!("{shard:.0}")
            };
            rows.push(format!(
                "  {t:>12.6}  {kind:<10} shard={shard_s:<5} \
                 pair={pair:.0} v={vv} e={ee}"
            ));
        }
        if rows.is_empty() {
            continue;
        }
        println!("req {idx:.0}:");
        for r in rows {
            println!("{r}");
        }
        shown += 1;
        if limit > 0 && shown >= limit {
            break;
        }
    }
    if shown == 0 {
        println!("no spans matched");
    }
    Ok(())
}

fn print_slo(s: &ecore::metrics::SloMetrics) {
    let per: Vec<String> = s
        .classes
        .iter()
        .enumerate()
        .map(|(i, name)| format!("{name} {:.1}%", s.attainment_pct(i)))
        .collect();
    println!(
        "SLO attainment {:.1}% ({}), mean batch size {:.2}",
        s.overall_attainment_pct(),
        per.join(", "),
        s.mean_batch_size()
    );
}
