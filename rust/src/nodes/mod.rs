//! Backend edge-node pool.
//!
//! Each [`EdgeNode`] binds one detector artifact to one simulated device:
//! a request executes *real* PJRT inference (accuracy is measured, never
//! tabulated) while latency/energy come from the device model, with a
//! small deterministic per-request jitter for realism. The pool is the
//! deployed testbed (Table 1 pairs).

use std::sync::Arc;

use anyhow::Result;

use crate::detection::{decode_heatmap, Detection};
use crate::devices::drift::{DriftConfig, DriftModel};
use crate::devices::{DeviceSpec, ExecProfile};
use crate::models::ModelMeta;
use crate::router::{PairId, PairKey, PairTable};
use crate::runtime::Engine;
use crate::util::rng::Rng;

/// Multiplicative latency jitter amplitude (+/-3%).
const JITTER: f64 = 0.03;

/// Default bounded per-node FIFO capacity (in-service slot included).
/// Closed-loop runs never hold more than one request in the system, so
/// any capacity >= 1 leaves the piggybacked protocol untouched; the
/// open-loop driver overrides this via [`NodePool::set_queue_capacity`].
pub const DEFAULT_QUEUE_CAPACITY: usize = 8;

/// Marker error returned by [`EdgeNode::process_at`] when the node's
/// ground-truth health is down. Churn drivers downcast to this
/// (`err.is::<NodeDown>()`) to lose the request through the resilience
/// policy — a dispatch onto a crashed node the membership view has not
/// caught up with yet; any other processing error is real
/// infrastructure failure and must propagate.
#[derive(Clone, Debug)]
pub struct NodeDown(pub PairKey);

impl std::fmt::Display for NodeDown {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "node {} is down", self.0)
    }
}

impl std::error::Error for NodeDown {}

/// Result of processing one request on a node.
#[derive(Clone, Debug)]
pub struct NodeResponse {
    pub detections: Vec<Detection>,
    /// Simulated service time on the device (s).
    pub latency_s: f64,
    /// Simulated dynamic energy (mWh).
    pub energy_mwh: f64,
}

/// One deployed (model, device) endpoint.
pub struct EdgeNode {
    pub pair: PairKey,
    meta: ModelMeta,
    device: DeviceSpec,
    base: ExecProfile,
    rng: Rng,
    pub requests_served: usize,
    /// Health flag: failed nodes reject requests and the gateway falls
    /// back to the next-best feasible pair (failure injection in tests).
    pub healthy: bool,
    /// Requests currently in this node's system (queued + in service).
    /// Maintained by the open-loop driver via [`NodePool::acquire`] /
    /// [`NodePool::release`]; stays 0 under the closed-loop protocol.
    pub in_flight: usize,
    /// Optional runtime drift (paper Future Work #1); None = static.
    drift: Option<DriftModel>,
    /// Virtual timestamp of the last service completion (for idle gaps).
    last_busy_end_s: f64,
    /// Reusable output buffer (avoids one large copy per request).
    heat_buf: Vec<f32>,
}

impl EdgeNode {
    pub fn new(
        engine: &Engine,
        pair: PairKey,
        device: DeviceSpec,
        seed: u64,
    ) -> Result<Self> {
        let meta = engine.meta(&pair.model)?;
        let base = device.profile(&meta);
        Ok(Self {
            pair,
            meta,
            device,
            base,
            rng: Rng::new(seed),
            requests_served: 0,
            healthy: true,
            in_flight: 0,
            drift: None,
            last_busy_end_s: 0.0,
            heat_buf: Vec::new(),
        })
    }

    /// Enable runtime drift (thermal throttling, battery droop,
    /// background load) on this node.
    pub fn enable_drift(&mut self, cfg: DriftConfig, seed: u64) {
        self.drift = Some(DriftModel::new(self.device.clone(), cfg, seed));
    }

    /// Current drift temperature (0 for static nodes) — metrics hook.
    pub fn temperature(&self) -> f64 {
        self.drift.as_ref().map(|d| d.temperature()).unwrap_or(0.0)
    }

    pub fn device(&self) -> &DeviceSpec {
        &self.device
    }

    /// Process one image: real inference + simulated cost.
    ///
    /// `now_s` is the gateway's virtual clock, used to account idle
    /// cooling in the drift model (pass 0.0 when drift is off).
    pub fn process_at(
        &mut self,
        engine: &Engine,
        image: &[f32],
        now_s: f64,
    ) -> Result<NodeResponse> {
        if !self.healthy {
            return Err(anyhow::Error::new(NodeDown(self.pair.clone())));
        }
        let mut heat = std::mem::take(&mut self.heat_buf);
        engine.infer_into(&self.pair.model, image, &mut heat)?;
        let detections =
            decode_heatmap(&heat, &self.meta, self.base.threshold_scale);
        self.heat_buf = heat;
        let jitter = 1.0 + JITTER * (2.0 * self.rng.f64() - 1.0);
        let mut latency_s = self.base.latency_s * jitter;
        let mut energy_mwh = self.base.energy_mwh * jitter;
        if let Some(d) = self.drift.as_mut() {
            let idle = (now_s - self.last_busy_end_s).max(0.0);
            let (l, e) = d.step(latency_s, energy_mwh, idle);
            latency_s = l;
            energy_mwh = e;
            self.last_busy_end_s = now_s + latency_s;
        }
        self.requests_served += 1;
        Ok(NodeResponse {
            detections,
            latency_s,
            energy_mwh,
        })
    }

    /// Process with no drift-clock context.
    pub fn process(&mut self, engine: &Engine, image: &[f32]) -> Result<NodeResponse> {
        self.process_at(engine, image, 0.0)
    }

    /// THE admission predicate: healthy and below the queue bound.
    /// Every health/capacity check in the pool funnels through here (or
    /// [`EdgeNode::has_slot`]), so the lifecycle layer has exactly one
    /// point to reason about.
    pub fn admits(&self, queue_capacity: usize) -> bool {
        self.healthy && self.has_slot(queue_capacity)
    }

    /// Capacity half of the admission predicate, ignoring health.
    /// Queue occupancy is gateway-side knowledge (the driver maintains
    /// `in_flight` locally), so churn gateways — which only *believe*
    /// health through probes — still check slots exactly.
    pub fn has_slot(&self, queue_capacity: usize) -> bool {
        self.in_flight < queue_capacity
    }

    /// A crashed node coming back: reboot resets the drift model's
    /// thermal/background-load state (a rebooted board is cold) and the
    /// idle clock. Battery droop persists — reboots do not recharge.
    pub fn on_rejoin(&mut self, now_s: f64) {
        self.last_busy_end_s = now_s;
        if let Some(d) = self.drift.as_mut() {
            d.reboot();
        }
    }
}

/// The deployed pool, indexed by pair.
///
/// Binding the pool to a routing table ([`NodePool::bind_table`])
/// additionally indexes nodes by interned [`PairId`], making every
/// `_id` accessor an O(1) array hit — the gateway's per-request
/// admission checks and slot accounting run on that path with zero
/// string comparisons. The key-based accessors stay available for
/// drivers and tests that work outside a routing table.
pub struct NodePool {
    nodes: Vec<EdgeNode>,
    /// Bounded FIFO capacity shared by every node (queued + in service).
    queue_capacity: usize,
    /// `PairId -> node index` under the bound table (`None` = no node
    /// deployed for that pair); empty until [`NodePool::bind_table`].
    node_of: Vec<Option<u32>>,
    /// The routing table this pool is bound to, if any.
    table: Option<Arc<PairTable>>,
}

impl NodePool {
    /// Deploy one node per pair; preloads every artifact.
    pub fn deploy(
        engine: &Engine,
        pairs: &[PairKey],
        fleet: &[DeviceSpec],
        seed: u64,
    ) -> Result<Self> {
        let mut nodes = Vec::with_capacity(pairs.len());
        for (i, pair) in pairs.iter().enumerate() {
            let device = crate::devices::find(fleet, &pair.device)
                .ok_or_else(|| {
                    anyhow::anyhow!("unknown device '{}'", pair.device)
                })?;
            nodes.push(EdgeNode::new(
                engine,
                pair.clone(),
                device,
                seed.wrapping_add(i as u64),
            )?);
        }
        let names: Vec<&str> =
            pairs.iter().map(|p| p.model.as_str()).collect();
        engine.preload(&names)?;
        Ok(Self {
            nodes,
            queue_capacity: DEFAULT_QUEUE_CAPACITY,
            node_of: Vec::new(),
            table: None,
        })
    }

    /// Wrap already-constructed nodes. The fleet builder uses this:
    /// synthesized nodes carry per-unit perturbed `DeviceSpec`s that
    /// exist nowhere in the base device table, so `deploy`'s
    /// lookup-by-name path does not apply. Callers are responsible for
    /// preloading the artifacts the nodes reference.
    pub fn from_nodes(nodes: Vec<EdgeNode>) -> Self {
        Self {
            nodes,
            queue_capacity: DEFAULT_QUEUE_CAPACITY,
            node_of: Vec::new(),
            table: None,
        }
    }

    /// Bind this pool to a routing table, indexing nodes by interned
    /// [`PairId`] so the `_id` accessors are O(1). Pairs without a
    /// deployed node stay unroutable (`None`); when several nodes share
    /// a pair, the first one wins — matching the key-based linear scan.
    /// The gateway binds its pool to its store's table at construction.
    pub fn bind_table(&mut self, table: Arc<PairTable>) {
        let mut node_of = vec![None; table.len()];
        for (i, n) in self.nodes.iter().enumerate() {
            if let Some(id) = table.id_of(&n.pair) {
                let slot = &mut node_of[id.index()];
                if slot.is_none() {
                    *slot = Some(i as u32);
                }
            }
        }
        self.node_of = node_of;
        self.table = Some(table);
    }

    /// The routing table this pool is bound to, if any.
    pub fn bound_table(&self) -> Option<&Arc<PairTable>> {
        self.table.as_ref()
    }

    #[inline]
    fn node_index(&self, id: PairId) -> Option<usize> {
        self.node_of
            .get(id.index())
            .copied()
            .flatten()
            .map(|i| i as usize)
    }

    /// O(1) node access by interned id (None when the pair has no
    /// deployed node or the pool is unbound).
    pub fn get_id(&mut self, id: PairId) -> Option<&mut EdgeNode> {
        let i = self.node_index(id)?;
        Some(&mut self.nodes[i])
    }

    /// The deployed node's device spec by interned id — O(1). Batch
    /// amortization reads `preprocess_s`/`cpu_dyn_power_w` through this
    /// without taking the mutable node borrow `get_id` requires.
    pub fn device_of_id(&self, id: PairId) -> Option<&DeviceSpec> {
        self.node_index(id).map(|i| self.nodes[i].device())
    }

    /// [`NodePool::is_available`] by interned id — O(1).
    pub fn is_available_id(&self, id: PairId) -> bool {
        self.node_index(id)
            .map(|i| self.nodes[i].admits(self.queue_capacity))
            .unwrap_or(false)
    }

    /// [`NodePool::has_slot`] by interned id — O(1).
    pub fn has_slot_id(&self, id: PairId) -> bool {
        self.node_index(id)
            .map(|i| self.nodes[i].has_slot(self.queue_capacity))
            .unwrap_or(false)
    }

    /// [`NodePool::is_healthy`] by interned id — O(1).
    pub fn is_healthy_id(&self, id: PairId) -> bool {
        self.node_index(id)
            .map(|i| self.nodes[i].healthy)
            .unwrap_or(false)
    }

    /// [`NodePool::queue_depth`] by interned id — O(1).
    pub fn queue_depth_id(&self, id: PairId) -> usize {
        self.node_index(id)
            .map(|i| self.nodes[i].in_flight)
            .unwrap_or(0)
    }

    /// [`NodePool::acquire`] by interned id — O(1).
    pub fn acquire_id(&mut self, id: PairId) -> bool {
        let cap = self.queue_capacity;
        match self.node_index(id) {
            Some(i) if self.nodes[i].has_slot(cap) => {
                self.nodes[i].in_flight += 1;
                true
            }
            _ => false,
        }
    }

    /// [`NodePool::release`] by interned id — O(1).
    pub fn release_id(&mut self, id: PairId) {
        if let Some(i) = self.node_index(id) {
            let n = &mut self.nodes[i];
            n.in_flight = n.in_flight.saturating_sub(1);
        }
    }

    /// [`NodePool::set_health`] by interned id — O(1).
    pub fn set_health_id(&mut self, id: PairId, healthy: bool) -> bool {
        match self.node_index(id) {
            Some(i) => {
                self.nodes[i].healthy = healthy;
                true
            }
            None => false,
        }
    }

    /// Requests currently in this pool's system across all nodes
    /// (queued + in service). The fleet driver keeps its own O(1)
    /// per-shard counters for dispatch; this scan is the ground truth
    /// those counters are checked against (and a monitoring hook).
    pub fn total_in_flight(&self) -> usize {
        self.nodes.iter().map(|n| n.in_flight).sum()
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn get(&mut self, pair: &PairKey) -> Option<&mut EdgeNode> {
        self.nodes.iter_mut().find(|n| &n.pair == pair)
    }

    pub fn nodes(&self) -> &[EdgeNode] {
        &self.nodes
    }

    pub fn nodes_mut(&mut self) -> &mut [EdgeNode] {
        &mut self.nodes
    }

    /// Enable drift on every node (distinct seeds).
    pub fn enable_drift(&mut self, cfg: &DriftConfig, seed: u64) {
        for (i, n) in self.nodes.iter_mut().enumerate() {
            n.enable_drift(cfg.clone(), seed.wrapping_add(i as u64));
        }
    }

    /// Mark one pair unhealthy (failure injection). Returns true if the
    /// pair existed.
    pub fn set_health(&mut self, pair: &PairKey, healthy: bool) -> bool {
        if let Some(n) = self.nodes.iter_mut().find(|n| &n.pair == pair) {
            n.healthy = healthy;
            true
        } else {
            false
        }
    }

    /// Health only — ignores queue occupancy. Admission decisions
    /// should use [`NodePool::is_available`] instead.
    pub fn is_healthy(&self, pair: &PairKey) -> bool {
        self.nodes
            .iter()
            .find(|n| &n.pair == pair)
            .map(|n| n.healthy)
            .unwrap_or(false)
    }

    /// Bounded FIFO capacity per node (queued + in service).
    pub fn queue_capacity(&self) -> usize {
        self.queue_capacity
    }

    /// Set the per-node queue bound (>= 1). The open-loop driver sets
    /// this from its config; the closed loop never exceeds depth 1.
    pub fn set_queue_capacity(&mut self, capacity: usize) {
        self.queue_capacity = capacity.max(1);
    }

    /// Requests currently in `pair`'s system (queued + in service);
    /// 0 for unknown pairs.
    pub fn queue_depth(&self, pair: &PairKey) -> usize {
        self.nodes
            .iter()
            .find(|n| &n.pair == pair)
            .map(|n| n.in_flight)
            .unwrap_or(0)
    }

    /// Can `pair` accept a new request? [`EdgeNode::admits`]: healthy
    /// *and* below the queue bound — the routing-time admission check
    /// for both loops (closed loop: depth is always 0, so this reduces
    /// to the health check).
    pub fn is_available(&self, pair: &PairKey) -> bool {
        self.nodes
            .iter()
            .find(|n| &n.pair == pair)
            .map(|n| n.admits(self.queue_capacity))
            .unwrap_or(false)
    }

    /// Capacity-only admission ([`EdgeNode::has_slot`]): what a churn
    /// gateway checks at routing time, where ground-truth health is
    /// replaced by the probe-driven membership view.
    pub fn has_slot(&self, pair: &PairKey) -> bool {
        self.nodes
            .iter()
            .find(|n| &n.pair == pair)
            .map(|n| n.has_slot(self.queue_capacity))
            .unwrap_or(false)
    }

    /// Claim one queue slot on `pair` (arrival admitted by the router).
    /// Returns false if the pair is unknown or already at capacity.
    /// Deliberately ignores health: a stale-view gateway *can* enqueue
    /// onto a crashed node — the dispatch then fails and the resilience
    /// policy takes over.
    pub fn acquire(&mut self, pair: &PairKey) -> bool {
        let cap = self.queue_capacity;
        if let Some(n) = self.nodes.iter_mut().find(|n| &n.pair == pair) {
            if n.has_slot(cap) {
                n.in_flight += 1;
                return true;
            }
        }
        false
    }

    /// Free one queue slot on `pair` (response left the system).
    pub fn release(&mut self, pair: &PairKey) {
        if let Some(n) = self.nodes.iter_mut().find(|n| &n.pair == pair) {
            n.in_flight = n.in_flight.saturating_sub(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{scene, SceneSpec};
    use crate::devices;

    fn engine() -> Engine {
        Engine::new(&crate::default_artifacts_dir()).unwrap()
    }

    #[test]
    fn node_processes_and_costs_match_device_model() {
        let e = engine();
        let fleet = devices::fleet();
        let pair = PairKey::new("ssd_v1", "pi5");
        let mut node = EdgeNode::new(
            &e,
            pair,
            devices::find(&fleet, "pi5").unwrap(),
            1,
        )
        .unwrap();
        let s = scene::render_spec(&SceneSpec {
            id: 0,
            seed: 3,
            n_objects: 1,
        });
        let r = node.process(&e, &s.image).unwrap();
        let base = node.base;
        assert!((r.latency_s - base.latency_s).abs()
            <= JITTER * base.latency_s + 1e-12);
        assert!((r.energy_mwh - base.energy_mwh).abs()
            <= JITTER * base.energy_mwh + 1e-12);
        assert_eq!(node.requests_served, 1);
    }

    #[test]
    fn pool_deploys_and_routes_by_pair() {
        let e = engine();
        let fleet = devices::fleet();
        let pairs = vec![
            PairKey::new("ssd_v1", "jetson_orin_nano"),
            PairKey::new("yolov8n", "pi5_aihat"),
        ];
        let mut pool = NodePool::deploy(&e, &pairs, &fleet, 5).unwrap();
        assert_eq!(pool.len(), 2);
        assert!(pool.get(&pairs[1]).is_some());
        assert!(pool.get(&PairKey::new("ssd_v1", "pi3")).is_none());
        let img = vec![0.5f32; 384 * 384];
        let r = pool
            .get(&pairs[0])
            .unwrap()
            .process(&e, &img)
            .unwrap();
        assert!(r.detections.is_empty()); // constant image
        assert!(r.latency_s > 0.0);
    }

    #[test]
    fn queue_occupancy_bounds_availability() {
        let e = engine();
        let fleet = devices::fleet();
        let pair = PairKey::new("ssd_v1", "jetson_orin_nano");
        let mut pool =
            NodePool::deploy(&e, &[pair.clone()], &fleet, 1).unwrap();
        pool.set_queue_capacity(2);
        assert_eq!(pool.queue_depth(&pair), 0);
        assert!(pool.is_available(&pair));
        assert!(pool.acquire(&pair));
        assert!(pool.acquire(&pair));
        // at capacity: full, and a further acquire is rejected
        assert_eq!(pool.queue_depth(&pair), 2);
        assert!(!pool.is_available(&pair));
        assert!(!pool.acquire(&pair));
        pool.release(&pair);
        assert!(pool.is_available(&pair));
        // unhealthy trumps free capacity for admits/is_available, but
        // has_slot (the churn gateway's capacity half) still reports
        // the free slot, and acquire still succeeds — stale-view
        // gateways can enqueue onto a crashed node
        pool.set_health(&pair, false);
        assert!(!pool.is_available(&pair));
        assert!(pool.has_slot(&pair));
        assert!(pool.acquire(&pair));
        pool.release(&pair);
        // unknown pairs are never available and release is a no-op
        let ghost = PairKey::new("ssd_v1", "pi3");
        assert!(!pool.is_available(&ghost));
        assert!(!pool.has_slot(&ghost));
        pool.release(&ghost);
    }

    #[test]
    fn down_node_returns_typed_node_down_error() {
        let e = engine();
        let fleet = devices::fleet();
        let pair = PairKey::new("ssd_v1", "pi5");
        let mut node = EdgeNode::new(
            &e,
            pair.clone(),
            devices::find(&fleet, "pi5").unwrap(),
            1,
        )
        .unwrap();
        node.healthy = false;
        let img = vec![0.5f32; 384 * 384];
        let err = node.process(&e, &img).unwrap_err();
        assert!(err.is::<NodeDown>(), "{err}");
        assert!(err.to_string().contains("is down"));
        // rejoin restores processing
        node.healthy = true;
        node.on_rejoin(1.0);
        assert!(node.process(&e, &img).is_ok());
    }

    #[test]
    fn from_nodes_pool_tracks_occupancy() {
        let e = engine();
        let fleet = devices::fleet();
        let spec = devices::find(&fleet, "pi5").unwrap();
        // synthesized identities: same model/device class, unique keys
        let a = PairKey::new("ssd_v1", "pi5#0000");
        let b = PairKey::new("ssd_v1", "pi5#0001");
        let nodes = vec![
            EdgeNode::new(&e, a.clone(), spec.clone(), 1).unwrap(),
            EdgeNode::new(&e, b.clone(), spec.scaled(1.2, 0.9), 2)
                .unwrap(),
        ];
        let mut pool = NodePool::from_nodes(nodes);
        assert_eq!(pool.len(), 2);
        assert_eq!(pool.queue_capacity(), DEFAULT_QUEUE_CAPACITY);
        assert!(pool.is_available(&a));
        assert!(pool.is_available(&b));
        assert_eq!(pool.total_in_flight(), 0);
        assert!(pool.acquire(&a));
        assert!(pool.acquire(&b));
        assert!(pool.acquire(&b));
        assert_eq!(pool.total_in_flight(), 3);
        pool.release(&a);
        assert_eq!(pool.total_in_flight(), 2);
    }

    #[test]
    fn bound_pool_id_accessors_mirror_key_accessors() {
        let e = engine();
        let fleet = devices::fleet();
        let pairs = vec![
            PairKey::new("ssd_v1", "jetson_orin_nano"),
            PairKey::new("yolov8n", "pi5_aihat"),
        ];
        let mut pool = NodePool::deploy(&e, &pairs, &fleet, 2).unwrap();
        // unbound pools answer id queries defensively
        assert!(!pool.is_available_id(PairId(0)));
        assert!(!pool.acquire_id(PairId(0)));
        pool.release_id(PairId(0)); // no-op, no panic

        let table = PairTable::from_keys(pairs.clone());
        pool.bind_table(table.clone());
        let a = table.id_of(&pairs[0]).unwrap();
        let b = table.id_of(&pairs[1]).unwrap();
        pool.set_queue_capacity(2);
        assert!(pool.is_available_id(a) && pool.is_available_id(b));
        assert!(pool.is_healthy_id(a));
        assert!(pool.acquire_id(a));
        assert_eq!(pool.queue_depth_id(a), 1);
        assert_eq!(pool.queue_depth(&pairs[0]), 1, "same node state");
        assert!(pool.acquire_id(a));
        assert!(!pool.acquire_id(a), "capacity 2 exhausted");
        assert!(pool.has_slot_id(b));
        pool.release_id(a);
        assert!(pool.has_slot_id(a));
        // health flips are visible through both access paths
        assert!(pool.set_health_id(b, false));
        assert!(!pool.is_available_id(b));
        assert!(!pool.is_healthy(&pairs[1]));
        assert!(pool.get_id(b).is_some());
        // ids outside the table are never routable
        assert!(!pool.is_available_id(PairId(99)));
        assert!(!pool.set_health_id(PairId(99), true));
        pool.release_id(a);
    }

    #[test]
    fn quantized_node_detects_fewer_weak_objects_than_fp32() {
        // same model on pi5 (fp32) vs pi5_tpu (int8 threshold scale):
        // across a crowded scene the quantized path never finds MORE
        let e = engine();
        let fleet = devices::fleet();
        let mut cpu = EdgeNode::new(
            &e,
            PairKey::new("ssd_lite", "pi5"),
            devices::find(&fleet, "pi5").unwrap(),
            1,
        )
        .unwrap();
        let mut tpu = EdgeNode::new(
            &e,
            PairKey::new("ssd_lite", "pi5_tpu"),
            devices::find(&fleet, "pi5_tpu").unwrap(),
            1,
        )
        .unwrap();
        let s = scene::render_spec(&SceneSpec {
            id: 0,
            seed: 42,
            n_objects: 6,
        });
        let n_cpu = cpu.process(&e, &s.image).unwrap().detections.len();
        let n_tpu = tpu.process(&e, &s.image).unwrap().detections.len();
        assert!(n_tpu <= n_cpu, "tpu {n_tpu} > cpu {n_cpu}");
    }
}
