//! Metrics accounting: per-run energy / latency / accuracy aggregation
//! with gateway overhead isolated (paper §4.2's four primary metrics),
//! plus report rendering helpers shared by the experiment drivers.

use std::collections::BTreeMap;

use crate::detection::map::{map_coco, ImageEval};
use crate::router::PairKey;
use crate::util::json::Json;

/// Accumulated measurements for one routing run.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub label: String,
    /// Dynamic energy spent on backend inference (mWh).
    pub backend_energy_mwh: f64,
    /// Dynamic energy spent in the gateway on estimation (mWh).
    pub gateway_energy_mwh: f64,
    /// Total virtual wall-clock of the closed loop (s): network +
    /// estimation + inference, request after request.
    pub total_latency_s: f64,
    /// Portion of latency spent in the gateway (s).
    pub gateway_latency_s: f64,
    /// Per-image evaluation records for accuracy.
    pub images: Vec<ImageEval>,
    /// Requests routed per pair.
    pub per_pair: BTreeMap<String, usize>,
    /// Requests per estimated group.
    pub per_group: BTreeMap<usize, usize>,
    /// Estimation error statistics (|estimate - truth|).
    pub est_abs_err_sum: f64,
    pub requests: usize,
}

impl RunMetrics {
    pub fn new(label: &str) -> Self {
        Self {
            label: label.to_string(),
            ..Default::default()
        }
    }

    #[allow(clippy::too_many_arguments)]
    pub fn record_request(
        &mut self,
        pair: &PairKey,
        group: usize,
        estimate: usize,
        truth: usize,
        gateway_latency_s: f64,
        gateway_energy_mwh: f64,
        backend_latency_s: f64,
        backend_energy_mwh: f64,
        network_s: f64,
        eval: ImageEval,
    ) {
        self.requests += 1;
        *self.per_pair.entry(pair.to_string()).or_default() += 1;
        *self.per_group.entry(group).or_default() += 1;
        self.gateway_latency_s += gateway_latency_s;
        self.gateway_energy_mwh += gateway_energy_mwh;
        self.backend_energy_mwh += backend_energy_mwh;
        self.total_latency_s +=
            gateway_latency_s + backend_latency_s + network_s;
        self.est_abs_err_sum += estimate.abs_diff(truth) as f64;
        self.images.push(eval);
    }

    /// Total dynamic energy (paper's headline energy metric).
    pub fn total_energy_mwh(&self) -> f64 {
        self.backend_energy_mwh + self.gateway_energy_mwh
    }

    /// COCO mAP over all recorded images (0–100).
    pub fn map(&self) -> f64 {
        map_coco(&self.images, crate::dataset::NUM_CLASSES).map
    }

    pub fn mean_estimation_error(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.est_abs_err_sum / self.requests as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::str(&self.label)),
            ("requests", Json::num(self.requests as f64)),
            ("map", Json::num(self.map())),
            ("total_energy_mwh", Json::num(self.total_energy_mwh())),
            (
                "backend_energy_mwh",
                Json::num(self.backend_energy_mwh),
            ),
            (
                "gateway_energy_mwh",
                Json::num(self.gateway_energy_mwh),
            ),
            ("total_latency_s", Json::num(self.total_latency_s)),
            ("gateway_latency_s", Json::num(self.gateway_latency_s)),
            (
                "mean_est_abs_err",
                Json::num(self.mean_estimation_error()),
            ),
            (
                "per_pair",
                Json::Obj(
                    self.per_pair
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::num(*v as f64)))
                        .collect(),
                ),
            ),
        ])
    }
}

/// Render a comparison table (one row per run) the way the paper's
/// figures report: mAP, total latency, dynamic energy, gateway overhead.
pub fn render_table(runs: &[&RunMetrics]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<6} {:>8} {:>12} {:>12} {:>12} {:>12} {:>8}\n",
        "router",
        "mAP",
        "energy_mWh",
        "latency_s",
        "gw_mWh",
        "gw_s",
        "est_err"
    ));
    for r in runs {
        out.push_str(&format!(
            "{:<6} {:>8.2} {:>12.2} {:>12.2} {:>12.3} {:>12.2} {:>8.2}\n",
            r.label,
            r.map(),
            r.total_energy_mwh(),
            r.total_latency_s,
            r.gateway_energy_mwh,
            r.gateway_latency_s,
            r.mean_estimation_error(),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::detection::{BBox, Detection};
    use crate::dataset::GtBox;

    fn eval_perfect() -> ImageEval {
        ImageEval {
            dets: vec![Detection {
                bbox: BBox::new(10.0, 10.0, 30.0, 30.0),
                score: 0.9,
                cls: 0,
            }],
            gt: vec![GtBox {
                x0: 10.0,
                y0: 10.0,
                x1: 30.0,
                y1: 30.0,
                cls: 0,
            }],
        }
    }

    #[test]
    fn accumulates_and_reports() {
        let mut m = RunMetrics::new("ED");
        let pair = PairKey::new("ssd_v1", "pi5");
        m.record_request(
            &pair,
            1,
            1,
            1,
            0.002,
            0.001,
            0.050,
            0.04,
            0.0035,
            eval_perfect(),
        );
        m.record_request(
            &pair,
            2,
            3,
            2,
            0.002,
            0.001,
            0.060,
            0.05,
            0.0035,
            eval_perfect(),
        );
        assert_eq!(m.requests, 2);
        assert!((m.total_energy_mwh() - 0.092).abs() < 1e-12);
        assert!(
            (m.total_latency_s - (0.002 * 2.0 + 0.11 + 0.007)).abs() < 1e-12
        );
        assert_eq!(m.per_pair["ssd_v1@pi5"], 2);
        assert!((m.mean_estimation_error() - 0.5).abs() < 1e-12);
        assert!((m.map() - 100.0).abs() < 1e-9);
        let j = m.to_json();
        assert_eq!(j.req("requests").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn table_renders_all_runs() {
        let a = RunMetrics::new("LE");
        let b = RunMetrics::new("HMG");
        let t = render_table(&[&a, &b]);
        assert!(t.contains("LE"));
        assert!(t.contains("HMG"));
        assert_eq!(t.lines().count(), 3);
    }
}
