//! Metrics accounting: per-run energy / latency / accuracy aggregation
//! with gateway overhead isolated (paper §4.2's four primary metrics),
//! plus report rendering helpers shared by the experiment drivers.

use std::collections::BTreeMap;

use crate::detection::map::{map_coco, ImageEval};
use crate::router::PairKey;
use crate::util::json::Json;
use crate::util::stats::percentile;

/// Accumulated measurements for one routing run.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub label: String,
    /// Dynamic energy spent on backend inference (mWh).
    pub backend_energy_mwh: f64,
    /// Dynamic energy spent in the gateway on estimation (mWh).
    pub gateway_energy_mwh: f64,
    /// Total virtual wall-clock of the closed loop (s): network +
    /// estimation + inference, request after request.
    pub total_latency_s: f64,
    /// Portion of latency spent in the gateway (s).
    pub gateway_latency_s: f64,
    /// Per-image evaluation records for accuracy.
    pub images: Vec<ImageEval>,
    /// Requests routed per pair.
    pub per_pair: BTreeMap<String, usize>,
    /// Requests per estimated group.
    pub per_group: BTreeMap<usize, usize>,
    /// Estimation error statistics (|estimate - truth|).
    pub est_abs_err_sum: f64,
    pub requests: usize,
    /// Total open-loop queueing delay (s): time spent waiting in a
    /// node's bounded FIFO before service. Always 0 under the
    /// closed-loop protocol (one request in flight at a time).
    pub queue_delay_s: f64,
    /// Per-request end-to-end latency samples (gateway + queueing +
    /// service + network), for the p50/p95/p99 tail reports.
    pub latency_samples: Vec<f64>,
}

impl RunMetrics {
    pub fn new(label: &str) -> Self {
        Self {
            label: label.to_string(),
            ..Default::default()
        }
    }

    #[allow(clippy::too_many_arguments)]
    pub fn record_request(
        &mut self,
        pair: &PairKey,
        group: usize,
        estimate: usize,
        truth: usize,
        gateway_latency_s: f64,
        gateway_energy_mwh: f64,
        backend_latency_s: f64,
        backend_energy_mwh: f64,
        network_s: f64,
        eval: ImageEval,
    ) {
        self.requests += 1;
        *self.per_pair.entry(pair.to_string()).or_default() += 1;
        *self.per_group.entry(group).or_default() += 1;
        self.gateway_latency_s += gateway_latency_s;
        self.gateway_energy_mwh += gateway_energy_mwh;
        self.backend_energy_mwh += backend_energy_mwh;
        self.total_latency_s +=
            gateway_latency_s + backend_latency_s + network_s;
        self.latency_samples
            .push(gateway_latency_s + backend_latency_s + network_s);
        self.est_abs_err_sum += estimate.abs_diff(truth) as f64;
        self.images.push(eval);
    }

    /// Account queueing delay for the most recently recorded request
    /// (open-loop runs call this right after `record_request`). The
    /// delay joins both the request's end-to-end latency sample and the
    /// run's total latency.
    pub fn record_queue_delay(&mut self, delay_s: f64) {
        self.queue_delay_s += delay_s;
        self.total_latency_s += delay_s;
        if let Some(last) = self.latency_samples.last_mut() {
            *last += delay_s;
        }
    }

    /// Total dynamic energy (paper's headline energy metric).
    pub fn total_energy_mwh(&self) -> f64 {
        self.backend_energy_mwh + self.gateway_energy_mwh
    }

    /// COCO mAP over all recorded images (0–100).
    pub fn map(&self) -> f64 {
        map_coco(&self.images, crate::dataset::NUM_CLASSES).map
    }

    pub fn mean_estimation_error(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.est_abs_err_sum / self.requests as f64
        }
    }

    /// End-to-end latency percentile, `p` in [0, 100].
    pub fn latency_percentile(&self, p: f64) -> f64 {
        percentile(&self.latency_samples, p)
    }

    /// Mean per-request queueing delay (s); 0 for closed-loop runs.
    pub fn mean_queue_delay_s(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.queue_delay_s / self.requests as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::str(&self.label)),
            ("requests", Json::num(self.requests as f64)),
            ("map", Json::num(self.map())),
            ("total_energy_mwh", Json::num(self.total_energy_mwh())),
            (
                "backend_energy_mwh",
                Json::num(self.backend_energy_mwh),
            ),
            (
                "gateway_energy_mwh",
                Json::num(self.gateway_energy_mwh),
            ),
            ("total_latency_s", Json::num(self.total_latency_s)),
            ("gateway_latency_s", Json::num(self.gateway_latency_s)),
            ("queue_delay_s", Json::num(self.queue_delay_s)),
            ("latency_p50_s", Json::num(self.latency_percentile(50.0))),
            ("latency_p95_s", Json::num(self.latency_percentile(95.0))),
            ("latency_p99_s", Json::num(self.latency_percentile(99.0))),
            (
                "mean_est_abs_err",
                Json::num(self.mean_estimation_error()),
            ),
            (
                "per_pair",
                Json::Obj(
                    self.per_pair
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::num(*v as f64)))
                        .collect(),
                ),
            ),
        ])
    }
}

/// SLO accounting for one run: per-deadline-class attainment, the
/// shed/miss breakdown, and the batch-size histogram (DESIGN.md §11).
/// Only materialized when an SLO config is active — `None` runs carry
/// no SLO block and serialize bit-identically to the pre-SLO reports.
#[derive(Clone, Debug, Default)]
pub struct SloMetrics {
    /// Deadline-class names, indexed by class id.
    pub classes: Vec<String>,
    /// Served within deadline, per class.
    pub met: Vec<usize>,
    /// Served but past deadline, per class.
    pub missed: Vec<usize>,
    /// Shed at admission (predicted completion blew the budget) or
    /// abandoned (retry past deadline), per class.
    pub shed: Vec<usize>,
    /// Dispatched batch sizes -> count (size 1 = unbatched dispatch).
    pub batch_sizes: BTreeMap<usize, usize>,
}

impl SloMetrics {
    pub fn new(classes: &[String]) -> Self {
        let n = classes.len();
        Self {
            classes: classes.to_vec(),
            met: vec![0; n],
            missed: vec![0; n],
            shed: vec![0; n],
            batch_sizes: BTreeMap::new(),
        }
    }

    /// A request of `class` completed; `on_time` is completion vs its
    /// absolute deadline on the virtual clock.
    pub fn record_completion(&mut self, class: usize, on_time: bool) {
        if let Some(c) = if on_time {
            self.met.get_mut(class)
        } else {
            self.missed.get_mut(class)
        } {
            *c += 1;
        }
    }

    /// A request of `class` was shed at admission or abandoned.
    pub fn record_shed(&mut self, class: usize) {
        if let Some(c) = self.shed.get_mut(class) {
            *c += 1;
        }
    }

    /// One service event dispatched `size` requests as a batch.
    pub fn record_batch(&mut self, size: usize) {
        *self.batch_sizes.entry(size).or_default() += 1;
    }

    /// Attainment % for one class: met / (met + missed + shed). A class
    /// nothing arrived in attains 100 by convention.
    pub fn attainment_pct(&self, class: usize) -> f64 {
        let met = self.met.get(class).copied().unwrap_or(0);
        let total = met
            + self.missed.get(class).copied().unwrap_or(0)
            + self.shed.get(class).copied().unwrap_or(0);
        if total == 0 {
            100.0
        } else {
            100.0 * met as f64 / total as f64
        }
    }

    /// Attainment % across every class.
    pub fn overall_attainment_pct(&self) -> f64 {
        let met: usize = self.met.iter().sum();
        let total: usize = met
            + self.missed.iter().sum::<usize>()
            + self.shed.iter().sum::<usize>();
        if total == 0 {
            100.0
        } else {
            100.0 * met as f64 / total as f64
        }
    }

    /// Mean dispatched batch size (1.0 when nothing was batched yet).
    pub fn mean_batch_size(&self) -> f64 {
        let events: usize = self.batch_sizes.values().sum();
        if events == 0 {
            return 1.0;
        }
        let members: usize =
            self.batch_sizes.iter().map(|(s, n)| s * n).sum();
        members as f64 / events as f64
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "attainment_pct",
                Json::num(self.overall_attainment_pct()),
            ),
            ("mean_batch_size", Json::num(self.mean_batch_size())),
            (
                "per_class",
                Json::Arr(
                    (0..self.classes.len())
                        .map(|i| {
                            Json::obj(vec![
                                ("class", Json::str(&self.classes[i])),
                                ("met", Json::num(self.met[i] as f64)),
                                (
                                    "missed",
                                    Json::num(self.missed[i] as f64),
                                ),
                                ("shed", Json::num(self.shed[i] as f64)),
                                (
                                    "attainment_pct",
                                    Json::num(self.attainment_pct(i)),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "batch_size_hist",
                Json::Obj(
                    self.batch_sizes
                        .iter()
                        .map(|(s, n)| {
                            (s.to_string(), Json::num(*n as f64))
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// One fixed-width table cell. Non-finite values (NaN mAP on an
/// empty run, NaN percentiles, inf from a degenerate divide) render
/// as `-` at the same width so the column layout never breaks.
fn cell(v: f64, width: usize, prec: usize) -> String {
    if v.is_finite() {
        format!("{v:>width$.prec$}")
    } else {
        format!("{:>width$}", "-")
    }
}

/// Render a comparison table (one row per run) the way the paper's
/// figures report: mAP, total latency, dynamic energy, gateway overhead.
pub fn render_table(runs: &[&RunMetrics]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<6} {:>8} {:>12} {:>12} {:>12} {:>12} {:>8}\n",
        "router",
        "mAP",
        "energy_mWh",
        "latency_s",
        "gw_mWh",
        "gw_s",
        "est_err"
    ));
    for r in runs {
        out.push_str(&format!(
            "{:<6} {} {} {} {} {} {}\n",
            r.label,
            cell(r.map(), 8, 2),
            cell(r.total_energy_mwh(), 12, 2),
            cell(r.total_latency_s, 12, 2),
            cell(r.gateway_energy_mwh, 12, 3),
            cell(r.gateway_latency_s, 12, 2),
            cell(r.mean_estimation_error(), 8, 2),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::detection::{BBox, Detection};
    use crate::dataset::GtBox;

    fn eval_perfect() -> ImageEval {
        ImageEval {
            dets: vec![Detection {
                bbox: BBox::new(10.0, 10.0, 30.0, 30.0),
                score: 0.9,
                cls: 0,
            }],
            gt: vec![GtBox {
                x0: 10.0,
                y0: 10.0,
                x1: 30.0,
                y1: 30.0,
                cls: 0,
            }],
        }
    }

    #[test]
    fn accumulates_and_reports() {
        let mut m = RunMetrics::new("ED");
        let pair = PairKey::new("ssd_v1", "pi5");
        m.record_request(
            &pair,
            1,
            1,
            1,
            0.002,
            0.001,
            0.050,
            0.04,
            0.0035,
            eval_perfect(),
        );
        m.record_request(
            &pair,
            2,
            3,
            2,
            0.002,
            0.001,
            0.060,
            0.05,
            0.0035,
            eval_perfect(),
        );
        assert_eq!(m.requests, 2);
        assert!((m.total_energy_mwh() - 0.092).abs() < 1e-12);
        assert!(
            (m.total_latency_s - (0.002 * 2.0 + 0.11 + 0.007)).abs() < 1e-12
        );
        assert_eq!(m.per_pair["ssd_v1@pi5"], 2);
        assert!((m.mean_estimation_error() - 0.5).abs() < 1e-12);
        assert!((m.map() - 100.0).abs() < 1e-9);
        let j = m.to_json();
        assert_eq!(j.req("requests").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn queue_delay_and_percentiles() {
        let mut m = RunMetrics::new("open");
        let pair = PairKey::new("ssd_v1", "pi5");
        for i in 0..4 {
            m.record_request(
                &pair,
                0,
                0,
                0,
                0.0,
                0.0,
                0.010 * (i + 1) as f64,
                0.001,
                0.0,
                eval_perfect(),
            );
            m.record_queue_delay(0.005 * i as f64);
        }
        // samples: 0.010, 0.025, 0.040, 0.055
        assert!((m.queue_delay_s - 0.030).abs() < 1e-12);
        assert!((m.mean_queue_delay_s() - 0.0075).abs() < 1e-12);
        assert!((m.latency_percentile(0.0) - 0.010).abs() < 1e-12);
        assert!((m.latency_percentile(50.0) - 0.0325).abs() < 1e-12);
        assert!((m.latency_percentile(100.0) - 0.055).abs() < 1e-12);
        // queue delay joins the total-latency accounting
        assert!((m.total_latency_s - 0.130).abs() < 1e-12);
        let j = m.to_json();
        assert!(j.req("latency_p95_s").is_ok());
        assert!(j.req("queue_delay_s").is_ok());
    }

    #[test]
    fn slo_metrics_attainment_and_histogram() {
        let classes =
            vec!["interactive".to_string(), "relaxed".to_string()];
        let mut s = SloMetrics::new(&classes);
        // empty classes attain 100 by convention
        assert_eq!(s.attainment_pct(0), 100.0);
        assert_eq!(s.overall_attainment_pct(), 100.0);
        assert_eq!(s.mean_batch_size(), 1.0);
        s.record_completion(0, true);
        s.record_completion(0, true);
        s.record_completion(0, false);
        s.record_shed(0);
        s.record_completion(1, true);
        assert!((s.attainment_pct(0) - 50.0).abs() < 1e-12);
        assert_eq!(s.attainment_pct(1), 100.0);
        assert!((s.overall_attainment_pct() - 60.0).abs() < 1e-12);
        // out-of-range classes are ignored, never panic
        s.record_completion(9, true);
        s.record_shed(9);
        s.record_batch(1);
        s.record_batch(3);
        s.record_batch(3);
        assert!((s.mean_batch_size() - 7.0 / 3.0).abs() < 1e-12);
        let j = s.to_json();
        assert!(j.req("attainment_pct").is_ok());
        assert!(j.req("per_class").is_ok());
        assert!(j.req("batch_size_hist").is_ok());
    }

    #[test]
    fn table_renders_all_runs() {
        let a = RunMetrics::new("LE");
        let b = RunMetrics::new("HMG");
        let t = render_table(&[&a, &b]);
        assert!(t.contains("LE"));
        assert!(t.contains("HMG"));
        assert_eq!(t.lines().count(), 3);
    }

    #[test]
    fn table_survives_empty_and_nonfinite_runs() {
        // No runs at all: just the header, no panic.
        assert_eq!(render_table(&[]).lines().count(), 1);

        // An untouched run (NaN mAP from zero images) and a run with
        // NaN/inf metrics must render `-` cells, never NaN/inf text,
        // and must keep every row at the header's width.
        let empty = RunMetrics::new("empty");
        let mut bad = RunMetrics::new("bad");
        bad.total_latency_s = f64::NAN;
        bad.gateway_energy_mwh = f64::INFINITY;
        bad.gateway_latency_s = f64::NEG_INFINITY;
        let t = render_table(&[&empty, &bad]);
        assert!(!t.contains("NaN"), "table leaked NaN: {t}");
        assert!(!t.contains("inf"), "table leaked inf: {t}");
        let widths: Vec<usize> =
            t.lines().map(str::len).collect();
        assert!(
            widths.iter().all(|w| *w == widths[0]),
            "ragged columns: {widths:?}\n{t}"
        );
    }
}
