//! Observability layer: request span tracing, virtual-time series
//! metrics, and deterministic streaming export (DESIGN.md §14).
//!
//! The layer is **purely passive**: collectors fold stage transitions
//! the serving engines already perform — obs schedules zero simulator
//! events, and when disabled (`obs: None` on the driver configs) the
//! hot path allocates nothing and every pre-obs golden trace stays
//! byte-identical.
//!
//! Determinism contract: an exported span/series file is a pure
//! function of the virtual-time event stream. Each record carries its
//! full identity `(idx, t, kind, shard, pair)`, and export sorts all
//! records by that canonical key before grouping — so it does not
//! matter *which* collector a record landed in (a worker shard of the
//! parallel engine vs the sequential loop), only that the record's
//! field values match. Under the watermark protocol of DESIGN.md §13
//! the per-shard event sequences are identical at any `--threads`,
//! which makes the exported bytes identical too. Wall-clock
//! self-profiling (events/sec) is inherently thread-dependent and is
//! therefore printed to stderr only, never into an exported file.

use std::cmp::Ordering;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::fs;
use std::path::Path;

use crate::util::json::{write_num, write_str};

/// Shard id used for spine-level records: events that belong to the
/// run rather than to one shard gateway (placement-failure sheds,
/// retry scheduling, abandons). Both the sequential and the parallel
/// fleet engines tag these `SPINE_SHARD`, so the exported records
/// agree regardless of where they were collected. Sorts after every
/// real shard.
pub const SPINE_SHARD: u32 = u32::MAX;

/// Number of log-scale latency histogram buckets per series bucket.
pub const LAT_BUCKETS: usize = 16;

/// Span stage-transition kinds. Declaration order is the canonical
/// sort rank used to order same-time records of one request, so two
/// engines emitting the same records in different collector order
/// still export identical lines.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum SpanKind {
    /// Request admitted into the driver (v = estimator group/count).
    Admit,
    /// Routing decision (pair chosen; v = predicted latency cost,
    /// e = predicted energy cost).
    Route,
    /// Shed at admission (SLO budget blown or no endpoint).
    Shed,
    /// Hedge copy dispatched to a second pair.
    Hedge,
    /// Joined a forming batch (v = batch size after joining).
    BatchForm,
    /// Entered a node queue (v = queue depth after entry).
    Queue,
    /// Service started (v = response latency, e = response energy).
    Serve,
    /// Request finished (v = end-to-end latency, e = energy;
    /// on-time completions fold into the attainment series).
    Finish,
    /// Hedge copy that lost the race (e = energy it still burned).
    HedgeLoss,
    /// In-flight copy lost to a node crash.
    Loss,
    /// Retry scheduled after a loss.
    Retry,
    /// Abandoned (retry budget or deadline exhausted).
    Abandon,
    /// Campaign: a failure domain tripped (v = domain id). Uses the
    /// sentinel request index (campaign events belong to no request).
    DomainOut,
    /// Campaign: a failure domain restored (v = domain id).
    DomainBack,
    /// Campaign: this shard's gateway was killed.
    GwKill,
    /// Campaign: this shard's gateway recovered.
    GwRestore,
    /// Campaign: a node was adopted by this shard after re-sharding
    /// (v = global node index, pair = its interned id here).
    Adopt,
}

/// Every kind in canonical rank order (drives per-kind totals).
pub const KINDS: [SpanKind; SpanKind::COUNT] = [
    SpanKind::Admit,
    SpanKind::Route,
    SpanKind::Shed,
    SpanKind::Hedge,
    SpanKind::BatchForm,
    SpanKind::Queue,
    SpanKind::Serve,
    SpanKind::Finish,
    SpanKind::HedgeLoss,
    SpanKind::Loss,
    SpanKind::Retry,
    SpanKind::Abandon,
    SpanKind::DomainOut,
    SpanKind::DomainBack,
    SpanKind::GwKill,
    SpanKind::GwRestore,
    SpanKind::Adopt,
];

impl SpanKind {
    /// Number of kinds (size of the per-kind totals array).
    pub const COUNT: usize = 17;

    /// Stable JSON/prom name of the kind.
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Admit => "admit",
            SpanKind::Route => "route",
            SpanKind::Shed => "shed",
            SpanKind::Hedge => "hedge",
            SpanKind::BatchForm => "batch",
            SpanKind::Queue => "queue",
            SpanKind::Serve => "serve",
            SpanKind::Finish => "finish",
            SpanKind::HedgeLoss => "hedge_loss",
            SpanKind::Loss => "loss",
            SpanKind::Retry => "retry",
            SpanKind::Abandon => "abandon",
            SpanKind::DomainOut => "domain_out",
            SpanKind::DomainBack => "domain_back",
            SpanKind::GwKill => "gw_kill",
            SpanKind::GwRestore => "gw_restore",
            SpanKind::Adopt => "adopt",
        }
    }
}

/// One retained span record: a stage transition of request `idx` at
/// virtual time `t`. `pair` is the interned `PairId` as a signed
/// value (-1 when no pair is involved); `v`/`e` are the kind-specific
/// value and energy payloads documented on [`SpanKind`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpanRec {
    /// Request index (arrival order).
    pub idx: u64,
    /// Virtual time of the transition (s).
    pub t: f64,
    /// Stage-transition kind.
    pub kind: SpanKind,
    /// Shard gateway the event belongs to ([`SPINE_SHARD`] for
    /// run-level events).
    pub shard: u32,
    /// Interned pair id, or -1.
    pub pair: i64,
    /// Kind-specific value payload.
    pub v: f64,
    /// Kind-specific energy payload (mWh).
    pub e: f64,
}

/// Observability configuration (materialized from the `obs_*` config
/// keys by `ExperimentConfig::obs_config`).
#[derive(Clone, Debug, PartialEq)]
pub struct ObsConfig {
    /// Virtual-time series bucket width (s).
    pub tick_s: f64,
    /// Always retain spans of the first `span_head` requests.
    pub span_head: usize,
    /// Always retain spans of the last `span_tail` requests.
    pub span_tail: usize,
    /// Expected number of middle requests retained by the hash
    /// reservoir (0 disables middle sampling).
    pub span_sample: usize,
    /// Seed of the retention reservoir (independent of the run seed
    /// streams — obs must not perturb the simulation).
    pub seed: u64,
    /// Export directory; empty string = collect but never touch the
    /// filesystem (bench / equivalence-test mode).
    pub out_dir: String,
}

/// One aggregation bucket of the virtual-time series: integer event
/// counters, an order-stable energy sum, a log-scale latency
/// histogram, and last-value gauges.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BucketAgg {
    /// Requests admitted.
    pub admits: u64,
    /// Service starts.
    pub serves: u64,
    /// Completions.
    pub finishes: u64,
    /// Completions inside their deadline (= finishes when no SLO).
    pub ontime: u64,
    /// Admission sheds.
    pub sheds: u64,
    /// Retries scheduled.
    pub retries: u64,
    /// Hedge copies dispatched.
    pub hedges: u64,
    /// Copies lost to crashes.
    pub losses: u64,
    /// Requests abandoned.
    pub abandons: u64,
    /// Batch-join events.
    pub batches: u64,
    /// Node crashes observed.
    pub crashes: u64,
    /// Node rejoins observed.
    pub rejoins: u64,
    /// Served energy folded in per-shard event order (mWh).
    pub energy_mwh: f64,
    /// End-to-end latency histogram (see [`lat_bucket`]).
    pub lat_hist: [u64; LAT_BUCKETS],
    /// Last in-flight gauge value seen in this bucket.
    pub in_flight_last: Option<u64>,
    /// Last powered-node gauge value seen in this bucket.
    pub powered_last: Option<u64>,
}

/// Log-scale latency histogram bucket for `lat_s` seconds: bucket 0
/// is `< 1e-4 s`, each next bucket doubles the threshold, and bucket
/// 15 is the overflow bucket. Implemented by loop-doubling (not
/// `log2`) so the bucket edges are exact binary floats on every
/// platform; non-finite samples land in the overflow bucket.
pub fn lat_bucket(lat_s: f64) -> usize {
    if !lat_s.is_finite() {
        return LAT_BUCKETS - 1;
    }
    let mut th = 1e-4;
    let mut i = 0;
    while i < LAT_BUCKETS - 1 && lat_s >= th {
        th *= 2.0;
        i += 1;
    }
    i
}

/// SplitMix64 finalizer — the retention reservoir hash. Pure in its
/// input, so the keep/drop decision for a request is identical no
/// matter which engine or collector folds the record.
fn mix64(seed: u64) -> u64 {
    let mut z = seed.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// One collector: the spans and series gathered by a single shard (or
/// by the run spine). The sequential engines own one per shard; the
/// parallel engine gives each worker its shard's collector and merges
/// at export time — see the module docs for why that is safe.
#[derive(Clone, Debug)]
pub struct ObsShard {
    shard: u32,
    tick_s: f64,
    span_head: u64,
    span_tail: u64,
    span_sample: u64,
    seed: u64,
    n_requests: u64,
    spans: Vec<SpanRec>,
    series: BTreeMap<u64, BucketAgg>,
    totals: [u64; SpanKind::COUNT],
}

impl ObsShard {
    /// New collector for `shard`, for a run of `n_requests` arrivals
    /// (drives head/tail/reservoir retention).
    pub fn new(cfg: &ObsConfig, shard: u32, n_requests: usize) -> Self {
        Self {
            shard,
            tick_s: cfg.tick_s,
            span_head: cfg.span_head as u64,
            span_tail: cfg.span_tail as u64,
            span_sample: cfg.span_sample as u64,
            seed: cfg.seed,
            n_requests: n_requests as u64,
            spans: Vec::new(),
            series: BTreeMap::new(),
            totals: [0; SpanKind::COUNT],
        }
    }

    /// Retention decision for request `idx`: head and tail requests
    /// are always kept; the middle is sampled by a pure hash
    /// reservoir keeping ~`span_sample` of the `middle_n` requests.
    /// Pure in `(seed, idx)` — no mutable reservoir state, so every
    /// collector agrees without coordination.
    pub fn keep(&self, idx: u64) -> bool {
        // saturating: the campaign sentinel index (u64::MAX) always
        // lands in the tail and is always retained
        if idx < self.span_head
            || idx.saturating_add(self.span_tail) >= self.n_requests
        {
            return true;
        }
        let middle_n = self
            .n_requests
            .saturating_sub(self.span_head + self.span_tail);
        if self.span_sample >= middle_n {
            return true;
        }
        if self.span_sample == 0 {
            return false;
        }
        let h = mix64(self.seed ^ idx) as u128;
        (h * middle_n as u128) >> 64 < self.span_sample as u128
    }

    fn bucket(&mut self, t: f64) -> &mut BucketAgg {
        let b = (t / self.tick_s).floor().max(0.0) as u64;
        self.series.entry(b).or_default()
    }

    fn span(
        &mut self,
        idx: usize,
        t: f64,
        kind: SpanKind,
        pair: i64,
        v: f64,
        e: f64,
    ) {
        self.totals[kind as usize] += 1;
        let idx = idx as u64;
        if self.keep(idx) {
            self.spans.push(SpanRec {
                idx,
                t,
                kind,
                shard: self.shard,
                pair,
                v,
                e,
            });
        }
    }

    /// Request `idx` admitted; `estimate` is the estimator's group.
    pub fn admit(&mut self, idx: usize, t: f64, estimate: usize) {
        self.bucket(t).admits += 1;
        self.span(idx, t, SpanKind::Admit, -1, estimate as f64, 0.0);
    }

    /// Routing decision: `pair` chosen at predicted cost.
    pub fn route(
        &mut self,
        idx: usize,
        t: f64,
        pair: i64,
        lat_cost_s: f64,
        e_cost_mwh: f64,
    ) {
        self.span(idx, t, SpanKind::Route, pair, lat_cost_s, e_cost_mwh);
    }

    /// Shed at admission.
    pub fn shed(&mut self, idx: usize, t: f64) {
        self.bucket(t).sheds += 1;
        self.span(idx, t, SpanKind::Shed, -1, 0.0, 0.0);
    }

    /// Hedge copy dispatched to `pair`.
    pub fn hedge(&mut self, idx: usize, t: f64, pair: i64) {
        self.bucket(t).hedges += 1;
        self.span(idx, t, SpanKind::Hedge, pair, 0.0, 0.0);
    }

    /// Joined a forming batch of `size` members (after joining).
    pub fn batch_form(&mut self, idx: usize, t: f64, pair: i64, size: usize) {
        self.bucket(t).batches += 1;
        self.span(idx, t, SpanKind::BatchForm, pair, size as f64, 0.0);
    }

    /// Entered `pair`'s queue at `depth` (after entry).
    pub fn queue(&mut self, idx: usize, t: f64, pair: i64, depth: usize) {
        self.span(idx, t, SpanKind::Queue, pair, depth as f64, 0.0);
    }

    /// Service started: the response will cost `lat_s`/`e_mwh`. The
    /// energy series folds here (covers hedge losers too).
    pub fn serve(&mut self, idx: usize, t: f64, pair: i64, lat_s: f64, e_mwh: f64) {
        let b = self.bucket(t);
        b.serves += 1;
        b.energy_mwh += e_mwh;
        self.span(idx, t, SpanKind::Serve, pair, lat_s, e_mwh);
    }

    /// Request finished end-to-end.
    pub fn finish(
        &mut self,
        idx: usize,
        t: f64,
        pair: i64,
        e2e_lat_s: f64,
        e_mwh: f64,
        on_time: bool,
    ) {
        let b = self.bucket(t);
        b.finishes += 1;
        if on_time {
            b.ontime += 1;
        }
        b.lat_hist[lat_bucket(e2e_lat_s)] += 1;
        self.span(idx, t, SpanKind::Finish, pair, e2e_lat_s, e_mwh);
    }

    /// Hedge copy lost the race after burning `e_mwh`.
    pub fn hedge_loss(&mut self, idx: usize, t: f64, pair: i64, e_mwh: f64) {
        self.span(idx, t, SpanKind::HedgeLoss, pair, 0.0, e_mwh);
    }

    /// In-flight copy lost to a crash of `pair`'s node.
    pub fn loss(&mut self, idx: usize, t: f64, pair: i64) {
        self.bucket(t).losses += 1;
        self.span(idx, t, SpanKind::Loss, pair, 0.0, 0.0);
    }

    /// Retry scheduled.
    pub fn retry(&mut self, idx: usize, t: f64) {
        self.bucket(t).retries += 1;
        self.span(idx, t, SpanKind::Retry, -1, 0.0, 0.0);
    }

    /// Request abandoned.
    pub fn abandon(&mut self, idx: usize, t: f64) {
        self.bucket(t).abandons += 1;
        self.span(idx, t, SpanKind::Abandon, -1, 0.0, 0.0);
    }

    /// A node of this shard crashed (series counter only).
    pub fn crash(&mut self, t: f64) {
        self.bucket(t).crashes += 1;
    }

    /// A node of this shard rejoined (series counter only).
    pub fn rejoin(&mut self, t: f64) {
        self.bucket(t).rejoins += 1;
    }

    /// Campaign: failure domain `domain` tripped (`down = true`) or
    /// restored, anchored to this shard (home of the domain's first
    /// member). Span-only — the member crashes feed the series
    /// crash/rejoin counters individually, so series lines keep their
    /// fixed field set.
    pub fn domain_mark(&mut self, t: f64, domain: usize, down: bool) {
        let kind = if down {
            SpanKind::DomainOut
        } else {
            SpanKind::DomainBack
        };
        self.span(usize::MAX, t, kind, -1, domain as f64, 0.0);
    }

    /// Campaign: this shard's gateway died (`up = false`) or recovered.
    pub fn gw_mark(&mut self, t: f64, up: bool) {
        let kind = if up {
            SpanKind::GwRestore
        } else {
            SpanKind::GwKill
        };
        self.span(usize::MAX, t, kind, -1, 0.0, 0.0);
    }

    /// Campaign: global node `node` (interned here as `pair`) was
    /// adopted by this shard after re-sharding.
    pub fn adopt(&mut self, node: usize, t: f64, pair: i64) {
        self.span(usize::MAX, t, SpanKind::Adopt, pair, node as f64, 0.0);
    }

    /// Powered-node gauge sample (autoscaler state).
    pub fn powered(&mut self, t: f64, n: usize) {
        self.bucket(t).powered_last = Some(n as u64);
    }

    /// In-flight gauge sample. Parallel-safe by construction: callers
    /// pass their own shard's count, never a cross-shard total.
    pub fn in_flight(&mut self, t: f64, n: usize) {
        self.bucket(t).in_flight_last = Some(n as u64);
    }

    /// Total events folded (all kinds), for self-profiling.
    pub fn events_total(&self) -> u64 {
        self.totals.iter().sum()
    }

    /// Number of span records retained.
    pub fn spans_kept(&self) -> usize {
        self.spans.len()
    }
}

/// Canonical record order: request, then virtual time (total order —
/// NaN sorts last), then kind rank, then shard, then pair. Everything
/// export emits is sorted by this key, which is what makes collector
/// placement irrelevant.
fn canon_cmp(a: &SpanRec, b: &SpanRec) -> Ordering {
    a.idx
        .cmp(&b.idx)
        .then(a.t.total_cmp(&b.t))
        .then((a.kind as u8).cmp(&(b.kind as u8)))
        .then(a.shard.cmp(&b.shard))
        .then(a.pair.cmp(&b.pair))
}

fn field_u(line: &mut String, name: &str, v: u64) {
    line.push(',');
    write_str(line, name);
    line.push(':');
    write_num(line, v as f64);
}

fn opt_gauge(line: &mut String, name: &str, v: Option<u64>) {
    line.push(',');
    write_str(line, name);
    line.push(':');
    match v {
        Some(x) => write_num(line, x as f64),
        None => line.push_str("null"),
    }
}

/// Render the span trace as JSONL: one line per retained request,
/// `{"idx":N,"events":[...]}`, events in canonical order. Built line
/// by line through `util::json`'s number/string writers — no
/// in-memory `Json` tree.
pub fn render_spans(shards: &[ObsShard]) -> String {
    let mut recs: Vec<&SpanRec> =
        shards.iter().flat_map(|s| s.spans.iter()).collect();
    recs.sort_by(|a, b| canon_cmp(a, b));
    let mut out = String::new();
    let mut line = String::new();
    let mut i = 0;
    while i < recs.len() {
        let idx = recs[i].idx;
        line.clear();
        line.push_str("{\"idx\":");
        write_num(&mut line, idx as f64);
        line.push_str(",\"events\":[");
        let mut first = true;
        while i < recs.len() && recs[i].idx == idx {
            let r = recs[i];
            if !first {
                line.push(',');
            }
            first = false;
            line.push_str("{\"t\":");
            write_num(&mut line, r.t);
            line.push_str(",\"kind\":");
            write_str(&mut line, r.kind.name());
            line.push_str(",\"shard\":");
            write_num(&mut line, f64::from(r.shard));
            line.push_str(",\"pair\":");
            write_num(&mut line, r.pair as f64);
            line.push_str(",\"v\":");
            write_num(&mut line, r.v);
            line.push_str(",\"e\":");
            write_num(&mut line, r.e);
            line.push('}');
            i += 1;
        }
        line.push_str("]}\n");
        out.push_str(&line);
    }
    out
}

/// Render the virtual-time series as JSONL: one line per
/// `(shard, bucket)` pair, sparse (only buckets that saw events),
/// with last-value gauges carried forward across a shard's buckets.
/// `shards` must already be sorted by shard id (`export_run` sorts).
pub fn render_series(shards: &[ObsShard]) -> String {
    let mut out = String::new();
    let mut line = String::new();
    for sh in shards {
        let mut in_flight: Option<u64> = None;
        let mut powered: Option<u64> = None;
        for (&b, agg) in &sh.series {
            if agg.in_flight_last.is_some() {
                in_flight = agg.in_flight_last;
            }
            if agg.powered_last.is_some() {
                powered = agg.powered_last;
            }
            line.clear();
            line.push_str("{\"shard\":");
            write_num(&mut line, f64::from(sh.shard));
            line.push_str(",\"bucket\":");
            write_num(&mut line, b as f64);
            line.push_str(",\"t\":");
            write_num(&mut line, b as f64 * sh.tick_s);
            field_u(&mut line, "admits", agg.admits);
            field_u(&mut line, "serves", agg.serves);
            field_u(&mut line, "finishes", agg.finishes);
            field_u(&mut line, "ontime", agg.ontime);
            field_u(&mut line, "sheds", agg.sheds);
            field_u(&mut line, "retries", agg.retries);
            field_u(&mut line, "hedges", agg.hedges);
            field_u(&mut line, "losses", agg.losses);
            field_u(&mut line, "abandons", agg.abandons);
            field_u(&mut line, "batches", agg.batches);
            field_u(&mut line, "crashes", agg.crashes);
            field_u(&mut line, "rejoins", agg.rejoins);
            line.push_str(",\"energy_mwh\":");
            write_num(&mut line, agg.energy_mwh);
            line.push_str(",\"lat_hist\":[");
            for (k, c) in agg.lat_hist.iter().enumerate() {
                if k > 0 {
                    line.push(',');
                }
                write_num(&mut line, *c as f64);
            }
            line.push(']');
            opt_gauge(&mut line, "in_flight", in_flight);
            opt_gauge(&mut line, "powered", powered);
            line.push_str("}\n");
            out.push_str(&line);
        }
    }
    out
}

/// Render the Prometheus-style snapshot: whole-run totals only. Every
/// number here is thread-invariant (integer counters, plus energy
/// summed in sorted shard order); wall-clock rates never appear.
/// `shards` must already be sorted by shard id (`export_run` sorts).
pub fn render_prom(shards: &[ObsShard]) -> String {
    let mut out = String::new();
    out.push_str("# ECORE observability snapshot (virtual-time totals)\n");
    out.push_str("# TYPE ecore_obs_events_total counter\n");
    for (k, kind) in KINDS.iter().enumerate() {
        let total: u64 = shards.iter().map(|s| s.totals[k]).sum();
        let _ = writeln!(
            out,
            "ecore_obs_events_total{{kind=\"{}\"}} {total}",
            kind.name()
        );
    }
    let mut crashes = 0u64;
    let mut rejoins = 0u64;
    let mut buckets = 0u64;
    let mut energy = 0.0f64;
    for sh in shards {
        for agg in sh.series.values() {
            crashes += agg.crashes;
            rejoins += agg.rejoins;
            energy += agg.energy_mwh;
            buckets += 1;
        }
    }
    let spans: usize = shards.iter().map(|s| s.spans.len()).sum();
    let _ = writeln!(out, "ecore_obs_crashes_total {crashes}");
    let _ = writeln!(out, "ecore_obs_rejoins_total {rejoins}");
    out.push_str("ecore_obs_energy_mwh_total ");
    write_num(&mut out, energy);
    out.push('\n');
    let _ = writeln!(out, "ecore_obs_span_records {spans}");
    let _ = writeln!(out, "ecore_obs_series_buckets {buckets}");
    out
}

/// End-of-run export. Sorts the collectors by shard id, prints a
/// wall-clock self-profile to stderr (`wall_s` = engine wall-clock
/// seconds; pass 0 to skip), and — when `cfg.out_dir` is non-empty —
/// writes `spans.jsonl`, `series.jsonl`, and `metrics.prom` under it.
pub fn export_run(
    cfg: &ObsConfig,
    label: &str,
    mut shards: Vec<ObsShard>,
    wall_s: f64,
) -> std::io::Result<()> {
    shards.sort_by_key(|s| s.shard);
    if wall_s > 0.0 {
        let events: u64 = shards.iter().map(|s| s.events_total()).sum();
        let spans: usize = shards.iter().map(|s| s.spans.len()).sum();
        eprintln!(
            "[obs] {label}: {events} events folded, {spans} spans kept, \
             {:.0} events/sec wall",
            events as f64 / wall_s
        );
    }
    if cfg.out_dir.is_empty() {
        return Ok(());
    }
    let dir = Path::new(&cfg.out_dir);
    fs::create_dir_all(dir)?;
    fs::write(dir.join("spans.jsonl"), render_spans(&shards))?;
    fs::write(dir.join("series.jsonl"), render_series(&shards))?;
    fs::write(dir.join("metrics.prom"), render_prom(&shards))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn cfg() -> ObsConfig {
        ObsConfig {
            tick_s: 1.0,
            span_head: 4,
            span_tail: 4,
            span_sample: 8,
            seed: 0x0B5,
            out_dir: String::new(),
        }
    }

    #[test]
    fn keep_retains_head_tail_and_samples_middle() {
        let sh = ObsShard::new(&cfg(), 0, 100);
        for idx in 0..4 {
            assert!(sh.keep(idx), "head idx {idx}");
        }
        for idx in 96..100 {
            assert!(sh.keep(idx), "tail idx {idx}");
        }
        let kept: Vec<u64> = (4..96).filter(|&i| sh.keep(i)).collect();
        assert!(!kept.is_empty());
        assert!(kept.len() < 92, "reservoir kept everything");
        // pure in (seed, idx): a second collector agrees exactly
        let sh2 = ObsShard::new(&cfg(), 7, 100);
        let kept2: Vec<u64> = (4..96).filter(|&i| sh2.keep(i)).collect();
        assert_eq!(kept, kept2);
        // tiny runs keep everything
        let tiny = ObsShard::new(&cfg(), 0, 6);
        assert!((0..6).all(|i| tiny.keep(i)));
        // sample >= middle keeps everything
        let wide = ObsShard::new(&cfg(), 0, 14);
        assert!((0..14).all(|i| wide.keep(i)));
    }

    #[test]
    fn keep_zero_sample_drops_middle() {
        let mut c = cfg();
        c.span_sample = 0;
        let sh = ObsShard::new(&c, 0, 100);
        assert!((4..96).all(|i| !sh.keep(i)));
        assert!(sh.keep(0) && sh.keep(99));
    }

    #[test]
    fn lat_bucket_edges() {
        assert_eq!(lat_bucket(0.0), 0);
        assert_eq!(lat_bucket(5e-5), 0);
        assert_eq!(lat_bucket(1e-4), 1);
        assert_eq!(lat_bucket(1.5e-4), 1);
        assert_eq!(lat_bucket(2e-4), 2);
        assert_eq!(lat_bucket(-1.0), 0);
        assert_eq!(lat_bucket(1e9), LAT_BUCKETS - 1);
        assert_eq!(lat_bucket(f64::NAN), LAT_BUCKETS - 1);
        assert_eq!(lat_bucket(f64::INFINITY), LAT_BUCKETS - 1);
    }

    #[test]
    fn spans_group_by_idx_in_canonical_order() {
        let c = cfg();
        let mut a = ObsShard::new(&c, 0, 8);
        let mut b = ObsShard::new(&c, 1, 8);
        // interleave collection across two collectors
        b.serve(1, 0.4, 3, 0.05, 0.2);
        a.admit(0, 0.0, 2);
        a.admit(1, 0.1, 1);
        a.route(1, 0.1, 3, 0.05, 0.2);
        b.finish(1, 0.5, 3, 0.4, 0.2, true);
        a.route(0, 0.0, 2, 0.03, 0.1);
        let txt = render_spans(&[a, b]);
        let lines: Vec<&str> = txt.lines().collect();
        assert_eq!(lines.len(), 2);
        // each line parses and is ordered by idx
        for (want_idx, line) in lines.iter().enumerate() {
            let v = json::parse(line).unwrap();
            assert_eq!(v.req("idx").unwrap().as_usize(), Some(want_idx));
        }
        // idx 1's events come out time-ordered despite collector split
        let v = json::parse(lines[1]).unwrap();
        let kinds: Vec<String> = v
            .req("events")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|e| e.req("kind").unwrap().as_str().unwrap().to_string())
            .collect();
        assert_eq!(kinds, ["admit", "route", "serve", "finish"]);
    }

    #[test]
    fn collector_placement_is_irrelevant() {
        let c = cfg();
        // same records, gathered by one collector vs split across two
        let mut solo = ObsShard::new(&c, 0, 4);
        solo.admit(0, 0.0, 1);
        solo.serve(0, 0.2, 5, 0.1, 0.3);
        solo.admit(1, 0.1, 2);
        let mut x = ObsShard::new(&c, 0, 4);
        let mut y = ObsShard::new(&c, 0, 4);
        y.admit(1, 0.1, 2);
        x.admit(0, 0.0, 1);
        y.serve(0, 0.2, 5, 0.1, 0.3);
        assert_eq!(render_spans(&[solo]), render_spans(&[x, y]));
    }

    #[test]
    fn series_sparse_buckets_carry_gauges_forward() {
        let c = cfg();
        let mut sh = ObsShard::new(&c, 2, 8);
        sh.admit(0, 0.5, 1);
        sh.in_flight(0.5, 3);
        sh.powered(0.5, 6);
        sh.admit(1, 2.5, 1); // bucket 2; bucket 1 stays absent
        let txt = render_series(&[sh]);
        let lines: Vec<&str> = txt.lines().collect();
        assert_eq!(lines.len(), 2, "sparse: only touched buckets emit");
        let b0 = json::parse(lines[0]).unwrap();
        assert_eq!(b0.req("bucket").unwrap().as_usize(), Some(0));
        assert_eq!(b0.req("in_flight").unwrap().as_usize(), Some(3));
        let b2 = json::parse(lines[1]).unwrap();
        assert_eq!(b2.req("bucket").unwrap().as_usize(), Some(2));
        // gauges carry forward into later buckets of the same shard
        assert_eq!(b2.req("in_flight").unwrap().as_usize(), Some(3));
        assert_eq!(b2.req("powered").unwrap().as_usize(), Some(6));
        assert_eq!(b2.req("admits").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn series_gauge_null_before_first_sample() {
        let c = cfg();
        let mut sh = ObsShard::new(&c, 0, 8);
        sh.admit(0, 0.5, 1);
        let txt = render_series(&[sh]);
        let v = json::parse(txt.lines().next().unwrap()).unwrap();
        assert_eq!(v.req("in_flight").unwrap(), &json::Json::Null);
        assert_eq!(v.req("powered").unwrap(), &json::Json::Null);
    }

    #[test]
    fn finish_folds_attainment_and_latency_histogram() {
        let c = cfg();
        let mut sh = ObsShard::new(&c, 0, 8);
        sh.finish(0, 0.1, 1, 5e-5, 0.1, true);
        sh.finish(1, 0.2, 1, 0.5, 0.1, false);
        let txt = render_series(&[sh]);
        let v = json::parse(txt.lines().next().unwrap()).unwrap();
        assert_eq!(v.req("finishes").unwrap().as_usize(), Some(2));
        assert_eq!(v.req("ontime").unwrap().as_usize(), Some(1));
        let hist = v.req("lat_hist").unwrap().f64s().unwrap();
        assert_eq!(hist.len(), LAT_BUCKETS);
        assert_eq!(hist[0], 1.0);
        assert_eq!(hist.iter().sum::<f64>(), 2.0);
    }

    #[test]
    fn prom_snapshot_reports_per_kind_totals() {
        let c = cfg();
        let mut sh = ObsShard::new(&c, 0, 8);
        sh.admit(0, 0.0, 1);
        sh.serve(0, 0.1, 2, 0.05, 0.25);
        sh.crash(0.2);
        let txt = render_prom(&[sh]);
        assert!(txt.contains("ecore_obs_events_total{kind=\"admit\"} 1\n"));
        assert!(txt.contains("ecore_obs_events_total{kind=\"serve\"} 1\n"));
        assert!(txt.contains("ecore_obs_events_total{kind=\"finish\"} 0\n"));
        assert!(txt.contains("ecore_obs_crashes_total 1\n"));
        assert!(txt.contains("ecore_obs_energy_mwh_total 0.25\n"));
    }

    #[test]
    fn spine_shard_sorts_last_in_exports() {
        let c = cfg();
        let mut spine = ObsShard::new(&c, SPINE_SHARD, 8);
        spine.retry(0, 0.3);
        let mut sh = ObsShard::new(&c, 0, 8);
        sh.admit(0, 0.0, 1);
        // export_run sorts; render_series takes sorted order
        let mut v = vec![spine, sh];
        v.sort_by_key(|s| s.shard);
        assert_eq!(v[0].shard, 0);
        assert_eq!(v[1].shard, SPINE_SHARD);
        let txt = render_series(&v);
        let last = txt.lines().last().unwrap();
        let j = json::parse(last).unwrap();
        assert_eq!(
            j.req("shard").unwrap().as_f64(),
            Some(f64::from(SPINE_SHARD))
        );
        assert_eq!(j.req("retries").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn export_run_with_empty_out_dir_touches_nothing() {
        let c = cfg();
        let sh = ObsShard::new(&c, 0, 4);
        export_run(&c, "test", vec![sh], 0.0).unwrap();
    }
}
