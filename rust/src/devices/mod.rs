//! Edge-device simulator (substitute for the paper's physical testbed —
//! DESIGN.md §3).
//!
//! Each [`DeviceSpec`] models one of the paper's eight edge platforms as
//! (a) an effective compute throughput per op class (CPU path vs
//! accelerator path), (b) a fixed dispatch overhead, (c) a *dynamic*
//! power draw (active minus idle, matching the paper's idle-subtracted
//! energy accounting), and (d) a deployment-framework effect: quantized
//! runtimes (Coral int8, Hailo HEF, TensorRT fp16) raise the effective
//! decode threshold slightly, which measurably lowers recall on hard
//! scenes — so per-(model, device) mAP differences are *measured*, not
//! tabulated.
//!
//! Coefficients are calibrated so the paper's Table 1 structure holds:
//! Jetson Orin Nano + SSD v1 is the energy optimum, Pi 5 + Coral TPU +
//! SSD v1 the latency optimum, and Pi 5 + AI-Hat + YOLOv8-s the
//! crowded-scene accuracy optimum.

pub mod drift;

use crate::models::ModelMeta;

/// Accelerator type attached to a device.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Accel {
    None,
    CoralTpu,
    Hailo8,
    Gpu,
}

/// Deployment framework used for a given (device, model) binding.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Framework {
    TfLite,
    TfLiteEdgeTpu,
    Hef,
    TensorRt,
}

impl Framework {
    pub fn label(&self) -> &'static str {
        match self {
            Framework::TfLite => "TFLite",
            Framework::TfLiteEdgeTpu => "TFLite-EdgeTPU",
            Framework::Hef => "HEF",
            Framework::TensorRt => "TensorRT",
        }
    }

    /// Decode-threshold multiplier modelling quantization effects.
    /// Coral int8 is the harshest; Hailo's HEF pipeline does per-layer
    /// calibration and lands closest to fp32; TensorRT fp16 with implicit
    /// range selection sits between them — which is what makes
    /// Pi5+AI-Hat the crowded-scene accuracy champion (paper Table 1).
    pub fn threshold_scale(&self) -> f64 {
        match self {
            Framework::TfLite => 1.0,
            Framework::TfLiteEdgeTpu => 1.18, // int8
            Framework::Hef => 1.03,
            Framework::TensorRt => 1.05, // fp16
        }
    }
}

/// One simulated edge platform.
#[derive(Clone, Debug)]
pub struct DeviceSpec {
    pub name: &'static str,
    pub accel: Accel,
    /// Effective CPU throughput for this workload class (MFLOP/s).
    pub cpu_mflops: f64,
    /// Effective accelerator throughput (MFLOP/s); 0 if no accelerator.
    pub accel_mflops: f64,
    /// Fixed per-request preprocessing on the host CPU (image decode,
    /// resize, tensor packing) — dominates the cost of small models and
    /// compresses the pool's energy spread to paper-like ratios.
    pub preprocess_s: f64,
    /// Fixed per-inference dispatch overhead on the CPU path (s).
    pub cpu_overhead_s: f64,
    /// Fixed per-inference dispatch overhead on the accelerator path (s).
    pub accel_overhead_s: f64,
    /// Dynamic (active - idle) power on the CPU path (W).
    pub cpu_dyn_power_w: f64,
    /// Dynamic power on the accelerator path (W).
    pub accel_dyn_power_w: f64,
}

/// Outcome of binding a model to a device.
#[derive(Clone, Copy, Debug)]
pub struct ExecProfile {
    pub latency_s: f64,
    pub energy_mwh: f64,
    pub framework: Framework,
    pub threshold_scale: f64,
}

const MWH_PER_JOULE: f64 = 1.0 / 3.6;

impl DeviceSpec {
    /// Can the accelerator run this model? The Coral edge-TPU only takes
    /// int8-quantizable SSD/EfficientDet graphs; YOLOv8 falls back to the
    /// host CPU (as on the paper's testbed). Hailo-8 and the Jetson GPU
    /// run everything.
    pub fn accel_supports(&self, model: &str) -> bool {
        match self.accel {
            Accel::None => false,
            Accel::CoralTpu => {
                model.starts_with("ssd") || model.starts_with("effdet")
            }
            Accel::Hailo8 | Accel::Gpu => true,
        }
    }

    fn framework_for(&self, model: &str) -> Framework {
        if !self.accel_supports(model) {
            return Framework::TfLite;
        }
        match self.accel {
            Accel::CoralTpu => Framework::TfLiteEdgeTpu,
            Accel::Hailo8 => Framework::Hef,
            Accel::Gpu => Framework::TensorRt,
            Accel::None => Framework::TfLite,
        }
    }

    /// A copy of this spec with compute throughput scaled by `speed`
    /// and dynamic power by `power`. Fleet synthesis models per-unit
    /// variation of nominally identical boards (silicon binning,
    /// cooling, supply quality) this way; fixed preprocessing and
    /// dispatch overheads are left unchanged.
    pub fn scaled(&self, speed: f64, power: f64) -> DeviceSpec {
        DeviceSpec {
            cpu_mflops: self.cpu_mflops * speed,
            accel_mflops: self.accel_mflops * speed,
            cpu_dyn_power_w: self.cpu_dyn_power_w * power,
            accel_dyn_power_w: self.accel_dyn_power_w * power,
            ..self.clone()
        }
    }

    /// Simulated latency/energy/framework for one inference of `meta`.
    pub fn profile(&self, meta: &ModelMeta) -> ExecProfile {
        let mflops = meta.flops / 1e6;
        let framework = self.framework_for(&meta.name);
        let on_accel = framework != Framework::TfLite || self.accel == Accel::None;
        let (thru, overhead, power) = if self.accel != Accel::None && on_accel
        {
            (
                self.accel_mflops,
                self.accel_overhead_s,
                self.accel_dyn_power_w,
            )
        } else {
            (self.cpu_mflops, self.cpu_overhead_s, self.cpu_dyn_power_w)
        };
        // Fallback path on accelerator devices still uses the host CPU.
        let (thru, overhead, power) = if framework == Framework::TfLite {
            (self.cpu_mflops, self.cpu_overhead_s, self.cpu_dyn_power_w)
        } else {
            (thru, overhead, power)
        };
        let compute_s = mflops / thru + overhead;
        let latency_s = self.preprocess_s + compute_s;
        let energy_mwh = (self.cpu_dyn_power_w * self.preprocess_s
            + power * compute_s)
            * MWH_PER_JOULE;
        ExecProfile {
            latency_s,
            energy_mwh,
            framework,
            threshold_scale: framework.threshold_scale(),
        }
    }
}

/// The paper's eight-device fleet.
pub fn fleet() -> Vec<DeviceSpec> {
    vec![
        DeviceSpec {
            name: "pi3",
            accel: Accel::None,
            cpu_mflops: 25.0,
            accel_mflops: 0.0,
            preprocess_s: 0.06,
            cpu_overhead_s: 0.001,
            accel_overhead_s: 0.0,
            cpu_dyn_power_w: 1.8,
            accel_dyn_power_w: 0.0,
        },
        DeviceSpec {
            name: "pi3_tpu",
            accel: Accel::CoralTpu,
            cpu_mflops: 25.0,
            accel_mflops: 1500.0,
            preprocess_s: 0.06,
            cpu_overhead_s: 0.001,
            accel_overhead_s: 0.003,
            cpu_dyn_power_w: 1.8,
            accel_dyn_power_w: 3.4,
        },
        DeviceSpec {
            name: "pi4",
            accel: Accel::None,
            cpu_mflops: 50.0,
            accel_mflops: 0.0,
            preprocess_s: 0.03,
            cpu_overhead_s: 0.0008,
            accel_overhead_s: 0.0,
            cpu_dyn_power_w: 2.3,
            accel_dyn_power_w: 0.0,
        },
        DeviceSpec {
            name: "pi4_tpu",
            accel: Accel::CoralTpu,
            cpu_mflops: 50.0,
            accel_mflops: 3000.0,
            preprocess_s: 0.03,
            cpu_overhead_s: 0.0008,
            accel_overhead_s: 0.002,
            cpu_dyn_power_w: 2.3,
            accel_dyn_power_w: 4.0,
        },
        DeviceSpec {
            name: "pi5",
            accel: Accel::None,
            cpu_mflops: 100.0,
            accel_mflops: 0.0,
            preprocess_s: 0.01,
            cpu_overhead_s: 0.0005,
            accel_overhead_s: 0.0,
            cpu_dyn_power_w: 3.5,
            accel_dyn_power_w: 0.0,
        },
        DeviceSpec {
            name: "pi5_tpu",
            accel: Accel::CoralTpu,
            cpu_mflops: 100.0,
            accel_mflops: 6000.0,
            preprocess_s: 0.01,
            cpu_overhead_s: 0.0005,
            accel_overhead_s: 0.001,
            cpu_dyn_power_w: 3.5,
            accel_dyn_power_w: 5.0,
        },
        DeviceSpec {
            name: "pi5_aihat",
            accel: Accel::Hailo8,
            cpu_mflops: 100.0,
            accel_mflops: 12000.0,
            preprocess_s: 0.01,
            cpu_overhead_s: 0.0005,
            accel_overhead_s: 0.0025,
            cpu_dyn_power_w: 3.5,
            accel_dyn_power_w: 4.5,
        },
        DeviceSpec {
            name: "jetson_orin_nano",
            accel: Accel::Gpu,
            cpu_mflops: 400.0,
            accel_mflops: 8000.0,
            preprocess_s: 0.01,
            cpu_overhead_s: 0.0006,
            accel_overhead_s: 0.002,
            cpu_dyn_power_w: 3.0,
            accel_dyn_power_w: 1.5,
        },
    ]
}

/// The gateway host (runs estimators only).
pub fn gateway_spec() -> DeviceSpec {
    DeviceSpec {
        name: "gateway",
            accel: Accel::None,
            cpu_mflops: 800.0,
            accel_mflops: 0.0,
            preprocess_s: 0.0,
            cpu_overhead_s: 0.0002,
            accel_overhead_s: 0.0,
            cpu_dyn_power_w: 3.0,
            accel_dyn_power_w: 0.0,
    }
}

/// Per-request network transfer time gateway -> node -> gateway (s).
pub const NETWORK_S: f64 = 0.0035;

pub fn find(fleet: &[DeviceSpec], name: &str) -> Option<DeviceSpec> {
    fleet.iter().find(|d| d.name == name).cloned()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelRegistry;
    use std::path::{Path, PathBuf};

    fn registry() -> ModelRegistry {
        let dir: PathBuf =
            Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        ModelRegistry::load(&dir).unwrap()
    }

    #[test]
    fn fleet_has_eight_devices_with_unique_names() {
        let f = fleet();
        assert_eq!(f.len(), 8);
        let mut names: Vec<_> = f.iter().map(|d| d.name).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 8);
    }

    #[test]
    fn coral_rejects_yolo_accepts_ssd_and_effdet() {
        let f = fleet();
        let tpu = find(&f, "pi5_tpu").unwrap();
        assert!(tpu.accel_supports("ssd_v1"));
        assert!(tpu.accel_supports("effdet_lite2"));
        assert!(!tpu.accel_supports("yolov8n"));
        let hat = find(&f, "pi5_aihat").unwrap();
        assert!(hat.accel_supports("yolov8m"));
    }

    #[test]
    fn table1_energy_champion_is_jetson_ssd_v1() {
        let reg = registry();
        let ssd = reg.get("ssd_v1").unwrap();
        let f = fleet();
        let mut best = ("", f64::INFINITY);
        for d in &f {
            for m in reg.backend_models() {
                let p = d.profile(m);
                if p.energy_mwh < best.1 {
                    best = (d.name, p.energy_mwh);
                }
            }
        }
        let jetson = find(&f, "jetson_orin_nano").unwrap();
        let jp = jetson.profile(ssd);
        assert_eq!(best.0, "jetson_orin_nano");
        assert!((jp.energy_mwh - best.1).abs() < 1e-12);
    }

    #[test]
    fn table1_latency_champion_is_pi5_tpu_ssd_v1() {
        let reg = registry();
        let f = fleet();
        let mut best = (("", ""), f64::INFINITY);
        for d in &f {
            for m in reg.backend_models() {
                let p = d.profile(m);
                if p.latency_s < best.1 {
                    best = ((d.name, m.name.as_str()), p.latency_s);
                }
            }
        }
        assert_eq!(best.0 .0, "pi5_tpu");
        assert_eq!(best.0 .1, "ssd_v1");
    }

    #[test]
    fn energy_monotone_in_flops_per_device() {
        let reg = registry();
        for d in fleet() {
            // within a fixed execution path, energy grows with flops
            let mut cpu_energies = vec![];
            let mut accel_energies = vec![];
            for m in reg.backend_models() {
                let p = d.profile(m);
                if p.framework == Framework::TfLite {
                    cpu_energies.push(p.energy_mwh);
                } else {
                    accel_energies.push(p.energy_mwh);
                }
            }
            for w in cpu_energies.windows(2) {
                assert!(w[1] > w[0], "{}: cpu not monotone", d.name);
            }
            for w in accel_energies.windows(2) {
                assert!(w[1] > w[0], "{}: accel not monotone", d.name);
            }
        }
    }

    #[test]
    fn framework_assignment_matches_paper_table1() {
        let f = fleet();
        let jetson = find(&f, "jetson_orin_nano").unwrap();
        let reg = registry();
        let ssd = reg.get("ssd_v1").unwrap();
        let yolo_s = reg.get("yolov8s").unwrap();
        assert_eq!(jetson.profile(ssd).framework, Framework::TensorRt);
        let pi5_tpu = find(&f, "pi5_tpu").unwrap();
        assert_eq!(
            pi5_tpu.profile(ssd).framework,
            Framework::TfLiteEdgeTpu
        );
        // YOLOv8 on a Coral device falls back to host TFLite
        assert_eq!(pi5_tpu.profile(yolo_s).framework, Framework::TfLite);
        let hat = find(&f, "pi5_aihat").unwrap();
        assert_eq!(hat.profile(yolo_s).framework, Framework::Hef);
    }

    #[test]
    fn scaled_spec_shifts_profile_in_the_right_direction() {
        let reg = registry();
        let m = reg.get("yolov8n").unwrap();
        let pi5 = find(&fleet(), "pi5").unwrap();
        let base = pi5.profile(m);
        // faster silicon: lower latency, same dispatch overheads
        let fast = pi5.scaled(2.0, 1.0).profile(m);
        assert!(fast.latency_s < base.latency_s);
        // hotter unit: same latency, more energy
        let hot = pi5.scaled(1.0, 2.0).profile(m);
        assert_eq!(hot.latency_s, base.latency_s);
        assert!(hot.energy_mwh > base.energy_mwh);
        // identity scaling is a no-op
        let same = pi5.scaled(1.0, 1.0).profile(m);
        assert_eq!(same.latency_s, base.latency_s);
        assert_eq!(same.energy_mwh, base.energy_mwh);
    }

    #[test]
    fn gateway_estimators_are_cheap() {
        let reg = registry();
        let g = gateway_spec();
        let canny = g.profile(reg.get("canny").unwrap());
        let front = g.profile(reg.get("ssd_front").unwrap());
        // ED cheaper than SF, both far below typical backend inference
        assert!(canny.energy_mwh < front.energy_mwh / 2.0);
        assert!(front.latency_s < 0.01);
    }

    #[test]
    fn threshold_scales_ordered_by_quantization_severity() {
        assert!(Framework::TfLiteEdgeTpu.threshold_scale()
            > Framework::TensorRt.threshold_scale());
        assert!(Framework::TensorRt.threshold_scale()
            > Framework::Hef.threshold_scale());
        assert!(Framework::Hef.threshold_scale()
            > Framework::TfLite.threshold_scale());
    }
}
