//! Runtime drift — the paper's Future Work #1 ("dynamic profiling to
//! account for runtime variability such as temperature, battery state,
//! and background load").
//!
//! [`DriftModel`] evolves a device's effective throughput and power over
//! simulated time: sustained utilization raises temperature, thermal
//! throttling cuts throughput; battery droop raises effective dynamic
//! power on battery-fed boards; background load adds a slow random walk.
//! The `ablation_drift` experiment shows static profiles going stale
//! against a drifting fleet, and the online adaptation subsystem
//! (`crate::adapt` — continuous or periodically published telemetry
//! corrections) recovering the loss through the production routing
//! path.

use super::DeviceSpec;
use crate::util::rng::Rng;

/// Drift parameters (per device).
#[derive(Clone, Debug)]
pub struct DriftConfig {
    /// Temperature rise per busy-second, °C.
    pub heat_per_busy_s: f64,
    /// Cooling per idle-second back toward ambient, °C.
    pub cool_per_idle_s: f64,
    /// Throttling threshold, °C above ambient.
    pub throttle_at: f64,
    /// Throughput multiplier when fully throttled.
    pub throttle_floor: f64,
    /// Battery droop: +W of effective dynamic power per busy-hour.
    pub battery_droop_w_per_h: f64,
    /// Std-dev of the background-load random walk (multiplier).
    pub load_walk_std: f64,
}

impl Default for DriftConfig {
    fn default() -> Self {
        Self {
            heat_per_busy_s: 0.8,
            cool_per_idle_s: 0.25,
            throttle_at: 15.0,
            throttle_floor: 0.55,
            battery_droop_w_per_h: 0.4,
            load_walk_std: 0.01,
        }
    }
}

/// Mutable drift state wrapping a base [`DeviceSpec`].
#[derive(Clone, Debug)]
pub struct DriftModel {
    pub base: DeviceSpec,
    pub cfg: DriftConfig,
    /// Degrees above ambient.
    temp: f64,
    /// Cumulative busy time (s).
    busy_s: f64,
    /// Background-load multiplier on service time (>= 1).
    load: f64,
    rng: Rng,
}

impl DriftModel {
    pub fn new(base: DeviceSpec, cfg: DriftConfig, seed: u64) -> Self {
        Self {
            base,
            cfg,
            temp: 0.0,
            busy_s: 0.0,
            load: 1.0,
            rng: Rng::new(seed),
        }
    }

    /// Throughput multiplier from thermal state (1.0 = cold).
    pub fn throttle_factor(&self) -> f64 {
        if self.temp <= self.cfg.throttle_at {
            1.0
        } else {
            // linear decay down to the floor over another `throttle_at` °C
            let over = (self.temp - self.cfg.throttle_at) / self.cfg.throttle_at;
            (1.0 - over).clamp(self.cfg.throttle_floor, 1.0)
        }
    }

    /// Effective extra dynamic power from battery droop (W).
    pub fn droop_w(&self) -> f64 {
        self.cfg.battery_droop_w_per_h * self.busy_s / 3600.0
    }

    /// Account one request: `base_latency_s` of busy time preceded by
    /// `idle_s` of idle. Returns (actual latency, actual energy) after
    /// drift effects.
    pub fn step(
        &mut self,
        base_latency_s: f64,
        base_energy_mwh: f64,
        idle_s: f64,
    ) -> (f64, f64) {
        // cool during idle
        self.temp =
            (self.temp - self.cfg.cool_per_idle_s * idle_s).max(0.0);
        // background-load random walk
        self.load = (self.load
            + self.cfg.load_walk_std * self.rng.normal())
        .clamp(1.0, 1.5);

        let slow = self.load / self.throttle_factor();
        let latency = base_latency_s * slow;
        // droop adds power proportionally to the busy window
        let droop_mwh = self.droop_w() * latency / 3.6;
        let energy = base_energy_mwh * slow + droop_mwh;

        self.temp += self.cfg.heat_per_busy_s * latency;
        self.busy_s += latency;
        (latency, energy)
    }

    pub fn temperature(&self) -> f64 {
        self.temp
    }

    /// Reboot (node lifecycle rejoin): thermal state and background
    /// load reset — a freshly booted board is cold and quiet. Battery
    /// droop persists, since cumulative busy time survives a reboot.
    pub fn reboot(&mut self) {
        self.temp = 0.0;
        self.load = 1.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices;

    fn model() -> DriftModel {
        DriftModel::new(
            devices::find(&devices::fleet(), "pi5").unwrap(),
            DriftConfig::default(),
            1,
        )
    }

    #[test]
    fn cold_device_matches_base_closely() {
        let mut m = model();
        let (lat, e) = m.step(0.1, 0.05, 10.0);
        assert!((lat - 0.1).abs() < 0.1 * 0.05, "lat {lat}");
        assert!((e - 0.05).abs() < 0.05 * 0.06, "e {e}");
    }

    #[test]
    fn sustained_load_throttles_and_slows() {
        let mut m = model();
        let mut first = 0.0;
        let mut last = 0.0;
        for i in 0..600 {
            let (lat, _) = m.step(0.1, 0.05, 0.0);
            if i == 0 {
                first = lat;
            }
            last = lat;
        }
        assert!(m.temperature() > m.cfg.throttle_at);
        assert!(
            last > first * 1.2,
            "no throttling: first {first}, last {last}"
        );
        assert!(m.throttle_factor() < 1.0);
        assert!(m.throttle_factor() >= m.cfg.throttle_floor);
    }

    #[test]
    fn idle_time_cools_back_down() {
        let mut m = model();
        for _ in 0..600 {
            m.step(0.1, 0.05, 0.0);
        }
        let hot = m.temperature();
        m.step(0.001, 0.001, 600.0);
        assert!(m.temperature() < hot * 0.2, "did not cool");
    }

    #[test]
    fn battery_droop_accumulates() {
        let mut m = model();
        for _ in 0..200 {
            m.step(1.0, 0.5, 0.0);
        }
        assert!(m.droop_w() > 0.01);
        // energy with droop exceeds the pure slowdown-scaled energy
        let slow = m.load / m.throttle_factor();
        let (_, e) = m.step(1.0, 0.5, 0.0);
        assert!(e > 0.5 * slow);
    }

    #[test]
    fn reboot_resets_thermal_state_but_not_droop() {
        let mut m = model();
        for _ in 0..600 {
            m.step(0.1, 0.05, 0.0);
        }
        assert!(m.temperature() > m.cfg.throttle_at);
        let droop = m.droop_w();
        assert!(droop > 0.0);
        m.reboot();
        assert_eq!(m.temperature(), 0.0);
        assert_eq!(m.throttle_factor(), 1.0);
        // busy time (and thus battery droop) survives the reboot
        assert_eq!(m.droop_w(), droop);
    }

    #[test]
    fn drift_is_deterministic_per_seed() {
        let mut a = model();
        let mut b = model();
        for _ in 0..50 {
            assert_eq!(a.step(0.05, 0.02, 0.01), b.step(0.05, 0.02, 0.01));
        }
    }
}
