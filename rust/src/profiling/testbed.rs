//! Testbed selection (paper §4.1.2, Table 1): from the full 64-pair
//! profiling grid, keep only pairs that are champions in at least one
//! dimension — global energy, global latency, and per-group mAP — i.e.
//! the pairs on or near the Pareto front that the paper deploys.

use crate::router::{PairKey, ProfileStore};

/// One selected testbed row (mirrors the paper's Table 1).
#[derive(Clone, Debug)]
pub struct TestbedRow {
    pub metric: String,
    pub pair: PairKey,
    pub value: f64,
}

/// Pick the Table 1 pairs from a full profiling grid.
pub fn select(store: &ProfileStore) -> Vec<TestbedRow> {
    let mut rows = Vec::new();
    let pairs = store.pairs();

    let mean = |pair: &PairKey, f: &dyn Fn(&crate::router::PairProfile) -> f64| {
        let vals: Vec<f64> = store
            .rows()
            .iter()
            .filter(|r| &r.pair == pair)
            .map(|r| f(r))
            .collect();
        vals.iter().sum::<f64>() / vals.len().max(1) as f64
    };

    // global energy champion
    if let Some(p) = pairs.iter().min_by(|a, b| {
        mean(a, &|r| r.energy_mwh)
            .partial_cmp(&mean(b, &|r| r.energy_mwh))
            .unwrap()
    }) {
        rows.push(TestbedRow {
            metric: "energy".into(),
            pair: p.clone(),
            value: mean(p, &|r| r.energy_mwh),
        });
    }
    // global latency champion
    if let Some(p) = pairs.iter().min_by(|a, b| {
        mean(a, &|r| r.latency_s)
            .partial_cmp(&mean(b, &|r| r.latency_s))
            .unwrap()
    }) {
        rows.push(TestbedRow {
            metric: "latency".into(),
            pair: p.clone(),
            value: mean(p, &|r| r.latency_s),
        });
    }
    // per-group mAP champions (ties broken by lower energy)
    for g in store.groups() {
        let best = store.group_rows(g).into_iter().max_by(|a, b| {
            (a.map, -a.energy_mwh)
                .partial_cmp(&(b.map, -b.energy_mwh))
                .unwrap()
        });
        if let Some(r) = best {
            rows.push(TestbedRow {
                metric: format!("map_g{g}"),
                pair: r.pair.clone(),
                value: r.map,
            });
        }
    }
    rows
}

/// Unique pairs from a testbed selection — the deployed node pool.
pub fn pool(rows: &[TestbedRow]) -> Vec<PairKey> {
    let mut pairs: Vec<PairKey> =
        rows.iter().map(|r| r.pair.clone()).collect();
    pairs.sort();
    pairs.dedup();
    pairs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::router::store::test_store;

    #[test]
    fn selects_champions_per_metric() {
        let s = test_store();
        let rows = select(&s);
        // energy + latency + 2 groups
        assert_eq!(rows.len(), 4);
        let energy = rows.iter().find(|r| r.metric == "energy").unwrap();
        assert_eq!(energy.pair, PairKey::new("small", "dev_a"));
        let g1 = rows.iter().find(|r| r.metric == "map_g1").unwrap();
        assert_eq!(g1.pair, PairKey::new("big", "dev_a"));
    }

    #[test]
    fn pool_is_unique_and_sorted() {
        let s = test_store();
        let p = pool(&select(&s));
        let mut q = p.clone();
        q.sort();
        q.dedup();
        assert_eq!(p, q);
        assert!(p.len() >= 2);
    }
}
