//! Offline profiler (paper §3.1: "each pair is profiled in advance").
//!
//! For every backend model, the profiler runs *real* inference over a
//! per-group profiling set and decodes the heat maps once per distinct
//! framework threshold-scale, then joins the measured per-group accuracy
//! with the device simulator's latency/energy to produce the full
//! 8 models x 8 devices x 5 groups [`ProfileStore`] (the Fig. 5 grid).
//!
//! Key economy: accuracy depends on the device only through its framework
//! threshold scale, so inference runs once per (model, image) and decode
//! runs once per (model, scale) — 8xN executions instead of 64xN.

pub mod testbed;

use std::collections::BTreeMap;

use anyhow::Result;

use crate::dataset::{Dataset, SceneSpec};
use crate::detection::map::{empty_image_score, map_coco, ImageEval};
use crate::detection::decode_heatmap;
use crate::devices::DeviceSpec;
use crate::models::BACKEND_MODELS;
use crate::router::{GroupRules, PairKey, PairProfile, ProfileStore};
use crate::runtime::Engine;
use crate::util::rng::Rng;

/// Profiling configuration.
#[derive(Clone, Debug)]
pub struct ProfilerConfig {
    /// Images per object-count group.
    pub images_per_group: usize,
    pub seed: u64,
    /// Counts sampled for the '4 or more' group.
    pub crowd_counts: Vec<usize>,
}

impl Default for ProfilerConfig {
    fn default() -> Self {
        Self {
            images_per_group: 40,
            seed: 0xEC02E_u64,
            crowd_counts: vec![4, 5, 6, 7, 8, 10],
        }
    }
}

/// Build the profiling dataset: `images_per_group` scenes per group.
pub fn profiling_dataset(
    rules: &GroupRules,
    cfg: &ProfilerConfig,
) -> Vec<(usize, Dataset)> {
    let base = Rng::new(cfg.seed);
    let mut out = Vec::new();
    for label in rules.labels() {
        let mut specs = Vec::with_capacity(cfg.images_per_group);
        for j in 0..cfg.images_per_group {
            let mut r = base.derive((label * 7_000_003 + j) as u64);
            let n_objects = if label == 4 {
                cfg.crowd_counts
                    [r.below(cfg.crowd_counts.len() as u64) as usize]
            } else {
                rules.representative(label).unwrap_or(label)
            };
            specs.push(SceneSpec {
                id: label * 100_000 + j,
                seed: r.next_u64(),
                n_objects,
            });
        }
        out.push((
            label,
            Dataset {
                name: format!("profiling_g{label}"),
                specs,
            },
        ));
    }
    out
}

/// Dedup (within 1e-12) and sort threshold scales ascending. The order
/// is total, so a NaN scale from a corrupt device profile sorts last
/// instead of panicking the whole profiling pass.
fn dedup_sorted_scales(raw: &[f64]) -> Vec<f64> {
    let mut scales: Vec<f64> = Vec::new();
    for &s in raw {
        if !scales.iter().any(|&x| (x - s).abs() < 1e-12) {
            scales.push(s);
        }
    }
    scales.sort_by(f64::total_cmp);
    scales
}

/// Run the full profiling pass over a fleet.
pub fn profile_fleet(
    engine: &Engine,
    fleet: &[DeviceSpec],
    rules: &GroupRules,
    cfg: &ProfilerConfig,
) -> Result<ProfileStore> {
    let groups = profiling_dataset(rules, cfg);

    // distinct threshold scales across the fleet (device -> scale dedup)
    let mut raw = Vec::new();
    for d in fleet {
        for m in BACKEND_MODELS {
            let meta = engine.meta(m)?;
            raw.push(d.profile(&meta).threshold_scale);
        }
    }
    let scales = dedup_sorted_scales(&raw);

    // measured accuracy: (model, scale_idx, group) -> mAP
    let mut acc: BTreeMap<(String, usize, usize), f64> = BTreeMap::new();
    for model in BACKEND_MODELS {
        let meta = engine.meta(model)?;
        for (label, ds) in &groups {
            // evals[scale_idx] accumulates per-image results
            let mut evals: Vec<Vec<ImageEval>> =
                vec![Vec::with_capacity(ds.len()); scales.len()];
            for scene in ds.iter_scenes() {
                let heat = engine.infer(model, &scene.image)?;
                for (si, &scale) in scales.iter().enumerate() {
                    evals[si].push(ImageEval {
                        dets: decode_heatmap(&heat, &meta, scale),
                        gt: scene.gt.clone(),
                    });
                }
            }
            for (si, ev) in evals.iter().enumerate() {
                // group '0' has no ground truth: use the paper-style
                // clean-image score; otherwise COCO mAP.
                let map = if *label == 0 {
                    empty_image_score(ev)
                } else {
                    map_coco(ev, crate::dataset::NUM_CLASSES).map
                };
                acc.insert((model.to_string(), si, *label), map);
            }
        }
    }

    // join with the device model
    let mut rows = Vec::new();
    for d in fleet {
        for model in BACKEND_MODELS {
            let meta = engine.meta(model)?;
            let p = d.profile(&meta);
            let si = scales
                .iter()
                .position(|&x| (x - p.threshold_scale).abs() < 1e-12)
                .expect("scale collected above");
            for (label, _) in &groups {
                let map = acc[&(model.to_string(), si, *label)];
                rows.push(PairProfile {
                    pair: PairKey::new(model, d.name),
                    group: *label,
                    map,
                    latency_s: p.latency_s,
                    energy_mwh: p.energy_mwh,
                });
            }
        }
    }
    Ok(ProfileStore::new(rows))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices;

    #[test]
    fn nan_threshold_scale_sorts_last_instead_of_panicking() {
        // regression: `sort_by(partial_cmp().unwrap())` panicked when a
        // corrupt device profile produced a NaN threshold scale
        let scales = dedup_sorted_scales(&[
            1.0,
            f64::NAN,
            0.5,
            0.5 + 1e-15, // dedups against 0.5
            2.0,
        ]);
        assert_eq!(scales.len(), 4);
        assert_eq!(&scales[..3], &[0.5, 1.0, 2.0]);
        assert!(scales[3].is_nan());
    }

    #[test]
    fn profiling_dataset_group_counts_match_rules() {
        let rules = GroupRules::paper_default();
        let cfg = ProfilerConfig {
            images_per_group: 5,
            ..Default::default()
        };
        let groups = profiling_dataset(&rules, &cfg);
        assert_eq!(groups.len(), 5);
        for (label, ds) in &groups {
            assert_eq!(ds.len(), 5);
            for spec in &ds.specs {
                assert_eq!(rules.group_of(spec.n_objects), *label);
            }
        }
    }

    #[test]
    fn profile_small_fleet_structure_and_phenomena() {
        let engine = Engine::new(&crate::default_artifacts_dir()).unwrap();
        let fleet = devices::fleet();
        let rules = GroupRules::paper_default();
        let cfg = ProfilerConfig {
            images_per_group: 6,
            seed: 99,
            crowd_counts: vec![5, 7],
        };
        let store = profile_fleet(&engine, &fleet, &rules, &cfg).unwrap();
        // full grid: 8 models x 8 devices x 5 groups
        assert_eq!(store.rows().len(), 8 * 8 * 5);

        // paper Fig. 2 phenomenon in the measured profiles: on the
        // crowded group, the big model beats the small one by a wide
        // margin; on the single-object group they are comparable.
        let big = store
            .lookup(&PairKey::new("yolov8m", "pi5"), 4)
            .unwrap()
            .map;
        let small = store
            .lookup(&PairKey::new("ssd_v1", "pi5"), 4)
            .unwrap()
            .map;
        assert!(
            big > small + 15.0,
            "crowded: yolov8m {big} vs ssd_v1 {small}"
        );
        let big1 = store
            .lookup(&PairKey::new("yolov8m", "pi5"), 1)
            .unwrap()
            .map;
        let small1 = store
            .lookup(&PairKey::new("ssd_v1", "pi5"), 1)
            .unwrap()
            .map;
        assert!(
            (big1 - small1).abs() < 25.0,
            "sparse gap too large: {big1} vs {small1}"
        );

        // energy identical across groups for a fixed pair (paper §4.1.2)
        let e0 = store
            .lookup(&PairKey::new("yolov8n", "pi4"), 0)
            .unwrap()
            .energy_mwh;
        let e4 = store
            .lookup(&PairKey::new("yolov8n", "pi4"), 4)
            .unwrap()
            .energy_mwh;
        assert_eq!(e0, e4);
    }
}
