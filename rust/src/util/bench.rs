//! Micro-benchmark harness substrate (no external `criterion` available).
//!
//! Provides warmup + calibrated measurement loops with median/p10/p90
//! reporting, plus a tiny `black_box` shim. Each file in `rust/benches/`
//! is a `harness = false` binary built on this module, so `cargo bench`
//! runs them all and prints one table per bench target.
//!
//! Perf trajectory: [`Bench::finish_json`] additionally serializes the
//! measurements (plus caller-supplied headline numbers such as
//! events/sec) to `BENCH_<group>.json` — written into `BENCH_OUT_DIR`
//! (default: the current directory). CI's perf-smoke job uploads these
//! files as artifacts so successive PRs have a comparable perf
//! baseline (EXPERIMENTS.md §Perf notes).

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

use crate::util::json::Json;

pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    pub mean: Duration,
}

impl BenchResult {
    pub fn throughput_per_sec(&self) -> f64 {
        if self.median.as_secs_f64() > 0.0 {
            1.0 / self.median.as_secs_f64()
        } else {
            f64::INFINITY
        }
    }
}

pub struct Bench {
    /// Target total measurement time per case.
    pub budget: Duration,
    /// Number of timed samples to collect.
    pub samples: usize,
    results: Vec<BenchResult>,
    group: String,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new("bench")
    }
}

impl Bench {
    pub fn new(group: &str) -> Self {
        // honor `cargo bench -- --quick` for CI smoke runs
        let quick = std::env::args().any(|a| a == "--quick");
        Self {
            budget: if quick {
                Duration::from_millis(200)
            } else {
                Duration::from_secs(1)
            },
            samples: if quick { 10 } else { 30 },
            results: Vec::new(),
            group: group.to_string(),
        }
    }

    /// Benchmark `f`, which performs ONE unit of work per call.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) {
        // warmup + calibration: find iters/sample so a sample ~= budget/samples
        let t0 = Instant::now();
        let mut calib_iters = 0u64;
        while t0.elapsed() < self.budget / 10 {
            black_box(f());
            calib_iters += 1;
        }
        let per_call = t0.elapsed().as_secs_f64() / calib_iters.max(1) as f64;
        let target_sample = self.budget.as_secs_f64() / self.samples as f64;
        let iters = ((target_sample / per_call.max(1e-9)) as u64).clamp(1, 1_000_000);

        let mut times: Vec<Duration> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                black_box(f());
            }
            times.push(t.elapsed() / iters as u32);
        }
        times.sort();
        let median = times[times.len() / 2];
        let p10 = times[times.len() / 10];
        let p90 = times[times.len() * 9 / 10];
        let mean = times.iter().sum::<Duration>() / times.len() as u32;
        let r = BenchResult {
            name: name.to_string(),
            iters,
            median,
            p10,
            p90,
            mean,
        };
        println!(
            "{:<44} median {:>12?}  p10 {:>12?}  p90 {:>12?}  ({} iters/sample)",
            format!("{}/{}", self.group, r.name),
            r.median,
            r.p10,
            r.p90,
            r.iters
        );
        self.results.push(r);
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Serialize every measured case plus caller-supplied headline
    /// scalars (e.g. `("n200_k8", events/sec)`) as a stable JSON
    /// document.
    pub fn to_json<S: AsRef<str>>(&self, extras: &[(S, f64)]) -> Json {
        let cases = Json::Arr(
            self.results
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("name", Json::str(&r.name)),
                        ("iters", Json::num(r.iters as f64)),
                        (
                            "median_ns",
                            Json::num(r.median.as_secs_f64() * 1e9),
                        ),
                        ("p10_ns", Json::num(r.p10.as_secs_f64() * 1e9)),
                        ("p90_ns", Json::num(r.p90.as_secs_f64() * 1e9)),
                        (
                            "mean_ns",
                            Json::num(r.mean.as_secs_f64() * 1e9),
                        ),
                        (
                            "per_sec",
                            Json::num(r.throughput_per_sec()),
                        ),
                    ])
                })
                .collect(),
        );
        Json::obj(vec![
            ("group", Json::str(&self.group)),
            ("cases", cases),
            (
                "extras",
                Json::obj(
                    extras
                        .iter()
                        .map(|(k, v)| (k.as_ref(), Json::num(*v)))
                        .collect(),
                ),
            ),
        ])
    }

    /// [`Bench::finish`] plus a `BENCH_<group>.json` dump (into
    /// `BENCH_OUT_DIR`, default the current directory) so CI can track
    /// the perf trajectory across commits. Write failures are reported
    /// but never fail the bench.
    pub fn finish_json<S: AsRef<str>>(self, extras: &[(S, f64)]) {
        let dir = std::env::var("BENCH_OUT_DIR")
            .unwrap_or_else(|_| ".".to_string());
        match self.write_json(std::path::Path::new(&dir), extras) {
            Ok(path) => println!("[bench] wrote {}", path.display()),
            Err(e) => eprintln!("[bench] could not write trajectory: {e}"),
        }
        self.finish();
    }

    /// Write `BENCH_<group>.json` into `dir`, creating the directory
    /// (and parents) if missing — a nonexistent `BENCH_OUT_DIR` used to
    /// drop the whole trajectory point with only an eprintln.
    pub fn write_json<S: AsRef<str>>(
        &self,
        dir: &std::path::Path,
        extras: &[(S, f64)],
    ) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("BENCH_{}.json", self.group));
        std::fs::write(&path, self.to_json(extras).pretty())?;
        Ok(path)
    }

    pub fn finish(self) {
        println!(
            "{}: {} case(s) measured",
            self.group,
            self.results.len()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::new("t");
        b.budget = Duration::from_millis(50);
        b.samples = 5;
        // black_box inside the loop body so release builds can neither
        // const-fold nor closed-form the reduction; keeps per-call time
        // well above the Duration division granularity.
        b.run("xor_fold_4k", || {
            (0..4096u64).fold(0u64, |acc, i| acc ^ black_box(i))
        });
        assert_eq!(b.results().len(), 1);
        assert!(b.results()[0].iters >= 1);
        assert!(b.results()[0].median > Duration::ZERO);
        // the JSON trajectory document carries cases + extras
        let j = b.to_json(&[("events_per_sec", 123.0)]);
        assert_eq!(j.req("group").unwrap().as_str(), Some("t"));
        let cases = j.req("cases").unwrap().as_arr().unwrap();
        assert_eq!(cases.len(), 1);
        assert!(cases[0].req("median_ns").unwrap().as_f64().unwrap() > 0.0);
        assert!(cases[0].req("per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(
            j.req("extras")
                .unwrap()
                .req("events_per_sec")
                .unwrap()
                .as_f64(),
            Some(123.0)
        );
    }

    #[test]
    fn write_json_creates_missing_out_dir() {
        let mut b = Bench::new("dirtest");
        b.budget = Duration::from_millis(20);
        b.samples = 3;
        b.run("noop", || black_box(1u64 + black_box(1)));
        let dir = std::env::temp_dir().join(format!(
            "ecore_bench_out_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let nested = dir.join("a/b");
        assert!(!nested.exists());
        let path = b
            .write_json(&nested, &[("events_per_sec", 7.0)])
            .expect("write through a missing directory");
        let body =
            std::fs::read_to_string(&path).expect("file written");
        assert!(body.contains("events_per_sec"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
