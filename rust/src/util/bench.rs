//! Micro-benchmark harness substrate (no external `criterion` available).
//!
//! Provides warmup + calibrated measurement loops with median/p10/p90
//! reporting, plus a tiny `black_box` shim. Each file in `rust/benches/`
//! is a `harness = false` binary built on this module, so `cargo bench`
//! runs them all and prints one table per bench target.

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    pub mean: Duration,
}

impl BenchResult {
    pub fn throughput_per_sec(&self) -> f64 {
        if self.median.as_secs_f64() > 0.0 {
            1.0 / self.median.as_secs_f64()
        } else {
            f64::INFINITY
        }
    }
}

pub struct Bench {
    /// Target total measurement time per case.
    pub budget: Duration,
    /// Number of timed samples to collect.
    pub samples: usize,
    results: Vec<BenchResult>,
    group: String,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new("bench")
    }
}

impl Bench {
    pub fn new(group: &str) -> Self {
        // honor `cargo bench -- --quick` for CI smoke runs
        let quick = std::env::args().any(|a| a == "--quick");
        Self {
            budget: if quick {
                Duration::from_millis(200)
            } else {
                Duration::from_secs(1)
            },
            samples: if quick { 10 } else { 30 },
            results: Vec::new(),
            group: group.to_string(),
        }
    }

    /// Benchmark `f`, which performs ONE unit of work per call.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) {
        // warmup + calibration: find iters/sample so a sample ~= budget/samples
        let t0 = Instant::now();
        let mut calib_iters = 0u64;
        while t0.elapsed() < self.budget / 10 {
            black_box(f());
            calib_iters += 1;
        }
        let per_call = t0.elapsed().as_secs_f64() / calib_iters.max(1) as f64;
        let target_sample = self.budget.as_secs_f64() / self.samples as f64;
        let iters = ((target_sample / per_call.max(1e-9)) as u64).clamp(1, 1_000_000);

        let mut times: Vec<Duration> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                black_box(f());
            }
            times.push(t.elapsed() / iters as u32);
        }
        times.sort();
        let median = times[times.len() / 2];
        let p10 = times[times.len() / 10];
        let p90 = times[times.len() * 9 / 10];
        let mean = times.iter().sum::<Duration>() / times.len() as u32;
        let r = BenchResult {
            name: name.to_string(),
            iters,
            median,
            p10,
            p90,
            mean,
        };
        println!(
            "{:<44} median {:>12?}  p10 {:>12?}  p90 {:>12?}  ({} iters/sample)",
            format!("{}/{}", self.group, r.name),
            r.median,
            r.p10,
            r.p90,
            r.iters
        );
        self.results.push(r);
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    pub fn finish(self) {
        println!(
            "{}: {} case(s) measured",
            self.group,
            self.results.len()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::new("t");
        b.budget = Duration::from_millis(50);
        b.samples = 5;
        // black_box inside the loop body so release builds can neither
        // const-fold nor closed-form the reduction; keeps per-call time
        // well above the Duration division granularity.
        b.run("xor_fold_4k", || {
            (0..4096u64).fold(0u64, |acc, i| acc ^ black_box(i))
        });
        assert_eq!(b.results().len(), 1);
        assert!(b.results()[0].iters >= 1);
        assert!(b.results()[0].median > Duration::ZERO);
    }
}
