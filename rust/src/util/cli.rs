//! CLI argument-parsing substrate (no external `clap` available).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! generates usage text from declared options. Used by the `ecore` binary
//! and every example/experiment driver.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    /// `--name` tokens that are not in the declared flag vocabulary and
    /// had no value to consume (next token was another `--…` or argv
    /// ended). These are almost always a typo'd or truncated value
    /// option (`serve --rates --quick`), so callers should surface
    /// them instead of silently running defaults.
    pub swallowed: Vec<String>,
}

impl Args {
    /// Parse a raw argv tail (everything after the program/subcommand).
    ///
    /// `--name token` is ambiguous between a flag followed by a
    /// positional and an option with a value; `known_flags` resolves it
    /// (anything listed there never consumes the next token).
    pub fn parse_with_flags<I: IntoIterator<Item = String>>(
        argv: I,
        known_flags: &[&str],
    ) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if !known_flags.contains(&body)
                    && it
                        .peek()
                        .map(|n| !n.starts_with("--"))
                        .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    if !known_flags.contains(&body) {
                        // A value-expecting option demoted to a flag:
                        // its value was swallowed by the following
                        // `--…` token (or the end of argv). Keep the
                        // flag for backward compatibility, but record
                        // the demotion so callers can report it.
                        out.swallowed.push(body.to_string());
                    }
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// `parse_with_flags` with the flag vocabulary used across ECORE's
    /// binaries, so `--verbose out.json` parses as flag + positional.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        Self::parse_with_flags(
            argv,
            &[
                "verbose",
                "quick",
                "full",
                "help",
                "quiet",
                "no-cache",
                "open-loop",
                "fleet",
                "churn",
                "slo",
                "adapt",
                "adapt-no-scale",
                "obs",
            ],
        )
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Print a warning for every option whose value was swallowed by a
    /// following `--…` token (see [`Args::swallowed`]). Returns true if
    /// anything was reported, so drivers can choose to abort.
    pub fn warn_swallowed(&self) -> bool {
        for name in &self.swallowed {
            eprintln!(
                "warning: `--{name}` looks like a value option but no \
                 value followed it (next token starts with `--` or argv \
                 ended); it was treated as a bare flag"
            );
        }
        !self.swallowed.is_empty()
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// Comma-separated list option.
    pub fn list_or(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }

    /// Comma-separated numeric list option (non-numeric items skipped).
    pub fn f64_list_or(&self, name: &str, default: &[f64]) -> Vec<f64> {
        match self.get(name) {
            Some(v) => v
                .split(',')
                .filter_map(|s| s.trim().parse().ok())
                .collect(),
            None => default.to_vec(),
        }
    }

    /// Comma-separated integer list option (non-numeric items skipped).
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            Some(v) => v
                .split(',')
                .filter_map(|s| s.trim().parse().ok())
                .collect(),
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn parses_mixed_forms() {
        let a = args(&[
            "profile",
            "--images",
            "500",
            "--delta=5",
            "--verbose",
            "out.json",
        ]);
        assert_eq!(a.positional, vec!["profile", "out.json"]);
        assert_eq!(a.usize_or("images", 0), 500);
        assert_eq!(a.f64_or("delta", 0.0), 5.0);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = args(&[]);
        assert_eq!(a.usize_or("n", 7), 7);
        assert_eq!(a.str_or("name", "x"), "x");
        assert_eq!(a.list_or("routers", &["ed", "ob"]), vec!["ed", "ob"]);
    }

    #[test]
    fn list_parsing() {
        let a = args(&["--routers", "orc, ed,ob"]);
        assert_eq!(a.list_or("routers", &[]), vec!["orc", "ed", "ob"]);
    }

    #[test]
    fn f64_list_parsing() {
        let a = args(&["--rates", "2, 8,32.5"]);
        assert_eq!(a.f64_list_or("rates", &[]), vec![2.0, 8.0, 32.5]);
        assert_eq!(a.f64_list_or("missing", &[1.5]), vec![1.5]);
    }

    #[test]
    fn open_loop_is_a_flag() {
        let a = args(&["--open-loop", "serve-me"]);
        assert!(a.flag("open-loop"));
        assert_eq!(a.positional, vec!["serve-me"]);
    }

    #[test]
    fn fleet_is_a_flag_and_usize_lists_parse() {
        let a = args(&["--fleet", "coco", "--fleet-sizes", "8, 16,x,200"]);
        assert!(a.flag("fleet"));
        assert_eq!(a.positional, vec!["coco"]);
        assert_eq!(a.usize_list_or("fleet-sizes", &[]), vec![8, 16, 200]);
        assert_eq!(a.usize_list_or("missing", &[4]), vec![4]);
    }

    #[test]
    fn churn_is_a_flag_with_value_options() {
        let a = args(&["--churn", "--mtbf", "12", "--resilience", "hedge"]);
        assert!(a.flag("churn"));
        assert_eq!(a.f64_or("mtbf", 0.0), 12.0);
        assert_eq!(a.str_or("resilience", ""), "hedge");
    }

    #[test]
    fn slo_is_a_flag_with_value_options() {
        let a = args(&["--slo", "--batch-window", "0.004", "--slo-classes", "fast:0.02,slow:1"]);
        assert!(a.flag("slo"));
        assert_eq!(a.f64_or("batch-window", 0.0), 0.004);
        assert_eq!(
            a.list_or("slo-classes", &[]),
            vec!["fast:0.02", "slow:1"]
        );
    }

    #[test]
    fn obs_is_a_flag_with_value_options() {
        let a = args(&["--obs", "--obs-tick", "0.5", "--obs-out", "o/dir"]);
        assert!(a.flag("obs"));
        assert_eq!(a.f64_or("obs-tick", 0.0), 0.5);
        assert_eq!(a.str_or("obs-out", ""), "o/dir");
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = args(&["--quick", "--full"]);
        assert!(a.flag("quick") && a.flag("full"));
        assert!(a.options.is_empty());
        // Both are in the declared vocabulary: nothing was swallowed.
        assert!(a.swallowed.is_empty());
    }

    #[test]
    fn swallowed_value_option_is_reported() {
        // The canonical misparse: `serve --rates --quick` used to run
        // the full default sweep silently because `--rates` lost its
        // value to `--quick` and became a flag.
        let a = args(&["serve", "--rates", "--quick"]);
        assert!(a.flag("quick"));
        assert_eq!(a.f64_list_or("rates", &[]), Vec::<f64>::new());
        assert_eq!(a.swallowed, vec!["rates"]);
        assert!(a.warn_swallowed());
    }

    #[test]
    fn swallowed_at_end_of_argv_is_reported() {
        let a = args(&["--images"]);
        assert_eq!(a.swallowed, vec!["images"]);
    }

    #[test]
    fn equals_form_can_carry_dashed_value() {
        // `--key=--v` is the explicit escape hatch: the `=` form never
        // consumes the next token and may carry a value that starts
        // with dashes.
        let a = args(&["--key=--v", "--quick"]);
        assert_eq!(a.get("key"), Some("--v"));
        assert!(a.flag("quick"));
        assert!(a.swallowed.is_empty());
    }

    #[test]
    fn negative_number_values_are_consumed() {
        // A single-dash token is a value, not an option: `--rate -5`
        // must parse as an option with value "-5".
        let a = args(&["--rate", "-5", "--offset", "-0.25"]);
        assert_eq!(a.f64_or("rate", 0.0), -5.0);
        assert_eq!(a.f64_or("offset", 0.0), -0.25);
        assert!(a.swallowed.is_empty());
        assert!(!a.warn_swallowed());
    }
}
