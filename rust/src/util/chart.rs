//! ASCII chart substrate: line series and scatter plots for terminal
//! rendering of the paper's figures (Fig. 5 Pareto scatter, Fig. 9 delta
//! sweep lines) without any plotting dependency.

/// Render one or more named (x, y) series as an ASCII line/point chart.
pub fn line_chart(
    title: &str,
    series: &[(&str, Vec<(f64, f64)>)],
    width: usize,
    height: usize,
) -> String {
    // Non-finite points cannot be placed on the grid: `as usize`
    // saturates NaN and -inf to 0, which used to silently plot them at
    // cell (0, 0). They are excluded from both the range and the plot.
    let pts: Vec<(f64, f64)> = series
        .iter()
        .flat_map(|(_, s)| s.iter().copied())
        .filter(|&(x, y)| x.is_finite() && y.is_finite())
        .collect();
    if pts.is_empty() {
        return format!("{title}\n(no data)\n");
    }
    // A degenerate frame has no cells: `grid[height - 1 - cy]` would
    // underflow on height == 0 and `grid[..][cx]` would index out of
    // bounds on width == 0.
    if width == 0 || height == 0 {
        return format!("{title}\n(degenerate {width}x{height} frame)\n");
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &pts {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    if (x1 - x0).abs() < 1e-12 {
        x1 = x0 + 1.0;
    }
    if (y1 - y0).abs() < 1e-12 {
        y1 = y0 + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    let marks = ['*', 'o', '+', 'x', '#', '@', '%', '&'];
    for (si, (_, s)) in series.iter().enumerate() {
        let m = marks[si % marks.len()];
        for &(x, y) in s {
            if !x.is_finite() || !y.is_finite() {
                continue;
            }
            let cx = ((x - x0) / (x1 - x0) * (width - 1) as f64) as usize;
            let cy = ((y - y0) / (y1 - y0) * (height - 1) as f64) as usize;
            grid[height - 1 - cy][cx] = m;
        }
    }
    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!(
            "  {} = {}\n",
            marks[si % marks.len()],
            name
        ));
    }
    out.push_str(&format!("{y1:>10.2} ┤\n"));
    for row in &grid {
        out.push_str("           │");
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!("{y0:>10.2} └"));
    out.push_str(&"─".repeat(width));
    out.push('\n');
    out.push_str(&format!(
        "            {:<10.3}{:>width$.3}\n",
        x0,
        x1,
        width = width.saturating_sub(10)
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_points_within_frame() {
        let s = line_chart(
            "t",
            &[("a", vec![(0.0, 0.0), (1.0, 1.0), (2.0, 4.0)])],
            30,
            10,
        );
        assert!(s.contains('*'));
        assert!(s.contains("a"));
        assert_eq!(s.lines().filter(|l| l.contains('│')).count(), 10);
    }

    #[test]
    fn two_series_get_distinct_marks() {
        let s = line_chart(
            "t",
            &[
                ("a", vec![(0.0, 0.0)]),
                ("b", vec![(1.0, 1.0)]),
            ],
            20,
            5,
        );
        assert!(s.contains('*') && s.contains('o'));
    }

    #[test]
    fn empty_series_no_panic() {
        let s = line_chart("t", &[("a", vec![])], 10, 5);
        assert!(s.contains("no data"));
    }

    #[test]
    fn zero_height_no_panic() {
        let s = line_chart("t", &[("a", vec![(0.0, 1.0)])], 10, 0);
        assert!(s.contains("degenerate"));
    }

    #[test]
    fn zero_width_no_panic() {
        let s = line_chart("t", &[("a", vec![(0.0, 1.0)])], 0, 5);
        assert!(s.contains("degenerate"));
    }

    #[test]
    fn non_finite_points_skipped() {
        let s = line_chart(
            "t",
            &[(
                "a",
                vec![
                    (f64::NAN, 0.5),
                    (0.25, f64::NEG_INFINITY),
                    (10.0, 20.0),
                    (30.0, 40.0),
                ],
            )],
            20,
            8,
        );
        // Only the two finite points land on the grid; the NaN/-inf
        // points must not collapse onto cell (0, 0).
        let stars: usize =
            s.lines().map(|l| l.matches('*').count()).sum();
        assert_eq!(stars, 3); // 2 plotted + 1 in the legend
        // The range comes from the finite points only.
        assert!(s.contains("40.00") && s.contains("20.00"));
    }

    #[test]
    fn all_non_finite_is_no_data() {
        let s = line_chart(
            "t",
            &[("a", vec![(f64::NAN, f64::NAN), (f64::INFINITY, 1.0)])],
            10,
            5,
        );
        assert!(s.contains("no data"));
    }

    #[test]
    fn constant_series_no_divide_by_zero() {
        let s = line_chart(
            "t",
            &[("a", vec![(1.0, 2.0), (1.0, 2.0)])],
            10,
            5,
        );
        assert!(s.contains('*'));
    }
}
