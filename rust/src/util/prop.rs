//! Property-testing substrate (no external `proptest` available).
//!
//! `forall(seed, cases, gen, prop)` runs `prop` over `cases` random inputs
//! drawn by `gen`; on failure it reports the failing case index and the
//! generator seed so the case replays deterministically. Shrinking is
//! intentionally simple: inputs carry their seed, which is enough to
//! reproduce and debug in this codebase's fully-deterministic setting.

use super::rng::Rng;

/// Run a property over `cases` generated inputs. Panics (with replay
/// information) on the first falsified case.
pub fn forall<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    for case in 0..cases {
        let mut rng = Rng::new(seed).derive(case as u64);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property falsified at case {case} (seed {seed}): {input:?}"
            );
        }
    }
}

/// Like `forall` but the property returns Result with a message.
pub fn forall_ok<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let mut rng = Rng::new(seed).derive(case as u64);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property falsified at case {case} (seed {seed}): {msg}\ninput: {input:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall(1, 50, |r| r.below(100), |&x| x < 100);
    }

    #[test]
    #[should_panic(expected = "property falsified")]
    fn fails_false_property() {
        forall(2, 50, |r| r.below(100), |&x| x < 50);
    }

    #[test]
    fn forall_ok_reports_message() {
        forall_ok(3, 10, |r| r.f64(), |&x| {
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }
}
