//! Deterministic PRNG substrate.
//!
//! Every stochastic component in ECORE (scene generation, Random router,
//! workload shuffling, property tests) draws from this SplitMix64-seeded
//! xoshiro256** generator, so whole experiments replay bit-identically
//! from a single seed — no external `rand` dependency.

/// xoshiro256** with SplitMix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the 256-bit state.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Self {
            s: [next(), next(), next(), next()],
        }
    }

    /// Derive an independent stream (e.g. one per image index).
    pub fn derive(&self, stream: u64) -> Rng {
        let mut mix = self.s[0] ^ stream.wrapping_mul(0xd1342543de82ef95);
        mix ^= mix >> 32;
        Rng::new(mix)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let [s0, s1, s2, s3] = self.s;
        let result = s1.wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s1 << 17;
        let mut s2n = s2 ^ s0;
        let mut s3n = s3 ^ s1;
        let s1n = s1 ^ s2n;
        let s0n = s0 ^ s3n;
        s2n ^= t;
        s3n = s3n.rotate_left(45);
        self.s = [s0n, s1n, s2n, s3n];
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Two independent standard normals for the price of one ln/sqrt
    /// (full Box-Muller pair) — the scene renderer's noise hot path.
    #[inline]
    pub fn normal_pair(&mut self) -> (f64, f64) {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        (r * c, r * s)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_smoke() {
        let mut r = Rng::new(2);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn derive_streams_are_independent() {
        let base = Rng::new(7);
        let mut s1 = base.derive(1);
        let mut s2 = base.derive(2);
        assert_ne!(s1.next_u64(), s2.next_u64());
        // deriving twice with the same stream id gives the same stream
        let mut s1b = base.derive(1);
        let mut s1a = base.derive(1);
        for _ in 0..10 {
            assert_eq!(s1a.next_u64(), s1b.next_u64());
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(11);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 2);
    }
}
