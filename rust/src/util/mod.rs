//! Shared substrates: deterministic RNG, JSON, micro-bench harness,
//! property-testing loop, CLI argument parsing, and small stats helpers.

pub mod bench;
pub mod chart;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
