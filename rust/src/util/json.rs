//! Minimal JSON substrate (parser + serializer).
//!
//! The crate registry available to this build has no `serde`/`serde_json`,
//! so ECORE carries its own RFC 8259 subset implementation. It covers
//! everything the system exchanges: the artifact manifest written by
//! `python/compile/aot.py`, persisted profiling tables, and experiment
//! report dumps. Numbers are f64; no arbitrary-precision support.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- accessors ------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that reports *which* key was missing.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError {
            msg: format!("missing key '{key}'"),
            offset: 0,
        })
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn f64s(&self) -> Option<Vec<f64>> {
        self.as_arr()
            .map(|v| v.iter().filter_map(|x| x.as_f64()).collect())
    }

    // ---- constructors ---------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    // ---- serialization --------------------------------------------------

    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

/// Serialize one JSON number into `out`. This is the crate's single
/// number-formatting policy, shared by [`Json::dump`] and the
/// streaming observability exporters (`obs`): whole finite values
/// under 1e15 print as integers, other finite values as shortest-f64,
/// and **non-finite values (NaN/±Inf) deterministically print as
/// `null`** — JSON has no NaN/Inf, and a streamed series must never
/// emit an unparseable token.
pub fn write_num(out: &mut String, x: f64) {
    if x.is_finite() {
        if x == x.trunc() && x.abs() < 1e15 {
            let _ = write!(out, "{}", x as i64);
        } else {
            let _ = write!(out, "{x}");
        }
    } else {
        out.push_str("null"); // JSON has no NaN/Inf
    }
}

/// Serialize one JSON string (with quotes and RFC 8259 escaping) into
/// `out`. Public for the streaming exporters that build JSONL lines
/// without an in-memory [`Json`] tree.
pub fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---- parsing -------------------------------------------------------------

pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let cp = self.unicode_escape()?;
                            s.push(cp);
                            continue;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 character
                    let rest = &self.bytes[self.pos..];
                    let ch_len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..ch_len.min(rest.len())])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos += chunk.len();
                }
            }
        }
    }

    fn unicode_escape(&mut self) -> Result<char, JsonError> {
        // self.pos is at 'u'
        self.pos += 1;
        let hex = self
            .bytes
            .get(self.pos..self.pos + 4)
            .ok_or_else(|| self.err("short \\u escape"))?;
        let code = u32::from_str_radix(
            std::str::from_utf8(hex).map_err(|_| self.err("bad hex"))?,
            16,
        )
        .map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        // surrogate pairs: only BMP needed for our own files; map lone
        // surrogates to replacement character.
        Ok(char::from_u32(code).unwrap_or('\u{fffd}'))
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1.5", "3e2", "\"hi\""] {
            let v = parse(src).unwrap();
            let back = parse(&v.dump()).unwrap();
            assert_eq!(v, back, "{src}");
        }
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".to_string());
        assert_eq!(parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            parse(r#""é""#).unwrap(),
            Json::Str("é".to_string())
        );
    }

    #[test]
    fn rejects_garbage() {
        for src in ["", "{", "[1,", "{\"a\":}", "tru", "1.2.3", "[1] x"] {
            assert!(parse(src).is_err(), "{src}");
        }
    }

    #[test]
    fn rejects_trailing() {
        assert!(parse("{} {}").is_err());
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(5.0).dump(), "5");
        assert_eq!(Json::Num(5.25).dump(), "5.25");
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        // One pinned policy for the whole crate: NaN/Inf become null,
        // never an unparseable bare token.
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
        assert_eq!(Json::Num(f64::INFINITY).dump(), "null");
        assert_eq!(Json::Num(f64::NEG_INFINITY).dump(), "null");
        let doc = Json::obj(vec![("x", Json::Num(f64::NAN))]);
        assert_eq!(parse(&doc.dump()).unwrap().get("x"), Some(&Json::Null));
        // the streaming writer is the same code path
        let mut s = String::new();
        write_num(&mut s, f64::NAN);
        assert_eq!(s, "null");
    }

    #[test]
    fn streaming_writers_match_tree_serialization() {
        let mut s = String::new();
        write_num(&mut s, 5.0);
        s.push(',');
        write_num(&mut s, 5.25);
        s.push(',');
        write_str(&mut s, "a\"b\nc");
        assert_eq!(
            s,
            format!(
                "5,5.25,{}",
                Json::Str("a\"b\nc".to_string()).dump()
            )
        );
    }

    #[test]
    fn pretty_is_parseable() {
        let v = Json::obj(vec![
            ("xs", Json::arr_f64(&[1.0, 2.0])),
            ("name", Json::str("ecore")),
        ]);
        assert_eq!(parse(&v.pretty()).unwrap(), v);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"version": 2, "native_res": 384,
            "models": {"ssd_v1": {"file": "ssd_v1.hlo.txt",
            "params": {"sigmas": [1.4, 2.45], "threshold": 0.03}}}}"#;
        let v = parse(src).unwrap();
        let m = v.req("models").unwrap().req("ssd_v1").unwrap();
        assert_eq!(m.req("file").unwrap().as_str(), Some("ssd_v1.hlo.txt"));
        assert_eq!(
            m.req("params").unwrap().req("sigmas").unwrap().f64s(),
            Some(vec![1.4, 2.45])
        );
    }
}
