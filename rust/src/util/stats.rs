//! Small statistics helpers shared by metrics and experiment reports.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64)
        .sqrt()
}

/// Linear-interpolated percentile, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    percentile_of_sorted(&v, p)
}

/// Several percentiles from one copy + sort (reports query p50/p95/p99
/// together; sorting the sample set once instead of per query).
pub fn percentiles(xs: &[f64], ps: &[f64]) -> Vec<f64> {
    if xs.is_empty() {
        return vec![0.0; ps.len()];
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    ps.iter().map(|&p| percentile_of_sorted(&v, p)).collect()
}

fn percentile_of_sorted(v: &[f64], p: f64) -> f64 {
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Relative change (b vs a) in percent: 100 * (b - a) / a.
pub fn pct_change(a: f64, b: f64) -> f64 {
    if a == 0.0 {
        0.0
    } else {
        100.0 * (b - a) / a
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((std_dev(&[2.0, 4.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
    }

    #[test]
    fn batched_percentiles_match_single_queries() {
        // `super::` path: the sibling test fn `percentiles` shadows
        // the glob-imported function inside this module
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        let ps = [0.0, 25.0, 50.0, 100.0];
        let batch = super::percentiles(&xs, &ps);
        for (i, &p) in ps.iter().enumerate() {
            assert_eq!(batch[i], percentile(&xs, p));
        }
        assert_eq!(
            super::percentiles(&[], &[50.0, 99.0]),
            vec![0.0, 0.0]
        );
    }

    #[test]
    fn pct_change_basic() {
        assert_eq!(pct_change(100.0, 150.0), 50.0);
        assert_eq!(pct_change(0.0, 5.0), 0.0);
        assert_eq!(pct_change(200.0, 100.0), -50.0);
    }
}
