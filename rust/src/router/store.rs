//! Profiling data store: the offline-measured
//! (model, device, group) → (mAP, latency, energy) table Algorithm 1
//! consumes, with JSON persistence and group-indexed lookups.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{self, Json};

/// A (model, device) pair identity.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PairKey {
    pub model: String,
    pub device: String,
}

impl PairKey {
    pub fn new(model: &str, device: &str) -> Self {
        Self {
            model: model.to_string(),
            device: device.to_string(),
        }
    }
}

impl std::fmt::Display for PairKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}@{}", self.model, self.device)
    }
}

/// One profiled row (paper §3.1: mAP_i, t_i, e_i, g_i).
#[derive(Clone, Debug)]
pub struct PairProfile {
    pub pair: PairKey,
    pub group: usize,
    /// mAP on the 0–100 scale (group-'0' rows hold the empty-image score).
    pub map: f64,
    pub latency_s: f64,
    pub energy_mwh: f64,
}

/// The full profiling table.
#[derive(Clone, Debug, Default)]
pub struct ProfileStore {
    rows: Vec<PairProfile>,
    by_group: BTreeMap<usize, Vec<usize>>,
}

impl ProfileStore {
    /// Build a store from profiled rows. Rows with a non-finite
    /// measurement (NaN/±inf mAP, latency, or energy) are rejected
    /// here: one poisoned row would otherwise make every downstream
    /// float comparison (Algorithm 1, baselines, testbed selection)
    /// unreliable.
    pub fn new(rows: Vec<PairProfile>) -> Self {
        let rows = rows
            .into_iter()
            .filter(|r| {
                r.map.is_finite()
                    && r.latency_s.is_finite()
                    && r.energy_mwh.is_finite()
            })
            .collect();
        let mut s = Self {
            rows,
            by_group: BTreeMap::new(),
        };
        s.reindex();
        s
    }

    fn reindex(&mut self) {
        self.by_group.clear();
        for (i, r) in self.rows.iter().enumerate() {
            self.by_group.entry(r.group).or_default().push(i);
        }
    }

    pub fn rows(&self) -> &[PairProfile] {
        &self.rows
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn groups(&self) -> Vec<usize> {
        self.by_group.keys().copied().collect()
    }

    /// All rows for one group (Algorithm 1 line 8).
    pub fn group_rows(&self, group: usize) -> Vec<&PairProfile> {
        self.by_group
            .get(&group)
            .map(|idxs| idxs.iter().map(|&i| &self.rows[i]).collect())
            .unwrap_or_default()
    }

    /// Unique pairs present in the store.
    pub fn pairs(&self) -> Vec<PairKey> {
        let mut v: Vec<PairKey> =
            self.rows.iter().map(|r| r.pair.clone()).collect();
        v.sort();
        v.dedup();
        v
    }

    /// Row for a specific (pair, group).
    pub fn lookup(&self, pair: &PairKey, group: usize) -> Option<&PairProfile> {
        self.group_rows(group)
            .into_iter()
            .find(|r| &r.pair == pair)
    }

    /// Mean mAP of a pair across groups (used by the HM baseline).
    pub fn overall_map(&self, pair: &PairKey) -> f64 {
        let vals: Vec<f64> = self
            .rows
            .iter()
            .filter(|r| &r.pair == pair)
            .map(|r| r.map)
            .collect();
        if vals.is_empty() {
            0.0
        } else {
            vals.iter().sum::<f64>() / vals.len() as f64
        }
    }

    /// Scale one pair's cost columns in place (mAP untouched). The
    /// lifecycle warm-up path ages a rejoining node's rows this way on
    /// a per-request routing view: the node routes as if slower and
    /// hungrier until its warm-up window closes. Group indexing is
    /// unaffected (row identities do not change).
    pub fn scale_pair(
        &mut self,
        pair: &PairKey,
        latency_mult: f64,
        energy_mult: f64,
    ) {
        for r in self.rows.iter_mut().filter(|r| &r.pair == pair) {
            r.latency_s *= latency_mult;
            r.energy_mwh *= energy_mult;
        }
    }

    /// Restrict the store to a subset of pairs (the deployed testbed).
    pub fn restrict(&self, pairs: &[PairKey]) -> ProfileStore {
        ProfileStore::new(
            self.rows
                .iter()
                .filter(|r| pairs.contains(&r.pair))
                .cloned()
                .collect(),
        )
    }

    // ---- persistence ----------------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.rows
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("model", Json::str(&r.pair.model)),
                        ("device", Json::str(&r.pair.device)),
                        ("group", Json::num(r.group as f64)),
                        ("map", Json::num(r.map)),
                        ("latency_s", Json::num(r.latency_s)),
                        ("energy_mwh", Json::num(r.energy_mwh)),
                    ])
                })
                .collect(),
        )
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let arr = j.as_arr().context("profile store must be an array")?;
        let mut rows = Vec::with_capacity(arr.len());
        for item in arr {
            rows.push(PairProfile {
                pair: PairKey::new(
                    item.req("model")?.as_str().context("model")?,
                    item.req("device")?.as_str().context("device")?,
                ),
                group: item.req("group")?.as_usize().context("group")?,
                map: item.req("map")?.as_f64().context("map")?,
                latency_s: item
                    .req("latency_s")?
                    .as_f64()
                    .context("latency_s")?,
                energy_mwh: item
                    .req("energy_mwh")?
                    .as_f64()
                    .context("energy_mwh")?,
            });
        }
        Ok(Self::new(rows))
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().pretty())
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_json(&json::parse(&text)?)
    }
}

#[cfg(test)]
pub(crate) fn test_store() -> ProfileStore {
    // Small hand-built table with known structure: 3 pairs x 2 groups.
    let row = |m: &str, d: &str, g: usize, map: f64, lat: f64, e: f64| {
        PairProfile {
            pair: PairKey::new(m, d),
            group: g,
            map,
            latency_s: lat,
            energy_mwh: e,
        }
    };
    ProfileStore::new(vec![
        row("small", "dev_a", 0, 50.0, 0.010, 1.0),
        row("small", "dev_a", 1, 30.0, 0.010, 1.0),
        row("big", "dev_a", 0, 52.0, 0.100, 9.0),
        row("big", "dev_a", 1, 60.0, 0.100, 9.0),
        row("big", "dev_b", 0, 51.0, 0.050, 4.0),
        row("big", "dev_b", 1, 58.0, 0.050, 4.0),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_index_and_pairs() {
        let s = test_store();
        assert_eq!(s.groups(), vec![0, 1]);
        assert_eq!(s.group_rows(0).len(), 3);
        assert_eq!(s.pairs().len(), 3);
        assert!(s.group_rows(7).is_empty());
    }

    #[test]
    fn lookup_and_overall_map() {
        let s = test_store();
        let k = PairKey::new("big", "dev_a");
        assert_eq!(s.lookup(&k, 1).unwrap().map, 60.0);
        assert!((s.overall_map(&k) - 56.0).abs() < 1e-12);
    }

    #[test]
    fn non_finite_rows_rejected_at_insertion() {
        let mut rows = vec![PairProfile {
            pair: PairKey::new("ok", "d"),
            group: 0,
            map: 40.0,
            latency_s: 0.02,
            energy_mwh: 2.0,
        }];
        for (map, lat, e) in [
            (f64::NAN, 0.01, 1.0),
            (50.0, f64::INFINITY, 1.0),
            (50.0, 0.01, f64::NEG_INFINITY),
        ] {
            rows.push(PairProfile {
                pair: PairKey::new("bad", "d"),
                group: 0,
                map,
                latency_s: lat,
                energy_mwh: e,
            });
        }
        let s = ProfileStore::new(rows);
        assert_eq!(s.rows().len(), 1);
        assert_eq!(s.pairs(), vec![PairKey::new("ok", "d")]);
        // the group index never references a rejected row
        assert_eq!(s.group_rows(0).len(), 1);
    }

    #[test]
    fn scale_pair_ages_costs_in_place() {
        let mut s = test_store();
        let k = PairKey::new("big", "dev_b");
        s.scale_pair(&k, 1.5, 2.0);
        for r in s.rows() {
            if r.pair == k {
                assert!((r.latency_s - 0.075).abs() < 1e-12);
                assert!((r.energy_mwh - 8.0).abs() < 1e-12);
                assert_eq!(r.map, if r.group == 1 { 58.0 } else { 51.0 });
            } else {
                // other pairs untouched
                assert!(r.latency_s <= 0.1 && r.energy_mwh <= 9.0);
            }
        }
        // group index still resolves the scaled rows
        assert_eq!(s.lookup(&k, 0).unwrap().energy_mwh, 8.0);
    }

    #[test]
    fn restrict_drops_other_pairs() {
        let s = test_store();
        let keep = vec![PairKey::new("small", "dev_a")];
        let r = s.restrict(&keep);
        assert_eq!(r.pairs(), keep);
        assert_eq!(r.rows().len(), 2);
    }

    #[test]
    fn json_roundtrip() {
        let s = test_store();
        let j = s.to_json();
        let back = ProfileStore::from_json(&j).unwrap();
        assert_eq!(back.rows().len(), s.rows().len());
        for (a, b) in s.rows().iter().zip(back.rows().iter()) {
            assert_eq!(a.pair, b.pair);
            assert_eq!(a.group, b.group);
            assert!((a.map - b.map).abs() < 1e-12);
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("ecore_store_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("profiles.json");
        let s = test_store();
        s.save(&p).unwrap();
        let back = ProfileStore::load(&p).unwrap();
        assert_eq!(back.rows().len(), s.rows().len());
    }
}
