//! Profiling data store: the offline-measured
//! (model, device, group) → (mAP, latency, energy) table Algorithm 1
//! consumes, with JSON persistence and group-indexed lookups.
//!
//! The store is the routing hot path's data layer, so it is built for
//! zero-allocation reads (DESIGN.md §10):
//!
//! * Pair identities are interned into copyable [`PairId`]s through a
//!   store-owned [`PairTable`]. Ids are assigned in sorted [`PairKey`]
//!   order, so comparing ids and comparing keys give the same order —
//!   every tie-break in the routing policies is bit-identical whether
//!   it runs on strings or on ids.
//! * Rows are stored dense, stably sorted by group, with precomputed
//!   group offsets: [`ProfileStore::group_rows`] returns a borrowed
//!   slice (no `Vec<&_>` per call), and within a group rows keep their
//!   original insertion order, so iteration order — and therefore
//!   every order-dependent tie-break and float reduction — matches the
//!   legacy linear-scan implementation exactly.
//! * Per-pair aggregates (mean energy/latency, overall mAP) are
//!   precomputed at construction by summing in original insertion
//!   order, bit-compatible with the full-table scans they replace.
//! * `(pair, group)` lookups resolve through a dense index in O(1).
//!
//! Copying a store is intentionally loud: [`ProfileStore::clone_count`]
//! exposes a thread-local counter so tests can assert that the
//! per-request routing path performs zero store copies.

use std::cell::Cell;
use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::util::json::{self, Json};

/// A (model, device) pair identity.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PairKey {
    pub model: String,
    pub device: String,
}

impl PairKey {
    pub fn new(model: &str, device: &str) -> Self {
        Self {
            model: model.to_string(),
            device: device.to_string(),
        }
    }
}

impl std::fmt::Display for PairKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}@{}", self.model, self.device)
    }
}

/// Interned pair identity: a copyable handle into a [`PairTable`].
///
/// Ids are assigned in sorted [`PairKey`] order, so `PairId` ordering
/// equals `PairKey` ordering within one table — routing tie-breaks may
/// compare ids instead of strings without changing any decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PairId(pub u32);

impl PairId {
    #[inline]
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// A store-owned symbol table interning [`PairKey`]s into [`PairId`]s.
/// Shared (via `Arc`) with the node pool and membership layers so one
/// id space spans the whole gateway.
#[derive(Debug, Default, PartialEq, Eq)]
pub struct PairTable {
    /// Sorted, distinct keys; `PairId(i)` names `keys[i]`.
    keys: Vec<PairKey>,
}

impl PairTable {
    /// Build a table from arbitrary keys (sorted + deduplicated).
    pub fn from_keys(mut keys: Vec<PairKey>) -> Arc<Self> {
        keys.sort();
        keys.dedup();
        Arc::new(Self { keys })
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    /// Resolve a key to its id (None if the key is not interned).
    pub fn id_of(&self, key: &PairKey) -> Option<PairId> {
        self.keys
            .binary_search(key)
            .ok()
            .map(|i| PairId(i as u32))
    }

    /// The key behind an id. Panics on an id from a different table.
    pub fn key_of(&self, id: PairId) -> &PairKey {
        &self.keys[id.index()]
    }

    /// All ids, ascending (== sorted key order).
    pub fn ids(&self) -> impl Iterator<Item = PairId> {
        (0..self.keys.len() as u32).map(PairId)
    }

    /// All keys, sorted (index i holds `PairId(i)`'s key).
    pub fn keys(&self) -> &[PairKey] {
        &self.keys
    }
}

/// One profiled row (paper §3.1: mAP_i, t_i, e_i, g_i).
#[derive(Clone, Debug)]
pub struct PairProfile {
    pub pair: PairKey,
    pub group: usize,
    /// mAP on the 0–100 scale (group-'0' rows hold the empty-image score).
    pub map: f64,
    pub latency_s: f64,
    pub energy_mwh: f64,
}

/// Precomputed per-pair aggregates (means over the pair's rows, summed
/// in original insertion order so they equal the legacy full-table
/// scans bit for bit).
#[derive(Clone, Copy, Debug, Default)]
pub struct PairStats {
    pub mean_energy_mwh: f64,
    pub mean_latency_s: f64,
    pub overall_map: f64,
}

thread_local! {
    /// Per-thread count of ProfileStore deep copies — the hot-path
    /// regression tests assert this stays flat across routed requests.
    static STORE_CLONES: Cell<usize> = const { Cell::new(0) };
}

/// Sentinel for "no row" in the dense (pair, group) index.
const NO_ROW: u32 = u32::MAX;

/// The full profiling table (indexed; see the module docs).
#[derive(Debug)]
pub struct ProfileStore {
    /// Rows stably sorted by group; within a group, original insertion
    /// order (so per-group iteration matches the legacy index exactly).
    rows: Vec<PairProfile>,
    /// Interned id of each row, aligned with `rows`.
    row_ids: Vec<PairId>,
    /// Sorted distinct group labels.
    groups: Vec<usize>,
    /// `groups[i]`'s rows are `rows[group_starts[i]..group_starts[i+1]]`.
    group_starts: Vec<usize>,
    /// The pair interner (shared with pool/membership via `Arc`).
    table: Arc<PairTable>,
    /// Per-pair aggregates, indexed by `PairId`.
    stats: Vec<PairStats>,
    /// Per-pair row indices (into `rows`) in original insertion order.
    pair_rows: Vec<Vec<u32>>,
    /// Dense `(pair, group-position) -> row` index (`NO_ROW` = absent;
    /// duplicates keep the first-inserted row, like the legacy scan).
    pair_group_row: Vec<u32>,
    /// Row indices in original insertion order (JSON dumps and
    /// `restrict` reproduce the legacy row order through this).
    by_insertion: Vec<u32>,
}

impl Default for ProfileStore {
    fn default() -> Self {
        Self::new(Vec::new())
    }
}

impl Clone for ProfileStore {
    fn clone(&self) -> Self {
        STORE_CLONES.with(|c| c.set(c.get() + 1));
        Self {
            rows: self.rows.clone(),
            row_ids: self.row_ids.clone(),
            groups: self.groups.clone(),
            group_starts: self.group_starts.clone(),
            table: Arc::clone(&self.table),
            stats: self.stats.clone(),
            pair_rows: self.pair_rows.clone(),
            pair_group_row: self.pair_group_row.clone(),
            by_insertion: self.by_insertion.clone(),
        }
    }
}

impl ProfileStore {
    /// Build a store from profiled rows. Rows with a non-finite
    /// measurement (NaN/±inf mAP, latency, or energy) are rejected
    /// here: one poisoned row would otherwise make every downstream
    /// float comparison (Algorithm 1, baselines, testbed selection)
    /// unreliable.
    pub fn new(rows: Vec<PairProfile>) -> Self {
        let pending: Vec<PairProfile> = rows
            .into_iter()
            .filter(|r| {
                r.map.is_finite()
                    && r.latency_s.is_finite()
                    && r.energy_mwh.is_finite()
            })
            .collect();
        let table = PairTable::from_keys(
            pending.iter().map(|r| r.pair.clone()).collect(),
        );
        let n_pairs = table.len();

        // ids per input row, in insertion order
        let ids: Vec<PairId> = pending
            .iter()
            .map(|r| table.id_of(&r.pair).expect("row pair interned"))
            .collect();

        // per-pair aggregates, accumulated in insertion order —
        // bit-compatible with the legacy `rows().filter(pair)` scans
        let mut e_sum = vec![0.0f64; n_pairs];
        let mut l_sum = vec![0.0f64; n_pairs];
        let mut m_sum = vec![0.0f64; n_pairs];
        let mut counts = vec![0usize; n_pairs];
        for (r, id) in pending.iter().zip(&ids) {
            let i = id.index();
            e_sum[i] += r.energy_mwh;
            l_sum[i] += r.latency_s;
            m_sum[i] += r.map;
            counts[i] += 1;
        }
        let stats: Vec<PairStats> = (0..n_pairs)
            .map(|i| {
                let n = counts[i].max(1) as f64;
                PairStats {
                    mean_energy_mwh: e_sum[i] / n,
                    mean_latency_s: l_sum[i] / n,
                    overall_map: m_sum[i] / n,
                }
            })
            .collect();

        // stable sort by group: within a group, insertion order survives
        let mut order: Vec<u32> = (0..pending.len() as u32).collect();
        order.sort_by_key(|&i| pending[i as usize].group);
        let mut slots: Vec<Option<PairProfile>> =
            pending.into_iter().map(Some).collect();
        let mut rows = Vec::with_capacity(slots.len());
        let mut row_ids = Vec::with_capacity(slots.len());
        let mut by_insertion = vec![0u32; slots.len()];
        for (si, &oi) in order.iter().enumerate() {
            rows.push(slots[oi as usize].take().expect("unique order"));
            row_ids.push(ids[oi as usize]);
            by_insertion[oi as usize] = si as u32;
        }

        // group offsets over the sorted rows
        let mut groups: Vec<usize> = Vec::new();
        let mut group_starts: Vec<usize> = Vec::new();
        for (si, r) in rows.iter().enumerate() {
            if groups.last() != Some(&r.group) {
                groups.push(r.group);
                group_starts.push(si);
            }
        }
        group_starts.push(rows.len());

        // per-pair row lists in insertion order
        let mut pair_rows: Vec<Vec<u32>> = vec![Vec::new(); n_pairs];
        for &si in &by_insertion {
            pair_rows[row_ids[si as usize].index()].push(si);
        }

        // dense (pair, group) -> first-inserted row
        let n_groups = groups.len();
        let mut pair_group_row = vec![NO_ROW; n_pairs * n_groups];
        for (si, r) in rows.iter().enumerate() {
            let gi = groups
                .binary_search(&r.group)
                .expect("group collected above");
            let cell =
                &mut pair_group_row[row_ids[si].index() * n_groups + gi];
            if *cell == NO_ROW {
                *cell = si as u32;
            }
        }

        Self {
            rows,
            row_ids,
            groups,
            group_starts,
            table,
            stats,
            pair_rows,
            pair_group_row,
            by_insertion,
        }
    }

    /// Deep copies of `ProfileStore` performed by this thread so far.
    /// The zero-allocation routing tests snapshot this around the hot
    /// path to prove no per-request store copy happens.
    pub fn clone_count() -> usize {
        STORE_CLONES.with(|c| c.get())
    }

    /// All rows, sorted by group (within a group: insertion order).
    pub fn rows(&self) -> &[PairProfile] {
        &self.rows
    }

    /// Interned id of `rows()[i]`, aligned with [`ProfileStore::rows`].
    pub fn row_ids(&self) -> &[PairId] {
        &self.row_ids
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn groups(&self) -> Vec<usize> {
        self.groups.clone()
    }

    /// The pair interner.
    pub fn table(&self) -> &PairTable {
        &self.table
    }

    /// A shareable handle to the interner (node pools bind to it so
    /// gateway-side lookups are O(1) id hits).
    pub fn table_arc(&self) -> Arc<PairTable> {
        Arc::clone(&self.table)
    }

    /// Distinct pairs in the store (== interned ids).
    pub fn n_pairs(&self) -> usize {
        self.table.len()
    }

    /// All pair ids, ascending (== sorted key order).
    pub fn pair_ids(&self) -> impl Iterator<Item = PairId> {
        self.table.ids()
    }

    pub fn id_of(&self, pair: &PairKey) -> Option<PairId> {
        self.table.id_of(pair)
    }

    pub fn key_of(&self, id: PairId) -> &PairKey {
        self.table.key_of(id)
    }

    fn group_index(&self, group: usize) -> Option<usize> {
        self.groups.binary_search(&group).ok()
    }

    /// All rows for one group (Algorithm 1 line 8), as a borrowed
    /// slice of the dense storage — zero allocation per call.
    pub fn group_rows(&self, group: usize) -> &[PairProfile] {
        match self.group_index(group) {
            Some(gi) => {
                &self.rows[self.group_starts[gi]..self.group_starts[gi + 1]]
            }
            None => &[],
        }
    }

    /// One group's rows plus their interned ids (aligned slices).
    pub fn group_rows_ids(
        &self,
        group: usize,
    ) -> (&[PairProfile], &[PairId]) {
        match self.group_index(group) {
            Some(gi) => {
                let span =
                    self.group_starts[gi]..self.group_starts[gi + 1];
                (&self.rows[span.clone()], &self.row_ids[span])
            }
            None => (&[], &[]),
        }
    }

    /// Unique pairs present in the store (sorted).
    pub fn pairs(&self) -> Vec<PairKey> {
        self.table.keys().to_vec()
    }

    /// Row for a specific (pair, group): an O(1) index hit. Duplicate
    /// (pair, group) rows resolve to the first-inserted one, like the
    /// linear scan this replaces.
    pub fn lookup(&self, pair: &PairKey, group: usize) -> Option<&PairProfile> {
        self.lookup_id(self.id_of(pair)?, group)
    }

    /// [`ProfileStore::lookup`] by interned id.
    pub fn lookup_id(&self, id: PairId, group: usize) -> Option<&PairProfile> {
        let gi = self.group_index(group)?;
        let cell = *self
            .pair_group_row
            .get(id.index() * self.groups.len() + gi)?;
        if cell == NO_ROW {
            None
        } else {
            Some(&self.rows[cell as usize])
        }
    }

    /// Precomputed per-pair aggregates.
    pub fn stats_of(&self, id: PairId) -> PairStats {
        self.stats[id.index()]
    }

    /// Row indices (into [`ProfileStore::rows`]) of one pair, in
    /// original insertion order — the order the legacy full-table
    /// scans visited them in.
    pub fn pair_row_indices(&self, id: PairId) -> &[u32] {
        &self.pair_rows[id.index()]
    }

    /// Mean mAP of a pair across groups (used by the HM baseline).
    pub fn overall_map(&self, pair: &PairKey) -> f64 {
        match self.id_of(pair) {
            Some(id) => self.stats[id.index()].overall_map,
            None => 0.0,
        }
    }

    /// Scale one pair's cost columns in place (mAP untouched), using
    /// the pair index instead of a full-table scan. Group indexing is
    /// unaffected (row identities do not change); the pair's
    /// precomputed means are refreshed.
    pub fn scale_pair(
        &mut self,
        pair: &PairKey,
        latency_mult: f64,
        energy_mult: f64,
    ) {
        let Some(id) = self.id_of(pair) else {
            return;
        };
        // move the index list out while mutating rows (no allocation)
        let idxs = std::mem::take(&mut self.pair_rows[id.index()]);
        for &ri in &idxs {
            let r = &mut self.rows[ri as usize];
            r.latency_s *= latency_mult;
            r.energy_mwh *= energy_mult;
        }
        self.pair_rows[id.index()] = idxs;
        self.recompute_stats(id);
    }

    /// Refresh one pair's means after a row mutation (insertion-order
    /// sums, bit-compatible with the legacy scans).
    fn recompute_stats(&mut self, id: PairId) {
        let idxs = &self.pair_rows[id.index()];
        let mut e = 0.0;
        let mut l = 0.0;
        let mut m = 0.0;
        for &ri in idxs {
            let r = &self.rows[ri as usize];
            e += r.energy_mwh;
            l += r.latency_s;
            m += r.map;
        }
        let n = idxs.len().max(1) as f64;
        self.stats[id.index()] = PairStats {
            mean_energy_mwh: e / n,
            mean_latency_s: l / n,
            overall_map: m / n,
        };
    }

    /// Restrict the store to a subset of pairs (the deployed testbed).
    /// Set-based: O(subset · log pairs + rows) instead of the old
    /// O(rows × subset) `contains` scan. Rows are emitted in original
    /// insertion order, so the result is identical to the legacy
    /// filter.
    pub fn restrict(&self, pairs: &[PairKey]) -> ProfileStore {
        let mut keep = vec![false; self.table.len()];
        for p in pairs {
            if let Some(id) = self.id_of(p) {
                keep[id.index()] = true;
            }
        }
        ProfileStore::new(
            self.by_insertion
                .iter()
                .filter(|&&si| keep[self.row_ids[si as usize].index()])
                .map(|&si| self.rows[si as usize].clone())
                .collect(),
        )
    }

    // ---- persistence ----------------------------------------------------

    /// Serialize in original insertion order (stable across the
    /// indexed-storage refactor: saved files keep their legacy layout).
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.by_insertion
                .iter()
                .map(|&si| {
                    let r = &self.rows[si as usize];
                    Json::obj(vec![
                        ("model", Json::str(&r.pair.model)),
                        ("device", Json::str(&r.pair.device)),
                        ("group", Json::num(r.group as f64)),
                        ("map", Json::num(r.map)),
                        ("latency_s", Json::num(r.latency_s)),
                        ("energy_mwh", Json::num(r.energy_mwh)),
                    ])
                })
                .collect(),
        )
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let arr = j.as_arr().context("profile store must be an array")?;
        let mut rows = Vec::with_capacity(arr.len());
        for item in arr {
            rows.push(PairProfile {
                pair: PairKey::new(
                    item.req("model")?.as_str().context("model")?,
                    item.req("device")?.as_str().context("device")?,
                ),
                group: item.req("group")?.as_usize().context("group")?,
                map: item.req("map")?.as_f64().context("map")?,
                latency_s: item
                    .req("latency_s")?
                    .as_f64()
                    .context("latency_s")?,
                energy_mwh: item
                    .req("energy_mwh")?
                    .as_f64()
                    .context("energy_mwh")?,
            });
        }
        Ok(Self::new(rows))
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().pretty())
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_json(&json::parse(&text)?)
    }
}

#[cfg(test)]
pub(crate) fn test_store() -> ProfileStore {
    // Small hand-built table with known structure: 3 pairs x 2 groups.
    let row = |m: &str, d: &str, g: usize, map: f64, lat: f64, e: f64| {
        PairProfile {
            pair: PairKey::new(m, d),
            group: g,
            map,
            latency_s: lat,
            energy_mwh: e,
        }
    };
    ProfileStore::new(vec![
        row("small", "dev_a", 0, 50.0, 0.010, 1.0),
        row("small", "dev_a", 1, 30.0, 0.010, 1.0),
        row("big", "dev_a", 0, 52.0, 0.100, 9.0),
        row("big", "dev_a", 1, 60.0, 0.100, 9.0),
        row("big", "dev_b", 0, 51.0, 0.050, 4.0),
        row("big", "dev_b", 1, 58.0, 0.050, 4.0),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_index_and_pairs() {
        let s = test_store();
        assert_eq!(s.groups(), vec![0, 1]);
        assert_eq!(s.group_rows(0).len(), 3);
        assert_eq!(s.pairs().len(), 3);
        assert!(s.group_rows(7).is_empty());
    }

    #[test]
    fn lookup_and_overall_map() {
        let s = test_store();
        let k = PairKey::new("big", "dev_a");
        assert_eq!(s.lookup(&k, 1).unwrap().map, 60.0);
        assert!((s.overall_map(&k) - 56.0).abs() < 1e-12);
    }

    #[test]
    fn interned_ids_follow_sorted_key_order() {
        let s = test_store();
        // sorted keys: big@dev_a < big@dev_b < small@dev_a
        let a = PairKey::new("big", "dev_a");
        let b = PairKey::new("big", "dev_b");
        let c = PairKey::new("small", "dev_a");
        assert_eq!(s.id_of(&a), Some(PairId(0)));
        assert_eq!(s.id_of(&b), Some(PairId(1)));
        assert_eq!(s.id_of(&c), Some(PairId(2)));
        assert_eq!(s.key_of(PairId(1)), &b);
        assert_eq!(s.id_of(&PairKey::new("ghost", "d")), None);
        // id order == key order
        let keys: Vec<&PairKey> =
            s.pair_ids().map(|id| s.key_of(id)).collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted);
        // row_ids align with rows
        for (r, id) in s.rows().iter().zip(s.row_ids()) {
            assert_eq!(s.key_of(*id), &r.pair);
        }
    }

    #[test]
    fn group_rows_are_dense_slices_in_insertion_order() {
        // rows inserted with DESCENDING groups per pair: the stable
        // group sort must still preserve within-group insertion order
        let row = |m: &str, g: usize, e: f64| PairProfile {
            pair: PairKey::new(m, "d"),
            group: g,
            map: 50.0,
            latency_s: 0.01,
            energy_mwh: e,
        };
        let s = ProfileStore::new(vec![
            row("x", 1, 1.0),
            row("y", 0, 2.0),
            row("x", 0, 3.0),
            row("y", 1, 4.0),
        ]);
        let g0: Vec<f64> =
            s.group_rows(0).iter().map(|r| r.energy_mwh).collect();
        assert_eq!(g0, vec![2.0, 3.0], "insertion order within group");
        let g1: Vec<f64> =
            s.group_rows(1).iter().map(|r| r.energy_mwh).collect();
        assert_eq!(g1, vec![1.0, 4.0]);
        // the (pair, group) index resolves every row
        assert_eq!(s.lookup(&PairKey::new("x", "d"), 0).unwrap().energy_mwh, 3.0);
        assert_eq!(s.lookup(&PairKey::new("y", "d"), 1).unwrap().energy_mwh, 4.0);
        assert!(s.lookup(&PairKey::new("x", "d"), 9).is_none());
    }

    #[test]
    fn duplicate_pair_group_rows_resolve_to_first_inserted() {
        let row = |e: f64| PairProfile {
            pair: PairKey::new("m", "d"),
            group: 0,
            map: 50.0,
            latency_s: 0.01,
            energy_mwh: e,
        };
        let s = ProfileStore::new(vec![row(5.0), row(7.0)]);
        assert_eq!(s.group_rows(0).len(), 2);
        assert_eq!(
            s.lookup(&PairKey::new("m", "d"), 0).unwrap().energy_mwh,
            5.0,
            "lookup must keep legacy first-match semantics"
        );
    }

    #[test]
    fn non_finite_rows_rejected_at_insertion() {
        let mut rows = vec![PairProfile {
            pair: PairKey::new("ok", "d"),
            group: 0,
            map: 40.0,
            latency_s: 0.02,
            energy_mwh: 2.0,
        }];
        for (map, lat, e) in [
            (f64::NAN, 0.01, 1.0),
            (50.0, f64::INFINITY, 1.0),
            (50.0, 0.01, f64::NEG_INFINITY),
        ] {
            rows.push(PairProfile {
                pair: PairKey::new("bad", "d"),
                group: 0,
                map,
                latency_s: lat,
                energy_mwh: e,
            });
        }
        let s = ProfileStore::new(rows);
        assert_eq!(s.rows().len(), 1);
        assert_eq!(s.pairs(), vec![PairKey::new("ok", "d")]);
        // the group index never references a rejected row
        assert_eq!(s.group_rows(0).len(), 1);
    }

    #[test]
    fn scale_pair_ages_costs_in_place() {
        let mut s = test_store();
        let k = PairKey::new("big", "dev_b");
        s.scale_pair(&k, 1.5, 2.0);
        for r in s.rows() {
            if r.pair == k {
                assert!((r.latency_s - 0.075).abs() < 1e-12);
                assert!((r.energy_mwh - 8.0).abs() < 1e-12);
                assert_eq!(r.map, if r.group == 1 { 58.0 } else { 51.0 });
            } else {
                // other pairs untouched
                assert!(r.latency_s <= 0.1 && r.energy_mwh <= 9.0);
            }
        }
        // group index still resolves the scaled rows
        assert_eq!(s.lookup(&k, 0).unwrap().energy_mwh, 8.0);
        // precomputed means track the scaling
        let id = s.id_of(&k).unwrap();
        assert!((s.stats_of(id).mean_energy_mwh - 8.0).abs() < 1e-12);
        assert!((s.stats_of(id).mean_latency_s - 0.075).abs() < 1e-12);
        // scaling an unknown pair is a no-op
        s.scale_pair(&PairKey::new("ghost", "d"), 2.0, 2.0);
    }

    #[test]
    fn restrict_drops_other_pairs() {
        let s = test_store();
        let keep = vec![PairKey::new("small", "dev_a")];
        let r = s.restrict(&keep);
        assert_eq!(r.pairs(), keep);
        assert_eq!(r.rows().len(), 2);
    }

    #[test]
    fn clone_counter_tracks_deep_copies() {
        let s = test_store();
        let before = ProfileStore::clone_count();
        let _c = s.clone();
        assert_eq!(ProfileStore::clone_count(), before + 1);
        // reads never count as copies
        let _ = s.group_rows(0);
        let _ = s.pairs();
        assert_eq!(ProfileStore::clone_count(), before + 1);
    }

    #[test]
    fn json_roundtrip() {
        let s = test_store();
        let j = s.to_json();
        let back = ProfileStore::from_json(&j).unwrap();
        assert_eq!(back.rows().len(), s.rows().len());
        for (a, b) in s.rows().iter().zip(back.rows().iter()) {
            assert_eq!(a.pair, b.pair);
            assert_eq!(a.group, b.group);
            assert!((a.map - b.map).abs() < 1e-12);
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("ecore_store_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("profiles.json");
        let s = test_store();
        s.save(&p).unwrap();
        let back = ProfileStore::load(&p).unwrap();
        assert_eq!(back.rows().len(), s.rows().len());
    }
}
