//! Algorithm 1: the greedy routing algorithm.
//!
//! Given an estimated object-count group G and the profiling table, the
//! router (1) filters to rows of group G, (2) computes
//! `mAP_max = max_i mAP_i`, (3) forms the feasible set
//! `F = { i : mAP_i >= mAP_max - delta_mAP }`, and (4) returns
//! `argmin_{i in F} e_i`. Theorem 3.1 (optimality) holds because after
//! filtering the problem is an unconstrained 1-D minimization over
//! independent profiled values; the property tests below check the
//! theorem's claim against brute force.

use super::store::{PairId, PairKey, ProfileStore};
use super::view::RoutingView;

#[derive(Clone, Debug)]
pub struct GreedyRouter {
    /// Accuracy tolerance margin, mAP points on the 0–100 scale.
    pub delta_map: f64,
}

impl GreedyRouter {
    pub fn new(delta_map: f64) -> Self {
        Self { delta_map }
    }

    /// Route one request over a borrowed view — the zero-allocation
    /// hot path. Returns the chosen pair id, or None if the group has
    /// no (non-excluded) profiled rows.
    pub fn route_view(
        &self,
        view: &RoutingView<'_>,
        group: usize,
    ) -> Option<PairId> {
        // lines 10-11: max achievable mAP and the feasibility threshold
        // (warm-up aging never touches mAP, so the overlay is ignored)
        let mut map_max = f64::NEG_INFINITY;
        let mut any = false;
        for (_, r, _) in view.group_iter(group) {
            map_max = map_max.max(r.map);
            any = true;
        }
        if !any {
            return None;
        }
        let map_min = map_max - self.delta_map;
        // lines 12-14: filter, then pick the lowest effective-energy
        // row (profiled energy times the warm-up multiplier — the same
        // arithmetic the old aged store copy materialized). The
        // comparison is total (NaN-safe — non-finite rows are rejected
        // at ProfileStore insertion) and energy ties break by pair id,
        // which equals the legacy pair-key tie-break because ids are
        // interned in sorted key order.
        view.group_iter(group)
            .filter(|(_, r, _)| r.map >= map_min)
            .min_by(|(ia, ra, ma), (ib, rb, mb)| {
                (ra.energy_mwh * ma)
                    .total_cmp(&(rb.energy_mwh * mb))
                    .then_with(|| ia.cmp(ib))
            })
            .map(|(id, _, _)| id)
    }

    /// Route one request directly over a store (plain view). Returns
    /// the chosen pair, or None if the group has no profiled rows.
    pub fn route(&self, store: &ProfileStore, group: usize) -> Option<PairKey> {
        self.route_view(&RoutingView::new(store), group)
            .map(|id| store.key_of(id).clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::router::store::{test_store, PairProfile};
    use crate::util::prop::forall_ok;
    use crate::util::rng::Rng;

    #[test]
    fn strict_delta_zero_picks_best_map() {
        let s = test_store();
        let r = GreedyRouter::new(0.0);
        // group 1: best mAP is ("big", "dev_a") at 60.0
        assert_eq!(r.route(&s, 1), Some(PairKey::new("big", "dev_a")));
    }

    #[test]
    fn relaxed_delta_switches_to_cheaper_pair() {
        let s = test_store();
        // group 1: delta 5 admits big@dev_b (58.0, energy 4) -> cheaper
        assert_eq!(
            GreedyRouter::new(5.0).route(&s, 1),
            Some(PairKey::new("big", "dev_b"))
        );
        // delta 30 admits small@dev_a (30.0, energy 1)
        assert_eq!(
            GreedyRouter::new(30.0).route(&s, 1),
            Some(PairKey::new("small", "dev_a"))
        );
    }

    #[test]
    fn unknown_group_routes_none() {
        let s = test_store();
        assert_eq!(GreedyRouter::new(5.0).route(&s, 9), None);
    }

    #[test]
    fn nan_energy_rows_cannot_poison_routing() {
        // regression: `min_by(partial_cmp().unwrap())` panicked when a
        // NaN energy row entered the table; non-finite rows are now
        // rejected at ProfileStore insertion and the comparison itself
        // is total, so a poisoned profiling dump degrades gracefully.
        let row = |m: &str, map: f64, lat: f64, e: f64| PairProfile {
            pair: PairKey::new(m, "d"),
            group: 0,
            map,
            latency_s: lat,
            energy_mwh: e,
        };
        let s = ProfileStore::new(vec![
            row("ok", 50.0, 0.01, 1.0),
            row("nan_energy", 60.0, 0.01, f64::NAN),
            row("inf_latency", 55.0, f64::INFINITY, 0.5),
            row("nan_map", f64::NAN, 0.01, 0.1),
        ]);
        assert_eq!(s.rows().len(), 1);
        let got = GreedyRouter::new(100.0).route(&s, 0);
        assert_eq!(got, Some(PairKey::new("ok", "d")));
    }

    #[test]
    fn equal_energy_ties_break_by_pair_key() {
        let row = |m: &str| PairProfile {
            pair: PairKey::new(m, "d"),
            group: 0,
            map: 50.0,
            latency_s: 0.01,
            energy_mwh: 1.0,
        };
        // identical rows under both insertion orders -> same winner
        let fwd = ProfileStore::new(vec![row("a"), row("b"), row("c")]);
        let rev = ProfileStore::new(vec![row("c"), row("b"), row("a")]);
        let r = GreedyRouter::new(5.0);
        assert_eq!(r.route(&fwd, 0), Some(PairKey::new("a", "d")));
        assert_eq!(r.route(&fwd, 0), r.route(&rev, 0));
    }

    fn random_store(r: &mut Rng) -> ProfileStore {
        let n_pairs = 2 + r.below(8) as usize;
        let mut rows = Vec::new();
        for p in 0..n_pairs {
            for g in 0..3usize {
                rows.push(PairProfile {
                    pair: PairKey::new(&format!("m{p}"), "d"),
                    group: g,
                    map: r.range(0.0, 100.0),
                    latency_s: r.range(0.001, 1.0),
                    energy_mwh: r.range(0.1, 10.0),
                });
            }
        }
        ProfileStore::new(rows)
    }

    /// Theorem 3.1: the greedy choice equals the brute-force optimum of
    /// the constrained problem, and satisfies all constraints.
    #[test]
    fn prop_matches_brute_force_and_respects_constraints() {
        forall_ok(
            51,
            200,
            |r| {
                let delta = [0.0, 5.0, 10.0, 25.0][r.below(4) as usize];
                (random_store(r), delta, r.below(3) as usize)
            },
            |(store, delta, group)| {
                let got = GreedyRouter::new(*delta)
                    .route(store, *group)
                    .ok_or("no route")?;
                let rows = store.group_rows(*group);
                let map_max = rows
                    .iter()
                    .map(|r| r.map)
                    .fold(f64::NEG_INFINITY, f64::max);
                let feasible: Vec<_> = rows
                    .iter()
                    .filter(|r| r.map >= map_max - delta)
                    .collect();
                let brute = feasible
                    .iter()
                    .min_by(|a, b| a.energy_mwh.total_cmp(&b.energy_mwh))
                    .unwrap();
                // (i) result is in the group and feasible
                let chosen = store
                    .lookup(&got, *group)
                    .ok_or("chosen pair not in group")?;
                if chosen.map < map_max - delta - 1e-12 {
                    return Err(format!(
                        "constraint violated: {} < {} - {}",
                        chosen.map, map_max, delta
                    ));
                }
                // (ii) no feasible row has strictly lower energy
                if chosen.energy_mwh > brute.energy_mwh + 1e-12 {
                    return Err(format!(
                        "not optimal: {} > {}",
                        chosen.energy_mwh, brute.energy_mwh
                    ));
                }
                Ok(())
            },
        );
    }

    /// Monotonicity: widening delta never increases chosen energy.
    #[test]
    fn prop_energy_monotone_in_delta() {
        forall_ok(
            52,
            150,
            |r| random_store(r),
            |store| {
                let mut prev = f64::INFINITY;
                for delta in [0.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0] {
                    let pair = GreedyRouter::new(delta)
                        .route(store, 0)
                        .ok_or("no route")?;
                    let e = store.lookup(&pair, 0).unwrap().energy_mwh;
                    if e > prev + 1e-12 {
                        return Err(format!(
                            "energy increased with delta: {e} > {prev}"
                        ));
                    }
                    prev = e;
                }
                Ok(())
            },
        );
    }
}
