//! Object-count group rules (Algorithm 1, lines 1–7).
//!
//! A rule set maps an estimated object count to a group label via ordered
//! numeric ranges. The paper's configuration is five groups:
//! '0', '1', '2', '3', '4 or more'.

/// One rule: counts in `lo..=hi` belong to `label`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GroupRule {
    pub lo: usize,
    /// Inclusive upper bound; `usize::MAX` encodes "or more".
    pub hi: usize,
    pub label: usize,
}

#[derive(Clone, Debug)]
pub struct GroupRules {
    rules: Vec<GroupRule>,
}

impl GroupRules {
    /// The paper's five-group configuration.
    pub fn paper_default() -> Self {
        Self {
            rules: vec![
                GroupRule { lo: 0, hi: 0, label: 0 },
                GroupRule { lo: 1, hi: 1, label: 1 },
                GroupRule { lo: 2, hi: 2, label: 2 },
                GroupRule { lo: 3, hi: 3, label: 3 },
                GroupRule { lo: 4, hi: usize::MAX, label: 4 },
            ],
        }
    }

    /// Build custom rules; validates totality and non-overlap over 0..=max.
    pub fn new(rules: Vec<GroupRule>) -> Result<Self, String> {
        let mut sorted = rules.clone();
        sorted.sort_by_key(|r| r.lo);
        let mut expect = 0usize;
        for r in &sorted {
            if r.lo > r.hi {
                return Err(format!("rule {r:?}: empty range"));
            }
            if r.lo != expect {
                return Err(format!(
                    "rules not contiguous at count {expect} (rule {r:?})"
                ));
            }
            if r.hi == usize::MAX {
                expect = usize::MAX;
            } else {
                expect = r.hi + 1;
            }
        }
        if expect != usize::MAX {
            return Err("rules do not cover all counts (missing tail)".into());
        }
        Ok(Self { rules })
    }

    /// Algorithm 1 group lookup.
    pub fn group_of(&self, count: usize) -> usize {
        for r in &self.rules {
            if count >= r.lo && count <= r.hi {
                return r.label;
            }
        }
        unreachable!("rules are total by construction")
    }

    pub fn num_groups(&self) -> usize {
        self.rules.len()
    }

    /// A representative count for a group (for tests / synthetic sets).
    pub fn representative(&self, label: usize) -> Option<usize> {
        self.rules.iter().find(|r| r.label == label).map(|r| r.lo)
    }

    pub fn labels(&self) -> Vec<usize> {
        self.rules.iter().map(|r| r.label).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn paper_default_mapping() {
        let g = GroupRules::paper_default();
        assert_eq!(g.group_of(0), 0);
        assert_eq!(g.group_of(1), 1);
        assert_eq!(g.group_of(2), 2);
        assert_eq!(g.group_of(3), 3);
        assert_eq!(g.group_of(4), 4);
        assert_eq!(g.group_of(19), 4);
        assert_eq!(g.group_of(usize::MAX), 4);
        assert_eq!(g.num_groups(), 5);
    }

    #[test]
    fn prop_total_cover() {
        let g = GroupRules::paper_default();
        forall(
            41,
            500,
            |r| r.below(1000) as usize,
            |&c| g.group_of(c) < g.num_groups(),
        );
    }

    #[test]
    fn rejects_gap() {
        let r = GroupRules::new(vec![
            GroupRule { lo: 0, hi: 0, label: 0 },
            GroupRule { lo: 2, hi: usize::MAX, label: 1 },
        ]);
        assert!(r.is_err());
    }

    #[test]
    fn rejects_overlap() {
        let r = GroupRules::new(vec![
            GroupRule { lo: 0, hi: 2, label: 0 },
            GroupRule { lo: 2, hi: usize::MAX, label: 1 },
        ]);
        assert!(r.is_err());
    }

    #[test]
    fn rejects_missing_tail() {
        let r = GroupRules::new(vec![GroupRule { lo: 0, hi: 5, label: 0 }]);
        assert!(r.is_err());
    }

    #[test]
    fn accepts_coarser_grouping() {
        let g = GroupRules::new(vec![
            GroupRule { lo: 0, hi: 1, label: 0 },
            GroupRule { lo: 2, hi: usize::MAX, label: 1 },
        ])
        .unwrap();
        assert_eq!(g.group_of(1), 0);
        assert_eq!(g.group_of(2), 1);
    }
}
