//! Multi-objective weighted routing — the paper's Future Work #3
//! ("incorporating multi-objective optimization techniques, such as
//! Pareto-based or weighted approaches").
//!
//! Instead of Algorithm 1's lexicographic scheme (filter by accuracy,
//! then minimize energy), [`WeightedRouter`] scalarizes the three
//! objectives with user weights over *normalized* per-group metrics, and
//! [`pareto_front`] exposes the non-dominated set for inspection. The
//! `ablation_weighted` experiment compares both against the greedy
//! router across weight settings.

use super::store::{PairKey, PairProfile, ProfileStore};

/// Objective weights (will be normalized; larger = more important).
#[derive(Clone, Copy, Debug)]
pub struct Weights {
    pub energy: f64,
    pub latency: f64,
    pub accuracy: f64,
}

impl Default for Weights {
    fn default() -> Self {
        Self {
            energy: 1.0,
            latency: 0.0,
            accuracy: 1.0,
        }
    }
}

#[derive(Clone, Debug)]
pub struct WeightedRouter {
    pub weights: Weights,
}

impl WeightedRouter {
    pub fn new(weights: Weights) -> Self {
        Self { weights }
    }

    /// Score = w_e * ê + w_l * t̂ − w_a * m̂ over min-max normalized group
    /// metrics; the minimizer wins. Returns None for unknown groups.
    pub fn route(&self, store: &ProfileStore, group: usize) -> Option<PairKey> {
        let rows = store.group_rows(group);
        if rows.is_empty() {
            return None;
        }
        let norm = |f: &dyn Fn(&PairProfile) -> f64| {
            let vals: Vec<f64> = rows.iter().map(|r| f(r)).collect();
            let lo = vals.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let span = (hi - lo).max(1e-12);
            vals.into_iter()
                .map(|v| (v - lo) / span)
                .collect::<Vec<f64>>()
        };
        let e = norm(&|r| r.energy_mwh);
        let t = norm(&|r| r.latency_s);
        let m = norm(&|r| r.map);
        let w = self.weights;
        let total = (w.energy + w.latency + w.accuracy).max(1e-12);
        // total order: a NaN score (possible when a caller passes
        // non-finite weights — profiled rows themselves are validated
        // at store insertion) sorts last instead of panicking, and
        // score ties break by row position, which is sorted pair-key
        // order, so the winner is deterministic across runs.
        rows.iter()
            .enumerate()
            .min_by(|(i, _), (j, _)| {
                let si = (w.energy * e[*i] + w.latency * t[*i]
                    - w.accuracy * m[*i])
                    / total;
                let sj = (w.energy * e[*j] + w.latency * t[*j]
                    - w.accuracy * m[*j])
                    / total;
                si.total_cmp(&sj).then_with(|| i.cmp(j))
            })
            .map(|(_, r)| r.pair.clone())
    }
}

/// Non-dominated (energy↓, latency↓, mAP↑) rows of one group.
pub fn pareto_front<'a>(
    store: &'a ProfileStore,
    group: usize,
) -> Vec<&'a PairProfile> {
    let rows = store.group_rows(group);
    rows.iter()
        .filter(|a| {
            !rows.iter().any(|b| {
                // b dominates a
                b.energy_mwh <= a.energy_mwh
                    && b.latency_s <= a.latency_s
                    && b.map >= a.map
                    && (b.energy_mwh < a.energy_mwh
                        || b.latency_s < a.latency_s
                        || b.map > a.map)
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::router::store::test_store;
    use crate::util::prop::forall_ok;
    use crate::util::rng::Rng;

    #[test]
    fn accuracy_only_weights_pick_best_map() {
        let s = test_store();
        let r = WeightedRouter::new(Weights {
            energy: 0.0,
            latency: 0.0,
            accuracy: 1.0,
        });
        // group 1 best mAP = big@dev_a
        assert_eq!(r.route(&s, 1), Some(PairKey::new("big", "dev_a")));
    }

    #[test]
    fn energy_only_weights_pick_cheapest() {
        let s = test_store();
        let r = WeightedRouter::new(Weights {
            energy: 1.0,
            latency: 0.0,
            accuracy: 0.0,
        });
        assert_eq!(r.route(&s, 1), Some(PairKey::new("small", "dev_a")));
    }

    #[test]
    fn latency_weight_shifts_choice() {
        let s = test_store();
        let r = WeightedRouter::new(Weights {
            energy: 0.2,
            latency: 5.0,
            accuracy: 0.2,
        });
        // small@dev_a has the lowest latency (0.010)
        assert_eq!(r.route(&s, 0), Some(PairKey::new("small", "dev_a")));
    }

    #[test]
    fn nan_weights_cannot_poison_scoring() {
        // regression: `min_by(partial_cmp().unwrap())` panicked when a
        // non-finite weight made every score NaN; the comparison is now
        // total and ties break by row position, so routing degrades to
        // a deterministic pick instead of crashing the gateway.
        let s = test_store();
        let r = WeightedRouter::new(Weights {
            energy: f64::NAN,
            latency: 0.0,
            accuracy: 0.0,
        });
        let a = r.route(&s, 1);
        assert!(a.is_some());
        assert_eq!(a, r.route(&s, 1));
    }

    #[test]
    fn pareto_front_excludes_dominated() {
        let s = test_store();
        // group 1: small(30,1.0,.01) big@a(60,9,.1) big@b(58,4,.05)
        // none dominates another -> all three on the front
        let front = pareto_front(&s, 1);
        assert_eq!(front.len(), 3);
    }

    #[test]
    fn prop_weighted_choice_is_on_pareto_front() {
        // a scalarized optimum is always non-dominated
        forall_ok(
            61,
            100,
            |r: &mut Rng| {
                let mut rows = Vec::new();
                for p in 0..(2 + r.below(6)) {
                    rows.push(PairProfile {
                        pair: PairKey::new(&format!("m{p}"), "d"),
                        group: 0,
                        map: r.range(0.0, 100.0),
                        latency_s: r.range(0.001, 1.0),
                        energy_mwh: r.range(0.1, 10.0),
                    });
                }
                let w = Weights {
                    energy: r.range(0.05, 1.0),
                    latency: r.range(0.05, 1.0),
                    accuracy: r.range(0.05, 1.0),
                };
                (ProfileStore::new(rows), w)
            },
            |(store, w)| {
                let choice = WeightedRouter::new(*w)
                    .route(store, 0)
                    .ok_or("no route")?;
                let front = pareto_front(store, 0);
                if !front.iter().any(|r| r.pair == choice) {
                    return Err(format!(
                        "choice {choice} not on the pareto front"
                    ));
                }
                Ok(())
            },
        );
    }
}
