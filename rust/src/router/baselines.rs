//! Routing policies: the greedy router plus the paper's six baselines
//! (§4.2). A policy maps (estimated group) → (model, device) pair over a
//! deployed node pool; estimator choice is orthogonal and lives in
//! `estimators`.

use super::greedy::GreedyRouter;
use super::store::{PairId, PairKey, ProfileStore};
use super::view::RoutingView;
use crate::util::rng::Rng;

/// All routing strategies evaluated in the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    /// The paper's proposed Algorithm 1 (§3.2): within the estimated
    /// group, keep pairs whose mAP is within `delta_mAP` of the group
    /// maximum and pick the lowest-energy survivor. Backs the §4.2
    /// "Orc", "ED", "SF", and "OB" router configurations (which differ
    /// only in their estimator).
    Greedy,
    /// §4.2 baseline "RR": round-robin over the deployed pairs,
    /// count-agnostic. The classic fairness baseline.
    RoundRobin,
    /// §4.2 baseline "Rnd": uniform random pair per request,
    /// count-agnostic.
    Random,
    /// §4.2 baseline "LE": always the pair with the lowest mean
    /// profiled energy — the energy lower bound of every panel.
    LowestEnergy,
    /// §4.2 baseline "LI": always the pair with the lowest mean
    /// profiled inference latency.
    LowestInference,
    /// §4.2 baseline "HM": the pair with the highest overall mAP,
    /// group-agnostic — the accuracy-centric static choice.
    HighestMap,
    /// §4.2 baseline "HMG": the highest-mAP pair *within the estimated
    /// group* — the accuracy upper bound the paper normalizes against.
    HighestMapPerGroup,
}

impl PolicyKind {
    pub fn label(&self) -> &'static str {
        match self {
            PolicyKind::Greedy => "greedy",
            PolicyKind::RoundRobin => "RR",
            PolicyKind::Random => "Rnd",
            PolicyKind::LowestEnergy => "LE",
            PolicyKind::LowestInference => "LI",
            PolicyKind::HighestMap => "HM",
            PolicyKind::HighestMapPerGroup => "HMG",
        }
    }
}

/// A stateful policy instance.
///
/// Every strategy derives its choices from the store passed to
/// `route()`, so a restricted store (e.g. with failed nodes removed by
/// the gateway's fallback path) is honoured by all of them. Routing
/// stays O(deployed pairs) per request — nanoseconds next to estimation
/// and inference (see bench_routing).
pub struct Policy {
    kind: PolicyKind,
    greedy: GreedyRouter,
    rr_next: usize,
    rng: Rng,
}

impl Policy {
    pub fn new(
        kind: PolicyKind,
        _store: &ProfileStore,
        delta_map: f64,
        seed: u64,
    ) -> Self {
        Self {
            kind,
            greedy: GreedyRouter::new(delta_map),
            rr_next: 0,
            rng: Rng::new(seed ^ 0x9e37_79b9),
        }
    }

    pub fn kind(&self) -> PolicyKind {
        self.kind
    }

    /// Route one request over a borrowed view — the zero-allocation
    /// hot path. `group` is the estimated object-count group (ignored
    /// by the group-agnostic baselines). Mean-metric baselines hit the
    /// store's precomputed per-pair stats (warm-up overlays recompute
    /// only the aged pairs); tie-breaks compare interned ids, which
    /// equals the legacy pair-key order by construction.
    pub fn route_view(
        &mut self,
        view: &RoutingView<'_>,
        group: usize,
    ) -> Option<PairId> {
        let n = view.live_pairs();
        if n == 0 {
            return None;
        }
        match self.kind {
            PolicyKind::Greedy => self.greedy.route_view(view, group),
            PolicyKind::RoundRobin => {
                let k = self.rr_next % n;
                self.rr_next += 1;
                view.live_ids().nth(k)
            }
            PolicyKind::Random => {
                let k = self.rng.below(n as u64) as usize;
                view.live_ids().nth(k)
            }
            PolicyKind::LowestEnergy => {
                min_live_by(view, |v, id| v.mean_energy_mwh(id))
            }
            PolicyKind::LowestInference => {
                min_live_by(view, |v, id| v.mean_latency_s(id))
            }
            PolicyKind::HighestMap => {
                min_live_by(view, |v, id| -v.overall_map(id))
            }
            PolicyKind::HighestMapPerGroup => view
                .group_iter(group)
                // total order, mAP ties toward the lower pair id —
                // NaN-safe and independent of row order
                .max_by(|(ia, ra, _), (ib, rb, _)| {
                    ra.map
                        .total_cmp(&rb.map)
                        .then_with(|| ib.cmp(ia))
                })
                .map(|(id, _, _)| id),
        }
    }

    /// Route one request directly over a store (plain view).
    pub fn route(
        &mut self,
        store: &ProfileStore,
        group: usize,
    ) -> Option<PairKey> {
        let view = RoutingView::new(store);
        self.route_view(&view, group)
            .map(|id| store.key_of(id).clone())
    }
}

fn min_live_by(
    view: &RoutingView<'_>,
    metric: impl Fn(&RoutingView<'_>, PairId) -> f64,
) -> Option<PairId> {
    // single forward pass: each pair's metric is computed exactly once
    // (Iterator::min_by would recompute the running minimum's metric
    // per comparison — O(pairs × pair-rows) when that pair is
    // warm-up-aged). The comparison is total (NaN cannot panic it)
    // and strict, so equal metrics keep the earliest id — identical to
    // the legacy `metric.total_cmp(..).then(pair.cmp(..))` winner,
    // because ids ascend and id order == pair-key order.
    let mut best: Option<(f64, PairId)> = None;
    for id in view.live_ids() {
        let m = metric(view, id);
        let better = match &best {
            None => true,
            Some((bm, _)) => m.total_cmp(bm) == std::cmp::Ordering::Less,
        };
        if better {
            best = Some((m, id));
        }
    }
    best.map(|(_, id)| id)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::router::store::test_store;

    #[test]
    fn round_robin_cycles_all_pairs() {
        let s = test_store();
        let mut p = Policy::new(PolicyKind::RoundRobin, &s, 5.0, 1);
        let n = s.pairs().len();
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..n {
            seen.insert(p.route(&s, 0).unwrap());
        }
        assert_eq!(seen.len(), n);
        // cycle repeats
        assert_eq!(p.route(&s, 0), Some(s.pairs()[0].clone()));
    }

    #[test]
    fn random_hits_every_pair_eventually() {
        let s = test_store();
        let mut p = Policy::new(PolicyKind::Random, &s, 5.0, 7);
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..200 {
            seen.insert(p.route(&s, 0).unwrap());
        }
        assert_eq!(seen.len(), s.pairs().len());
    }

    #[test]
    fn lowest_energy_is_static_minimum() {
        let s = test_store();
        let mut p = Policy::new(PolicyKind::LowestEnergy, &s, 5.0, 1);
        // small@dev_a has energy 1.0 in both groups
        for g in [0, 1, 0] {
            assert_eq!(p.route(&s, g), Some(PairKey::new("small", "dev_a")));
        }
    }

    #[test]
    fn lowest_inference_picks_fastest() {
        let s = test_store();
        let mut p = Policy::new(PolicyKind::LowestInference, &s, 5.0, 1);
        assert_eq!(p.route(&s, 1), Some(PairKey::new("small", "dev_a")));
    }

    #[test]
    fn highest_map_is_group_agnostic() {
        let s = test_store();
        let mut p = Policy::new(PolicyKind::HighestMap, &s, 5.0, 1);
        // overall mAP: big@dev_a = 56, big@dev_b = 54.5, small = 40
        for g in [0, 1] {
            assert_eq!(p.route(&s, g), Some(PairKey::new("big", "dev_a")));
        }
    }

    #[test]
    fn hmg_switches_with_group() {
        let s = test_store();
        let mut p = Policy::new(PolicyKind::HighestMapPerGroup, &s, 5.0, 1);
        // group 0 best: big@dev_a (52); group 1 best: big@dev_a (60)
        assert_eq!(p.route(&s, 0), Some(PairKey::new("big", "dev_a")));
        assert_eq!(p.route(&s, 1), Some(PairKey::new("big", "dev_a")));
    }

    #[test]
    fn greedy_policy_delegates_to_algorithm1() {
        let s = test_store();
        let mut p = Policy::new(PolicyKind::Greedy, &s, 30.0, 1);
        assert_eq!(p.route(&s, 1), Some(PairKey::new("small", "dev_a")));
    }
}
