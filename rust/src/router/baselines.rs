//! Routing policies: the greedy router plus the paper's six baselines
//! (§4.2). A policy maps (estimated group) → (model, device) pair over a
//! deployed node pool; estimator choice is orthogonal and lives in
//! `estimators`.

use super::greedy::GreedyRouter;
use super::store::{PairKey, ProfileStore};
use crate::util::rng::Rng;

/// All routing strategies evaluated in the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    /// The paper's proposed Algorithm 1 (§3.2): within the estimated
    /// group, keep pairs whose mAP is within `delta_mAP` of the group
    /// maximum and pick the lowest-energy survivor. Backs the §4.2
    /// "Orc", "ED", "SF", and "OB" router configurations (which differ
    /// only in their estimator).
    Greedy,
    /// §4.2 baseline "RR": round-robin over the deployed pairs,
    /// count-agnostic. The classic fairness baseline.
    RoundRobin,
    /// §4.2 baseline "Rnd": uniform random pair per request,
    /// count-agnostic.
    Random,
    /// §4.2 baseline "LE": always the pair with the lowest mean
    /// profiled energy — the energy lower bound of every panel.
    LowestEnergy,
    /// §4.2 baseline "LI": always the pair with the lowest mean
    /// profiled inference latency.
    LowestInference,
    /// §4.2 baseline "HM": the pair with the highest overall mAP,
    /// group-agnostic — the accuracy-centric static choice.
    HighestMap,
    /// §4.2 baseline "HMG": the highest-mAP pair *within the estimated
    /// group* — the accuracy upper bound the paper normalizes against.
    HighestMapPerGroup,
}

impl PolicyKind {
    pub fn label(&self) -> &'static str {
        match self {
            PolicyKind::Greedy => "greedy",
            PolicyKind::RoundRobin => "RR",
            PolicyKind::Random => "Rnd",
            PolicyKind::LowestEnergy => "LE",
            PolicyKind::LowestInference => "LI",
            PolicyKind::HighestMap => "HM",
            PolicyKind::HighestMapPerGroup => "HMG",
        }
    }
}

/// A stateful policy instance.
///
/// Every strategy derives its choices from the store passed to
/// `route()`, so a restricted store (e.g. with failed nodes removed by
/// the gateway's fallback path) is honoured by all of them. Routing
/// stays O(deployed pairs) per request — nanoseconds next to estimation
/// and inference (see bench_routing).
pub struct Policy {
    kind: PolicyKind,
    greedy: GreedyRouter,
    rr_next: usize,
    rng: Rng,
}

impl Policy {
    pub fn new(
        kind: PolicyKind,
        _store: &ProfileStore,
        delta_map: f64,
        seed: u64,
    ) -> Self {
        Self {
            kind,
            greedy: GreedyRouter::new(delta_map),
            rr_next: 0,
            rng: Rng::new(seed ^ 0x9e37_79b9),
        }
    }

    pub fn kind(&self) -> PolicyKind {
        self.kind
    }

    /// Route one request. `group` is the estimated object-count group
    /// (ignored by the group-agnostic baselines).
    pub fn route(&mut self, store: &ProfileStore, group: usize) -> Option<PairKey> {
        let pairs = store.pairs();
        if pairs.is_empty() {
            return None;
        }
        match self.kind {
            PolicyKind::Greedy => self.greedy.route(store, group),
            PolicyKind::RoundRobin => {
                let p = pairs[self.rr_next % pairs.len()].clone();
                self.rr_next += 1;
                Some(p)
            }
            PolicyKind::Random => {
                let i = self.rng.below(pairs.len() as u64) as usize;
                Some(pairs[i].clone())
            }
            PolicyKind::LowestEnergy => min_by_metric(&pairs, |p| {
                mean_metric(store, p, |r| r.energy_mwh)
            }),
            PolicyKind::LowestInference => min_by_metric(&pairs, |p| {
                mean_metric(store, p, |r| r.latency_s)
            }),
            PolicyKind::HighestMap => {
                min_by_metric(&pairs, |p| -store.overall_map(p))
            }
            PolicyKind::HighestMapPerGroup => store
                .group_rows(group)
                .into_iter()
                // total order, mAP ties toward the lower pair key —
                // NaN-safe and independent of row order
                .max_by(|a, b| {
                    a.map
                        .total_cmp(&b.map)
                        .then_with(|| b.pair.cmp(&a.pair))
                })
                .map(|r| r.pair.clone()),
        }
    }
}

fn mean_metric(
    store: &ProfileStore,
    pair: &PairKey,
    f: impl Fn(&super::store::PairProfile) -> f64,
) -> f64 {
    let vals: Vec<f64> = store
        .rows()
        .iter()
        .filter(|r| &r.pair == pair)
        .map(f)
        .collect();
    if vals.is_empty() {
        f64::INFINITY
    } else {
        vals.iter().sum::<f64>() / vals.len() as f64
    }
}

fn min_by_metric(
    pairs: &[PairKey],
    metric: impl Fn(&PairKey) -> f64,
) -> Option<PairKey> {
    // total order with a pair-key tiebreak: NaN cannot panic the
    // comparison, and metric ties resolve deterministically
    pairs
        .iter()
        .min_by(|a, b| {
            metric(a).total_cmp(&metric(b)).then_with(|| a.cmp(b))
        })
        .cloned()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::router::store::test_store;

    #[test]
    fn round_robin_cycles_all_pairs() {
        let s = test_store();
        let mut p = Policy::new(PolicyKind::RoundRobin, &s, 5.0, 1);
        let n = s.pairs().len();
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..n {
            seen.insert(p.route(&s, 0).unwrap());
        }
        assert_eq!(seen.len(), n);
        // cycle repeats
        assert_eq!(p.route(&s, 0), Some(s.pairs()[0].clone()));
    }

    #[test]
    fn random_hits_every_pair_eventually() {
        let s = test_store();
        let mut p = Policy::new(PolicyKind::Random, &s, 5.0, 7);
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..200 {
            seen.insert(p.route(&s, 0).unwrap());
        }
        assert_eq!(seen.len(), s.pairs().len());
    }

    #[test]
    fn lowest_energy_is_static_minimum() {
        let s = test_store();
        let mut p = Policy::new(PolicyKind::LowestEnergy, &s, 5.0, 1);
        // small@dev_a has energy 1.0 in both groups
        for g in [0, 1, 0] {
            assert_eq!(p.route(&s, g), Some(PairKey::new("small", "dev_a")));
        }
    }

    #[test]
    fn lowest_inference_picks_fastest() {
        let s = test_store();
        let mut p = Policy::new(PolicyKind::LowestInference, &s, 5.0, 1);
        assert_eq!(p.route(&s, 1), Some(PairKey::new("small", "dev_a")));
    }

    #[test]
    fn highest_map_is_group_agnostic() {
        let s = test_store();
        let mut p = Policy::new(PolicyKind::HighestMap, &s, 5.0, 1);
        // overall mAP: big@dev_a = 56, big@dev_b = 54.5, small = 40
        for g in [0, 1] {
            assert_eq!(p.route(&s, g), Some(PairKey::new("big", "dev_a")));
        }
    }

    #[test]
    fn hmg_switches_with_group() {
        let s = test_store();
        let mut p = Policy::new(PolicyKind::HighestMapPerGroup, &s, 5.0, 1);
        // group 0 best: big@dev_a (52); group 1 best: big@dev_a (60)
        assert_eq!(p.route(&s, 0), Some(PairKey::new("big", "dev_a")));
        assert_eq!(p.route(&s, 1), Some(PairKey::new("big", "dev_a")));
    }

    #[test]
    fn greedy_policy_delegates_to_algorithm1() {
        let s = test_store();
        let mut p = Policy::new(PolicyKind::Greedy, &s, 30.0, 1);
        assert_eq!(p.route(&s, 1), Some(PairKey::new("small", "dev_a")));
    }
}
