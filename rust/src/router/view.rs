//! Copy-on-write routing views (DESIGN.md §10).
//!
//! The gateway used to deep-clone its whole [`ProfileStore`] on every
//! routed request (and re-clone it for every fallback re-route). A
//! [`RoutingView`] replaces both copies with a borrow plus two tiny
//! overlays:
//!
//! * an **exclusion set** — the fallback walk removes a pair from
//!   consideration by flipping a bit instead of materializing a
//!   restricted store;
//! * a **cost overlay** — a per-pair multiplier applied lazily inside
//!   the policy comparators (the same `value * multiplier` arithmetic
//!   the old `scale_pair` copy performed, so every decision stays
//!   bit-identical). The gateway composes every multiplier source
//!   into it multiplicatively: lifecycle warm-up cost-aging of
//!   recently rejoined nodes, times the telemetry correction factor
//!   of the online adaptation subsystem (`crate::adapt`).
//!
//! In the steady state (no fallback, nobody warming, no published
//! corrections) a view is a pure borrow: zero allocation, zero copies
//! — the degenerate case the zero-copy regression tests pin.

use super::store::{PairId, PairProfile, ProfileStore};

/// A borrowed, optionally-overlaid routing snapshot of one store.
pub struct RoutingView<'s> {
    store: &'s ProfileStore,
    /// Excluded pair flags, indexed by `PairId`; empty until the first
    /// exclusion (the no-fallback hot path never allocates it).
    excluded: Vec<bool>,
    /// Pairs still routable (`n_pairs` minus exclusions).
    live: usize,
    /// `(pair, cost multiplier)` warm-up overlay, ascending by id;
    /// empty unless some node is warming.
    aged: Vec<(PairId, f64)>,
}

impl<'s> RoutingView<'s> {
    pub fn new(store: &'s ProfileStore) -> Self {
        Self {
            store,
            excluded: Vec::new(),
            live: store.n_pairs(),
            aged: Vec::new(),
        }
    }

    pub fn store(&self) -> &'s ProfileStore {
        self.store
    }

    /// Pairs still routable under the exclusion overlay.
    pub fn live_pairs(&self) -> usize {
        self.live
    }

    /// Apply a warm-up cost multiplier to one pair. The overlay is
    /// kept sorted by id regardless of call order (re-aging a pair
    /// replaces its multiplier); the gateway pushes ascending, which
    /// makes the insertion O(1) amortized.
    pub fn age(&mut self, id: PairId, mult: f64) {
        match self.aged.binary_search_by_key(&id, |&(i, _)| i) {
            Ok(k) => self.aged[k].1 = mult,
            Err(k) => self.aged.insert(k, (id, mult)),
        }
    }

    /// Remove one pair from consideration (fallback walk).
    pub fn exclude(&mut self, id: PairId) {
        if self.excluded.is_empty() {
            self.excluded = vec![false; self.store.n_pairs()];
        }
        let e = &mut self.excluded[id.index()];
        if !*e {
            *e = true;
            self.live -= 1;
        }
    }

    pub fn is_excluded(&self, id: PairId) -> bool {
        !self.excluded.is_empty() && self.excluded[id.index()]
    }

    /// Warm-up cost multiplier for one pair (1.0 when not warming).
    pub fn multiplier(&self, id: PairId) -> f64 {
        if self.aged.is_empty() {
            return 1.0;
        }
        match self.aged.binary_search_by_key(&id, |&(i, _)| i) {
            Ok(k) => self.aged[k].1,
            Err(_) => 1.0,
        }
    }

    /// Non-excluded pair ids, ascending (== sorted key order).
    pub fn live_ids(&self) -> impl Iterator<Item = PairId> + '_ {
        self.store.pair_ids().filter(move |&id| !self.is_excluded(id))
    }

    /// One group's non-excluded rows with their ids and effective cost
    /// multipliers, in the store's group order (insertion order within
    /// the group — the legacy iteration order).
    pub fn group_iter(
        &self,
        group: usize,
    ) -> impl Iterator<Item = (PairId, &'s PairProfile, f64)> + '_ {
        let (rows, ids) = self.store.group_rows_ids(group);
        ids.iter().zip(rows).filter_map(move |(&id, r)| {
            if self.is_excluded(id) {
                None
            } else {
                Some((id, r, self.multiplier(id)))
            }
        })
    }

    /// Mean profiled energy of one pair under the warm-up overlay.
    /// Unaged pairs hit the precomputed store stats; aged pairs
    /// recompute the mean over `value * mult` in insertion order —
    /// exactly the sum the old aged store copy produced.
    pub fn mean_energy_mwh(&self, id: PairId) -> f64 {
        let m = self.multiplier(id);
        if m == 1.0 {
            self.store.stats_of(id).mean_energy_mwh
        } else {
            self.scaled_mean(id, m, |r| r.energy_mwh)
        }
    }

    /// Mean profiled inference latency, overlay-aware (see
    /// [`RoutingView::mean_energy_mwh`]).
    pub fn mean_latency_s(&self, id: PairId) -> f64 {
        let m = self.multiplier(id);
        if m == 1.0 {
            self.store.stats_of(id).mean_latency_s
        } else {
            self.scaled_mean(id, m, |r| r.latency_s)
        }
    }

    /// Mean mAP across groups (warm-up aging never touches accuracy).
    pub fn overall_map(&self, id: PairId) -> f64 {
        self.store.stats_of(id).overall_map
    }

    fn scaled_mean(
        &self,
        id: PairId,
        mult: f64,
        f: impl Fn(&PairProfile) -> f64,
    ) -> f64 {
        let idxs = self.store.pair_row_indices(id);
        if idxs.is_empty() {
            return f64::INFINITY;
        }
        let rows = self.store.rows();
        let mut sum = 0.0;
        for &ri in idxs {
            sum += f(&rows[ri as usize]) * mult;
        }
        sum / idxs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::router::store::{test_store, PairKey};

    #[test]
    fn plain_view_borrows_without_copying() {
        let s = test_store();
        let before = ProfileStore::clone_count();
        let v = RoutingView::new(&s);
        assert_eq!(v.live_pairs(), 3);
        assert_eq!(v.group_iter(0).count(), 3);
        let id = s.id_of(&PairKey::new("small", "dev_a")).unwrap();
        assert_eq!(v.mean_energy_mwh(id), 1.0);
        assert_eq!(v.multiplier(id), 1.0);
        assert_eq!(ProfileStore::clone_count(), before);
    }

    #[test]
    fn exclusion_shrinks_live_set_idempotently() {
        let s = test_store();
        let mut v = RoutingView::new(&s);
        let id = s.id_of(&PairKey::new("big", "dev_a")).unwrap();
        v.exclude(id);
        v.exclude(id); // idempotent
        assert_eq!(v.live_pairs(), 2);
        assert!(v.is_excluded(id));
        assert_eq!(v.group_iter(1).count(), 2);
        assert!(v.live_ids().all(|i| i != id));
    }

    #[test]
    fn aging_scales_costs_like_the_old_store_copy() {
        let s = test_store();
        let k = PairKey::new("big", "dev_b");
        let id = s.id_of(&k).unwrap();

        // the legacy path: clone + scale_pair
        let mut aged_copy = s.clone();
        aged_copy.scale_pair(&k, 1.5, 1.5);

        let mut v = RoutingView::new(&s);
        v.age(id, 1.5);
        let aged_id = aged_copy.id_of(&k).unwrap();
        assert_eq!(
            v.mean_energy_mwh(id),
            aged_copy.stats_of(aged_id).mean_energy_mwh
        );
        assert_eq!(
            v.mean_latency_s(id),
            aged_copy.stats_of(aged_id).mean_latency_s
        );
        // per-row effective energy matches the scaled copy bit for bit
        for ((_, r, m), cr) in
            v.group_iter(1).zip(aged_copy.group_rows(1))
        {
            assert_eq!(r.map, cr.map, "aging never touches accuracy");
            assert_eq!(r.energy_mwh * m, cr.energy_mwh);
        }
        // other pairs are untouched
        let other = s.id_of(&PairKey::new("small", "dev_a")).unwrap();
        assert_eq!(v.mean_energy_mwh(other), 1.0);
    }
}
