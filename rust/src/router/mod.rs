//! The paper's routing layer: object-count group rules, the profiling
//! data store, Algorithm 1 (greedy energy-min under an accuracy margin),
//! and the six baseline policies.

pub mod baselines;
pub mod greedy;
pub mod group;
pub mod store;
pub mod view;
pub mod weighted;

pub use baselines::{Policy, PolicyKind};
pub use greedy::GreedyRouter;
pub use group::GroupRules;
pub use store::{PairId, PairKey, PairProfile, PairStats, PairTable, ProfileStore};
pub use view::RoutingView;
pub use weighted::{pareto_front, WeightedRouter, Weights};
