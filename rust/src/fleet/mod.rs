//! Fleet-scale sharded serving (DESIGN.md §8).
//!
//! The paper evaluates one gateway over a six-pair testbed. ECORE's
//! smart-city setting is the opposite shape: many gateways, each
//! fronting a slice of a large heterogeneous device pool. This module
//! scales the open-loop subsystem to that regime:
//!
//! * [`FleetBuilder`] synthesizes an N-node fleet by replicating the
//!   base testbed pairs and perturbing each unit's silicon (throughput)
//!   and power draw through the seeded RNG — no two nodes are exactly
//!   alike, like a real deployment of nominally identical boards.
//! * Nodes are partitioned across K gateway **shards**. Each shard is a
//!   full [`Gateway`]: its own [`ProfileStore`] (rows scaled to its
//!   nodes' perturbations), its own estimator state, its own policy RNG.
//! * A [`DispatchPolicy`] picks the shard for each arriving request
//!   (hash, least-loaded, or sticky-by-source) and defines the
//!   **cross-shard fallback** order: a request that finds its shard
//!   saturated re-routes to the next shard before being shed.
//! * One shared event heap drives all shards on the same virtual clock
//!   as [`crate::workload::openloop`], so whole fleet runs replay
//!   bit-identically from their seeds (the golden-trace tests pin this).

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, VecDeque};

use anyhow::{Context, Result};

use crate::dataset::{Dataset, GtBox, Scene};
use crate::detection::map::{map_coco, ImageEval};
use crate::devices;
use crate::devices::drift::DriftConfig;
use crate::gateway::{Gateway, NoEndpoint, RoutedRequest, RouterSpec};
use crate::metrics::RunMetrics;
use crate::nodes::{EdgeNode, NodePool, NodeResponse};
use crate::router::{PairKey, PairProfile, ProfileStore};
use crate::runtime::Engine;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats::{percentile, percentiles};
use crate::workload::openloop::ArrivalProcess;

/// How the fleet front-end assigns an arriving request to a shard.
///
/// Every policy returns a full visit order, not just a primary shard:
/// position 0 is the dispatch choice and the rest is the cross-shard
/// fallback sequence tried when earlier shards are saturated.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// Stateless hash of the request index — uniform spread, no
    /// affinity, the classic L4 load-balancer baseline.
    Hash,
    /// Fewest requests currently in flight (queued + in service) wins;
    /// ties break toward the lower shard index.
    LeastLoaded,
    /// Hash of the request's *source* id, so all traffic from one
    /// source lands on one shard (cache/OB-estimator affinity).
    Sticky,
}

impl DispatchPolicy {
    /// Parse a config/CLI name: `hash`, `least`, or `sticky`.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "hash" => Some(Self::Hash),
            "least" | "least-loaded" | "least_loaded" => {
                Some(Self::LeastLoaded)
            }
            "sticky" | "sticky-by-source" => Some(Self::Sticky),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Self::Hash => "hash",
            Self::LeastLoaded => "least",
            Self::Sticky => "sticky",
        }
    }

    /// Shard visit order for request `idx` given per-shard in-flight
    /// counts: primary shard first, then the cross-shard fallback
    /// sequence. Deterministic in its inputs.
    pub fn order(
        &self,
        idx: usize,
        n_sources: usize,
        in_flight: &[usize],
    ) -> Vec<usize> {
        let k = in_flight.len();
        if k == 0 {
            return Vec::new();
        }
        match self {
            DispatchPolicy::Hash => {
                rotation(mix64(idx as u64 ^ 0x00D1_57A7) as usize % k, k)
            }
            DispatchPolicy::Sticky => {
                let source = idx % n_sources.max(1);
                rotation(mix64(source as u64 ^ 0x0057_1C4B) as usize % k, k)
            }
            DispatchPolicy::LeastLoaded => {
                let mut order: Vec<usize> = (0..k).collect();
                order.sort_by_key(|&s| (in_flight[s], s));
                order
            }
        }
    }
}

fn rotation(start: usize, k: usize) -> Vec<usize> {
    (0..k).map(|i| (start + i) % k).collect()
}

/// SplitMix64 finalizer — stateless integer mixing for shard hashing.
fn mix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Shape of one synthesized fleet.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Total synthesized nodes, spread round-robin over the base pairs.
    pub n_nodes: usize,
    /// Gateway shards the nodes are partitioned across.
    pub n_shards: usize,
    /// ± fractional perturbation of each unit's throughput and dynamic
    /// power (silicon binning / cooling variation); 0 = identical units.
    pub perturb: f64,
    /// Bounded per-node FIFO capacity (in-service slot included).
    pub queue_capacity: usize,
    pub dispatch: DispatchPolicy,
    /// Distinct request sources (sticky-dispatch granularity).
    pub n_sources: usize,
    /// Seed for synthesis (node perturbations, jitter, shard policies).
    pub seed: u64,
    /// Optional per-node runtime drift (paper Future Work #1).
    pub drift: Option<DriftConfig>,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            n_nodes: 24,
            n_shards: 4,
            perturb: 0.15,
            queue_capacity: 8,
            dispatch: DispatchPolicy::LeastLoaded,
            n_sources: 16,
            seed: 7,
            drift: None,
        }
    }
}

/// Synthesizes sharded fleets from a base profiling store (normally the
/// deployed Table-1 testbed store).
pub struct FleetBuilder<'e> {
    engine: &'e Engine,
    base: ProfileStore,
}

impl<'e> FleetBuilder<'e> {
    pub fn new(engine: &'e Engine, base: ProfileStore) -> Self {
        Self { engine, base }
    }

    /// Build an N-node / K-shard fleet wired for one router config.
    ///
    /// Node `i` replicates base pair `i % pairs` with a unique identity
    /// (`model@device#i`), a device perturbed by the seeded RNG, and
    /// profile rows rescaled to first order (latency ∝ 1/speed, energy
    /// ∝ power/speed, mAP unchanged — the framework and decode
    /// threshold are those of the base device). Shards get the nodes
    /// round-robin, so every shard sees the same mix of base pairs.
    pub fn build(
        &self,
        spec: RouterSpec,
        delta_map: f64,
        cfg: &FleetConfig,
    ) -> Result<Fleet<'e>> {
        anyhow::ensure!(cfg.n_shards >= 1, "fleet needs at least one shard");
        anyhow::ensure!(
            cfg.n_nodes >= cfg.n_shards,
            "fewer nodes ({}) than shards ({})",
            cfg.n_nodes,
            cfg.n_shards
        );
        anyhow::ensure!(
            (0.0..0.95).contains(&cfg.perturb),
            "perturb {} outside [0, 0.95)",
            cfg.perturb
        );
        let base_pairs = self.base.pairs();
        anyhow::ensure!(!base_pairs.is_empty(), "base profile store is empty");
        let base_fleet = devices::fleet();

        let mut shard_nodes: Vec<Vec<EdgeNode>> =
            (0..cfg.n_shards).map(|_| Vec::new()).collect();
        let mut shard_rows: Vec<Vec<PairProfile>> =
            (0..cfg.n_shards).map(|_| Vec::new()).collect();
        let rng = Rng::new(cfg.seed ^ 0xF1EE_7B0A);
        for i in 0..cfg.n_nodes {
            let bp = &base_pairs[i % base_pairs.len()];
            let base_dev = devices::find(&base_fleet, &bp.device)
                .with_context(|| {
                    format!("unknown base device '{}'", bp.device)
                })?;
            let mut r = rng.derive(i as u64);
            let speed = 1.0 + cfg.perturb * (2.0 * r.f64() - 1.0);
            let power = 1.0 + cfg.perturb * (2.0 * r.f64() - 1.0);
            let dev = base_dev.scaled(speed, power);
            let pair =
                PairKey::new(&bp.model, &format!("{}#{:04}", bp.device, i));
            let mut node = EdgeNode::new(
                self.engine,
                pair.clone(),
                dev,
                cfg.seed.wrapping_add(i as u64),
            )?;
            if let Some(dc) = &cfg.drift {
                node.enable_drift(dc.clone(), cfg.seed ^ mix64(i as u64));
            }
            let shard = i % cfg.n_shards;
            for row in self.base.rows().iter().filter(|row| &row.pair == bp)
            {
                shard_rows[shard].push(PairProfile {
                    pair: pair.clone(),
                    group: row.group,
                    map: row.map,
                    latency_s: row.latency_s / speed,
                    energy_mwh: row.energy_mwh * power / speed,
                });
            }
            shard_nodes[shard].push(node);
        }

        let mut models: Vec<&str> =
            base_pairs.iter().map(|p| p.model.as_str()).collect();
        models.sort();
        models.dedup();
        self.engine.preload(&models)?;

        let mut shards = Vec::with_capacity(cfg.n_shards);
        for (s, (nodes, rows)) in
            shard_nodes.into_iter().zip(shard_rows).enumerate()
        {
            let mut pool = NodePool::from_nodes(nodes);
            pool.set_queue_capacity(cfg.queue_capacity);
            shards.push(Gateway::new(
                self.engine,
                spec,
                ProfileStore::new(rows),
                pool,
                delta_map,
                cfg.seed ^ mix64(0x0005_1A2D + s as u64),
            ));
        }
        Ok(Fleet {
            shards,
            dispatch: cfg.dispatch,
            n_sources: cfg.n_sources.max(1),
            n_nodes: cfg.n_nodes,
        })
    }
}

/// A built fleet: K shard gateways plus the dispatch front-end.
pub struct Fleet<'e> {
    shards: Vec<Gateway<'e>>,
    dispatch: DispatchPolicy,
    n_sources: usize,
    n_nodes: usize,
}

impl<'e> Fleet<'e> {
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn n_nodes(&self) -> usize {
        self.n_nodes
    }

    pub fn dispatch(&self) -> DispatchPolicy {
        self.dispatch
    }

    pub fn shards(&self) -> &[Gateway<'e>] {
        &self.shards
    }

    pub fn shards_mut(&mut self) -> &mut [Gateway<'e>] {
        &mut self.shards
    }
}

/// Outcome of one fleet run.
#[derive(Clone, Debug)]
pub struct FleetReport {
    /// Per-shard request accounting, index-aligned with the shards.
    pub per_shard: Vec<RunMetrics>,
    /// Requests offered by the arrival process (served + dropped).
    pub offered: usize,
    /// Requests shed because every shard was saturated.
    pub dropped: usize,
    /// Within-shard fallback re-routes (down or queue-full nodes).
    pub node_fallbacks: usize,
    /// Requests that left their dispatch shard for another because the
    /// primary was saturated.
    pub cross_shard_fallbacks: usize,
    /// Virtual time at which the last response left the system (s).
    pub makespan_s: f64,
    /// Peak requests simultaneously in the system, fleet-wide.
    pub peak_in_flight: usize,
}

impl FleetReport {
    /// Served requests across all shards.
    pub fn requests(&self) -> usize {
        self.per_shard.iter().map(|m| m.requests).sum()
    }

    /// Served throughput over the run's virtual wall-clock (req/s).
    pub fn goodput_rps(&self) -> f64 {
        if self.makespan_s > 0.0 {
            self.requests() as f64 / self.makespan_s
        } else {
            0.0
        }
    }

    pub fn total_energy_mwh(&self) -> f64 {
        self.per_shard.iter().map(|m| m.total_energy_mwh()).sum()
    }

    pub fn energy_per_request_mwh(&self) -> f64 {
        let n = self.requests();
        if n > 0 {
            self.total_energy_mwh() / n as f64
        } else {
            0.0
        }
    }

    /// All shards' end-to-end latency samples merged (unsorted).
    fn merged_samples(&self) -> Vec<f64> {
        self.per_shard
            .iter()
            .flat_map(|m| m.latency_samples.iter().copied())
            .collect()
    }

    /// End-to-end latency percentile over all shards' samples merged.
    /// For several percentiles at once, prefer
    /// [`FleetReport::latency_percentiles`] (one merge + sort).
    pub fn latency_percentile(&self, p: f64) -> f64 {
        percentile(&self.merged_samples(), p)
    }

    /// Several merged-sample percentiles from a single merge + sort.
    pub fn latency_percentiles(&self, ps: &[f64]) -> Vec<f64> {
        percentiles(&self.merged_samples(), ps)
    }

    /// Mean per-request queueing delay across the fleet (s).
    pub fn mean_queue_delay_s(&self) -> f64 {
        let n = self.requests();
        if n > 0 {
            self.per_shard.iter().map(|m| m.queue_delay_s).sum::<f64>()
                / n as f64
        } else {
            0.0
        }
    }

    /// COCO mAP over every image served by any shard (0–100).
    pub fn map(&self) -> f64 {
        let images: Vec<ImageEval> = self
            .per_shard
            .iter()
            .flat_map(|m| m.images.iter().cloned())
            .collect();
        map_coco(&images, crate::dataset::NUM_CLASSES).map
    }

    /// Max/mean served requests per shard: 1.0 is perfectly balanced,
    /// K means one shard took everything; 0.0 when nothing was served.
    pub fn shard_imbalance(&self) -> f64 {
        let total = self.requests();
        if total == 0 || self.per_shard.is_empty() {
            return 0.0;
        }
        let mean = total as f64 / self.per_shard.len() as f64;
        let max =
            self.per_shard.iter().map(|m| m.requests).max().unwrap_or(0);
        max as f64 / mean
    }

    /// Stable JSON report (field order fixed by the Json substrate's
    /// BTreeMap) — the golden-trace determinism tests compare this dump
    /// byte for byte.
    pub fn to_json(&self) -> Json {
        let pcts = self.latency_percentiles(&[50.0, 95.0, 99.0]);
        Json::obj(vec![
            ("offered", Json::num(self.offered as f64)),
            ("requests", Json::num(self.requests() as f64)),
            ("dropped", Json::num(self.dropped as f64)),
            ("node_fallbacks", Json::num(self.node_fallbacks as f64)),
            (
                "cross_shard_fallbacks",
                Json::num(self.cross_shard_fallbacks as f64),
            ),
            ("makespan_s", Json::num(self.makespan_s)),
            ("peak_in_flight", Json::num(self.peak_in_flight as f64)),
            ("goodput_rps", Json::num(self.goodput_rps())),
            ("latency_p50_s", Json::num(pcts[0])),
            ("latency_p95_s", Json::num(pcts[1])),
            ("latency_p99_s", Json::num(pcts[2])),
            (
                "mean_queue_delay_s",
                Json::num(self.mean_queue_delay_s()),
            ),
            ("energy_mwh", Json::num(self.total_energy_mwh())),
            (
                "energy_per_request_mwh",
                Json::num(self.energy_per_request_mwh()),
            ),
            ("map", Json::num(self.map())),
            ("shard_imbalance", Json::num(self.shard_imbalance())),
            (
                "shards",
                Json::Arr(
                    self.per_shard.iter().map(|m| m.to_json()).collect(),
                ),
            ),
        ])
    }
}

/// One event on the shared virtual clock; ordered by (time, sequence)
/// so ties resolve in insertion order — a shard-aware copy of the
/// `workload::openloop` event machinery. A fix to the ordering,
/// queue-delay formula, or completion scheduling must land in both
/// copies; the golden-trace tests pin each side's behavior.
struct Event {
    t: f64,
    seq: u64,
    kind: EventKind,
}

enum EventKind {
    /// Request `idx` arrives at the fleet front-end.
    Arrival(usize),
    /// The in-service request on `pair` (owned by `shard`) completes.
    Completion { shard: usize, pair: PairKey },
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq && self.t.total_cmp(&other.t).is_eq()
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t.total_cmp(&other.t).then(self.seq.cmp(&other.seq))
    }
}

/// A request admitted to a node's FIFO, waiting for service.
struct Pending {
    routed: RoutedRequest,
    idx: usize,
    arrival_s: f64,
}

/// The request a node is currently serving.
struct InService {
    routed: RoutedRequest,
    idx: usize,
    arrival_s: f64,
    start_s: f64,
    resp: NodeResponse,
}

/// Per-node serving state: one in-service slot + FIFO backlog.
#[derive(Default)]
struct NodeQueue {
    serving: Option<InService>,
    backlog: VecDeque<Pending>,
}

/// Drive a fleet over pre-rendered frames under open-loop arrivals.
///
/// Per arrival: the dispatch policy yields a shard visit order; the
/// first shard whose gateway admits the request (it has a healthy node
/// with a free queue slot for the estimated group) wins. Visits beyond
/// the first count as cross-shard fallbacks; exhausting every shard
/// sheds the request. Completions release the slot, record metrics on
/// the serving shard, and start that node's next queued request.
pub fn run_frames(
    fleet: &mut Fleet<'_>,
    frames: &[Scene],
    pseudo_gt: &[Vec<GtBox>],
    arrivals: &ArrivalProcess,
    seed: u64,
) -> Result<FleetReport> {
    anyhow::ensure!(frames.len() == pseudo_gt.len());
    let k = fleet.shards.len();
    let fallbacks_before: Vec<usize> =
        fleet.shards.iter().map(|g| g.fallbacks).collect();
    let mut metrics: Vec<RunMetrics> = (0..k)
        .map(|s| {
            RunMetrics::new(&format!("{}-s{s}", fleet.shards[s].spec.name))
        })
        .collect();
    let mut queues: Vec<BTreeMap<PairKey, NodeQueue>> =
        (0..k).map(|_| BTreeMap::new()).collect();
    let mut heap: BinaryHeap<Reverse<Event>> = BinaryHeap::new();
    let mut seq = 0u64;
    for (idx, t) in
        arrivals.times(frames.len(), seed).into_iter().enumerate()
    {
        heap.push(Reverse(Event {
            t,
            seq,
            kind: EventKind::Arrival(idx),
        }));
        seq += 1;
    }

    let mut dropped = 0usize;
    let mut cross_shard_fallbacks = 0usize;
    let mut in_flight = vec![0usize; k];
    let mut total_in_flight = 0usize;
    let mut peak_in_flight = 0usize;
    let mut makespan_s = 0.0f64;

    while let Some(Reverse(ev)) = heap.pop() {
        match ev.kind {
            EventKind::Arrival(idx) => {
                let scene = &frames[idx];
                let true_count = pseudo_gt[idx].len();
                let order =
                    fleet.dispatch.order(idx, fleet.n_sources, &in_flight);
                let mut admitted: Option<(usize, RoutedRequest)> = None;
                for (attempt, &s) in order.iter().enumerate() {
                    match fleet.shards[s].route(&scene.image, true_count) {
                        Ok(routed) => {
                            cross_shard_fallbacks += attempt;
                            admitted = Some((s, routed));
                            break;
                        }
                        Err(e) if e.is::<NoEndpoint>() => continue,
                        Err(e) => return Err(e),
                    }
                }
                let Some((s, routed)) = admitted else {
                    dropped += 1;
                    continue;
                };
                let ok = fleet.shards[s].pool_mut().acquire(&routed.pair);
                debug_assert!(
                    ok,
                    "route() returned a pair without a free slot"
                );
                in_flight[s] += 1;
                total_in_flight += 1;
                peak_in_flight = peak_in_flight.max(total_in_flight);
                let pair = routed.pair.clone();
                queues[s].entry(pair.clone()).or_default().backlog.push_back(
                    Pending {
                        routed,
                        idx,
                        arrival_s: ev.t,
                    },
                );
                start_next(
                    &mut fleet.shards[s],
                    s,
                    frames,
                    &mut queues[s],
                    &mut heap,
                    &mut seq,
                    &pair,
                    ev.t,
                )?;
            }
            EventKind::Completion { shard: s, pair } => {
                let done = queues[s]
                    .get_mut(&pair)
                    .expect("completion for unknown queue")
                    .serving
                    .take()
                    .expect("completion with no in-service request");
                fleet.shards[s].pool_mut().release(&pair);
                in_flight[s] -= 1;
                total_in_flight -= 1;
                makespan_s = makespan_s.max(ev.t);
                let queue_delay_s = (done.start_s
                    - (done.arrival_s + done.routed.cost.latency_s))
                    .max(0.0);
                fleet.shards[s].finish(
                    &done.routed,
                    done.resp,
                    &pseudo_gt[done.idx],
                    queue_delay_s,
                    &mut metrics[s],
                );
                start_next(
                    &mut fleet.shards[s],
                    s,
                    frames,
                    &mut queues[s],
                    &mut heap,
                    &mut seq,
                    &pair,
                    ev.t,
                )?;
            }
        }
    }

    let node_fallbacks = fleet
        .shards
        .iter()
        .zip(&fallbacks_before)
        .map(|(g, &before)| g.fallbacks - before)
        .sum();
    Ok(FleetReport {
        per_shard: metrics,
        offered: frames.len(),
        dropped,
        node_fallbacks,
        cross_shard_fallbacks,
        makespan_s,
        peak_in_flight,
    })
}

/// If `pair` (on shard `shard`) is idle and has backlog, begin serving
/// the head request at `now_s` and schedule its completion.
#[allow(clippy::too_many_arguments)]
fn start_next(
    gw: &mut Gateway<'_>,
    shard: usize,
    frames: &[Scene],
    queues: &mut BTreeMap<PairKey, NodeQueue>,
    heap: &mut BinaryHeap<Reverse<Event>>,
    seq: &mut u64,
    pair: &PairKey,
    now_s: f64,
) -> Result<()> {
    let q = queues.get_mut(pair).expect("start_next on unknown queue");
    if q.serving.is_some() {
        return Ok(());
    }
    let Some(p) = q.backlog.pop_front() else {
        return Ok(());
    };
    let start_s = now_s.max(p.arrival_s + p.routed.cost.latency_s);
    let resp = gw.serve(pair, &frames[p.idx].image, start_s)?;
    let done_s = start_s + resp.latency_s + devices::NETWORK_S;
    heap.push(Reverse(Event {
        t: done_s,
        seq: *seq,
        kind: EventKind::Completion {
            shard,
            pair: pair.clone(),
        },
    }));
    *seq += 1;
    // re-borrow: gw.serve() above needed &mut Gateway exclusively
    queues.get_mut(pair).expect("queue vanished").serving =
        Some(InService {
            routed: p.routed,
            idx: p.idx,
            arrival_s: p.arrival_s,
            start_s,
            resp,
        });
    Ok(())
}

/// Render a dataset up front and drive it through the fleet.
pub fn run_dataset(
    fleet: &mut Fleet<'_>,
    dataset: &Dataset,
    arrivals: &ArrivalProcess,
    seed: u64,
) -> Result<FleetReport> {
    let frames: Vec<Scene> = dataset.iter_scenes().collect();
    let gts: Vec<Vec<GtBox>> =
        frames.iter().map(|s| s.gt.clone()).collect();
    run_frames(fleet, &frames, &gts, arrivals, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::coco;
    use crate::gateway::router_by_name;

    fn engine() -> Engine {
        Engine::new(&crate::default_artifacts_dir()).unwrap()
    }

    fn base_store() -> ProfileStore {
        let mut rows = Vec::new();
        for g in 0..5 {
            rows.push(PairProfile {
                pair: PairKey::new("ssd_v1", "jetson_orin_nano"),
                group: g,
                map: 50.0,
                latency_s: 0.005,
                energy_mwh: 0.002,
            });
            rows.push(PairProfile {
                pair: PairKey::new("yolov8n", "pi5"),
                group: g,
                map: if g >= 2 { 75.0 } else { 51.0 },
                latency_s: 0.05,
                energy_mwh: 0.05,
            });
        }
        ProfileStore::new(rows)
    }

    fn build_fleet<'e>(
        e: &'e Engine,
        router: &str,
        cfg: &FleetConfig,
    ) -> Fleet<'e> {
        FleetBuilder::new(e, base_store())
            .build(router_by_name(router).unwrap(), 5.0, cfg)
            .unwrap()
    }

    #[test]
    fn builder_scales_to_200_nodes_over_8_shards() {
        let e = engine();
        let cfg = FleetConfig {
            n_nodes: 200,
            n_shards: 8,
            ..Default::default()
        };
        let fleet = build_fleet(&e, "LE", &cfg);
        assert_eq!(fleet.n_shards(), 8);
        assert_eq!(fleet.n_nodes(), 200);
        let mut all_pairs: Vec<PairKey> = Vec::new();
        for gw in fleet.shards() {
            let pairs = gw.store().pairs();
            assert_eq!(pairs.len(), 25, "round-robin partition");
            // every profiled node exists (and is healthy) in the pool
            for p in &pairs {
                assert!(gw.pool().is_healthy(p), "{p} missing from pool");
            }
            // 2 base pairs x 5 groups per node
            assert_eq!(gw.store().rows().len(), 25 * 5);
            all_pairs.extend(pairs);
        }
        let n = all_pairs.len();
        all_pairs.sort();
        all_pairs.dedup();
        assert_eq!(all_pairs.len(), n, "node identities must be unique");
        assert_eq!(n, 200);
    }

    #[test]
    fn builder_rejects_degenerate_shapes() {
        let e = engine();
        let b = FleetBuilder::new(&e, base_store());
        let spec = router_by_name("LE").unwrap();
        for cfg in [
            FleetConfig { n_shards: 0, ..Default::default() },
            FleetConfig { n_nodes: 2, n_shards: 4, ..Default::default() },
            FleetConfig { perturb: 1.5, ..Default::default() },
        ] {
            assert!(b.build(spec, 5.0, &cfg).is_err(), "{cfg:?}");
        }
    }

    #[test]
    fn low_rate_fleet_serves_everything_without_fallbacks() {
        let e = engine();
        let ds = coco::build(10, 5);
        let cfg = FleetConfig {
            n_nodes: 8,
            n_shards: 2,
            queue_capacity: 4,
            ..Default::default()
        };
        let mut fl = build_fleet(&e, "LE", &cfg);
        let report = run_dataset(
            &mut fl,
            &ds,
            &ArrivalProcess::Uniform { gap_s: 5.0 },
            3,
        )
        .unwrap();
        assert_eq!(report.offered, 10);
        assert_eq!(report.requests(), 10);
        assert_eq!(report.dropped, 0);
        assert_eq!(report.cross_shard_fallbacks, 0);
        assert_eq!(report.peak_in_flight, 1);
        assert_eq!(report.mean_queue_delay_s(), 0.0);
        assert!(report.makespan_s > 0.0);
        assert!(report.total_energy_mwh() > 0.0);
    }

    #[test]
    fn saturated_fleet_falls_back_across_shards_then_sheds() {
        let e = engine();
        let ds = coco::build(12, 13);
        // sticky dispatch + one source: every arrival targets the same
        // primary shard, so saturation must spill across shards before
        // anything is shed. Capacity 1 on 2x2 nodes = 4 total slots.
        let cfg = FleetConfig {
            n_nodes: 4,
            n_shards: 2,
            queue_capacity: 1,
            dispatch: DispatchPolicy::Sticky,
            n_sources: 1,
            ..Default::default()
        };
        let mut fl = build_fleet(&e, "LE", &cfg);
        let report = run_dataset(
            &mut fl,
            &ds,
            &ArrivalProcess::Uniform { gap_s: 1e-6 },
            2,
        )
        .unwrap();
        assert!(
            report.cross_shard_fallbacks > 0,
            "expected cross-shard spill"
        );
        assert!(report.dropped > 0, "expected load shedding");
        assert_eq!(report.requests() + report.dropped, report.offered);
        // both shards ended up serving traffic
        assert!(report.per_shard.iter().all(|m| m.requests > 0));
        // every acquired slot was released: the driver's O(1) counters
        // agree with the pools' ground-truth occupancy scan
        assert_eq!(
            fl.shards()
                .iter()
                .map(|g| g.pool().total_in_flight())
                .sum::<usize>(),
            0
        );
    }

    #[test]
    fn fleet_replays_bit_identically_from_seeds() {
        let e = engine();
        let ds = coco::build(16, 99);
        let run = |e: &Engine| {
            let cfg = FleetConfig {
                n_nodes: 12,
                n_shards: 3,
                queue_capacity: 2,
                ..Default::default()
            };
            let mut fl = build_fleet(e, "ED", &cfg);
            run_dataset(
                &mut fl,
                &ds,
                &ArrivalProcess::Poisson { rate_rps: 300.0 },
                17,
            )
            .unwrap()
            .to_json()
            .dump()
        };
        assert_eq!(run(&e), run(&e));
    }

    #[test]
    fn dispatch_orders_are_deterministic_and_complete() {
        use std::collections::BTreeSet;
        let in_flight = [3usize, 0, 5, 1];
        for d in [
            DispatchPolicy::Hash,
            DispatchPolicy::LeastLoaded,
            DispatchPolicy::Sticky,
        ] {
            let o = d.order(9, 4, &in_flight);
            let mut sorted = o.clone();
            sorted.sort();
            assert_eq!(sorted, vec![0, 1, 2, 3], "{d:?} must cover");
            assert_eq!(o, d.order(9, 4, &in_flight), "{d:?} deterministic");
        }
        // least-loaded visits shards in load order
        assert_eq!(
            DispatchPolicy::LeastLoaded.order(0, 4, &in_flight),
            vec![1, 3, 0, 2]
        );
        // sticky: requests from the same source share an order
        assert_eq!(
            DispatchPolicy::Sticky.order(2, 4, &in_flight),
            DispatchPolicy::Sticky.order(6, 4, &in_flight)
        );
        // hash spreads primaries across every shard eventually
        let mut seen = BTreeSet::new();
        for idx in 0..64 {
            seen.insert(DispatchPolicy::Hash.order(idx, 4, &in_flight)[0]);
        }
        assert_eq!(seen.len(), 4);
        // parsing round-trips the labels
        for d in [
            DispatchPolicy::Hash,
            DispatchPolicy::LeastLoaded,
            DispatchPolicy::Sticky,
        ] {
            assert_eq!(DispatchPolicy::parse(d.label()), Some(d));
        }
        assert_eq!(DispatchPolicy::parse("wat"), None);
    }

    #[test]
    fn report_imbalance_and_json_shape() {
        let mut m0 = RunMetrics::new("s0");
        m0.requests = 6;
        let mut m1 = RunMetrics::new("s1");
        m1.requests = 2;
        let report = FleetReport {
            per_shard: vec![m0, m1],
            offered: 9,
            dropped: 1,
            node_fallbacks: 0,
            cross_shard_fallbacks: 3,
            makespan_s: 4.0,
            peak_in_flight: 5,
        };
        assert_eq!(report.requests(), 8);
        assert!((report.shard_imbalance() - 1.5).abs() < 1e-12);
        assert!((report.goodput_rps() - 2.0).abs() < 1e-12);
        let j = report.to_json();
        assert_eq!(j.req("requests").unwrap().as_usize(), Some(8));
        assert_eq!(j.req("dropped").unwrap().as_usize(), Some(1));
        assert_eq!(
            j.req("cross_shard_fallbacks").unwrap().as_usize(),
            Some(3)
        );
        assert_eq!(j.req("shards").unwrap().as_arr().unwrap().len(), 2);
    }
}
