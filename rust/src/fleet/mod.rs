//! Fleet-scale sharded serving (DESIGN.md §8).
//!
//! The paper evaluates one gateway over a six-pair testbed. ECORE's
//! smart-city setting is the opposite shape: many gateways, each
//! fronting a slice of a large heterogeneous device pool. This module
//! scales the open-loop subsystem to that regime:
//!
//! * [`FleetBuilder`] synthesizes an N-node fleet by replicating the
//!   base testbed pairs and perturbing each unit's silicon (throughput)
//!   and power draw through the seeded RNG — no two nodes are exactly
//!   alike, like a real deployment of nominally identical boards.
//! * Nodes are partitioned across K gateway **shards**. Each shard is a
//!   full [`Gateway`]: its own [`ProfileStore`] (rows scaled to its
//!   nodes' perturbations), its own estimator state, its own policy RNG.
//! * A [`DispatchPolicy`] picks the shard for each arriving request
//!   (hash, least-loaded, or sticky-by-source) and defines the
//!   **cross-shard fallback** order: a request that finds its shard
//!   saturated re-routes to the next shard before being shed.
//! * One shared event heap drives all shards on the same virtual clock
//!   as [`crate::workload::openloop`], so whole fleet runs replay
//!   bit-identically from their seeds (the golden-trace tests pin this).

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, VecDeque};

use anyhow::{Context, Result};

use crate::adapt::{AdaptConfig, AdaptReport};
use crate::dataset::{Dataset, GtBox, Scene};
use crate::detection::map::{map_coco, ImageEval};
use crate::devices;
use crate::devices::drift::DriftConfig;
use crate::estimators::GatewayCost;
use crate::gateway::{
    amortize, Gateway, NoEndpoint, RoutedRequest, RouterSpec,
};
use crate::lifecycle::campaign::{
    CampaignConfig, CampaignPlan, CampaignReport, PlanEvent,
};
use crate::lifecycle::{
    self, ChurnConfig, ChurnReport, ChurnState, LossOutcome,
    ResiliencePolicy,
};
use crate::metrics::{RunMetrics, SloMetrics};
use crate::nodes::{EdgeNode, NodeDown, NodePool, NodeResponse};
use crate::obs::{ObsConfig, ObsShard, SPINE_SHARD};
use crate::router::{PairId, PairKey, PairProfile, ProfileStore};
use crate::runtime::Engine;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats::{percentile, percentiles};
use crate::workload::openloop::ArrivalProcess;
use crate::workload::slo::{SloConfig, SloTag};

pub mod parallel;

/// How the fleet front-end assigns an arriving request to a shard.
///
/// Every policy returns a full visit order, not just a primary shard:
/// position 0 is the dispatch choice and the rest is the cross-shard
/// fallback sequence tried when earlier shards are saturated.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// Stateless hash of the request index — uniform spread, no
    /// affinity, the classic L4 load-balancer baseline.
    Hash,
    /// Fewest requests currently in flight (queued + in service) wins;
    /// ties break toward the lower shard index.
    LeastLoaded,
    /// Hash of the request's *source* id, so all traffic from one
    /// source lands on one shard (cache/OB-estimator affinity).
    Sticky,
}

impl DispatchPolicy {
    /// Parse a config/CLI name: `hash`, `least`, or `sticky`.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "hash" => Some(Self::Hash),
            "least" | "least-loaded" | "least_loaded" => {
                Some(Self::LeastLoaded)
            }
            "sticky" | "sticky-by-source" => Some(Self::Sticky),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Self::Hash => "hash",
            Self::LeastLoaded => "least",
            Self::Sticky => "sticky",
        }
    }

    /// Shard visit order for request `idx` given per-shard in-flight
    /// counts: primary shard first, then the cross-shard fallback
    /// sequence. Deterministic in its inputs.
    pub fn order(
        &self,
        idx: usize,
        n_sources: usize,
        in_flight: &[usize],
    ) -> Vec<usize> {
        let k = in_flight.len();
        if k == 0 {
            return Vec::new();
        }
        match self {
            DispatchPolicy::Hash => {
                rotation(mix64(idx as u64 ^ 0x00D1_57A7) as usize % k, k)
            }
            DispatchPolicy::Sticky => {
                let source = idx % n_sources.max(1);
                rotation(mix64(source as u64 ^ 0x0057_1C4B) as usize % k, k)
            }
            DispatchPolicy::LeastLoaded => {
                let mut order: Vec<usize> = (0..k).collect();
                order.sort_by_key(|&s| (in_flight[s], s));
                order
            }
        }
    }
}

fn rotation(start: usize, k: usize) -> Vec<usize> {
    (0..k).map(|i| (start + i) % k).collect()
}

/// SplitMix64 finalizer — stateless integer mixing for shard hashing.
fn mix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Shape of one synthesized fleet.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Total synthesized nodes, spread round-robin over the base pairs.
    pub n_nodes: usize,
    /// Gateway shards the nodes are partitioned across.
    pub n_shards: usize,
    /// ± fractional perturbation of each unit's throughput and dynamic
    /// power (silicon binning / cooling variation); 0 = identical units.
    pub perturb: f64,
    /// Bounded per-node FIFO capacity (in-service slot included).
    pub queue_capacity: usize,
    pub dispatch: DispatchPolicy,
    /// Distinct request sources (sticky-dispatch granularity).
    pub n_sources: usize,
    /// Seed for synthesis (node perturbations, jitter, shard policies).
    pub seed: u64,
    /// Optional per-node runtime drift (paper Future Work #1).
    pub drift: Option<DriftConfig>,
    /// Optional node churn (DESIGN.md §9): ground-truth crash/rejoin
    /// events on the shared heap, per-shard probe-driven membership,
    /// and a resilience policy for requests lost to crashes.
    pub churn: Option<ChurnConfig>,
    /// SLO + batching (DESIGN.md §11): deadline classes with admission
    /// control, EDF queue ordering, and per-(shard, pair) batch
    /// formation. `None` keeps the event stream bit-identical.
    pub slo: Option<SloConfig>,
    /// Online adaptation (DESIGN.md §12): per-shard telemetry-driven
    /// profile corrections plus energy-proportional autoscaling.
    /// `None` keeps the event stream bit-identical.
    pub adapt: Option<AdaptConfig>,
    /// Observability (DESIGN.md §14): passive per-shard collectors
    /// (plus one spine collector for run-level events) fold stage
    /// transitions into span records and virtual-time series, exported
    /// at end of run. Schedules zero events either way; `None`
    /// collects nothing and keeps reports/traces bit-identical. The
    /// merged export is byte-identical at any `threads` value.
    pub obs: Option<ObsConfig>,
    /// Correlated failure campaign (DESIGN.md §15): domain-wide
    /// outages and shard-gateway kills with deterministic re-sharding,
    /// composed with (and requiring) the churn config. `None` keeps
    /// the event stream bit-identical to the pre-campaign engine.
    pub campaign: Option<CampaignConfig>,
    /// Worker threads for the event engine ([`parallel::run_frames_threads`]):
    /// `0` or `1` runs the sequential shared-heap engine ([`run_frames`])
    /// unchanged; `> 1` partitions shards over that many workers, each
    /// with its own PJRT engine, merged by the deterministic watermark
    /// protocol (DESIGN.md §13). The merged trace is bit-identical
    /// across thread counts.
    pub threads: usize,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            n_nodes: 24,
            n_shards: 4,
            perturb: 0.15,
            queue_capacity: 8,
            dispatch: DispatchPolicy::LeastLoaded,
            n_sources: 16,
            seed: 7,
            drift: None,
            churn: None,
            slo: None,
            adapt: None,
            obs: None,
            campaign: None,
            threads: 1,
        }
    }
}

/// Does `cfg` run a gateway-kill campaign? That mode pre-provisions
/// every shard with the full node set (identical pair tables, foreign
/// nodes parked PoweredDown) so adoption is a membership/health flip,
/// never a mid-run PJRT deploy.
pub(crate) fn campaign_gateway_mode(cfg: &FleetConfig) -> bool {
    cfg.campaign.as_ref().is_some_and(|c| c.gateway_enabled())
}

/// Synthesizes sharded fleets from a base profiling store (normally the
/// deployed Table-1 testbed store).
pub struct FleetBuilder<'e> {
    engine: &'e Engine,
    base: ProfileStore,
}

impl<'e> FleetBuilder<'e> {
    pub fn new(engine: &'e Engine, base: ProfileStore) -> Self {
        Self { engine, base }
    }

    /// Build an N-node / K-shard fleet wired for one router config.
    ///
    /// Node `i` replicates base pair `i % pairs` with a unique identity
    /// (`model@device#i`), a device perturbed by the seeded RNG, and
    /// profile rows rescaled to first order (latency ∝ 1/speed, energy
    /// ∝ power/speed, mAP unchanged — the framework and decode
    /// threshold are those of the base device). Shards get the nodes
    /// round-robin, so every shard sees the same mix of base pairs.
    pub fn build(
        &self,
        spec: RouterSpec,
        delta_map: f64,
        cfg: &FleetConfig,
    ) -> Result<Fleet<'e>> {
        let synth = synth_nodes(&self.base, cfg)?;
        let all_shards = campaign_gateway_mode(cfg);
        let mut shard_nodes: Vec<Vec<EdgeNode>> =
            (0..cfg.n_shards).map(|_| Vec::new()).collect();
        let mut shard_rows: Vec<Vec<PairProfile>> =
            (0..cfg.n_shards).map(|_| Vec::new()).collect();
        let mut home_keys: Vec<(usize, PairKey)> =
            Vec::with_capacity(cfg.n_nodes);
        for ns in synth {
            home_keys.push((ns.shard, ns.pair.clone()));
            if all_shards {
                // gateway campaigns: every shard holds every node
                // (same rows, same seed, so the copies are twins);
                // foreign nodes are parked dormant below and only an
                // Adopt event wakes them.
                for s in 0..cfg.n_shards {
                    shard_rows[s].extend(ns.rows.iter().cloned());
                    shard_nodes[s]
                        .push(ns.make_node(self.engine, cfg)?);
                }
            } else {
                shard_rows[ns.shard].extend(ns.rows.iter().cloned());
                shard_nodes[ns.shard]
                    .push(ns.make_node(self.engine, cfg)?);
            }
        }
        self.engine.preload(&base_models(&self.base))?;

        let mut shards = Vec::with_capacity(cfg.n_shards);
        for (s, (nodes, rows)) in
            shard_nodes.into_iter().zip(shard_rows).enumerate()
        {
            shards.push(wire_shard(
                self.engine,
                spec,
                delta_map,
                cfg,
                s,
                nodes,
                rows,
            ));
        }
        // resolve each node's identity in its owning shard's id space
        // (the failure timeline addresses nodes by synthesis index).
        // In gateway-campaign mode every shard interned the same key
        // set in the same order, so the id is valid fleet-wide.
        let node_homes: Vec<(usize, PairId)> = home_keys
            .into_iter()
            .map(|(s, key)| {
                let id = shards[s]
                    .store()
                    .id_of(&key)
                    .expect("synthesized pair interned in its shard");
                (s, id)
            })
            .collect();
        if all_shards {
            // park each node's foreign copies: pool health down (the
            // physical node is not attached here) and membership
            // PoweredDown (sticky — probes cannot resurrect it, only
            // an Adopt event's power_up does).
            for (s, gw) in shards.iter_mut().enumerate() {
                for &(home, id) in &node_homes {
                    if home != s {
                        gw.pool_mut().set_health_id(id, false);
                        if let Some(m) = gw.membership_mut() {
                            m.power_down(id);
                        }
                    }
                }
            }
        }
        Ok(Fleet {
            shards,
            dispatch: cfg.dispatch,
            n_sources: cfg.n_sources.max(1),
            n_nodes: cfg.n_nodes,
            churn: cfg.churn.clone(),
            slo: cfg.slo.clone(),
            adapt: cfg.adapt.clone(),
            obs: cfg.obs.clone(),
            campaign: cfg.campaign.clone(),
            node_homes,
        })
    }
}

/// Engine-free synthesis of one fleet node: everything about the node's
/// identity, perturbed silicon, and rescaled profile rows that can be
/// computed without touching PJRT. [`FleetBuilder::build`] materializes
/// every entry on one engine; the parallel engine's workers materialize
/// only the shards they own on their own engines — each entry's RNG
/// stream is derived per synthesis index, so a subset synthesizes
/// exactly the same nodes as the full pass.
pub(crate) struct NodeSynth {
    pub shard: usize,
    pub pair: PairKey,
    pub dev: devices::DeviceSpec,
    pub synth_idx: usize,
    pub rows: Vec<PairProfile>,
}

impl NodeSynth {
    /// Materialize the node on `engine` (the only PJRT-touching step).
    pub fn make_node(
        &self,
        engine: &Engine,
        cfg: &FleetConfig,
    ) -> Result<EdgeNode> {
        let i = self.synth_idx as u64;
        let mut node = EdgeNode::new(
            engine,
            self.pair.clone(),
            self.dev.clone(),
            cfg.seed.wrapping_add(i),
        )?;
        if let Some(dc) = &cfg.drift {
            node.enable_drift(dc.clone(), cfg.seed ^ mix64(i));
        }
        Ok(node)
    }
}

/// Validate `cfg` and synthesize all `n_nodes` node descriptions
/// (node `i` replicates base pair `i % pairs`, shard `i % n_shards`).
pub(crate) fn synth_nodes(
    base: &ProfileStore,
    cfg: &FleetConfig,
) -> Result<Vec<NodeSynth>> {
    anyhow::ensure!(cfg.n_shards >= 1, "fleet needs at least one shard");
    anyhow::ensure!(
        cfg.n_nodes >= cfg.n_shards,
        "fewer nodes ({}) than shards ({})",
        cfg.n_nodes,
        cfg.n_shards
    );
    anyhow::ensure!(
        (0.0..0.95).contains(&cfg.perturb),
        "perturb {} outside [0, 0.95)",
        cfg.perturb
    );
    if let Some(camp) = &cfg.campaign {
        camp.validate()?;
        anyhow::ensure!(
            cfg.churn.is_some(),
            "campaign requires a churn config (campaign_* composes \
             with churn_*)"
        );
        if camp.gateway_enabled() {
            // both the autoscaler and gateway failover drive the
            // power state of the same membership entries; composing
            // them is future work, so reject it loudly
            anyhow::ensure!(
                cfg.adapt.is_none(),
                "gateway campaigns and the autoscaler are mutually \
                 exclusive (both drive node power state)"
            );
        }
    }
    let base_pairs = base.pairs();
    anyhow::ensure!(!base_pairs.is_empty(), "base profile store is empty");
    let base_fleet = devices::fleet();
    let rng = Rng::new(cfg.seed ^ 0xF1EE_7B0A);
    let mut out = Vec::with_capacity(cfg.n_nodes);
    for i in 0..cfg.n_nodes {
        let bp = &base_pairs[i % base_pairs.len()];
        let bp_id = base.id_of(bp).expect("base pair interned");
        let base_dev = devices::find(&base_fleet, &bp.device)
            .with_context(|| {
                format!("unknown base device '{}'", bp.device)
            })?;
        let mut r = rng.derive(i as u64);
        let speed = 1.0 + cfg.perturb * (2.0 * r.f64() - 1.0);
        let power = 1.0 + cfg.perturb * (2.0 * r.f64() - 1.0);
        let dev = base_dev.scaled(speed, power);
        let pair =
            PairKey::new(&bp.model, &format!("{}#{:04}", bp.device, i));
        // the base pair's rows via the pair index (insertion order),
        // not a full-table string scan
        let rows = base
            .pair_row_indices(bp_id)
            .iter()
            .map(|&ri| {
                let row = &base.rows()[ri as usize];
                PairProfile {
                    pair: pair.clone(),
                    group: row.group,
                    map: row.map,
                    latency_s: row.latency_s / speed,
                    energy_mwh: row.energy_mwh * power / speed,
                }
            })
            .collect();
        out.push(NodeSynth {
            shard: i % cfg.n_shards,
            pair,
            dev,
            synth_idx: i,
            rows,
        });
    }
    Ok(out)
}

/// Sorted, deduplicated model names of a base store — the preload set
/// for any engine serving a fleet synthesized from it.
pub(crate) fn base_models(base: &ProfileStore) -> Vec<&str> {
    let mut models: Vec<&str> =
        base.pairs().iter().map(|p| p.model.as_str()).collect();
    models.sort();
    models.dedup();
    models
}

/// Wire one shard gateway exactly the way [`FleetBuilder::build`] does:
/// pool capacity, per-shard policy seed, churn membership, adapt
/// runtime. Shared with the parallel engine so both paths stay
/// byte-identical.
pub(crate) fn wire_shard<'e>(
    engine: &'e Engine,
    spec: RouterSpec,
    delta_map: f64,
    cfg: &FleetConfig,
    s: usize,
    nodes: Vec<EdgeNode>,
    rows: Vec<PairProfile>,
) -> Gateway<'e> {
    let mut pool = NodePool::from_nodes(nodes);
    pool.set_queue_capacity(cfg.queue_capacity);
    let mut gw = Gateway::new(
        engine,
        spec,
        ProfileStore::new(rows),
        pool,
        delta_map,
        cfg.seed ^ mix64(0x0005_1A2D + s as u64),
    );
    if let Some(c) = &cfg.churn {
        gw.enable_churn(c);
    }
    if let Some(a) = &cfg.adapt {
        gw.enable_adapt(a);
    }
    gw
}

/// A built fleet: K shard gateways plus the dispatch front-end.
pub struct Fleet<'e> {
    shards: Vec<Gateway<'e>>,
    dispatch: DispatchPolicy,
    n_sources: usize,
    n_nodes: usize,
    /// Churn scenario the fleet was built with (drives `run_frames`).
    churn: Option<ChurnConfig>,
    /// SLO/batching config the fleet was built with.
    slo: Option<SloConfig>,
    /// Adaptation config the fleet was built with (each shard already
    /// carries its own live [`crate::adapt::AdaptRuntime`]).
    adapt: Option<AdaptConfig>,
    /// Observability config the fleet was built with.
    obs: Option<ObsConfig>,
    /// Failure-campaign config the fleet was built with.
    campaign: Option<CampaignConfig>,
    /// Global synthesis index → (owning shard, node identity in that
    /// shard's id space): how the ground-truth failure timeline
    /// addresses nodes.
    node_homes: Vec<(usize, PairId)>,
}

impl<'e> Fleet<'e> {
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn n_nodes(&self) -> usize {
        self.n_nodes
    }

    pub fn dispatch(&self) -> DispatchPolicy {
        self.dispatch
    }

    pub fn shards(&self) -> &[Gateway<'e>] {
        &self.shards
    }

    pub fn shards_mut(&mut self) -> &mut [Gateway<'e>] {
        &mut self.shards
    }
}

/// Outcome of one fleet run.
#[derive(Clone, Debug)]
pub struct FleetReport {
    /// Per-shard request accounting, index-aligned with the shards.
    pub per_shard: Vec<RunMetrics>,
    /// Requests offered by the arrival process (served + dropped).
    pub offered: usize,
    /// Requests shed because every shard was saturated.
    pub dropped: usize,
    /// Within-shard fallback re-routes (down or queue-full nodes).
    pub node_fallbacks: usize,
    /// Requests that left their dispatch shard for another because the
    /// primary was saturated.
    pub cross_shard_fallbacks: usize,
    /// Virtual time at which the last response left the system (s).
    pub makespan_s: f64,
    /// Peak requests simultaneously in the system, fleet-wide.
    pub peak_in_flight: usize,
    /// Churn accounting — present exactly when the fleet was built with
    /// a lifecycle config. `requests + dropped + lost == offered`.
    pub churn: Option<ChurnReport>,
    /// SLO accounting (attainment per class, sheds, batch-size
    /// histogram) — present exactly when the fleet had an SLO config.
    pub slo: Option<SloMetrics>,
    /// Adaptation accounting merged across shards — present exactly
    /// when the fleet had an adapt config.
    pub adapt: Option<AdaptReport>,
    /// Campaign schedule summary — present exactly when the fleet had
    /// a campaign config. A pure function of the plan, so it is
    /// bit-identical at every thread count by construction.
    pub campaign: Option<CampaignReport>,
}

impl FleetReport {
    /// Served requests across all shards.
    pub fn requests(&self) -> usize {
        self.per_shard.iter().map(|m| m.requests).sum()
    }

    /// Requests permanently lost to node crashes (0 without churn).
    pub fn lost(&self) -> usize {
        self.churn.as_ref().map(|c| c.lost).unwrap_or(0)
    }

    /// Served throughput over the run's virtual wall-clock (req/s).
    pub fn goodput_rps(&self) -> f64 {
        if self.makespan_s > 0.0 {
            self.requests() as f64 / self.makespan_s
        } else {
            0.0
        }
    }

    pub fn total_energy_mwh(&self) -> f64 {
        self.per_shard.iter().map(|m| m.total_energy_mwh()).sum()
    }

    pub fn energy_per_request_mwh(&self) -> f64 {
        let n = self.requests();
        if n > 0 {
            self.total_energy_mwh() / n as f64
        } else {
            0.0
        }
    }

    /// All shards' end-to-end latency samples merged (unsorted).
    fn merged_samples(&self) -> Vec<f64> {
        self.per_shard
            .iter()
            .flat_map(|m| m.latency_samples.iter().copied())
            .collect()
    }

    /// End-to-end latency percentile over all shards' samples merged.
    /// For several percentiles at once, prefer
    /// [`FleetReport::latency_percentiles`] (one merge + sort).
    pub fn latency_percentile(&self, p: f64) -> f64 {
        percentile(&self.merged_samples(), p)
    }

    /// Several merged-sample percentiles from a single merge + sort.
    pub fn latency_percentiles(&self, ps: &[f64]) -> Vec<f64> {
        percentiles(&self.merged_samples(), ps)
    }

    /// Mean per-request queueing delay across the fleet (s).
    pub fn mean_queue_delay_s(&self) -> f64 {
        let n = self.requests();
        if n > 0 {
            self.per_shard.iter().map(|m| m.queue_delay_s).sum::<f64>()
                / n as f64
        } else {
            0.0
        }
    }

    /// COCO mAP over every image served by any shard (0–100).
    pub fn map(&self) -> f64 {
        let images: Vec<ImageEval> = self
            .per_shard
            .iter()
            .flat_map(|m| m.images.iter().cloned())
            .collect();
        map_coco(&images, crate::dataset::NUM_CLASSES).map
    }

    /// Max/mean served requests per shard: 1.0 is perfectly balanced,
    /// K means one shard took everything; 0.0 when nothing was served.
    pub fn shard_imbalance(&self) -> f64 {
        let total = self.requests();
        if total == 0 || self.per_shard.is_empty() {
            return 0.0;
        }
        let mean = total as f64 / self.per_shard.len() as f64;
        let max =
            self.per_shard.iter().map(|m| m.requests).max().unwrap_or(0);
        max as f64 / mean
    }

    /// Stable JSON report (field order fixed by the Json substrate's
    /// BTreeMap) — the golden-trace determinism tests compare this dump
    /// byte for byte.
    pub fn to_json(&self) -> Json {
        let pcts = self.latency_percentiles(&[50.0, 95.0, 99.0]);
        let mut fields = vec![
            ("offered", Json::num(self.offered as f64)),
            ("requests", Json::num(self.requests() as f64)),
            ("dropped", Json::num(self.dropped as f64)),
            ("lost", Json::num(self.lost() as f64)),
            ("node_fallbacks", Json::num(self.node_fallbacks as f64)),
            (
                "cross_shard_fallbacks",
                Json::num(self.cross_shard_fallbacks as f64),
            ),
            ("makespan_s", Json::num(self.makespan_s)),
            ("peak_in_flight", Json::num(self.peak_in_flight as f64)),
            ("goodput_rps", Json::num(self.goodput_rps())),
            ("latency_p50_s", Json::num(pcts[0])),
            ("latency_p95_s", Json::num(pcts[1])),
            ("latency_p99_s", Json::num(pcts[2])),
            (
                "mean_queue_delay_s",
                Json::num(self.mean_queue_delay_s()),
            ),
            ("energy_mwh", Json::num(self.total_energy_mwh())),
            (
                "energy_per_request_mwh",
                Json::num(self.energy_per_request_mwh()),
            ),
            ("map", Json::num(self.map())),
            ("shard_imbalance", Json::num(self.shard_imbalance())),
            (
                "shards",
                Json::Arr(
                    self.per_shard.iter().map(|m| m.to_json()).collect(),
                ),
            ),
        ];
        if let Some(c) = &self.churn {
            fields.push(("churn", c.to_json()));
        }
        if let Some(s) = &self.slo {
            fields.push(("slo", s.to_json()));
        }
        if let Some(a) = &self.adapt {
            fields.push(("adapt", a.to_json()));
        }
        if let Some(c) = &self.campaign {
            fields.push(("campaign", c.to_json()));
        }
        Json::obj(fields)
    }
}

/// One event on the shared virtual clock; ordered by (time, sequence)
/// so ties resolve in insertion order — a shard-aware copy of the
/// `workload::openloop` event machinery. A fix to the ordering,
/// queue-delay formula, or completion scheduling must land in both
/// copies; the golden-trace tests pin each side's behavior.
struct Event {
    t: f64,
    seq: u64,
    kind: EventKind,
}

enum EventKind {
    /// Request `idx` arrives at the fleet front-end.
    Arrival(usize),
    /// The in-service request on `pair` (owned by `shard`) completes.
    /// `token` identifies the service instance: completions of requests
    /// lost to a crash are stale (token mismatch) and ignored.
    Completion {
        shard: usize,
        pair: PairId,
        token: u64,
    },
    /// Ground-truth crash of synthesized node `node` (churn only).
    Crash(usize),
    /// Ground-truth rejoin of synthesized node `node`.
    Rejoin(usize),
    /// Shard `shard`'s periodic health probe fires (snapshot now,
    /// results apply after the probe timeout).
    Probe { shard: usize },
    /// Probe responses (shard pool order) reach that shard's view.
    ProbeResult { shard: usize, responses: Vec<bool> },
    /// Re-dispatch of request `idx` lost to a crash (retry policy).
    Retry(usize),
    /// A batch formation window on `pair` (owned by `shard`) closes
    /// (SLO runs only). `token` identifies the formation generation: a
    /// new member reschedules the close, leaving earlier events stale.
    BatchClose {
        shard: usize,
        pair: PairId,
        token: u64,
    },
    /// Shard `shard`'s autoscaler decision tick (adapt runs with
    /// `scale` only): close the arrival-rate window and perform at
    /// most one power transition in that shard.
    ScaleTick { shard: usize },
    /// Campaign: failure domain `domain` tripped or restored —
    /// observability marker anchored to `shard` (the member crashes
    /// arrive as their own Crash/Rejoin events).
    DomainMark { shard: usize, domain: usize, down: bool },
    /// Campaign: `shard`'s gateway dies (obs marker; its queued work
    /// drains through the Release events planned immediately after).
    GwDown { shard: usize },
    /// Campaign: `shard`'s gateway recovers (obs marker).
    GwUp { shard: usize },
    /// Campaign: node `node` (interned as `pair`) leaves `shard` —
    /// drain its queue through the resilience policy, then park it
    /// dormant (health down + membership PoweredDown).
    Release { shard: usize, node: usize, pair: PairId },
    /// Campaign: node `node` (interned as `pair`) is adopted by
    /// `shard`; `up` is its ground-truth health at adoption. The
    /// adopting gateway bootstraps belief from Warming + probes.
    Adopt { shard: usize, node: usize, pair: PairId, up: bool },
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq && self.t.total_cmp(&other.t).is_eq()
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t.total_cmp(&other.t).then(self.seq.cmp(&other.seq))
    }
}

/// A request admitted to a node's FIFO, waiting for service.
struct Pending {
    routed: RoutedRequest,
    idx: usize,
    arrival_s: f64,
    /// This copy is a hedged duplicate (its completion may be waste).
    hedge: bool,
    /// Deadline/batching tag; [`SloTag::default`] (inert) without SLOs.
    slo: SloTag,
}

/// The request a node is currently serving.
struct InService {
    routed: RoutedRequest,
    idx: usize,
    arrival_s: f64,
    start_s: f64,
    resp: NodeResponse,
    /// Matches the scheduled completion event (stale-event guard).
    token: u64,
    hedge: bool,
    slo: SloTag,
}

/// A batch under formation on one (shard, pair) — the twin of the
/// structure in `workload::openloop`. Members hold their queue slots
/// from admission and flush as one contiguous amortized train.
struct Forming {
    members: Vec<Pending>,
    close_s: f64,
    /// Matches the live scheduled [`EventKind::BatchClose`].
    token: u64,
}

impl Default for Forming {
    fn default() -> Self {
        Self { members: Vec::new(), close_s: f64::INFINITY, token: 0 }
    }
}

/// Per-node serving state: one in-service slot + FIFO backlog.
#[derive(Default)]
struct NodeQueue {
    serving: Option<InService>,
    backlog: VecDeque<Pending>,
}

/// Mutable simulator state threaded through the event handlers.
struct SimState {
    queues: Vec<BTreeMap<PairId, NodeQueue>>,
    heap: BinaryHeap<Reverse<Event>>,
    seq: u64,
    dropped: usize,
    cross_shard_fallbacks: usize,
    in_flight: Vec<usize>,
    total_in_flight: usize,
    peak_in_flight: usize,
    makespan_s: f64,
    /// Per-shard batches under formation (always empty without SLOs).
    forming: Vec<BTreeMap<PairId, Forming>>,
    /// Passive observability collectors (`None` = obs off): one per
    /// shard plus a final spine collector ([`SPINE_SHARD`]) for
    /// run-level events — placement sheds, retries, abandons.
    obs: Option<Vec<ObsShard>>,
}

impl SimState {
    fn new(k: usize) -> Self {
        Self {
            queues: (0..k).map(|_| BTreeMap::new()).collect(),
            heap: BinaryHeap::new(),
            seq: 0,
            dropped: 0,
            cross_shard_fallbacks: 0,
            in_flight: vec![0; k],
            total_in_flight: 0,
            peak_in_flight: 0,
            makespan_s: 0.0,
            forming: (0..k).map(|_| BTreeMap::new()).collect(),
            obs: None,
        }
    }

    fn push(&mut self, t: f64, kind: EventKind) {
        self.heap.push(Reverse(Event {
            t,
            seq: self.seq,
            kind,
        }));
        self.seq += 1;
    }

    /// Shard `s`'s obs collector, when obs is on.
    fn obs_at(&mut self, s: usize) -> Option<&mut ObsShard> {
        self.obs.as_mut().map(|v| &mut v[s])
    }

    /// The spine collector for run-level events, when obs is on.
    fn obs_spine(&mut self) -> Option<&mut ObsShard> {
        self.obs.as_mut().and_then(|v| v.last_mut())
    }
}

/// Driver-side churn context (shard-aware twin of the one in
/// `workload::openloop`).
struct ChurnDriver {
    /// Global synthesis index → (owning shard, node identity).
    homes: Vec<(usize, PairId)>,
    /// Pool-ordered node identities per shard (probe snapshots).
    shard_pairs: Vec<Vec<PairId>>,
    probe_timeout_s: f64,
    state: ChurnState,
    /// `(estimate, gateway cost)` paid at each request's first
    /// successful placement; retries re-route with these instead of
    /// re-running every visited shard's estimator.
    est: Vec<Option<(usize, GatewayCost)>>,
    /// Hedged requests' `(primary pair, hedge pair)` — both always on
    /// the winning shard — so cancellation-on-first-response can find
    /// the losing sibling without scanning queues (`hedge_cancel`).
    hedge_pairs: Vec<Option<(PairId, PairId)>>,
    /// Hedge cancellation-on-first-response enabled.
    hedge_cancel: bool,
}

/// Driver-side SLO context (twin of the one in `workload::openloop`):
/// fleet-wide attainment accounting over per-request deadlines
/// precomputed from the materialized arrival times.
struct SloRt {
    cfg: SloConfig,
    deadlines: Vec<f64>,
    metrics: SloMetrics,
}

impl SloRt {
    fn record_done(&mut self, idx: usize, class: usize, done_s: f64) {
        self.metrics
            .record_completion(class, done_s <= self.deadlines[idx]);
    }

    fn shed(&mut self, idx: usize) {
        self.metrics.record_shed(self.cfg.class_of(idx));
    }
}

/// Drive a fleet over pre-rendered frames under open-loop arrivals.
///
/// Per arrival: the dispatch policy yields a shard visit order; the
/// first shard whose gateway admits the request (it has a healthy node
/// with a free queue slot for the estimated group) wins. Visits beyond
/// the first count as cross-shard fallbacks; exhausting every shard
/// sheds the request. Completions release the slot, record metrics on
/// the serving shard, and start that node's next queued request.
pub fn run_frames(
    fleet: &mut Fleet<'_>,
    frames: &[Scene],
    pseudo_gt: &[Vec<GtBox>],
    arrivals: &ArrivalProcess,
    seed: u64,
) -> Result<FleetReport> {
    anyhow::ensure!(frames.len() == pseudo_gt.len());
    let k = fleet.shards.len();
    let fallbacks_before: Vec<usize> =
        fleet.shards.iter().map(|g| g.fallbacks).collect();
    let mut metrics: Vec<RunMetrics> = (0..k)
        .map(|s| {
            RunMetrics::new(&format!("{}-s{s}", fleet.shards[s].spec.name))
        })
        .collect();
    let mut sim = SimState::new(k);
    // Observability (DESIGN.md §14): one passive collector per shard
    // plus a spine collector for run-level events (placement sheds,
    // retries, abandons). `None` leaves the hot path untouched.
    sim.obs = fleet.obs.as_ref().map(|c| {
        let mut v: Vec<ObsShard> = (0..k)
            .map(|s| ObsShard::new(c, s as u32, frames.len()))
            .collect();
        v.push(ObsShard::new(c, SPINE_SHARD, frames.len()));
        v
    });
    let obs_t0 =
        fleet.obs.as_ref().map(|_| std::time::Instant::now());
    let arrival_times = arrivals.times(frames.len(), seed);
    let horizon_s = arrival_times.last().copied().unwrap_or(0.0)
        + fleet
            .churn
            .as_ref()
            .map(|c| c.horizon_slack_s)
            .unwrap_or(0.0);
    // SLO runs: absolute deadlines are a pure function of the arrival
    // process, so they're materialized up front alongside it.
    let mut slo = match fleet.slo.clone() {
        Some(c) => {
            anyhow::ensure!(
                !c.classes.is_empty(),
                "slo config needs at least one deadline class"
            );
            Some(SloRt {
                deadlines: arrival_times
                    .iter()
                    .enumerate()
                    .map(|(i, &t)| c.deadline_for(i, t))
                    .collect(),
                metrics: SloMetrics::new(&c.class_names()),
                cfg: c,
            })
        }
        None => None,
    };
    for (idx, t) in arrival_times.into_iter().enumerate() {
        sim.push(t, EventKind::Arrival(idx));
    }

    // campaign runs (DESIGN.md §15): fold churn + domain + gateway
    // processes into one pre-sorted plan. The plan (and its report)
    // is a pure function of the configs, so the parallel engine
    // rebuilds the identical one. Without a campaign the original
    // failure-schedule path below runs byte-identically.
    let campaign_plan = match (&fleet.churn, &fleet.campaign) {
        (Some(c), Some(camp)) => Some(CampaignPlan::build(
            fleet.node_homes.len(),
            k,
            horizon_s,
            c,
            camp,
        )?),
        (None, Some(_)) => {
            anyhow::bail!("campaign requires a churn config")
        }
        _ => None,
    };

    // churn runs: the ground-truth failure timeline addresses nodes by
    // their global synthesis index; each shard probes only its own
    // pool. The shard gateways were switched to membership routing at
    // build time. Without churn nothing below adds a single event.
    let mut churn = match fleet.churn.clone() {
        Some(c) => {
            match &campaign_plan {
                Some(plan) => {
                    for pe in &plan.events {
                        let kind = match *pe {
                            PlanEvent::Truth { node, up: true, .. } => {
                                EventKind::Rejoin(node)
                            }
                            PlanEvent::Truth {
                                node, up: false, ..
                            } => EventKind::Crash(node),
                            PlanEvent::DomainMark {
                                shard,
                                domain,
                                down,
                                ..
                            } => EventKind::DomainMark {
                                shard,
                                domain,
                                down,
                            },
                            PlanEvent::GwDown { shard, .. } => {
                                EventKind::GwDown { shard }
                            }
                            PlanEvent::GwUp { shard, .. } => {
                                EventKind::GwUp { shard }
                            }
                            PlanEvent::Release { shard, node, .. } => {
                                EventKind::Release {
                                    shard,
                                    node,
                                    pair: fleet.node_homes[node].1,
                                }
                            }
                            PlanEvent::Adopt {
                                shard, node, up, ..
                            } => EventKind::Adopt {
                                shard,
                                node,
                                pair: fleet.node_homes[node].1,
                                up,
                            },
                        };
                        sim.push(pe.t(), kind);
                    }
                }
                None => {
                    for ev in lifecycle::failure_schedule(
                        fleet.node_homes.len(),
                        horizon_s,
                        &c,
                    ) {
                        let kind = if ev.up {
                            EventKind::Rejoin(ev.node)
                        } else {
                            EventKind::Crash(ev.node)
                        };
                        sim.push(ev.t, kind);
                    }
                }
            }
            let gap = c.probe_interval_s.max(1e-6);
            for s in 0..k {
                let mut t = gap;
                while t < horizon_s {
                    sim.push(t, EventKind::Probe { shard: s });
                    t += gap;
                }
            }
            let shard_pairs: Vec<Vec<PairId>> = fleet
                .shards
                .iter()
                .map(|g| {
                    g.pool()
                        .nodes()
                        .iter()
                        .map(|n| {
                            g.store().id_of(&n.pair).expect(
                                "shard pair missing from its table",
                            )
                        })
                        .collect()
                })
                .collect();
            Some(ChurnDriver {
                homes: fleet.node_homes.clone(),
                shard_pairs,
                probe_timeout_s: c.probe_timeout_s,
                state: ChurnState::new(
                    frames.len(),
                    c.policy,
                    c.retry_backoff_s,
                ),
                est: vec![None; frames.len()],
                hedge_pairs: vec![None; frames.len()],
                hedge_cancel: c.hedge_cancel,
            })
        }
        None => None,
    };

    // adaptation runs: each shard's gateway already carries its live
    // AdaptRuntime (built in `FleetBuilder::build`); when scaling is
    // on, every shard gets its own decision-tick train, like probes.
    // Without adapt nothing below adds a single event.
    if let Some(a) = &fleet.adapt {
        if a.scale {
            let gap = a.scale_interval_s.max(1e-6);
            for s in 0..k {
                let mut t = gap;
                while t < horizon_s {
                    sim.push(t, EventKind::ScaleTick { shard: s });
                    t += gap;
                }
            }
        }
    }

    while let Some(Reverse(ev)) = sim.heap.pop() {
        match ev.kind {
            EventKind::Arrival(idx) => {
                let Some((s, routed)) =
                    try_place(fleet, frames, pseudo_gt, &mut sim, idx, ev.t)?
                else {
                    match churn.as_mut() {
                        Some(ch)
                            if matches!(
                                ch.state.policy(),
                                ResiliencePolicy::Retry { .. }
                            ) =>
                        {
                            if let LossOutcome::RetryAt(t) =
                                ch.state.placement_failed(idx, ev.t)
                            {
                                retry_or_abandon(
                                    &mut sim,
                                    &mut ch.state,
                                    slo.as_mut(),
                                    idx,
                                    t,
                                );
                            }
                        }
                        _ => {
                            sim.dropped += 1;
                            // an overflow drop misses its SLO too
                            if let Some(sr) = slo.as_mut() {
                                sr.shed(idx);
                            }
                            if let Some(o) = sim.obs_spine() {
                                o.shed(idx, ev.t);
                            }
                        }
                    }
                    continue;
                };
                // the winning shard's rate EWMA sees the demand (the
                // dispatch policy decides which shard absorbs load, so
                // each scaler tracks its own slice)
                fleet.shards[s].adapt_arrival();
                // admit + route land on the WINNING shard's collector
                // (there is no standalone estimate step: every visited
                // shard estimated inside `try_place`)
                if let Some(o) = sim.obs_at(s) {
                    o.admit(idx, ev.t, routed.estimate);
                    o.route(
                        idx,
                        ev.t,
                        i64::from(routed.pair_id.0),
                        routed.cost.latency_s,
                        routed.cost.energy_mwh,
                    );
                }
                // SLO admission control: predicted completion on the
                // placed shard already past the deadline → shed now
                // instead of queueing doomed work (DESIGN.md §11).
                let mut tag = SloTag::default();
                if let Some(sr) = slo.as_mut() {
                    let deadline = sr.deadlines[idx];
                    let pred = fleet.shards[s].predicted_completion_s(
                        routed.pair_id,
                        ev.t,
                        routed.cost.latency_s,
                    );
                    if ev.t + pred > deadline {
                        sim.dropped += 1;
                        sr.shed(idx);
                        if let Some(o) = sim.obs_at(s) {
                            o.shed(idx, ev.t);
                        }
                        continue;
                    }
                    tag = SloTag {
                        class: sr.cfg.class_of(idx),
                        deadline_s: deadline,
                        edf_s: deadline,
                        ..tag
                    };
                }
                // proactive hedging stays within the winning shard (the
                // duplicate reuses the primary's estimate)
                let dup = match churn.as_ref() {
                    Some(ch)
                        if ch.state.policy()
                            == ResiliencePolicy::Hedge =>
                    {
                        fleet.shards[s]
                            .route_secondary(&routed, ev.t)
                            .filter(|&p| match slo.as_ref() {
                                // hedges respect the remaining budget
                                Some(sr) => {
                                    ev.t + fleet.shards[s]
                                        .predicted_completion_s(
                                            p, ev.t, 0.0,
                                        )
                                        <= sr.deadlines[idx]
                                }
                                None => true,
                            })
                            .map(|p| RoutedRequest {
                                pair_id: p,
                                ..routed
                            })
                    }
                    _ => None,
                };
                // register BOTH copies before admitting either: the
                // primary can die synchronously at dispatch (stale
                // view), and its loss must see the hedge as a live
                // sibling, not declare the request lost. The winning
                // shard's estimate + cost are cached so a retry never
                // pays the estimator again.
                if let Some(ch) = churn.as_mut() {
                    ch.est[idx] = Some((routed.estimate, routed.cost));
                    ch.state.dispatched(idx);
                    if let Some(d) = &dup {
                        ch.state.hedge_dispatched(idx);
                        ch.hedge_pairs[idx] =
                            Some((routed.pair_id, d.pair_id));
                    }
                }
                // batch formation: primary copies without a hedge
                // sibling join their (shard, pair) forming batch
                let forms = dup.is_none()
                    && slo.as_ref().is_some_and(|sr| {
                        sr.cfg.batch_window_s > 0.0
                            && sr.cfg.max_batch > 1
                    });
                if forms {
                    join_forming(
                        &mut fleet.shards[s],
                        s,
                        frames,
                        &mut sim,
                        &mut churn,
                        &mut slo,
                        routed,
                        tag,
                        idx,
                        ev.t,
                    )?;
                    continue;
                }
                if let Some(sr) = slo.as_mut() {
                    // unbatched dispatch: a size-1 "batch"
                    sr.metrics.record_batch(1);
                }
                admit_copy(
                    &mut fleet.shards[s],
                    s,
                    frames,
                    &mut sim,
                    &mut churn,
                    &mut slo,
                    routed,
                    idx,
                    ev.t,
                    false,
                    tag,
                )?;
                if let Some(d) = dup {
                    if let Some(o) = sim.obs_at(s) {
                        o.hedge(idx, ev.t, i64::from(d.pair_id.0));
                    }
                    admit_copy(
                        &mut fleet.shards[s],
                        s,
                        frames,
                        &mut sim,
                        &mut churn,
                        &mut slo,
                        d,
                        idx,
                        ev.t,
                        true,
                        tag,
                    )?;
                }
            }
            EventKind::Retry(idx) => {
                // a request that placed before carries its ORIGINAL
                // estimate + cost (estimator caching); one that never
                // placed re-estimates like a fresh arrival.
                let cached = churn
                    .as_ref()
                    .expect("retry without churn")
                    .est[idx];
                let placed = match cached {
                    Some((estimate, cost)) => try_place_with_estimate(
                        fleet,
                        &mut sim,
                        idx,
                        estimate,
                        pseudo_gt[idx].len(),
                        cost,
                        ev.t,
                    )?,
                    None => try_place(
                        fleet, frames, pseudo_gt, &mut sim, idx, ev.t,
                    )?,
                };
                let ch = churn.as_mut().expect("retry without churn");
                let Some((s, routed)) = placed else {
                    if let LossOutcome::RetryAt(t) =
                        ch.state.placement_failed(idx, ev.t)
                    {
                        retry_or_abandon(
                            &mut sim,
                            &mut ch.state,
                            slo.as_mut(),
                            idx,
                            t,
                        );
                    }
                    continue;
                };
                if ch.est[idx].is_none() {
                    ch.est[idx] = Some((routed.estimate, routed.cost));
                }
                ch.state.retry_dispatched(idx);
                // a re-placed retry re-routes but was admitted once
                if let Some(o) = sim.obs_at(s) {
                    o.route(
                        idx,
                        ev.t,
                        i64::from(routed.pair_id.0),
                        routed.cost.latency_s,
                        routed.cost.energy_mwh,
                    );
                }
                // retries bypass batch formation but keep their
                // deadline for EDF and attainment accounting
                let tag = match slo.as_ref() {
                    Some(sr) => SloTag {
                        class: sr.cfg.class_of(idx),
                        deadline_s: sr.deadlines[idx],
                        edf_s: sr.deadlines[idx],
                        ..SloTag::default()
                    },
                    None => SloTag::default(),
                };
                admit_copy(
                    &mut fleet.shards[s],
                    s,
                    frames,
                    &mut sim,
                    &mut churn,
                    &mut slo,
                    routed,
                    idx,
                    ev.t,
                    false,
                    tag,
                )?;
            }
            EventKind::Completion {
                shard: s,
                pair,
                token,
            } => {
                let q = sim.queues[s]
                    .get_mut(&pair)
                    .expect("completion for unknown queue");
                if q.serving.as_ref().map(|x| x.token) != Some(token) {
                    // in-service request was lost to a crash after this
                    // completion was scheduled — stale event
                    debug_assert!(
                        churn.is_some(),
                        "stale completion without churn"
                    );
                    continue;
                }
                let done = q.serving.take().expect("token just matched");
                fleet.shards[s].pool_mut().release_id(pair);
                sim.in_flight[s] -= 1;
                sim.total_in_flight -= 1;
                sim.makespan_s = sim.makespan_s.max(ev.t);
                let n_if = sim.in_flight[s];
                if let Some(o) = sim.obs_at(s) {
                    o.in_flight(ev.t, n_if);
                }
                // energy + arrival captured before `done.resp` is
                // consumed by `finish_with_network` below
                let (e2e_s, e_mwh) =
                    (ev.t - done.arrival_s, done.resp.energy_mwh);
                let (r_idx, r_hedge) = (done.idx, done.hedge);
                let winner = match churn.as_mut() {
                    Some(ch) => ch.state.copy_completed(
                        done.idx,
                        done.resp.energy_mwh,
                        done.hedge,
                    ),
                    None => true,
                };
                if winner {
                    let queue_delay_s = (done.start_s
                        - (done.arrival_s + done.routed.cost.latency_s))
                        .max(0.0);
                    // batch followers rode the leader's transfer
                    let net_s = if done.slo.net {
                        devices::NETWORK_S
                    } else {
                        0.0
                    };
                    let (d_idx, d_class) = (done.idx, done.slo.class);
                    fleet.shards[s].finish_with_network(
                        &done.routed,
                        done.resp,
                        &pseudo_gt[done.idx],
                        queue_delay_s,
                        net_s,
                        &mut metrics[s],
                    );
                    if let Some(sr) = slo.as_mut() {
                        sr.record_done(d_idx, d_class, ev.t);
                    }
                    let on_time = match slo.as_ref() {
                        Some(sr) => ev.t <= sr.deadlines[d_idx],
                        None => true,
                    };
                    if let Some(o) = sim.obs_at(s) {
                        o.finish(
                            d_idx,
                            ev.t,
                            i64::from(pair.0),
                            e2e_s,
                            e_mwh,
                            on_time,
                        );
                    }
                } else if let Some(o) = sim.obs_at(s) {
                    // a hedge loser burned energy without producing
                    // the answer: attribute the waste where it ran
                    o.hedge_loss(done.idx, ev.t, i64::from(pair.0), e_mwh);
                }
                // cancellation-on-first-response: the winning copy's
                // completion cancels the in-flight sibling, freeing
                // its slot NOW and charging only accrued energy. A
                // sibling already gone (crash-lost) is a no-op.
                let sib = match churn.as_mut() {
                    Some(ch) if winner && ch.hedge_cancel => ch
                        .hedge_pairs[r_idx]
                        .take()
                        .map(|(p, h)| if r_hedge { p } else { h }),
                    _ => None,
                };
                if let Some(sib) = sib {
                    cancel_sibling(
                        &mut fleet.shards[s],
                        s,
                        frames,
                        &mut sim,
                        &mut churn,
                        &mut slo,
                        sib,
                        r_idx,
                        ev.t,
                    )?;
                }
                start_next(
                    &mut fleet.shards[s],
                    s,
                    frames,
                    &mut sim,
                    &mut churn,
                    &mut slo,
                    pair,
                    ev.t,
                )?;
            }
            EventKind::Crash(node) => {
                let ch = churn.as_mut().expect("crash without churn");
                let (s, pair) = ch.homes[node];
                ch.state.crashes += 1;
                if let Some(o) = sim.obs_at(s) {
                    o.crash(ev.t);
                }
                let gw = &mut fleet.shards[s];
                gw.pool_mut().set_health_id(pair, false);
                if let Some(m) = gw.membership_mut() {
                    m.ground_truth_changed(pair, false, ev.t);
                }
                lose_queued(
                    gw, s, &mut sim, &mut ch.state, &mut slo, pair, None,
                    ev.t,
                );
            }
            EventKind::Rejoin(node) => {
                let ch = churn.as_ref().expect("rejoin without churn");
                let (s, pair) = ch.homes[node];
                let gw = &mut fleet.shards[s];
                gw.pool_mut().set_health_id(pair, true);
                if let Some(n) = gw.pool_mut().get_id(pair) {
                    n.on_rejoin(ev.t);
                }
                if let Some(m) = gw.membership_mut() {
                    m.ground_truth_changed(pair, true, ev.t);
                }
                if let Some(o) = sim.obs_at(s) {
                    o.rejoin(ev.t);
                }
            }
            EventKind::Probe { shard } => {
                let ch = churn.as_ref().expect("probe without churn");
                let gw = &fleet.shards[shard];
                let responses: Vec<bool> = ch.shard_pairs[shard]
                    .iter()
                    .map(|&p| gw.pool().is_healthy_id(p))
                    .collect();
                let timeout = ch.probe_timeout_s;
                sim.push(
                    ev.t + timeout,
                    EventKind::ProbeResult { shard, responses },
                );
            }
            EventKind::ProbeResult { shard, responses } => {
                let ch = churn.as_ref().expect("probe without churn");
                let m = fleet.shards[shard]
                    .membership_mut()
                    .expect("churn shard lost its membership");
                for (&p, up) in
                    ch.shard_pairs[shard].iter().zip(&responses)
                {
                    m.observe_probe(p, *up, ev.t);
                }
            }
            EventKind::BatchClose { shard, pair, token } => {
                if sim.forming[shard].get(&pair).map(|f| f.token)
                    != Some(token)
                {
                    // superseded: a later member rescheduled the close,
                    // the batch already flushed full, or a crash
                    // drained the formation
                    continue;
                }
                flush_batch(
                    &mut fleet.shards[shard],
                    shard,
                    frames,
                    &mut sim,
                    &mut churn,
                    &mut slo,
                    pair,
                    ev.t,
                )?;
            }
            EventKind::ScaleTick { shard } => {
                fleet.shards[shard].adapt_scale_tick(ev.t);
                let powered = fleet.shards[shard]
                    .adapt()
                    .and_then(|a| a.scaler.as_ref())
                    .map(|sc| sc.n_powered());
                if let (Some(o), Some(n)) =
                    (sim.obs_at(shard), powered)
                {
                    o.powered(ev.t, n);
                }
            }
            // campaign markers (DESIGN.md §15): the node-level effects
            // of a domain trip arrive as ordinary Crash/Rejoin events
            // from the merged plan; these only annotate the trace.
            EventKind::DomainMark { shard, domain, down } => {
                if let Some(o) = sim.obs_at(shard) {
                    o.domain_mark(ev.t, domain, down);
                }
            }
            EventKind::GwDown { shard } => {
                if let Some(o) = sim.obs_at(shard) {
                    o.gw_mark(ev.t, false);
                }
            }
            EventKind::GwUp { shard } => {
                if let Some(o) = sim.obs_at(shard) {
                    o.gw_mark(ev.t, true);
                }
            }
            // gateway failover: the dying (or ceding) shard releases a
            // node — everything queued on it drains through the
            // resilience policy, and the local replica goes dormant.
            EventKind::Release { shard, node: _, pair } => {
                let ch =
                    churn.as_mut().expect("campaign without churn");
                let gw = &mut fleet.shards[shard];
                gw.pool_mut().set_health_id(pair, false);
                if let Some(m) = gw.membership_mut() {
                    m.power_down(pair);
                }
                lose_queued(
                    gw, shard, &mut sim, &mut ch.state, &mut slo, pair,
                    None, ev.t,
                );
            }
            // adoption: the surviving shard wakes its dormant replica
            // of the orphan. Membership re-enters through Warming and
            // probes from scratch — stale-view realism, the adopting
            // gateway earns its view of the node (DESIGN.md §15). The
            // ground truth (`up`) still gates pool health: adopting a
            // node whose domain is down must not resurrect it.
            EventKind::Adopt { shard, node, pair, up } => {
                let ch =
                    churn.as_mut().expect("campaign without churn");
                ch.homes[node] = (shard, pair);
                let gw = &mut fleet.shards[shard];
                gw.pool_mut().set_health_id(pair, up);
                if up {
                    if let Some(n) = gw.pool_mut().get_id(pair) {
                        n.on_rejoin(ev.t);
                    }
                }
                if let Some(m) = gw.membership_mut() {
                    m.power_up(pair, ev.t);
                }
                if let Some(o) = sim.obs_at(shard) {
                    o.adopt(node, ev.t, i64::from(pair.0));
                }
            }
        }
    }

    if let Some(oc) = &fleet.obs {
        let wall_s =
            obs_t0.map_or(0.0, |t0| t0.elapsed().as_secs_f64());
        if let Some(shards) = sim.obs.take() {
            if let Err(e) =
                crate::obs::export_run(oc, "fleet", shards, wall_s)
            {
                eprintln!("[obs] export failed: {e}");
            }
        }
    }

    let node_fallbacks = fleet
        .shards
        .iter()
        .zip(&fallbacks_before)
        .map(|(g, &before)| g.fallbacks - before)
        .sum();
    let churn_report = churn.map(|c| {
        ChurnReport::collect(
            &c.state,
            fleet.shards.iter().filter_map(|g| g.membership()),
        )
    });
    let adapt_report = {
        let mut merged: Option<AdaptReport> = None;
        for g in &fleet.shards {
            if let Some(r) = g.adapt_report(sim.makespan_s) {
                match merged.as_mut() {
                    Some(m) => m.merge(&r),
                    None => merged = Some(r),
                }
            }
        }
        merged
    };
    Ok(FleetReport {
        per_shard: metrics,
        offered: frames.len(),
        dropped: sim.dropped,
        node_fallbacks,
        cross_shard_fallbacks: sim.cross_shard_fallbacks,
        makespan_s: sim.makespan_s,
        peak_in_flight: sim.peak_in_flight,
        churn: churn_report,
        slo: slo.map(|s| s.metrics),
        adapt: adapt_report,
        campaign: campaign_plan.map(|p| p.report),
    })
}

/// Walk the dispatch order until a shard admits request `idx`; spills
/// beyond the first shard count as cross-shard fallbacks only when
/// placement succeeds. Every visited shard runs its own estimator
/// (per-shard OB state), exactly like the pre-caching behavior.
fn try_place(
    fleet: &mut Fleet<'_>,
    frames: &[Scene],
    pseudo_gt: &[Vec<GtBox>],
    sim: &mut SimState,
    idx: usize,
    now_s: f64,
) -> Result<Option<(usize, RoutedRequest)>> {
    let order = fleet.dispatch.order(idx, fleet.n_sources, &sim.in_flight);
    for (attempt, &s) in order.iter().enumerate() {
        match fleet.shards[s].route_at(
            &frames[idx].image,
            pseudo_gt[idx].len(),
            now_s,
        ) {
            Ok(routed) => {
                sim.cross_shard_fallbacks += attempt;
                return Ok(Some((s, routed)));
            }
            Err(e) if e.is::<NoEndpoint>() => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(None)
}

/// [`try_place`] for a retry that already paid the estimator: walk the
/// dispatch order routing with the request's cached estimate + cost,
/// so no shard re-runs gateway-side inference (estimator caching).
fn try_place_with_estimate(
    fleet: &mut Fleet<'_>,
    sim: &mut SimState,
    idx: usize,
    estimate: usize,
    true_count: usize,
    cost: GatewayCost,
    now_s: f64,
) -> Result<Option<(usize, RoutedRequest)>> {
    let order = fleet.dispatch.order(idx, fleet.n_sources, &sim.in_flight);
    for (attempt, &s) in order.iter().enumerate() {
        match fleet.shards[s]
            .route_with_estimate(estimate, true_count, cost, now_s)
        {
            Ok(routed) => {
                sim.cross_shard_fallbacks += attempt;
                return Ok(Some((s, routed)));
            }
            Err(e) if e.is::<NoEndpoint>() => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(None)
}

/// Enqueue one pending copy. A finite EDF key inserts in deadline order
/// (stable: ties and infinite keys go after), which degenerates to the
/// exact pre-SLO FIFO when SLOs are off — every key is infinite then.
fn push_pending(q: &mut NodeQueue, p: Pending) {
    if p.slo.edf_s.is_finite() {
        if let Some(pos) =
            q.backlog.iter().position(|b| b.slo.edf_s > p.slo.edf_s)
        {
            q.backlog.insert(pos, p);
            return;
        }
    }
    q.backlog.push_back(p);
}

/// Under SLOs a retry scheduled past the request's deadline cannot
/// help: abandon the request (it counts as lost) and record the shed.
/// Otherwise schedule the re-dispatch normally.
fn retry_or_abandon(
    sim: &mut SimState,
    state: &mut ChurnState,
    slo: Option<&mut SloRt>,
    idx: usize,
    retry_t: f64,
) {
    match slo {
        Some(s) if retry_t > s.deadlines[idx] => {
            state.abandon(idx);
            s.shed(idx);
            if let Some(o) = sim.obs_spine() {
                o.abandon(idx, retry_t);
            }
        }
        _ => {
            if let Some(o) = sim.obs_spine() {
                o.retry(idx, retry_t);
            }
            sim.push(retry_t, EventKind::Retry(idx));
        }
    }
}

/// Admit one routed copy of request `idx` into its pair's FIFO on
/// `shard` at time `t` and try to start service.
#[allow(clippy::too_many_arguments)]
fn admit_copy(
    gw: &mut Gateway<'_>,
    shard: usize,
    frames: &[Scene],
    sim: &mut SimState,
    churn: &mut Option<ChurnDriver>,
    slo: &mut Option<SloRt>,
    routed: RoutedRequest,
    idx: usize,
    t: f64,
    hedge: bool,
    tag: SloTag,
) -> Result<()> {
    let admitted = gw.pool_mut().acquire_id(routed.pair_id);
    debug_assert!(admitted, "route() returned a pair without a free slot");
    sim.in_flight[shard] += 1;
    sim.total_in_flight += 1;
    sim.peak_in_flight = sim.peak_in_flight.max(sim.total_in_flight);
    let pair = routed.pair_id;
    let depth = {
        let q = sim.queues[shard].entry(pair).or_default();
        push_pending(
            q,
            Pending { routed, idx, arrival_s: t, hedge, slo: tag },
        );
        q.backlog.len() + usize::from(q.serving.is_some())
    };
    let n_if = sim.in_flight[shard];
    if let Some(o) = sim.obs_at(shard) {
        o.queue(idx, t, i64::from(pair.0), depth);
        o.in_flight(t, n_if);
    }
    start_next(gw, shard, frames, sim, churn, slo, pair, t)
}

/// Admit request `idx` into `(shard, pair)`'s forming batch (twin of
/// the openloop version): the queue slot is acquired NOW, and the batch
/// flushes when it fills, the window closes, or slack runs out.
#[allow(clippy::too_many_arguments)]
fn join_forming(
    gw: &mut Gateway<'_>,
    shard: usize,
    frames: &[Scene],
    sim: &mut SimState,
    churn: &mut Option<ChurnDriver>,
    slo: &mut Option<SloRt>,
    routed: RoutedRequest,
    tag: SloTag,
    idx: usize,
    t: f64,
) -> Result<()> {
    let admitted = gw.pool_mut().acquire_id(routed.pair_id);
    debug_assert!(admitted, "route() returned a pair without a free slot");
    sim.in_flight[shard] += 1;
    sim.total_in_flight += 1;
    sim.peak_in_flight = sim.peak_in_flight.max(sim.total_in_flight);
    let pair = routed.pair_id;
    let (window_s, max_batch) = {
        let s = slo.as_ref().expect("forming without slo");
        (s.cfg.batch_window_s, s.cfg.max_batch)
    };
    let latest_s = (tag.deadline_s
        - gw.predicted_completion_s(pair, t, 0.0))
    .max(t);
    let member_close = (t + window_s).min(latest_s);
    let (flush_now, close_s, size) = {
        let f = sim.forming[shard].entry(pair).or_default();
        f.members.push(Pending {
            routed,
            idx,
            arrival_s: t,
            hedge: false,
            slo: tag,
        });
        f.close_s = f.close_s.min(member_close);
        (
            f.members.len() >= max_batch || f.close_s <= t,
            f.close_s,
            f.members.len(),
        )
    };
    let n_if = sim.in_flight[shard];
    if let Some(o) = sim.obs_at(shard) {
        o.batch_form(idx, t, i64::from(pair.0), size);
        o.in_flight(t, n_if);
    }
    if flush_now {
        return flush_batch(gw, shard, frames, sim, churn, slo, pair, t);
    }
    // (re)schedule the close; earlier BatchClose events go stale
    let token = sim.seq;
    sim.forming[shard].get_mut(&pair).expect("just inserted").token =
        token;
    sim.push(close_s, EventKind::BatchClose { shard, pair, token });
    Ok(())
}

/// Flush `(shard, pair)`'s forming batch into its FIFO as one amortized
/// service train (twin of the openloop version).
#[allow(clippy::too_many_arguments)]
fn flush_batch(
    gw: &mut Gateway<'_>,
    shard: usize,
    frames: &[Scene],
    sim: &mut SimState,
    churn: &mut Option<ChurnDriver>,
    slo: &mut Option<SloRt>,
    pair: PairId,
    now_s: f64,
) -> Result<()> {
    let Some(f) = sim.forming[shard].remove(&pair) else {
        return Ok(());
    };
    if f.members.is_empty() {
        return Ok(());
    }
    if let Some(s) = slo.as_mut() {
        s.metrics.record_batch(f.members.len());
    }
    let edf_s = f
        .members
        .iter()
        .map(|m| m.slo.deadline_s)
        .fold(f64::INFINITY, f64::min);
    for (i, mut m) in f.members.into_iter().enumerate() {
        m.slo.edf_s = edf_s;
        m.slo.amortized = i > 0;
        m.slo.net = i == 0;
        // slots were acquired at formation entry — enqueue directly
        push_pending(sim.queues[shard].entry(pair).or_default(), m);
    }
    start_next(gw, shard, frames, sim, churn, slo, pair, now_s)
}

/// If `pair` (on shard `shard`) is idle and has backlog, begin serving
/// the head request at `now_s` and schedule its completion. Under
/// churn, a dispatch that discovers a dead node loses everything queued
/// there through the resilience policy and feeds the failure back to
/// the shard's membership as passive health evidence.
#[allow(clippy::too_many_arguments)]
fn start_next(
    gw: &mut Gateway<'_>,
    shard: usize,
    frames: &[Scene],
    sim: &mut SimState,
    churn: &mut Option<ChurnDriver>,
    slo: &mut Option<SloRt>,
    pair: PairId,
    now_s: f64,
) -> Result<()> {
    let q = sim.queues[shard]
        .get_mut(&pair)
        .expect("start_next on unknown queue");
    if q.serving.is_some() {
        return Ok(());
    }
    let Some(p) = q.backlog.pop_front() else {
        return Ok(());
    };
    let start_s = now_s.max(p.arrival_s + p.routed.cost.latency_s);
    let mut resp = match gw.serve(pair, &frames[p.idx].image, start_s) {
        Ok(r) => r,
        Err(e) if churn.is_some() && e.is::<NodeDown>() => {
            if let Some(m) = gw.membership_mut() {
                m.observe_dispatch_failure(pair, now_s);
            }
            let ch = churn.as_mut().expect("checked above");
            lose_queued(
                gw,
                shard,
                sim,
                &mut ch.state,
                slo,
                pair,
                Some(p),
                now_s,
            );
            return Ok(());
        }
        Err(e) => return Err(e),
    };
    if p.slo.amortized {
        // batch follower: the leader already paid the shared
        // preprocess; amortize it out of latency and energy
        let (save_s, save_mwh) = gw.batch_savings(pair);
        resp.latency_s = amortize(resp.latency_s, save_s);
        resp.energy_mwh = amortize(resp.energy_mwh, save_mwh);
    }
    let net_s = if p.slo.net { devices::NETWORK_S } else { 0.0 };
    if let Some(o) = sim.obs_at(shard) {
        o.serve(
            p.idx,
            start_s,
            i64::from(pair.0),
            resp.latency_s,
            resp.energy_mwh,
        );
    }
    let token = sim.seq;
    sim.push(
        start_s + resp.latency_s + net_s,
        EventKind::Completion { shard, pair, token },
    );
    // re-borrow: gw.serve() above needed &mut Gateway exclusively
    sim.queues[shard].get_mut(&pair).expect("queue vanished").serving =
        Some(InService {
            routed: p.routed,
            idx: p.idx,
            arrival_s: p.arrival_s,
            start_s,
            resp,
            token,
            hedge: p.hedge,
            slo: p.slo,
        });
    Ok(())
}

/// Drain every copy on `pair`'s queue (shard-local) — the in-service
/// request, an optional already-popped head, and the backlog —
/// releasing slots and feeding each loss through the resilience policy.
#[allow(clippy::too_many_arguments)]
fn lose_queued(
    gw: &mut Gateway<'_>,
    shard: usize,
    sim: &mut SimState,
    state: &mut ChurnState,
    slo: &mut Option<SloRt>,
    pair: PairId,
    head: Option<Pending>,
    now_s: f64,
) {
    let mut idxs: Vec<usize> = Vec::new();
    if let Some(q) = sim.queues[shard].get_mut(&pair) {
        if let Some(s) = q.serving.take() {
            idxs.push(s.idx);
        }
        if let Some(p) = &head {
            idxs.push(p.idx);
        }
        while let Some(p) = q.backlog.pop_front() {
            idxs.push(p.idx);
        }
    } else if let Some(p) = &head {
        idxs.push(p.idx);
    }
    // a forming batch on this pair holds slots too — it dies with the node
    if let Some(f) = sim.forming[shard].remove(&pair) {
        for p in f.members {
            idxs.push(p.idx);
        }
    }
    let lost_any = !idxs.is_empty();
    for idx in idxs {
        gw.pool_mut().release_id(pair);
        sim.in_flight[shard] -= 1;
        sim.total_in_flight -= 1;
        if let Some(o) = sim.obs_at(shard) {
            o.loss(idx, now_s, i64::from(pair.0));
        }
        match state.copy_lost(idx, now_s) {
            LossOutcome::RetryAt(t) => {
                retry_or_abandon(sim, state, slo.as_mut(), idx, t)
            }
            LossOutcome::Absorbed | LossOutcome::Lost => {}
        }
    }
    if lost_any {
        let n_if = sim.in_flight[shard];
        if let Some(o) = sim.obs_at(shard) {
            o.in_flight(now_s, n_if);
        }
    }
}

/// Hedge cancellation-on-first-response: pull request `idx`'s
/// still-pending copy off `sib`'s queue on `shard`. A copy caught
/// mid-service charges the energy accrued so far (pro-rata by elapsed
/// service time, its stale Completion dies on the token guard); a
/// queued copy charges nothing. Either way the slot frees immediately
/// and the ledger absorbs the copy as hedge waste, never a loss.
#[allow(clippy::too_many_arguments)]
fn cancel_sibling(
    gw: &mut Gateway<'_>,
    shard: usize,
    frames: &[Scene],
    sim: &mut SimState,
    churn: &mut Option<ChurnDriver>,
    slo: &mut Option<SloRt>,
    sib: PairId,
    idx: usize,
    now_s: f64,
) -> Result<()> {
    enum Hit {
        Serving(f64),
        Queued,
        Gone,
    }
    let hit = match sim.queues[shard].get_mut(&sib) {
        Some(q) => {
            if q.serving.as_ref().is_some_and(|x| x.idx == idx) {
                let sv = q.serving.take().expect("just matched");
                let frac = ((now_s - sv.start_s)
                    / sv.resp.latency_s.max(1e-12))
                .clamp(0.0, 1.0);
                Hit::Serving(sv.resp.energy_mwh * frac)
            } else if let Some(pos) =
                q.backlog.iter().position(|b| b.idx == idx)
            {
                q.backlog.remove(pos);
                Hit::Queued
            } else {
                Hit::Gone
            }
        }
        None => Hit::Gone,
    };
    let (partial, was_serving) = match hit {
        Hit::Serving(e) => (e, true),
        Hit::Queued => (0.0, false),
        Hit::Gone => return Ok(()), // crash-lost before the winner
    };
    gw.pool_mut().release_id(sib);
    sim.in_flight[shard] -= 1;
    sim.total_in_flight -= 1;
    let ch = churn.as_mut().expect("hedge without churn");
    ch.state.copy_cancelled(idx, partial);
    let n_if = sim.in_flight[shard];
    if let Some(o) = sim.obs_at(shard) {
        o.hedge_loss(idx, now_s, i64::from(sib.0), partial);
        o.in_flight(now_s, n_if);
    }
    if was_serving {
        start_next(gw, shard, frames, sim, churn, slo, sib, now_s)?;
    }
    Ok(())
}

/// Render a dataset up front and drive it through the fleet.
pub fn run_dataset(
    fleet: &mut Fleet<'_>,
    dataset: &Dataset,
    arrivals: &ArrivalProcess,
    seed: u64,
) -> Result<FleetReport> {
    let frames: Vec<Scene> = dataset.iter_scenes().collect();
    let gts: Vec<Vec<GtBox>> =
        frames.iter().map(|s| s.gt.clone()).collect();
    run_frames(fleet, &frames, &gts, arrivals, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::coco;
    use crate::gateway::router_by_name;

    fn engine() -> Engine {
        Engine::new(&crate::default_artifacts_dir()).unwrap()
    }

    fn base_store() -> ProfileStore {
        let mut rows = Vec::new();
        for g in 0..5 {
            rows.push(PairProfile {
                pair: PairKey::new("ssd_v1", "jetson_orin_nano"),
                group: g,
                map: 50.0,
                latency_s: 0.005,
                energy_mwh: 0.002,
            });
            rows.push(PairProfile {
                pair: PairKey::new("yolov8n", "pi5"),
                group: g,
                map: if g >= 2 { 75.0 } else { 51.0 },
                latency_s: 0.05,
                energy_mwh: 0.05,
            });
        }
        ProfileStore::new(rows)
    }

    fn build_fleet<'e>(
        e: &'e Engine,
        router: &str,
        cfg: &FleetConfig,
    ) -> Fleet<'e> {
        FleetBuilder::new(e, base_store())
            .build(router_by_name(router).unwrap(), 5.0, cfg)
            .unwrap()
    }

    #[test]
    fn builder_scales_to_200_nodes_over_8_shards() {
        let e = engine();
        let cfg = FleetConfig {
            n_nodes: 200,
            n_shards: 8,
            ..Default::default()
        };
        let fleet = build_fleet(&e, "LE", &cfg);
        assert_eq!(fleet.n_shards(), 8);
        assert_eq!(fleet.n_nodes(), 200);
        let mut all_pairs: Vec<PairKey> = Vec::new();
        for gw in fleet.shards() {
            let pairs = gw.store().pairs();
            assert_eq!(pairs.len(), 25, "round-robin partition");
            // every profiled node exists (and is healthy) in the pool
            for p in &pairs {
                assert!(gw.pool().is_healthy(p), "{p} missing from pool");
            }
            // 2 base pairs x 5 groups per node
            assert_eq!(gw.store().rows().len(), 25 * 5);
            all_pairs.extend(pairs);
        }
        let n = all_pairs.len();
        all_pairs.sort();
        all_pairs.dedup();
        assert_eq!(all_pairs.len(), n, "node identities must be unique");
        assert_eq!(n, 200);
    }

    #[test]
    fn builder_rejects_degenerate_shapes() {
        let e = engine();
        let b = FleetBuilder::new(&e, base_store());
        let spec = router_by_name("LE").unwrap();
        for cfg in [
            FleetConfig { n_shards: 0, ..Default::default() },
            FleetConfig { n_nodes: 2, n_shards: 4, ..Default::default() },
            FleetConfig { perturb: 1.5, ..Default::default() },
        ] {
            assert!(b.build(spec, 5.0, &cfg).is_err(), "{cfg:?}");
        }
    }

    #[test]
    fn low_rate_fleet_serves_everything_without_fallbacks() {
        let e = engine();
        let ds = coco::build(10, 5);
        let cfg = FleetConfig {
            n_nodes: 8,
            n_shards: 2,
            queue_capacity: 4,
            ..Default::default()
        };
        let mut fl = build_fleet(&e, "LE", &cfg);
        let report = run_dataset(
            &mut fl,
            &ds,
            &ArrivalProcess::Uniform { gap_s: 5.0 },
            3,
        )
        .unwrap();
        assert_eq!(report.offered, 10);
        assert_eq!(report.requests(), 10);
        assert_eq!(report.dropped, 0);
        assert_eq!(report.cross_shard_fallbacks, 0);
        assert_eq!(report.peak_in_flight, 1);
        assert_eq!(report.mean_queue_delay_s(), 0.0);
        assert!(report.makespan_s > 0.0);
        assert!(report.total_energy_mwh() > 0.0);
    }

    #[test]
    fn saturated_fleet_falls_back_across_shards_then_sheds() {
        let e = engine();
        let ds = coco::build(12, 13);
        // sticky dispatch + one source: every arrival targets the same
        // primary shard, so saturation must spill across shards before
        // anything is shed. Capacity 1 on 2x2 nodes = 4 total slots.
        let cfg = FleetConfig {
            n_nodes: 4,
            n_shards: 2,
            queue_capacity: 1,
            dispatch: DispatchPolicy::Sticky,
            n_sources: 1,
            ..Default::default()
        };
        let mut fl = build_fleet(&e, "LE", &cfg);
        let report = run_dataset(
            &mut fl,
            &ds,
            &ArrivalProcess::Uniform { gap_s: 1e-6 },
            2,
        )
        .unwrap();
        assert!(
            report.cross_shard_fallbacks > 0,
            "expected cross-shard spill"
        );
        assert!(report.dropped > 0, "expected load shedding");
        assert_eq!(report.requests() + report.dropped, report.offered);
        // both shards ended up serving traffic
        assert!(report.per_shard.iter().all(|m| m.requests > 0));
        // every acquired slot was released: the driver's O(1) counters
        // agree with the pools' ground-truth occupancy scan
        assert_eq!(
            fl.shards()
                .iter()
                .map(|g| g.pool().total_in_flight())
                .sum::<usize>(),
            0
        );
    }

    #[test]
    fn fleet_replays_bit_identically_from_seeds() {
        let e = engine();
        let ds = coco::build(16, 99);
        let run = |e: &Engine| {
            let cfg = FleetConfig {
                n_nodes: 12,
                n_shards: 3,
                queue_capacity: 2,
                ..Default::default()
            };
            let mut fl = build_fleet(e, "ED", &cfg);
            run_dataset(
                &mut fl,
                &ds,
                &ArrivalProcess::Poisson { rate_rps: 300.0 },
                17,
            )
            .unwrap()
            .to_json()
            .dump()
        };
        assert_eq!(run(&e), run(&e));
    }

    #[test]
    fn fleet_slo_runs_replay_bit_identically_with_slo_block() {
        let e = engine();
        let ds = coco::build(18, 55);
        let run = |e: &Engine| {
            let cfg = FleetConfig {
                n_nodes: 8,
                n_shards: 2,
                queue_capacity: 4,
                slo: Some(crate::workload::slo::SloConfig::default()),
                ..Default::default()
            };
            let mut fl = build_fleet(e, "ED", &cfg);
            run_dataset(
                &mut fl,
                &ds,
                &ArrivalProcess::Poisson { rate_rps: 250.0 },
                11,
            )
            .unwrap()
            .to_json()
            .dump()
        };
        let a = run(&e);
        assert_eq!(a, run(&e));
        assert!(a.contains("\"slo\""), "report must carry the slo block");
    }

    #[test]
    fn fleet_batching_forms_multi_request_trains() {
        use crate::workload::slo::{SloClass, SloConfig};
        let e = engine();
        let ds = coco::build(40, 47);
        // one loose class: nothing sheds, so the batch machinery is
        // exercised in isolation (capacity is generous for the same
        // reason — drops would confound the accounting).
        let slo = SloConfig {
            classes: vec![SloClass {
                name: "relaxed".to_string(),
                deadline_s: 1e9,
            }],
            batch_window_s: 0.02,
            max_batch: 4,
        };
        let cfg = FleetConfig {
            n_nodes: 8,
            n_shards: 2,
            queue_capacity: 64,
            slo: Some(slo),
            ..Default::default()
        };
        let mut fl = build_fleet(&e, "LE", &cfg);
        let report = run_dataset(
            &mut fl,
            &ds,
            &ArrivalProcess::Poisson { rate_rps: 400.0 },
            7,
        )
        .unwrap();
        assert_eq!(report.dropped, 0, "nothing should shed");
        assert_eq!(report.requests(), report.offered);
        let s = report.slo.as_ref().expect("slo metrics");
        assert!(
            s.mean_batch_size() > 1.5,
            "saturating arrivals must coalesce: mean batch {}",
            s.mean_batch_size()
        );
        assert!((s.overall_attainment_pct() - 100.0).abs() < 1e-9);
        // every slot released despite batch formation holding slots
        assert_eq!(
            fl.shards()
                .iter()
                .map(|g| g.pool().total_in_flight())
                .sum::<usize>(),
            0
        );
    }

    #[test]
    fn fleet_churn_crashes_lose_and_recover_deterministically() {
        // both the retry and hedge policies: crashes fire, every
        // request is accounted exactly once (served, shed, or lost —
        // hedged duplicates never double-count), replay is
        // bit-identical, and no slot leaks.
        let e = engine();
        let ds = coco::build(24, 33);
        for policy in [
            ResiliencePolicy::Retry { budget: 4 },
            ResiliencePolicy::Hedge,
        ] {
            let churn = ChurnConfig {
                mtbf_s: 0.05,
                mttr_s: 0.1,
                probe_interval_s: 0.02,
                probe_timeout_s: 0.01,
                suspect_after: 1,
                warmup_s: 0.05,
                policy,
                retry_backoff_s: 0.02,
                horizon_slack_s: 1.0,
                seed: 3,
                ..Default::default()
            };
            let run = |e: &Engine| {
                let cfg = FleetConfig {
                    n_nodes: 6,
                    n_shards: 2,
                    queue_capacity: 2,
                    churn: Some(churn.clone()),
                    ..Default::default()
                };
                let mut fl = build_fleet(e, "LE", &cfg);
                let report = run_dataset(
                    &mut fl,
                    &ds,
                    &ArrivalProcess::Poisson { rate_rps: 300.0 },
                    21,
                )
                .unwrap();
                // every slot released despite crashes mid-service
                assert_eq!(
                    fl.shards()
                        .iter()
                        .map(|g| g.pool().total_in_flight())
                        .sum::<usize>(),
                    0,
                    "{policy:?}"
                );
                report
            };
            let a = run(&e);
            let c = a.churn.as_ref().expect("churn report");
            assert!(c.crashes > 0, "{policy:?}: no crash within the run");
            assert_eq!(
                a.requests() + a.dropped + c.lost,
                a.offered,
                "{policy:?}: every request must be served, shed, or lost"
            );
            // bit-identical replay, churn block included
            let b = run(&e);
            assert_eq!(a.to_json().dump(), b.to_json().dump());
        }
    }

    #[test]
    fn drifting_fleet_diverges_deterministically_from_static() {
        // satellite: FleetConfig::drift -> EdgeNode::enable_drift had no
        // coverage. A drifting fleet must (a) replay bit-identically,
        // (b) diverge from the static fleet on the same workload, and
        // (c) give nodes distinct drift streams (per-node seeds differ).
        let e = engine();
        let ds = coco::build(30, 71);
        let run = |drift: Option<DriftConfig>| {
            let cfg = FleetConfig {
                n_nodes: 4,
                n_shards: 2,
                queue_capacity: 16,
                perturb: 0.0, // identical silicon: only drift differs
                drift,
                ..Default::default()
            };
            let mut fl = build_fleet(&e, "LE", &cfg);
            let report = run_dataset(
                &mut fl,
                &ds,
                &ArrivalProcess::Poisson { rate_rps: 500.0 },
                13,
            )
            .unwrap();
            let temps: Vec<f64> = fl
                .shards()
                .iter()
                .flat_map(|g| g.pool().nodes())
                .filter(|n| n.requests_served > 0)
                .map(|n| n.temperature())
                .collect();
            (report.to_json().dump(), temps)
        };
        let (stat, stat_temps) = run(None);
        let (drift_a, temps_a) = run(Some(DriftConfig::default()));
        let (drift_b, temps_b) = run(Some(DriftConfig::default()));
        assert_eq!(drift_a, drift_b, "drift must be deterministic");
        assert_eq!(temps_a, temps_b);
        assert_ne!(
            stat, drift_a,
            "drifting fleet must diverge from the static one"
        );
        // static nodes report zero temperature; drifting served nodes
        // heat up, and with identical silicon + per-node seeds their
        // trajectories must differ
        assert!(stat_temps.iter().all(|&t| t == 0.0));
        assert!(temps_a.iter().any(|&t| t > 0.0));
        assert!(temps_a.len() >= 2, "need >= 2 served nodes");
        let first = temps_a[0];
        assert!(
            temps_a.iter().any(|&t| (t - first).abs() > 1e-12),
            "per-node drift seeds must differ: {temps_a:?}"
        );
    }

    #[test]
    fn dispatch_orders_are_deterministic_and_complete() {
        use std::collections::BTreeSet;
        let in_flight = [3usize, 0, 5, 1];
        for d in [
            DispatchPolicy::Hash,
            DispatchPolicy::LeastLoaded,
            DispatchPolicy::Sticky,
        ] {
            let o = d.order(9, 4, &in_flight);
            let mut sorted = o.clone();
            sorted.sort();
            assert_eq!(sorted, vec![0, 1, 2, 3], "{d:?} must cover");
            assert_eq!(o, d.order(9, 4, &in_flight), "{d:?} deterministic");
        }
        // least-loaded visits shards in load order
        assert_eq!(
            DispatchPolicy::LeastLoaded.order(0, 4, &in_flight),
            vec![1, 3, 0, 2]
        );
        // sticky: requests from the same source share an order
        assert_eq!(
            DispatchPolicy::Sticky.order(2, 4, &in_flight),
            DispatchPolicy::Sticky.order(6, 4, &in_flight)
        );
        // hash spreads primaries across every shard eventually
        let mut seen = BTreeSet::new();
        for idx in 0..64 {
            seen.insert(DispatchPolicy::Hash.order(idx, 4, &in_flight)[0]);
        }
        assert_eq!(seen.len(), 4);
        // parsing round-trips the labels
        for d in [
            DispatchPolicy::Hash,
            DispatchPolicy::LeastLoaded,
            DispatchPolicy::Sticky,
        ] {
            assert_eq!(DispatchPolicy::parse(d.label()), Some(d));
        }
        assert_eq!(DispatchPolicy::parse("wat"), None);
    }

    #[test]
    fn report_imbalance_and_json_shape() {
        let mut m0 = RunMetrics::new("s0");
        m0.requests = 6;
        let mut m1 = RunMetrics::new("s1");
        m1.requests = 2;
        let report = FleetReport {
            per_shard: vec![m0, m1],
            offered: 9,
            dropped: 1,
            node_fallbacks: 0,
            cross_shard_fallbacks: 3,
            makespan_s: 4.0,
            peak_in_flight: 5,
            churn: None,
            slo: None,
            adapt: None,
            campaign: None,
        };
        assert_eq!(report.requests(), 8);
        assert!((report.shard_imbalance() - 1.5).abs() < 1e-12);
        assert!((report.goodput_rps() - 2.0).abs() < 1e-12);
        let j = report.to_json();
        assert_eq!(j.req("requests").unwrap().as_usize(), Some(8));
        assert_eq!(j.req("dropped").unwrap().as_usize(), Some(1));
        assert_eq!(
            j.req("cross_shard_fallbacks").unwrap().as_usize(),
            Some(3)
        );
        assert_eq!(j.req("shards").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn adaptive_fleet_replays_and_merges_shard_reports() {
        // the full adapt path at fleet scale: drifting nodes feed each
        // shard's telemetry, per-shard scalers tick on the shared
        // clock, and the report block merges across shards — all of it
        // bit-identical on replay.
        let e = engine();
        let ds = coco::build(30, 63);
        let run = |adapt: Option<AdaptConfig>| {
            let cfg = FleetConfig {
                n_nodes: 6,
                n_shards: 2,
                queue_capacity: 8,
                drift: Some(DriftConfig::default()),
                adapt,
                ..Default::default()
            };
            let mut fl = build_fleet(&e, "ED", &cfg);
            run_dataset(
                &mut fl,
                &ds,
                &ArrivalProcess::Poisson { rate_rps: 200.0 },
                27,
            )
            .unwrap()
        };
        let a = run(Some(AdaptConfig::default()));
        let b = run(Some(AdaptConfig::default()));
        assert_eq!(a.to_json().dump(), b.to_json().dump());
        let r = a.adapt.as_ref().expect("adapt report");
        assert!(r.telemetry_samples > 0, "no telemetry at fleet scale");
        assert_eq!(r.telemetry_samples, a.requests());
        // the merged static baseline covers every synthesized node
        assert_eq!(r.static_node_s, 6.0 * a.makespan_s);
        // without adapt the report must not carry the block
        let plain = run(None);
        assert!(plain.adapt.is_none());
        assert!(!plain.to_json().dump().contains("\"adapt\""));
    }

    #[test]
    fn campaign_domain_outages_crash_whole_domains_and_replay() {
        // pure-campaign churn (mtbf = inf): every crash comes from a
        // domain trip, so the crash count is exactly domain_size per
        // outage, the ledger stays exact, and replay is bit-identical.
        let e = engine();
        let ds = coco::build(24, 41);
        let churn = ChurnConfig {
            mtbf_s: f64::INFINITY,
            mttr_s: 0.1,
            probe_interval_s: 0.02,
            probe_timeout_s: 0.01,
            suspect_after: 1,
            warmup_s: 0.05,
            policy: ResiliencePolicy::Retry { budget: 4 },
            retry_backoff_s: 0.02,
            horizon_slack_s: 1.0,
            seed: 5,
            ..Default::default()
        };
        let camp = CampaignConfig {
            domain_size: 3,
            domain_mtbf_s: 0.05,
            domain_mttr_s: 0.05,
            ..Default::default()
        };
        let run = |e: &Engine| {
            let cfg = FleetConfig {
                n_nodes: 6,
                n_shards: 2,
                queue_capacity: 2,
                churn: Some(churn.clone()),
                campaign: Some(camp.clone()),
                ..Default::default()
            };
            let mut fl = build_fleet(e, "LE", &cfg);
            let r = run_dataset(
                &mut fl,
                &ds,
                &ArrivalProcess::Poisson { rate_rps: 300.0 },
                9,
            )
            .unwrap();
            assert_eq!(
                fl.shards()
                    .iter()
                    .map(|g| g.pool().total_in_flight())
                    .sum::<usize>(),
                0
            );
            r
        };
        let a = run(&e);
        let cr = a.campaign.as_ref().expect("campaign report");
        assert_eq!(cr.domains, 2);
        assert_eq!(cr.domain_size, 3);
        assert!(cr.domain_outages > 0, "no outage within the run");
        assert_eq!(cr.gw_kills, 0, "gateway process disabled");
        let c = a.churn.as_ref().expect("churn report");
        assert_eq!(
            c.crashes,
            3 * cr.domain_outages,
            "a trip crashes every domain member at one instant"
        );
        assert_eq!(
            a.requests() + a.dropped + c.lost,
            a.offered,
            "every request must be served, shed, or lost"
        );
        let b = run(&e);
        let (ja, jb) = (a.to_json().dump(), b.to_json().dump());
        assert_eq!(ja, jb);
        assert!(ja.contains("\"campaign\""));
    }

    #[test]
    fn campaign_gateway_failover_rehomes_orphans_and_recovers() {
        // gateway kills only: orphans re-home to survivors, recovery
        // re-adopts, and the request ledger survives the whole dance.
        let e = engine();
        let ds = coco::build(30, 59);
        let churn = ChurnConfig {
            mtbf_s: f64::INFINITY,
            policy: ResiliencePolicy::Retry { budget: 6 },
            retry_backoff_s: 0.02,
            horizon_slack_s: 1.0,
            seed: 7,
            ..Default::default()
        };
        let camp = CampaignConfig {
            domain_mtbf_s: f64::INFINITY,
            gateway_mtbf_s: 0.06,
            gateway_mttr_s: 0.08,
            ..Default::default()
        };
        let run = |e: &Engine| {
            let cfg = FleetConfig {
                n_nodes: 6,
                n_shards: 3,
                queue_capacity: 2,
                churn: Some(churn.clone()),
                campaign: Some(camp.clone()),
                ..Default::default()
            };
            let mut fl = build_fleet(e, "LE", &cfg);
            let r = run_dataset(
                &mut fl,
                &ds,
                &ArrivalProcess::Poisson { rate_rps: 250.0 },
                31,
            )
            .unwrap();
            assert_eq!(
                fl.shards()
                    .iter()
                    .map(|g| g.pool().total_in_flight())
                    .sum::<usize>(),
                0
            );
            r
        };
        let a = run(&e);
        let cr = a.campaign.as_ref().expect("campaign report");
        assert_eq!(cr.domain_outages, 0, "domain process disabled");
        assert!(cr.gw_kills > 0, "no gateway kill within the run");
        assert!(cr.adoptions > 0, "kills must re-home orphans");
        let c = a.churn.as_ref().expect("churn report");
        assert_eq!(a.requests() + a.dropped + c.lost, a.offered);
        assert_eq!(a.to_json().dump(), run(&e).to_json().dump());
    }

    #[test]
    fn campaign_validation_rejects_unsupported_combos() {
        let e = engine();
        let b = FleetBuilder::new(&e, base_store());
        let spec = router_by_name("LE").unwrap();
        // campaign without churn
        let no_churn = FleetConfig {
            n_nodes: 4,
            n_shards: 2,
            campaign: Some(CampaignConfig::default()),
            ..Default::default()
        };
        assert!(b.build(spec, 5.0, &no_churn).is_err());
        // gateway campaign x autoscaler
        let gw_adapt = FleetConfig {
            n_nodes: 4,
            n_shards: 2,
            churn: Some(ChurnConfig::default()),
            adapt: Some(AdaptConfig::default()),
            campaign: Some(CampaignConfig {
                gateway_mtbf_s: 10.0,
                ..Default::default()
            }),
            ..Default::default()
        };
        assert!(b.build(spec, 5.0, &gw_adapt).is_err());
        // domain-only campaigns compose with adapt just fine
        let dom_adapt = FleetConfig {
            n_nodes: 4,
            n_shards: 2,
            churn: Some(ChurnConfig::default()),
            adapt: Some(AdaptConfig::default()),
            campaign: Some(CampaignConfig::default()),
            ..Default::default()
        };
        assert!(b.build(spec, 5.0, &dom_adapt).is_ok());
    }

    #[test]
    fn hedge_cancellation_cuts_waste_and_keeps_the_ledger_exact() {
        // gentle load so both runs schedule identically on the winner
        // side: cancellation must strictly cut hedge waste (losers are
        // charged pro-rata, not in full) without changing what serves.
        let e = engine();
        let ds = coco::build(12, 83);
        let run = |cancel: bool| {
            let churn = ChurnConfig {
                mtbf_s: f64::INFINITY, // no crashes: isolate hedging
                policy: ResiliencePolicy::Hedge,
                hedge_cancel: cancel,
                horizon_slack_s: 1.0,
                seed: 11,
                ..Default::default()
            };
            let cfg = FleetConfig {
                n_nodes: 4,
                n_shards: 2,
                queue_capacity: 8,
                churn: Some(churn),
                ..Default::default()
            };
            let mut fl = build_fleet(&e, "LE", &cfg);
            let r = run_dataset(
                &mut fl,
                &ds,
                &ArrivalProcess::Uniform { gap_s: 0.5 },
                19,
            )
            .unwrap();
            assert_eq!(
                fl.shards()
                    .iter()
                    .map(|g| g.pool().total_in_flight())
                    .sum::<usize>(),
                0,
                "cancel={cancel}: leaked slots"
            );
            r
        };
        let off = run(false);
        let on = run(true);
        for r in [&off, &on] {
            let c = r.churn.as_ref().expect("churn report");
            assert!(c.hedged > 0, "no hedges dispatched");
            assert_eq!(c.lost, 0, "no crashes, nothing may be lost");
            assert_eq!(r.requests() + r.dropped + c.lost, r.offered);
        }
        assert_eq!(off.requests(), on.requests(), "winners unaffected");
        let w_off = off.churn.as_ref().unwrap().wasted_energy_mwh;
        let w_on = on.churn.as_ref().unwrap().wasted_energy_mwh;
        assert!(w_off > 0.0, "run-to-completion hedges waste energy");
        assert!(
            w_on < w_off,
            "cancellation must cut waste: on={w_on} off={w_off}"
        );
        // replay pins the cancellation path bit-identically
        assert_eq!(run(true).to_json().dump(), on.to_json().dump());
    }
}
