//! Parallel per-shard event engine (DESIGN.md §13).
//!
//! [`run_frames_threads`] drives the same simulation as
//! [`super::run_frames`], but partitions the shard gateways over worker
//! threads, each with its own PJRT [`Engine`] and its own local event
//! heap. The merged trace is **bit-identical** to the sequential
//! engine's — the golden-trace corpus is replayed at several thread
//! counts by `tests/parallel_equiv.rs` to pin that.
//!
//! # Protocol
//!
//! Events split into two classes:
//!
//! * **Spine events** (arrivals, retries) need a *global* decision: the
//!   dispatch policy ranks every shard by its live in-flight count and
//!   the losing shards' estimators must not run. They live in one
//!   shared heap inside [`Coord`].
//! * **Local events** (completions, batch closes, crashes, rejoins,
//!   probes, scale ticks) touch exactly one shard. Each lives in its
//!   owning worker's private heap.
//!
//! Every event carries a key `(t, cls, seq)` that reproduces the
//! sequential engine's `(t, seq)` total order: `cls 0` events
//! (arrivals + the statically scheduled crash/rejoin/probe/scale
//! trains) are assigned their *exact* sequential sequence numbers at
//! setup, so cross-class ties resolve precisely as the shared-heap
//! engine would. `cls 1` events (completions, batch closes, probe
//! results, retries) are created at runtime; within one worker their
//! per-worker counter preserves the sequential relative order, and the
//! `cls 0 < cls 1` rule matches the sequential invariant that
//! setup-time events always outrank runtime events at equal time.
//! (The one approximation: a runtime local event and a retry at the
//! *bit-identical* `f64` time resolve local-first — a measure-zero tie
//! the equivalence suite has never hit.)
//!
//! A worker may commit (pop + process) its local head `v` only when
//!
//! 1. `v` precedes the spine head (the **gate**) in key order, and
//! 2. under the retry policy, `v.t ≤ min(other workers' watermarks) +
//!    retry_backoff_s` — every retry a concurrent worker can still
//!    produce lands at `its watermark + backoff`, so nothing can be
//!    inserted before `v` (the **lookahead** rule). A worker's
//!    watermark is a lower bound on its next commit: the time it is
//!    currently processing, the head it is waiting to commit, the gate
//!    it is parked at, or `∞` when it has nothing — publishing the
//!    *pending* head (not just the last commit) is what keeps two
//!    waiting workers from stalling on each other's stale clocks.
//!
//! When every worker's local head has reached the gate, the workers
//! park (`at_gate`) and the spine head becomes a **walk**: the dispatch
//! order is computed from the exact barrier state, then each visited
//! shard's *owner* runs its router (per-shard estimator + policy RNG
//! state stay single-threaded). The winner finalizes the admission —
//! SLO gate, hedging, batch formation — while everyone else stays
//! parked, so global counters (`peak_in_flight`, SLO metrics, churn
//! accounting) observe exactly the sequential interleaving.
//!
//! Hedge-waste energy is the one order-sensitive `f64` sum that crosses
//! workers: losing completions log `(t, energy)` and the final sum is
//! replayed in time order, reproducing the sequential accumulation.
//!
//! # Send/Sync boundary
//!
//! Workers share only `&SharedRo` (frames, ground truth, deadlines,
//! configs — all immutable) and the single `Mutex<Coord>`. Everything
//! touching a [`Gateway`] — estimator state, policy RNGs, node pools,
//! drift, queues, metrics — is owned by exactly one worker and never
//! crosses the boundary; per-worker `Engine`s are created inside each
//! thread. `ProfileStore` shares its interned [`super::PairKey`] table
//! via `Arc`, which is the only shared allocation inside worker state.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap};
use std::path::Path;
use std::sync::Mutex;

use anyhow::Result;

use crate::adapt::AdaptReport;
use crate::dataset::{GtBox, Scene};
use crate::devices;
use crate::estimators::GatewayCost;
use crate::gateway::{
    amortize, Gateway, NoEndpoint, RoutedRequest, RouterSpec,
};
use crate::lifecycle::campaign::{CampaignPlan, PlanEvent};
use crate::lifecycle::{
    self, ChurnReport, ChurnState, LossOutcome, Membership,
    ResiliencePolicy,
};
use crate::metrics::{RunMetrics, SloMetrics};
use crate::nodes::NodeDown;
use crate::obs::{ObsShard, SPINE_SHARD};
use crate::router::{PairId, ProfileStore};
use crate::runtime::Engine;
use crate::workload::openloop::ArrivalProcess;
use crate::workload::slo::{SloConfig, SloTag};

use super::{
    base_models, campaign_gateway_mode, push_pending, synth_nodes,
    wire_shard, DispatchPolicy, FleetBuilder, FleetConfig, FleetReport,
    Forming, InService, NodeQueue, NodeSynth, Pending,
};

/// Everything [`run_frames_threads`] needs besides the fleet config:
/// where to find AOT artifacts (each worker opens its own engine
/// there), the base profile store to synthesize from, and the router
/// wiring that [`super::FleetBuilder::build`] would receive.
pub struct ParallelFleetSpec<'a> {
    pub artifacts_dir: &'a Path,
    pub base: &'a ProfileStore,
    pub spec: RouterSpec,
    pub delta_map: f64,
}

/// One event on the shared spine: an arrival or a retry, the two kinds
/// that need the global dispatch decision. Min-order: `(t, retry,
/// idx)` — arrivals carry their exact sequential sequence number
/// (`idx`), retries tie-break deterministically on the request index.
#[derive(Clone, Copy, Debug)]
struct SEvent {
    t: f64,
    retry: bool,
    idx: usize,
}

impl SEvent {
    fn key(&self) -> (f64, u8, u64) {
        (self.t, self.retry as u8, self.idx as u64)
    }
}

impl PartialEq for SEvent {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other).is_eq()
    }
}
impl Eq for SEvent {}
impl PartialOrd for SEvent {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for SEvent {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t
            .total_cmp(&other.t)
            .then((self.retry as u8).cmp(&(other.retry as u8)))
            .then(self.idx.cmp(&other.idx))
    }
}

/// A worker-local event. `cls 0` carries an exact global sequence
/// number assigned at setup; `cls 1` carries the worker's own counter.
struct LEvent {
    t: f64,
    cls: u8,
    seq: u64,
    kind: LKind,
}

enum LKind {
    /// Ground-truth crash of synthesized node `node`, homed on
    /// `shard` at the event's time (re-homing is a pure function of
    /// the campaign plan, so the home is resolved at setup).
    Crash { node: usize, shard: usize },
    /// Ground-truth rejoin of synthesized node `node`.
    Rejoin { node: usize, shard: usize },
    /// Shard `shard`'s periodic health probe fires.
    Probe { shard: usize },
    /// Shard `shard`'s autoscaler decision tick.
    ScaleTick { shard: usize },
    /// The in-service request on `pair` completes (stale if `token`
    /// no longer matches).
    Completion { shard: usize, pair: PairId, token: u64 },
    /// Probe responses reach shard `shard`'s membership view.
    ProbeResult { shard: usize, responses: Vec<bool> },
    /// A batch formation window closes (stale if `token` mismatches).
    BatchClose { shard: usize, pair: PairId, token: u64 },
    /// Campaign trace marker: domain outage flip (DESIGN.md §15).
    DomainMark { shard: usize, domain: usize, down: bool },
    /// Campaign trace marker: shard `shard`'s gateway dies.
    GwDown { shard: usize },
    /// Campaign trace marker: shard `shard`'s gateway recovers.
    GwUp { shard: usize },
    /// Gateway failover: `shard` releases `node` — queued work drains
    /// through the resilience policy, the local replica goes dormant.
    Release { shard: usize, node: usize },
    /// Gateway failover: `shard` adopts `node` (ground truth `up`).
    Adopt { shard: usize, node: usize, up: bool },
}

impl LEvent {
    fn key(&self) -> (f64, u8, u64) {
        (self.t, self.cls, self.seq)
    }
}

impl PartialEq for LEvent {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other).is_eq()
    }
}
impl Eq for LEvent {}
impl PartialOrd for LEvent {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for LEvent {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t
            .total_cmp(&other.t)
            .then(self.cls.cmp(&other.cls))
            .then(self.seq.cmp(&other.seq))
    }
}

/// Does the local key `l` strictly precede the gate key `g`?
///
/// Exact except for one measure-zero tie: two `cls 1` events (a
/// runtime local vs. a retry) at the bit-identical time resolve
/// local-first, where the sequential engine would compare their true
/// creation sequence numbers.
fn local_before_gate(l: (f64, u8, u64), g: (f64, u8, u64)) -> bool {
    match l.0.total_cmp(&g.0) {
        std::cmp::Ordering::Less => true,
        std::cmp::Ordering::Greater => false,
        std::cmp::Ordering::Equal => match l.1.cmp(&g.1) {
            std::cmp::Ordering::Less => true,
            std::cmp::Ordering::Greater => false,
            std::cmp::Ordering::Equal => l.1 != 0 || l.2 < g.2,
        },
    }
}

/// The spine head being serviced: each shard in `order` is visited by
/// its owning worker until one admits the request.
struct Walk {
    t: f64,
    idx: usize,
    retry: bool,
    /// Cached `(estimate, gateway cost)` for retries that placed once.
    cached: Option<(usize, GatewayCost)>,
    order: Vec<usize>,
    pos: usize,
    /// The owner of `order[pos]` is routing right now.
    visiting: bool,
    /// A winner is finalizing the admission; everyone stays parked.
    finalizing: bool,
}

/// Churn state shared across workers (behind the coordinator mutex).
struct ChurnShared {
    state: ChurnState,
    /// Estimator cache: `(estimate, cost)` paid at first placement.
    est: Vec<Option<(usize, GatewayCost)>>,
    /// `(primary, hedge)` pair ids of each request's live hedge
    /// split, for cancellation-on-first-response. Both copies live on
    /// the winning shard, so the cancel itself is worker-local.
    hedge: Vec<Option<(PairId, PairId)>>,
    hedge_cancel: bool,
}

/// All cross-worker mutable state, behind one mutex. Held briefly for
/// local-event bookkeeping; held across a walk's admission only while
/// every other worker is parked at the gate.
struct Coord {
    spine: BinaryHeap<Reverse<SEvent>>,
    walk: Option<Walk>,
    /// Per-worker watermark: a lower bound on the worker's next commit
    /// time (its pending local head, the gate time when parked, `∞`
    /// when idle).
    clocks: Vec<f64>,
    /// Worker `w`'s local head has reached the spine head.
    at_gate: Vec<bool>,
    /// Worker `w`'s local heap is empty.
    idle: Vec<bool>,
    in_flight: Vec<usize>,
    total_in_flight: usize,
    peak_in_flight: usize,
    makespan_s: f64,
    dropped: usize,
    cross_shard_fallbacks: usize,
    churn: Option<ChurnShared>,
    slo: Option<SloMetrics>,
    /// `(t, energy)` of losing hedge completions — summed in time
    /// order at the end (see module docs).
    waste: Vec<(f64, f64)>,
    /// Spine obs collector ([`SPINE_SHARD`]) for run-level events:
    /// placement sheds, retries, abandons — all decided under this
    /// lock, exactly where the sequential engine records them.
    obs_spine: Option<ObsShard>,
    done: bool,
}

impl Coord {
    /// Push a retry onto the spine. Every parked worker re-parks
    /// against the (possibly smaller) new head, refreshing its clock.
    fn push_retry(&mut self, t: f64, idx: usize) {
        self.spine.push(Reverse(SEvent { t, retry: true, idx }));
        self.at_gate.iter_mut().for_each(|f| *f = false);
    }
}

/// Immutable run context shared by reference across workers.
struct SharedRo<'a> {
    frames: &'a [Scene],
    pseudo_gt: &'a [Vec<GtBox>],
    dispatch: DispatchPolicy,
    n_sources: usize,
    w_count: usize,
    /// Resilience policy, when churn is configured.
    policy: Option<ResiliencePolicy>,
    /// `Some(backoff)` iff the policy can schedule retries — enables
    /// the lookahead commit rule.
    retry_lookahead: Option<f64>,
    probe_timeout_s: f64,
    slo: Option<SloRo>,
}

struct SloRo {
    cfg: SloConfig,
    deadlines: Vec<f64>,
}

/// Per-worker, per-owned-shard state: the gateway plus the node queues,
/// forming batches, and metrics the sequential engine keeps in its
/// shard-indexed vectors.
struct ShardSlot<'e> {
    s: usize,
    gw: Gateway<'e>,
    queues: BTreeMap<PairId, NodeQueue>,
    forming: BTreeMap<PairId, Forming>,
    metrics: RunMetrics,
    fallbacks_before: usize,
    /// Pool-ordered node identities (probe snapshots); empty without
    /// churn.
    pairs: Vec<PairId>,
    /// This shard's obs collector (`None` = obs off). Shard-local
    /// events fold here in the worker's commit order, which the
    /// protocol guarantees equals the sequential engine's per-shard
    /// event order — so the merged export is byte-identical.
    obs: Option<ObsShard>,
}

/// A worker's private event machinery.
struct Wsim {
    heap: BinaryHeap<Reverse<LEvent>>,
    /// Runtime (`cls 1`) sequence counter; doubles as the token space
    /// for completions and batch closes, mirroring the sequential
    /// engine's `token = sim.seq`.
    ord: u64,
}

impl Wsim {
    fn push_dynamic(&mut self, t: f64, kind: LKind) {
        let seq = self.ord;
        self.ord += 1;
        self.heap.push(Reverse(LEvent { t, cls: 1, seq, kind }));
    }
}

/// What each worker hands back per owned shard, in global shard order.
struct ShardOut {
    s: usize,
    metrics: RunMetrics,
    fallbacks: usize,
    membership: Option<Membership>,
    adapt: Option<AdaptReport>,
    obs: Option<ObsShard>,
}

/// Sets `done` when dropped — including during a panic unwind, where a
/// poisoned lock is skipped (the poison itself unblocks the others).
struct StopOnDrop<'a>(&'a Mutex<Coord>);

impl Drop for StopOnDrop<'_> {
    fn drop(&mut self) {
        if let Ok(mut c) = self.0.lock() {
            c.done = true;
        }
    }
}

/// [`super::run_frames`] with the engine selected by `cfg.threads`:
/// `<= 1` builds the fleet on one engine and runs the sequential
/// shared-heap driver unchanged; `> 1` runs the per-shard worker
/// protocol above. Reports are identical either way.
pub fn run_frames_threads(
    p: &ParallelFleetSpec<'_>,
    cfg: &FleetConfig,
    frames: &[Scene],
    pseudo_gt: &[Vec<GtBox>],
    arrivals: &ArrivalProcess,
    seed: u64,
) -> Result<FleetReport> {
    let w_count = cfg.threads.max(1).min(cfg.n_shards.max(1));
    if w_count <= 1 {
        let engine = Engine::new(p.artifacts_dir)?;
        let mut fleet = FleetBuilder::new(&engine, p.base.clone())
            .build(p.spec, p.delta_map, cfg)?;
        return super::run_frames(
            &mut fleet, frames, pseudo_gt, arrivals, seed,
        );
    }
    anyhow::ensure!(frames.len() == pseudo_gt.len());
    let obs_t0 =
        cfg.obs.as_ref().map(|_| std::time::Instant::now());
    // validations (and the per-node synthesis) run up front on the
    // main thread, so config errors surface before any thread spawns
    let synth = synth_nodes(p.base, cfg)?;
    if let Some(c) = &cfg.slo {
        anyhow::ensure!(
            !c.classes.is_empty(),
            "slo config needs at least one deadline class"
        );
    }
    let models: Vec<String> = base_models(p.base)
        .into_iter()
        .map(str::to_string)
        .collect();

    let arrival_times = arrivals.times(frames.len(), seed);
    let horizon_s = arrival_times.last().copied().unwrap_or(0.0)
        + cfg
            .churn
            .as_ref()
            .map(|c| c.horizon_slack_s)
            .unwrap_or(0.0);
    let slo_ro = cfg.slo.clone().map(|c| SloRo {
        deadlines: arrival_times
            .iter()
            .enumerate()
            .map(|(i, &t)| c.deadline_for(i, t))
            .collect(),
        cfg: c,
    });

    let mut spine = BinaryHeap::new();
    for (idx, &t) in arrival_times.iter().enumerate() {
        spine.push(Reverse(SEvent { t, retry: false, idx }));
    }
    // statically scheduled local events carry their exact sequential
    // sequence numbers: arrivals took 0..n, then the failure timeline,
    // then each shard's probe train, then each shard's scale ticks —
    // the precise `sim.push` order of the sequential engine's setup
    let mut statics: Vec<Vec<LEvent>> =
        (0..w_count).map(|_| Vec::new()).collect();
    let mut gseq = arrival_times.len() as u64;
    let push_static = |statics: &mut Vec<Vec<LEvent>>,
                           gseq: &mut u64,
                           shard: usize,
                           t: f64,
                           kind: LKind| {
        statics[shard % w_count]
            .push(LEvent { t, cls: 0, seq: *gseq, kind });
        *gseq += 1;
    };
    // the campaign plan is a pure function of the configs, so this
    // rebuild is bit-identical to the sequential engine's (and its
    // report rides along for free)
    let campaign_plan = match (&cfg.churn, &cfg.campaign) {
        (Some(c), Some(camp)) => Some(CampaignPlan::build(
            cfg.n_nodes,
            cfg.n_shards,
            horizon_s,
            c,
            camp,
        )?),
        (None, Some(_)) => {
            anyhow::bail!("campaign requires a churn config")
        }
        _ => None,
    };
    if let Some(c) = &cfg.churn {
        match &campaign_plan {
            Some(plan) => {
                for pe in &plan.events {
                    let (shard, kind) = match *pe {
                        PlanEvent::Truth { t, node, up } => {
                            // the home at `t` is where the sequential
                            // engine's runtime `homes[node]` points
                            // when this event commits
                            let shard = plan.home_at(node, t);
                            let kind = if up {
                                LKind::Rejoin { node, shard }
                            } else {
                                LKind::Crash { node, shard }
                            };
                            (shard, kind)
                        }
                        PlanEvent::DomainMark {
                            shard,
                            domain,
                            down,
                            ..
                        } => {
                            (shard, LKind::DomainMark {
                                shard,
                                domain,
                                down,
                            })
                        }
                        PlanEvent::GwDown { shard, .. } => {
                            (shard, LKind::GwDown { shard })
                        }
                        PlanEvent::GwUp { shard, .. } => {
                            (shard, LKind::GwUp { shard })
                        }
                        PlanEvent::Release { shard, node, .. } => {
                            (shard, LKind::Release { shard, node })
                        }
                        PlanEvent::Adopt {
                            shard, node, up, ..
                        } => (shard, LKind::Adopt { shard, node, up }),
                    };
                    push_static(
                        &mut statics,
                        &mut gseq,
                        shard,
                        pe.t(),
                        kind,
                    );
                }
            }
            None => {
                for ev in lifecycle::failure_schedule(
                    cfg.n_nodes,
                    horizon_s,
                    c,
                ) {
                    let shard = ev.node % cfg.n_shards;
                    let kind = if ev.up {
                        LKind::Rejoin { node: ev.node, shard }
                    } else {
                        LKind::Crash { node: ev.node, shard }
                    };
                    push_static(
                        &mut statics,
                        &mut gseq,
                        shard,
                        ev.t,
                        kind,
                    );
                }
            }
        }
        let gap = c.probe_interval_s.max(1e-6);
        for s in 0..cfg.n_shards {
            let mut t = gap;
            while t < horizon_s {
                push_static(
                    &mut statics,
                    &mut gseq,
                    s,
                    t,
                    LKind::Probe { shard: s },
                );
                t += gap;
            }
        }
    }
    if let Some(a) = &cfg.adapt {
        if a.scale {
            let gap = a.scale_interval_s.max(1e-6);
            for s in 0..cfg.n_shards {
                let mut t = gap;
                while t < horizon_s {
                    push_static(
                        &mut statics,
                        &mut gseq,
                        s,
                        t,
                        LKind::ScaleTick { shard: s },
                    );
                    t += gap;
                }
            }
        }
    }

    let ro = SharedRo {
        frames,
        pseudo_gt,
        dispatch: cfg.dispatch,
        n_sources: cfg.n_sources.max(1),
        w_count,
        policy: cfg.churn.as_ref().map(|c| c.policy),
        retry_lookahead: cfg.churn.as_ref().and_then(|c| {
            matches!(c.policy, ResiliencePolicy::Retry { .. })
                .then_some(c.retry_backoff_s)
        }),
        probe_timeout_s: cfg
            .churn
            .as_ref()
            .map(|c| c.probe_timeout_s)
            .unwrap_or(0.0),
        slo: slo_ro,
    };
    let coord = Mutex::new(Coord {
        spine,
        walk: None,
        clocks: vec![0.0; w_count],
        at_gate: vec![false; w_count],
        idle: vec![false; w_count],
        in_flight: vec![0; cfg.n_shards],
        total_in_flight: 0,
        peak_in_flight: 0,
        makespan_s: 0.0,
        dropped: 0,
        cross_shard_fallbacks: 0,
        churn: cfg.churn.as_ref().map(|c| ChurnShared {
            state: ChurnState::new(
                frames.len(),
                c.policy,
                c.retry_backoff_s,
            ),
            est: vec![None; frames.len()],
            hedge: vec![None; frames.len()],
            hedge_cancel: c.hedge_cancel,
        }),
        slo: ro
            .slo
            .as_ref()
            .map(|s| SloMetrics::new(&s.cfg.class_names())),
        waste: Vec::new(),
        obs_spine: cfg
            .obs
            .as_ref()
            .map(|c| ObsShard::new(c, SPINE_SHARD, frames.len())),
        done: false,
    });

    let mut per_worker: Vec<Vec<NodeSynth>> =
        (0..w_count).map(|_| Vec::new()).collect();
    if campaign_gateway_mode(cfg) {
        // gateway campaigns pre-provision every node on every shard
        // (twins: same rows, same seed — see `FleetBuilder::build`);
        // each worker materializes the full node set per owned shard
        for ns in synth {
            for s in 0..cfg.n_shards {
                per_worker[s % w_count].push(NodeSynth {
                    shard: s,
                    pair: ns.pair.clone(),
                    dev: ns.dev.clone(),
                    synth_idx: ns.synth_idx,
                    rows: ns.rows.clone(),
                });
            }
        }
    } else {
        for ns in synth {
            per_worker[ns.shard % w_count].push(ns);
        }
    }

    let results: Vec<Result<Vec<ShardOut>>> =
        std::thread::scope(|sc| {
            let handles: Vec<_> = per_worker
                .into_iter()
                .zip(statics)
                .enumerate()
                .map(|(w, (synth, statics))| {
                    let (ro, coord, models, artifacts_dir) =
                        (&ro, &coord, &models, p.artifacts_dir);
                    let (spec, delta_map) = (p.spec, p.delta_map);
                    sc.spawn(move || {
                        // on ANY exit — normal, error, or panic while
                        // not holding the lock — mark the run done so
                        // the other workers' loops terminate instead
                        // of spinning forever
                        let _stop = StopOnDrop(coord);
                        worker_run(
                            w,
                            artifacts_dir,
                            spec,
                            delta_map,
                            cfg,
                            ro,
                            coord,
                            synth,
                            statics,
                            models,
                        )
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("fleet worker panicked"))
                .collect()
        });
    let mut outs: Vec<ShardOut> = Vec::with_capacity(cfg.n_shards);
    for r in results {
        outs.extend(r?);
    }
    outs.sort_by_key(|o| o.s);

    let mut coord = coord.into_inner().expect("coordinator poisoned");
    if let Some(oc) = &cfg.obs {
        // per-shard collectors in shard order, spine last — the same
        // logical layout the sequential engine exports, so the merged
        // files are byte-identical at any thread count
        let mut shards: Vec<ObsShard> =
            outs.iter_mut().filter_map(|o| o.obs.take()).collect();
        shards.extend(coord.obs_spine.take());
        let wall_s =
            obs_t0.map_or(0.0, |t0| t0.elapsed().as_secs_f64());
        if let Err(e) =
            crate::obs::export_run(oc, "fleet", shards, wall_s)
        {
            eprintln!("[obs] export failed: {e}");
        }
    }
    let mut waste = coord.waste;
    waste.sort_by(|a, b| a.0.total_cmp(&b.0));
    let churn_report = coord.churn.map(|mut ch| {
        // replay the losing-hedge energy in time order: the sequential
        // engine accumulates it at (nondecreasing) completion times
        for &(_, e) in &waste {
            ch.state.wasted_energy_mwh += e;
        }
        ChurnReport::collect(
            &ch.state,
            outs.iter().filter_map(|o| o.membership.as_ref()),
        )
    });
    let adapt_report = {
        let mut merged: Option<AdaptReport> = None;
        for o in &outs {
            if let Some(r) = &o.adapt {
                match merged.as_mut() {
                    Some(m) => m.merge(r),
                    None => merged = Some(r.clone()),
                }
            }
        }
        merged
    };
    Ok(FleetReport {
        per_shard: outs.iter().map(|o| o.metrics.clone()).collect(),
        offered: frames.len(),
        dropped: coord.dropped,
        node_fallbacks: outs.iter().map(|o| o.fallbacks).sum(),
        cross_shard_fallbacks: coord.cross_shard_fallbacks,
        makespan_s: coord.makespan_s,
        peak_in_flight: coord.peak_in_flight,
        churn: churn_report,
        slo: coord.slo,
        adapt: adapt_report,
        campaign: campaign_plan.map(|p| p.report),
    })
}

/// One worker: build the owned shards on a private engine, then drive
/// the protocol loop until the run completes (or any worker errors).
#[allow(clippy::too_many_arguments)]
fn worker_run(
    w: usize,
    artifacts_dir: &Path,
    spec: RouterSpec,
    delta_map: f64,
    cfg: &FleetConfig,
    ro: &SharedRo<'_>,
    coord: &Mutex<Coord>,
    synth: Vec<NodeSynth>,
    statics: Vec<LEvent>,
    models: &[String],
) -> Result<Vec<ShardOut>> {
    let engine = Engine::new(artifacts_dir)?;
    // group the owned synthesis entries by shard, preserving synthesis
    // order within each shard (= the sequential engine's pool order)
    let mut grouped: BTreeMap<usize, Vec<NodeSynth>> = BTreeMap::new();
    for ns in synth {
        grouped.entry(ns.shard).or_default().push(ns);
    }
    let mut slots: Vec<ShardSlot<'_>> = Vec::with_capacity(grouped.len());
    let mut homes: BTreeMap<usize, (usize, PairId)> = BTreeMap::new();
    for (s, group) in grouped {
        let mut nodes = Vec::with_capacity(group.len());
        let mut rows = Vec::new();
        let mut keys = Vec::with_capacity(group.len());
        for ns in &group {
            rows.extend(ns.rows.iter().cloned());
            keys.push((ns.synth_idx, ns.pair.clone()));
            nodes.push(ns.make_node(&engine, cfg)?);
        }
        let mut gw =
            wire_shard(&engine, spec, delta_map, cfg, s, nodes, rows);
        let all_shards = campaign_gateway_mode(cfg);
        for (idx, key) in keys {
            let id = gw
                .store()
                .id_of(&key)
                .expect("synthesized pair interned in its shard");
            homes.insert(idx, (s, id));
            if all_shards && idx % cfg.n_shards != s {
                // park the foreign replica dormant, exactly as the
                // sequential builder does: only an Adopt wakes it
                gw.pool_mut().set_health_id(id, false);
                if let Some(m) = gw.membership_mut() {
                    m.power_down(id);
                }
            }
        }
        let pairs = if cfg.churn.is_some() {
            gw.pool()
                .nodes()
                .iter()
                .map(|n| {
                    gw.store()
                        .id_of(&n.pair)
                        .expect("shard pair missing from its table")
                })
                .collect()
        } else {
            Vec::new()
        };
        slots.push(ShardSlot {
            s,
            fallbacks_before: gw.fallbacks,
            metrics: RunMetrics::new(&format!("{}-s{s}", spec.name)),
            queues: BTreeMap::new(),
            forming: BTreeMap::new(),
            pairs,
            obs: cfg
                .obs
                .as_ref()
                .map(|c| ObsShard::new(c, s as u32, ro.frames.len())),
            gw,
        });
    }
    let model_refs: Vec<&str> =
        models.iter().map(|m| m.as_str()).collect();
    engine.preload(&model_refs)?;

    let mut wsim = Wsim { heap: BinaryHeap::new(), ord: 0 };
    for ev in statics {
        wsim.heap.push(Reverse(ev));
    }

    loop {
        let mut c = coord.lock().expect("coordinator poisoned");
        if c.done {
            break;
        }
        // --- walk phase: the spine head is being serviced ---
        if let Some(wk) = c.walk.as_mut() {
            let my_turn = !wk.finalizing
                && !wk.visiting
                && wk.order[wk.pos] % ro.w_count == w;
            if !my_turn {
                drop(c);
                std::thread::yield_now();
                continue;
            }
            wk.visiting = true;
            let (t, idx, retry, cached, shard) =
                (wk.t, wk.idx, wk.retry, wk.cached, wk.order[wk.pos]);
            drop(c);
            let i = slot_of(&slots, shard);
            let sl = &mut slots[i];
            // route outside the lock: estimator + policy RNG state are
            // this worker's own
            let res = match (retry, cached) {
                (true, Some((estimate, cost))) => {
                    sl.gw.route_with_estimate(
                        estimate,
                        ro.pseudo_gt[idx].len(),
                        cost,
                        t,
                    )
                }
                _ => sl.gw.route_at(
                    &ro.frames[idx].image,
                    ro.pseudo_gt[idx].len(),
                    t,
                ),
            };
            match res {
                Ok(routed) => {
                    {
                        let mut c =
                            coord.lock().expect("coordinator poisoned");
                        let wk = c.walk.as_mut().expect("walk vanished");
                        wk.finalizing = true;
                        let pos = wk.pos;
                        c.cross_shard_fallbacks += pos;
                    }
                    // everyone else stays parked until the walk
                    // resolves, so the admission below observes (and
                    // mutates) exactly the sequential barrier state
                    let fin = if retry {
                        finalize_retry(
                            sl, &mut wsim, ro, coord, routed, idx, t,
                        )
                    } else {
                        finalize_arrival(
                            sl, &mut wsim, ro, coord, routed, idx, t,
                        )
                    };
                    let mut c =
                        coord.lock().expect("coordinator poisoned");
                    c.walk = None;
                    c.at_gate.iter_mut().for_each(|f| *f = false);
                    if let Err(e) = fin {
                        c.done = true;
                        return Err(e);
                    }
                }
                Err(e) if e.is::<NoEndpoint>() => {
                    let mut c =
                        coord.lock().expect("coordinator poisoned");
                    let wk = c.walk.as_mut().expect("walk vanished");
                    wk.visiting = false;
                    wk.pos += 1;
                    if wk.pos == wk.order.len() {
                        walk_exhausted(&mut c, ro, idx, retry, t);
                        c.walk = None;
                        c.at_gate.iter_mut().for_each(|f| *f = false);
                    }
                }
                Err(e) => {
                    let mut c =
                        coord.lock().expect("coordinator poisoned");
                    c.done = true;
                    return Err(e);
                }
            }
            continue;
        }
        // --- local phase: commit, park, or go idle ---
        let local = wsim.heap.peek().map(|Reverse(e)| e.key());
        let gate = c.spine.peek().map(|Reverse(e)| e.key());
        match (local, gate) {
            (None, None) => {
                c.idle[w] = true;
                c.clocks[w] = f64::INFINITY;
                if c.idle.iter().all(|&i| i) {
                    // no local work, no spine, no walk: the run is over
                    c.done = true;
                    break;
                }
                drop(c);
                std::thread::yield_now();
            }
            (l, Some(g))
                if l.map(|lk| !local_before_gate(lk, g))
                    .unwrap_or(true) =>
            {
                // nothing to do before the spine head: park at the gate
                c.idle[w] = l.is_none();
                c.at_gate[w] = true;
                c.clocks[w] = g.0;
                if c.at_gate.iter().all(|&f| f) {
                    create_walk(&mut c, ro);
                }
                drop(c);
                std::thread::yield_now();
            }
            (Some(lk), _) => {
                // local head precedes the gate: publish it as this
                // worker's watermark FIRST — while we hold out below,
                // our heap cannot change (only this worker pushes into
                // it, and walks need us parked), so our next commit is
                // exactly `lk` and publishing it keeps two stalled
                // workers from waiting on each other's stale clocks
                c.idle[w] = false;
                c.at_gate[w] = false;
                c.clocks[w] = lk.0;
                // under the retry policy also wait out the lookahead
                // window: a concurrent worker whose watermark is `u`
                // can still insert a retry at `u + backoff`
                if let Some(backoff) = ro.retry_lookahead {
                    let min_other = c
                        .clocks
                        .iter()
                        .enumerate()
                        .filter(|&(x, _)| x != w)
                        .map(|(_, &t)| t)
                        .fold(f64::INFINITY, f64::min);
                    if lk.0 > min_other + backoff {
                        drop(c);
                        std::thread::yield_now();
                        continue;
                    }
                }
                drop(c);
                let Reverse(ev) =
                    wsim.heap.pop().expect("peeked local head");
                if let Err(e) = handle_local(
                    &mut slots, &mut wsim, &homes, ro, coord, ev,
                ) {
                    let mut c =
                        coord.lock().expect("coordinator poisoned");
                    c.done = true;
                    return Err(e);
                }
            }
        }
    }

    // the run is complete: makespan is final, assemble per-shard output
    let makespan_s =
        coord.lock().expect("coordinator poisoned").makespan_s;
    Ok(slots
        .into_iter()
        .map(|sl| ShardOut {
            s: sl.s,
            fallbacks: sl.gw.fallbacks - sl.fallbacks_before,
            membership: sl.gw.membership().cloned(),
            adapt: sl.gw.adapt_report(makespan_s),
            obs: sl.obs,
            metrics: sl.metrics,
        })
        .collect())
}

/// Pop the spine head and open a walk over the dispatch order computed
/// from the exact barrier state. Requires every worker parked.
fn create_walk(c: &mut Coord, ro: &SharedRo<'_>) {
    let Some(Reverse(head)) = c.spine.pop() else {
        return;
    };
    let order =
        ro.dispatch.order(head.idx, ro.n_sources, &c.in_flight);
    let cached = if head.retry {
        c.churn.as_ref().expect("retry without churn").est[head.idx]
    } else {
        None
    };
    c.walk = Some(Walk {
        t: head.t,
        idx: head.idx,
        retry: head.retry,
        cached,
        order,
        pos: 0,
        visiting: false,
        finalizing: false,
    });
}

/// Every shard refused the spine request: apply the same terminal path
/// as the sequential engine's placement-failure arms.
fn walk_exhausted(
    c: &mut Coord,
    ro: &SharedRo<'_>,
    idx: usize,
    retry: bool,
    t: f64,
) {
    if retry || ro.retry_lookahead.is_some() {
        let outcome = c
            .churn
            .as_mut()
            .expect("retry policy without churn")
            .state
            .placement_failed(idx, t);
        if let LossOutcome::RetryAt(rt) = outcome {
            retry_or_abandon(c, ro, idx, rt);
        }
    } else {
        c.dropped += 1;
        // an overflow drop misses its SLO too
        if let Some(sr) = ro.slo.as_ref() {
            if let Some(m) = c.slo.as_mut() {
                m.record_shed(sr.cfg.class_of(idx));
            }
        }
        if let Some(o) = c.obs_spine.as_mut() {
            o.shed(idx, t);
        }
    }
}

/// Under SLOs a retry scheduled past the deadline cannot help: abandon
/// and record the shed; otherwise push the re-dispatch onto the spine.
fn retry_or_abandon(
    c: &mut Coord,
    ro: &SharedRo<'_>,
    idx: usize,
    retry_t: f64,
) {
    match ro.slo.as_ref() {
        Some(sr) if retry_t > sr.deadlines[idx] => {
            c.churn
                .as_mut()
                .expect("retry without churn")
                .state
                .abandon(idx);
            if let Some(m) = c.slo.as_mut() {
                m.record_shed(sr.cfg.class_of(idx));
            }
            if let Some(o) = c.obs_spine.as_mut() {
                o.abandon(idx, retry_t);
            }
        }
        _ => {
            if let Some(o) = c.obs_spine.as_mut() {
                o.retry(idx, retry_t);
            }
            c.push_retry(retry_t, idx);
        }
    }
}

/// Index of the slot owning global shard `shard`.
fn slot_of(slots: &[ShardSlot<'_>], shard: usize) -> usize {
    slots
        .iter()
        .position(|sl| sl.s == shard)
        .expect("event for unowned shard")
}

/// Dispatch one committed local event — the worker-side twin of the
/// sequential engine's event arms.
fn handle_local(
    slots: &mut [ShardSlot<'_>],
    wsim: &mut Wsim,
    homes: &BTreeMap<usize, (usize, PairId)>,
    ro: &SharedRo<'_>,
    coord: &Mutex<Coord>,
    ev: LEvent,
) -> Result<()> {
    let t = ev.t;
    match ev.kind {
        LKind::Completion { shard, pair, token } => {
            let i = slot_of(slots, shard);
            on_completion(&mut slots[i], wsim, ro, coord, pair, token, t)
        }
        LKind::Crash { node, shard } => {
            let pair = homes
                .get(&node)
                .expect("crash for unowned node")
                .1;
            let i = slot_of(slots, shard);
            let sl = &mut slots[i];
            {
                let mut c = coord.lock().expect("coordinator poisoned");
                c.churn
                    .as_mut()
                    .expect("crash without churn")
                    .state
                    .crashes += 1;
            }
            if let Some(o) = sl.obs.as_mut() {
                o.crash(t);
            }
            sl.gw.pool_mut().set_health_id(pair, false);
            if let Some(m) = sl.gw.membership_mut() {
                m.ground_truth_changed(pair, false, t);
            }
            lose_queued(sl, ro, coord, pair, None, t);
            Ok(())
        }
        LKind::Rejoin { node, shard } => {
            let pair = homes
                .get(&node)
                .expect("rejoin for unowned node")
                .1;
            let i = slot_of(slots, shard);
            let sl = &mut slots[i];
            sl.gw.pool_mut().set_health_id(pair, true);
            if let Some(n) = sl.gw.pool_mut().get_id(pair) {
                n.on_rejoin(t);
            }
            if let Some(m) = sl.gw.membership_mut() {
                m.ground_truth_changed(pair, true, t);
            }
            if let Some(o) = sl.obs.as_mut() {
                o.rejoin(t);
            }
            Ok(())
        }
        LKind::Probe { shard } => {
            let sl = &slots[slot_of(slots, shard)];
            let responses: Vec<bool> = sl
                .pairs
                .iter()
                .map(|&p| sl.gw.pool().is_healthy_id(p))
                .collect();
            wsim.push_dynamic(
                t + ro.probe_timeout_s,
                LKind::ProbeResult { shard, responses },
            );
            Ok(())
        }
        LKind::ProbeResult { shard, responses } => {
            let i = slot_of(slots, shard);
            let sl = &mut slots[i];
            let m = sl
                .gw
                .membership_mut()
                .expect("churn shard lost its membership");
            for (&p, up) in sl.pairs.iter().zip(&responses) {
                m.observe_probe(p, *up, t);
            }
            Ok(())
        }
        LKind::BatchClose { shard, pair, token } => {
            let i = slot_of(slots, shard);
            let sl = &mut slots[i];
            if sl.forming.get(&pair).map(|f| f.token) != Some(token) {
                // superseded: a later member rescheduled the close,
                // the batch already flushed full, or a crash drained
                // the formation
                return Ok(());
            }
            flush_batch(sl, wsim, ro, coord, pair, t)
        }
        LKind::ScaleTick { shard } => {
            let i = slot_of(slots, shard);
            slots[i].gw.adapt_scale_tick(t);
            let powered = slots[i]
                .gw
                .adapt()
                .and_then(|a| a.scaler.as_ref())
                .map(|sc| sc.n_powered());
            if let (Some(o), Some(n)) =
                (slots[i].obs.as_mut(), powered)
            {
                o.powered(t, n);
            }
            Ok(())
        }
        // campaign markers: the node-level effects of a domain trip
        // arrive as ordinary Crash/Rejoin events from the merged plan
        LKind::DomainMark { shard, domain, down } => {
            let i = slot_of(slots, shard);
            if let Some(o) = slots[i].obs.as_mut() {
                o.domain_mark(t, domain, down);
            }
            Ok(())
        }
        LKind::GwDown { shard } => {
            let i = slot_of(slots, shard);
            if let Some(o) = slots[i].obs.as_mut() {
                o.gw_mark(t, false);
            }
            Ok(())
        }
        LKind::GwUp { shard } => {
            let i = slot_of(slots, shard);
            if let Some(o) = slots[i].obs.as_mut() {
                o.gw_mark(t, true);
            }
            Ok(())
        }
        LKind::Release { shard, node } => {
            let pair = homes
                .get(&node)
                .expect("release for unowned node")
                .1;
            let i = slot_of(slots, shard);
            let sl = &mut slots[i];
            sl.gw.pool_mut().set_health_id(pair, false);
            if let Some(m) = sl.gw.membership_mut() {
                m.power_down(pair);
            }
            lose_queued(sl, ro, coord, pair, None, t);
            Ok(())
        }
        LKind::Adopt { shard, node, up } => {
            let pair = homes
                .get(&node)
                .expect("adopt for unowned node")
                .1;
            let i = slot_of(slots, shard);
            let sl = &mut slots[i];
            sl.gw.pool_mut().set_health_id(pair, up);
            if up {
                if let Some(n) = sl.gw.pool_mut().get_id(pair) {
                    n.on_rejoin(t);
                }
            }
            if let Some(m) = sl.gw.membership_mut() {
                m.power_up(pair, t);
            }
            if let Some(o) = sl.obs.as_mut() {
                o.adopt(node, t, i64::from(pair.0));
            }
            Ok(())
        }
    }
}

/// The in-service request on `(slot, pair)` completes at `t`.
fn on_completion(
    sl: &mut ShardSlot<'_>,
    wsim: &mut Wsim,
    ro: &SharedRo<'_>,
    coord: &Mutex<Coord>,
    pair: PairId,
    token: u64,
    t: f64,
) -> Result<()> {
    let q = sl
        .queues
        .get_mut(&pair)
        .expect("completion for unknown queue");
    if q.serving.as_ref().map(|x| x.token) != Some(token) {
        // in-service request was lost to a crash after this completion
        // was scheduled — stale event
        debug_assert!(
            ro.policy.is_some(),
            "stale completion without churn"
        );
        return Ok(());
    }
    let done = q.serving.take().expect("token just matched");
    sl.gw.pool_mut().release_id(pair);
    // energy + arrival captured before `done.resp` is consumed by
    // `finish_with_network` below
    let (e2e_s, e_mwh) = (t - done.arrival_s, done.resp.energy_mwh);
    let (winner, n_if) = {
        let mut c = coord.lock().expect("coordinator poisoned");
        c.in_flight[sl.s] -= 1;
        c.total_in_flight -= 1;
        c.makespan_s = c.makespan_s.max(t);
        let winner = match c.churn.as_mut() {
            // energy is accounted through the time-ordered waste log
            // (f64 sums are order-sensitive), so pass 0 here
            Some(ch) => {
                ch.state.copy_completed(done.idx, 0.0, done.hedge)
            }
            None => true,
        };
        if !winner {
            c.waste.push((t, done.resp.energy_mwh));
        }
        if winner {
            if let Some(m) = c.slo.as_mut() {
                let sr = ro.slo.as_ref().expect("slo metrics without cfg");
                m.record_completion(
                    done.slo.class,
                    t <= sr.deadlines[done.idx],
                );
            }
        }
        (winner, c.in_flight[sl.s])
    };
    if let Some(o) = sl.obs.as_mut() {
        o.in_flight(t, n_if);
    }
    if winner {
        let queue_delay_s = (done.start_s
            - (done.arrival_s + done.routed.cost.latency_s))
            .max(0.0);
        // batch followers rode the leader's transfer
        let net_s = if done.slo.net { devices::NETWORK_S } else { 0.0 };
        sl.gw.finish_with_network(
            &done.routed,
            done.resp,
            &ro.pseudo_gt[done.idx],
            queue_delay_s,
            net_s,
            &mut sl.metrics,
        );
        let on_time = match ro.slo.as_ref() {
            Some(sr) => t <= sr.deadlines[done.idx],
            None => true,
        };
        if let Some(o) = sl.obs.as_mut() {
            o.finish(
                done.idx,
                t,
                i64::from(pair.0),
                e2e_s,
                e_mwh,
                on_time,
            );
        }
    } else if let Some(o) = sl.obs.as_mut() {
        // a hedge loser burned energy without producing the answer:
        // attribute the waste where it ran
        o.hedge_loss(done.idx, t, i64::from(pair.0), e_mwh);
    }
    // cancellation-on-first-response: the winning copy's completion
    // cancels the in-flight sibling, freeing its slot NOW and charging
    // only accrued energy. Both copies live on this shard, so the
    // cancel itself is worker-local; only the ledger goes via the lock.
    let sib = if winner {
        let mut c = coord.lock().expect("coordinator poisoned");
        match c.churn.as_mut() {
            Some(ch) if ch.hedge_cancel => ch.hedge[done.idx]
                .take()
                .map(|(p, h)| if done.hedge { p } else { h }),
            _ => None,
        }
    } else {
        None
    };
    if let Some(sib) = sib {
        cancel_sibling(sl, wsim, ro, coord, sib, done.idx, t)?;
    }
    start_next(sl, wsim, ro, coord, pair, t)
}

/// Hedge cancellation-on-first-response: pull request `idx`'s
/// still-pending copy off `sib`'s queue — the worker-local twin of the
/// sequential `cancel_sibling`. A copy caught mid-service charges the
/// energy accrued so far (through the time-ordered waste log, like all
/// cross-worker energy); a queued copy charges nothing.
fn cancel_sibling(
    sl: &mut ShardSlot<'_>,
    wsim: &mut Wsim,
    ro: &SharedRo<'_>,
    coord: &Mutex<Coord>,
    sib: PairId,
    idx: usize,
    now_s: f64,
) -> Result<()> {
    enum Hit {
        Serving(f64),
        Queued,
        Gone,
    }
    let hit = match sl.queues.get_mut(&sib) {
        Some(q) => {
            if q.serving.as_ref().is_some_and(|x| x.idx == idx) {
                let sv = q.serving.take().expect("just matched");
                let frac = ((now_s - sv.start_s)
                    / sv.resp.latency_s.max(1e-12))
                .clamp(0.0, 1.0);
                Hit::Serving(sv.resp.energy_mwh * frac)
            } else if let Some(pos) =
                q.backlog.iter().position(|b| b.idx == idx)
            {
                q.backlog.remove(pos);
                Hit::Queued
            } else {
                Hit::Gone
            }
        }
        None => Hit::Gone,
    };
    let (partial, was_serving) = match hit {
        Hit::Serving(e) => (e, true),
        Hit::Queued => (0.0, false),
        Hit::Gone => return Ok(()), // crash-lost before the winner
    };
    sl.gw.pool_mut().release_id(sib);
    let n_if = {
        let mut c = coord.lock().expect("coordinator poisoned");
        c.in_flight[sl.s] -= 1;
        c.total_in_flight -= 1;
        // energy goes through the time-ordered waste log (f64 sums
        // are order-sensitive), so the ledger sees 0 here
        c.churn
            .as_mut()
            .expect("hedge without churn")
            .state
            .copy_cancelled(idx, 0.0);
        c.waste.push((now_s, partial));
        c.in_flight[sl.s]
    };
    if let Some(o) = sl.obs.as_mut() {
        o.hedge_loss(idx, now_s, i64::from(sib.0), partial);
        o.in_flight(now_s, n_if);
    }
    if was_serving {
        start_next(sl, wsim, ro, coord, sib, now_s)?;
    }
    Ok(())
}

/// If `pair` is idle and has backlog, begin serving the head request
/// (engine call outside the lock — the parallelism win) and schedule
/// its completion.
fn start_next(
    sl: &mut ShardSlot<'_>,
    wsim: &mut Wsim,
    ro: &SharedRo<'_>,
    coord: &Mutex<Coord>,
    pair: PairId,
    now_s: f64,
) -> Result<()> {
    let q = sl
        .queues
        .get_mut(&pair)
        .expect("start_next on unknown queue");
    if q.serving.is_some() {
        return Ok(());
    }
    let Some(p) = q.backlog.pop_front() else {
        return Ok(());
    };
    let start_s = now_s.max(p.arrival_s + p.routed.cost.latency_s);
    let mut resp =
        match sl.gw.serve(pair, &ro.frames[p.idx].image, start_s) {
            Ok(r) => r,
            Err(e) if ro.policy.is_some() && e.is::<NodeDown>() => {
                if let Some(m) = sl.gw.membership_mut() {
                    m.observe_dispatch_failure(pair, now_s);
                }
                lose_queued(sl, ro, coord, pair, Some(p), now_s);
                return Ok(());
            }
            Err(e) => return Err(e),
        };
    if p.slo.amortized {
        // batch follower: the leader already paid the shared
        // preprocess; amortize it out of latency and energy
        let (save_s, save_mwh) = sl.gw.batch_savings(pair);
        resp.latency_s = amortize(resp.latency_s, save_s);
        resp.energy_mwh = amortize(resp.energy_mwh, save_mwh);
    }
    let net_s = if p.slo.net { devices::NETWORK_S } else { 0.0 };
    if let Some(o) = sl.obs.as_mut() {
        o.serve(
            p.idx,
            start_s,
            i64::from(pair.0),
            resp.latency_s,
            resp.energy_mwh,
        );
    }
    let token = wsim.ord;
    wsim.push_dynamic(
        start_s + resp.latency_s + net_s,
        LKind::Completion { shard: sl.s, pair, token },
    );
    // re-borrow: gw.serve() above needed &mut Gateway exclusively
    sl.queues.get_mut(&pair).expect("queue vanished").serving =
        Some(InService {
            routed: p.routed,
            idx: p.idx,
            arrival_s: p.arrival_s,
            start_s,
            resp,
            token,
            hedge: p.hedge,
            slo: p.slo,
        });
    Ok(())
}

/// Drain every copy on `pair`'s queue — the in-service request, an
/// optional already-popped head, and the backlog — releasing slots and
/// feeding each loss through the resilience policy.
fn lose_queued(
    sl: &mut ShardSlot<'_>,
    ro: &SharedRo<'_>,
    coord: &Mutex<Coord>,
    pair: PairId,
    head: Option<Pending>,
    now_s: f64,
) {
    let mut idxs: Vec<usize> = Vec::new();
    if let Some(q) = sl.queues.get_mut(&pair) {
        if let Some(s) = q.serving.take() {
            idxs.push(s.idx);
        }
        if let Some(p) = &head {
            idxs.push(p.idx);
        }
        while let Some(p) = q.backlog.pop_front() {
            idxs.push(p.idx);
        }
    } else if let Some(p) = &head {
        idxs.push(p.idx);
    }
    // a forming batch on this pair holds slots too — it dies with the
    // node
    if let Some(f) = sl.forming.remove(&pair) {
        for p in f.members {
            idxs.push(p.idx);
        }
    }
    let lost_any = !idxs.is_empty();
    let mut c = coord.lock().expect("coordinator poisoned");
    for idx in idxs {
        sl.gw.pool_mut().release_id(pair);
        c.in_flight[sl.s] -= 1;
        c.total_in_flight -= 1;
        if let Some(o) = sl.obs.as_mut() {
            o.loss(idx, now_s, i64::from(pair.0));
        }
        let outcome = c
            .churn
            .as_mut()
            .expect("loss without churn")
            .state
            .copy_lost(idx, now_s);
        match outcome {
            LossOutcome::RetryAt(rt) => {
                retry_or_abandon(&mut c, ro, idx, rt)
            }
            LossOutcome::Absorbed | LossOutcome::Lost => {}
        }
    }
    if lost_any {
        let n_if = c.in_flight[sl.s];
        if let Some(o) = sl.obs.as_mut() {
            o.in_flight(now_s, n_if);
        }
    }
}

/// Admit one routed copy into its pair's FIFO and try to start service.
#[allow(clippy::too_many_arguments)]
fn admit_copy(
    sl: &mut ShardSlot<'_>,
    wsim: &mut Wsim,
    ro: &SharedRo<'_>,
    coord: &Mutex<Coord>,
    routed: RoutedRequest,
    idx: usize,
    t: f64,
    hedge: bool,
    tag: SloTag,
) -> Result<()> {
    let admitted = sl.gw.pool_mut().acquire_id(routed.pair_id);
    debug_assert!(admitted, "route() returned a pair without a free slot");
    let n_if = {
        let mut c = coord.lock().expect("coordinator poisoned");
        c.in_flight[sl.s] += 1;
        c.total_in_flight += 1;
        c.peak_in_flight = c.peak_in_flight.max(c.total_in_flight);
        c.in_flight[sl.s]
    };
    let pair = routed.pair_id;
    let depth = {
        let q = sl.queues.entry(pair).or_default();
        push_pending(
            q,
            Pending { routed, idx, arrival_s: t, hedge, slo: tag },
        );
        q.backlog.len() + usize::from(q.serving.is_some())
    };
    if let Some(o) = sl.obs.as_mut() {
        o.queue(idx, t, i64::from(pair.0), depth);
        o.in_flight(t, n_if);
    }
    start_next(sl, wsim, ro, coord, pair, t)
}

/// Admit request `idx` into `(shard, pair)`'s forming batch: the queue
/// slot is acquired NOW, and the batch flushes when it fills, the
/// window closes, or slack runs out.
#[allow(clippy::too_many_arguments)]
fn join_forming(
    sl: &mut ShardSlot<'_>,
    wsim: &mut Wsim,
    ro: &SharedRo<'_>,
    coord: &Mutex<Coord>,
    routed: RoutedRequest,
    tag: SloTag,
    idx: usize,
    t: f64,
) -> Result<()> {
    let admitted = sl.gw.pool_mut().acquire_id(routed.pair_id);
    debug_assert!(admitted, "route() returned a pair without a free slot");
    let n_if = {
        let mut c = coord.lock().expect("coordinator poisoned");
        c.in_flight[sl.s] += 1;
        c.total_in_flight += 1;
        c.peak_in_flight = c.peak_in_flight.max(c.total_in_flight);
        c.in_flight[sl.s]
    };
    let pair = routed.pair_id;
    let (window_s, max_batch) = {
        let sr = ro.slo.as_ref().expect("forming without slo");
        (sr.cfg.batch_window_s, sr.cfg.max_batch)
    };
    let latest_s = (tag.deadline_s
        - sl.gw.predicted_completion_s(pair, t, 0.0))
    .max(t);
    let member_close = (t + window_s).min(latest_s);
    let (flush_now, close_s, size) = {
        let f = sl.forming.entry(pair).or_default();
        f.members.push(Pending {
            routed,
            idx,
            arrival_s: t,
            hedge: false,
            slo: tag,
        });
        f.close_s = f.close_s.min(member_close);
        (
            f.members.len() >= max_batch || f.close_s <= t,
            f.close_s,
            f.members.len(),
        )
    };
    if let Some(o) = sl.obs.as_mut() {
        o.batch_form(idx, t, i64::from(pair.0), size);
        o.in_flight(t, n_if);
    }
    if flush_now {
        return flush_batch(sl, wsim, ro, coord, pair, t);
    }
    // (re)schedule the close; earlier BatchClose events go stale
    let token = wsim.ord;
    sl.forming.get_mut(&pair).expect("just inserted").token = token;
    wsim.push_dynamic(
        close_s,
        LKind::BatchClose { shard: sl.s, pair, token },
    );
    Ok(())
}

/// Flush `(shard, pair)`'s forming batch into its FIFO as one
/// amortized service train.
fn flush_batch(
    sl: &mut ShardSlot<'_>,
    wsim: &mut Wsim,
    ro: &SharedRo<'_>,
    coord: &Mutex<Coord>,
    pair: PairId,
    now_s: f64,
) -> Result<()> {
    let Some(f) = sl.forming.remove(&pair) else {
        return Ok(());
    };
    if f.members.is_empty() {
        return Ok(());
    }
    {
        let mut c = coord.lock().expect("coordinator poisoned");
        if let Some(m) = c.slo.as_mut() {
            m.record_batch(f.members.len());
        }
    }
    let edf_s = f
        .members
        .iter()
        .map(|m| m.slo.deadline_s)
        .fold(f64::INFINITY, f64::min);
    for (i, mut m) in f.members.into_iter().enumerate() {
        m.slo.edf_s = edf_s;
        m.slo.amortized = i > 0;
        m.slo.net = i == 0;
        // slots were acquired at formation entry — enqueue directly
        push_pending(sl.queues.entry(pair).or_default(), m);
    }
    start_next(sl, wsim, ro, coord, pair, now_s)
}

/// The winner's admission of an arrival: SLO gate, hedging, batch
/// formation — the twin of the sequential Arrival arm, run while every
/// other worker is parked at the gate.
fn finalize_arrival(
    sl: &mut ShardSlot<'_>,
    wsim: &mut Wsim,
    ro: &SharedRo<'_>,
    coord: &Mutex<Coord>,
    routed: RoutedRequest,
    idx: usize,
    t: f64,
) -> Result<()> {
    // the winning shard's rate EWMA sees the demand
    sl.gw.adapt_arrival();
    // admit + route land on the WINNING shard's collector (there is no
    // standalone estimate step: every visited shard estimated inside
    // its own `route_at` during the walk)
    if let Some(o) = sl.obs.as_mut() {
        o.admit(idx, t, routed.estimate);
        o.route(
            idx,
            t,
            i64::from(routed.pair_id.0),
            routed.cost.latency_s,
            routed.cost.energy_mwh,
        );
    }
    // SLO admission control: predicted completion on the placed shard
    // already past the deadline → shed now instead of queueing doomed
    // work (DESIGN.md §11)
    let mut tag = SloTag::default();
    if let Some(sr) = ro.slo.as_ref() {
        let deadline = sr.deadlines[idx];
        let pred = sl.gw.predicted_completion_s(
            routed.pair_id,
            t,
            routed.cost.latency_s,
        );
        if t + pred > deadline {
            let mut c = coord.lock().expect("coordinator poisoned");
            c.dropped += 1;
            if let Some(m) = c.slo.as_mut() {
                m.record_shed(sr.cfg.class_of(idx));
            }
            if let Some(o) = sl.obs.as_mut() {
                o.shed(idx, t);
            }
            return Ok(());
        }
        tag = SloTag {
            class: sr.cfg.class_of(idx),
            deadline_s: deadline,
            edf_s: deadline,
            ..tag
        };
    }
    // proactive hedging stays within the winning shard (the duplicate
    // reuses the primary's estimate)
    let dup = if ro.policy == Some(ResiliencePolicy::Hedge) {
        match sl.gw.route_secondary(&routed, t) {
            Some(p) => {
                // hedges respect the remaining budget
                let fits = match ro.slo.as_ref() {
                    Some(sr) => {
                        t + sl.gw.predicted_completion_s(p, t, 0.0)
                            <= sr.deadlines[idx]
                    }
                    None => true,
                };
                fits.then_some(RoutedRequest { pair_id: p, ..routed })
            }
            None => None,
        }
    } else {
        None
    };
    // register BOTH copies before admitting either: the primary can
    // die synchronously at dispatch (stale view), and its loss must
    // see the hedge as a live sibling. The winning shard's estimate +
    // cost are cached so a retry never pays the estimator again.
    {
        let mut c = coord.lock().expect("coordinator poisoned");
        if let Some(ch) = c.churn.as_mut() {
            ch.est[idx] = Some((routed.estimate, routed.cost));
            ch.state.dispatched(idx);
            if let Some(d) = &dup {
                ch.state.hedge_dispatched(idx);
                ch.hedge[idx] = Some((routed.pair_id, d.pair_id));
            }
        }
    }
    // batch formation: primary copies without a hedge sibling join
    // their (shard, pair) forming batch
    let forms = dup.is_none()
        && ro.slo.as_ref().is_some_and(|sr| {
            sr.cfg.batch_window_s > 0.0 && sr.cfg.max_batch > 1
        });
    if forms {
        return join_forming(sl, wsim, ro, coord, routed, tag, idx, t);
    }
    if ro.slo.is_some() {
        let mut c = coord.lock().expect("coordinator poisoned");
        if let Some(m) = c.slo.as_mut() {
            // unbatched dispatch: a size-1 "batch"
            m.record_batch(1);
        }
    }
    admit_copy(sl, wsim, ro, coord, routed, idx, t, false, tag)?;
    if let Some(d) = dup {
        if let Some(o) = sl.obs.as_mut() {
            o.hedge(idx, t, i64::from(d.pair_id.0));
        }
        admit_copy(sl, wsim, ro, coord, d, idx, t, true, tag)?;
    }
    Ok(())
}

/// The winner's admission of a retry re-dispatch: backfill the
/// estimator cache, count the retry, and admit with the request's
/// original deadline (retries bypass batch formation).
fn finalize_retry(
    sl: &mut ShardSlot<'_>,
    wsim: &mut Wsim,
    ro: &SharedRo<'_>,
    coord: &Mutex<Coord>,
    routed: RoutedRequest,
    idx: usize,
    t: f64,
) -> Result<()> {
    {
        let mut c = coord.lock().expect("coordinator poisoned");
        let ch = c.churn.as_mut().expect("retry without churn");
        if ch.est[idx].is_none() {
            ch.est[idx] = Some((routed.estimate, routed.cost));
        }
        ch.state.retry_dispatched(idx);
    }
    // a re-placed retry re-routes but was admitted once
    if let Some(o) = sl.obs.as_mut() {
        o.route(
            idx,
            t,
            i64::from(routed.pair_id.0),
            routed.cost.latency_s,
            routed.cost.energy_mwh,
        );
    }
    let tag = match ro.slo.as_ref() {
        Some(sr) => SloTag {
            class: sr.cfg.class_of(idx),
            deadline_s: sr.deadlines[idx],
            edf_s: sr.deadlines[idx],
            ..SloTag::default()
        },
        None => SloTag::default(),
    };
    admit_copy(sl, wsim, ro, coord, routed, idx, t, false, tag)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spine_orders_arrivals_before_retries_at_equal_time() {
        let a = SEvent { t: 1.0, retry: false, idx: 7 };
        let r = SEvent { t: 1.0, retry: true, idx: 0 };
        assert!(a < r, "arrival outranks retry at equal time");
        let b = SEvent { t: 1.0, retry: false, idx: 3 };
        assert!(b < a, "equal-time arrivals order by index");
    }

    #[test]
    fn local_key_order_matches_sequential_rules() {
        // static (cls 0) events share the arrival seq space exactly
        let arrival = (1.0, 0u8, 5u64);
        let static_ev = (1.0, 0u8, 40u64);
        assert!(local_before_gate(arrival, static_ev));
        assert!(!local_before_gate(static_ev, arrival));
        // runtime events always lose equal-time ties to setup events
        let dynamic = (1.0, 1u8, 0u64);
        assert!(!local_before_gate(dynamic, static_ev));
        // earlier time always wins
        assert!(local_before_gate((0.5, 1, 9), (1.0, 0, 0)));
        // dynamic vs. spine retry at the bit-identical time commits
        // local-first (the documented measure-zero approximation)
        let retry_gate = (1.0, 1u8, 3u64);
        assert!(local_before_gate(dynamic, retry_gate));
    }
}
