//! The gateway: ECORE's serving loop (paper Fig. 3).
//!
//! Per request: estimate object count → map to group (group rules) →
//! route (policy) → dispatch to the chosen edge node → collect detections
//! and feed the count back to the estimator (OB). All gateway-side costs
//! are accounted separately so experiments can report the paper's
//! "Gateway Overhead" metric.

use anyhow::{Context, Result};

use crate::adapt::{AdaptConfig, AdaptReport, AdaptRuntime};
use crate::dataset::GtBox;
use crate::detection::map::ImageEval;
use crate::devices::{self, DeviceSpec};
use crate::estimators::{Estimator, EstimatorKind, GatewayCost};
use crate::lifecycle::{ChurnConfig, Membership};
use crate::metrics::RunMetrics;
use crate::nodes::{NodePool, NodeResponse};
use crate::router::{
    GroupRules, PairId, PairKey, Policy, PolicyKind, ProfileStore,
    RoutingView,
};
use crate::runtime::Engine;

/// Share of a device's preprocess cost saved by every batch member
/// after the first (pipelined decode keeps the device warm). Exposed so
/// drivers and tests can reproduce the amortization arithmetic.
pub const BATCH_PREPROCESS_DISCOUNT: f64 = 0.6;

/// Amortized cost after subtracting a batch saving, clamped at zero —
/// a discount can never turn a latency or energy figure negative.
pub fn amortize(cost: f64, save: f64) -> f64 {
    (cost - save).max(0.0)
}

/// One of the paper's ten evaluated router configurations: an estimator
/// plus a routing policy.
#[derive(Clone, Copy, Debug)]
pub struct RouterSpec {
    pub name: &'static str,
    pub estimator: EstimatorKind,
    pub policy: PolicyKind,
}

/// The ten configurations of §4.2 (Orc, RR, Rnd, LE, LI, HM, HMG + the
/// proposed ED, SF, OB). Baselines that ignore the object count get the
/// Oracle estimator, which costs nothing at the gateway; HMG genuinely
/// consumes the oracle group as in the paper.
pub fn paper_routers() -> Vec<RouterSpec> {
    use EstimatorKind as E;
    use PolicyKind as P;
    vec![
        RouterSpec { name: "Orc", estimator: E::Oracle, policy: P::Greedy },
        RouterSpec { name: "RR", estimator: E::Oracle, policy: P::RoundRobin },
        RouterSpec { name: "Rnd", estimator: E::Oracle, policy: P::Random },
        RouterSpec { name: "LE", estimator: E::Oracle, policy: P::LowestEnergy },
        RouterSpec { name: "LI", estimator: E::Oracle, policy: P::LowestInference },
        RouterSpec { name: "HM", estimator: E::Oracle, policy: P::HighestMap },
        RouterSpec { name: "HMG", estimator: E::Oracle, policy: P::HighestMapPerGroup },
        RouterSpec { name: "ED", estimator: E::EdgeDetection, policy: P::Greedy },
        RouterSpec { name: "SF", estimator: E::SsdFront, policy: P::Greedy },
        RouterSpec { name: "OB", estimator: E::OutputBased, policy: P::Greedy },
    ]
}

pub fn router_by_name(name: &str) -> Option<RouterSpec> {
    paper_routers()
        .into_iter()
        .find(|r| r.name.eq_ignore_ascii_case(name))
}

/// Outcome of one request, as seen by the workload driver.
#[derive(Clone, Debug)]
pub struct RequestOutcome {
    pub pair: PairKey,
    pub group: usize,
    pub estimate: usize,
    pub detections: usize,
}

/// Marker error returned by [`Gateway::route`] when every feasible
/// endpoint is down or at queue capacity. Open-loop drivers downcast
/// to this (`err.is::<NoEndpoint>()`) to shed the request; any other
/// routing error is real infrastructure failure and must propagate.
#[derive(Clone, Copy, Debug)]
pub struct NoEndpoint;

impl std::fmt::Display for NoEndpoint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "no available endpoint: all deployed nodes are down or at queue capacity")
    }
}

impl std::error::Error for NoEndpoint {}

/// A routing decision: the admission-time half of a request, produced
/// by [`Gateway::route`] and consumed by [`Gateway::finish`] once the
/// backend response is in. Carries only the interned [`PairId`] (the
/// key is resolved at the JSON/metrics edges), so the struct is `Copy`
/// and the retry/hedge paths duplicate it for free. Carrying the
/// gateway-side estimation cost here lets the open-loop driver account
/// it at arrival time while the dispatch happens arbitrarily later on
/// the event clock — and lets retries re-enter routing without paying
/// the estimator again.
#[derive(Clone, Copy, Debug)]
pub struct RoutedRequest {
    pub pair_id: PairId,
    pub group: usize,
    pub estimate: usize,
    pub true_count: usize,
    pub cost: GatewayCost,
}

/// A fully wired gateway.
pub struct Gateway<'e> {
    engine: &'e Engine,
    gateway_dev: DeviceSpec,
    rules: GroupRules,
    estimator: Estimator,
    policy: Policy,
    store: ProfileStore,
    pool: NodePool,
    pub spec: RouterSpec,
    /// Virtual clock (s): advances with each closed-loop request; feeds
    /// idle-time cooling in drifting node pools.
    now_s: f64,
    /// Requests that needed a fallback re-route (failed primary node).
    pub fallbacks: usize,
    /// Probe-driven membership (churn runs only, DESIGN.md §9). When
    /// present, routing admissibility reads this *believed* health view
    /// instead of ground-truth node health, and warming (recently
    /// rejoined) nodes route with cost-aged profile rows. `None` keeps
    /// the pre-churn behavior bit for bit.
    membership: Option<Membership>,
    /// Online adaptation runtime (DESIGN.md §12): telemetry-driven
    /// profile corrections composed onto the routing view, plus the
    /// optional energy-proportional autoscaler. `None` keeps the
    /// pre-adaptation behavior bit for bit.
    adapt: Option<AdaptRuntime>,
}

impl<'e> Gateway<'e> {
    /// Wire a gateway for one router configuration over a deployed pool.
    ///
    /// `store` must already be restricted to the deployed pairs (the
    /// router can only choose endpoints that exist).
    pub fn new(
        engine: &'e Engine,
        spec: RouterSpec,
        store: ProfileStore,
        mut pool: NodePool,
        delta_map: f64,
        seed: u64,
    ) -> Self {
        // one id space for store, pool, and membership: the pool's
        // admission/occupancy checks become O(1) array hits
        pool.bind_table(store.table_arc());
        Self {
            engine,
            gateway_dev: devices::gateway_spec(),
            rules: GroupRules::paper_default(),
            estimator: Estimator::new(spec.estimator),
            policy: Policy::new(spec.policy, &store, delta_map, seed),
            store,
            pool,
            spec,
            now_s: 0.0,
            fallbacks: 0,
            membership: None,
            adapt: None,
        }
    }

    /// Switch this gateway to probe-driven membership over its routing
    /// table (all pairs start believed-Up; the deployed pool covers
    /// exactly the store's pairs). Routing stops reading ground-truth
    /// health; only probe results and dispatch failures fed through
    /// [`Gateway::membership_mut`] move the view.
    pub fn enable_churn(&mut self, cfg: &ChurnConfig) {
        self.membership = Some(Membership::new(self.store.table(), cfg));
    }

    pub fn membership(&self) -> Option<&Membership> {
        self.membership.as_ref()
    }

    pub fn membership_mut(&mut self) -> Option<&mut Membership> {
        self.membership.as_mut()
    }

    /// Switch on online adaptation (DESIGN.md §12). Telemetry always
    /// runs; when `cfg.scale` is set the autoscaler does too, and a
    /// gateway without churn membership synthesizes one
    /// ([`AdaptConfig::membership_config`]) so power transitions flow
    /// through the same believed-health path churn uses. Call after
    /// [`Gateway::enable_churn`] when combining both.
    pub fn enable_adapt(&mut self, cfg: &AdaptConfig) {
        let deployed: Vec<bool> = self
            .store
            .pair_ids()
            .map(|id| self.pool.device_of_id(id).is_some())
            .collect();
        if cfg.scale && self.membership.is_none() {
            self.membership = Some(Membership::new(
                self.store.table(),
                &cfg.membership_config(),
            ));
        }
        self.adapt = Some(AdaptRuntime::new(cfg, deployed));
    }

    pub fn adapt(&self) -> Option<&AdaptRuntime> {
        self.adapt.as_ref()
    }

    pub fn adapt_mut(&mut self) -> Option<&mut AdaptRuntime> {
        self.adapt.as_mut()
    }

    /// Driver hook: one offered arrival reached this gateway (feeds
    /// the autoscaler's rate estimate). A no-op without a scaler.
    pub fn adapt_arrival(&mut self) {
        if let Some(sc) =
            self.adapt.as_mut().and_then(|a| a.scaler.as_mut())
        {
            sc.note_arrival();
        }
    }

    /// Driver hook: one scaler decision tick at `now_s`. Closes the
    /// rate window, computes predicted utilization over the powered
    /// set, and performs at most one power transition — power-down of
    /// the dearest idle node in a trough, power-up of the cheapest
    /// off node when utilization crosses the upper threshold. Both
    /// transitions flow through pool health + membership
    /// (PoweredDown / Warming), so routing, probes, and warm-up aging
    /// see them exactly like lifecycle events.
    pub fn adapt_scale_tick(&mut self, now_s: f64) {
        let store = &self.store;
        let pool = &mut self.pool;
        let membership = self.membership.as_mut();
        let Some(sc) =
            self.adapt.as_mut().and_then(|a| a.scaler.as_mut())
        else {
            return;
        };
        let Some(util) =
            sc.tick(now_s, |id| store.stats_of(id).mean_latency_s)
        else {
            return;
        };
        if util < sc.down_util() && sc.n_powered() > sc.min_powered() {
            // victim: a powered node that is idle (empty queue) and
            // truly up — never strand queued work or "power down" a
            // node that is actually crashed — preferring the dearest
            // mean energy; ties break on the higher id for determinism
            let victim = store
                .pair_ids()
                .filter(|&id| {
                    sc.is_powered(id)
                        && pool.is_healthy_id(id)
                        && pool.queue_depth_id(id) == 0
                })
                .max_by(|&i, &j| {
                    store
                        .stats_of(i)
                        .mean_energy_mwh
                        .total_cmp(&store.stats_of(j).mean_energy_mwh)
                        .then(i.cmp(&j))
                });
            if let Some(id) = victim {
                sc.power_down(id, now_s);
                pool.set_health_id(id, false);
                if let Some(m) = membership {
                    m.power_down(id);
                }
            }
        } else if util > sc.up_util() && sc.n_off() > 0 {
            // re-warm the cheapest powered-off node (ties: lower id)
            let cand = store
                .pair_ids()
                .filter(|&id| !sc.is_powered(id))
                .min_by(|&i, &j| {
                    store
                        .stats_of(i)
                        .mean_energy_mwh
                        .total_cmp(&store.stats_of(j).mean_energy_mwh)
                        .then(i.cmp(&j))
                });
            if let Some(id) = cand {
                sc.power_up(id, now_s);
                // ground truth wins over the scaler: a node that
                // crashed while powered down stays physically dead —
                // its pending Rejoin event restores pool health when
                // repair completes. The believed view still flips to
                // Warming, and the gateway pays for that stale
                // optimism at dispatch, exactly like any other crash.
                let truth_up = membership
                    .as_ref()
                    .map(|m| !m.truth_down(id))
                    .unwrap_or(true);
                pool.set_health_id(id, truth_up);
                if truth_up {
                    if let Some(node) = pool.get_id(id) {
                        node.on_rejoin(now_s);
                    }
                }
                if let Some(m) = membership {
                    m.power_up(id, now_s);
                }
            }
        }
    }

    /// End-of-run adaptation summary (`None` without an adapt config).
    pub fn adapt_report(&self, makespan_s: f64) -> Option<AdaptReport> {
        self.adapt
            .as_ref()
            .map(|a| a.report(self.pool.len(), makespan_s))
    }

    pub fn pool_mut(&mut self) -> &mut NodePool {
        &mut self.pool
    }

    /// Replace the gateway's group rules (must match the store's group
    /// labels — used by the group-granularity ablation).
    pub fn set_rules(&mut self, rules: GroupRules) {
        self.rules = rules;
    }

    pub fn virtual_now(&self) -> f64 {
        self.now_s
    }

    pub fn pool(&self) -> &NodePool {
        &self.pool
    }

    /// The profiling table this gateway routes over (a fleet shard's
    /// store covers exactly its own nodes).
    pub fn store(&self) -> &ProfileStore {
        &self.store
    }

    /// Admission phase: estimate + group + policy routing, skipping
    /// unavailable endpoints. If the chosen node is down — or, in open
    /// loop, its bounded queue is full — re-route over the store with
    /// that pair removed (the next-best feasible pair), like a
    /// health-checked LB. Re-routes count toward `fallbacks` once
    /// routing succeeds; exhausting every endpoint yields the typed
    /// [`NoEndpoint`] error (open-loop drivers shed on it).
    ///
    /// `true_count` is evaluation-side information feeding the Oracle
    /// estimator (as request metadata, like the paper).
    pub fn route(
        &mut self,
        image: &[f32],
        true_count: usize,
    ) -> Result<RoutedRequest> {
        let now_s = self.now_s;
        self.route_at(image, true_count, now_s)
    }

    /// [`Gateway::route`] at an explicit virtual time (open-loop and
    /// fleet drivers pass their event clock). The time only matters
    /// under churn, where warm-up aging of recently rejoined nodes is a
    /// function of `now_s`.
    pub fn route_at(
        &mut self,
        image: &[f32],
        true_count: usize,
        now_s: f64,
    ) -> Result<RoutedRequest> {
        let (estimate, cost) = self.estimate_request(image, true_count)?;
        self.route_with_estimate(estimate, true_count, cost, now_s)
    }

    /// Estimation phase alone: run the configured estimator on one
    /// image and return (estimate, gateway-side cost). Split out from
    /// [`Gateway::route_at`] so drivers can cache the result and route
    /// retries without paying [`GatewayCost`] twice (ROADMAP
    /// "estimator caching").
    pub fn estimate_request(
        &mut self,
        image: &[f32],
        true_count: usize,
    ) -> Result<(usize, GatewayCost)> {
        self.estimator.estimate(
            self.engine,
            &self.gateway_dev,
            image,
            true_count,
        )
    }

    /// Policy phase: route an already-estimated request, skipping
    /// unavailable endpoints — the zero-allocation hot path. The
    /// policy runs over a borrowed [`RoutingView`] of the shard store;
    /// the fallback walk excludes failed pairs on the view (a bit
    /// flip) instead of materializing restricted store copies, and
    /// warm-up aging rides the view's cost overlay. `cost` is carried
    /// into the [`RoutedRequest`] verbatim: a retry passes the
    /// original estimate + cost so the estimator is consulted exactly
    /// once per request, and the winning copy records that one cost.
    pub fn route_with_estimate(
        &mut self,
        estimate: usize,
        true_count: usize,
        cost: GatewayCost,
        now_s: f64,
    ) -> Result<RoutedRequest> {
        let group = self.rules.group_of(estimate);
        let store = &self.store;
        let membership = self.membership.as_ref();
        let adapt = self.adapt.as_ref();
        let pool = &self.pool;
        let policy = &mut self.policy;
        let mut view = Self::aged_view(store, membership, adapt, now_s);
        let mut pair_id = policy
            .route_view(&view, group)
            .context("policy returned no endpoint")?;
        // attempts are committed to `self.fallbacks` only when routing
        // succeeds: re-routes that end in a shed request rescued
        // nothing and must not inflate the fallback metric.
        let mut attempts = 0;
        while !Self::admits(pool, membership, pair_id) {
            attempts += 1;
            if attempts > pool.len() {
                return Err(anyhow::Error::new(NoEndpoint));
            }
            view.exclude(pair_id);
            pair_id = match policy.route_view(&view, group) {
                Some(p) => p,
                None => return Err(anyhow::Error::new(NoEndpoint)),
            };
        }
        self.fallbacks += attempts;
        Ok(RoutedRequest {
            pair_id,
            group,
            estimate,
            true_count,
            cost,
        })
    }

    /// Pick the second-best admissible pair for a hedged duplicate of
    /// `routed`: re-run the policy over the routing view with the
    /// primary pair excluded, walking the same fallback sequence. No
    /// estimator cost is charged — the duplicate reuses the primary's
    /// estimate — and the walk does not touch the `fallbacks` counter.
    pub fn route_secondary(
        &mut self,
        routed: &RoutedRequest,
        now_s: f64,
    ) -> Option<PairId> {
        let store = &self.store;
        let membership = self.membership.as_ref();
        let adapt = self.adapt.as_ref();
        let pool = &self.pool;
        let policy = &mut self.policy;
        let mut view = Self::aged_view(store, membership, adapt, now_s);
        let mut exclude = routed.pair_id;
        loop {
            view.exclude(exclude);
            if view.live_pairs() == 0 {
                return None;
            }
            let pair_id = policy.route_view(&view, routed.group)?;
            if Self::admits(pool, membership, pair_id) {
                return Some(pair_id);
            }
            exclude = pair_id;
        }
    }

    /// The routing view for one request: a borrow of the shard store,
    /// with per-pair cost multipliers composed from every overlay
    /// source — lifecycle warm-up aging (a rejoining node looks
    /// expensive until its window closes) times the telemetry
    /// correction (observed/predicted drift, DESIGN.md §12). One
    /// overlay path, multiplicative composition; ids ascend so the
    /// overlay stays sorted. An associated fn over the borrowed
    /// fields so the policy can hold its own mutable borrow.
    fn aged_view<'a>(
        store: &'a ProfileStore,
        membership: Option<&Membership>,
        adapt: Option<&AdaptRuntime>,
        now_s: f64,
    ) -> RoutingView<'a> {
        let mut view = RoutingView::new(store);
        // telemetry gate: until a correction is published the adapt
        // runtime contributes nothing and costs nothing per request
        let telemetry =
            adapt.map(|a| &a.telemetry).filter(|t| t.active());
        if membership.is_none() && telemetry.is_none() {
            return view;
        }
        for id in store.pair_ids() {
            let mut mult = match membership {
                Some(m) => m.cost_multiplier(id, now_s),
                None => 1.0,
            };
            if let Some(t) = telemetry {
                mult *= t.correction(id);
            }
            if mult != 1.0 {
                view.age(id, mult);
            }
        }
        view
    }

    /// Routing-time admissibility of one endpoint. Without churn this
    /// is ground truth (`NodePool::is_available_id`); with churn it is
    /// the probe-driven *believed* health plus the (locally exact)
    /// queue occupancy — the gateway can and does admit onto a node
    /// that is already dead, paying for the stale view at dispatch.
    /// An associated fn over the borrowed fields so the fallback walk
    /// can run while the policy holds its own mutable borrow.
    fn admits(
        pool: &NodePool,
        membership: Option<&Membership>,
        id: PairId,
    ) -> bool {
        match membership {
            Some(m) => m.believed_up(id) && pool.has_slot_id(id),
            None => pool.is_available_id(id),
        }
    }

    /// Per-member batch savings on one endpoint: the (latency s, energy
    /// mWh) every batch member after the first saves by amortizing the
    /// device's preprocess stage ([`BATCH_PREPROCESS_DISCOUNT`]).
    /// `(0, 0)` for pairs without a deployed node, so callers can apply
    /// it unconditionally.
    pub fn batch_savings(&self, pair_id: PairId) -> (f64, f64) {
        match self.pool.device_of_id(pair_id) {
            Some(dev) => {
                let save_s = dev.preprocess_s * BATCH_PREPROCESS_DISCOUNT;
                (save_s, dev.cpu_dyn_power_w * save_s / 3.6)
            }
            None => (0.0, 0.0),
        }
    }

    /// Admission-time completion prediction for one routed endpoint:
    /// the gateway-side estimation latency already paid, plus every
    /// request ahead of this one (current queue occupancy) and the
    /// request itself at the pair's mean profiled service time (under
    /// the warm-up overlay, like routing itself), plus the network hop.
    /// SLO admission sheds a request when `now + prediction` already
    /// blows its deadline, instead of waiting for queue overflow.
    pub fn predicted_completion_s(
        &self,
        pair_id: PairId,
        now_s: f64,
        gw_latency_s: f64,
    ) -> f64 {
        let view = Self::aged_view(
            &self.store,
            self.membership.as_ref(),
            self.adapt.as_ref(),
            now_s,
        );
        let ahead = self.pool.queue_depth_id(pair_id) as f64;
        gw_latency_s
            + (ahead + 1.0) * view.mean_latency_s(pair_id)
            + devices::NETWORK_S
    }

    /// Dispatch phase: execute one request on the routed node at time
    /// `now_s` on the virtual clock (open-loop drivers pass their event
    /// time; the closed loop passes its serial clock).
    pub fn serve(
        &mut self,
        pair_id: PairId,
        image: &[f32],
        now_s: f64,
    ) -> Result<NodeResponse> {
        let engine = self.engine;
        let node = self.pool.get_id(pair_id).with_context(|| {
            // error path only: resolve the id for the diagnostic
            match self.store.table().keys().get(pair_id.index()) {
                Some(k) => format!("no deployed node for {k}"),
                None => format!(
                    "no deployed node for unknown pair id {}",
                    pair_id.0
                ),
            }
        })?;
        node.process_at(engine, image, now_s)
    }

    /// Completion phase: feed the response back to the estimator (OB)
    /// and record the request into `metrics`. `queue_delay_s` is the
    /// time the request waited in the node's FIFO (0 in closed loop);
    /// `gt` is used only for accuracy accounting.
    pub fn finish(
        &mut self,
        routed: &RoutedRequest,
        resp: NodeResponse,
        gt: &[GtBox],
        queue_delay_s: f64,
        metrics: &mut RunMetrics,
    ) -> RequestOutcome {
        self.finish_with_network(
            routed,
            resp,
            gt,
            queue_delay_s,
            devices::NETWORK_S,
            metrics,
        )
    }

    /// [`Gateway::finish`] with an explicit network charge. Batch
    /// followers ride the first member's transfer, so the open-loop
    /// drivers record them with `network_s = 0.0`; everything else
    /// passes [`devices::NETWORK_S`].
    pub fn finish_with_network(
        &mut self,
        routed: &RoutedRequest,
        resp: NodeResponse,
        gt: &[GtBox],
        queue_delay_s: f64,
        network_s: f64,
        metrics: &mut RunMetrics,
    ) -> RequestOutcome {
        // telemetry feedback (DESIGN.md §12): compare this completion
        // against the profiled row it was routed on. Batch followers
        // (network_s == 0) are skipped — their amortized costs would
        // read as phantom "drift" against the per-request profile.
        if network_s > 0.0 {
            if let Some(a) = self.adapt.as_mut() {
                if let Some(row) =
                    self.store.lookup_id(routed.pair_id, routed.group)
                {
                    a.telemetry.observe(
                        routed.pair_id,
                        row.latency_s,
                        row.energy_mwh,
                        resp.latency_s,
                        resp.energy_mwh,
                    );
                }
            }
        }
        self.estimator.observe_response(resp.detections.len());
        let n_det = resp.detections.len();
        // resolve the interned id at the metrics edge (strings live
        // only in reports, never on the routing hot path)
        let pair = self.store.key_of(routed.pair_id);
        metrics.record_request(
            pair,
            routed.group,
            routed.estimate,
            routed.true_count,
            routed.cost.latency_s,
            routed.cost.energy_mwh,
            resp.latency_s,
            resp.energy_mwh,
            network_s,
            ImageEval {
                dets: resp.detections,
                gt: gt.to_vec(),
            },
        );
        metrics.record_queue_delay(queue_delay_s);
        RequestOutcome {
            pair: pair.clone(),
            group: routed.group,
            estimate: routed.estimate,
            detections: n_det,
        }
    }

    /// Handle one request end to end, recording into `metrics` — the
    /// closed-loop path: route, serve immediately on the serial virtual
    /// clock, finish with zero queueing delay.
    ///
    /// `true_count` and `gt` are evaluation-side information: the former
    /// feeds the Oracle estimator (as request metadata, like the paper),
    /// the latter is used only for accuracy accounting.
    pub fn handle(
        &mut self,
        image: &[f32],
        true_count: usize,
        gt: &[GtBox],
        metrics: &mut RunMetrics,
    ) -> Result<RequestOutcome> {
        let routed = self.route(image, true_count)?;
        let resp = self.serve(routed.pair_id, image, self.now_s)?;
        self.now_s +=
            routed.cost.latency_s + resp.latency_s + devices::NETWORK_S;
        Ok(self.finish(&routed, resp, gt, 0.0, metrics))
    }
}

/// Batch-level routing (paper Future Work #2): estimate once on a batch
/// representative, route the whole batch to one pair, and amortize the
/// per-request preprocessing.
pub struct BatchOutcome {
    pub pair: PairKey,
    pub group: usize,
    pub detections_per_image: Vec<usize>,
}

impl<'e> Gateway<'e> {
    /// Handle a batch of images with one routing decision.
    ///
    /// The estimator sees only the first image; the chosen node serves
    /// the whole batch back-to-back (device stays warm: the preprocess
    /// share of latency/energy after the first request is discounted by
    /// [`BATCH_PREPROCESS_DISCOUNT`], modelling pipelined decode).
    ///
    /// Routing goes through the same admission path as
    /// [`Gateway::handle`] — membership-aware health, queue occupancy,
    /// and the fallback walk — and the batch holds one queue slot while
    /// it drains, so batch traffic is visible to occupancy-aware
    /// routing instead of reaching the pool behind admission's back.
    pub fn handle_batch(
        &mut self,
        images: &[(Vec<f32>, usize, Vec<GtBox>)],
        metrics: &mut RunMetrics,
    ) -> Result<BatchOutcome> {
        anyhow::ensure!(!images.is_empty(), "empty batch");
        let (first_img, first_count, _) = &images[0];
        let (estimate, cost) =
            self.estimate_request(first_img, *first_count)?;
        let now = self.now_s;
        let routed =
            self.route_with_estimate(estimate, *first_count, cost, now)?;
        let pair_id = routed.pair_id;
        let pair = self.store.key_of(pair_id).clone();
        anyhow::ensure!(
            self.pool.acquire_id(pair_id),
            "no queue slot on {pair} for batch"
        );
        let (save_s, save_mwh) = self.batch_savings(pair_id);
        let mut dets_per_image = Vec::with_capacity(images.len());
        for (i, (img, true_count, gt)) in images.iter().enumerate() {
            let mut resp = match self.serve(pair_id, img, now) {
                Ok(r) => r,
                Err(e) => {
                    // free the batch's slot before propagating, or the
                    // node leaks occupancy into every later decision
                    self.pool.release_id(pair_id);
                    return Err(e);
                }
            };
            if i > 0 {
                // amortized preprocessing within the batch
                resp.latency_s = amortize(resp.latency_s, save_s);
                resp.energy_mwh = amortize(resp.energy_mwh, save_mwh);
            }
            let gw_cost =
                if i == 0 { routed.cost } else { Default::default() };
            self.now_s += gw_cost.latency_s + resp.latency_s;
            dets_per_image.push(resp.detections.len());
            metrics.record_request(
                &pair,
                routed.group,
                estimate,
                *true_count,
                gw_cost.latency_s,
                gw_cost.energy_mwh,
                resp.latency_s,
                resp.energy_mwh,
                if i == 0 { devices::NETWORK_S } else { 0.0 },
                ImageEval {
                    dets: resp.detections,
                    gt: gt.clone(),
                },
            );
        }
        self.pool.release_id(pair_id);
        if let Some(&last) = dets_per_image.last() {
            self.estimator.observe_response(last);
        }
        Ok(BatchOutcome {
            pair,
            group: routed.group,
            detections_per_image: dets_per_image,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{scene, SceneSpec};
    use crate::devices::fleet;
    use crate::router::{PairProfile, ProfileStore};

    fn engine() -> Engine {
        Engine::new(&crate::default_artifacts_dir()).unwrap()
    }

    fn tiny_store() -> ProfileStore {
        let mut rows = Vec::new();
        for g in 0..5 {
            rows.push(PairProfile {
                pair: PairKey::new("ssd_v1", "jetson_orin_nano"),
                group: g,
                map: 50.0,
                latency_s: 0.005,
                energy_mwh: 0.002,
            });
            rows.push(PairProfile {
                pair: PairKey::new("yolov8n", "pi5_aihat"),
                group: g,
                map: if g >= 3 { 80.0 } else { 52.0 },
                latency_s: 0.03,
                energy_mwh: 0.03,
            });
        }
        ProfileStore::new(rows)
    }

    #[test]
    fn oracle_greedy_routes_by_group() {
        let e = engine();
        let store = tiny_store();
        let pool = NodePool::deploy(&e, &store.pairs(), &fleet(), 1).unwrap();
        let mut gw = Gateway::new(
            &e,
            router_by_name("Orc").unwrap(),
            store,
            pool,
            5.0,
            1,
        );
        let mut m = RunMetrics::new("Orc");
        let sparse = scene::render_spec(&SceneSpec {
            id: 0,
            seed: 1,
            n_objects: 1,
        });
        let out = gw
            .handle(&sparse.image, 1, &sparse.gt, &mut m)
            .unwrap();
        // group 1: cheap pair wins within delta (52 - 5 = 47 <= 50)
        assert_eq!(out.pair, PairKey::new("ssd_v1", "jetson_orin_nano"));
        assert_eq!(out.group, 1);

        let crowded = scene::render_spec(&SceneSpec {
            id: 1,
            seed: 2,
            n_objects: 6,
        });
        let out = gw
            .handle(&crowded.image, crowded.gt.len(), &crowded.gt, &mut m)
            .unwrap();
        // group 4: only the big pair is within delta of 80
        assert_eq!(out.pair, PairKey::new("yolov8n", "pi5_aihat"));
        assert_eq!(m.requests, 2);
        assert!(m.total_energy_mwh() > 0.0);
    }

    #[test]
    fn ob_estimator_follows_backend_counts() {
        let e = engine();
        let store = tiny_store();
        let pool = NodePool::deploy(&e, &store.pairs(), &fleet(), 1).unwrap();
        let mut gw = Gateway::new(
            &e,
            router_by_name("OB").unwrap(),
            store,
            pool,
            5.0,
            1,
        );
        let mut m = RunMetrics::new("OB");
        let crowded = scene::render_spec(&SceneSpec {
            id: 0,
            seed: 9,
            n_objects: 7,
        });
        // first request: default estimate 0 -> group 0
        let o1 = gw
            .handle(&crowded.image, 7, &crowded.gt, &mut m)
            .unwrap();
        assert_eq!(o1.estimate, 0);
        // second request: estimate = detections of the previous response
        let o2 = gw
            .handle(&crowded.image, 7, &crowded.gt, &mut m)
            .unwrap();
        assert_eq!(o2.estimate, o1.detections);
    }

    #[test]
    fn churn_gateway_routes_on_believed_health_not_ground_truth() {
        let e = engine();
        let store = tiny_store();
        let pool =
            NodePool::deploy(&e, &store.pairs(), &fleet(), 1).unwrap();
        let mut gw = Gateway::new(
            &e,
            router_by_name("LE").unwrap(),
            store,
            pool,
            5.0,
            1,
        );
        let cheap = PairKey::new("ssd_v1", "jetson_orin_nano");
        let big = PairKey::new("yolov8n", "pi5_aihat");
        let cheap_id = gw.store().id_of(&cheap).unwrap();
        let big_id = gw.store().id_of(&big).unwrap();
        gw.enable_churn(&crate::lifecycle::ChurnConfig {
            suspect_after: 2,
            warmup_s: 2.0,
            // huge warm-up penalty so aging visibly flips LE's choice
            warmup_penalty: 40.0,
            ..Default::default()
        });
        let img = vec![0.5f32; 384 * 384];
        // believed Up: LE picks the cheap pair
        assert_eq!(gw.route_at(&img, 0, 0.0).unwrap().pair_id, cheap_id);
        // ground truth down but no probe noticed yet: still routed
        // there (the stale-view cost this subsystem exists to model)
        gw.pool_mut().set_health(&cheap, false);
        assert_eq!(gw.route_at(&img, 0, 0.1).unwrap().pair_id, cheap_id);
        // two missed probes: believed Down, routing avoids it
        gw.membership_mut().unwrap().observe_probe(cheap_id, false, 0.2);
        gw.membership_mut().unwrap().observe_probe(cheap_id, false, 0.3);
        assert_eq!(gw.route_at(&img, 0, 0.4).unwrap().pair_id, big_id);
        // rejoin observed: Warming until 3.0, aged rows keep LE away
        gw.pool_mut().set_health(&cheap, true);
        gw.membership_mut().unwrap().observe_probe(cheap_id, true, 1.0);
        assert_eq!(gw.route_at(&img, 0, 1.0).unwrap().pair_id, big_id);
        // after the warm-up window the cheap pair wins again
        assert_eq!(gw.route_at(&img, 0, 3.5).unwrap().pair_id, cheap_id);
    }

    #[test]
    fn telemetry_corrections_steer_routing_and_compose_with_warmup() {
        let e = engine();
        let store = tiny_store();
        let pool =
            NodePool::deploy(&e, &store.pairs(), &fleet(), 1).unwrap();
        let mut gw = Gateway::new(
            &e,
            router_by_name("LE").unwrap(),
            store,
            pool,
            5.0,
            1,
        );
        let cheap = PairKey::new("ssd_v1", "jetson_orin_nano");
        let big = PairKey::new("yolov8n", "pi5_aihat");
        let cheap_id = gw.store().id_of(&cheap).unwrap();
        let big_id = gw.store().id_of(&big).unwrap();
        // telemetry only: no scaler, so no membership is synthesized
        gw.enable_adapt(&crate::adapt::AdaptConfig {
            scale: false,
            max_correction: 32.0,
            ..Default::default()
        });
        assert!(gw.membership().is_none());
        let img = vec![0.5f32; 384 * 384];
        // uncorrected: LE picks the cheap pair (0.002 vs 0.03 mWh)
        assert_eq!(gw.route_at(&img, 0, 0.0).unwrap().pair_id, cheap_id);
        // feed drift evidence: the cheap pair actually costs 20x its
        // profile, pushing its believed energy past the big pair's
        for _ in 0..50 {
            gw.adapt_mut().unwrap().telemetry.observe(
                cheap_id, 0.005, 0.002, 0.1, 0.04,
            );
        }
        assert_eq!(gw.route_at(&img, 0, 0.1).unwrap().pair_id, big_id);
        // and the fix is reversible: fresh evidence matching the
        // profile pulls the correction back down
        for _ in 0..200 {
            gw.adapt_mut().unwrap().telemetry.observe(
                cheap_id, 0.005, 0.002, 0.005, 0.002,
            );
        }
        assert_eq!(gw.route_at(&img, 0, 0.2).unwrap().pair_id, cheap_id);
    }

    #[test]
    fn finish_feeds_telemetry_from_completions_but_not_batch_followers() {
        let e = engine();
        let store = tiny_store();
        let pool =
            NodePool::deploy(&e, &store.pairs(), &fleet(), 1).unwrap();
        let mut gw = Gateway::new(
            &e,
            router_by_name("LE").unwrap(),
            store,
            pool,
            5.0,
            1,
        );
        gw.enable_adapt(&crate::adapt::AdaptConfig {
            scale: false,
            ..Default::default()
        });
        let img = vec![0.5f32; 384 * 384];
        let mut m = RunMetrics::new("LE");
        gw.handle(&img, 0, &[], &mut m).unwrap();
        assert_eq!(gw.adapt().unwrap().telemetry.samples(), 1);
        // a batch follower (network_s == 0) must not feed telemetry:
        // its amortized costs would read as phantom drift
        let routed = gw.route(&img, 0).unwrap();
        let resp = gw.serve(routed.pair_id, &img, 0.0).unwrap();
        gw.finish_with_network(&routed, resp, &[], 0.0, 0.0, &mut m);
        assert_eq!(gw.adapt().unwrap().telemetry.samples(), 1);
    }

    #[test]
    fn scale_tick_power_up_respects_ground_truth_crashes() {
        // PoweredDown x crash interplay: a node that crashes while the
        // scaler has it powered off must NOT come back healthy when
        // the scaler powers it up — membership flips to Warming (the
        // believed view is allowed to be optimistic) but pool health
        // stays down until the churn Rejoin event lands.
        let e = engine();
        let store = tiny_store();
        let pool =
            NodePool::deploy(&e, &store.pairs(), &fleet(), 1).unwrap();
        let mut gw = Gateway::new(
            &e,
            router_by_name("LE").unwrap(),
            store,
            pool,
            5.0,
            1,
        );
        let big = PairKey::new("yolov8n", "pi5_aihat");
        let big_id = gw.store().id_of(&big).unwrap();
        gw.enable_adapt(&crate::adapt::AdaptConfig {
            scale_interval_s: 1.0,
            rate_alpha: 1.0,
            down_util: 0.35,
            up_util: 0.75,
            warmup_s: 2.0,
            ..Default::default()
        });
        // trough powers the dear pair down
        gw.adapt_scale_tick(1.0);
        assert_eq!(
            gw.membership().unwrap().state(big_id),
            Some(crate::lifecycle::MemberState::PoweredDown)
        );
        // ground-truth crash lands on the powered-down node (the
        // driver would also set pool health false — already false)
        gw.pool_mut().set_health_id(big_id, false);
        gw.membership_mut()
            .unwrap()
            .ground_truth_changed(big_id, false, 1.5);
        // burst forces a power-up of the only off node
        for _ in 0..400 {
            gw.adapt_arrival();
        }
        gw.adapt_scale_tick(2.0);
        let sc = gw.adapt().unwrap().scaler.as_ref().unwrap();
        assert_eq!(sc.power_ups, 1);
        assert_eq!(
            gw.membership().unwrap().state(big_id),
            Some(crate::lifecycle::MemberState::Warming),
            "believed view re-enters through Warming"
        );
        assert!(
            !gw.pool().is_healthy_id(big_id),
            "scaler must not resurrect a crashed node"
        );
        // repair completes: the driver's Rejoin path restores health
        gw.pool_mut().set_health_id(big_id, true);
        gw.membership_mut()
            .unwrap()
            .ground_truth_changed(big_id, true, 3.0);
        assert!(gw.pool().is_healthy_id(big_id));
    }

    #[test]
    fn scale_tick_powers_down_in_troughs_and_rewarms_under_load() {
        let e = engine();
        let store = tiny_store();
        let pool =
            NodePool::deploy(&e, &store.pairs(), &fleet(), 1).unwrap();
        let mut gw = Gateway::new(
            &e,
            router_by_name("LE").unwrap(),
            store,
            pool,
            5.0,
            1,
        );
        let cheap = PairKey::new("ssd_v1", "jetson_orin_nano");
        let big = PairKey::new("yolov8n", "pi5_aihat");
        let cheap_id = gw.store().id_of(&cheap).unwrap();
        let big_id = gw.store().id_of(&big).unwrap();
        gw.enable_adapt(&crate::adapt::AdaptConfig {
            scale_interval_s: 1.0,
            rate_alpha: 1.0, // no smoothing: the test drives rates
            down_util: 0.35,
            up_util: 0.75,
            warmup_s: 2.0,
            ..Default::default()
        });
        // scaling synthesized a membership (everything believed Up)
        assert!(gw.membership().is_some());
        assert_eq!(gw.membership().unwrap().counts(), (2, 0, 0, 0));

        // trough: zero arrivals in the window => util 0 => the dearer
        // pair (big, 0.03 mWh) powers down through the lifecycle path
        gw.adapt_scale_tick(1.0);
        let sc = gw.adapt().unwrap().scaler.as_ref().unwrap();
        assert_eq!(sc.power_downs, 1);
        assert!(!sc.is_powered(big_id));
        assert!(sc.is_powered(cheap_id));
        assert_eq!(
            gw.membership().unwrap().state(big_id),
            Some(crate::lifecycle::MemberState::PoweredDown)
        );
        assert!(!gw.pool().is_healthy_id(big_id));
        // min_powered floor: another trough tick cannot empty the pool
        gw.adapt_scale_tick(2.0);
        let sc = gw.adapt().unwrap().scaler.as_ref().unwrap();
        assert_eq!(sc.power_downs, 1, "min_powered floor held");

        // routing in the trough avoids the powered-down pair
        let img = vec![0.5f32; 384 * 384];
        assert_eq!(gw.route_at(&img, 4, 2.0).unwrap().pair_id, cheap_id);

        // burst: 400 arrivals/s * 0.005 s / 1 node = util 2.0 => the
        // powered-off pair re-warms through Warming with aged costs
        for _ in 0..400 {
            gw.adapt_arrival();
        }
        gw.adapt_scale_tick(3.0);
        let sc = gw.adapt().unwrap().scaler.as_ref().unwrap();
        assert_eq!(sc.power_ups, 1);
        assert!(sc.is_powered(big_id));
        assert_eq!(
            gw.membership().unwrap().state(big_id),
            Some(crate::lifecycle::MemberState::Warming)
        );
        assert!(gw.pool().is_healthy_id(big_id));
        assert!(
            gw.membership().unwrap().cost_multiplier(big_id, 3.0) > 1.0
        );
    }

    #[test]
    fn route_secondary_picks_a_distinct_admissible_pair() {
        let e = engine();
        let store = tiny_store();
        let pool =
            NodePool::deploy(&e, &store.pairs(), &fleet(), 1).unwrap();
        let mut gw = Gateway::new(
            &e,
            router_by_name("LE").unwrap(),
            store,
            pool,
            5.0,
            1,
        );
        let img = vec![0.5f32; 384 * 384];
        let routed = gw.route(&img, 0).unwrap();
        let second = gw.route_secondary(&routed, 0.0).unwrap();
        assert_ne!(
            second, routed.pair_id,
            "hedge must use a distinct pair"
        );
        // with the only alternative down there is no hedge target
        let second_key = gw.store().key_of(second).clone();
        gw.pool_mut().set_health(&second_key, false);
        assert!(gw.route_secondary(&routed, 0.0).is_none());
    }

    #[test]
    fn gateway_overhead_only_for_estimating_routers() {
        let e = engine();
        let img = vec![0.5f32; 384 * 384];
        for (name, expect_cost) in
            [("LE", false), ("ED", true), ("SF", true), ("OB", false)]
        {
            let store = tiny_store();
            let pool =
                NodePool::deploy(&e, &store.pairs(), &fleet(), 1).unwrap();
            let mut gw = Gateway::new(
                &e,
                router_by_name(name).unwrap(),
                store,
                pool,
                5.0,
                1,
            );
            let mut m = RunMetrics::new(name);
            gw.handle(&img, 0, &[], &mut m).unwrap();
            assert_eq!(
                m.gateway_energy_mwh > 0.0,
                expect_cost,
                "router {name}"
            );
        }
    }

    #[test]
    fn no_churn_routing_performs_zero_store_copies() {
        // the tentpole regression: the degenerate (no-churn) routing
        // path must be a borrow of the shard store, never a copy —
        // Gateway::routing_store used to deep-clone every row and
        // string on every routed request.
        let e = engine();
        let store = tiny_store();
        let pool =
            NodePool::deploy(&e, &store.pairs(), &fleet(), 1).unwrap();
        let mut gw = Gateway::new(
            &e,
            router_by_name("Orc").unwrap(),
            store,
            pool,
            5.0,
            1,
        );
        let img = vec![0.5f32; 384 * 384];
        let before = ProfileStore::clone_count();
        for i in 0..50 {
            gw.route_at(&img, i % 7, i as f64 * 0.01).unwrap();
        }
        assert_eq!(
            ProfileStore::clone_count(),
            before,
            "no-churn routing must not copy the ProfileStore"
        );
        // churn enabled but nobody warming: still zero copies (the
        // warm-up overlay only materializes multipliers, never rows)
        gw.enable_churn(&crate::lifecycle::ChurnConfig::default());
        let before = ProfileStore::clone_count();
        for i in 0..50 {
            gw.route_at(&img, i % 7, i as f64 * 0.01).unwrap();
        }
        assert_eq!(
            ProfileStore::clone_count(),
            before,
            "membership routing without warm-up must not copy either"
        );
    }

    #[test]
    fn route_with_estimate_reuses_the_paid_estimate() {
        // retry semantics: routing with a cached estimate must carry
        // the original estimate/cost into the RoutedRequest and leave
        // the estimator state untouched (the request pays GatewayCost
        // exactly once, at first admission).
        let e = engine();
        let store = tiny_store();
        let pool =
            NodePool::deploy(&e, &store.pairs(), &fleet(), 1).unwrap();
        let mut gw = Gateway::new(
            &e,
            router_by_name("OB").unwrap(),
            store,
            pool,
            5.0,
            1,
        );
        let mut m = RunMetrics::new("OB");
        let crowded = scene::render_spec(&SceneSpec {
            id: 0,
            seed: 9,
            n_objects: 7,
        });
        // prime the OB estimator with a real response
        let o1 = gw
            .handle(&crowded.image, 7, &crowded.gt, &mut m)
            .unwrap();
        // a retry copy re-enters routing with its ORIGINAL estimate
        // and cost — not a fresh OB reading
        let cost = crate::estimators::GatewayCost {
            latency_s: 0.5,
            energy_mwh: 0.25,
        };
        let routed = gw.route_with_estimate(3, 7, cost, 0.0).unwrap();
        assert_eq!(routed.estimate, 3, "original estimate carried");
        assert_eq!(routed.cost.latency_s, 0.5, "original cost carried");
        assert_eq!(routed.cost.energy_mwh, 0.25);
        // the estimator was neither consulted nor advanced: the next
        // estimate is still the previous backend response's count
        let (next, next_cost) =
            gw.estimate_request(&crowded.image, 7).unwrap();
        assert_eq!(next, o1.detections);
        assert_eq!(next_cost.latency_s, 0.0, "OB estimation is free");
    }

    #[test]
    fn amortize_clamps_at_zero() {
        assert_eq!(amortize(3.0, 1.0), 2.0);
        assert_eq!(amortize(1.0, 1.0), 0.0);
        assert_eq!(amortize(0.001, 5.0), 0.0, "never negative");
        assert_eq!(amortize(0.0, 0.0), 0.0);
    }

    #[test]
    fn empty_batch_is_an_error() {
        let e = engine();
        let store = tiny_store();
        let pool =
            NodePool::deploy(&e, &store.pairs(), &fleet(), 1).unwrap();
        let mut gw = Gateway::new(
            &e,
            router_by_name("ED").unwrap(),
            store,
            pool,
            5.0,
            1,
        );
        let mut m = RunMetrics::new("ED");
        let err = gw.handle_batch(&[], &mut m).unwrap_err();
        assert!(err.to_string().contains("empty batch"));
        assert_eq!(m.requests, 0);
    }

    #[test]
    fn batch_pays_estimator_and_network_once_and_amortizes_preprocess() {
        // Differential against the single-request path: two gateways
        // with identical pools (same deploy seed => same per-node
        // jitter sequence) serve the SAME image three times — once as
        // one batch, once as three independent requests. The batch must
        // pay the estimator and the network hop exactly once and save
        // 2x the amortized preprocess share on both latency and energy.
        let e = engine();
        let img =
            scene::render_spec(&SceneSpec { id: 0, seed: 5, n_objects: 2 });
        let batch: Vec<(Vec<f32>, usize, Vec<GtBox>)> = (0..3)
            .map(|_| (img.image.clone(), img.gt.len(), img.gt.clone()))
            .collect();
        let build = |e: &'_ Engine| {
            let store = tiny_store();
            let pool =
                NodePool::deploy(e, &store.pairs(), &fleet(), 7).unwrap();
            Gateway::new(e, router_by_name("ED").unwrap(), store, pool, 5.0, 7)
        };
        let per = crate::devices::gateway_spec()
            .profile(&e.meta(crate::models::CANNY_MODEL).unwrap());

        let mut gw_b = build(&e);
        let mut m_b = RunMetrics::new("batch");
        let out = gw_b.handle_batch(&batch, &mut m_b).unwrap();
        assert_eq!(out.detections_per_image.len(), 3);
        assert_eq!(m_b.requests, 3);
        let pair_id = gw_b.store().id_of(&out.pair).unwrap();
        let (save_s, save_mwh) = gw_b.batch_savings(pair_id);
        assert!(save_s > 0.0 && save_mwh > 0.0);
        // the batch's queue slot is released once it drains
        assert_eq!(gw_b.pool().queue_depth_id(pair_id), 0);

        let mut gw_s = build(&e);
        let mut m_s = RunMetrics::new("single");
        for (image, count, gt) in &batch {
            gw_s.handle(image, *count, gt, &mut m_s).unwrap();
        }
        assert_eq!(m_s.requests, 3);

        // estimator ran once for the batch, three times single-shot
        assert!((m_b.gateway_energy_mwh - per.energy_mwh).abs() < 1e-12);
        assert!(
            (m_s.gateway_energy_mwh - 3.0 * per.energy_mwh).abs() < 1e-12
        );
        // NETWORK_S charged once per batch, and two members amortize:
        // the single-shot run is dearer by exactly 2 x (estimator
        // latency + network hop + preprocess saving)
        let extra = m_s.total_latency_s - m_b.total_latency_s;
        assert!(
            (extra - 2.0 * (per.latency_s + devices::NETWORK_S + save_s))
                .abs()
                < 1e-9,
            "latency delta {extra}"
        );
        let extra_e = m_s.total_energy_mwh() - m_b.total_energy_mwh();
        assert!(
            (extra_e - 2.0 * (per.energy_mwh + save_mwh)).abs() < 1e-9,
            "energy delta {extra_e}"
        );
    }

    #[test]
    fn batch_routes_through_node_admission() {
        // the regression this fixes: handle_batch used to reach the
        // node via pool.get_id without health checks or slot
        // accounting, so batches landed on crashed nodes and were
        // invisible to occupancy-aware routing
        let e = engine();
        let store = tiny_store();
        let pool =
            NodePool::deploy(&e, &store.pairs(), &fleet(), 1).unwrap();
        let mut gw = Gateway::new(
            &e,
            router_by_name("LE").unwrap(),
            store,
            pool,
            5.0,
            1,
        );
        let cheap = PairKey::new("ssd_v1", "jetson_orin_nano");
        let big = PairKey::new("yolov8n", "pi5_aihat");
        let img = vec![0.5f32; 384 * 384];
        let batch = vec![(img, 0usize, Vec::<GtBox>::new())];
        let mut m = RunMetrics::new("LE");
        // healthy pool: LE's batch lands on the cheap pair
        let out = gw.handle_batch(&batch, &mut m).unwrap();
        assert_eq!(out.pair, cheap);
        // cheap pair down: admission walks to the fallback pair
        // instead of dispatching onto the crashed node
        gw.pool_mut().set_health(&cheap, false);
        let before = gw.fallbacks;
        let out = gw.handle_batch(&batch, &mut m).unwrap();
        assert_eq!(out.pair, big);
        assert!(gw.fallbacks > before, "fallback re-route counted");
        // every node down: the batch is refused at admission with the
        // typed shed error, not served
        gw.pool_mut().set_health(&big, false);
        let err = gw.handle_batch(&batch, &mut m).unwrap_err();
        assert!(err.is::<NoEndpoint>(), "{err}");
        // no slot leaked by any of the above
        let big_id = gw.store().id_of(&big).unwrap();
        let cheap_id = gw.store().id_of(&cheap).unwrap();
        assert_eq!(gw.pool().queue_depth_id(big_id), 0);
        assert_eq!(gw.pool().queue_depth_id(cheap_id), 0);
    }
}
