//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! `Engine` wraps a `PjRtClient` (CPU) and a cache of compiled
//! executables, one per artifact. The hot path is
//! `Engine::infer(name, &input) -> &[f32]`: one host-to-literal copy, one
//! PJRT execution, one literal-to-host copy into a reusable per-model
//! output buffer (no per-request allocation after warm-up).
//!
//! PJRT handles are raw pointers (`!Send`), so an `Engine` lives on one
//! thread; the coordinator is built around that (DESIGN.md §4 runtime).

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;

use anyhow::Result;

use crate::models::{ModelMeta, ModelRegistry};

/// One compiled artifact plus its reusable output buffer.
struct LoadedModel {
    exe: xla::PjRtLoadedExecutable,
    meta: ModelMeta,
}

/// PJRT engine: compiles artifacts on first use and caches executables.
pub struct Engine {
    client: xla::PjRtClient,
    registry: ModelRegistry,
    loaded: RefCell<BTreeMap<String, std::rc::Rc<LoadedModel>>>,
    /// Cumulative wall time spent inside PJRT execution (profiling aid).
    exec_nanos: std::cell::Cell<u64>,
    exec_count: std::cell::Cell<u64>,
}

impl Engine {
    /// Create a CPU engine over an artifact directory.
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let registry = ModelRegistry::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self {
            client,
            registry,
            loaded: RefCell::new(BTreeMap::new()),
            exec_nanos: std::cell::Cell::new(0),
            exec_count: std::cell::Cell::new(0),
        })
    }

    pub fn registry(&self) -> &ModelRegistry {
        &self.registry
    }

    /// Compile (or fetch the cached) executable for `name`.
    fn load(&self, name: &str) -> Result<std::rc::Rc<LoadedModel>> {
        if let Some(m) = self.loaded.borrow().get(name) {
            return Ok(m.clone());
        }
        let meta = self.registry.get(name)?.clone();
        let proto = xla::HloModuleProto::from_text_file(&meta.file)
            .map_err(|e| {
                anyhow::anyhow!("loading {}: {e:?}", meta.file.display())
            })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
        let model = std::rc::Rc::new(LoadedModel { exe, meta });
        self.loaded
            .borrow_mut()
            .insert(name.to_string(), model.clone());
        Ok(model)
    }

    /// Eagerly compile a set of models (warm-up before serving).
    pub fn preload(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.load(n)?;
        }
        Ok(())
    }

    /// Run one inference, returning an owned copy of the output.
    ///
    /// Hot paths should prefer [`Engine::infer_into`], which writes into
    /// a caller-owned buffer and avoids the output copy (up to ~8 MB per
    /// request for the largest model) — see EXPERIMENTS.md §Perf.
    pub fn infer(&self, name: &str, input: &[f32]) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.infer_into(name, input, &mut out)?;
        Ok(out)
    }

    /// Run one inference into `out` (resized to the output length).
    pub fn infer_into(
        &self,
        name: &str,
        input: &[f32],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let model = self.load(name)?;
        anyhow::ensure!(
            input.len() == model.meta.input_len(),
            "{name}: input length {} != expected {}",
            input.len(),
            model.meta.input_len()
        );
        let dims: Vec<usize> =
            model.meta.input_shape.iter().copied().collect();
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(
                input.as_ptr() as *const u8,
                std::mem::size_of_val(input),
            )
        };
        let lit = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            &dims,
            bytes,
        )
        .map_err(|e| anyhow::anyhow!("literal for {name}: {e:?}"))?;

        let t0 = std::time::Instant::now();
        let result = model
            .exe
            .execute::<xla::Literal>(&[lit])
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?;
        let out_lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {name}: {e:?}"))?
            // artifacts are lowered with return_tuple=True -> 1-tuple
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("untuple {name}: {e:?}"))?;
        self.exec_nanos.set(
            self.exec_nanos.get() + t0.elapsed().as_nanos() as u64,
        );
        self.exec_count.set(self.exec_count.get() + 1);

        out.resize(model.meta.output_len(), 0.0);
        out_lit
            .copy_raw_to::<f32>(out)
            .map_err(|e| anyhow::anyhow!("copy out {name}: {e:?}"))?;
        Ok(())
    }

    /// (total PJRT execution seconds, execution count) since startup.
    pub fn exec_stats(&self) -> (f64, u64) {
        (
            self.exec_nanos.get() as f64 * 1e-9,
            self.exec_count.get(),
        )
    }

    /// Output shape of a model, for decoders.
    pub fn meta(&self, name: &str) -> Result<ModelMeta> {
        Ok(self.registry.get(name)?.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn engine() -> Engine {
        Engine::new(&artifacts_dir()).expect("engine")
    }

    #[test]
    fn infer_ssd_v1_shapes_and_finiteness() {
        let e = engine();
        let input = vec![0.5f32; 384 * 384];
        let out = e.infer("ssd_v1", &input).unwrap();
        assert_eq!(out.len(), 2 * 3 * 96 * 96);
        assert!(out.iter().all(|x| x.is_finite()));
        // constant image -> no DoG response anywhere
        assert!(out.iter().all(|&x| x.abs() < 1e-4));
    }

    #[test]
    fn infer_detects_planted_bright_blob() {
        let e = engine();
        let mut img = vec![0.5f32; 384 * 384];
        // gaussian bump radius ~20 at (192, 192)
        for y in 0..384 {
            for x in 0..384 {
                let dx = x as f32 - 192.0;
                let dy = y as f32 - 192.0;
                let s = 10.0f32;
                img[y * 384 + x] +=
                    0.45 * (-0.5 * (dx * dx + dy * dy) / (s * s)).exp();
            }
        }
        let out = e.infer("yolov8n", &img).unwrap();
        let meta = e.meta("yolov8n").unwrap();
        let (mut best, mut arg) = (0.0f32, 0usize);
        for (i, &v) in out.iter().enumerate() {
            if v > best {
                best = v;
                arg = i;
            }
        }
        assert!(best > meta.threshold as f32, "peak {best}");
        // index -> (cls, band, y, x)
        let res = meta.res;
        let cls = arg / (meta.k * res * res);
        let rem = arg % (meta.k * res * res);
        let y = (rem % (res * res)) / res;
        let x = rem % res;
        assert_eq!(cls, 0);
        let f = meta.factor;
        assert!((y * f).abs_diff(192) <= 2 * f, "y={y}");
        assert!((x * f).abs_diff(192) <= 2 * f, "x={x}");
    }

    #[test]
    fn wrong_input_length_is_error() {
        let e = engine();
        assert!(e.infer("ssd_v1", &[0.0; 10]).is_err());
    }

    #[test]
    fn canny_artifact_runs() {
        let e = engine();
        let mut img = vec![0.2f32; 384 * 384];
        for y in 0..384 {
            for x in 192..384 {
                img[y * 384 + x] = 0.8;
            }
        }
        let out = e.infer("canny", &img).unwrap();
        assert_eq!(out.len(), 96 * 96);
        assert!(out.iter().any(|&v| v == 2.0), "strong edge expected");
        assert!(out
            .iter()
            .all(|&v| v == 0.0 || v == 1.0 || v == 2.0));
    }

    #[test]
    fn exec_stats_accumulate() {
        let e = engine();
        let input = vec![0.5f32; 384 * 384];
        e.infer("ssd_v1", &input).unwrap();
        e.infer("ssd_v1", &input).unwrap();
        let (secs, count) = e.exec_stats();
        assert_eq!(count, 2);
        assert!(secs > 0.0);
    }
}
