//! # ECORE — Energy-Conscious Optimized Routing for DL Models at the Edge
//!
//! Reproduction of Alqahtani et al. (SENSYS 2025) as a three-layer
//! Rust + JAX + Pallas system. This crate is Layer 3: the coordinator.
//! It routes image requests across a pool of simulated heterogeneous edge
//! devices, each executing a real AOT-compiled detector artifact through
//! PJRT; Python exists only on the build path (`python/compile/`).
//!
//! Module map (see DESIGN.md §4 for the full inventory):
//!
//! * [`util`] — substrates: deterministic RNG, JSON, CLI, bench, prop.
//! * [`adapt`] — online adaptation: telemetry-driven profile
//!   correction (EWMA observed/predicted overlays on the routing view)
//!   and energy-proportional autoscaling through the lifecycle
//!   power-down/warm-up path.
//! * [`models`] — artifact manifest registry (build-path contract).
//! * [`runtime`] — PJRT engine: HLO-text load, compile cache, inference.
//! * [`dataset`] — synthetic COCO-like scenes, balanced/sorted set, video.
//! * [`detection`] — boxes, IoU, heat-map decode, COCO-style mAP.
//! * [`devices`] — edge-device energy/latency simulator (8 devices).
//! * [`profiling`] — offline per-(model, device, group) profiler.
//! * [`router`] — Algorithm 1 greedy router + the six baselines.
//! * [`estimators`] — object-count estimators: Oracle, ED, SF, OB.
//! * [`nodes`] — backend edge-node pool bound to the PJRT engine.
//! * [`gateway`] — the serving loop gluing estimator → router → node.
//! * [`lifecycle`] — node churn: seeded failure/recovery process,
//!   probe-driven membership (stale health views), resilience policies
//!   (drop / retry / hedge) for requests lost to crashes.
//! * [`workload`] — closed-loop (piggy-backed) request driver, plus the
//!   open-loop discrete-event concurrent driver ([`workload::openloop`]).
//! * [`fleet`] — multi-gateway sharded serving: synthesized N-node
//!   fleets partitioned over K shard gateways with cross-shard fallback.
//! * [`metrics`] — energy/latency/accuracy accounting and reports.
//! * [`obs`] — option-gated observability: request span tracing,
//!   virtual-time series metrics, deterministic per-shard merge, and
//!   streaming JSONL/prom export.
//! * [`experiments`] — one driver per paper table/figure, plus the
//!   open-loop saturation and fleet sweeps.

pub mod adapt;
pub mod config;
pub mod dataset;
pub mod detection;
pub mod devices;
pub mod estimators;
pub mod experiments;
pub mod fleet;
pub mod gateway;
pub mod lifecycle;
pub mod metrics;
pub mod models;
pub mod nodes;
pub mod obs;
pub mod profiling;
pub mod router;
pub mod runtime;
pub mod util;
pub mod workload;

use std::path::PathBuf;

/// Default artifacts directory: `<crate root>/artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
