//! Experiment drivers: one per paper table/figure (DESIGN.md §5).
//!
//! | id        | paper artefact                                   |
//! |-----------|--------------------------------------------------|
//! | `fig2`    | prelim: energy & mAP, 1-obj vs 4+-obj groups     |
//! | `fig4`    | COCO object-count distribution                   |
//! | `fig5`    | 64-pair Pareto grid (energy vs mAP)              |
//! | `table1`  | testbed selection (per-metric champions)         |
//! | `fig6`    | full-COCO router comparison @ delta=5            |
//! | `fig7`    | balanced-sorted dataset comparison               |
//! | `fig8`    | pedestrian-video comparison                      |
//! | `fig9`    | delta_mAP sweep x {Orc, ED, SF, OB}              |
//! | `overhead`| gateway overhead per router (§4.2)               |
//! | `openloop`| open-loop saturation sweep (beyond the paper)    |
//! | `fleet`   | sharded multi-gateway fleet sweep (beyond paper) |
//! | `churn`   | router survivability under node churn (§9)       |
//! | `slo`     | SLO attainment + dynamic batching sweep (§11)    |
//! | `adapt`   | online adaptation under device drift (§12)       |
//! | `campaign`| correlated failure campaigns + failover (§15)    |
//!
//! Every driver prints the paper-style table and writes
//! `results/<id>.json` for downstream plotting.

pub mod ablations;
pub mod adapt;
pub mod campaign;
pub mod churn;
pub mod fleet;
pub mod openloop;
pub mod serve;
pub mod slo;
pub mod static_figs;
pub mod sweep;

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::config::ExperimentConfig;
use crate::devices;
use crate::profiling::{self, ProfilerConfig};
use crate::router::{GroupRules, ProfileStore};
use crate::runtime::Engine;
use crate::util::json::Json;

pub const ALL_EXPERIMENTS: [&str; 15] = [
    "fig2", "fig4", "fig5", "table1", "fig6", "fig7", "fig8", "fig9",
    "overhead", "openloop", "fleet", "churn", "slo", "adapt", "campaign",
];

/// Shared experiment context.
pub struct Harness {
    pub engine: Engine,
    pub cfg: ExperimentConfig,
    pub out_dir: PathBuf,
    /// Compiled-artifact cache root (the directory `engine` was opened
    /// on); parallel fleet workers open their own engines against it.
    artifacts: PathBuf,
    /// Cached full profiling grid.
    profiles: std::cell::RefCell<Option<ProfileStore>>,
}

impl Harness {
    pub fn new(cfg: ExperimentConfig) -> Result<Self> {
        let artifacts = if cfg.artifacts_dir.is_empty() {
            crate::default_artifacts_dir()
        } else {
            PathBuf::from(&cfg.artifacts_dir)
        };
        let out_dir = artifacts
            .parent()
            .unwrap_or(std::path::Path::new("."))
            .join("results");
        std::fs::create_dir_all(&out_dir)?;
        Ok(Self {
            engine: Engine::new(&artifacts)
                .context("starting PJRT engine")?,
            cfg,
            out_dir,
            artifacts,
            profiles: std::cell::RefCell::new(None),
        })
    }

    /// Compiled-artifact cache root shared by every engine this
    /// harness (or its worker threads) opens.
    pub fn artifacts_dir(&self) -> &std::path::Path {
        &self.artifacts
    }

    /// The full 8x8x5 profiling grid, computed once per process and
    /// persisted to `results/profiles.json` (reused across runs unless
    /// the config's profiling parameters changed).
    pub fn profiles(&self) -> Result<ProfileStore> {
        if let Some(p) = self.profiles.borrow().as_ref() {
            return Ok(p.clone());
        }
        // bump PROFILE_CACHE_VERSION whenever the device model or decode
        // path changes — the cache key must reflect everything that
        // determines profile contents.
        const PROFILE_CACHE_VERSION: u32 = 3;
        let path = self.out_dir.join(format!(
            "profiles_v{PROFILE_CACHE_VERSION}_g{}_s{}.json",
            self.cfg.profile_per_group, self.cfg.seed
        ));
        let store = if path.exists() {
            ProfileStore::load(&path)?
        } else {
            eprintln!(
                "[profiling] building 8x8x5 grid ({} images/group)...",
                self.cfg.profile_per_group
            );
            let store = profiling::profile_fleet(
                &self.engine,
                &devices::fleet(),
                &GroupRules::paper_default(),
                &ProfilerConfig {
                    images_per_group: self.cfg.profile_per_group,
                    seed: self.cfg.seed ^ 0xF0F1_u64,
                    ..Default::default()
                },
            )?;
            store.save(&path)?;
            store
        };
        *self.profiles.borrow_mut() = Some(store.clone());
        Ok(store)
    }

    pub fn save_json(&self, id: &str, j: &Json) -> Result<()> {
        let path = self.out_dir.join(format!("{id}.json"));
        std::fs::write(&path, j.pretty())?;
        eprintln!("[{id}] wrote {}", path.display());
        Ok(())
    }

    /// Dispatch one experiment by id.
    pub fn run(&self, id: &str) -> Result<()> {
        match id {
            "fig2" => static_figs::fig2(self),
            "fig4" => static_figs::fig4(self),
            "fig5" => static_figs::fig5(self),
            "table1" => static_figs::table1(self),
            "fig6" => serve::fig6(self),
            "fig7" => serve::fig7(self),
            "fig8" => serve::fig8(self),
            "fig9" => sweep::fig9(self),
            "overhead" => serve::overhead(self),
            "openloop" => openloop::openloop(self),
            "fleet" => fleet::fleet(self),
            "churn" => churn::churn(self),
            "slo" => slo::slo(self),
            "adapt" => adapt::adapt(self),
            "campaign" => campaign::campaign(self),
            "ablation_groups" => ablations::ablation_groups(self),
            "ablation_batch" => ablations::ablation_batch(self),
            "ablation_weighted" => ablations::ablation_weighted(self),
            "ablation_drift" => ablations::ablation_drift(self),
            "ablation_failover" => ablations::ablation_failover(self),
            "ablations" => ablations::run_all(self),
            "all" => {
                for e in ALL_EXPERIMENTS {
                    eprintln!("=== experiment {e} ===");
                    self.run(e)?;
                }
                Ok(())
            }
            other => bail!(
                "unknown experiment '{other}' (known: {})",
                ALL_EXPERIMENTS.join(", ")
            ),
        }
    }
}
