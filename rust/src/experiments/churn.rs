//! Churn sweep: router survivability under node failures (DESIGN.md
//! §9).
//!
//! For each (availability, router, resilience policy) cell the driver
//! deploys a fresh Table-1 pool, switches the gateway to probe-driven
//! membership, replays the same pre-rendered request set through the
//! open-loop simulator with a seeded crash/rejoin timeline (MTBF
//! derived from the availability level, MTTR fixed), and reports
//! goodput, tail latency, energy per request, shed/lost/retried/hedged
//! counts, crash count, and mean time-to-recover. Availability 1.0 is
//! the no-churn baseline every policy is measured against — the
//! headline question is how much of that goodput each policy buys back
//! on a degraded fleet, and at what energy cost (hedging pays double).

use anyhow::{Context, Result};

use super::serve::{build_gateway, deployed_store};
use super::Harness;
use crate::dataset::{coco, GtBox, Scene};
use crate::gateway::router_by_name;
use crate::lifecycle::{mtbf_for_availability, ChurnConfig, ResiliencePolicy};
use crate::util::json::Json;
use crate::workload::openloop::{self, ArrivalProcess, OpenLoopConfig};

/// The `churn` experiment: sweep availability x router x policy.
pub fn churn(h: &Harness) -> Result<()> {
    let n = h.cfg.churn_requests.max(1);
    let ds = coco::build(n, h.cfg.seed ^ 0xC4A5);
    let frames: Vec<Scene> = ds.iter_scenes().collect();
    let gts: Vec<Vec<GtBox>> =
        frames.iter().map(|s| s.gt.clone()).collect();
    let deployed = deployed_store(h)?;
    let base = h.cfg.churn_config()?;
    eprintln!(
        "[churn] pool {} pairs, {} requests @ {} req/s, mttr {} s, probes every {} s (timeout {} s)",
        deployed.pairs().len(),
        n,
        h.cfg.churn_rate_rps,
        base.mttr_s,
        base.probe_interval_s,
        base.probe_timeout_s
    );
    println!(
        "--- churn (availability x router x resilience over {n} requests) ---"
    );
    println!(
        "{:<6} {:>6} {:>7} {:>9} {:>9} {:>12} {:>5} {:>5} {:>6} {:>6} {:>8} {:>8}",
        "router",
        "avail",
        "policy",
        "goodput",
        "p99_ms",
        "mWh_per_req",
        "drop",
        "lost",
        "retry",
        "hedge",
        "crashes",
        "ttr_s"
    );
    let mut rows = Vec::new();
    for &avail in &h.cfg.churn_availability {
        // every policy is swept at every availability — including 1.0,
        // because hedging differs even without crashes (it duplicates
        // every request), so each policy needs its own no-churn
        // baseline cell
        for name in &h.cfg.churn_routers {
            let spec = router_by_name(name)
                .with_context(|| format!("unknown router '{name}'"))?;
            for pname in &h.cfg.churn_policies {
                let policy = ResiliencePolicy::parse(
                    pname,
                    h.cfg.churn_retry_budget,
                )
                .with_context(|| {
                    format!(
                        "unknown resilience policy '{pname}' (drop|retry|hedge)"
                    )
                })?;
                let churn_cfg = ChurnConfig {
                    mtbf_s: mtbf_for_availability(avail, base.mttr_s),
                    policy,
                    ..base.clone()
                };
                let mut gw =
                    build_gateway(h, spec, &deployed, h.cfg.delta_map)?;
                let report = openloop::run_frames(
                    &mut gw,
                    &frames,
                    &gts,
                    &OpenLoopConfig {
                        arrivals: ArrivalProcess::Poisson {
                            rate_rps: h.cfg.churn_rate_rps,
                        },
                        queue_capacity: h.cfg.queue_capacity,
                        seed: h.cfg.seed,
                        churn: Some(churn_cfg),
                        slo: None,
                        adapt: None,
                        campaign: None,
                        obs: None,
                    },
                )?;
                let c =
                    report.churn.clone().expect("churn report missing");
                println!(
                    "{:<6} {:>6.2} {:>7} {:>9.2} {:>9.1} {:>12.4} {:>5} {:>5} {:>6} {:>6} {:>8} {:>8.2}",
                    spec.name,
                    avail,
                    policy.label(),
                    report.goodput_rps(),
                    1000.0 * report.metrics.latency_percentile(99.0),
                    report.energy_per_request_mwh(),
                    report.dropped,
                    c.lost,
                    c.retried,
                    c.hedged,
                    c.crashes,
                    c.mean_time_to_recover_s,
                );
                rows.push(Json::obj(vec![
                    ("router", Json::str(spec.name)),
                    ("availability", Json::num(avail)),
                    ("policy", Json::str(policy.label())),
                    ("rate_rps", Json::num(h.cfg.churn_rate_rps)),
                    ("report", report.to_json()),
                ]));
            }
        }
        println!();
    }
    h.save_json("churn", &Json::Arr(rows))
}
