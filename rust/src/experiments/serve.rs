//! Serving experiments: the router-comparison panels (Figs. 6, 7, 8) and
//! the gateway-overhead table (§4.2).
//!
//! Each run deploys the Table-1 node pool, wires one router
//! configuration, drives the closed-loop workload over the dataset, and
//! reports (mAP, total latency, dynamic energy, gateway overhead) — the
//! same rows the paper's figures plot.

use anyhow::Result;

use super::Harness;
use crate::dataset::{balanced, coco, video, Dataset};
use crate::gateway::{paper_routers, router_by_name, Gateway, RouterSpec};
use crate::metrics::{render_table, RunMetrics};
use crate::nodes::NodePool;
use crate::profiling::testbed;
use crate::router::ProfileStore;
use crate::util::json::Json;
use crate::util::stats::pct_change;
use crate::workload;

/// Deploy a fresh pool over `deployed`'s pairs and wire one router —
/// the single construction point shared by the closed-loop panels, the
/// open-loop sweep, and the `serve` CLI, so every driver builds its
/// gateway from the same fleet/seed recipe.
pub fn build_gateway<'e>(
    h: &'e Harness,
    spec: RouterSpec,
    deployed: &ProfileStore,
    delta_map: f64,
) -> Result<Gateway<'e>> {
    let pool = NodePool::deploy(
        &h.engine,
        &deployed.pairs(),
        &crate::devices::fleet(),
        h.cfg.seed,
    )?;
    Ok(Gateway::new(
        &h.engine,
        spec,
        deployed.clone(),
        pool,
        delta_map,
        h.cfg.seed,
    ))
}

/// Deploy pool + run one router over a dataset.
pub fn run_router_on_dataset(
    h: &Harness,
    spec: RouterSpec,
    deployed: &ProfileStore,
    dataset: &Dataset,
) -> Result<RunMetrics> {
    run_router_with_delta(h, spec, deployed, dataset, h.cfg.delta_map)
}

/// Same, with an explicit delta_mAP (used by the Fig. 9 sweep).
pub fn run_router_with_delta(
    h: &Harness,
    spec: RouterSpec,
    deployed: &ProfileStore,
    dataset: &Dataset,
    delta_map: f64,
) -> Result<RunMetrics> {
    let mut gw = build_gateway(h, spec, deployed, delta_map)?;
    workload::run_dataset(&mut gw, dataset)
}

/// The deployed testbed store: full grid restricted to Table-1 pairs.
pub fn deployed_store(h: &Harness) -> Result<ProfileStore> {
    let full = h.profiles()?;
    let rows = testbed::select(&full);
    Ok(full.restrict(&testbed::pool(&rows)))
}

pub(crate) fn selected_routers(h: &Harness) -> Vec<RouterSpec> {
    h.cfg
        .routers
        .iter()
        .filter_map(|n| router_by_name(n))
        .collect()
}

/// Shared panel driver for figs 6/7/8.
///
/// Scenes are rendered ONCE and shared across all router runs (a ~10x
/// reduction in renderer work for the ten-router panels; see
/// EXPERIMENTS.md §Perf).
fn router_panel(
    h: &Harness,
    id: &str,
    dataset: &Dataset,
) -> Result<Vec<RunMetrics>> {
    let deployed = deployed_store(h)?;
    eprintln!(
        "[{id}] pool: {} pairs, dataset: {} ({} images), delta={}",
        deployed.pairs().len(),
        dataset.name,
        dataset.len(),
        h.cfg.delta_map
    );
    let scenes: Vec<crate::dataset::Scene> =
        dataset.iter_scenes().collect();
    let gts: Vec<Vec<crate::dataset::GtBox>> =
        scenes.iter().map(|s| s.gt.clone()).collect();
    let mut runs = Vec::new();
    for spec in selected_routers(h) {
        let mut gw = build_gateway(h, spec, &deployed, h.cfg.delta_map)?;
        let m = workload::run_frames(&mut gw, &scenes, &gts)?;
        eprintln!(
            "[{id}] {:<4} mAP={:6.2} energy={:9.2} mWh latency={:8.2} s",
            m.label,
            m.map(),
            m.total_energy_mwh(),
            m.total_latency_s
        );
        runs.push(m);
    }
    print_panel(id, &runs);
    let j = Json::Arr(runs.iter().map(|m| m.to_json()).collect());
    h.save_json(id, &j)?;
    Ok(runs)
}

/// Print the table plus paper-shape normalized comparisons.
pub fn print_panel(id: &str, runs: &[RunMetrics]) {
    let refs: Vec<&RunMetrics> = runs.iter().collect();
    println!("--- {id} ---");
    println!("{}", render_table(&refs));
    let find = |label: &str| runs.iter().find(|m| m.label == label);
    if let (Some(le), Some(hmg)) = (find("LE"), find("HMG")) {
        println!(
            "reference points: LE energy = {:.2} mWh (lower bound), HMG mAP = {:.2} (upper bound)",
            le.total_energy_mwh(),
            hmg.map()
        );
        for m in runs {
            println!(
                "  {:<4} energy +{:.0}% vs LE | mAP {:+.1}% vs HMG | energy {:+.0}% vs HMG",
                m.label,
                pct_change(le.total_energy_mwh(), m.total_energy_mwh()),
                pct_change(hmg.map(), m.map()),
                pct_change(hmg.total_energy_mwh(), m.total_energy_mwh()),
            );
        }
    }
}

/// Fig. 6: full synthetic-COCO comparison.
pub fn fig6(h: &Harness) -> Result<()> {
    let ds = coco::build(h.cfg.coco_images, h.cfg.seed ^ 0xC0C0);
    router_panel(h, "fig6", &ds)?;
    Ok(())
}

/// Fig. 7: balanced sorted dataset.
pub fn fig7(h: &Harness) -> Result<()> {
    let ds = balanced::build(h.cfg.balanced_per_group, h.cfg.seed ^ 0xBA1A);
    router_panel(h, "fig7", &ds)?;
    Ok(())
}

/// Fig. 8: pedestrian video with pseudo ground truth from yolov8x.
pub fn fig8(h: &Harness) -> Result<()> {
    let frames = video::build_frames(h.cfg.video_frames, h.cfg.seed ^ 0x71DE);
    let pseudo = workload::pseudo_annotate(&h.engine, &frames)?;
    let deployed = deployed_store(h)?;
    eprintln!(
        "[fig8] {} frames, pool {} pairs",
        frames.len(),
        deployed.pairs().len()
    );
    let mut runs = Vec::new();
    for spec in selected_routers(h) {
        let mut gw = build_gateway(h, spec, &deployed, h.cfg.delta_map)?;
        let m = workload::run_frames(&mut gw, &frames, &pseudo)?;
        eprintln!(
            "[fig8] {:<4} mAP={:6.2} energy={:9.2} latency={:8.2}",
            m.label,
            m.map(),
            m.total_energy_mwh(),
            m.total_latency_s
        );
        runs.push(m);
    }
    print_panel("fig8", &runs);
    let j = Json::Arr(runs.iter().map(|m| m.to_json()).collect());
    h.save_json("fig8", &j)?;
    Ok(())
}

/// §4.2 gateway-overhead table: per-router estimation cost, isolated.
pub fn overhead(h: &Harness) -> Result<()> {
    let n = (h.cfg.coco_images / 4).max(50);
    let ds = coco::build(n, h.cfg.seed ^ 0x0EAD);
    let deployed = deployed_store(h)?;
    println!("--- overhead (per-request gateway cost over {n} images) ---");
    println!(
        "{:<6} {:>14} {:>14} {:>10}",
        "router", "gw_energy_uWh", "gw_latency_ms", "est_err"
    );
    let mut rows = Vec::new();
    for spec in paper_routers() {
        let m = run_router_on_dataset(h, spec, &deployed, &ds)?;
        println!(
            "{:<6} {:>14.3} {:>14.3} {:>10.2}",
            m.label,
            1000.0 * m.gateway_energy_mwh / m.requests as f64,
            1000.0 * m.gateway_latency_s / m.requests as f64,
            m.mean_estimation_error()
        );
        rows.push(Json::obj(vec![
            ("router", Json::str(&m.label)),
            (
                "gw_energy_mwh_per_req",
                Json::num(m.gateway_energy_mwh / m.requests as f64),
            ),
            (
                "gw_latency_s_per_req",
                Json::num(m.gateway_latency_s / m.requests as f64),
            ),
            ("est_err", Json::num(m.mean_estimation_error())),
        ]));
    }
    h.save_json("overhead", &Json::Arr(rows))?;
    Ok(())
}
