//! Campaign sweep: router survivability under correlated failure
//! campaigns (DESIGN.md §15).
//!
//! For each (domain size, outage rate, router, resilience policy) cell
//! the driver synthesizes a sharded fleet from the deployed Table-1
//! store, layers a seeded campaign schedule on probe-driven membership
//! (per-node churn silenced: every failure is a domain-wide outage),
//! replays the same pre-rendered request set, and reports goodput,
//! time-to-recover, and energy per request. The conservation invariant
//! `offered == served + dropped + lost` is asserted on every cell —
//! a campaign may black out whole shards, but no request may vanish
//! from the ledger.
//!
//! With escalation enabled (`campaign_escalate`, on by default) a
//! second phase walks each router's outage rate upward — doubling per
//! step — until goodput collapses below half its calmest-cell value,
//! reporting the breaking point as outages/s.

use anyhow::{Context, Result};

use super::serve::deployed_store;
use super::Harness;
use crate::dataset::{coco, GtBox, Scene};
use crate::fleet::parallel::{run_frames_threads, ParallelFleetSpec};
use crate::fleet::{DispatchPolicy, FleetConfig, FleetReport};
use crate::gateway::router_by_name;
use crate::lifecycle::campaign::CampaignConfig;
use crate::lifecycle::{ChurnConfig, ResiliencePolicy};
use crate::util::json::Json;
use crate::workload::openloop::ArrivalProcess;

/// Run one campaign cell and assert the conservation ledger.
#[allow(clippy::too_many_arguments)]
fn run_cell(
    h: &Harness,
    base: &crate::router::ProfileStore,
    frames: &[Scene],
    gts: &[Vec<GtBox>],
    router: &str,
    churn_cfg: ChurnConfig,
    campaign_cfg: Option<CampaignConfig>,
    dispatch: DispatchPolicy,
) -> Result<FleetReport> {
    let spec = router_by_name(router)
        .with_context(|| format!("unknown router '{router}'"))?;
    let fcfg = FleetConfig {
        n_nodes: h.cfg.campaign_nodes,
        n_shards: h.cfg.campaign_shards,
        perturb: h.cfg.fleet_perturb,
        queue_capacity: h.cfg.queue_capacity,
        dispatch,
        n_sources: h.cfg.fleet_sources,
        seed: h.cfg.seed,
        drift: None,
        churn: Some(churn_cfg),
        slo: None,
        adapt: None,
        campaign: campaign_cfg,
        obs: None,
        threads: h.cfg.fleet_threads,
    };
    let report = run_frames_threads(
        &ParallelFleetSpec {
            artifacts_dir: h.artifacts_dir(),
            base,
            spec,
            delta_map: h.cfg.delta_map,
        },
        &fcfg,
        frames,
        gts,
        &ArrivalProcess::Poisson {
            rate_rps: h.cfg.campaign_rate_rps,
        },
        h.cfg.seed,
    )?;
    let lost = report.churn.as_ref().map_or(0, |c| c.lost);
    anyhow::ensure!(
        report.offered == report.requests() + report.dropped + lost,
        "campaign ledger violated: offered {} != served {} + dropped {} + lost {}",
        report.offered,
        report.requests(),
        report.dropped,
        lost
    );
    Ok(report)
}

/// The `campaign` experiment: sweep domain size x outage rate x router
/// x resilience policy, then (optionally) escalate to each router's
/// breaking point.
pub fn campaign(h: &Harness) -> Result<()> {
    let n = h.cfg.campaign_requests.max(1);
    let ds = coco::build(n, h.cfg.seed ^ 0x0CA5);
    let frames: Vec<Scene> = ds.iter_scenes().collect();
    let gts: Vec<Vec<GtBox>> =
        frames.iter().map(|s| s.gt.clone()).collect();
    let base = deployed_store(h)?;
    let dispatch =
        DispatchPolicy::parse(&h.cfg.fleet_dispatch).with_context(|| {
            format!(
                "unknown dispatch policy '{}' (hash|least|sticky)",
                h.cfg.fleet_dispatch
            )
        })?;
    // per-node churn silenced: the campaign schedule is the only
    // failure source, so cells differ purely in correlation structure
    let churn_base = ChurnConfig {
        mtbf_s: f64::INFINITY,
        ..h.cfg.churn_config()?
    };
    let camp_base = h.cfg.campaign_config()?;
    eprintln!(
        "[campaign] fleet {} nodes / {} shards, {} requests @ {} req/s, gw mtbf {} s, threads {}",
        h.cfg.campaign_nodes,
        h.cfg.campaign_shards,
        n,
        h.cfg.campaign_rate_rps,
        camp_base.gateway_mtbf_s,
        h.cfg.fleet_threads
    );
    println!(
        "--- campaign (domain x outage-rate x router x resilience over {n} requests) ---"
    );
    println!(
        "{:<6} {:>4} {:>7} {:>7} {:>9} {:>12} {:>5} {:>5} {:>8} {:>7} {:>8}",
        "router",
        "dom",
        "out/s",
        "policy",
        "goodput",
        "mWh_per_req",
        "drop",
        "lost",
        "outages",
        "adopt",
        "ttr_s"
    );
    let mut rows = Vec::new();
    for &dsize in &h.cfg.campaign_domain_sizes {
        for &rate in &h.cfg.campaign_outage_rates {
            for router in &h.cfg.campaign_routers {
                for pname in &h.cfg.campaign_policies {
                    let policy = ResiliencePolicy::parse(
                        pname,
                        h.cfg.churn_retry_budget,
                    )
                    .with_context(|| {
                        format!(
                            "unknown resilience policy '{pname}' (drop|retry|hedge)"
                        )
                    })?;
                    let churn_cfg = ChurnConfig {
                        policy,
                        ..churn_base.clone()
                    };
                    let campaign_cfg = CampaignConfig {
                        domain_size: dsize.max(1),
                        domain_mtbf_s: 1.0 / rate.max(1e-9),
                        ..camp_base.clone()
                    };
                    let report = run_cell(
                        h,
                        &base,
                        &frames,
                        &gts,
                        router,
                        churn_cfg,
                        Some(campaign_cfg),
                        dispatch,
                    )?;
                    let c = report
                        .campaign
                        .clone()
                        .expect("campaign report missing");
                    let ch = report
                        .churn
                        .clone()
                        .expect("churn report missing");
                    println!(
                        "{:<6} {:>4} {:>7.3} {:>7} {:>9.2} {:>12.4} {:>5} {:>5} {:>8} {:>7} {:>8.2}",
                        router,
                        dsize,
                        rate,
                        policy.label(),
                        report.goodput_rps(),
                        report.energy_per_request_mwh(),
                        report.dropped,
                        ch.lost,
                        c.domain_outages,
                        c.adoptions,
                        ch.mean_time_to_recover_s,
                    );
                    rows.push(Json::obj(vec![
                        ("phase", Json::str("sweep")),
                        ("router", Json::str(router.as_str())),
                        ("domain_size", Json::num(dsize as f64)),
                        ("outage_rate", Json::num(rate)),
                        ("policy", Json::str(policy.label())),
                        (
                            "rate_rps",
                            Json::num(h.cfg.campaign_rate_rps),
                        ),
                        ("report", report.to_json()),
                    ]));
                }
            }
        }
        println!();
    }
    if h.cfg.campaign_escalate {
        escalate(
            h, &base, &frames, &gts, &churn_base, &camp_base, dispatch,
            &mut rows,
        )?;
    }
    h.save_json("campaign", &Json::Arr(rows))
}

/// Escalation phase: per router, double the outage rate each step
/// until goodput collapses below half the calmest cell's goodput (or
/// the step cap is hit), and report the breaking point.
#[allow(clippy::too_many_arguments)]
fn escalate(
    h: &Harness,
    base: &crate::router::ProfileStore,
    frames: &[Scene],
    gts: &[Vec<GtBox>],
    churn_base: &ChurnConfig,
    camp_base: &CampaignConfig,
    dispatch: DispatchPolicy,
    rows: &mut Vec<Json>,
) -> Result<()> {
    const MAX_STEPS: usize = 6;
    // escalate under retry if the sweep includes it — the policy most
    // runs deploy — else under whatever the sweep led with
    let pname = h
        .cfg
        .campaign_policies
        .iter()
        .find(|p| p.as_str() == "retry")
        .or_else(|| h.cfg.campaign_policies.first())
        .map_or("retry", |s| s.as_str());
    let policy =
        ResiliencePolicy::parse(pname, h.cfg.churn_retry_budget)
            .with_context(|| {
                format!("unknown resilience policy '{pname}'")
            })?;
    let dsize = h
        .cfg
        .campaign_domain_sizes
        .last()
        .copied()
        .unwrap_or(camp_base.domain_size)
        .max(1);
    let base_rate = h
        .cfg
        .campaign_outage_rates
        .first()
        .copied()
        .unwrap_or(0.05)
        .max(1e-9);
    println!("--- campaign escalation (domain {dsize}, policy {}, x2 per step) ---", policy.label());
    println!(
        "{:<6} {:>5} {:>8} {:>9} {:>9} {:>8} {:>9}",
        "router", "step", "out/s", "goodput", "frac", "outages", "broken"
    );
    for router in &h.cfg.campaign_routers {
        let mut baseline = None;
        let mut breaking = None;
        for step in 0..MAX_STEPS {
            let rate = base_rate * (1 << step) as f64;
            let churn_cfg = ChurnConfig {
                policy,
                ..churn_base.clone()
            };
            let campaign_cfg = CampaignConfig {
                domain_size: dsize,
                domain_mtbf_s: 1.0 / rate,
                ..camp_base.clone()
            };
            let report = run_cell(
                h,
                base,
                frames,
                gts,
                router,
                churn_cfg,
                Some(campaign_cfg),
                dispatch,
            )?;
            let good = report.goodput_rps();
            let bl = *baseline.get_or_insert(good.max(1e-9));
            let frac = good / bl;
            let broke = frac < 0.5;
            let c = report
                .campaign
                .clone()
                .expect("campaign report missing");
            println!(
                "{:<6} {:>5} {:>8.3} {:>9.2} {:>9.2} {:>8} {:>9}",
                router,
                step,
                rate,
                good,
                frac,
                c.domain_outages,
                if broke { "yes" } else { "-" }
            );
            rows.push(Json::obj(vec![
                ("phase", Json::str("escalate")),
                ("router", Json::str(router.as_str())),
                ("step", Json::num(step as f64)),
                ("domain_size", Json::num(dsize as f64)),
                ("outage_rate", Json::num(rate)),
                ("policy", Json::str(policy.label())),
                ("goodput_frac", Json::num(frac)),
                ("report", report.to_json()),
            ]));
            if broke {
                breaking = Some(rate);
                break;
            }
        }
        match breaking {
            Some(r) => println!(
                "{router}: breaks at {r:.3} outages/s per domain"
            ),
            None => println!(
                "{router}: survives {MAX_STEPS} escalation steps (last rate {:.3}/s)",
                base_rate * (1 << (MAX_STEPS - 1)) as f64
            ),
        }
    }
    println!();
    Ok(())
}
