//! Fig. 9: delta_mAP sweep — Oracle and the three proposed routers at
//! delta in {0, 5, 10, 15, 20, 25}, reporting mAP / latency / energy per
//! setting (paper §4.3.4, Insight #4).

use anyhow::Result;

use super::serve::deployed_store;
use super::Harness;
use crate::dataset::coco;
use crate::gateway::router_by_name;
use crate::util::json::Json;
use std::collections::BTreeMap;

pub const DELTAS: [f64; 6] = [0.0, 5.0, 10.0, 15.0, 20.0, 25.0];
pub const SWEEP_ROUTERS: [&str; 4] = ["Orc", "ED", "SF", "OB"];

pub fn fig9(h: &Harness) -> Result<()> {
    // a lighter dataset than fig6: the sweep runs 24 full configurations
    let n = (h.cfg.coco_images / 2).max(100);
    let ds = coco::build(n, h.cfg.seed ^ 0xC0C0);
    let deployed = deployed_store(h)?;

    println!("--- fig9 (delta_mAP sweep over {n} images) ---");
    println!(
        "{:<6} {:>6} {:>8} {:>12} {:>12}",
        "router", "delta", "mAP", "energy_mWh", "latency_s"
    );
    let mut out = Vec::new();
    let mut energy_series: BTreeMap<&str, Vec<(f64, f64)>> =
        BTreeMap::new();
    for name in SWEEP_ROUTERS {
        let spec = router_by_name(name).unwrap();
        for delta in DELTAS {
            let m = super::serve::run_router_with_delta(
                h, spec, &deployed, &ds, delta,
            )?;
            println!(
                "{:<6} {:>6.0} {:>8.2} {:>12.2} {:>12.2}",
                name,
                delta,
                m.map(),
                m.total_energy_mwh(),
                m.total_latency_s
            );
            energy_series
                .entry(name)
                .or_default()
                .push((delta, m.total_energy_mwh()));
            out.push(Json::obj(vec![
                ("router", Json::str(name)),
                ("delta", Json::num(delta)),
                ("map", Json::num(m.map())),
                ("energy_mwh", Json::num(m.total_energy_mwh())),
                ("latency_s", Json::num(m.total_latency_s)),
            ]));
        }
    }
    let series: Vec<(&str, Vec<(f64, f64)>)> = energy_series
        .iter()
        .map(|(k, v)| (*k, v.clone()))
        .collect();
    println!(
        "{}",
        crate::util::chart::line_chart(
            "fig9: energy (mWh) vs delta_mAP",
            &series,
            60,
            14,
        )
    );
    h.save_json("fig9", &Json::Arr(out))
}
