//! Fleet sweep: sharded multi-gateway serving over synthesized
//! heterogeneous fleets (DESIGN.md §8).
//!
//! For each (fleet size, shard count, router) cell the driver
//! synthesizes a fresh fleet from the deployed Table-1 store, replays
//! the same pre-rendered request set through the discrete-event
//! simulator (sequential shared-heap at `fleet_threads = 1`, per-shard
//! heaps under the watermark merge above that — DESIGN.md §13), and
//! reports goodput, tail latency,
//! queueing delay, sheds, cross-shard fallbacks, shard imbalance, and
//! energy per request. This is where dispatch policy and shard count
//! become first-class experimental variables: a hash front-end keeps
//! shards independent but wastes capacity under skew, least-loaded
//! chases the global optimum at the cost of affinity, and sticky trades
//! balance for per-source estimator locality.

use anyhow::{Context, Result};

use super::serve::deployed_store;
use super::Harness;
use crate::dataset::{coco, GtBox, Scene};
use crate::fleet::parallel::{run_frames_threads, ParallelFleetSpec};
use crate::fleet::{DispatchPolicy, FleetConfig};
use crate::gateway::router_by_name;
use crate::util::json::Json;
use crate::workload::openloop::ArrivalProcess;

/// The `fleet` experiment: sweep fleet size x shard count x router.
pub fn fleet(h: &Harness) -> Result<()> {
    let n = h.cfg.fleet_requests.max(1);
    let ds = coco::build(n, h.cfg.seed ^ 0xF1EE);
    let frames: Vec<Scene> = ds.iter_scenes().collect();
    let gts: Vec<Vec<GtBox>> =
        frames.iter().map(|s| s.gt.clone()).collect();
    let base = deployed_store(h)?;
    let dispatch =
        DispatchPolicy::parse(&h.cfg.fleet_dispatch).with_context(|| {
            format!(
                "unknown dispatch policy '{}' (hash|least|sticky)",
                h.cfg.fleet_dispatch
            )
        })?;
    eprintln!(
        "[fleet] base {} pairs, {} requests @ {} req/s, dispatch {}, perturb ±{:.0}%",
        base.pairs().len(),
        n,
        h.cfg.fleet_rate_rps,
        dispatch.label(),
        100.0 * h.cfg.fleet_perturb
    );
    println!("--- fleet (size x shards x router sweep over {n} requests) ---");
    println!(
        "{:<6} {:>6} {:>7} {:>9} {:>9} {:>10} {:>6} {:>7} {:>10} {:>12} {:>8}",
        "router",
        "nodes",
        "shards",
        "goodput",
        "p99_ms",
        "qdelay_ms",
        "drop",
        "xshard",
        "imbalance",
        "mWh_per_req",
        "mAP"
    );
    let mut rows = Vec::new();
    for &size in &h.cfg.fleet_sizes {
        for &k in &h.cfg.fleet_shards {
            if k == 0 || k > size {
                continue;
            }
            for name in &h.cfg.fleet_routers {
                let spec = router_by_name(name)
                    .with_context(|| format!("unknown router '{name}'"))?;
                let fcfg = FleetConfig {
                    n_nodes: size,
                    n_shards: k,
                    perturb: h.cfg.fleet_perturb,
                    queue_capacity: h.cfg.queue_capacity,
                    dispatch,
                    n_sources: h.cfg.fleet_sources,
                    seed: h.cfg.seed,
                    drift: None,
                    churn: None,
                    slo: None,
                    adapt: None,
                    campaign: None,
                    obs: None,
                    threads: h.cfg.fleet_threads,
                };
                let report = run_frames_threads(
                    &ParallelFleetSpec {
                        artifacts_dir: h.artifacts_dir(),
                        base: &base,
                        spec,
                        delta_map: h.cfg.delta_map,
                    },
                    &fcfg,
                    &frames,
                    &gts,
                    &ArrivalProcess::Poisson {
                        rate_rps: h.cfg.fleet_rate_rps,
                    },
                    h.cfg.seed,
                )?;
                println!(
                    "{:<6} {:>6} {:>7} {:>9.2} {:>9.1} {:>10.1} {:>6} {:>7} {:>10.2} {:>12.4} {:>8.2}",
                    spec.name,
                    size,
                    k,
                    report.goodput_rps(),
                    1000.0 * report.latency_percentile(99.0),
                    1000.0 * report.mean_queue_delay_s(),
                    report.dropped,
                    report.cross_shard_fallbacks,
                    report.shard_imbalance(),
                    report.energy_per_request_mwh(),
                    report.map(),
                );
                rows.push(Json::obj(vec![
                    ("router", Json::str(spec.name)),
                    ("nodes", Json::num(size as f64)),
                    ("shards", Json::num(k as f64)),
                    ("dispatch", Json::str(dispatch.label())),
                    ("rate_rps", Json::num(h.cfg.fleet_rate_rps)),
                    ("report", report.to_json()),
                ]));
            }
        }
        println!();
    }
    h.save_json("fleet", &Json::Arr(rows))
}
