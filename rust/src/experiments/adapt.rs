//! Adaptation sweep: telemetry-driven profile correction and
//! energy-proportional autoscaling under device drift (DESIGN.md §12).
//!
//! For each (drift intensity, router, adaptation mode) cell the driver
//! deploys a fresh Table-1 pool, turns on thermal/battery drift scaled
//! by the intensity multiplier, and replays the same pre-rendered
//! request set through the open-loop simulator. Four arms isolate the
//! subsystem's two halves:
//!
//! * `static`   — drift on, adaptation off: the stale-profile baseline
//!   every other arm is measured against.
//! * `online`   — telemetry feedback published continuously
//!   (`publish_every = 0`), autoscaling off.
//! * `periodic` — telemetry published in epochs (every N samples),
//!   the classic re-profiling cadence expressed through the same
//!   corrector instead of a separate profiling pass.
//! * `scaled`   — continuous feedback plus the energy-proportional
//!   scaler powering surplus nodes down in arrival troughs.
//!
//! Reported per cell: goodput, p99, energy per request, corrected
//! pairs and mean correction factor, scaler transitions, and powered
//! node-seconds vs the always-on fleet. The headline comparison is
//! `static` vs `online` at each drift level: the corrector should buy
//! back tail latency and energy per request that stale profiles leak.

use anyhow::{Context, Result};

use super::serve::{build_gateway, deployed_store};
use super::Harness;
use crate::adapt::AdaptConfig;
use crate::dataset::{coco, GtBox, Scene};
use crate::devices::drift::DriftConfig;
use crate::gateway::router_by_name;
use crate::util::json::Json;
use crate::workload::openloop::{
    self, ArrivalProcess, OpenLoopConfig, OpenLoopReport,
};

/// How many telemetry samples one periodic epoch spans. Small enough
/// that even the smoke-sized sweep publishes at least once.
const PERIODIC_EPOCH: usize = 25;

/// Scale the default drift model by an intensity multiplier: hotter
/// accumulation and a noisier load walk, same throttle geometry.
fn drift_at(intensity: f64) -> DriftConfig {
    let base = DriftConfig::default();
    DriftConfig {
        heat_per_busy_s: base.heat_per_busy_s * intensity,
        load_walk_std: base.load_walk_std * intensity,
        ..base
    }
}

/// Run one (router, drift, mode) cell over shared pre-rendered frames.
fn run_cell(
    h: &Harness,
    spec: crate::gateway::RouterSpec,
    deployed: &crate::router::ProfileStore,
    frames: &[Scene],
    gts: &[Vec<GtBox>],
    drift: &DriftConfig,
    adapt: Option<AdaptConfig>,
) -> Result<OpenLoopReport> {
    let mut gw = build_gateway(h, spec, deployed, h.cfg.delta_map)?;
    gw.pool_mut().enable_drift(drift, h.cfg.seed);
    openloop::run_frames(
        &mut gw,
        frames,
        gts,
        &OpenLoopConfig {
            arrivals: ArrivalProcess::Poisson {
                rate_rps: h.cfg.adapt_rate_rps,
            },
            queue_capacity: h.cfg.queue_capacity,
            seed: h.cfg.seed,
            churn: None,
            slo: None,
            adapt,
            campaign: None,
            obs: None,
        },
    )
}

/// The `adapt` experiment: sweep drift intensity x router x mode.
pub fn adapt(h: &Harness) -> Result<()> {
    let n = h.cfg.adapt_requests.max(1);
    let ds = coco::build(n, h.cfg.seed ^ 0xADA9);
    let frames: Vec<Scene> = ds.iter_scenes().collect();
    let gts: Vec<Vec<GtBox>> =
        frames.iter().map(|s| s.gt.clone()).collect();
    let deployed = deployed_store(h)?;
    let base = h.cfg.adapt_config()?;
    eprintln!(
        "[adapt] pool {} pairs, {} requests @ {} req/s, drift x{:?}, alpha {}, epoch {}",
        deployed.pairs().len(),
        n,
        h.cfg.adapt_rate_rps,
        h.cfg.adapt_drift,
        base.alpha,
        PERIODIC_EPOCH
    );
    println!(
        "--- adapt (drift x router x adaptation over {n} requests) ---"
    );
    println!(
        "{:<6} {:>6} {:>9} {:>9} {:>9} {:>12} {:>6} {:>7} {:>9} {:>5} {:>5} {:>10}",
        "router",
        "drift",
        "mode",
        "goodput",
        "p99_ms",
        "mWh_per_req",
        "pairs",
        "corr",
        "node_s",
        "down",
        "up",
        "idle_mWh"
    );
    // arm order matters for the printed table: the static baseline
    // leads each (router, drift) block so the adaptive rows read as
    // deltas against it.
    let modes: Vec<(&str, Option<AdaptConfig>)> = vec![
        ("static", None),
        (
            "online",
            Some(AdaptConfig {
                publish_every: 0,
                scale: false,
                ..base.clone()
            }),
        ),
        (
            "periodic",
            Some(AdaptConfig {
                publish_every: PERIODIC_EPOCH,
                scale: false,
                ..base.clone()
            }),
        ),
        (
            "scaled",
            Some(AdaptConfig {
                publish_every: 0,
                scale: true,
                ..base.clone()
            }),
        ),
    ];
    let mut rows = Vec::new();
    for &intensity in &h.cfg.adapt_drift {
        let drift = drift_at(intensity);
        for name in &h.cfg.adapt_routers {
            let spec = router_by_name(name)
                .with_context(|| format!("unknown router '{name}'"))?;
            for (mode, adapt_cfg) in &modes {
                let report = run_cell(
                    h,
                    spec,
                    &deployed,
                    &frames,
                    &gts,
                    &drift,
                    adapt_cfg.clone(),
                )?;
                match report.adapt.as_ref() {
                    Some(a) => println!(
                        "{:<6} {:>6.1} {:>9} {:>9.2} {:>9.1} {:>12.4} {:>6} {:>7.3} {:>9.1} {:>5} {:>5} {:>10.3}",
                        spec.name,
                        intensity,
                        mode,
                        report.goodput_rps(),
                        1000.0 * report.metrics.latency_percentile(99.0),
                        report.energy_per_request_mwh(),
                        a.corrected_pairs,
                        a.mean_correction,
                        a.powered_node_s,
                        a.power_downs,
                        a.power_ups,
                        a.idle_energy_mwh,
                    ),
                    None => println!(
                        "{:<6} {:>6.1} {:>9} {:>9.2} {:>9.1} {:>12.4} {:>6} {:>7} {:>9} {:>5} {:>5} {:>10}",
                        spec.name,
                        intensity,
                        mode,
                        report.goodput_rps(),
                        1000.0 * report.metrics.latency_percentile(99.0),
                        report.energy_per_request_mwh(),
                        "-",
                        "-",
                        "-",
                        "-",
                        "-",
                        "-"
                    ),
                }
                rows.push(Json::obj(vec![
                    ("router", Json::str(spec.name)),
                    ("drift", Json::num(intensity)),
                    ("mode", Json::str(mode)),
                    ("rate_rps", Json::num(h.cfg.adapt_rate_rps)),
                    ("report", report.to_json()),
                ]));
            }
        }
        println!();
    }
    h.save_json("adapt", &Json::Arr(rows))
}
