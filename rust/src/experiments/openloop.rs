//! Open-loop saturation sweep: all configured routers under rising
//! Poisson arrival rates (DESIGN.md §6).
//!
//! For each (router, rate) cell the driver deploys a fresh Table-1
//! pool, replays the same pre-rendered scene set through the
//! discrete-event simulator, and reports tail latency (p50/p95/p99),
//! mean queueing delay, shed requests, and fallback re-routes alongside
//! the paper's energy/accuracy metrics. This is the experiment where
//! policy choice shows up as *queueing* behaviour: single-endpoint
//! policies (LE, LI, HM) saturate their champion node first, while the
//! group-aware policies spread load across the pool.

use anyhow::Result;

use super::serve::{build_gateway, deployed_store, selected_routers};
use super::Harness;
use crate::dataset::{coco, GtBox, Scene};
use crate::util::json::Json;
use crate::workload::openloop::{
    ArrivalProcess, OpenLoopConfig, OpenLoopReport,
};

/// Run one (router, arrival process) cell over shared pre-rendered
/// frames.
fn run_cell(
    h: &Harness,
    spec: crate::gateway::RouterSpec,
    deployed: &crate::router::ProfileStore,
    frames: &[Scene],
    gts: &[Vec<GtBox>],
    arrivals: ArrivalProcess,
    label: &str,
) -> Result<OpenLoopReport> {
    let mut gw = build_gateway(h, spec, deployed, h.cfg.delta_map)?;
    crate::workload::openloop::run_frames(
        &mut gw,
        frames,
        gts,
        &OpenLoopConfig {
            arrivals,
            queue_capacity: h.cfg.queue_capacity,
            seed: h.cfg.seed,
            churn: None,
            slo: None,
            adapt: None,
            campaign: None,
            obs: None,
        },
    )
    .map(|mut report| {
        report.metrics.label = format!("{}@{label}", spec.name);
        report
    })
}

/// The `openloop` experiment: sweep arrival rate x router.
pub fn openloop(h: &Harness) -> Result<()> {
    // a quarter of the closed-loop panel size: the sweep runs
    // routers x rates full cells. `--images` is honored down to 1.
    let n = (h.cfg.coco_images / 4).max(1);
    let ds = coco::build(n, h.cfg.seed ^ 0x0BE1);
    let deployed = deployed_store(h)?;
    let frames: Vec<Scene> = ds.iter_scenes().collect();
    let gts: Vec<Vec<GtBox>> =
        frames.iter().map(|s| s.gt.clone()).collect();
    let rates = &h.cfg.open_rates;
    eprintln!(
        "[openloop] pool: {} pairs, {} images, rates {:?} req/s, queue cap {}",
        deployed.pairs().len(),
        frames.len(),
        rates,
        h.cfg.queue_capacity
    );
    println!("--- openloop (saturation sweep over {n} images) ---");
    println!(
        "{:<6} {:>8} {:>9} {:>9} {:>9} {:>10} {:>6} {:>6} {:>8} {:>12}",
        "router",
        "rate",
        "p50_ms",
        "p95_ms",
        "p99_ms",
        "qdelay_ms",
        "drop",
        "fallbk",
        "mAP",
        "energy_mWh"
    );
    let mut rows = Vec::new();
    // the Poisson saturation sweep, then one bursty MMPP row per
    // router: a 2-phase process whose hot phase doubles the top rate
    // while the cold phase idles — same knob positions, clumped
    // arrivals, so queueing (not mean load) is what differs
    let top = rates.last().copied().unwrap_or(8.0);
    let cells: Vec<(ArrivalProcess, String, f64)> = rates
        .iter()
        .map(|&r| {
            (
                ArrivalProcess::Poisson { rate_rps: r },
                format!("{r}"),
                r,
            )
        })
        .chain(std::iter::once((
            ArrivalProcess::Mmpp {
                rates: [2.0 * top, top / 4.0],
                dwell_s: 0.5,
            },
            format!("mmpp{top}"),
            top,
        )))
        .collect();
    for (arrivals, label, rate) in &cells {
        for spec in selected_routers(h) {
            let report = run_cell(
                h,
                spec,
                &deployed,
                &frames,
                &gts,
                arrivals.clone(),
                label,
            )?;
            let m = &report.metrics;
            println!(
                "{:<6} {:>8.1} {:>9.1} {:>9.1} {:>9.1} {:>10.1} {:>6} {:>6} {:>8.2} {:>12.2}",
                spec.name,
                rate,
                1000.0 * m.latency_percentile(50.0),
                1000.0 * m.latency_percentile(95.0),
                1000.0 * m.latency_percentile(99.0),
                1000.0 * m.mean_queue_delay_s(),
                report.dropped,
                report.fallbacks,
                m.map(),
                m.total_energy_mwh(),
            );
            rows.push(Json::obj(vec![
                ("router", Json::str(spec.name)),
                ("arrivals", Json::str(label.as_str())),
                ("rate_rps", Json::num(*rate)),
                ("requests", Json::num(m.requests as f64)),
                ("dropped", Json::num(report.dropped as f64)),
                ("fallbacks", Json::num(report.fallbacks as f64)),
                (
                    "peak_in_flight",
                    Json::num(report.peak_in_flight as f64),
                ),
                ("makespan_s", Json::num(report.makespan_s)),
                ("goodput_rps", Json::num(report.goodput_rps())),
                (
                    "latency_p50_s",
                    Json::num(m.latency_percentile(50.0)),
                ),
                (
                    "latency_p95_s",
                    Json::num(m.latency_percentile(95.0)),
                ),
                (
                    "latency_p99_s",
                    Json::num(m.latency_percentile(99.0)),
                ),
                ("queue_delay_s", Json::num(m.queue_delay_s)),
                (
                    "mean_queue_delay_s",
                    Json::num(m.mean_queue_delay_s()),
                ),
                ("map", Json::num(m.map())),
                ("energy_mwh", Json::num(m.total_energy_mwh())),
            ]));
        }
        println!();
    }
    h.save_json("openloop", &Json::Arr(rows))
}
