//! SLO sweep: deadline attainment and batching under rising load
//! (DESIGN.md §11).
//!
//! For each (rate, router) pair the driver replays the same
//! pre-rendered request set through the open-loop simulator once per
//! batch-formation window, plus a no-SLO baseline row. The baseline row
//! shares the event stream with the plain `openloop` experiment
//! (admission control off, FIFO order, no batching), so every
//! difference in the SLO rows is attributable to the subsystem under
//! test: window 0 isolates admission control + EDF, and wider windows
//! add amortized batch dispatch on top. Reported per cell: goodput,
//! p99, energy per request, sheds, overall and per-class attainment,
//! and the mean dispatched batch size.

use anyhow::{Context, Result};

use super::serve::{build_gateway, deployed_store};
use super::Harness;
use crate::dataset::{coco, GtBox, Scene};
use crate::gateway::router_by_name;
use crate::util::json::Json;
use crate::workload::openloop::{
    self, ArrivalProcess, OpenLoopConfig, OpenLoopReport,
};
use crate::workload::slo::SloConfig;

fn run_cell(
    h: &Harness,
    spec: crate::gateway::RouterSpec,
    deployed: &crate::router::ProfileStore,
    frames: &[Scene],
    gts: &[Vec<GtBox>],
    rate_rps: f64,
    slo: Option<SloConfig>,
) -> Result<OpenLoopReport> {
    let mut gw = build_gateway(h, spec, deployed, h.cfg.delta_map)?;
    openloop::run_frames(
        &mut gw,
        frames,
        gts,
        &OpenLoopConfig {
            arrivals: ArrivalProcess::Poisson { rate_rps },
            queue_capacity: h.cfg.queue_capacity,
            seed: h.cfg.seed,
            churn: None,
            slo,
            adapt: None,
            campaign: None,
            obs: None,
        },
    )
}

/// The `slo` experiment: sweep rate x router x batch window.
pub fn slo(h: &Harness) -> Result<()> {
    let n = h.cfg.slo_requests.max(1);
    let ds = coco::build(n, h.cfg.seed ^ 0x510A);
    let frames: Vec<Scene> = ds.iter_scenes().collect();
    let gts: Vec<Vec<GtBox>> =
        frames.iter().map(|s| s.gt.clone()).collect();
    let deployed = deployed_store(h)?;
    let base = h.cfg.slo_config()?;
    eprintln!(
        "[slo] pool {} pairs, {} requests, rates {:?} req/s, windows {:?} s, classes {:?}, max batch {}",
        deployed.pairs().len(),
        n,
        h.cfg.slo_rate_rps,
        h.cfg.slo_windows_s,
        base.class_names(),
        base.max_batch
    );
    println!(
        "--- slo (rate x router x batch window over {n} requests) ---"
    );
    println!(
        "{:<6} {:>6} {:>9} {:>9} {:>9} {:>12} {:>6} {:>8} {:>7} {:>18}",
        "router",
        "rate",
        "window",
        "goodput",
        "p99_ms",
        "mWh_per_req",
        "shed",
        "attain%",
        "batch",
        "per-class attain%"
    );
    let mut rows = Vec::new();
    for &rate in &h.cfg.slo_rate_rps {
        for name in &h.cfg.slo_routers {
            let spec = router_by_name(name)
                .with_context(|| format!("unknown router '{name}'"))?;
            // baseline: no SLO subsystem at all (the openloop path)
            let baseline = run_cell(
                h, spec, &deployed, &frames, &gts, rate, None,
            )?;
            println!(
                "{:<6} {:>6.1} {:>9} {:>9.2} {:>9.1} {:>12.4} {:>6} {:>8} {:>7} {:>18}",
                spec.name,
                rate,
                "off",
                baseline.goodput_rps(),
                1000.0 * baseline.metrics.latency_percentile(99.0),
                baseline.energy_per_request_mwh(),
                baseline.dropped,
                "-",
                "-",
                "-"
            );
            rows.push(Json::obj(vec![
                ("router", Json::str(spec.name)),
                ("rate_rps", Json::num(rate)),
                ("slo", Json::Bool(false)),
                ("window_s", Json::Null),
                ("report", baseline.to_json()),
            ]));
            for &window in &h.cfg.slo_windows_s {
                let cfg = SloConfig {
                    batch_window_s: window,
                    ..base.clone()
                };
                let report = run_cell(
                    h,
                    spec,
                    &deployed,
                    &frames,
                    &gts,
                    rate,
                    Some(cfg),
                )?;
                let s = report.slo.as_ref().expect("slo block missing");
                let per: Vec<String> = s
                    .classes
                    .iter()
                    .enumerate()
                    .map(|(i, c)| {
                        format!("{c}:{:.0}", s.attainment_pct(i))
                    })
                    .collect();
                println!(
                    "{:<6} {:>6.1} {:>9.4} {:>9.2} {:>9.1} {:>12.4} {:>6} {:>8.1} {:>7.2} {:>18}",
                    spec.name,
                    rate,
                    window,
                    report.goodput_rps(),
                    1000.0 * report.metrics.latency_percentile(99.0),
                    report.energy_per_request_mwh(),
                    report.dropped,
                    s.overall_attainment_pct(),
                    s.mean_batch_size(),
                    per.join(" ")
                );
                rows.push(Json::obj(vec![
                    ("router", Json::str(spec.name)),
                    ("rate_rps", Json::num(rate)),
                    ("slo", Json::Bool(true)),
                    ("window_s", Json::num(window)),
                    ("max_batch", Json::num(base.max_batch as f64)),
                    ("report", report.to_json()),
                ]));
            }
        }
        println!();
    }
    h.save_json("slo", &Json::Arr(rows))
}
